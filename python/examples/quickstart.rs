fn main() {}
