// placeholder
