// placeholder
