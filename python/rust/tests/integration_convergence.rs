// placeholder
