// placeholder
