fn main() {}
