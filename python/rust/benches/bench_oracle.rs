fn main() {}
