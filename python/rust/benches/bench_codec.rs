fn main() {}
