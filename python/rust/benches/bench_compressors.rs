fn main() {}
