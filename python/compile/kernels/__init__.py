"""L1 Pallas kernels (build-time only; lowered into the L2 HLO artifacts).

Modules:
  - :mod:`.logreg`   - fused logistic-regression loss+grad over row tiles
  - :mod:`.lstsq`    - fused least-squares loss+grad (PL case)
  - :mod:`.compress` - magnitude-threshold mask (parallel half of Top-k)
  - :mod:`.ref`      - pure-jnp oracles the kernels are tested against
"""

from . import compress, logreg, lstsq, ref  # noqa: F401
