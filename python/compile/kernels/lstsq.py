"""L1 Pallas kernel: fused least-squares loss + gradient (PL case, SA.2).

Same tiling scheme as :mod:`.logreg` - one pass over row tiles of ``A``,
forward and backward matvec fused so ``A`` is read once - but with the
squared-error link, which is the paper's canonical PL-but-not-strongly-convex
objective (used for Figures 9-12).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .logreg import DEFAULT_TILE


def _lstsq_tile_kernel(a_ref, b_ref, w_ref, x_ref, g_ref, loss_ref):
    """One grid step: accumulate loss/grad of a (TILE, d) row block."""
    a = a_ref[...]
    b = b_ref[...]
    w = w_ref[...]
    x = x_ref[...]

    z = a @ x - b                      # residual (MXU + VPU)
    loss_part = jnp.sum(w * z * z)
    r = 2.0 * w * z
    g_part = r @ a                     # backward matvec (MXU)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    g_ref[...] += g_part
    loss_ref[...] += jnp.reshape(loss_part, (1,))


@functools.partial(jax.jit, static_argnames=("tile",))
def lstsq_loss_grad(a, b, w, x, *, tile: int = DEFAULT_TILE):
    """Mean-form least-squares loss and gradient via Pallas.

    Matches ``ref.lstsq_loss_grad``: loss = (1/n) sum w_i (a_i^T x - b_i)^2,
    grad = (2/n) A^T (w * (A x - b)), n = sum(w).
    """
    n_rows, d = a.shape
    if n_rows % tile != 0:
        raise ValueError(f"rows {n_rows} not divisible by tile {tile}")
    grid = (n_rows // tile,)
    g_sum, loss_sum = pl.pallas_call(
        _lstsq_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), a.dtype),
            jax.ShapeDtypeStruct((1,), a.dtype),
        ],
        interpret=True,
    )(a, b, w, x)
    n = jnp.sum(w)
    return loss_sum[0] / n, g_sum / n
