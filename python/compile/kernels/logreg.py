"""L1 Pallas kernel: fused logistic-regression loss + gradient (data term).

This is the per-worker compute hot spot of every experiment in the paper:
each of the ``n`` nodes evaluates ``f_i`` and ``grad f_i`` on its local shard
every communication round (Algorithm 2, line 5). The kernel fuses the
forward matvec ``z = A x``, the elementwise logistic link, and the backward
matvec ``g = A^T r`` into a single pass over row-tiles of ``A``, so each
tile of the data matrix is read from HBM exactly once.

TPU mapping (see DESIGN.md SHardware-Adaptation): the grid iterates over
``(TILE_N, d)`` blocks of ``A`` staged through VMEM by the BlockSpec; the
two matvecs are MXU ``dot``s; sigmoid/softplus ride the VPU between them;
the ``(d,)`` gradient accumulator lives in the output block that is revisited
by every grid step (constant index_map), which Pallas keeps resident in VMEM
across the whole grid. ``interpret=True`` is mandatory on this CPU-only
image - real TPU lowering emits a Mosaic custom-call the CPU PJRT plugin
cannot execute.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile height. 256 rows x 300 cols x 4 B = 300 KiB per A-block: three
# such buffers (double-buffered input + accumulator) sit comfortably in a
# 16 MiB TPU VMEM while keeping the MXU fed with (256, d) x (d, 1) dots.
DEFAULT_TILE = 256


def _logreg_tile_kernel(a_ref, y_ref, w_ref, x_ref, g_ref, loss_ref):
    """One grid step: accumulate loss and gradient of a (TILE, d) row block."""
    a = a_ref[...]  # (TILE, d)  f32, staged in VMEM
    y = y_ref[...]  # (TILE,)
    w = w_ref[...]  # (TILE,)    0/1 validity mask (zero-padded rows)
    x = x_ref[...]  # (d,)       model, replicated to every grid step

    # Forward matvec (MXU): margins for this tile.
    z = a @ x
    m = -y * z
    # Stable softplus on the VPU: log(1+e^m) = max(m,0) + log1p(e^{-|m|}).
    loss_part = jnp.sum(w * (jnp.maximum(m, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(m)))))
    # Residual and backward matvec (MXU): r^T A gives the tile's grad share.
    r = w * (-y) * jax.nn.sigmoid(m)
    g_part = r @ a

    # First grid step initializes the revisited accumulators.
    @pl.when(pl.program_id(0) == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)

    g_ref[...] += g_part
    loss_ref[...] += jnp.reshape(loss_part, (1,))


@functools.partial(jax.jit, static_argnames=("tile",))
def logreg_data_loss_grad(a, y, w, x, *, tile: int = DEFAULT_TILE):
    """Sum-form loss and gradient of the logistic data term via Pallas.

    Returns ``(loss, grad)`` already divided by ``n = sum(w)``, matching
    ``ref.logreg_loss_grad``. Row count must be divisible by ``tile``; the
    L2 wrapper (``model.pad_shard``) guarantees this by zero-padding and
    masking with ``w``.
    """
    n_rows, d = a.shape
    if n_rows % tile != 0:
        raise ValueError(f"rows {n_rows} not divisible by tile {tile}")
    grid = (n_rows // tile,)
    g_sum, loss_sum = pl.pallas_call(
        _logreg_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, d), lambda i: (i, 0)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((d,), a.dtype),
            jax.ShapeDtypeStruct((1,), a.dtype),
        ],
        interpret=True,
    )(a, y, w, x)
    n = jnp.sum(w)
    return loss_sum[0] / n, g_sum / n
