"""Pure-jnp reference oracles for the Pallas kernels (L1 correctness signal).

Every kernel in this package has an exact counterpart here, written with
plain ``jax.numpy`` ops only. ``python/tests`` asserts ``allclose`` between
the kernel and the reference across a hypothesis-driven sweep of shapes and
dtypes; the Rust side additionally checks its pure-Rust oracle against the
AOT artifact built from these kernels, closing the three-way loop

    pure-Rust oracle  ==  HLO artifact (Pallas kernel)  ==  ref.py
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

__all__ = [
    "softplus",
    "logreg_loss_grad",
    "logreg_reg_term",
    "logreg_full_loss_grad",
    "lstsq_loss_grad",
    "threshold_mask",
    "topk_dense",
]


def softplus(z: jax.Array) -> jax.Array:
    """Numerically stable log(1 + exp(z))."""
    return jnp.maximum(z, 0.0) + jnp.log1p(jnp.exp(-jnp.abs(z)))


def logreg_loss_grad(a, y, w, x):
    """Weighted logistic-regression data term: loss and gradient.

    loss = (1/n) sum_i w_i * log(1 + exp(-y_i a_i^T x)),  n = sum_i w_i
    grad = (1/n) sum_i w_i * (-y_i) * sigmoid(-y_i a_i^T x) * a_i

    ``w`` is a 0/1 row-validity mask so that zero-padded shards (needed for
    the static-shape AOT artifact) contribute nothing.
    """
    n = jnp.sum(w)
    z = a @ x
    m = -y * z
    loss = jnp.sum(w * softplus(m)) / n
    r = w * (-y) * jax.nn.sigmoid(m)
    grad = (r @ a) / n
    return loss, grad


def logreg_reg_term(x, lam):
    """Nonconvex regularizer of Eq. (19): lam * sum_j x_j^2/(1+x_j^2)."""
    x2 = x * x
    reg = lam * jnp.sum(x2 / (1.0 + x2))
    reg_grad = lam * 2.0 * x / ((1.0 + x2) ** 2)
    return reg, reg_grad


def logreg_full_loss_grad(a, y, w, x, lam):
    """Eq. (19) on one shard: data term + nonconvex regularizer."""
    loss, grad = logreg_loss_grad(a, y, w, x)
    reg, reg_grad = logreg_reg_term(x, lam)
    return loss + reg, grad + reg_grad


def lstsq_loss_grad(a, b, w, x):
    """Weighted least squares (PL case, paper SA.2).

    loss = (1/n) sum_i w_i (a_i^T x - b_i)^2
    grad = (2/n) A^T (w * (A x - b))
    """
    n = jnp.sum(w)
    z = a @ x - b
    loss = jnp.sum(w * z * z) / n
    grad = (2.0 / n) * ((w * z) @ a)
    return loss, grad


def threshold_mask(v, thresh):
    """Keep entries with |v_j| >= thresh, zero the rest.

    This is the data-parallel half of Top-k: the host selects the k-th
    largest magnitude as ``thresh``; the accelerator applies the mask.
    """
    return jnp.where(jnp.abs(v) >= thresh, v, 0.0)


def topk_dense(v, k):
    """Dense Top-k compressor output (keeps k largest-magnitude entries)."""
    d = v.shape[0]
    if k >= d:
        return v
    idx = jnp.argsort(-jnp.abs(v), stable=True)[:k]
    out = jnp.zeros_like(v)
    return out.at[idx].set(v[idx])
