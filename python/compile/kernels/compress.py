"""L1 Pallas kernel: magnitude-threshold compression mask.

Top-k splits naturally into a sequential part (selecting the k-th largest
magnitude - done on the host / in Rust via ``select_nth_unstable``) and a
perfectly data-parallel part (zeroing every entry below the threshold).
This kernel implements the parallel part, tiled over the vector so that
arbitrarily large gradients (the DL experiment compresses ~0.7M floats)
stream through VMEM in fixed-size chunks.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_VTILE = 4096


def _mask_tile_kernel(v_ref, t_ref, o_ref):
    v = v_ref[...]
    t = t_ref[...]  # (1,) threshold, replicated to every tile
    o_ref[...] = jnp.where(jnp.abs(v) >= t[0], v, jnp.zeros_like(v))


@functools.partial(jax.jit, static_argnames=("tile",))
def threshold_mask(v, thresh, *, tile: int = DEFAULT_VTILE):
    """Zero all entries of ``v`` with ``|v_j| < thresh``; keep the rest.

    ``thresh`` is a shape-(1,) array. Length must divide into ``tile``; the
    caller pads (padding entries are zero and stay zero under any mask).
    """
    (n,) = v.shape
    if n % tile != 0:
        raise ValueError(f"length {n} not divisible by tile {tile}")
    grid = (n // tile,)
    return pl.pallas_call(
        _mask_tile_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile,), lambda i: (i,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((n,), v.dtype),
        interpret=True,
    )(v, thresh)
