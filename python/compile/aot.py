"""AOT lowering: JAX (L2, calling Pallas L1) -> HLO text artifacts + manifest.

Run once at build time (``make artifacts``); the Rust coordinator loads the
results via ``HloModuleProto::from_text_file`` and never touches Python.

Interchange format is HLO **text**, not a serialized ``HloModuleProto``:
jax >= 0.5 emits protos with 64-bit instruction ids which the ``xla`` crate's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly (see /opt/xla-example/README.md).

Artifacts (shapes are static, so shards are zero-padded + masked):

  logreg_grad_<ds>   (a, y, w, x, lam) -> (loss, grad)     per Table-3 dataset
  lstsq_grad_<ds>    (a, b, w, x)      -> (loss, grad)     per Table-3 dataset
  transformer_step   (flat, tokens)    -> (loss, grad)     DL experiment
  transformer_eval   (flat, tokens)    -> (loss, acc)      DL experiment
  compress_mask      (v, thresh)       -> (masked,)        Top-k parallel half
"""

from __future__ import annotations

import argparse
import json
import math
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model
from .kernels import compress as kcompress
from .kernels import logreg as klogreg

# Table 3 of the paper: (name, N, d). Shards = 20-way contiguous split,
# first 19 workers get floor(N/20) rows, the last additionally the remainder.
DATASETS = [
    ("phishing", 11055, 68),
    ("mushrooms", 8120, 112),
    ("a9a", 32560, 123),
    ("w8a", 49749, 300),
]
N_WORKERS = 20

# DL experiment (Figures 13-15 substitute).
TRANSFORMER_SPEC = model.TransformerSpec(
    vocab=256, d_model=128, n_layers=2, n_heads=4, seq_len=64
)
TRANSFORMER_BATCH = 8


def max_shard_rows(n_total: int, n_workers: int = N_WORKERS) -> int:
    base = n_total // n_workers
    return base + n_total % n_workers


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (id-safe interchange)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def _io(name, shape, dtype):
    return {"name": name, "shape": list(shape), "dtype": dtype}


def build_entries():
    """Yield (artifact_name, jitted_fn, example_specs, manifest_meta)."""
    entries = []

    for ds_name, n_total, d in DATASETS:
        n_pad = model.padded_rows(max_shard_rows(n_total))

        def logreg_fn(a, y, w, x, lam):
            return model.logreg_loss_grad(a, y, w, x, lam)

        entries.append(
            dict(
                name=f"logreg_grad_{ds_name}",
                fn=logreg_fn,
                specs=[
                    _spec((n_pad, d)),
                    _spec((n_pad,)),
                    _spec((n_pad,)),
                    _spec((d,)),
                    _spec(()),
                ],
                inputs=[
                    _io("a", (n_pad, d), "f32"),
                    _io("y", (n_pad,), "f32"),
                    _io("w", (n_pad,), "f32"),
                    _io("x", (d,), "f32"),
                    _io("lam", (), "f32"),
                ],
                outputs=[_io("loss", (), "f32"), _io("grad", (d,), "f32")],
                meta=dict(
                    kind="logreg",
                    dataset=ds_name,
                    n_total=n_total,
                    d=d,
                    n_rows_padded=n_pad,
                    tile=klogreg.DEFAULT_TILE,
                    n_workers=N_WORKERS,
                ),
            )
        )

        def lstsq_fn(a, b, w, x):
            return model.lstsq_loss_grad(a, b, w, x)

        entries.append(
            dict(
                name=f"lstsq_grad_{ds_name}",
                fn=lstsq_fn,
                specs=[
                    _spec((n_pad, d)),
                    _spec((n_pad,)),
                    _spec((n_pad,)),
                    _spec((d,)),
                ],
                inputs=[
                    _io("a", (n_pad, d), "f32"),
                    _io("b", (n_pad,), "f32"),
                    _io("w", (n_pad,), "f32"),
                    _io("x", (d,), "f32"),
                ],
                outputs=[_io("loss", (), "f32"), _io("grad", (d,), "f32")],
                meta=dict(
                    kind="lstsq",
                    dataset=ds_name,
                    n_total=n_total,
                    d=d,
                    n_rows_padded=n_pad,
                    tile=klogreg.DEFAULT_TILE,
                    n_workers=N_WORKERS,
                ),
            )
        )

    spec = TRANSFORMER_SPEC
    n_params = spec.n_params
    bsz, slen = TRANSFORMER_BATCH, spec.seq_len

    def tr_step(flat, tokens):
        return model.transformer_loss_and_grad(spec, flat, tokens)

    def tr_eval(flat, tokens):
        return model.transformer_eval(spec, flat, tokens)

    tr_meta = dict(
        kind="transformer",
        vocab=spec.vocab,
        d_model=spec.d_model,
        n_layers=spec.n_layers,
        n_heads=spec.n_heads,
        seq_len=slen,
        batch=bsz,
        n_params=n_params,
        param_shapes=[[n, list(s)] for n, s in spec.param_shapes()],
    )
    entries.append(
        dict(
            name="transformer_step",
            fn=tr_step,
            specs=[_spec((n_params,)), _spec((bsz, slen), jnp.int32)],
            inputs=[
                _io("flat_params", (n_params,), "f32"),
                _io("tokens", (bsz, slen), "i32"),
            ],
            outputs=[_io("loss", (), "f32"), _io("grad", (n_params,), "f32")],
            meta=tr_meta,
        )
    )
    entries.append(
        dict(
            name="transformer_eval",
            fn=tr_eval,
            specs=[_spec((n_params,)), _spec((bsz, slen), jnp.int32)],
            inputs=[
                _io("flat_params", (n_params,), "f32"),
                _io("tokens", (bsz, slen), "i32"),
            ],
            outputs=[_io("loss", (), "f32"), _io("accuracy", (), "f32")],
            meta=tr_meta,
        )
    )

    # Threshold mask sized for the transformer gradient (padded to the
    # vector tile); Rust zero-pads the tail before invoking.
    vtile = kcompress.DEFAULT_VTILE
    n_mask = vtile * math.ceil(n_params / vtile)

    def mask_fn(v, thresh):
        return (kcompress.threshold_mask(v, thresh),)

    entries.append(
        dict(
            name="compress_mask",
            fn=mask_fn,
            specs=[_spec((n_mask,)), _spec((1,))],
            inputs=[_io("v", (n_mask,), "f32"), _io("thresh", (1,), "f32")],
            outputs=[_io("masked", (n_mask,), "f32")],
            meta=dict(kind="compress_mask", n=n_mask, tile=vtile),
        )
    )
    return entries


def main() -> None:
    parser = argparse.ArgumentParser(description=__doc__)
    parser.add_argument("--out-dir", default="../artifacts")
    parser.add_argument("--only", default=None, help="lower a single artifact")
    args = parser.parse_args()

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {}
    for e in build_entries():
        if args.only and e["name"] != args.only:
            continue
        lowered = jax.jit(e["fn"]).lower(*e["specs"])
        text = to_hlo_text(lowered)
        fname = f"{e['name']}.hlo.txt"
        path = os.path.join(args.out_dir, fname)
        with open(path, "w") as f:
            f.write(text)
        manifest[e["name"]] = dict(
            file=fname,
            inputs=e["inputs"],
            outputs=e["outputs"],
            meta=e["meta"],
        )
        print(f"lowered {e['name']:28s} -> {fname} ({len(text)} chars)")

    mpath = os.path.join(args.out_dir, "manifest.json")
    if args.only and os.path.exists(mpath):
        with open(mpath) as f:
            old = json.load(f)
        old.update(manifest)
        manifest = old
    with open(mpath, "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"wrote manifest with {len(manifest)} entries -> {mpath}")


if __name__ == "__main__":
    main()
