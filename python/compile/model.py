"""L2: the paper's compute graphs in JAX, calling the L1 Pallas kernels.

Three model families, matching the paper's three experiment suites:

  1. ``logreg_loss_grad``      - nonconvex-regularized logistic regression,
                                 Eq. (19); Figures 1-8.
  2. ``lstsq_loss_grad``       - least squares (PL but not strongly convex);
                                 Figures 9-12.
  3. ``transformer_*``         - small causal transformer LM, the tractable
                                 stand-in for the ResNet18/VGG11 CIFAR-10
                                 appendix (SA.3); Figures 13-15.

Everything here is build-time Python: ``aot.py`` lowers these functions once
to HLO text; the Rust coordinator executes the artifacts via PJRT and never
imports this module.
"""

from __future__ import annotations

import dataclasses
import math
from typing import List, Tuple

import jax
import jax.numpy as jnp

from .kernels import compress as kcompress
from .kernels import logreg as klogreg
from .kernels import lstsq as klstsq
from .kernels import ref

# ---------------------------------------------------------------------------
# Shard padding (static shapes for AOT)
# ---------------------------------------------------------------------------


def padded_rows(n_rows: int, tile: int = klogreg.DEFAULT_TILE) -> int:
    """Smallest multiple of ``tile`` that is >= n_rows (and >= tile)."""
    return max(tile, tile * math.ceil(n_rows / tile))


def pad_shard(a, y, tile: int = klogreg.DEFAULT_TILE):
    """Zero-pad a shard to a tile multiple; returns (a_pad, y_pad, w)."""
    import numpy as np

    n, d = a.shape
    n_pad = padded_rows(n, tile)
    a_pad = np.zeros((n_pad, d), dtype=np.float32)
    y_pad = np.zeros((n_pad,), dtype=np.float32)
    w = np.zeros((n_pad,), dtype=np.float32)
    a_pad[:n] = a
    y_pad[:n] = y
    w[:n] = 1.0
    return a_pad, y_pad, w


# ---------------------------------------------------------------------------
# 1. Nonconvex logistic regression (Eq. 19)
# ---------------------------------------------------------------------------


def logreg_loss_grad(a, y, w, x, lam):
    """Loss and gradient of Eq. (19) on one (padded) shard.

    Data term via the fused Pallas kernel (one pass over A); the O(d)
    nonconvex-regularizer term is added outside the kernel.
    """
    loss, grad = klogreg.logreg_data_loss_grad(a, y, w, x)
    reg, reg_grad = ref.logreg_reg_term(x, lam)
    return loss + reg, grad + reg_grad


# ---------------------------------------------------------------------------
# 2. Least squares (PL case)
# ---------------------------------------------------------------------------


def lstsq_loss_grad(a, b, w, x):
    """Loss and gradient of the least-squares objective on one shard."""
    return klstsq.lstsq_loss_grad(a, b, w, x)


# ---------------------------------------------------------------------------
# 3. Compression mask (exported so Rust can offload masking to the artifact)
# ---------------------------------------------------------------------------


def compress_mask(v, thresh):
    """Threshold mask over a padded flat vector (parallel half of Top-k)."""
    return kcompress.threshold_mask(v, thresh)


# ---------------------------------------------------------------------------
# 4. Small causal transformer LM (DL experiment substitute)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class TransformerSpec:
    """Architecture of the flat-parameter causal LM.

    Parameters are exchanged between Rust and the artifact as ONE flat f32
    vector: Rust owns the optimizer/compressor state over that vector and
    never needs to know the pytree structure.
    """

    vocab: int = 256
    d_model: int = 128
    n_layers: int = 2
    n_heads: int = 4
    seq_len: int = 64
    mlp_mult: int = 4

    @property
    def d_head(self) -> int:
        assert self.d_model % self.n_heads == 0
        return self.d_model // self.n_heads

    def param_shapes(self) -> List[Tuple[str, Tuple[int, ...]]]:
        """Ordered (name, shape) list defining the flat layout."""
        d, v, s, m = self.d_model, self.vocab, self.seq_len, self.mlp_mult
        shapes: List[Tuple[str, Tuple[int, ...]]] = [
            ("tok_emb", (v, d)),
            ("pos_emb", (s, d)),
        ]
        for layer in range(self.n_layers):
            p = f"l{layer}."
            shapes += [
                (p + "ln1_g", (d,)),
                (p + "ln1_b", (d,)),
                (p + "wqkv", (d, 3 * d)),
                (p + "wo", (d, d)),
                (p + "ln2_g", (d,)),
                (p + "ln2_b", (d,)),
                (p + "w1", (d, m * d)),
                (p + "b1", (m * d,)),
                (p + "w2", (m * d, d)),
                (p + "b2", (d,)),
            ]
        shapes += [
            ("lnf_g", (d,)),
            ("lnf_b", (d,)),
            ("head", (d, v)),
        ]
        return shapes

    @property
    def n_params(self) -> int:
        return sum(int(jnp.prod(jnp.array(s))) for _, s in self.param_shapes())


def unflatten(spec: TransformerSpec, flat):
    """Split the flat f32 vector into the named parameter dict."""
    params = {}
    off = 0
    for name, shape in spec.param_shapes():
        size = int(math.prod(shape))
        params[name] = flat[off : off + size].reshape(shape)
        off += size
    return params


def init_flat_params(spec: TransformerSpec, seed: int = 0):
    """Scaled-Gaussian init, returned as the flat vector."""
    key = jax.random.PRNGKey(seed)
    chunks = []
    for name, shape in spec.param_shapes():
        key, sub = jax.random.split(key)
        if name.endswith(("_g",)):
            chunk = jnp.ones(shape, jnp.float32)
        elif name.endswith(("_b", "b1", "b2")):
            chunk = jnp.zeros(shape, jnp.float32)
        else:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            scale = 1.0 / math.sqrt(fan_in)
            chunk = scale * jax.random.normal(sub, shape, jnp.float32)
        chunks.append(chunk.reshape(-1))
    return jnp.concatenate(chunks)


def _layer_norm(x, g, b, eps=1e-5):
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.var(x, axis=-1, keepdims=True)
    return (x - mu) * jax.lax.rsqrt(var + eps) * g + b


def _attention(spec: TransformerSpec, p, prefix, h):
    b, s, d = h.shape
    nh, dh = spec.n_heads, spec.d_head
    qkv = h @ p[prefix + "wqkv"]  # (b, s, 3d)
    q, k, v = jnp.split(qkv, 3, axis=-1)
    q = q.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, nh, dh).transpose(0, 2, 1, 3)
    att = (q @ k.transpose(0, 1, 3, 2)) / math.sqrt(dh)  # (b, nh, s, s)
    mask = jnp.tril(jnp.ones((s, s), bool))
    att = jnp.where(mask, att, -1e9)
    att = jax.nn.softmax(att, axis=-1)
    out = (att @ v).transpose(0, 2, 1, 3).reshape(b, s, d)
    return out @ p[prefix + "wo"]


def transformer_logits(spec: TransformerSpec, flat, tokens):
    """Causal-LM logits. tokens: (B, S) int32, S == spec.seq_len."""
    p = unflatten(spec, flat)
    b, s = tokens.shape
    h = p["tok_emb"][tokens] + p["pos_emb"][None, :s, :]
    for layer in range(spec.n_layers):
        pre = f"l{layer}."
        h = h + _attention(spec, p, pre, _layer_norm(h, p[pre + "ln1_g"], p[pre + "ln1_b"]))
        hh = _layer_norm(h, p[pre + "ln2_g"], p[pre + "ln2_b"])
        hh = jax.nn.gelu(hh @ p[pre + "w1"] + p[pre + "b1"]) @ p[pre + "w2"] + p[pre + "b2"]
        h = h + hh
    h = _layer_norm(h, p["lnf_g"], p["lnf_b"])
    return h @ p["head"]  # (B, S, vocab)


def transformer_loss(spec: TransformerSpec, flat, tokens):
    """Mean next-token cross entropy: predict tokens[:,1:] from tokens[:,:-1]."""
    logits = transformer_logits(spec, flat, tokens)[:, :-1, :]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    return jnp.mean(nll)


def transformer_loss_and_grad(spec: TransformerSpec, flat, tokens):
    """(loss, flat gradient) - the per-worker step of Algorithm 5."""
    loss, grad = jax.value_and_grad(lambda f: transformer_loss(spec, f, tokens))(flat)
    return loss, grad


def transformer_eval(spec: TransformerSpec, flat, tokens):
    """(loss, next-token accuracy) on an eval batch."""
    logits = transformer_logits(spec, flat, tokens)[:, :-1, :]
    targets = tokens[:, 1:]
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, targets[..., None], axis=-1)[..., 0]
    acc = jnp.mean((jnp.argmax(logits, axis=-1) == targets).astype(jnp.float32))
    return jnp.mean(nll), acc
