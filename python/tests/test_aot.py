"""AOT pipeline: entries are well-formed and the HLO-text bridge works."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model

ARTIFACT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")


def test_entries_have_unique_names_and_consistent_specs():
    entries = aot.build_entries()
    names = [e["name"] for e in entries]
    assert len(names) == len(set(names))
    for e in entries:
        assert len(e["specs"]) == len(e["inputs"])
        for spec, io in zip(e["specs"], e["inputs"]):
            assert tuple(io["shape"]) == tuple(spec.shape), e["name"]


def test_dataset_entries_match_table3():
    entries = {e["name"]: e for e in aot.build_entries()}
    for name, n_total, d in aot.DATASETS:
        meta = entries[f"logreg_grad_{name}"]["meta"]
        assert meta["n_total"] == n_total and meta["d"] == d
        # padded rows hold the largest shard (base + remainder)
        largest = n_total // aot.N_WORKERS + n_total % aot.N_WORKERS
        assert meta["n_rows_padded"] >= largest
        assert meta["n_rows_padded"] % meta["tile"] == 0


def test_max_shard_rows():
    assert aot.max_shard_rows(100, 20) == 5
    assert aot.max_shard_rows(101, 20) == 6
    assert aot.max_shard_rows(11055, 20) == 552 + 15


def test_to_hlo_text_roundtrips_a_tiny_function():
    def fn(x):
        return (x * 2.0 + 1.0,)

    lowered = jax.jit(fn).lower(jax.ShapeDtypeStruct((4,), jnp.float32))
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ENTRY" in text


def test_logreg_entry_executes_and_matches_ref():
    """Execute the jitted entry function (pre-lowering) against ref.py."""
    from compile.kernels import ref

    entries = {e["name"]: e for e in aot.build_entries()}
    e = entries["logreg_grad_phishing"]
    n_pad = e["meta"]["n_rows_padded"]
    d = e["meta"]["d"]
    rng = np.random.default_rng(0)
    n = 100
    a = np.zeros((n_pad, d), np.float32)
    y = np.zeros((n_pad,), np.float32)
    w = np.zeros((n_pad,), np.float32)
    a[:n] = rng.normal(size=(n, d))
    y[:n] = rng.choice([-1.0, 1.0], size=n)
    w[:n] = 1.0
    x = rng.normal(size=d).astype(np.float32)
    loss, grad = e["fn"](a, y, w, x, jnp.float32(0.1))
    rl, rg = ref.logreg_full_loss_grad(a, y, w, x, 0.1)
    np.testing.assert_allclose(loss, rl, rtol=1e-5)
    np.testing.assert_allclose(grad, rg, rtol=1e-4, atol=1e-5)


@pytest.mark.skipif(
    not os.path.exists(os.path.join(ARTIFACT_DIR, "manifest.json")),
    reason="artifacts not built (run `make artifacts`)",
)
def test_manifest_files_exist_and_parse():
    with open(os.path.join(ARTIFACT_DIR, "manifest.json")) as f:
        manifest = json.load(f)
    expected = {e["name"] for e in aot.build_entries()}
    assert expected.issubset(set(manifest))
    for name, entry in manifest.items():
        path = os.path.join(ARTIFACT_DIR, entry["file"])
        assert os.path.exists(path), name
        with open(path) as f:
            head = f.read(256)
        assert "HloModule" in head, name


def test_transformer_meta_matches_spec():
    entries = {e["name"]: e for e in aot.build_entries()}
    meta = entries["transformer_step"]["meta"]
    spec = aot.TRANSFORMER_SPEC
    assert meta["n_params"] == spec.n_params
    assert meta["seq_len"] == spec.seq_len
    flat_sizes = sum(int(np.prod(s)) for _, s in meta["param_shapes"])
    assert flat_sizes == spec.n_params
