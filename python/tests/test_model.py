"""L2 correctness: model graphs (shapes, gradients, training signal)."""

import math

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from compile import model

SPEC = model.TransformerSpec(vocab=64, d_model=32, n_layers=2, n_heads=4, seq_len=16)


def test_param_shapes_cover_flat_vector_exactly():
    flat = model.init_flat_params(SPEC, seed=0)
    assert flat.shape == (SPEC.n_params,)
    params = model.unflatten(SPEC, flat)
    total = sum(int(np.prod(p.shape)) for p in params.values())
    assert total == SPEC.n_params


def test_unflatten_layout_is_contiguous_and_ordered():
    flat = jnp.arange(SPEC.n_params, dtype=jnp.float32)
    params = model.unflatten(SPEC, flat)
    off = 0
    for name, shape in SPEC.param_shapes():
        size = int(math.prod(shape))
        np.testing.assert_array_equal(
            np.asarray(params[name]).reshape(-1),
            np.arange(off, off + size, dtype=np.float32),
        )
        off += size


def test_logits_shape():
    flat = model.init_flat_params(SPEC, seed=1)
    toks = jnp.zeros((3, SPEC.seq_len), jnp.int32)
    logits = model.transformer_logits(SPEC, flat, toks)
    assert logits.shape == (3, SPEC.seq_len, SPEC.vocab)


def test_initial_loss_close_to_uniform():
    flat = model.init_flat_params(SPEC, seed=2)
    rng = np.random.default_rng(0)
    toks = rng.integers(0, SPEC.vocab, size=(4, SPEC.seq_len)).astype(np.int32)
    loss = float(model.transformer_loss(SPEC, flat, toks))
    assert abs(loss - math.log(SPEC.vocab)) < 1.0


def test_causality_future_tokens_do_not_affect_past_logits():
    flat = model.init_flat_params(SPEC, seed=3)
    rng = np.random.default_rng(1)
    toks = rng.integers(0, SPEC.vocab, size=(1, SPEC.seq_len)).astype(np.int32)
    toks2 = toks.copy()
    toks2[0, -1] = (toks2[0, -1] + 1) % SPEC.vocab
    l1 = model.transformer_logits(SPEC, flat, jnp.asarray(toks))
    l2 = model.transformer_logits(SPEC, flat, jnp.asarray(toks2))
    np.testing.assert_allclose(
        np.asarray(l1[0, : SPEC.seq_len - 1]),
        np.asarray(l2[0, : SPEC.seq_len - 1]),
        atol=1e-5,
    )


def test_grad_matches_finite_difference_along_random_direction():
    flat = model.init_flat_params(SPEC, seed=4)
    rng = np.random.default_rng(2)
    toks = rng.integers(0, SPEC.vocab, size=(2, SPEC.seq_len)).astype(np.int32)
    _, grad = model.transformer_loss_and_grad(SPEC, flat, toks)
    u = rng.normal(size=SPEC.n_params).astype(np.float32)
    u /= np.linalg.norm(u)
    eps = 1e-2
    lp = float(model.transformer_loss(SPEC, flat + eps * u, toks))
    lm = float(model.transformer_loss(SPEC, flat - eps * u, toks))
    fd = (lp - lm) / (2 * eps)
    an = float(jnp.dot(grad, u))
    assert abs(fd - an) < 5e-3, (fd, an)


def test_gd_steps_decrease_loss():
    flat = model.init_flat_params(SPEC, seed=5)
    rng = np.random.default_rng(3)
    toks = rng.integers(0, SPEC.vocab, size=(4, SPEC.seq_len)).astype(np.int32)
    loss0, grad = model.transformer_loss_and_grad(SPEC, flat, toks)
    for _ in range(5):
        flat = flat - 0.5 * grad
        loss, grad = model.transformer_loss_and_grad(SPEC, flat, toks)
    assert float(loss) < float(loss0)


def test_eval_reports_accuracy_in_unit_interval():
    flat = model.init_flat_params(SPEC, seed=6)
    rng = np.random.default_rng(4)
    toks = rng.integers(0, SPEC.vocab, size=(2, SPEC.seq_len)).astype(np.int32)
    loss, acc = model.transformer_eval(SPEC, flat, toks)
    assert 0.0 <= float(acc) <= 1.0
    assert float(loss) > 0.0


def test_padded_rows_properties():
    t = 256
    assert model.padded_rows(1, t) == t
    assert model.padded_rows(t, t) == t
    assert model.padded_rows(t + 1, t) == 2 * t
    for n in [3, 100, 999, 5000]:
        p = model.padded_rows(n, t)
        assert p >= n and p % t == 0 and p - n < t


def test_pad_shard_masks_only_real_rows():
    rng = np.random.default_rng(5)
    a = rng.normal(size=(300, 7)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=300).astype(np.float32)
    ap, yp, w = model.pad_shard(a, y)
    assert ap.shape[0] % 256 == 0
    assert w.sum() == 300
    np.testing.assert_array_equal(ap[300:], 0.0)
    np.testing.assert_array_equal(yp[300:], 0.0)


def test_regularizer_is_bounded_and_nonconvex_shape():
    # reg(x) = lam * sum x^2/(1+x^2) is bounded by lam*d; grad -> 0 at inf.
    from compile.kernels import ref

    lam = 0.1
    d = 13
    x_big = 1e4 * np.ones(d, np.float32)
    reg, reg_grad = ref.logreg_reg_term(jnp.asarray(x_big), lam)
    assert float(reg) <= lam * d + 1e-4
    assert float(jnp.linalg.norm(reg_grad)) < 1e-6
