"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes/values; explicit cases pin the tile boundaries and
degenerate masks. This is the CORE correctness signal for the compute that
ends up inside the AOT artifacts.
"""

import numpy as np
import jax.numpy as jnp
import pytest
from hypothesis import given, settings, strategies as st

from compile import model
from compile.kernels import compress, logreg, lstsq, ref

settings.register_profile("kernels", deadline=None, max_examples=25)
settings.load_profile("kernels")


def _shard(seed, n, d):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, d)).astype(np.float32)
    y = rng.choice([-1.0, 1.0], size=n).astype(np.float32)
    x = rng.normal(size=d).astype(np.float32)
    return a, y, x


# ---------------------------------------------------------------------------
# logreg kernel
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 2**31 - 1),
    n=st.integers(1, 700),
    d=st.integers(1, 96),
)
def test_logreg_kernel_matches_ref(seed, n, d):
    a, y, x = _shard(seed, n, d)
    ap, yp, w = model.pad_shard(a, y)
    kl, kg = logreg.logreg_data_loss_grad(ap, yp, w, x)
    rl, rg = ref.logreg_loss_grad(ap, yp, w, x)
    np.testing.assert_allclose(kl, rl, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(kg, rg, rtol=1e-4, atol=1e-5)


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 700), d=st.integers(1, 96))
def test_logreg_padding_is_inert(seed, n, d):
    """Padded shard must give the same answer as the exact unpadded one."""
    a, y, x = _shard(seed, n, d)
    ap, yp, w = model.pad_shard(a, y)
    kl, kg = logreg.logreg_data_loss_grad(ap, yp, w, x)
    rl, rg = ref.logreg_loss_grad(a, y, np.ones(n, np.float32), x)
    np.testing.assert_allclose(kl, rl, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(kg, rg, rtol=1e-4, atol=1e-5)


@pytest.mark.parametrize("n", [1, 255, 256, 257, 512, 513])
def test_logreg_tile_boundaries(n):
    a, y, x = _shard(7, n, 33)
    ap, yp, w = model.pad_shard(a, y)
    kl, kg = logreg.logreg_data_loss_grad(ap, yp, w, x)
    rl, rg = ref.logreg_loss_grad(a, y, np.ones(n, np.float32), x)
    np.testing.assert_allclose(kl, rl, rtol=1e-5, atol=1e-6)
    np.testing.assert_allclose(kg, rg, rtol=1e-4, atol=1e-5)


def test_logreg_rejects_unaligned_rows():
    a, y, x = _shard(0, 100, 8)
    with pytest.raises(ValueError):
        logreg.logreg_data_loss_grad(a, y, np.ones(100, np.float32), x)


def test_logreg_extreme_margins_are_finite():
    """Stable softplus: huge |margins| must not produce inf/nan."""
    a, y, x = _shard(1, 256, 4)
    x = (1e4 * x).astype(np.float32)
    kl, kg = logreg.logreg_data_loss_grad(a, y, np.ones(256, np.float32), x)
    assert np.isfinite(float(kl))
    assert np.all(np.isfinite(np.asarray(kg)))


def test_logreg_full_objective_matches_ref():
    a, y, x = _shard(3, 256, 20)
    w = np.ones(256, np.float32)
    lam = jnp.float32(0.1)
    kl, kg = model.logreg_loss_grad(a, y, w, x, lam)
    rl, rg = ref.logreg_full_loss_grad(a, y, w, x, 0.1)
    np.testing.assert_allclose(kl, rl, rtol=1e-5)
    np.testing.assert_allclose(kg, rg, rtol=1e-4, atol=1e-5)


def test_logreg_gradient_is_correct_via_finite_differences():
    a, y, x = _shard(11, 256, 6)
    w = np.ones(256, np.float32)
    lam = 0.1
    _, g = model.logreg_loss_grad(a, y, w, x, jnp.float32(lam))
    g = np.asarray(g, np.float64)
    eps = 1e-3
    for j in range(6):
        xp, xm = x.copy(), x.copy()
        xp[j] += eps
        xm[j] -= eps
        lp, _ = ref.logreg_full_loss_grad(a, y, w, xp, lam)
        lm, _ = ref.logreg_full_loss_grad(a, y, w, xm, lam)
        fd = (float(lp) - float(lm)) / (2 * eps)
        assert abs(fd - g[j]) < 5e-3, (j, fd, g[j])


# ---------------------------------------------------------------------------
# lstsq kernel
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**31 - 1), n=st.integers(1, 700), d=st.integers(1, 96))
def test_lstsq_kernel_matches_ref(seed, n, d):
    rng = np.random.default_rng(seed)
    a = rng.normal(size=(n, d)).astype(np.float32)
    b = rng.normal(size=n).astype(np.float32)
    x = rng.normal(size=d).astype(np.float32)
    ap, bp, w = model.pad_shard(a, b)
    kl, kg = lstsq.lstsq_loss_grad(ap, bp, w, x)
    rl, rg = ref.lstsq_loss_grad(a, b, np.ones(n, np.float32), x)
    np.testing.assert_allclose(kl, rl, rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(kg, rg, rtol=1e-3, atol=1e-4)


def test_lstsq_zero_residual_gives_zero_grad():
    rng = np.random.default_rng(5)
    a = rng.normal(size=(256, 10)).astype(np.float32)
    x = rng.normal(size=10).astype(np.float32)
    b = (a @ x).astype(np.float32)
    w = np.ones(256, np.float32)
    loss, g = lstsq.lstsq_loss_grad(a, b, w, x)
    assert float(loss) < 1e-8
    assert float(jnp.linalg.norm(g)) < 1e-3


# ---------------------------------------------------------------------------
# threshold-mask kernel
# ---------------------------------------------------------------------------


@given(
    seed=st.integers(0, 2**31 - 1),
    n_tiles=st.integers(1, 4),
    thresh=st.floats(0.0, 3.0),
)
def test_mask_kernel_matches_ref(seed, n_tiles, thresh):
    rng = np.random.default_rng(seed)
    v = rng.normal(size=compress.DEFAULT_VTILE * n_tiles).astype(np.float32)
    km = compress.threshold_mask(v, jnp.array([thresh], jnp.float32))
    rm = ref.threshold_mask(v, np.float32(thresh))
    np.testing.assert_array_equal(np.asarray(km), np.asarray(rm))


def test_mask_zero_threshold_is_identity():
    rng = np.random.default_rng(1)
    v = rng.normal(size=compress.DEFAULT_VTILE).astype(np.float32)
    out = compress.threshold_mask(v, jnp.array([0.0], jnp.float32))
    np.testing.assert_array_equal(np.asarray(out), v)


def test_mask_huge_threshold_zeros_everything():
    rng = np.random.default_rng(2)
    v = rng.normal(size=compress.DEFAULT_VTILE).astype(np.float32)
    out = compress.threshold_mask(v, jnp.array([1e9], jnp.float32))
    assert float(jnp.sum(jnp.abs(out))) == 0.0


def test_mask_matches_topk_when_threshold_is_kth_magnitude():
    """Host-selected k-th magnitude + mask == dense Top-k (no tie case)."""
    rng = np.random.default_rng(3)
    v = rng.normal(size=compress.DEFAULT_VTILE).astype(np.float32)
    k = 100
    mags = np.sort(np.abs(v))[::-1]
    thresh = mags[k - 1]
    out = np.asarray(compress.threshold_mask(v, jnp.array([thresh], jnp.float32)))
    expect = np.asarray(ref.topk_dense(jnp.asarray(v), k))
    np.testing.assert_array_equal(out, expect)


# ---------------------------------------------------------------------------
# contraction property (3): Top-k is in B(alpha) with alpha = k/d
# ---------------------------------------------------------------------------


@given(seed=st.integers(0, 2**31 - 1), d=st.integers(2, 200), k=st.integers(1, 200))
def test_topk_contraction_bound(seed, d, k):
    k = min(k, d)
    rng = np.random.default_rng(seed)
    v = rng.normal(size=d).astype(np.float32)
    c = np.asarray(ref.topk_dense(jnp.asarray(v), k))
    lhs = float(np.sum((c - v) ** 2))
    rhs = (1.0 - k / d) * float(np.sum(v**2))
    assert lhs <= rhs * (1 + 1e-5) + 1e-7
