//! §Perf L3 instrument: full-round latency per algorithm — the end-to-end
//! coordinator cost (oracles + compression + aggregation + step) for one
//! communication round of the a9a logistic problem, 20 workers. One bench
//! per paper method == one row per Figure-1/2 curve family.
//!
//! Second section: sequential vs pooled protocol ([`coordinator::par`])
//! over a full multi-round run, reporting the measured speedup — the
//! acceptance instrument for the deterministic parallel engine.

#[path = "harness.rs"]
mod harness;

use ef21::algo::{AlgoSpec, MasterNode, WorkerNode};
use ef21::coordinator::{self, RunConfig};
use ef21::exp::{Objective, Problem};
use harness::{bench, header};
use std::sync::Arc;
use std::time::Instant;

fn setup(algo: AlgoSpec, comp: &str) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let p = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    let c: Arc<dyn ef21::compress::Compressor> =
        Arc::from(ef21::compress::from_spec(comp).unwrap());
    let alpha = c.alpha(p.d());
    let gamma = p.theory_gamma(alpha);
    let x0 = vec![0.0; p.d()];
    let (mut m, mut w) = ef21::algo::build(algo, x0, p.oracles(), c, gamma, 0);
    let x = m.x().to_vec();
    let msgs: Vec<_> = w.iter_mut().map(|wk| wk.init(&x)).collect();
    m.init_absorb(&msgs);
    (m, w)
}

/// Wall-clock of one full EF21 protocol run (fresh nodes per call) on
/// the given pool width; `threads == 1` is the sequential runner.
fn protocol_secs(problem: &Problem, rounds: usize, threads: usize) -> f64 {
    let c: Arc<dyn ef21::compress::Compressor> =
        Arc::from(ef21::compress::from_spec("top8").unwrap());
    let gamma = problem.theory_gamma(c.alpha(problem.d()));
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![0.0; problem.d()], problem.oracles(), c, gamma, 0);
    let cfg = RunConfig::rounds(rounds).with_record_every(50);
    let t0 = Instant::now();
    let h = coordinator::run_protocol_par(m, w, &cfg, threads);
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(h.records.last().unwrap().round, rounds - 1);
    dt
}

fn main() {
    header("full round (a9a, 20 workers)");
    for (algo, comp) in [
        (AlgoSpec::Ef21, "top1"),
        (AlgoSpec::Ef21Plus, "top1"),
        (AlgoSpec::Ef, "top1"),
        (AlgoSpec::Dcgd, "top1"),
        (AlgoSpec::Gd, "identity"),
        (AlgoSpec::Ef21, "top32"),
        (AlgoSpec::Ef21, "rand32"),
        (AlgoSpec::Ef21, "sign"),
    ] {
        let (mut m, mut w) = setup(algo, comp);
        bench(&format!("{:<6} {comp}", algo.name()), || {
            let x = m.begin_round();
            let msgs: Vec<_> = w.iter_mut().map(|wk| wk.round(&x)).collect();
            m.absorb(&msgs);
        });
    }

    // Sequential vs pooled protocol: same trajectory (bit-identical),
    // different wall-clock. Widths: 1 (baseline), 2, 4, and auto.
    println!("\n== sequential vs parallel protocol (EF21 top8, a9a, 20 workers, 120 rounds) ==");
    println!("{:<44} {:>12} {:>9}", "engine", "wall", "speedup");
    let problem = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    let rounds = 120;
    // Warm the dataset cache / allocator before timing.
    let _ = protocol_secs(&problem, 10, 1);
    let t_seq = protocol_secs(&problem, rounds, 1);
    println!("{:<44} {:>9.3} s {:>8.2}x", "sequential (threads=1)", t_seq, 1.0);
    let mut widths = vec![2usize, 4];
    let auto = ef21::coordinator::auto_threads();
    if !widths.contains(&auto) {
        widths.push(auto);
    }
    for threads in widths {
        let t = protocol_secs(&problem, rounds, threads);
        println!(
            "{:<44} {:>9.3} s {:>8.2}x",
            format!("pooled (threads={threads})"),
            t,
            t_seq / t
        );
    }
}
