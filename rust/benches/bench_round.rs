//! §Perf L3 instrument: full-round latency per algorithm — the end-to-end
//! coordinator cost (oracles + compression + aggregation + step) for one
//! communication round of the a9a logistic problem, 20 workers. One bench
//! per paper method == one row per Figure-1/2 curve family.
//!
//! Second section: sequential vs pooled protocol ([`coordinator::par`])
//! over a full multi-round run, reporting the measured speedup — the
//! acceptance instrument for the deterministic parallel engine.
//!
//! Third section: flat vs blocked — per-round latency of the flat
//! whole-vector pipeline against the block-partitioned one on the same
//! problem (the flat case is the no-regression guard for the block
//! refactor), a large-d layer-wise compression latency comparison, and
//! the downlink delta-broadcast savings over a real EF21 run.
//!
//! Fourth section: the participation scheduler — EF21-PP round latency
//! and uplink bits at p ∈ {1.0, 0.5, 0.1} against full participation,
//! and a straggler-deadline scenario over the local transport showing
//! the barrier no longer stalls on a scheduled 200ms straggler once the
//! deadline cuts it.
//!
//! Machine-readable twin: `ef21 bench` (`src/bench.rs`) runs the same
//! scenario families and emits `BENCH_round.json` — the perf trajectory
//! CI archives and diffs (DESIGN.md §8.3). This file stays the
//! human-readable console instrument.

#[path = "harness.rs"]
mod harness;

use ef21::algo::{AlgoSpec, MasterNode, WorkerNode};
use ef21::blocks::BlockLayout;
use ef21::coordinator::{self, RunConfig};
use ef21::exp::{Objective, Problem};
use harness::{bench, header};
use std::sync::Arc;
use std::time::Instant;

fn setup(algo: AlgoSpec, comp: &str) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let p = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    let c: Arc<dyn ef21::compress::Compressor> =
        Arc::from(ef21::compress::from_spec(comp).unwrap());
    let alpha = c.alpha(p.d());
    let gamma = p.theory_gamma(alpha);
    let x0 = vec![0.0; p.d()];
    let (mut m, mut w) = ef21::algo::build(algo, x0, p.oracles(), c, gamma, 0);
    let x = m.x().to_vec();
    let msgs: Vec<_> = w.iter_mut().map(|wk| wk.init(&x)).collect();
    m.init_absorb(&msgs);
    (m, w)
}

/// Wall-clock of one full EF21 protocol run (fresh nodes per call) on
/// the given pool width; `threads == 1` is the sequential runner.
fn protocol_secs(problem: &Problem, rounds: usize, threads: usize) -> f64 {
    let c: Arc<dyn ef21::compress::Compressor> =
        Arc::from(ef21::compress::from_spec("top8").unwrap());
    let gamma = problem.theory_gamma(c.alpha(problem.d()));
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![0.0; problem.d()], problem.oracles(), c, gamma, 0);
    let cfg = RunConfig::rounds(rounds).with_record_every(50);
    let t0 = Instant::now();
    let h = coordinator::run_protocol_par(m, w, &cfg, threads);
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(h.records.last().unwrap().round, rounds - 1);
    dt
}

fn main() {
    header("full round (a9a, 20 workers)");
    for (algo, comp) in [
        (AlgoSpec::Ef21, "top1"),
        (AlgoSpec::Ef21Plus, "top1"),
        (AlgoSpec::Ef, "top1"),
        (AlgoSpec::Dcgd, "top1"),
        (AlgoSpec::Gd, "identity"),
        (AlgoSpec::Ef21, "top32"),
        (AlgoSpec::Ef21, "rand32"),
        (AlgoSpec::Ef21, "sign"),
    ] {
        let (mut m, mut w) = setup(algo, comp);
        bench(&format!("{:<6} {comp}", algo.name()), || {
            let x = m.begin_round();
            let msgs: Vec<_> = w.iter_mut().map(|wk| wk.round(&x)).collect();
            m.absorb(&msgs);
        });
    }

    // Sequential vs pooled protocol: same trajectory (bit-identical),
    // different wall-clock. Widths: 1 (baseline), 2, 4, and auto.
    println!("\n== sequential vs parallel protocol (EF21 top8, a9a, 20 workers, 120 rounds) ==");
    println!("{:<44} {:>12} {:>9}", "engine", "wall", "speedup");
    let problem = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    let rounds = 120;
    // Warm the dataset cache / allocator before timing.
    let _ = protocol_secs(&problem, 10, 1);
    let t_seq = protocol_secs(&problem, rounds, 1);
    println!("{:<44} {:>9.3} s {:>8.2}x", "sequential (threads=1)", t_seq, 1.0);
    let mut widths = vec![2usize, 4];
    let auto = ef21::coordinator::auto_threads();
    if !widths.contains(&auto) {
        widths.push(auto);
    }
    for threads in widths {
        let t = protocol_secs(&problem, rounds, threads);
        println!(
            "{:<44} {:>9.3} s {:>8.2}x",
            format!("pooled (threads={threads})"),
            t,
            t_seq / t
        );
    }

    // Flat vs blocked: same problem, same budget. The flat row is the
    // no-regression guard (run_trial_blocked with a flat layout must
    // cost what the legacy path did); the blocked rows show the
    // layer-wise pipeline's overhead/benefit per round.
    header("flat vs blocked rounds (EF21 top8, a9a, 20 workers)");
    let p = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    for n_blocks in [1usize, 4, 16] {
        let layout = Arc::new(BlockLayout::equal(n_blocks, p.d()).unwrap());
        bench(&format!("blocks={n_blocks} (30 rounds)"), || {
            let h = p.run_trial_blocked(
                AlgoSpec::Ef21,
                "top8",
                1.0,
                None,
                30,
                30,
                0,
                1,
                layout.clone(),
            );
            harness::black_box(h.records.len());
        });
    }

    // Layer-wise compression latency at DL-like scale: one 2^18-dim
    // gradient, Top-k at ~5% density, flat vs 32 blocks (inline and
    // block-parallel fan-out).
    header("compression: flat vs layer-wise (d=262144, top 5%)");
    let d = 1 << 18;
    let k = d / 20;
    let mut rng = ef21::util::rng::Rng::seed(1);
    let v: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
    let flat_c = ef21::compress::TopK::new(k);
    bench("flat top-k", || {
        harness::black_box(ef21::compress::Compressor::compress(&flat_c, &v, &mut rng).bits);
    });
    let layout32 = Arc::new(BlockLayout::equal(32, d).unwrap());
    for threads in [1usize, 4] {
        let c = ef21::compress::BlockCompressor::from_spec(
            &format!("top{k}"),
            layout32.clone(),
            threads,
        )
        .unwrap();
        bench(&format!("blocked top-k (32 blocks, fanout={threads})"), || {
            harness::black_box(ef21::compress::Compressor::compress(&c, &v, &mut rng).bits);
        });
    }

    // Participation sweep: same problem, scheduled Bernoulli-p rounds.
    // Wall-clock per round shrinks with the per-round oracle work and
    // the uplink bits shrink ~linearly in p — the whole point of
    // EF21-PP's sampling. p = 1.0 goes through the scheduler's noop
    // path and is the no-regression guard for the subset machinery.
    header("participation sweep (EF21 top8, a9a, 20 workers, 120 rounds)");
    println!(
        "{:<24} {:>12} {:>16} {:>10}",
        "participation", "wall", "bits/client", "vs full"
    );
    let pp_run = |part: Option<f64>| {
        let mut problem = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
        if let Some(frac) = part {
            problem.sched = ef21::config::SchedSpec {
                participation: ef21::sched::Participation::Bernoulli(frac),
                ..ef21::config::SchedSpec::default()
            };
        }
        let t0 = Instant::now();
        let h = problem.run_trial(AlgoSpec::Ef21, "top8", 1.0, None, 120, 120, 0);
        (t0.elapsed().as_secs_f64(), h.records.last().unwrap().bits_per_client)
    };
    let (t_full, bits_full) = pp_run(None);
    println!("{:<24} {:>9.3} s {:>16.3e} {:>10}", "full (legacy path)", t_full, bits_full, "1.00x");
    for frac in [1.0, 0.5, 0.1] {
        let (t, bits) = pp_run(Some(frac));
        println!(
            "{:<24} {:>9.3} s {:>16.3e} {:>9.2}x",
            format!("p = {frac} (scheduled)"),
            t,
            bits,
            bits / bits_full
        );
    }

    // Straggler deadline: a worker scheduled to sleep 200ms per round
    // over the local transport. Without a deadline every round waits on
    // it; with a 50ms deadline the scheduler cuts it and the barrier
    // keeps pace. 10 rounds => ~2s stalled vs milliseconds cut.
    header("straggler deadline (EF21 top1, 3 workers, local transport, 10 rounds)");
    let straggle_run = |deadline_ms: Option<u64>| {
        let c: Arc<dyn ef21::compress::Compressor> = Arc::new(ef21::compress::TopK::new(1));
        let master = Box::new(ef21::algo::ef21::Ef21Master::new(vec![1.0; 3], 3, 0.01));
        let sched = Arc::new(
            ef21::sched::Scheduler::new(
                ef21::sched::Participation::Full,
                ef21::sched::FaultPlan::parse("straggle(1,0..9,200ms)").unwrap(),
                deadline_ms,
                3,
                0,
            )
            .unwrap(),
        );
        let t0 = Instant::now();
        let out = ef21::coordinator::dist::run_distributed_sched(
            master,
            3,
            move |i| {
                let rng = ef21::util::rng::worker_rng(0, i);
                Box::new(ef21::algo::ef21::Ef21Worker::new(
                    Box::new(ef21::oracle::quadratic::divergence_example().remove(i)),
                    c.clone(),
                    rng,
                )) as Box<dyn WorkerNode>
            },
            10,
            ef21::coordinator::dist::TransportKind::Local,
            "straggle",
            sched,
        )
        .unwrap();
        assert_eq!(out.history.records.len(), 10);
        t0.elapsed().as_secs_f64()
    };
    let t_wait = straggle_run(None);
    let t_cut = straggle_run(Some(50));
    println!("no deadline (barrier waits) {t_wait:>9.3} s");
    println!(
        "deadline 50ms (straggler cut) {t_cut:>7.3} s   ({:.1}x faster; barrier never stalls)",
        t_wait / t_cut
    );

    // Downlink savings: metered delta broadcast vs dense baseline over a
    // converging EF21 run (least squares is PL, so late-run model
    // updates drop below the f32-quantization floor block by block and
    // stop being re-broadcast — the regime the delta frames target).
    let rounds = 1500u64;
    println!(
        "\n== downlink: delta broadcast vs dense (EF21 top8, a9a lstsq, 20 workers, {rounds} rounds) =="
    );
    let pl = Problem::new("a9a", Objective::Lstsq, 20, 0.1, 0);
    for n_blocks in [8usize, 32] {
        let layout = Arc::new(BlockLayout::equal(n_blocks, pl.d()).unwrap());
        let h = pl.run_trial_blocked(
            AlgoSpec::Ef21,
            "top8",
            1.0,
            None,
            rounds as usize,
            rounds as usize,
            0,
            1,
            layout,
        );
        let dense = (rounds + 1) * 32 * pl.d() as u64; // init + per-round dense
        println!(
            "blocks={n_blocks:<3} downlink {:>12} bits vs dense {:>12} bits  ({:.1}% saved)",
            h.downlink_bits,
            dense,
            100.0 * (1.0 - h.downlink_bits as f64 / dense as f64)
        );
    }
}
