//! §Perf L3 instrument: full-round latency per algorithm — the end-to-end
//! coordinator cost (oracles + compression + aggregation + step) for one
//! communication round of the a9a logistic problem, 20 workers. One bench
//! per paper method == one row per Figure-1/2 curve family.
//!
//! Second section: sequential vs pooled protocol ([`coordinator::par`])
//! over a full multi-round run, reporting the measured speedup — the
//! acceptance instrument for the deterministic parallel engine.
//!
//! Third section: flat vs blocked — per-round latency of the flat
//! whole-vector pipeline against the block-partitioned one on the same
//! problem (the flat case is the no-regression guard for the block
//! refactor), a large-d layer-wise compression latency comparison, and
//! the downlink delta-broadcast savings over a real EF21 run.

#[path = "harness.rs"]
mod harness;

use ef21::algo::{AlgoSpec, MasterNode, WorkerNode};
use ef21::blocks::BlockLayout;
use ef21::coordinator::{self, RunConfig};
use ef21::exp::{Objective, Problem};
use harness::{bench, header};
use std::sync::Arc;
use std::time::Instant;

fn setup(algo: AlgoSpec, comp: &str) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let p = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    let c: Arc<dyn ef21::compress::Compressor> =
        Arc::from(ef21::compress::from_spec(comp).unwrap());
    let alpha = c.alpha(p.d());
    let gamma = p.theory_gamma(alpha);
    let x0 = vec![0.0; p.d()];
    let (mut m, mut w) = ef21::algo::build(algo, x0, p.oracles(), c, gamma, 0);
    let x = m.x().to_vec();
    let msgs: Vec<_> = w.iter_mut().map(|wk| wk.init(&x)).collect();
    m.init_absorb(&msgs);
    (m, w)
}

/// Wall-clock of one full EF21 protocol run (fresh nodes per call) on
/// the given pool width; `threads == 1` is the sequential runner.
fn protocol_secs(problem: &Problem, rounds: usize, threads: usize) -> f64 {
    let c: Arc<dyn ef21::compress::Compressor> =
        Arc::from(ef21::compress::from_spec("top8").unwrap());
    let gamma = problem.theory_gamma(c.alpha(problem.d()));
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![0.0; problem.d()], problem.oracles(), c, gamma, 0);
    let cfg = RunConfig::rounds(rounds).with_record_every(50);
    let t0 = Instant::now();
    let h = coordinator::run_protocol_par(m, w, &cfg, threads);
    let dt = t0.elapsed().as_secs_f64();
    assert_eq!(h.records.last().unwrap().round, rounds - 1);
    dt
}

fn main() {
    header("full round (a9a, 20 workers)");
    for (algo, comp) in [
        (AlgoSpec::Ef21, "top1"),
        (AlgoSpec::Ef21Plus, "top1"),
        (AlgoSpec::Ef, "top1"),
        (AlgoSpec::Dcgd, "top1"),
        (AlgoSpec::Gd, "identity"),
        (AlgoSpec::Ef21, "top32"),
        (AlgoSpec::Ef21, "rand32"),
        (AlgoSpec::Ef21, "sign"),
    ] {
        let (mut m, mut w) = setup(algo, comp);
        bench(&format!("{:<6} {comp}", algo.name()), || {
            let x = m.begin_round();
            let msgs: Vec<_> = w.iter_mut().map(|wk| wk.round(&x)).collect();
            m.absorb(&msgs);
        });
    }

    // Sequential vs pooled protocol: same trajectory (bit-identical),
    // different wall-clock. Widths: 1 (baseline), 2, 4, and auto.
    println!("\n== sequential vs parallel protocol (EF21 top8, a9a, 20 workers, 120 rounds) ==");
    println!("{:<44} {:>12} {:>9}", "engine", "wall", "speedup");
    let problem = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    let rounds = 120;
    // Warm the dataset cache / allocator before timing.
    let _ = protocol_secs(&problem, 10, 1);
    let t_seq = protocol_secs(&problem, rounds, 1);
    println!("{:<44} {:>9.3} s {:>8.2}x", "sequential (threads=1)", t_seq, 1.0);
    let mut widths = vec![2usize, 4];
    let auto = ef21::coordinator::auto_threads();
    if !widths.contains(&auto) {
        widths.push(auto);
    }
    for threads in widths {
        let t = protocol_secs(&problem, rounds, threads);
        println!(
            "{:<44} {:>9.3} s {:>8.2}x",
            format!("pooled (threads={threads})"),
            t,
            t_seq / t
        );
    }

    // Flat vs blocked: same problem, same budget. The flat row is the
    // no-regression guard (run_trial_blocked with a flat layout must
    // cost what the legacy path did); the blocked rows show the
    // layer-wise pipeline's overhead/benefit per round.
    header("flat vs blocked rounds (EF21 top8, a9a, 20 workers)");
    let p = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    for n_blocks in [1usize, 4, 16] {
        let layout = Arc::new(BlockLayout::equal(n_blocks, p.d()).unwrap());
        bench(&format!("blocks={n_blocks} (30 rounds)"), || {
            let h = p.run_trial_blocked(
                AlgoSpec::Ef21,
                "top8",
                1.0,
                None,
                30,
                30,
                0,
                1,
                layout.clone(),
            );
            harness::black_box(h.records.len());
        });
    }

    // Layer-wise compression latency at DL-like scale: one 2^18-dim
    // gradient, Top-k at ~5% density, flat vs 32 blocks (inline and
    // block-parallel fan-out).
    header("compression: flat vs layer-wise (d=262144, top 5%)");
    let d = 1 << 18;
    let k = d / 20;
    let mut rng = ef21::util::rng::Rng::seed(1);
    let v: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
    let flat_c = ef21::compress::TopK::new(k);
    bench("flat top-k", || {
        harness::black_box(ef21::compress::Compressor::compress(&flat_c, &v, &mut rng).bits);
    });
    let layout32 = Arc::new(BlockLayout::equal(32, d).unwrap());
    for threads in [1usize, 4] {
        let c = ef21::compress::BlockCompressor::from_spec(
            &format!("top{k}"),
            layout32.clone(),
            threads,
        )
        .unwrap();
        bench(&format!("blocked top-k (32 blocks, fanout={threads})"), || {
            harness::black_box(ef21::compress::Compressor::compress(&c, &v, &mut rng).bits);
        });
    }

    // Downlink savings: metered delta broadcast vs dense baseline over a
    // converging EF21 run (least squares is PL, so late-run model
    // updates drop below the f32-quantization floor block by block and
    // stop being re-broadcast — the regime the delta frames target).
    let rounds = 1500u64;
    println!(
        "\n== downlink: delta broadcast vs dense (EF21 top8, a9a lstsq, 20 workers, {rounds} rounds) =="
    );
    let pl = Problem::new("a9a", Objective::Lstsq, 20, 0.1, 0);
    for n_blocks in [8usize, 32] {
        let layout = Arc::new(BlockLayout::equal(n_blocks, pl.d()).unwrap());
        let h = pl.run_trial_blocked(
            AlgoSpec::Ef21,
            "top8",
            1.0,
            None,
            rounds as usize,
            rounds as usize,
            0,
            1,
            layout,
        );
        let dense = (rounds + 1) * 32 * pl.d() as u64; // init + per-round dense
        println!(
            "blocks={n_blocks:<3} downlink {:>12} bits vs dense {:>12} bits  ({:.1}% saved)",
            h.downlink_bits,
            dense,
            100.0 * (1.0 - h.downlink_bits as f64 / dense as f64)
        );
    }
}
