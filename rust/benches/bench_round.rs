//! §Perf L3 instrument: full-round latency per algorithm — the end-to-end
//! coordinator cost (oracles + compression + aggregation + step) for one
//! communication round of the a9a logistic problem, 20 workers. One bench
//! per paper method == one row per Figure-1/2 curve family.

#[path = "harness.rs"]
mod harness;

use ef21::algo::{AlgoSpec, MasterNode, WorkerNode};
use ef21::exp::{Objective, Problem};
use harness::{bench, header};
use std::sync::Arc;

fn setup(algo: AlgoSpec, comp: &str) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let p = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    let c: Arc<dyn ef21::compress::Compressor> =
        Arc::from(ef21::compress::from_spec(comp).unwrap());
    let alpha = c.alpha(p.d());
    let gamma = p.theory_gamma(alpha);
    let x0 = vec![0.0; p.d()];
    let (mut m, mut w) = ef21::algo::build(algo, x0, p.oracles(), c, gamma, 0);
    let x = m.x().to_vec();
    let msgs: Vec<_> = w.iter_mut().map(|wk| wk.init(&x)).collect();
    m.init_absorb(&msgs);
    (m, w)
}

fn main() {
    header("full round (a9a, 20 workers)");
    for (algo, comp) in [
        (AlgoSpec::Ef21, "top1"),
        (AlgoSpec::Ef21Plus, "top1"),
        (AlgoSpec::Ef, "top1"),
        (AlgoSpec::Dcgd, "top1"),
        (AlgoSpec::Gd, "identity"),
        (AlgoSpec::Ef21, "top32"),
        (AlgoSpec::Ef21, "rand32"),
        (AlgoSpec::Ef21, "sign"),
    ] {
        let (mut m, mut w) = setup(algo, comp);
        bench(&format!("{:<6} {comp}", algo.name()), || {
            let x = m.begin_round();
            let msgs: Vec<_> = w.iter_mut().map(|wk| wk.round(&x)).collect();
            m.absorb(&msgs);
        });
    }
}
