//! Minimal shared bench harness (criterion is not vendored offline).
//! Each bench binary calls [`bench`] per case and prints aligned rows:
//!
//!   name                              median        mean     iters
//!
//! Timing: warmup, then adaptive iteration count targeting ~0.4 s per
//! case, median-of-batches to cut scheduler noise.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub median_ns: f64,
    pub mean_ns: f64,
    pub iters: u64,
}

pub fn bench<F: FnMut()>(name: &str, mut f: F) -> BenchResult {
    // Warmup + calibration.
    f();
    let t0 = Instant::now();
    f();
    let once = t0.elapsed().as_nanos().max(1) as f64;
    let target_ns = 4e8;
    let batch = ((target_ns / 12.0 / once).ceil() as u64).clamp(1, 1_000_000);
    let batches = 12;

    let mut samples = Vec::with_capacity(batches);
    for _ in 0..batches {
        let t = Instant::now();
        for _ in 0..batch {
            f();
        }
        samples.push(t.elapsed().as_nanos() as f64 / batch as f64);
    }
    samples.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let median_ns = samples[batches / 2];
    let mean_ns = samples.iter().sum::<f64>() / batches as f64;
    let r = BenchResult {
        name: name.to_string(),
        median_ns,
        mean_ns,
        iters: batch * batches as u64,
    };
    println!(
        "{:<44} {:>12} {:>12} {:>9}",
        r.name,
        fmt_ns(r.median_ns),
        fmt_ns(r.mean_ns),
        r.iters
    );
    r
}

pub fn header(title: &str) {
    println!("\n== {title} ==");
    println!("{:<44} {:>12} {:>12} {:>9}", "case", "median", "mean", "iters");
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.0} ns")
    } else if ns < 1e6 {
        format!("{:.2} us", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.2} s", ns / 1e9)
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
