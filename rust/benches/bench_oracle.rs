//! §Perf L3/L2 instrument: gradient-oracle latency — the pure-Rust shard
//! oracle (simulation hot path) vs the PJRT-executed HLO artifact (the
//! production path; requires `make artifacts`, silently skipped otherwise).

#[path = "harness.rs"]
mod harness;

use ef21::data::{partition, synth};
use ef21::oracle::{GradOracle, LogRegOracle, LstsqOracle};
use ef21::util::rng::Rng;
use harness::{bench, black_box, header};
#[cfg(feature = "xla-runtime")]
use std::sync::Arc;

fn main() {
    header("oracles (pure rust)");
    let mut rng = Rng::seed(0);
    for name in ["phishing", "a9a", "w8a"] {
        let ds = synth::generate(name, 0);
        let shard = partition::shards(&ds, 20)[19];
        let x: Vec<f64> = (0..ds.d).map(|_| rng.next_normal()).collect();
        let mut o = LogRegOracle::new(shard, 0.1);
        bench(&format!("rust logreg grad {name} shard ({}x{})", shard.n, shard.d), || {
            black_box(o.loss_grad(&x));
        });
        let mut o = LstsqOracle::new(shard);
        bench(&format!("rust lstsq  grad {name} shard ({}x{})", shard.n, shard.d), || {
            black_box(o.loss_grad(&x));
        });
    }

    xla_section(&mut rng);
}

#[cfg(feature = "xla-runtime")]
fn xla_section(rng: &mut Rng) {
    match ef21::runtime::Runtime::from_default_dir() {
        Err(e) => eprintln!("(skipping XLA oracle bench: {e:#})"),
        Ok(rt) => {
            let rt = Arc::new(rt);
            header("oracles (PJRT artifact: L1 pallas + L2 jax)");
            for name in ["phishing", "a9a"] {
                let ds = synth::generate(name, 0);
                let shard = partition::shards(&ds, 20)[19];
                let x: Vec<f64> = (0..ds.d).map(|_| rng.next_normal()).collect();
                let mut o = ef21::oracle::xla::XlaShardOracle::new(
                    rt.clone(),
                    name,
                    ef21::oracle::xla::ShardKind::LogReg,
                    shard,
                    0.1,
                )
                .expect("xla oracle");
                bench(&format!("xla  logreg grad {name} shard ({}x{})", shard.n, shard.d), || {
                    black_box(o.loss_grad(&x));
                });
            }
        }
    }
}

#[cfg(not(feature = "xla-runtime"))]
fn xla_section(_rng: &mut Rng) {
    eprintln!("(xla-runtime feature disabled; skipping XLA oracle bench)");
}
