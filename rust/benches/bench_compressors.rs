//! §Perf L3 instrument: compressor throughput. Top-k selection over the
//! ~470k-dim transformer gradient is the coordinator hot spot; this bench
//! tracks it across compressors and dimensions (see EXPERIMENTS.md §Perf).

#[path = "harness.rs"]
mod harness;

use ef21::compress::{Compressor, Markov, RandK, ScaledSign, TopK};
use ef21::util::rng::Rng;
use harness::{bench, black_box, header};

fn main() {
    let mut rng = Rng::seed(0);
    header("compressors");

    for &d in &[300usize, 10_000, 469_504] {
        let v: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
        let k_small = (d / 100).max(1);
        let k_big = (d / 20).max(1);

        let c = TopK::new(k_small);
        let mut r = Rng::seed(1);
        bench(&format!("top-k    d={d:>7} k={k_small:>6}"), || {
            black_box(c.compress(&v, &mut r));
        });

        let c = TopK::new(k_big);
        bench(&format!("top-k    d={d:>7} k={k_big:>6}"), || {
            black_box(c.compress(&v, &mut r));
        });

        // §Perf ablation: the pre-optimization baseline (full sort, fresh
        // allocation per call) vs the select_nth + thread-local scratch
        // path above.
        let c = TopK::new(k_big);
        bench(&format!("top-k(sort-baseline) d={d:>7} k={k_big:>6}"), || {
            black_box(c.select_indices_via_sort(&v));
        });

        let c = RandK::new(k_big);
        bench(&format!("rand-k   d={d:>7} k={k_big:>6}"), || {
            black_box(c.compress(&v, &mut r));
        });

        let c = ScaledSign;
        bench(&format!("sign     d={d:>7}"), || {
            black_box(c.compress(&v, &mut r));
        });

        let mut m = Markov::new(TopK::new(k_big), d);
        bench(&format!("markov   d={d:>7} k={k_big:>6}"), || {
            black_box(m.step(&v, &mut r));
        });
    }
}
