//! §Perf telemetry instrument: hot-path cost of the metrics facade — the
//! disabled (noop) fast path that every ordinary run pays, versus the
//! enabled path with per-record registry lookup, versus a cached handle.
//! The noop rows are the ones that must stay ~1ns so `bench_round` is
//! unaffected by instrumentation (< 2% acceptance budget).

#[path = "harness.rs"]
mod harness;

use ef21::telemetry::{self, keys};
use harness::{bench, black_box, header};

fn main() {
    header("telemetry disabled (noop fast path)");
    assert!(!telemetry::is_enabled());
    bench("counter lookup+incr       (noop)", || {
        telemetry::counter(keys::TX_BYTES).incr(1);
    });
    bench("histogram span via maybe_now (noop)", || {
        let t0 = telemetry::maybe_now();
        telemetry::record_elapsed_ns("bench.ns", t0);
    });
    let cached = telemetry::counter(keys::TX_BYTES);
    bench("counter incr, cached handle (noop)", || {
        cached.incr(1);
    });
    bench("span create+end           (noop)", || {
        telemetry::span("bench.span").end();
    });
    bench("worker histogram lookup   (noop)", || {
        // The per-worker key needs a format!; the noop path must bail
        // before allocating it (zero-allocation gate).
        telemetry::worker_round_ns(black_box(3)).record(1);
    });

    telemetry::enable();
    header("telemetry enabled");
    bench("counter lookup+incr       (live)", || {
        telemetry::counter(keys::TX_BYTES).incr(1);
    });
    let cached = telemetry::counter(keys::TX_BYTES);
    bench("counter incr, cached handle (live)", || {
        cached.incr(1);
    });
    bench("histogram span via maybe_now (live)", || {
        let t0 = telemetry::maybe_now();
        telemetry::record_elapsed_ns("bench.ns", t0);
    });
    let hist = telemetry::histogram("bench.cached.ns");
    let mut v = 1u64;
    bench("histogram record, cached handle (live)", || {
        v = v.wrapping_mul(6364136223846793005).wrapping_add(1);
        hist.record(v >> 40);
    });
    bench("span create+end (metrics on, tracing off)", || {
        // Spans gate on the separate tracing flag: enabling the metrics
        // registry must not start paying for trace events.
        telemetry::span("bench.span").end();
    });
    bench("snapshot render (prometheus)", || {
        black_box(telemetry::snapshot().render_prometheus());
    });
    telemetry::disable();
}
