//! §Perf L3 instrument: wire codec + sparse-vector aggregation throughput
//! (the master's absorb path and the transport's encode/decode path).

#[path = "harness.rs"]
mod harness;

use ef21::algo::WireMsg;
use ef21::compress::{Compressed, SparseVec};
use ef21::transport::codec::{decode, encode, Frame};
use ef21::util::rng::Rng;
use harness::{bench, black_box, header};

fn sparse(d: usize, k: usize, rng: &mut Rng) -> SparseVec {
    let idx = rng.sample_indices(d, k);
    let val: Vec<f64> = (0..k).map(|_| rng.next_normal()).collect();
    SparseVec::new(idx, val)
}

fn main() {
    let mut rng = Rng::seed(0);
    header("codec");
    for &(d, k) in &[(300usize, 32usize), (469_504, 23_475)] {
        let sv = sparse(d, k, &mut rng);
        let msg = WireMsg::Sparse(Compressed { bits: sv.standard_bits(), sparse: sv });
        let up = Frame::Up { msg, loss: 1.0, health: None };
        bench(&format!("encode Up d={d:>7} k={k:>6}"), || {
            black_box(encode(&up));
        });
        let bytes = encode(&up);
        bench(&format!("decode Up d={d:>7} k={k:>6}"), || {
            black_box(decode(&bytes).unwrap());
        });

        let model = Frame::Model(vec![0.5; d]);
        bench(&format!("encode Model d={d:>7}"), || {
            black_box(encode(&model));
        });
    }

    header("aggregation (absorb path)");
    for &(d, k, n) in &[(300usize, 32usize, 20usize), (469_504, 23_475, 4)] {
        let msgs: Vec<SparseVec> = (0..n).map(|_| sparse(d, k, &mut rng)).collect();
        let mut acc = vec![0.0f64; d];
        bench(&format!("absorb {n} msgs d={d:>7} k={k:>6}"), || {
            for m in &msgs {
                m.add_scaled_into(1.0 / n as f64, &mut acc);
            }
            black_box(&acc);
        });
    }
}
