//! Checkpoint/resume acceptance suite (ISSUE 7): durable snapshots with
//! bitwise-identical restarts.
//!
//!   * kill-at-round-r (`killmaster@r`) + resume from the last snapshot
//!     replays the exact uninterrupted trajectory — every RoundRecord and
//!     the final model bit for bit — for EF21/EF21+/EF/DCGD under top-k
//!     and rand-k (the RNG stream position is checkpoint state);
//!   * the same holds with partial participation and worker faults in the
//!     schedule (the resync tracker mirrors ride in the snapshot), and
//!     over the local transport on both the plain and scheduled paths;
//!   * a snapshot also extends a completed run: resuming with a larger
//!     `--rounds` continues bitwise-identically to a run that had the
//!     larger horizon from the start;
//!   * fingerprint mismatches, corrupted bytes, and truncated files are
//!     rejected with a clear error before any state is touched.

use ef21::algo::{AlgoSpec, WorkerNode};
use ef21::ckpt::Checkpoint;
use ef21::compress::{Compressor, RandK, TopK};
use ef21::coordinator::dist::{
    run_distributed_ckpt, run_distributed_opts, run_distributed_sched,
    run_distributed_sched_ckpt, Broadcast, TransportKind,
};
use ef21::coordinator::runner::{run_protocol, run_protocol_ckpt, CkptOptions, RunConfig};
use ef21::metrics::History;
use ef21::oracle::GradOracle;
use ef21::sched::{FaultPlan, Participation, Scheduler};
use std::path::PathBuf;
use std::sync::Arc;

fn quads() -> Vec<Box<dyn GradOracle>> {
    ef21::oracle::quadratic::divergence_example()
        .into_iter()
        .map(|q| Box::new(q) as Box<dyn GradOracle>)
        .collect()
}

fn quad(i: usize) -> Box<dyn GradOracle> {
    Box::new(ef21::oracle::quadratic::divergence_example().remove(i))
}

fn sched(part: Participation, faults: &str, n: usize) -> Arc<Scheduler> {
    Arc::new(Scheduler::new(part, FaultPlan::parse(faults).unwrap(), None, n, 99).unwrap())
}

/// Fresh snapshot path under the system temp dir (unique per test name;
/// any stale file from a previous run is removed first).
fn tmp_ckpt(name: &str) -> PathBuf {
    let p = std::env::temp_dir()
        .join(format!("ef21_ckpt_test_{}_{name}.ckpt", std::process::id()));
    let _ = std::fs::remove_file(&p);
    p
}

fn assert_histories_bitwise(a: &History, b: &History, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.round, y.round, "{what}");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: loss at round {}", x.round);
        assert_eq!(
            x.grad_norm_sq.to_bits(),
            y.grad_norm_sq.to_bits(),
            "{what}: grad at round {}",
            x.round
        );
        assert_eq!(
            x.bits_per_client.to_bits(),
            y.bits_per_client.to_bits(),
            "{what}: bits at round {}",
            x.round
        );
        assert_eq!(x.gt.to_bits(), y.gt.to_bits(), "{what}: gt at round {}", x.round);
    }
    assert_eq!(a.final_x.len(), b.final_x.len(), "{what}: final_x dim");
    for (x, y) in a.final_x.iter().zip(&b.final_x) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: final_x");
    }
    assert_eq!(a.downlink_bits, b.downlink_bits, "{what}: downlink bits");
}

/// THE acceptance property: kill the master mid-run, resume from the
/// last snapshot, and the trajectory is bitwise identical to a run that
/// was never interrupted — for every checkpointable algorithm, under the
/// deterministic Top-k AND the randomized Rand-k (whose RNG position
/// must ride in the snapshot).
#[test]
fn kill_and_resume_is_bitwise_identical_for_all_algos_and_compressors() {
    let compressors: Vec<(&str, Arc<dyn Compressor>)> = vec![
        ("top1", Arc::new(TopK::new(1))),
        ("rand2", Arc::new(RandK::new(2))),
    ];
    for (name, c) in compressors {
        for algo in [AlgoSpec::Ef21, AlgoSpec::Ef21Plus, AlgoSpec::Ef, AlgoSpec::Dcgd] {
            if algo == AlgoSpec::Ef21Plus && name == "rand2" {
                continue; // EF21+ requires a deterministic compressor
            }
            let what = format!("{} {name}", algo.name());
            let build = || {
                ef21::algo::build(algo, vec![1.0; 3], quads(), c.clone(), 0.01, 5)
            };
            // Uninterrupted reference.
            let (m, w) = build();
            let baseline = run_protocol(m, w, &RunConfig::rounds(30));

            // Crashed run: snapshots every 4 rounds, master killed at the
            // start of round 13 → the last snapshot resumes from round 12.
            let path = tmp_ckpt(&format!("kill_{}_{name}", algo.name()));
            let (m, w) = build();
            let cfg = RunConfig::rounds(30)
                .with_sched(sched(Participation::Full, "killmaster@13", 3));
            let err = run_protocol_ckpt(m, w, &cfg, CkptOptions::saving(path.clone(), 4))
                .expect_err("the fault plan must kill this run");
            assert!(format!("{err:#}").contains("killmaster"), "{what}: {err:#}");

            // Resume: fresh nodes, no fault plan, state from the snapshot.
            let ck = Checkpoint::read(&path).unwrap();
            assert_eq!(ck.next_round, 12, "{what}: snapshot cadence");
            let (m, w) = build();
            let resumed =
                run_protocol_ckpt(m, w, &RunConfig::rounds(30), CkptOptions::resuming(ck))
                    .unwrap();
            assert_histories_bitwise(&baseline, &resumed, &what);
            let _ = std::fs::remove_file(&path);
        }
    }
}

/// Kill + resume under partial participation AND a crash/rejoin fault
/// window: the resync tracker's mirrors ride in the snapshot, and the
/// resumed run (same schedule minus the killmaster clause) replays the
/// uninterrupted trajectory exactly.
#[test]
fn kill_and_resume_with_participation_and_faults_is_bitwise() {
    let faults = "crash@2,rejoin@5";
    let build = || {
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5)
    };
    let (m, w) = build();
    let base_cfg =
        RunConfig::rounds(30).with_sched(sched(Participation::Bernoulli(0.7), faults, 3));
    let baseline = run_protocol(m, w, &base_cfg);

    let path = tmp_ckpt("kill_pp_faults");
    let (m, w) = build();
    let killed_cfg = RunConfig::rounds(30).with_sched(sched(
        Participation::Bernoulli(0.7),
        &format!("{faults},killmaster@17"),
        3,
    ));
    run_protocol_ckpt(m, w, &killed_cfg, CkptOptions::saving(path.clone(), 5))
        .expect_err("killmaster@17 must abort the run");

    let ck = Checkpoint::read(&path).unwrap();
    assert_eq!(ck.next_round, 15);
    assert!(ck.tracker.is_some(), "rejoin schedules must checkpoint the resync mirrors");
    let (m, w) = build();
    let resumed = run_protocol_ckpt(m, w, &base_cfg, CkptOptions::resuming(ck)).unwrap();
    assert_histories_bitwise(&baseline, &resumed, "pp+faults");
    let _ = std::fs::remove_file(&path);
}

/// A final-round snapshot extends a finished run: resuming it with a
/// larger horizon continues bitwise-identically to a run that had the
/// larger horizon from the start (uplink/downlink accounting and the
/// recorded history carry over exactly).
#[test]
fn resume_extends_a_completed_run_bitwise() {
    let build = || {
        ef21::algo::build(
            AlgoSpec::Ef21Plus,
            vec![1.0; 3],
            quads(),
            Arc::new(TopK::new(1)),
            0.01,
            5,
        )
    };
    let (m, w) = build();
    let long = run_protocol(m, w, &RunConfig::rounds(20));

    let path = tmp_ckpt("extend");
    let (m, w) = build();
    let short =
        run_protocol_ckpt(m, w, &RunConfig::rounds(10), CkptOptions::saving(path.clone(), 10))
            .unwrap();
    assert_eq!(short.records.len(), 10);
    let ck = Checkpoint::read(&path).unwrap();
    assert_eq!(ck.next_round, 10);
    let (m, w) = build();
    let extended =
        run_protocol_ckpt(m, w, &RunConfig::rounds(20), CkptOptions::resuming(ck)).unwrap();
    assert_histories_bitwise(&long, &extended, "extend");
    let _ = std::fs::remove_file(&path);
}

fn dist_build_master() -> Box<ef21::algo::ef21::Ef21Master> {
    Box::new(ef21::algo::ef21::Ef21Master::new(vec![1.0; 3], 3, 0.01))
}

fn dist_make_worker(c: Arc<dyn Compressor>) -> impl Fn(usize) -> Box<dyn WorkerNode> + Send + Sync {
    move |i: usize| {
        let rng = ef21::util::rng::worker_rng(9, i);
        Box::new(ef21::algo::ef21::Ef21Worker::new(quad(i), c.clone(), rng))
            as Box<dyn WorkerNode>
    }
}

/// Plain-path local transport: a mid-run snapshot resumes into the exact
/// uninterrupted trajectory — master state, worker Markov state, and the
/// downlink meter image all restore over the wire's Restore frame.
#[test]
fn local_transport_snapshot_resumes_bitwise() {
    let c: Arc<dyn Compressor> = Arc::new(TopK::new(1));
    let baseline = run_distributed_opts(
        dist_build_master(),
        3,
        dist_make_worker(c.clone()),
        12,
        TransportKind::Local,
        "dist-ckpt",
        Broadcast::Dense,
    )
    .unwrap();

    // Saving run: snapshots at rounds 5 and 10 → the file holds round 10.
    let path = tmp_ckpt("dist_plain");
    run_distributed_ckpt(
        dist_build_master(),
        3,
        dist_make_worker(c.clone()),
        12,
        TransportKind::Local,
        "dist-ckpt",
        Broadcast::Dense,
        CkptOptions::saving(path.clone(), 5),
    )
    .unwrap();
    let ck = Checkpoint::read(&path).unwrap();
    assert_eq!(ck.next_round, 10);

    let resumed = run_distributed_ckpt(
        dist_build_master(),
        3,
        dist_make_worker(c),
        12,
        TransportKind::Local,
        "dist-ckpt",
        Broadcast::Dense,
        CkptOptions::resuming(ck),
    )
    .unwrap();
    assert_histories_bitwise(&baseline.history, &resumed.history, "dist plain");
    let _ = std::fs::remove_file(&path);
}

/// Scheduled local transport: `killmaster@r` really tears the master
/// down mid-run (workers shut down cleanly, the error names the fault),
/// and resuming from the last snapshot — same schedule minus the kill —
/// replays the uninterrupted trajectory bit for bit.
#[test]
fn local_transport_killmaster_and_resume_is_bitwise() {
    let c: Arc<dyn Compressor> = Arc::new(TopK::new(1));
    let part = Participation::Bernoulli(0.7);
    let baseline = run_distributed_sched(
        dist_build_master(),
        3,
        dist_make_worker(c.clone()),
        15,
        TransportKind::Local,
        "dist-kill",
        sched(part, "", 3),
    )
    .unwrap();

    let path = tmp_ckpt("dist_kill");
    let err = run_distributed_sched_ckpt(
        dist_build_master(),
        3,
        dist_make_worker(c.clone()),
        15,
        TransportKind::Local,
        "dist-kill",
        sched(part, "killmaster@7", 3),
        CkptOptions::saving(path.clone(), 3),
    )
    .expect_err("killmaster@7 must abort the scheduled run");
    assert!(format!("{err:#}").contains("killmaster"), "{err:#}");

    let ck = Checkpoint::read(&path).unwrap();
    assert_eq!(ck.next_round, 6);
    assert!(ck.last_loss.is_some(), "scheduled dist snapshots carry the loss cache");
    let resumed = run_distributed_sched_ckpt(
        dist_build_master(),
        3,
        dist_make_worker(c),
        15,
        TransportKind::Local,
        "dist-kill",
        sched(part, "", 3),
        CkptOptions::resuming(ck),
    )
    .unwrap();
    assert_histories_bitwise(&baseline.history, &resumed.history, "dist killmaster");
    let _ = std::fs::remove_file(&path);
}

/// A snapshot from one run configuration must not silently resume
/// another: the fingerprint check rejects it before any state moves.
#[test]
fn fingerprint_mismatch_is_rejected() {
    let path = tmp_ckpt("fingerprint");
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5);
    run_protocol_ckpt(
        m,
        w,
        &RunConfig::rounds(6),
        CkptOptions::saving(path.clone(), 3).with_fingerprint("run-A"),
    )
    .unwrap();
    let ck = Checkpoint::read(&path).unwrap();
    assert_eq!(ck.fingerprint, "run-A");
    assert!(ck.verify_fingerprint("run-A").is_ok());
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5);
    let err = run_protocol_ckpt(
        m,
        w,
        &RunConfig::rounds(6),
        CkptOptions::resuming(ck).with_fingerprint("run-B"),
    )
    .expect_err("a different fingerprint must be rejected");
    assert!(format!("{err:#}").contains("different run"), "{err:#}");
    let _ = std::fs::remove_file(&path);
}

/// Corrupted and truncated checkpoint files are rejected with a clear
/// error — never decoded into garbage state.
#[test]
fn corrupted_and_truncated_checkpoints_are_rejected() {
    let path = tmp_ckpt("corrupt");
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5);
    run_protocol_ckpt(m, w, &RunConfig::rounds(6), CkptOptions::saving(path.clone(), 3))
        .unwrap();
    let good = std::fs::read(&path).unwrap();
    assert!(Checkpoint::decode(&good).is_ok());

    // Flip one byte at several offsets: every corruption is caught
    // (structurally or by the FNV checksum), never silently accepted.
    for at in [20, good.len() / 2, good.len() - 5] {
        let mut bad = good.clone();
        bad[at] ^= 0x40;
        assert!(Checkpoint::decode(&bad).is_err(), "flip at {at} must be rejected");
    }
    // A clean prefix truncation (as a crashed writer without the atomic
    // rename would leave) is caught too.
    for keep in [0, MAGIC_LEN, good.len() / 2, good.len() - 1] {
        assert!(
            Checkpoint::decode(&good[..keep]).is_err(),
            "truncation to {keep} bytes must be rejected"
        );
    }
    // Checksum errors name the problem.
    let mut bad = good.clone();
    let mid = good.len() / 2;
    bad[mid] ^= 0x01;
    let msg = format!("{:#}", Checkpoint::decode(&bad).unwrap_err());
    assert!(
        msg.contains("checksum") || msg.contains("truncated") || msg.contains("section"),
        "unhelpful corruption error: {msg}"
    );
    let _ = std::fs::remove_file(&path);
}

const MAGIC_LEN: usize = 13; // b"ef21.ckpt/v1\n"

/// Resuming with the wrong worker count is rejected up front.
#[test]
fn worker_count_mismatch_is_rejected() {
    let path = tmp_ckpt("nworkers");
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5);
    run_protocol_ckpt(m, w, &RunConfig::rounds(4), CkptOptions::saving(path.clone(), 2))
        .unwrap();
    let mut ck = Checkpoint::read(&path).unwrap();
    ck.workers.pop(); // now claims 2 workers
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5);
    let err = run_protocol_ckpt(m, w, &RunConfig::rounds(4), CkptOptions::resuming(ck))
        .expect_err("worker-count mismatch must be rejected");
    assert!(format!("{err:#}").contains("workers"), "{err:#}");
    let _ = std::fs::remove_file(&path);
}
