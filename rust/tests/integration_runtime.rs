//! Three-layer composition tests: the AOT HLO artifacts (L2+L1, built by
//! `make artifacts`) executed through PJRT must agree with the pure-Rust
//! oracles, and the PJRT-backed EF21 run must track the simulated one.
//!
//! These tests are skipped (with a notice) if `artifacts/manifest.json` is
//! absent — run `make artifacts` first. The whole file is compiled only
//! with the `xla-runtime` feature (PJRT bindings).

#![cfg(feature = "xla-runtime")]

use ef21::data::{partition, synth};
use ef21::oracle::xla::{ShardKind, XlaShardOracle, XlaTransformerOracle};
use ef21::oracle::{GradOracle, LogRegOracle, LstsqOracle};
use ef21::runtime::Runtime;
use std::sync::Arc;

fn runtime() -> Option<Arc<Runtime>> {
    match Runtime::from_default_dir() {
        Ok(rt) => Some(Arc::new(rt)),
        Err(e) => {
            eprintln!("SKIP (no artifacts): {e:#}");
            None
        }
    }
}

#[test]
fn xla_logreg_oracle_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let ds = synth::generate("phishing", 0);
    let shards = partition::shards(&ds, 20);
    let lam = 0.1;
    // Check the first, middle, and last (larger) shard.
    for &i in &[0usize, 10, 19] {
        let mut xla =
            XlaShardOracle::new(rt.clone(), "phishing", ShardKind::LogReg, shards[i], lam)
                .expect("xla oracle");
        let mut rust = LogRegOracle::new(shards[i], lam);
        let mut rng = ef21::util::rng::Rng::seed(7 + i as u64);
        for _ in 0..3 {
            let x: Vec<f64> = (0..ds.d).map(|_| 0.5 * rng.next_normal()).collect();
            let (lx, gx) = xla.loss_grad(&x);
            let (lr, gr) = rust.loss_grad(&x);
            assert!(
                (lx - lr).abs() < 1e-4 * lr.abs().max(1.0),
                "shard {i}: loss {lx} vs {lr}"
            );
            let num: f64 = gx.iter().zip(&gr).map(|(a, b)| (a - b) * (a - b)).sum();
            let den: f64 = gr.iter().map(|v| v * v).sum::<f64>().max(1e-12);
            assert!(
                (num / den).sqrt() < 1e-3,
                "shard {i}: grad rel err {}",
                (num / den).sqrt()
            );
        }
    }
}

#[test]
fn xla_lstsq_oracle_matches_rust_oracle() {
    let Some(rt) = runtime() else { return };
    let ds = synth::generate("mushrooms", 0);
    let shards = partition::shards(&ds, 20);
    let mut xla = XlaShardOracle::new(rt.clone(), "mushrooms", ShardKind::Lstsq, shards[3], 0.0)
        .expect("xla oracle");
    let mut rust = LstsqOracle::new(shards[3]);
    let mut rng = ef21::util::rng::Rng::seed(3);
    let x: Vec<f64> = (0..ds.d).map(|_| 0.3 * rng.next_normal()).collect();
    let (lx, gx) = xla.loss_grad(&x);
    let (lr, gr) = rust.loss_grad(&x);
    assert!((lx - lr).abs() < 1e-3 * lr.abs().max(1.0), "{lx} vs {lr}");
    for (a, b) in gx.iter().zip(&gr) {
        assert!((a - b).abs() < 1e-3 * b.abs().max(1e-3), "{a} vs {b}");
    }
}

/// End-to-end: EF21 with XLA-backed oracles takes the same trajectory as
/// EF21 with pure-Rust oracles (to f32 wire/compute precision).
#[test]
fn ef21_over_xla_oracles_tracks_simulation() {
    let Some(rt) = runtime() else { return };
    let ds = synth::generate("phishing", 0);
    let n_workers = 4; // 4 shards through the padded artifact
    let shards = partition::shards(&ds, n_workers);
    let lam = 0.1;
    // Note: the phishing artifact pads to the 20-way max shard size, which
    // is smaller than a 4-way shard — so re-split 20-way and take 4 shards.
    let shards20 = partition::shards(&ds, 20);
    let _ = shards;
    let picks = [0usize, 5, 10, 19];

    let make = |use_xla: bool| -> Vec<Box<dyn GradOracle>> {
        picks
            .iter()
            .map(|&i| {
                if use_xla {
                    Box::new(
                        XlaShardOracle::new(
                            rt.clone(),
                            "phishing",
                            ShardKind::LogReg,
                            shards20[i],
                            lam,
                        )
                        .unwrap(),
                    ) as Box<dyn GradOracle>
                } else {
                    Box::new(LogRegOracle::new(shards20[i], lam)) as Box<dyn GradOracle>
                }
            })
            .collect()
    };

    use ef21::algo::AlgoSpec;
    use ef21::coordinator::runner::{run_protocol, RunConfig};
    use std::sync::Arc;
    let gamma = 0.05;
    let run = |oracles| {
        let (m, w) = ef21::algo::build(
            AlgoSpec::Ef21,
            vec![0.0; ds.d],
            oracles,
            Arc::new(ef21::compress::TopK::new(2)),
            gamma,
            0,
        );
        run_protocol(m, w, &RunConfig::rounds(8))
    };
    let h_xla = run(make(true));
    let h_rust = run(make(false));
    for (a, b) in h_xla.records.iter().zip(&h_rust.records) {
        assert!(
            (a.loss - b.loss).abs() < 2e-3 * b.loss.abs().max(1.0),
            "round {}: {} vs {}",
            a.round,
            a.loss,
            b.loss
        );
    }
}

#[test]
fn transformer_step_artifact_trains() {
    let Some(rt) = runtime() else { return };
    let entry = rt.entry("transformer_step").expect("entry").clone();
    let layout = ef21::nn::ParamLayout::from_entry(&entry).expect("layout");
    let mut rng = ef21::util::rng::Rng::seed(0);
    let flat = layout.init_flat(&mut rng);

    let vocab = entry.meta_usize("vocab").unwrap();
    let batch = entry.meta_usize("batch").unwrap();
    let seq = entry.meta_usize("seq_len").unwrap();
    let mut sampler = ef21::nn::tokens::TokenSampler::new(vocab, 0.1, 1, 2);
    let mut oracle = XlaTransformerOracle::new(
        rt.clone(),
        Box::new(move || sampler.batch(batch, seq)),
    )
    .expect("oracle");

    // Initial loss ≈ ln(vocab) for a fresh model.
    let (l0, g0) = oracle.step_f32(&flat).expect("step");
    assert!(
        (l0 - (vocab as f64).ln()).abs() < 1.0,
        "initial loss {l0} vs ln(V)={}",
        (vocab as f64).ln()
    );
    assert_eq!(g0.len(), layout.n_params);

    // A few SGD steps must reduce the loss.
    let mut x: Vec<f64> = flat.iter().map(|&v| v as f64).collect();
    let mut last = l0;
    for _ in 0..5 {
        let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let (l, g) = oracle.step_f32(&xf).expect("step");
        for (xi, gi) in x.iter_mut().zip(&g) {
            *xi -= 0.5 * gi;
        }
        last = l;
    }
    assert!(last < l0, "loss did not decrease: {l0} -> {last}");

    // Eval artifact returns accuracy in [0, 1].
    let xf: Vec<f32> = x.iter().map(|&v| v as f32).collect();
    let mut eval_sampler = ef21::nn::tokens::TokenSampler::new(vocab, 0.1, 1, 99);
    let tokens = eval_sampler.batch(batch, seq);
    let (el, ea) = oracle.eval(&xf, &tokens).expect("eval");
    assert!(el.is_finite() && (0.0..=1.0).contains(&ea));
}

#[test]
fn compress_mask_artifact_matches_rust_topk_threshold() {
    let Some(rt) = runtime() else { return };
    let entry = rt.entry("compress_mask").expect("entry").clone();
    let n = entry.meta_usize("n").unwrap();
    let mut rng = ef21::util::rng::Rng::seed(5);
    let v: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
    let thresh = 1.5f32;
    let v_lit = ef21::runtime::client::lit_f32_1d_exact(&v);
    let t_lit = ef21::runtime::client::lit_f32_1d_exact(&[thresh]);
    let outs = rt.execute("compress_mask", &[v_lit, t_lit]).expect("exec");
    let masked = outs[0].to_vec::<f32>().expect("vec");
    for (o, &x) in masked.iter().zip(&v) {
        let want = if x.abs() >= thresh { x } else { 0.0 };
        assert_eq!(*o, want);
    }
}
