//! Self-healing session acceptance suite (ISSUE 10): seeded wire chaos
//! must be *invisible* — a run that survives resets, corrupted frames,
//! and stalls is bitwise identical to the fault-free run, including the
//! logical uplink/downlink frame-byte accounting — and recovery that
//! exhausts its options degrades to EF21-PP absence (provably equal to
//! the equivalent `--participation` schedule) or aborts through the
//! quorum floor with a valid blackbox and a loadable checkpoint.

use ef21::algo::WorkerNode;
use ef21::ckpt::Checkpoint;
use ef21::compress::{Compressor, TopK};
use ef21::coordinator::dist::{
    run_distributed_ckpt_net, run_distributed_sched, run_distributed_sched_ckpt_net, Broadcast,
    DistOutcome, LossPolicy, NetOpts, TransportKind,
};
use ef21::coordinator::runner::CkptOptions;
use ef21::health::HealthSpec;
use ef21::oracle::GradOracle;
use ef21::sched::{FaultPlan, Participation, Scheduler};
use ef21::transport::chaos::ChaosPlan;
use ef21::transport::session::SessionCfg;
use std::sync::Arc;

fn quad(i: usize) -> Box<dyn GradOracle> {
    Box::new(ef21::oracle::quadratic::divergence_example().remove(i))
}

fn master() -> Box<ef21::algo::ef21::Ef21Master> {
    Box::new(ef21::algo::ef21::Ef21Master::new(vec![1.0; 3], 3, 0.01))
}

fn workers() -> impl Fn(usize) -> Box<dyn WorkerNode> + Send + Sync + 'static {
    let c: Arc<dyn Compressor> = Arc::new(TopK::new(1));
    move |i| {
        let rng = ef21::util::rng::worker_rng(9, i);
        Box::new(ef21::algo::ef21::Ef21Worker::new(quad(i), c.clone(), rng))
            as Box<dyn WorkerNode>
    }
}

fn net(seed: u64, chaos: &str) -> NetOpts {
    NetOpts {
        session: Some(SessionCfg::new(seed)),
        chaos: if chaos.is_empty() {
            None
        } else {
            Some(Arc::new(ChaosPlan::parse(chaos).expect("chaos spec")))
        },
        ..NetOpts::default()
    }
}

/// Full bitwise equality: every RoundRecord field, the final model, AND
/// the frame-byte meters. Sessions account logical payload bytes (what
/// the protocol accepted, not what the wire retried), so replayed and
/// corrupt-rejected frames must leave both meters untouched.
fn assert_outcomes_bitwise(a: &DistOutcome, b: &DistOutcome, what: &str) {
    assert_eq!(a.history.records.len(), b.history.records.len(), "{what}: record count");
    for (x, y) in a.history.records.iter().zip(&b.history.records) {
        assert_eq!(x.round, y.round, "{what}");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: loss at round {}", x.round);
        assert_eq!(
            x.grad_norm_sq.to_bits(),
            y.grad_norm_sq.to_bits(),
            "{what}: grad at round {}",
            x.round
        );
        assert_eq!(
            x.bits_per_client.to_bits(),
            y.bits_per_client.to_bits(),
            "{what}: bits at round {}",
            x.round
        );
        assert_eq!(x.gt.to_bits(), y.gt.to_bits(), "{what}: gt at round {}", x.round);
    }
    assert_eq!(a.final_x.len(), b.final_x.len(), "{what}: final_x dim");
    for (x, y) in a.final_x.iter().zip(&b.final_x) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: final_x");
    }
    assert_eq!(a.uplink_frame_bytes, b.uplink_frame_bytes, "{what}: uplink frame bytes");
    assert_eq!(
        a.downlink_frame_bytes, b.downlink_frame_bytes,
        "{what}: downlink frame bytes"
    );
}

fn threads_run(kind: TransportKind, rounds: usize, n: NetOpts) -> DistOutcome {
    run_distributed_ckpt_net(
        master(),
        3,
        workers(),
        rounds,
        kind,
        "sess-threads",
        Broadcast::Dense,
        CkptOptions::default(),
        n,
    )
    .expect("net run")
}

/// Turning sessions ON with no faults must not move a single bit or a
/// single accounted byte versus the legacy (sessions-off) protocol —
/// the envelope is pure overhead that the meters deliberately ignore.
#[test]
fn sessions_on_no_faults_equals_sessions_off() {
    for kind in [TransportKind::Local, TransportKind::Tcp] {
        let off = threads_run(kind, 12, NetOpts::default());
        let on = threads_run(kind, 12, net(7, ""));
        assert_outcomes_bitwise(&off, &on, &format!("sessions on vs off ({kind:?})"));
    }
}

/// THE acceptance property: a run that recovers from a connection
/// reset, a corrupted frame (CRC reject → re-request → replay), and a
/// mid-run stall is bitwise identical to the fault-free session run —
/// RoundRecords, final_x, and both frame-byte meters — on local
/// channels AND real TCP sockets under the thread-per-conn master.
#[test]
fn chaos_recovery_is_bitwise_identical_to_fault_free() {
    let chaos = "reset(0@2),corrupt(1@4),stall(2,3..5,5ms)";
    for kind in [TransportKind::Local, TransportKind::Tcp] {
        let clean = threads_run(kind, 12, net(7, ""));
        let chaotic = threads_run(kind, 12, net(7, chaos));
        assert_outcomes_bitwise(&clean, &chaotic, &format!("chaos recovery ({kind:?})"));
    }
}

/// The reactor master recovers soft chaos (in-stream reset + corrupt)
/// through its shared SessionMux: bitwise equal to both the fault-free
/// session run and the sessions-off run.
#[test]
fn reactor_recovers_soft_chaos_bitwise() {
    let run = |kind: TransportKind, n: NetOpts| {
        ef21::coordinator::reactor::run_reactor_net(
            master(),
            3,
            workers(),
            12,
            kind,
            "sess-reactor",
            ef21::coordinator::reactor::default_shards(),
            None,
            n,
        )
        .expect("reactor net run")
    };
    for kind in [TransportKind::Local, TransportKind::Tcp] {
        let off = run(kind, NetOpts::default());
        let on = run(kind, net(11, ""));
        let chaotic = run(kind, net(11, "reset(0@2),corrupt(1@3)"));
        assert_outcomes_bitwise(&off, &on, &format!("reactor sessions ({kind:?})"));
        assert_outcomes_bitwise(&off, &chaotic, &format!("reactor chaos ({kind:?})"));
    }
}

/// Graceful degradation IS EF21-PP: a worker lost for good under
/// `--on-worker-loss degrade` leaves exactly the trajectory of the same
/// worker being absent on every remaining round of a participation
/// schedule (loss, uplink bits, final model — all bitwise).
#[test]
fn degrade_path_equals_equivalent_participation_schedule() {
    let rounds = 12;
    let mut n = net(13, "down(2@5)");
    n.on_loss = LossPolicy::Degrade { grace_ms: 500 };
    let degraded = run_distributed_sched_ckpt_net(
        master(),
        3,
        workers(),
        rounds,
        TransportKind::Local,
        "sess-degrade",
        Arc::new(Scheduler::noop(3)),
        CkptOptions::default(),
        n,
    )
    .expect("degrade run");

    let drops: String =
        (5..rounds).map(|r| format!("drop(2@{r})")).collect::<Vec<_>>().join(",");
    let sched = Arc::new(
        Scheduler::new(Participation::Full, FaultPlan::parse(&drops).unwrap(), None, 3, 99)
            .unwrap(),
    );
    let absent = run_distributed_sched(
        master(),
        3,
        workers(),
        rounds,
        TransportKind::Local,
        "sess-absent",
        sched,
    )
    .expect("absence run");

    assert_eq!(degraded.history.records.len(), absent.history.records.len());
    for (x, y) in degraded.history.records.iter().zip(&absent.history.records) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "loss at round {}", x.round);
        assert_eq!(
            x.bits_per_client.to_bits(),
            y.bits_per_client.to_bits(),
            "bits at round {}",
            x.round
        );
    }
    for (x, y) in degraded.final_x.iter().zip(&absent.final_x) {
        assert_eq!(x.to_bits(), y.to_bits(), "final_x");
    }
}

/// Losing the quorum floor aborts the run through the flight recorder:
/// the error names the breach, the blackbox artifact is a valid
/// `ef21.blackbox/v1` dump with reason `quorum`, and the last
/// checkpoint written before the breach decodes and is consistent with
/// the resume pointer in the error message.
#[test]
fn quorum_breach_dumps_blackbox_and_leaves_loadable_checkpoint() {
    let dir = std::env::temp_dir().join(format!("ef21_sess_quorum_{}", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    let ckpt_path = dir.join("run.ckpt");
    let bb_path = dir.join("bb.json");

    let health = HealthSpec {
        every: 1,
        window: 8,
        tol: 1e9, // observation only: no anomaly rule may fire first
        blackbox: Some(bb_path.display().to_string()),
    }
    .build(1.0 / 3.0, 0.01);
    let opts = CkptOptions::saving(ckpt_path.clone(), 1).with_health(health);

    let mut n = net(17, "down(2@4)");
    n.on_loss = LossPolicy::Degrade { grace_ms: 500 };
    n.min_workers = Some(3);
    let err = match run_distributed_sched_ckpt_net(
        master(),
        3,
        workers(),
        12,
        TransportKind::Local,
        "sess-quorum",
        Arc::new(Scheduler::noop(3)),
        opts,
        n,
    ) {
        Ok(_) => panic!("3-worker floor with a downed worker must abort"),
        Err(e) => e,
    };
    let msg = format!("{err:#}");
    assert!(msg.contains("quorum lost"), "unexpected error: {msg}");

    let bb = std::fs::read_to_string(&bb_path).expect("blackbox artifact written");
    assert!(
        bb.contains(ef21::health::blackbox::SCHEMA),
        "blackbox missing schema tag: {bb}"
    );
    assert!(bb.contains("quorum"), "blackbox missing dump reason: {bb}");

    let ck = Checkpoint::read(&ckpt_path).expect("checkpoint decodes after the breach");
    assert!(ck.next_round >= 1, "at least one round must have been captured");
    assert!(
        msg.contains(&format!("rounds ..={}", ck.next_round - 1)),
        "error resume pointer disagrees with the checkpoint on disk \
         (next_round {}): {msg}",
        ck.next_round
    );

    let _ = std::fs::remove_dir_all(&dir);
}
