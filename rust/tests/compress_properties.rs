//! Property suite for the contraction inequality Eq. (3),
//! `E ||C(x) - x||^2 <= (1 - alpha) ||x||^2`:
//!
//!   * deterministic compressors satisfy it **pointwise** — asserted
//!     with a 1e-12 absolute slack across many seeds and dimensions;
//!   * randomized compressors satisfy it in expectation — asserted
//!     empirically over repeated draws;
//!   * Top-k edge cases at `d = k` and `d = 1`, NaN inputs, and the
//!     deterministic tie-break (load-bearing for the parallel runner:
//!     a tie broken differently per thread would break bit-identity).

use ef21::blocks::BlockLayout;
use ef21::compress::{
    distortion_ratio, BlockCompressor, Compressor, Identity, RandK, ScaledSign, SparseVec, TopK,
};
use ef21::util::rng::Rng;
use ef21::util::testing::{for_all_seeds, random_vec};
use std::sync::Arc;

fn deterministic_compressors(d: usize) -> Vec<Box<dyn Compressor>> {
    let mut all: Vec<Box<dyn Compressor>> = vec![
        Box::new(TopK::new(1)),
        Box::new(TopK::new((d / 4).max(1))),
        Box::new(TopK::new(d)), // k = d: identity-like
        Box::new(ScaledSign),
        Box::new(Identity),
    ];
    // Layer-wise variants: the composite operator must satisfy Eq. (3)
    // with alpha = min_b alpha_b, through the same pointwise harness.
    for n_blocks in [1usize, 2, 3] {
        if n_blocks <= d {
            let layout = Arc::new(BlockLayout::equal(n_blocks, d).unwrap());
            all.push(Box::new(
                BlockCompressor::from_spec(&format!("top{}", (d / 3).max(1)), layout, 1)
                    .unwrap(),
            ));
        }
    }
    all
}

/// Eq. (3) pointwise for every deterministic compressor, many seeds and
/// dims (including d = 1), tight 1e-12 slack.
#[test]
fn contraction_eq3_pointwise_for_deterministic() {
    for_all_seeds(40, |rng| {
        let d = 1 + rng.next_below(80);
        let scale = 0.1 + 10.0 * rng.next_f64();
        let v = random_vec(rng, d, scale);
        for c in deterministic_compressors(d) {
            assert!(c.is_deterministic(), "{}", c.name());
            let alpha = c.alpha(d);
            assert!(alpha > 0.0 && alpha <= 1.0, "{} alpha {alpha}", c.name());
            let r = distortion_ratio(c.as_ref(), &v, rng);
            assert!(
                r <= 1.0 - alpha + 1e-12,
                "{} d={d}: ratio {r} > 1 - alpha = {}",
                c.name(),
                1.0 - alpha
            );
        }
    });
}

/// Eq. (3) in expectation for Rand-k: the mean ratio over many draws
/// must approach `1 - k/d` (pointwise it can exceed it, which is why
/// Rand-k alone cannot drive EF21+).
#[test]
fn contraction_eq3_in_expectation_for_randk() {
    for_all_seeds(10, |rng| {
        let d = 2 + rng.next_below(40);
        let k = 1 + rng.next_below(d);
        let v = random_vec(rng, d, 2.0);
        let c = RandK::new(k);
        assert!(!c.is_deterministic());
        let alpha = c.alpha(d);
        let reps = 400;
        let mean: f64 = (0..reps)
            .map(|_| distortion_ratio(&c, &v, rng))
            .sum::<f64>()
            / reps as f64;
        assert!(
            mean <= (1.0 - alpha) * 1.15 + 1e-9,
            "rand{k} d={d}: mean ratio {mean} vs 1 - alpha = {}",
            1.0 - alpha
        );
    });
}

/// d = k: Top-k must be exactly the identity (zero distortion, alpha 1).
#[test]
fn topk_edge_d_equals_k() {
    for_all_seeds(20, |rng| {
        let d = 1 + rng.next_below(32);
        let v = random_vec(rng, d, 3.0);
        let c = TopK::new(d);
        assert_eq!(c.alpha(d), 1.0);
        let out = c.compress(&v, rng).sparse.to_dense(d);
        assert_eq!(out, v, "top-{d} over d={d} must be lossless");
        let r = distortion_ratio(&c, &v, rng);
        assert_eq!(r, 0.0);
    });
}

/// d = 1: any k keeps the single entry; alpha is clamped to 1.
#[test]
fn topk_edge_d_one() {
    let mut rng = Rng::seed(5);
    for k in [1usize, 2, 7] {
        let c = TopK::new(k);
        assert_eq!(c.alpha(1), 1.0, "top{k} alpha at d=1");
        for v in [[3.5], [-0.0], [f64::MIN_POSITIVE]] {
            let out = c.compress(&v, &mut rng).sparse.to_dense(1);
            assert_eq!(out, v, "top{k} at d=1 must be identity");
        }
    }
}

/// NaN entries sort as smallest magnitude: never selected while a
/// finite candidate remains, and an all-NaN input still yields a valid
/// deterministic selection (k = d path).
#[test]
fn topk_nan_edge_cases() {
    let c = TopK::new(2);
    assert_eq!(c.select_indices(&[f64::NAN, 1.0, 2.0]), vec![1, 2]);
    assert_eq!(c.select_indices(&[1.0, f64::NAN, f64::NAN, -3.0]), vec![0, 3]);
    // More NaNs than finite entries: lowest-index NaN fills the slot.
    assert_eq!(c.select_indices(&[f64::NAN, f64::NAN, 5.0]), vec![0, 2]);
    // d = k with NaN: full passthrough.
    assert_eq!(TopK::new(1).select_indices(&[f64::NAN]), vec![0]);
}

/// Ties break toward the lower index, identically on every call and on
/// every thread — the property the parallel runner's bit-identity
/// leans on (per-thread scratch buffers must not leak into selection).
#[test]
fn topk_tie_break_is_deterministic_across_threads() {
    let v = vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
    let reference = TopK::new(3).select_indices(&v);
    assert_eq!(reference, vec![0, 1, 2]);
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let v = v.clone();
            let want = reference.clone();
            std::thread::spawn(move || {
                let c = TopK::new(3);
                // Dirty this thread's scratch with a different-size
                // selection first, then verify the tie-break.
                let _ = c.select_indices(&v[..5]);
                for _ in 0..50 {
                    assert_eq!(c.select_indices(&v), want);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

/// The selection fast path must agree with the sort baseline on
/// adversarial inputs too (duplicates, zeros, signed zeros).
#[test]
fn select_matches_sort_baseline_on_degenerate_inputs() {
    let cases: Vec<Vec<f64>> = vec![
        vec![0.0; 6],
        vec![-0.0, 0.0, -0.0, 0.0],
        vec![2.0, -2.0, 2.0, -2.0, 2.0],
        vec![1e-300, -1e-300, 1e300, -1e300],
    ];
    for v in cases {
        for k in 1..=v.len() {
            let c = TopK::new(k);
            assert_eq!(
                c.select_indices(&v),
                c.select_indices_via_sort(&v),
                "k={k} v={v:?}"
            );
        }
    }
}

/// Compressed vectors round-trip their sparse representation: the
/// payload the pool threads ship to the coordinator is exactly what a
/// dense reconstruction sees.
#[test]
fn compressed_payload_roundtrips_dense() {
    for_all_seeds(15, |rng| {
        let d = 1 + rng.next_below(50);
        let v = random_vec(rng, d, 1.5);
        let k = 1 + rng.next_below(d);
        let comp = TopK::new(k).compress(&v, rng);
        let dense = comp.sparse.to_dense(d);
        let again = SparseVec::from_dense_full(&dense);
        assert_eq!(again.to_dense(d), dense);
        assert!(comp.bits > 0);
    });
}
