//! Property suite for the contraction inequality Eq. (3),
//! `E ||C(x) - x||^2 <= (1 - alpha) ||x||^2`:
//!
//!   * deterministic compressors satisfy it **pointwise** — asserted
//!     with a 1e-12 absolute slack across many seeds and dimensions;
//!   * randomized compressors satisfy it in expectation — asserted
//!     empirically over repeated draws;
//!   * Top-k edge cases at `d = k` and `d = 1`, NaN inputs, and the
//!     deterministic tie-break (load-bearing for the parallel runner:
//!     a tie broken differently per thread would break bit-identity).

use ef21::blocks::BlockLayout;
use ef21::compress::{
    distortion_ratio, BlockCompressor, Compressor, Identity, RandK, RandKUnbiased, Scaled,
    ScaledSign, SparseVec, TopK,
};
use ef21::compress::unbiased::UnbiasedCompressor;
use ef21::util::rng::Rng;
use ef21::util::testing::{for_all_seeds, random_vec};
use std::sync::Arc;

fn deterministic_compressors(d: usize) -> Vec<Box<dyn Compressor>> {
    let mut all: Vec<Box<dyn Compressor>> = vec![
        Box::new(TopK::new(1)),
        Box::new(TopK::new((d / 4).max(1))),
        Box::new(TopK::new(d)), // k = d: identity-like
        Box::new(ScaledSign),
        Box::new(Identity),
    ];
    // Layer-wise variants: the composite operator must satisfy Eq. (3)
    // with alpha = min_b alpha_b, through the same pointwise harness.
    for n_blocks in [1usize, 2, 3] {
        if n_blocks <= d {
            let layout = Arc::new(BlockLayout::equal(n_blocks, d).unwrap());
            all.push(Box::new(
                BlockCompressor::from_spec(&format!("top{}", (d / 3).max(1)), layout, 1)
                    .unwrap(),
            ));
        }
    }
    all
}

/// Eq. (3) pointwise for every deterministic compressor, many seeds and
/// dims (including d = 1), tight 1e-12 slack.
#[test]
fn contraction_eq3_pointwise_for_deterministic() {
    for_all_seeds(40, |rng| {
        let d = 1 + rng.next_below(80);
        let scale = 0.1 + 10.0 * rng.next_f64();
        let v = random_vec(rng, d, scale);
        for c in deterministic_compressors(d) {
            assert!(c.is_deterministic(), "{}", c.name());
            let alpha = c.alpha(d);
            assert!(alpha > 0.0 && alpha <= 1.0, "{} alpha {alpha}", c.name());
            let r = distortion_ratio(c.as_ref(), &v, rng);
            assert!(
                r <= 1.0 - alpha + 1e-12,
                "{} d={d}: ratio {r} > 1 - alpha = {}",
                c.name(),
                1.0 - alpha
            );
        }
    });
}

/// Eq. (3) in expectation for Rand-k: the mean ratio over many draws
/// must approach `1 - k/d` (pointwise it can exceed it, which is why
/// Rand-k alone cannot drive EF21+).
#[test]
fn contraction_eq3_in_expectation_for_randk() {
    for_all_seeds(10, |rng| {
        let d = 2 + rng.next_below(40);
        let k = 1 + rng.next_below(d);
        let v = random_vec(rng, d, 2.0);
        let c = RandK::new(k);
        assert!(!c.is_deterministic());
        let alpha = c.alpha(d);
        let reps = 400;
        let mean: f64 = (0..reps)
            .map(|_| distortion_ratio(&c, &v, rng))
            .sum::<f64>()
            / reps as f64;
        assert!(
            mean <= (1.0 - alpha) * 1.15 + 1e-9,
            "rand{k} d={d}: mean ratio {mean} vs 1 - alpha = {}",
            1.0 - alpha
        );
    });
}

/// d = k: Top-k must be exactly the identity (zero distortion, alpha 1).
#[test]
fn topk_edge_d_equals_k() {
    for_all_seeds(20, |rng| {
        let d = 1 + rng.next_below(32);
        let v = random_vec(rng, d, 3.0);
        let c = TopK::new(d);
        assert_eq!(c.alpha(d), 1.0);
        let out = c.compress(&v, rng).sparse.to_dense(d);
        assert_eq!(out, v, "top-{d} over d={d} must be lossless");
        let r = distortion_ratio(&c, &v, rng);
        assert_eq!(r, 0.0);
    });
}

/// d = 1: any k keeps the single entry; alpha is clamped to 1.
#[test]
fn topk_edge_d_one() {
    let mut rng = Rng::seed(5);
    for k in [1usize, 2, 7] {
        let c = TopK::new(k);
        assert_eq!(c.alpha(1), 1.0, "top{k} alpha at d=1");
        for v in [[3.5], [-0.0], [f64::MIN_POSITIVE]] {
            let out = c.compress(&v, &mut rng).sparse.to_dense(1);
            assert_eq!(out, v, "top{k} at d=1 must be identity");
        }
    }
}

/// NaN entries sort as smallest magnitude: never selected while a
/// finite candidate remains, and an all-NaN input still yields a valid
/// deterministic selection (k = d path).
#[test]
fn topk_nan_edge_cases() {
    let c = TopK::new(2);
    assert_eq!(c.select_indices(&[f64::NAN, 1.0, 2.0]), vec![1, 2]);
    assert_eq!(c.select_indices(&[1.0, f64::NAN, f64::NAN, -3.0]), vec![0, 3]);
    // More NaNs than finite entries: lowest-index NaN fills the slot.
    assert_eq!(c.select_indices(&[f64::NAN, f64::NAN, 5.0]), vec![0, 2]);
    // d = k with NaN: full passthrough.
    assert_eq!(TopK::new(1).select_indices(&[f64::NAN]), vec![0]);
}

/// Ties break toward the lower index, identically on every call and on
/// every thread — the property the parallel runner's bit-identity
/// leans on (per-thread scratch buffers must not leak into selection).
#[test]
fn topk_tie_break_is_deterministic_across_threads() {
    let v = vec![1.0, -1.0, 1.0, -1.0, 1.0, -1.0, 1.0, -1.0];
    let reference = TopK::new(3).select_indices(&v);
    assert_eq!(reference, vec![0, 1, 2]);
    let handles: Vec<_> = (0..4)
        .map(|_| {
            let v = v.clone();
            let want = reference.clone();
            std::thread::spawn(move || {
                let c = TopK::new(3);
                // Dirty this thread's scratch with a different-size
                // selection first, then verify the tie-break.
                let _ = c.select_indices(&v[..5]);
                for _ in 0..50 {
                    assert_eq!(c.select_indices(&v), want);
                }
            })
        })
        .collect();
    for h in handles {
        h.join().unwrap();
    }
}

/// The selection fast path must agree with the sort baseline on
/// adversarial inputs too (duplicates, zeros, signed zeros).
#[test]
fn select_matches_sort_baseline_on_degenerate_inputs() {
    let cases: Vec<Vec<f64>> = vec![
        vec![0.0; 6],
        vec![-0.0, 0.0, -0.0, 0.0],
        vec![2.0, -2.0, 2.0, -2.0, 2.0],
        vec![1e-300, -1e-300, 1e300, -1e300],
    ];
    for v in cases {
        for k in 1..=v.len() {
            let c = TopK::new(k);
            assert_eq!(
                c.select_indices(&v),
                c.select_indices_via_sort(&v),
                "k={k} v={v:?}"
            );
        }
    }
}

/// ScaledSign's distortion has a closed form: `||C(v) - v||^2 =
/// ||v||^2 - ||v||_1^2 / d`, which simultaneously proves Eq. (3) with
/// `alpha = 1/d` pointwise AND pins the exact achieved ratio (a drifted
/// scale factor would move it).
#[test]
fn sign_distortion_matches_closed_form_exactly() {
    for_all_seeds(25, |rng| {
        let d = 1 + rng.next_below(70);
        let v = random_vec(rng, d, 3.0);
        let n2: f64 = v.iter().map(|x| x * x).sum();
        let l1: f64 = v.iter().map(|x| x.abs()).sum();
        let out = ScaledSign.compress(&v, rng);
        let dense = out.sparse.to_dense(d);
        let dist: f64 = dense.iter().zip(&v).map(|(a, b)| (a - b) * (a - b)).sum();
        let expect = n2 - l1 * l1 / d as f64;
        assert!(
            (dist - expect).abs() <= 1e-9 * n2.max(1.0),
            "d={d}: {dist} vs closed form {expect}"
        );
        // Pointwise Eq. (3) with alpha = 1/d follows.
        let alpha = ScaledSign.alpha(d);
        assert!(dist <= (1.0 - alpha) * n2 + 1e-9 * n2.max(1.0));
        // Wire cost is exactly d sign bits + one f32 scale.
        assert_eq!(out.bits, d as u64 + 32);
    });
}

/// Sign edge cases: the zero vector maps to exactly zero (stationarity
/// safety), and a NaN coordinate poisons the shared `||v||_1` scale — a
/// documented propagation, not a crash.
#[test]
fn sign_zero_and_nan_edges() {
    let mut rng = Rng::seed(3);
    let zeros = vec![0.0; 17];
    let out = ScaledSign.compress(&zeros, &mut rng).sparse.to_dense(17);
    assert!(out.iter().all(|&x| x == 0.0));
    // Zero coordinates stay *identically* zero (no signed-zero noise).
    let v = vec![1.0, 0.0, -2.0, -0.0];
    let out = ScaledSign.compress(&v, &mut rng).sparse.to_dense(4);
    assert_eq!(out[1], 0.0);
    assert_eq!(out[3], 0.0);
    assert!(out[0] > 0.0 && out[2] < 0.0);
    // NaN input: the l1 scale is NaN, so every signed output is NaN —
    // and never silently masked back to a finite value.
    let v = vec![1.0, f64::NAN, -3.0];
    let out = ScaledSign.compress(&v, &mut rng).sparse.to_dense(3);
    assert!(out[0].is_nan() && out[2].is_nan(), "NaN must propagate, got {out:?}");
}

/// Unbiasedness of Rand-k (Eq. 2's first moment): the empirical mean
/// over many draws approaches the input coordinate-wise.
#[test]
fn unbiased_randk_first_moment() {
    for_all_seeds(6, |rng| {
        let d = 4 + rng.next_below(20);
        let k = 1 + rng.next_below(d);
        let v = random_vec(rng, d, 1.0);
        let c = RandKUnbiased::new(k);
        let reps = 4000;
        let mut mean = vec![0.0; d];
        for _ in 0..reps {
            let out = c.compress(&v, rng).sparse.to_dense(d);
            for (m, o) in mean.iter_mut().zip(&out) {
                *m += o / reps as f64;
            }
        }
        for (i, (m, t)) in mean.iter().zip(&v).enumerate() {
            assert!(
                (m - t).abs() < 0.25 * (1.0 + t.abs()),
                "coordinate {i}: mean {m} vs true {t} (d={d}, k={k})"
            );
        }
    });
}

/// Eq. (2)'s second moment for unbiased Rand-k is exact:
/// `E||C(v)-v||^2 = (d/k - 1)||v||^2`; checked empirically with slack.
#[test]
fn unbiased_randk_variance_bound() {
    for_all_seeds(6, |rng| {
        let d = 4 + rng.next_below(24);
        let k = 1 + rng.next_below(d);
        let v = random_vec(rng, d, 1.5);
        let n2: f64 = v.iter().map(|x| x * x).sum();
        let c = RandKUnbiased::new(k);
        let omega = c.omega(d);
        let reps = 3000;
        let mean: f64 = (0..reps)
            .map(|_| {
                let out = c.compress(&v, rng).sparse.to_dense(d);
                out.iter().zip(&v).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
            })
            .sum::<f64>()
            / reps as f64;
        assert!(
            (mean / n2 - omega).abs() < 0.3 * (1.0 + omega),
            "d={d} k={k}: measured omega {} vs {omega}",
            mean / n2
        );
    });
}

/// Lemma 8: `(1/(1+omega)) C'` of an unbiased `C'` lands in
/// `B(1/(1+omega))` — the scaled operator satisfies Eq. (3) in
/// expectation with `alpha = k/d`.
#[test]
fn lemma8_scaled_unbiased_is_contractive() {
    for_all_seeds(8, |rng| {
        let d = 3 + rng.next_below(30);
        let k = 1 + rng.next_below(d);
        let c = Scaled::new(RandKUnbiased::new(k));
        let alpha = Compressor::alpha(&c, d);
        assert!((alpha - k.min(d) as f64 / d as f64).abs() < 1e-12);
        let v = random_vec(rng, d, 2.0);
        let reps = 500;
        let mean: f64 =
            (0..reps).map(|_| distortion_ratio(&c, &v, rng)).sum::<f64>() / reps as f64;
        assert!(
            mean <= (1.0 - alpha) * 1.2 + 1e-9,
            "d={d} k={k}: mean ratio {mean} vs 1-alpha {}",
            1.0 - alpha
        );
    });
}

/// Unbiased Rand-k edge cases: the zero vector compresses to exactly
/// zero bits of signal (all-zero output), a NaN coordinate only
/// propagates when sampled, and k >= d degenerates to the identity
/// (omega = 0).
#[test]
fn unbiased_randk_zero_nan_and_full_k_edges() {
    let mut rng = Rng::seed(11);
    let c = RandKUnbiased::new(3);
    let zeros = vec![0.0; 12];
    let out = c.compress(&zeros, &mut rng).sparse.to_dense(12);
    assert!(out.iter().all(|&x| x == 0.0));
    // k >= d: identity scaling (d/k = 1), omega = 0, output == input.
    let v = vec![1.0, -2.0, 0.5];
    let cfull = RandKUnbiased::new(7);
    assert_eq!(cfull.omega(3), 0.0);
    assert_eq!(cfull.compress(&v, &mut rng).sparse.to_dense(3), v);
    // NaN propagates exactly when its coordinate is kept.
    let v = vec![f64::NAN, 1.0];
    let c1 = RandKUnbiased::new(1);
    for _ in 0..40 {
        let out = c1.compress(&v, &mut rng).sparse.to_dense(2);
        let kept_nan = out[0].is_nan();
        let kept_other = out[1] != 0.0;
        assert!(
            kept_nan ^ kept_other,
            "exactly one coordinate must be kept: {out:?}"
        );
    }
}

/// Compressed vectors round-trip their sparse representation: the
/// payload the pool threads ship to the coordinator is exactly what a
/// dense reconstruction sees.
#[test]
fn compressed_payload_roundtrips_dense() {
    for_all_seeds(15, |rng| {
        let d = 1 + rng.next_below(50);
        let v = random_vec(rng, d, 1.5);
        let k = 1 + rng.next_below(d);
        let comp = TopK::new(k).compress(&v, rng);
        let dense = comp.sparse.to_dense(d);
        let again = SparseVec::from_dense_full(&dense);
        assert_eq!(again.to_dense(d), dense);
        assert!(comp.bits > 0);
    });
}
