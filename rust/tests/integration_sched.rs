//! Scheduler acceptance suite (ISSUE 4): partial participation and the
//! deterministic fault model across every runner.
//!
//!   * a noop scheduler (full participation, no faults) forced through
//!     the scheduled code path is bit-identical to the legacy protocol,
//!     for every algorithm and any pool width;
//!   * seeded PP/fault schedules are reproducible run-to-run and
//!     pool-width-invariant;
//!   * crash→rejoin resync restores EXACT worker state: a crash window
//!     is bitwise indistinguishable from the same rounds of plain
//!     absence (so post-rejoin uplink deltas match an uninterrupted
//!     worker's exactly), including under randomized compressors;
//!   * the same fault plan produces the same trajectory on the sim
//!     runner and over real transports (f32 wire tolerance vs sim;
//!     bitwise between local and TCP), with `dup` frames verified and
//!     deadline-cut stragglers never stalling the barrier;
//!   * EF21-PP at p = 0.5 converges on a heterogeneous least-squares
//!     problem at the `theory::stepsize_pp` stepsize.

use ef21::algo::{AlgoSpec, WorkerNode};
use ef21::compress::{Compressor, RandK, TopK};
use ef21::coordinator::dist::{run_distributed_sched, TransportKind};
use ef21::coordinator::runner::{run_protocol, RunConfig};
use ef21::coordinator::run_protocol_par;
use ef21::config::SchedSpec;
use ef21::exp::{Objective, Problem};
use ef21::metrics::History;
use ef21::oracle::GradOracle;
use ef21::sched::{FaultPlan, Participation, Scheduler};
use ef21::theory;
use std::sync::Arc;

fn quads() -> Vec<Box<dyn GradOracle>> {
    ef21::oracle::quadratic::divergence_example()
        .into_iter()
        .map(|q| Box::new(q) as Box<dyn GradOracle>)
        .collect()
}

fn quad(i: usize) -> Box<dyn GradOracle> {
    Box::new(ef21::oracle::quadratic::divergence_example().remove(i))
}

fn sched(part: Participation, faults: &str, deadline_ms: Option<u64>, n: usize) -> Arc<Scheduler> {
    Arc::new(
        Scheduler::new(part, FaultPlan::parse(faults).unwrap(), deadline_ms, n, 99).unwrap(),
    )
}

fn assert_histories_bitwise(a: &History, b: &History, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.round, y.round, "{what}");
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: loss at round {}", x.round);
        assert_eq!(
            x.grad_norm_sq.to_bits(),
            y.grad_norm_sq.to_bits(),
            "{what}: grad at round {}",
            x.round
        );
        assert_eq!(
            x.bits_per_client.to_bits(),
            y.bits_per_client.to_bits(),
            "{what}: bits at round {}",
            x.round
        );
        assert_eq!(x.gt.to_bits(), y.gt.to_bits(), "{what}: gt at round {}", x.round);
    }
    assert_eq!(a.final_x.len(), b.final_x.len(), "{what}: final_x dim");
    for (x, y) in a.final_x.iter().zip(&b.final_x) {
        assert_eq!(x.to_bits(), y.to_bits(), "{what}: final_x");
    }
}

/// `--participation full` with no faults is not allowed to move a single
/// bit — even when forced through the scheduled code path (round_subset,
/// plan derivation, absent-message plumbing) — for every algorithm and
/// pool width.
#[test]
fn noop_scheduler_is_bit_identical_for_all_algos_and_widths() {
    for algo in AlgoSpec::ALL {
        for threads in [1usize, 3] {
            let build = || {
                ef21::algo::build(algo, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5)
            };
            let (m, w) = build();
            let legacy = run_protocol_par(m, w, &RunConfig::rounds(30), threads);
            let (m, w) = build();
            let cfg = RunConfig::rounds(30).with_sched(Arc::new(Scheduler::noop(3)));
            let scheduled = run_protocol_par(m, w, &cfg, threads);
            assert_histories_bitwise(
                &legacy,
                &scheduled,
                &format!("{} threads={threads}", algo.name()),
            );
        }
    }
}

/// Seeded schedules are exactly reproducible run-to-run, and the
/// parallel pool reproduces the sequential scheduled trajectory at any
/// width (the subset path keeps worker-order reductions).
#[test]
fn seeded_pp_runs_are_reproducible_and_width_invariant() {
    let run = |threads: usize| {
        let (m, w) = ef21::algo::build(
            AlgoSpec::Ef21,
            vec![1.0; 3],
            quads(),
            Arc::new(TopK::new(1)),
            0.01,
            5,
        );
        let cfg = RunConfig::rounds(60)
            .with_sched(sched(Participation::Bernoulli(0.5), "", None, 3));
        run_protocol_par(m, w, &cfg, threads)
    };
    let a = run(1);
    let b = run(1);
    assert_histories_bitwise(&a, &b, "rerun");
    let c = run(3);
    assert_histories_bitwise(&a, &c, "width");
    // A different scheduler seed yields a different trajectory.
    let (m, w) = ef21::algo::build(
        AlgoSpec::Ef21,
        vec![1.0; 3],
        quads(),
        Arc::new(TopK::new(1)),
        0.01,
        5,
    );
    let other = Arc::new(
        Scheduler::new(Participation::Bernoulli(0.5), FaultPlan::none(), None, 3, 100).unwrap(),
    );
    let d = run_protocol_par(m, w, &RunConfig::rounds(60).with_sched(other), 1);
    let differs = a
        .records
        .iter()
        .zip(&d.records)
        .any(|(x, y)| x.loss.to_bits() != y.loss.to_bits());
    assert!(differs, "scheduler seed must matter");
}

/// Fixed-m sampling sends exactly m compressed messages per round: the
/// uplink accounting proves absent workers really go silent.
#[test]
fn fixed_m_uplink_bits_are_exact() {
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5);
    let cfg = RunConfig::rounds(10).with_sched(sched(Participation::FixedM(2), "", None, 3));
    let h = run_protocol_par(m, w, &cfg, 1);
    // Init: all 3 workers send one 64-bit entry; rounds: exactly 2.
    for (t, r) in h.records.iter().enumerate() {
        let expect = (3.0 * 64.0 + (t as f64 + 1.0) * 2.0 * 64.0) / 3.0;
        assert!(
            (r.bits_per_client - expect).abs() < 1e-9,
            "round {t}: {} vs {expect}",
            r.bits_per_client
        );
    }
}

/// Round-robin cohorts cycle deterministically (no seed sensitivity).
#[test]
fn round_robin_is_seed_independent() {
    let run = |sched_seed: u64| {
        let (m, w) = ef21::algo::build(
            AlgoSpec::Ef21,
            vec![1.0; 3],
            quads(),
            Arc::new(TopK::new(1)),
            0.01,
            5,
        );
        let s = Arc::new(
            Scheduler::new(
                Participation::RoundRobin(3),
                FaultPlan::none(),
                None,
                3,
                sched_seed,
            )
            .unwrap(),
        );
        run_protocol_par(m, w, &RunConfig::rounds(30).with_sched(s), 1)
    };
    assert_histories_bitwise(&run(1), &run(999), "rr seeds");
}

/// THE resync-exactness property: a crash window with rejoin is bitwise
/// indistinguishable from the same rounds of plain absence — the
/// StateSync reconstruction restores the exact f64 worker state, so
/// every post-rejoin uplink delta matches the uninterrupted worker's.
/// Covered for the deterministic Top-k AND the randomized Rand-k (whose
/// RNG stream must not advance while down).
#[test]
fn crash_rejoin_is_bitwise_equal_to_plain_absence() {
    let compressors: Vec<(&str, Arc<dyn Compressor>)> = vec![
        ("top1", Arc::new(TopK::new(1))),
        ("rand2", Arc::new(RandK::new(2))),
    ];
    for (name, c) in compressors {
        for algo in [AlgoSpec::Ef21, AlgoSpec::Ef21Plus] {
            if algo == AlgoSpec::Ef21Plus && name == "rand2" {
                continue; // EF21+ requires a deterministic compressor
            }
            let build = || {
                ef21::algo::build(algo, vec![1.0; 3], quads(), c.clone(), 0.01, 5)
            };
            let (m, w) = build();
            let crash = RunConfig::rounds(30)
                .with_sched(sched(Participation::Full, "crash@3,rejoin@6", None, 3));
            let h_crash = run_protocol(m, w, &crash);
            let (m, w) = build();
            let absent = RunConfig::rounds(30).with_sched(sched(
                Participation::Full,
                "drop(0@3),drop(0@4),drop(0@5)",
                None,
                3,
            ));
            let h_absent = run_protocol(m, w, &absent);
            // Loss/grad/bits and the final model must agree on EVERY
            // round; the G^t instrumentation legitimately differs inside
            // the crash window itself (the crashed worker's state reads
            // zero instead of held) — but must snap back bitwise from
            // the rejoin round on, which is exactly the resync claim.
            let what = format!("{} {name}", algo.name());
            assert_eq!(h_crash.records.len(), h_absent.records.len(), "{what}");
            for (x, y) in h_crash.records.iter().zip(&h_absent.records) {
                assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "{what}: loss r{}", x.round);
                assert_eq!(
                    x.grad_norm_sq.to_bits(),
                    y.grad_norm_sq.to_bits(),
                    "{what}: grad r{}",
                    x.round
                );
                assert_eq!(
                    x.bits_per_client.to_bits(),
                    y.bits_per_client.to_bits(),
                    "{what}: bits r{}",
                    x.round
                );
                if !(3..6).contains(&x.round) {
                    assert_eq!(x.gt.to_bits(), y.gt.to_bits(), "{what}: gt r{}", x.round);
                }
            }
            for (x, y) in h_crash.final_x.iter().zip(&h_absent.final_x) {
                assert_eq!(x.to_bits(), y.to_bits(), "{what}: final_x");
            }
            // The pooled runner routes crash/resync commands to the
            // owning chunk threads — same trajectory at any width.
            let (m, w) = build();
            let crash2 = RunConfig::rounds(30)
                .with_sched(sched(Participation::Full, "crash@3,rejoin@6", None, 3));
            let h_crash_par = run_protocol_par(m, w, &crash2, 2);
            assert_histories_bitwise(&h_crash, &h_crash_par, &format!("{what} pooled"));
        }
    }
}

/// Classic EF cannot model crashes (its error state is not
/// message-reconstructible); scheduling one for it must fail loudly up
/// front — even a crash WITHOUT a rejoin, which exercises no resync.
#[test]
#[should_panic(expected = "resync")]
fn crash_plan_on_ef_workers_is_rejected() {
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5);
    let cfg =
        RunConfig::rounds(10).with_sched(sched(Participation::Full, "crash@2", None, 3));
    let _ = run_protocol(m, w, &cfg);
}

/// A permanent crash (no rejoin) on a supporting worker is a valid
/// plan: the worker goes down at the crash round and stays down, and
/// that equals dropping it from every later round.
#[test]
fn permanent_crash_equals_permanent_absence() {
    let build = || {
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5)
    };
    let (m, w) = build();
    let crash = RunConfig::rounds(12)
        .with_sched(sched(Participation::Full, "w1:crash@4", None, 3));
    let h_crash = run_protocol(m, w, &crash);
    let (m, w) = build();
    let drops: String =
        (4..12).map(|r| format!("drop(1@{r})")).collect::<Vec<_>>().join(",");
    let absent_cfg =
        RunConfig::rounds(12).with_sched(sched(Participation::Full, &drops, None, 3));
    let h_absent = run_protocol(m, w, &absent_cfg);
    // Same loss/grad/bits trajectory; gt differs after the crash (state
    // zeroed vs held) exactly like the windowed case.
    for (x, y) in h_crash.records.iter().zip(&h_absent.records) {
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "round {}", x.round);
        assert_eq!(x.bits_per_client.to_bits(), y.bits_per_client.to_bits());
    }
    for (x, y) in h_crash.final_x.iter().zip(&h_absent.final_x) {
        assert_eq!(x.to_bits(), y.to_bits());
    }
}

/// A straggler past the deadline is cut to non-participation — exactly
/// equivalent to scheduled drops — and an in-deadline straggle is a
/// wall-clock matter only (the sim trajectory is untouched by it).
#[test]
fn deadline_cuts_equal_drops_and_slack_straggles_are_free() {
    let build = || {
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.01, 5)
    };
    let (m, w) = build();
    let cut = RunConfig::rounds(20).with_sched(sched(
        Participation::Full,
        "straggle(1,2..4,200ms)",
        Some(100),
        3,
    ));
    let h_cut = run_protocol(m, w, &cut);
    let (m, w) = build();
    let dropped = RunConfig::rounds(20).with_sched(sched(
        Participation::Full,
        "drop(1@2),drop(1@3),drop(1@4)",
        None,
        3,
    ));
    let h_dropped = run_protocol(m, w, &dropped);
    assert_histories_bitwise(&h_cut, &h_dropped, "deadline cut vs drops");

    // Within the deadline, the (virtual) delay changes nothing in sim.
    let (m, w) = build();
    let slack = RunConfig::rounds(20).with_sched(sched(
        Participation::Full,
        "straggle(1,2..4,50ms)",
        Some(100),
        3,
    ));
    let h_slack = run_protocol(m, w, &slack);
    let (m, w) = build();
    let clean = RunConfig::rounds(20).with_sched(sched(Participation::Full, "", Some(100), 3));
    let h_clean = run_protocol(m, w, &clean);
    assert_histories_bitwise(&h_slack, &h_clean, "in-deadline straggle");
}

fn dist_run(kind: TransportKind, faults: &str, deadline: Option<u64>, rounds: usize) -> History {
    let gamma = 0.01;
    let c: Arc<dyn Compressor> = Arc::new(TopK::new(1));
    let master = Box::new(ef21::algo::ef21::Ef21Master::new(vec![1.0; 3], 3, gamma));
    let s = sched(Participation::Bernoulli(0.7), faults, deadline, 3);
    let out = run_distributed_sched(
        master,
        3,
        move |i| {
            let rng = ef21::util::rng::worker_rng(9, i);
            Box::new(ef21::algo::ef21::Ef21Worker::new(quad(i), c.clone(), rng))
                as Box<dyn WorkerNode>
        },
        rounds,
        kind,
        "dist-sched",
        s,
    )
    .unwrap();
    out.history
}

/// The same seeded PP + fault plan yields the same trajectory on the sim
/// runner and over the local transport (to f32 wire rounding; uplink
/// bit accounting matches exactly).
#[test]
fn sim_and_local_transport_agree_under_faults() {
    let faults = "crash@2,rejoin@5,dup(1@3)";
    // Sim reference (same construction as dist_run, f64 end to end).
    let c: Arc<dyn Compressor> = Arc::new(TopK::new(1));
    let (m, w) =
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], quads(), c, 0.01, 9);
    let cfg = RunConfig::rounds(25)
        .with_sched(sched(Participation::Bernoulli(0.7), faults, None, 3));
    let h_sim = run_protocol(m, w, &cfg);
    let h_local = dist_run(TransportKind::Local, faults, None, 25);
    assert_eq!(h_sim.records.len(), h_local.records.len());
    for (a, b) in h_sim.records.iter().zip(&h_local.records) {
        assert!(
            (a.loss - b.loss).abs() < 1e-4 * a.loss.abs().max(1.0),
            "loss mismatch at {}: {} vs {}",
            a.round,
            a.loss,
            b.loss
        );
        assert!(
            (a.bits_per_client - b.bits_per_client).abs() < 1e-9,
            "bits mismatch at {}: {} vs {}",
            a.round,
            a.bits_per_client,
            b.bits_per_client
        );
    }
}

/// Local channels and real TCP sockets realize the identical scheduled
/// protocol — bitwise, since both quantize through the same codec.
#[test]
fn local_and_tcp_transports_agree_bitwise_under_faults() {
    let faults = "crash@2,rejoin@5,dup(0@3),straggle(1,3..4,30ms)";
    let h_local = dist_run(TransportKind::Local, faults, Some(500), 15);
    let h_tcp = dist_run(TransportKind::Tcp, faults, Some(500), 15);
    assert_eq!(h_local.records.len(), h_tcp.records.len());
    for (a, b) in h_local.records.iter().zip(&h_tcp.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "round {}", a.round);
        assert_eq!(a.bits_per_client.to_bits(), b.bits_per_client.to_bits());
    }
    for (a, b) in h_local.final_x.iter().zip(&h_tcp.final_x) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}

/// Duplicated uplink frames change the wire bytes but not the
/// trajectory (the master reads and verifies both copies).
#[test]
fn dup_frames_cost_bytes_but_not_trajectory() {
    let run = |faults: &str| {
        let gamma = 0.01;
        let c: Arc<dyn Compressor> = Arc::new(TopK::new(1));
        let master = Box::new(ef21::algo::ef21::Ef21Master::new(vec![1.0; 3], 3, gamma));
        let s = sched(Participation::Full, faults, None, 3);
        run_distributed_sched(
            master,
            3,
            move |i| {
                let rng = ef21::util::rng::worker_rng(9, i);
                Box::new(ef21::algo::ef21::Ef21Worker::new(quad(i), c.clone(), rng))
                    as Box<dyn WorkerNode>
            },
            10,
            TransportKind::Local,
            "dup",
            s,
        )
        .unwrap()
    };
    let clean = run("");
    let duped = run("dup(0@2),dup(2@5)");
    assert_histories_bitwise(&clean.history, &duped.history, "dup");
    assert!(
        duped.uplink_frame_bytes > clean.uplink_frame_bytes,
        "duplicates must cost wire bytes ({} vs {})",
        duped.uplink_frame_bytes,
        clean.uplink_frame_bytes
    );
}

/// A deadline-cut straggler must not stall the barrier: the scheduled
/// 300ms-per-round straggler is excluded, so the whole run finishes far
/// faster than the delays it would otherwise have imposed.
#[test]
fn deadline_keeps_the_barrier_moving() {
    let t0 = std::time::Instant::now();
    let h = dist_run(TransportKind::Local, "straggle(1,0..9,300ms)", Some(50), 10);
    let elapsed = t0.elapsed();
    assert_eq!(h.records.len(), 10);
    assert!(
        elapsed < std::time::Duration::from_millis(1500),
        "barrier stalled on a cut straggler: {elapsed:?} (10 rounds x 300ms were scheduled)"
    );
}

/// EF21-PP at p = 0.5 converges on a pathologically heterogeneous
/// least-squares problem (shards sorted by target) within the EF21-PP
/// theory stepsize.
#[test]
fn ef21_pp_converges_on_heterogeneous_lstsq_at_theory_stepsize() {
    let base = ef21::data::synth::generate_custom("pphet", 240, 8, 0.6, 3);
    let het = ef21::exp::pp::heterogenize(&base);
    let mut p = Problem::from_dataset(het, Objective::Lstsq, 4, 0.0);
    // Shards really are heterogeneous: per-shard mean targets differ.
    let shards = ef21::data::partition::shards(&p.dataset, 4);
    let means: Vec<f64> = shards
        .iter()
        .map(|s| s.y.iter().map(|&v| v as f64).sum::<f64>() / s.n as f64)
        .collect();
    assert!(
        means.windows(2).all(|w| w[0] <= w[1]) && means[3] > means[0],
        "heterogenize must skew the shards: {means:?}"
    );
    let pp = 0.5;
    let alpha = TopK::new(2).alpha(p.d());
    let gamma = theory::stepsize_pp(p.smoothness.l, p.smoothness.l_tilde, alpha, pp);
    assert!(gamma > 0.0);
    p.sched = SchedSpec {
        participation: Participation::Bernoulli(pp),
        ..SchedSpec::default()
    };
    let rounds = 20_000;
    let h = p.run_trial(AlgoSpec::Ef21, "top2", 1.0, Some(gamma), rounds, 500, 7);
    assert!(!h.diverged(), "EF21-PP diverged within the theory stepsize");
    let x_init = vec![0.0; p.d()];
    let (loss0, grad0_sq) = p.eval_at(&x_init);
    let (loss, grad_sq) = p.eval_at(&h.final_x);
    assert!(loss.is_finite() && loss < loss0, "no loss progress: {loss} vs {loss0}");
    assert!(
        grad_sq < grad0_sq * 1e-3,
        "EF21-PP failed to converge at the PP stepsize: exact |grad|^2 went \
         {grad0_sq:.3e} -> {grad_sq:.3e} over {rounds} rounds"
    );
}
