//! Differential suite for the deterministic parallel execution engine
//! (`coordinator::par`): for every algorithm × compressor family, the
//! pooled runner's `History` must equal the sequential runner's
//! **bit-for-bit** — same records, same `bits_per_client`, same stop
//! round — across seeds and pool widths; and `coordinator::dist` must
//! still match both (to f32 wire precision, its documented contract).

use ef21::algo::{AlgoSpec, MasterNode, WorkerNode};
use ef21::compress::{Compressor, Identity, RandK, ScaledSign, TopK};
use ef21::coordinator::runner::{run_protocol, RunConfig};
use ef21::coordinator::run_protocol_par;
use ef21::exp::{Objective, Problem};
use ef21::metrics::History;
use ef21::oracle::GradOracle;
use ef21::util::testing::for_all_seeds;
use std::sync::Arc;

/// The compressor grid of the differential sweep. EF21+ requires a
/// deterministic compressor (its constructor asserts), so Rand-k is
/// skipped for it — randomized compressors are still deterministic
/// *runs* here (seeded per-worker streams), which is exactly what the
/// bit-identity claim covers.
fn compressors() -> Vec<(&'static str, Arc<dyn Compressor>)> {
    vec![
        ("top2", Arc::new(TopK::new(2))),
        ("rand2", Arc::new(RandK::new(2))),
        ("sign", Arc::new(ScaledSign)),
        ("identity", Arc::new(Identity)),
    ]
}

fn small_problem(seed: u64) -> Problem {
    let ds = ef21::data::synth::generate_custom("par-diff", 240, 10, 0.4, seed);
    Problem::from_dataset(ds, Objective::LogReg, 5, 0.1)
}

fn build_nodes(
    p: &Problem,
    algo: AlgoSpec,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let oracles: Vec<Box<dyn GradOracle>> = p.oracles();
    ef21::algo::build(algo, vec![0.0; p.d()], oracles, c, gamma, seed)
}

#[track_caller]
fn assert_bit_identical(a: &History, b: &History, what: &str) {
    assert_eq!(a.records.len(), b.records.len(), "{what}: record count");
    for (ra, rb) in a.records.iter().zip(&b.records) {
        assert_eq!(ra.round, rb.round, "{what}: stop/record round");
        // to_bits: exact f64 equality that also treats NaN == NaN (both
        // runners produce the literal f64::NAN for absent fields).
        assert_eq!(
            ra.bits_per_client.to_bits(),
            rb.bits_per_client.to_bits(),
            "{what}: bits at round {}",
            ra.round
        );
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{what}: loss at {}", ra.round);
        assert_eq!(
            ra.grad_norm_sq.to_bits(),
            rb.grad_norm_sq.to_bits(),
            "{what}: |grad|^2 at {}",
            ra.round
        );
        assert_eq!(ra.gt.to_bits(), rb.gt.to_bits(), "{what}: G^t at {}", ra.round);
        assert_eq!(
            ra.dcgd_frac.to_bits(),
            rb.dcgd_frac.to_bits(),
            "{what}: dcgd at {}",
            ra.round
        );
    }
}

/// The core differential sweep: every algorithm × compressor × seed,
/// sequential vs pool widths 2 and 4 (5 workers ⇒ both uneven and
/// near-1:1 chunking).
#[test]
fn parallel_runner_is_bit_identical_across_algos_and_compressors() {
    for_all_seeds(3, |rng| {
        let seed = rng.next_u64() % 1000;
        let p = small_problem(seed);
        for algo in AlgoSpec::ALL {
            for (cname, c) in compressors() {
                if algo == AlgoSpec::Ef21Plus && !c.is_deterministic() {
                    continue;
                }
                let gamma = p.theory_gamma(c.alpha(p.d()));
                let cfg = RunConfig::rounds(40).with_record_every(3);
                let (m, w) = build_nodes(&p, algo, c.clone(), gamma, seed);
                let h_seq = run_protocol(m, w, &cfg);
                for threads in [2usize, 4] {
                    let (m, w) = build_nodes(&p, algo, c.clone(), gamma, seed);
                    let h_par = run_protocol_par(m, w, &cfg, threads);
                    assert_bit_identical(
                        &h_seq,
                        &h_par,
                        &format!("{:?} {cname} seed {seed} threads {threads}", algo),
                    );
                }
            }
        }
    });
}

/// Early stopping must agree: the gradient-tolerance exit fires at the
/// same round on both engines.
#[test]
fn grad_tol_stop_round_matches() {
    let quads = || -> Vec<Box<dyn GradOracle>> {
        ef21::oracle::quadratic::divergence_example()
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradOracle>)
            .collect()
    };
    let gamma = ef21::theory::stepsize_theorem1(16.0, 16.0, 1.0 / 3.0);
    let build = || {
        ef21::algo::build(
            AlgoSpec::Ef21,
            vec![1.0; 3],
            quads(),
            Arc::new(TopK::new(1)),
            gamma,
            0,
        )
    };
    let cfg = RunConfig::rounds(100_000).with_grad_tol(1e-10).with_record_every(37);
    let (m, w) = build();
    let h_seq = run_protocol(m, w, &cfg);
    let (m, w) = build();
    let h_par = run_protocol_par(m, w, &cfg, 3);
    assert!(h_seq.final_grad_norm_sq() <= 1e-10, "reference never converged");
    assert!(h_seq.records.last().unwrap().round < 99_999, "tolerance never hit");
    assert_bit_identical(&h_seq, &h_par, "grad-tol stop");
}

/// The divergence guard must abort at the same round with the same
/// recorded blow-up, whichever engine runs the round.
#[test]
fn divergence_round_matches() {
    let quads = || -> Vec<Box<dyn GradOracle>> {
        ef21::oracle::quadratic::divergence_example()
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradOracle>)
            .collect()
    };
    let build = || {
        ef21::algo::build(
            AlgoSpec::Dcgd,
            vec![1.0; 3],
            quads(),
            Arc::new(TopK::new(1)),
            10.0,
            0,
        )
    };
    let mut cfg = RunConfig::rounds(100_000).with_record_every(500);
    cfg.divergence_cap = 1e50;
    let (m, w) = build();
    let h_seq = run_protocol(m, w, &cfg);
    let (m, w) = build();
    let h_par = run_protocol_par(m, w, &cfg, 2);
    assert!(h_seq.records.last().unwrap().round < 99_999, "guard never fired");
    assert_bit_identical(&h_seq, &h_par, "divergence abort");
}

/// `coordinator::dist` (real transport, one thread per worker) still
/// matches both in-process engines to its documented f32 wire
/// precision, and exactly in bit accounting.
#[test]
fn dist_runner_still_matches_both() {
    use ef21::coordinator::dist::{run_distributed, TransportKind};
    let gamma = 0.01;
    let c: Arc<dyn Compressor> = Arc::new(TopK::new(1));
    let quad = |i: usize| -> Box<dyn GradOracle> {
        Box::new(ef21::oracle::quadratic::divergence_example().remove(i))
    };
    let build = || {
        let oracles: Vec<Box<dyn GradOracle>> = (0..3).map(quad).collect();
        ef21::algo::build(AlgoSpec::Ef21, vec![1.0; 3], oracles, c.clone(), gamma, 9)
    };
    let cfg = RunConfig::rounds(25);
    let (m, w) = build();
    let h_seq = run_protocol(m, w, &cfg);
    let (m, w) = build();
    let h_par = run_protocol_par(m, w, &cfg, 2);
    assert_bit_identical(&h_seq, &h_par, "seq vs par before dist");

    let master = Box::new(ef21::algo::ef21::Ef21Master::new(vec![1.0; 3], 3, gamma));
    let c2 = c.clone();
    let out = run_distributed(
        master,
        3,
        move |i| {
            // build()'s per-worker fork sequence, via the shared helper.
            let rng = ef21::util::rng::worker_rng(9, i);
            Box::new(ef21::algo::ef21::Ef21Worker::new(quad(i), c2.clone(), rng))
        },
        25,
        TransportKind::Local,
        "dist",
    )
    .unwrap();
    for (a, b) in h_par.records.iter().zip(&out.history.records) {
        assert!(
            (a.loss - b.loss).abs() < 1e-4 * a.loss.abs().max(1.0),
            "dist loss mismatch at {}: {} vs {}",
            a.round,
            a.loss,
            b.loss
        );
        assert!(
            (a.bits_per_client - b.bits_per_client).abs() < 1e-9,
            "dist bits mismatch at {}",
            a.round
        );
    }
}
