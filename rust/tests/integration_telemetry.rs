//! Telemetry integration: the global facade's disabled fast path, the
//! exact agreement between the `transport.uplink.bits` counter and the
//! simulated `bits_per_client` accounting, and both exporters serving
//! the same numbers.
//!
//! Global enable/disable lives in ONE test function: the remaining tests
//! use private `Registry` instances so this binary's parallel test
//! threads never race on the process-wide flag.

use ef21::algo::AlgoSpec;
use ef21::exp::{Objective, Problem};
use ef21::telemetry::{self, keys, Registry};
use ef21::util::json::Json;
use std::io::{Read as _, Write as _};
use std::sync::Arc;
use std::time::Duration;

#[test]
fn global_lifecycle_uplink_exactness_and_exporters() {
    // --- Disabled (default): handles are noop, registry untouched. ---
    let before_enable = telemetry::counter("itest.pre_enable");
    assert!(before_enable.is_noop());
    before_enable.incr(7);
    assert!(!telemetry::is_enabled());

    telemetry::enable();
    assert!(telemetry::is_enabled());
    assert_eq!(
        telemetry::snapshot().counter("itest.pre_enable"),
        None,
        "disabled-era increments must never reach the registry"
    );

    // --- 20-worker simulated EF21 run, 10 rounds: the telemetry uplink
    // counter must equal History::bits_per_client * n EXACTLY. ---
    let evals_before = telemetry::snapshot().counter(keys::ORACLE_GRAD_EVALS).unwrap_or(0);
    let bits_before = telemetry::snapshot().counter(keys::UPLINK_BITS).unwrap_or(0);
    let down_before = telemetry::snapshot().counter(keys::DOWNLINK_BITS).unwrap_or(0);
    let ds = ef21::data::synth::generate_custom("tele", 800, 16, 0.4, 7);
    let p = Problem::from_dataset(ds, Objective::LogReg, 20, 0.1);
    let h = p.run_trial(AlgoSpec::Ef21, "top2", 1.0, None, 10, 1, 3);
    assert!(!h.diverged());
    let bits_after = telemetry::snapshot().counter(keys::UPLINK_BITS).unwrap();
    // Downlink finally metered next to the uplink: flat layout = dense
    // accounting, (init + 10 rounds) x 32 bits x d = 16.
    let down_after = telemetry::snapshot().counter(keys::DOWNLINK_BITS).unwrap();
    assert_eq!(down_after - down_before, 11 * 32 * 16);
    assert_eq!(h.downlink_bits, 11 * 32 * 16);
    let bits_per_client = h.records.last().unwrap().bits_per_client;
    assert_eq!(
        bits_after - bits_before,
        (bits_per_client * 20.0).round() as u64,
        "uplink bits counter disagrees with the simulated accounting"
    );

    // Per-layer instrumentation fired: 20 workers x (init + 10 rounds)
    // gradient evaluations, compressor sparsity gauge, round latency.
    let evals_after = telemetry::snapshot().counter(keys::ORACLE_GRAD_EVALS).unwrap();
    assert_eq!(evals_after - evals_before, 20 * 11);

    // --- Same trial on the pooled runner (threads = 4): rounds execute
    // on pool threads, yet every per-run telemetry delta must be
    // IDENTICAL to the sequential run's — uplink bits (incremented
    // coordinator-side with the ordered per-round totals), gradient
    // evals (atomic, summed across threads), and the history itself. ---
    let h_pool = p.run_trial_threads(AlgoSpec::Ef21, "top2", 1.0, None, 10, 1, 3, 4);
    let bits_after_pool = telemetry::snapshot().counter(keys::UPLINK_BITS).unwrap();
    assert_eq!(
        bits_after_pool - bits_after,
        bits_after - bits_before,
        "threads=4 uplink delta != threads=1 delta"
    );
    assert_eq!(
        bits_after_pool - bits_after,
        (h_pool.records.last().unwrap().bits_per_client * 20.0).round() as u64,
        "pooled uplink bits counter disagrees with the simulated accounting"
    );
    for (a, b) in h.records.iter().zip(&h_pool.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "pooled history drifted");
        assert_eq!(a.bits_per_client.to_bits(), b.bits_per_client.to_bits());
    }
    let evals_after_pool = telemetry::snapshot().counter(keys::ORACLE_GRAD_EVALS).unwrap();
    assert_eq!(evals_after_pool - evals_after, 20 * 11, "pooled eval count drifted");
    // Per-thread chunk latency fired on pool threads: 4 chunks x 10
    // rounds (init is not chunk-timed).
    let snap_pool = telemetry::snapshot();
    let chunk = snap_pool.histogram(keys::POOL_CHUNK_NS).expect("chunk ns");
    assert_eq!(chunk.count, 4 * 10);
    let snap = telemetry::snapshot();
    let sparsity = snap.gauge("compress.top2.sparsity").expect("sparsity gauge");
    assert!((sparsity - 2.0 / 16.0).abs() < 1e-12, "top2 over d=16: {sparsity}");
    assert!(snap.histogram(keys::ROUND_NS).expect("round ns").count >= 10);

    // --- Per-worker round latency: both runners key a histogram per
    // worker, and the straggler report ranks all 20. ---
    let w0 = snap
        .histogram(&format!("{}0", keys::WORKER_ROUND_NS_PREFIX))
        .expect("per-worker histogram for w0");
    assert!(w0.count >= 10, "w0 timed on every round: {}", w0.count);
    assert_eq!(snap.straggler_report(25).len(), 20, "one report row per worker");
    let report = snap.render_straggler_report(5).expect("straggler report");
    assert!(report.contains("top 5 of 20 workers"), "{report}");

    // --- Recorder layering: a pushed layer receives every new record
    // alongside the global registry; popping restores the plain facade. ---
    let side = Arc::new(Registry::new());
    telemetry::push_layer(Arc::new(telemetry::RegistryRecorder::new(side.clone())));
    telemetry::counter("itest.layered").incr(5);
    telemetry::pop_layer();
    telemetry::counter("itest.layered").incr(2);
    assert_eq!(side.snapshot().counter("itest.layered"), Some(5));
    assert_eq!(telemetry::snapshot().counter("itest.layered"), Some(7));

    // --- JSONL exporter: last line carries the same cumulative counter. ---
    let path = std::env::temp_dir()
        .join(format!("ef21_itest_telemetry_{}.jsonl", std::process::id()));
    let exporter =
        telemetry::jsonl::JsonlExporter::spawn(&path, Duration::from_millis(50)).unwrap();
    std::thread::sleep(Duration::from_millis(80));
    exporter.stop().unwrap();
    let text = std::fs::read_to_string(&path).unwrap();
    let last = text.lines().last().expect("at least one jsonl line");
    let j = Json::parse(last).expect("valid json");
    assert_eq!(
        j.get("counters").unwrap().get(keys::UPLINK_BITS).unwrap().as_f64(),
        Some(bits_after_pool as f64)
    );
    std::fs::remove_file(&path).ok();

    // --- Prometheus TCP exposition serves the same counter. ---
    let server = telemetry::prom::PromServer::bind(0).unwrap();
    let mut conn =
        std::net::TcpStream::connect(("127.0.0.1", server.port())).unwrap();
    conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
    let mut response = String::new();
    conn.read_to_string(&mut response).unwrap();
    server.stop();
    assert!(response.starts_with("HTTP/1.0 200 OK"));
    assert!(
        response.contains(&format!("ef21_transport_uplink_bits {bits_after_pool}")),
        "exposition missing the uplink counter:\n{response}"
    );
    assert!(response.contains("# TYPE ef21_coordinator_round_ns histogram"));

    // --- Back to noop. ---
    telemetry::disable();
    assert!(telemetry::counter("itest.post_disable").is_noop());
}

#[test]
fn concurrent_counter_increments_sum_exactly() {
    let reg = Arc::new(Registry::new());
    let threads: Vec<_> = (0..8)
        .map(|_| {
            let reg = reg.clone();
            std::thread::spawn(move || {
                let c = reg.counter("itest.concurrent");
                for _ in 0..10_000 {
                    c.incr(3);
                }
            })
        })
        .collect();
    for t in threads {
        t.join().unwrap();
    }
    assert_eq!(reg.counter("itest.concurrent").get(), 8 * 10_000 * 3);
}

#[test]
fn histogram_buckets_are_log_linear_with_16_sub_buckets() {
    let reg = Registry::new();
    let h = reg.histogram("itest.hist");
    for v in [0u64, 1, 2, 3, 4, 31, 32, 33, 1023, 1024] {
        h.record(v);
    }
    let snap = reg.snapshot();
    let hs = snap.histogram("itest.hist").unwrap();
    assert_eq!(hs.count, 10);
    assert_eq!(hs.sum, 0 + 1 + 2 + 3 + 4 + 31 + 32 + 33 + 1023 + 1024);
    // Values below 32 land in exact unit buckets.
    for v in [0usize, 1, 2, 3, 4, 31] {
        assert_eq!(hs.buckets[v], 1, "unit bucket {v}");
    }
    // From 32 up, each octave splits into 16 sub-buckets of width
    // 2^(octave-4): 32 and 33 share [32, 34); 1023 tops out the
    // [992, 1024) sub-bucket; 1024 opens [1024, 1088).
    assert_eq!(hs.buckets[32], 2, "sub-bucket [32, 34) holds {{32, 33}}");
    assert_eq!(hs.buckets[111], 1, "sub-bucket [992, 1024) holds 1023");
    assert_eq!(hs.buckets[112], 1, "sub-bucket [1024, 1088) holds 1024");
    assert_eq!(hs.buckets.iter().sum::<u64>(), 10);
    assert_eq!(hs.max, 1024, "exact max rides alongside the buckets");
}

#[test]
fn noop_handles_have_a_zero_cost_shape() {
    // The disabled fast path hands out cell-free handles; recording
    // through them is a branch on None (nothing to observe afterwards).
    let reg = Registry::new();
    let live = reg.counter("itest.live");
    let noop = ef21::telemetry::Counter::noop();
    live.incr(1);
    noop.incr(1);
    assert_eq!(live.get(), 1);
    assert_eq!(noop.get(), 0);
    assert!(noop.is_noop() && !live.is_noop());
}
