//! Cross-module convergence tests: the paper's qualitative claims on real
//! (synthetic Table-3) problems, run through the full coordinator stack.

use ef21::algo::AlgoSpec;
use ef21::data::synth;
use ef21::exp::{Objective, Problem};

fn small_problem(seed: u64) -> Problem {
    let ds = synth::generate_custom("itest", 1000, 20, 0.4, seed);
    Problem::from_dataset(ds, Objective::LogReg, 5, 0.1)
}

/// [Beznosikov et al. 2020, Example 1] reproduced end-to-end: on three
/// conflicting quadratics, DCGD+Top-1 fails while EF, EF21, EF21+ all
/// converge at the same stepsize.
#[test]
fn dcgd_diverges_ef_family_converges() {
    use ef21::coordinator::runner::{run_protocol, RunConfig};
    use ef21::oracle::GradOracle;
    use std::sync::Arc;

    let quads = || -> Vec<Box<dyn GradOracle>> {
        ef21::oracle::quadratic::divergence_example()
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradOracle>)
            .collect()
    };
    let gamma = ef21::theory::stepsize_theorem1(16.0, 16.0, 1.0 / 3.0);
    let mut outcomes = Vec::new();
    for algo in [AlgoSpec::Dcgd, AlgoSpec::Ef, AlgoSpec::Ef21, AlgoSpec::Ef21Plus] {
        let (m, w) = ef21::algo::build(
            algo,
            vec![1.0; 3],
            quads(),
            Arc::new(ef21::compress::TopK::new(1)),
            gamma,
            0,
        );
        let h = run_protocol(m, w, &RunConfig::rounds(20_000).with_grad_tol(1e-10));
        outcomes.push((algo, h.final_grad_norm_sq()));
    }
    let (_, dcgd) = outcomes[0];
    assert!(dcgd > 1e-8, "DCGD should not converge, got {dcgd:.3e}");
    // EF famously gets *stuck at an accuracy level* (Figure 1) — it must
    // stay finite but need not reach stationarity.
    let (_, ef) = outcomes[1];
    assert!(ef.is_finite(), "EF blew up: {ef:.3e}");
    // EF21 and EF21+ converge to stationarity (Theorem 1 regime).
    for &(algo, g) in &outcomes[2..] {
        assert!(g <= 1e-10, "{} failed to converge: {g:.3e}", algo.name());
    }
}

/// On a heterogeneous logistic problem, every EF-family method at the 1x
/// theory stepsize makes monotone-ish progress and EF21 reaches a
/// stationarity level DCGD cannot.
#[test]
fn ef21_beats_dcgd_floor_on_logreg() {
    let p = small_problem(1);
    let h_dcgd = p.run_trial(AlgoSpec::Dcgd, "top1", 1.0, None, 2500, 25, 0);
    let h_ef21 = p.run_trial(AlgoSpec::Ef21, "top1", 1.0, None, 2500, 25, 0);
    let floor_dcgd = h_dcgd.best_grad_norm_sq();
    let floor_ef21 = h_ef21.best_grad_norm_sq();
    assert!(
        floor_ef21 < floor_dcgd * 0.5,
        "EF21 floor {floor_ef21:.3e} vs DCGD floor {floor_dcgd:.3e}"
    );
}

/// G^t (compression distortion) must vanish along EF21's trajectory —
/// the Markov-compressor mechanism working as designed (§3.1).
#[test]
fn gt_vanishes_along_ef21_run() {
    let p = small_problem(2);
    let h = p.run_trial(AlgoSpec::Ef21, "top2", 1.0, None, 3000, 10, 0);
    let early = h.records[2].gt;
    let late = h.records.last().unwrap().gt;
    assert!(late < early * 1e-2, "G^t not vanishing: {early:.3e} -> {late:.3e}");
}

/// EF21+ is never slower than EF21 in rounds-to-tolerance on the same
/// problem/seed (it picks the better branch pointwise).
#[test]
fn ef21plus_at_least_matches_ef21() {
    let p = small_problem(3);
    let tol = 1e-7;
    let h21 = p.run_trial(AlgoSpec::Ef21, "top1", 2.0, None, 6000, 1, 0);
    let hplus = p.run_trial(AlgoSpec::Ef21Plus, "top1", 2.0, None, 6000, 1, 0);
    let r21 = h21.rounds_to_tolerance(tol);
    let rplus = hplus.rounds_to_tolerance(tol);
    assert!(rplus.is_some(), "EF21+ never reached tol");
    if let (Some(a), Some(b)) = (rplus, r21) {
        // Allow 25% slack: branch switching can locally reorder progress.
        assert!(
            (a as f64) <= (b as f64) * 1.25,
            "EF21+ rounds {a} vs EF21 {b}"
        );
    }
}

/// Stochastic regime (Algorithm 5): EF21 with minibatch oracles still
/// drives the full gradient down on the logistic problem.
#[test]
fn ef21_sgd_algorithm5_converges_stochastically() {
    use ef21::coordinator::runner::{run_protocol, RunConfig};
    use ef21::data::partition;
    use ef21::oracle::{GradOracle, LogRegOracle, StochasticOracle};
    use ef21::util::rng::Rng;
    use std::sync::Arc;

    let ds = synth::generate_custom("sgd", 1200, 16, 0.4, 4);
    let lam = 0.1;
    let shards = partition::shards(&ds, 4);
    let oracles: Vec<Box<dyn GradOracle>> = shards
        .iter()
        .enumerate()
        .map(|(i, s)| {
            Box::new(StochasticOracle::new(
                LogRegOracle::new(*s, lam),
                64,
                Rng::seed(100 + i as u64),
            )) as Box<dyn GradOracle>
        })
        .collect();
    let p = Problem::from_dataset(ds.clone(), Objective::LogReg, 4, lam);
    let gamma = 4.0 * p.theory_gamma(2.0 / 16.0);
    let (m, w) = ef21::algo::build(
        AlgoSpec::Ef21,
        vec![0.0; 16],
        oracles,
        Arc::new(ef21::compress::TopK::new(2)),
        gamma,
        0,
    );
    let h = run_protocol(m, w, &RunConfig::rounds(4000));
    // h.loss is a minibatch estimate; average the tail to beat the noise
    // and compare against the analytic starting loss f(0) = ln 2 (+ zero
    // regularizer). The synthetic labels carry ~12% noise, so the
    // attainable floor is well above zero — require clear progress.
    let tail: f64 =
        h.records[h.records.len() - 100..].iter().map(|r| r.loss).sum::<f64>() / 100.0;
    let start = std::f64::consts::LN_2;
    assert!(
        tail < start * 0.97,
        "no stochastic progress: f(0)={start:.4} -> tail {tail:.4}"
    );
}
