//! SIMD bit-identity suite: the dispatched AVX2/SSE2 kernels must be
//! **bitwise** equal to the scalar reference on every input — including
//! lengths with every `% 4` remainder, NaN/±inf payload propagation,
//! and subnormals — and whole trajectories must be byte-identical under
//! `EF21_FORCE_SCALAR` vs the dispatched path (the golden-trajectory
//! lock for the runtime-dispatch contract, DESIGN.md §8).
//!
//! Tests that pin the ISA via `simd::set_override` serialize on a local
//! mutex. A concurrently-running test observing a temporary override
//! still computes identical values (that is exactly the contract under
//! test), so the override is safe to flip; the mutex only keeps the
//! pin/unpin windows from interleaving.

use ef21::algo::AlgoSpec;
use ef21::compress::{Compressor, TopK};
use ef21::coordinator::{run_protocol, RunConfig};
use ef21::data::synth;
use ef21::metrics::History;
use ef21::oracle::{GradOracle, LogRegOracle, LstsqOracle};
use ef21::util::rng::Rng;
use ef21::util::simd::{self, Isa};
use std::sync::Mutex;

static ISA_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    ISA_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

/// Pin the ISA for a scope; restores the detected default on drop.
struct ForceIsa;
impl ForceIsa {
    fn new(isa: Isa) -> ForceIsa {
        simd::set_override(Some(isa));
        ForceIsa
    }
}
impl Drop for ForceIsa {
    fn drop(&mut self) {
        simd::set_override(None);
    }
}

const ISAS: [Isa; 3] = [Isa::Scalar, Isa::Sse2, Isa::Avx2];

/// Inputs mixing normals with NaN, ±inf, subnormals, zeros, and exact
/// ties — the payload classes where a reordered or fused vector path
/// would betray itself bitwise.
fn adversarial_vec(d: usize, seed: u64) -> Vec<f64> {
    let mut rng = Rng::seed(seed);
    (0..d)
        .map(|j| match (j + seed as usize) % 11 {
            0 => f64::NAN,
            1 => f64::INFINITY,
            2 => f64::NEG_INFINITY,
            3 => f64::MIN_POSITIVE / 8.0, // subnormal
            4 => -f64::MIN_POSITIVE,
            5 => 0.0,
            6 => -0.0,
            7 => 1.0, // exact ties with other 1.0 entries
            _ => rng.next_normal(),
        })
        .collect()
}

#[test]
fn kernels_bit_identical_across_isas_on_adversarial_inputs() {
    let _l = lock();
    for d in [1usize, 2, 3, 4, 5, 6, 7, 8, 9, 15, 16, 17, 33, 100, 127] {
        for seed in 0..4u64 {
            let a = adversarial_vec(d, seed);
            let b = adversarial_vec(d, seed + 100);
            let row: Vec<f32> = adversarial_vec(d, seed + 200)
                .iter()
                .map(|&x| x as f32)
                .collect();
            // Reference under forced scalar.
            let (r_dot, r_dotf, r_axpy, r_sub) = {
                let _g = ForceIsa::new(Isa::Scalar);
                let mut y = b.clone();
                simd::axpy(1.5, &a, &mut y);
                simd::axpy_f32(-0.75, &row, &mut y);
                let mut s = vec![0.0; d];
                simd::sub_into(&a, &b, &mut s);
                (simd::dot(&a, &b), simd::dot_f32_f64(&row, &a), y, s)
            };
            for isa in ISAS {
                let _g = ForceIsa::new(isa);
                assert_eq!(
                    simd::dot(&a, &b).to_bits(),
                    r_dot.to_bits(),
                    "dot d={d} seed={seed} {isa:?}"
                );
                assert_eq!(
                    simd::dot_f32_f64(&row, &a).to_bits(),
                    r_dotf.to_bits(),
                    "dot_f32_f64 d={d} seed={seed} {isa:?}"
                );
                let mut y = b.clone();
                simd::axpy(1.5, &a, &mut y);
                simd::axpy_f32(-0.75, &row, &mut y);
                for (got, want) in y.iter().zip(&r_axpy) {
                    assert_eq!(got.to_bits(), want.to_bits(), "axpy d={d} seed={seed} {isa:?}");
                }
                let mut s = vec![0.0; d];
                simd::sub_into(&a, &b, &mut s);
                for (got, want) in s.iter().zip(&r_sub) {
                    assert_eq!(got.to_bits(), want.to_bits(), "sub d={d} seed={seed} {isa:?}");
                }
            }
        }
    }
}

#[test]
fn blocked_matvec_kernels_match_row_calls_across_isas() {
    let _l = lock();
    for d in [1usize, 3, 4, 5, 8, 13, 64, 65] {
        let mut rng = Rng::seed(d as u64 + 9);
        let rows: Vec<Vec<f32>> = (0..4)
            .map(|r| {
                adversarial_vec(d, r as u64 + 50)
                    .iter()
                    .map(|&x| x as f32)
                    .collect()
            })
            .collect();
        let x: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
        let coef = [2.0, -0.5, 0.125, -3.0];
        // Reference: four sequential single-row kernels, forced scalar.
        let (r_dots, r_y) = {
            let _g = ForceIsa::new(Isa::Scalar);
            let dots: Vec<f64> = rows.iter().map(|r| simd::dot_f32_f64(r, &x)).collect();
            let mut y = x.clone();
            for (c, r) in coef.iter().zip(&rows) {
                simd::axpy_f32(*c, r, &mut y);
            }
            (dots, y)
        };
        for isa in ISAS {
            let _g = ForceIsa::new(isa);
            let got = simd::dot4_f32_f64(&rows[0], &rows[1], &rows[2], &rows[3], &x);
            for lane in 0..4 {
                assert_eq!(
                    got[lane].to_bits(),
                    r_dots[lane].to_bits(),
                    "dot4 lane {lane} d={d} {isa:?}"
                );
            }
            let mut y = x.clone();
            simd::axpy4_f32(coef, &rows[0], &rows[1], &rows[2], &rows[3], &mut y);
            for (got, want) in y.iter().zip(&r_y) {
                assert_eq!(got.to_bits(), want.to_bits(), "axpy4 d={d} {isa:?}");
            }
        }
    }
}

/// The register-blocked oracle evaluation must equal the legacy
/// row-at-a-time walk bitwise, for every `n % 4` remainder — under the
/// dispatched ISA *and* forced scalar.
#[test]
fn oracle_blocked_rows_match_rowwise_baseline_bitwise() {
    let _l = lock();
    for n in [1usize, 2, 3, 4, 5, 7, 8, 9, 12, 30, 33] {
        let ds = synth::generate_custom("simdid", n.max(4), 9, 0.5, n as u64);
        let mut rng = Rng::seed(n as u64);
        let x: Vec<f64> = (0..9).map(|_| rng.next_normal()).collect();
        for isa in ISAS {
            let _g = ForceIsa::new(isa);
            let mut lr = LogRegOracle::new(ds.slice(0, n.min(ds.n)), 0.1);
            let mut want = Vec::new();
            let want_loss = lr.loss_grad_rowwise(&x, &mut want);
            let mut got = Vec::new();
            let got_loss = lr.loss_grad_into(&x, &mut got);
            assert_eq!(got_loss.to_bits(), want_loss.to_bits(), "logreg loss n={n} {isa:?}");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "logreg grad n={n} {isa:?}");
            }

            let mut ls = LstsqOracle::new(ds.slice(0, n.min(ds.n)));
            let want_loss = ls.loss_grad_rowwise(&x, &mut want);
            let got_loss = ls.loss_grad_into(&x, &mut got);
            assert_eq!(got_loss.to_bits(), want_loss.to_bits(), "lstsq loss n={n} {isa:?}");
            for (g, w) in got.iter().zip(&want) {
                assert_eq!(g.to_bits(), w.to_bits(), "lstsq grad n={n} {isa:?}");
            }
        }
    }
}

/// Tie-breaks feeding top-k: the Markov difference `grad - g` computed
/// by any ISA must select the identical top-k support (ties broken by
/// index), so compressed messages cannot depend on the dispatch.
#[test]
fn topk_selection_identical_across_isas_with_ties() {
    let _l = lock();
    for seed in 0..6u64 {
        let d = 40;
        let g = adversarial_vec(d, seed + 300);
        // A gradient engineered to tie with g on half the coordinates.
        let grad: Vec<f64> = g
            .iter()
            .enumerate()
            .map(|(j, &v)| if j % 2 == 0 { v } else { v + 1.0 })
            .collect();
        let select = |isa: Isa| {
            let _f = ForceIsa::new(isa);
            let mut diff = vec![0.0; d];
            // NaN-free lanes only for the selection input: replace
            // non-finite diffs deterministically so TopK sees ties.
            simd::sub_into(&grad, &g, &mut diff);
            for v in diff.iter_mut() {
                if !v.is_finite() {
                    *v = 1.0;
                }
            }
            TopK::new(7).select_indices(&diff)
        };
        let want = select(Isa::Scalar);
        for isa in ISAS {
            assert_eq!(select(isa), want, "seed={seed} {isa:?}");
        }
    }
}

fn ef21_trajectory(rounds: usize) -> History {
    let p = synth::generate_custom("simdtraj", 600, 14, 0.4, 11);
    let shards = ef21::data::partition::shards(&p, 4);
    let oracles: Vec<Box<dyn GradOracle>> = shards
        .iter()
        .map(|s| Box::new(LogRegOracle::new(*s, 0.1)) as Box<dyn GradOracle>)
        .collect();
    let c = std::sync::Arc::new(TopK::new(2));
    let alpha = Compressor::alpha(&*c, 14);
    let l = 2.0;
    let gamma = ef21::theory::stepsize_theorem1(l, l, alpha);
    let (m, w) = ef21::algo::build(AlgoSpec::Ef21, vec![0.0; 14], oracles, c, gamma, 5);
    run_protocol(m, w, &RunConfig::rounds(rounds))
}

/// Forced-scalar vs dispatched-SIMD golden-trajectory lock: every
/// recorded f64 of a full EF21 run must agree to the bit.
#[test]
fn forced_scalar_trajectory_is_byte_identical_to_dispatched() {
    let _l = lock();
    let scalar = {
        let _g = ForceIsa::new(Isa::Scalar);
        ef21_trajectory(60)
    };
    let dispatched = ef21_trajectory(60); // detected ISA (AVX2 on CI)
    assert_eq!(scalar.records.len(), dispatched.records.len());
    for (a, b) in scalar.records.iter().zip(&dispatched.records) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "round {}", a.round);
        assert_eq!(a.grad_norm_sq.to_bits(), b.grad_norm_sq.to_bits());
        assert_eq!(a.bits_per_client.to_bits(), b.bits_per_client.to_bits());
        assert_eq!(a.gt.to_bits(), b.gt.to_bits());
    }
    for (a, b) in scalar.final_x.iter().zip(&dispatched.final_x) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
}
