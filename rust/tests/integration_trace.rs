//! End-to-end tracing: `--telemetry trace:<path>` on a short simulated
//! run must produce a chrome://tracing file whose `coordinator.round`
//! spans account for (at least) the wall time the `coordinator.round.ns`
//! histogram measured, with per-worker and per-phase spans present.
//!
//! This binary holds the ONLY test that turns the process-wide tracing
//! flag on end-to-end (the lib's single unit test exercises the span
//! machinery in the lib binary; integration_telemetry.rs never traces),
//! so the global flag cannot race across parallel test threads.

use ef21::algo::AlgoSpec;
use ef21::exp::{Objective, Problem};
use ef21::telemetry::{self, keys};
use ef21::util::json::Json;

#[test]
fn trace_spans_cover_the_round_loop() {
    let path = std::env::temp_dir().join(format!("ef21_itest_trace_{}.json", std::process::id()));
    let guard = telemetry::init_from_spec(&format!("trace:{}", path.display())).unwrap();
    assert!(telemetry::is_enabled(), "trace: spec enables the metrics facade too");

    const ROUNDS: usize = 30;
    let ds = ef21::data::synth::generate_custom("trace", 600, 12, 0.4, 11);
    let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
    let h = p.run_trial(AlgoSpec::Ef21, "top2", 1.0, None, ROUNDS, 1, 5);
    assert!(!h.diverged());
    let snap = telemetry::snapshot();
    let round_ns_sum = snap.histogram(keys::ROUND_NS).expect("round ns histogram").sum;

    guard.shutdown().unwrap();
    telemetry::disable();
    assert!(!telemetry::trace::is_tracing(), "shutdown stops capture");

    let text = std::fs::read_to_string(&path).unwrap();
    std::fs::remove_file(&path).ok();
    let j = Json::parse(&text).expect("trace file parses as JSON");
    let evs = j.get("traceEvents").unwrap().as_arr().unwrap();

    // Thread-name metadata and a bounded (here: zero) drop count.
    assert!(evs.iter().any(|e| e.get("ph").unwrap().as_str() == Some("M")));
    assert_eq!(
        j.get("otherData").unwrap().get("dropped_events").unwrap().as_f64(),
        Some(0.0)
    );

    // One coordinator.round complete event per round, together covering
    // >= 95% of the wall time the round histogram recorded (the span
    // brackets the same region the timer measures).
    let rounds: Vec<&Json> = evs
        .iter()
        .filter(|e| e.get("name").unwrap().as_str() == Some("coordinator.round"))
        .collect();
    assert_eq!(rounds.len(), ROUNDS, "one round span per round");
    let span_us: f64 = rounds.iter().map(|e| e.get("dur").unwrap().as_f64().unwrap()).sum();
    let hist_us = round_ns_sum as f64 / 1_000.0;
    assert!(
        span_us >= 0.95 * hist_us,
        "round spans cover only {span_us:.1}us of {hist_us:.1}us measured"
    );
    // Round spans carry their round index.
    assert!(rounds
        .iter()
        .any(|e| e.get("args").unwrap().get("round").unwrap().as_f64() == Some(0.0)));

    // Phase, per-worker, and leaf (oracle/compressor) spans all landed.
    for name in [
        "round.broadcast",
        "round.workers",
        "round.absorb",
        "round.observe",
        "worker.round",
        "oracle.grad",
        "compress.apply",
    ] {
        assert!(
            evs.iter().any(|e| e.get("name").unwrap().as_str() == Some(name)),
            "missing {name} spans in the exported trace"
        );
    }
    // All four workers show up as worker.round annotations.
    for w in 0..4u64 {
        assert!(
            evs.iter().any(|e| {
                e.get("name").unwrap().as_str() == Some("worker.round")
                    && e.get("args").and_then(|a| a.get("w")).and_then(Json::as_f64)
                        == Some(w as f64)
            }),
            "missing worker.round span for worker {w}"
        );
    }
}
