//! Transport-stack integration: the threaded distributed runner over local
//! channels AND real TCP sockets reproduces the simulated EF21 trajectory
//! (to f32 wire precision), with consistent byte/bit accounting.

use ef21::algo::AlgoSpec;
use ef21::coordinator::dist::{run_distributed, TransportKind};
use ef21::coordinator::runner::{run_protocol, RunConfig};
use ef21::data::{partition, synth};
use ef21::oracle::{GradOracle, LogRegOracle};
use ef21::util::rng::Rng;
use std::sync::Arc;

fn problem_data() -> (ef21::data::Dataset, f64) {
    (synth::generate_custom("tp", 600, 12, 0.4, 9), 0.1)
}

fn sequential_reference(rounds: usize, gamma: f64) -> ef21::metrics::History {
    let (ds, lam) = problem_data();
    let oracles: Vec<Box<dyn GradOracle>> = partition::shards(&ds, 4)
        .into_iter()
        .map(|s| Box::new(LogRegOracle::new(s, lam)) as Box<dyn GradOracle>)
        .collect();
    let (m, w) = ef21::algo::build(
        AlgoSpec::Ef21,
        vec![0.0; ds.d],
        oracles,
        Arc::new(ef21::compress::TopK::new(2)),
        gamma,
        17,
    );
    run_protocol(m, w, &RunConfig::rounds(rounds))
}

fn distributed(
    rounds: usize,
    gamma: f64,
    kind: TransportKind,
) -> ef21::coordinator::dist::DistOutcome {
    let (ds, lam) = problem_data();
    let d = ds.d;
    let shards: Vec<(Vec<f32>, Vec<f32>, usize, usize)> = partition::shards(&ds, 4)
        .into_iter()
        .map(|s| (s.a.to_vec(), s.y.to_vec(), s.n, s.d))
        .collect();
    let master = Box::new(ef21::algo::ef21::Ef21Master::new(vec![0.0; d], 4, gamma));
    run_distributed(
        master,
        4,
        move |i| {
            let (a, y, n, d) = shards[i].clone();
            let oracle = Box::new(LogRegOracle::from_parts(a, y, n, d, lam));
            let c: Arc<dyn ef21::compress::Compressor> =
                Arc::new(ef21::compress::TopK::new(2));
            let mut base = Rng::seed(17);
            let mut rng = base.fork(0);
            for j in 1..=i {
                rng = base.fork(j as u64);
            }
            Box::new(ef21::algo::ef21::Ef21Worker::new(oracle, c, rng))
        },
        rounds,
        kind,
        "dist",
    )
    .expect("distributed run")
}

fn check_against_reference(kind: TransportKind) {
    let rounds = 30;
    let gamma = 0.05;
    let h_ref = sequential_reference(rounds, gamma);
    let out = distributed(rounds, gamma, kind);
    assert_eq!(out.history.records.len(), h_ref.records.len());
    for (a, b) in h_ref.records.iter().zip(&out.history.records) {
        assert!(
            (a.loss - b.loss).abs() < 1e-4 * a.loss.abs().max(1.0),
            "round {}: {} vs {}",
            a.round,
            a.loss,
            b.loss
        );
        assert!((a.bits_per_client - b.bits_per_client).abs() < 1e-9);
    }
    // Transport moved real bytes.
    assert!(out.uplink_frame_bytes > 0);
    assert!(out.final_x.iter().all(|v| v.is_finite()));
}

#[test]
fn local_channel_transport_matches_simulation() {
    check_against_reference(TransportKind::Local);
}

#[test]
fn tcp_transport_matches_simulation() {
    check_against_reference(TransportKind::Tcp);
}

/// Payload byte accounting: the wire frames carry exactly the accounted
/// bits (plus fixed per-frame headers).
#[test]
fn frame_bytes_are_consistent_with_bit_accounting() {
    let rounds = 10;
    let out = distributed(rounds, 0.05, TransportKind::Local);
    // 4 workers, k=2 top-k: payload = 2*(32+32) bits = 16 bytes; header =
    // tag(1)+kind(1)+loss(8)+bits(8)+nnz(4) = 22 bytes. Per gather: 4
    // frames. Total gathers = rounds + 1 (init).
    let expect = (rounds as u64 + 1) * 4 * (22 + 16);
    assert_eq!(out.uplink_frame_bytes, expect);
}
