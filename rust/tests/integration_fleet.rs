//! Fleet-scale integration: the sharded reactor master is bit-identical
//! to the thread-per-connection engine (every algorithm, deterministic
//! and randomized compressors, local channels and real TCP), the
//! hierarchical aggregation tree reproduces the flat worker-order fold
//! bitwise at every fan-out/shard split, sparse state mirrors survive a
//! crash→image→restore cycle bit-identically to a dense replay, and a
//! 2000-client simulated fleet completes a bounded-time smoke run.

use ef21::algo::{AlgoSpec, MasterNode, WireMsg, WorkerNode};
use ef21::compress::Compressor;
use ef21::coordinator::dist::{run_distributed, DistOutcome, TransportKind};
use ef21::coordinator::fleet::{dense_digest, reference_round, FleetSpec};
use ef21::coordinator::reactor::run_reactor;
use ef21::data::{partition, synth};
use ef21::oracle::{GradOracle, LogRegOracle};
use ef21::sched::StateTracker;
use ef21::telemetry;
use ef21::util::linalg;
use ef21::util::rng::Rng;
use std::collections::BTreeMap;
use std::sync::{Arc, Mutex};

const N_WORKERS: usize = 6;
const ROUNDS: usize = 15;
const GAMMA: f64 = 0.05;

/// Build the (master, make_worker) pair for one engine run. Both engines
/// get byte-identical node constructions, so any trajectory divergence
/// is the engine's fault.
fn nodes(
    algo: AlgoSpec,
    comp: &str,
) -> (Box<dyn MasterNode>, impl Fn(usize) -> Box<dyn WorkerNode> + Send + Sync + 'static) {
    let ds = synth::generate_custom("fleet", 480, 10, 0.4, 3);
    let oracles: Vec<Box<dyn GradOracle>> = partition::shards(&ds, N_WORKERS)
        .into_iter()
        .map(|s| Box::new(LogRegOracle::new(s, 0.1)) as Box<dyn GradOracle>)
        .collect();
    let c: Arc<dyn Compressor> = Arc::from(ef21::compress::from_spec(comp).expect("spec"));
    let (m, w) = ef21::algo::build(algo, vec![0.0; ds.d], oracles, c, GAMMA, 17);
    let slots = Mutex::new(w.into_iter().map(Some).collect::<Vec<_>>());
    let make = move |i: usize| slots.lock().unwrap()[i].take().expect("worker built twice");
    (m, make)
}

fn run_threads(algo: AlgoSpec, comp: &str, kind: TransportKind) -> DistOutcome {
    let (m, make) = nodes(algo, comp);
    run_distributed(m, N_WORKERS, make, ROUNDS, kind, "threads").expect("thread engine")
}

fn run_reactor_engine(
    algo: AlgoSpec,
    comp: &str,
    kind: TransportKind,
    shards: usize,
) -> DistOutcome {
    let (m, make) = nodes(algo, comp);
    run_reactor(m, N_WORKERS, make, ROUNDS, kind, "reactor", shards).expect("reactor engine")
}

/// Bitwise trajectory equality: every recorded f64 compared by bits, not
/// tolerance — the reactor's contract is exact lockstep reproduction.
fn assert_bitwise_equal(a: &DistOutcome, b: &DistOutcome, what: &str) {
    assert_eq!(a.history.records.len(), b.history.records.len(), "{what}: record count");
    for (ra, rb) in a.history.records.iter().zip(&b.history.records) {
        assert_eq!(ra.round, rb.round, "{what}");
        assert_eq!(ra.loss.to_bits(), rb.loss.to_bits(), "{what}: loss @r{}", ra.round);
        assert_eq!(
            ra.grad_norm_sq.to_bits(),
            rb.grad_norm_sq.to_bits(),
            "{what}: |grad|^2 @r{}",
            ra.round
        );
        assert_eq!(
            ra.bits_per_client.to_bits(),
            rb.bits_per_client.to_bits(),
            "{what}: bits @r{}",
            ra.round
        );
    }
    assert_eq!(a.history.downlink_bits, b.history.downlink_bits, "{what}: downlink bits");
    assert_eq!(a.final_x.len(), b.final_x.len(), "{what}: final_x len");
    for (i, (xa, xb)) in a.final_x.iter().zip(&b.final_x).enumerate() {
        assert_eq!(xa.to_bits(), xb.to_bits(), "{what}: final_x[{i}]");
    }
    // Identical protocol ⇒ identical wire accounting.
    assert_eq!(a.uplink_frame_bytes, b.uplink_frame_bytes, "{what}: uplink bytes");
    assert_eq!(a.downlink_frame_bytes, b.downlink_frame_bytes, "{what}: downlink bytes");
}

#[test]
fn reactor_matches_threads_bitwise_all_algos_local() {
    for algo in AlgoSpec::ALL {
        for comp in ["top2", "rand2"] {
            let threads = run_threads(algo, comp, TransportKind::Local);
            // Shard counts bracketing the fleet: 1 (pure event loop) and
            // more shards than workers (degenerate 1-conn shards).
            for shards in [1, 3, N_WORKERS + 2] {
                let reactor = run_reactor_engine(algo, comp, TransportKind::Local, shards);
                assert_bitwise_equal(
                    &threads,
                    &reactor,
                    &format!("{} {comp} shards={shards}", algo.name()),
                );
            }
        }
    }
}

#[test]
fn reactor_matches_threads_bitwise_over_tcp() {
    // One real-socket case: the nonblocking framing state machine under
    // genuine partial reads/writes.
    let threads = run_threads(AlgoSpec::Ef21, "top2", TransportKind::Tcp);
    let reactor = run_reactor_engine(AlgoSpec::Ef21, "top2", TransportKind::Tcp, 2);
    assert_bitwise_equal(&threads, &reactor, "ef21 top2 tcp");
}

/// Run `f` with telemetry enabled and a private registry layered onto
/// the facade (the `bench::with_round_stats` pattern), returning `f`'s
/// result plus per-worker round-latency sample counts and the rendered
/// straggler report from that registry.
fn with_worker_latency<T>(
    f: impl FnOnce() -> T,
) -> (T, BTreeMap<usize, u64>, Option<String>) {
    let reg = Arc::new(telemetry::Registry::new());
    telemetry::push_layer(Arc::new(telemetry::RegistryRecorder::new(reg.clone())));
    let was_enabled = telemetry::is_enabled();
    telemetry::enable();
    let out = f();
    if !was_enabled {
        telemetry::disable();
    }
    telemetry::pop_layer();
    let snap = reg.snapshot();
    let counts = snap
        .histograms
        .iter()
        .filter_map(|(key, h)| {
            let w: usize = key.strip_prefix(telemetry::keys::WORKER_ROUND_NS_PREFIX)?.parse().ok()?;
            Some((w, h.count))
        })
        .collect();
    (out, counts, snap.render_straggler_report(N_WORKERS))
}

/// Reactor-master parity for per-worker latency telemetry: the reactor's
/// `collect_round` must populate the same `coordinator.worker.round.ns.w<i>`
/// histograms the thread master does, and the straggler report must
/// render from either engine's samples. Counts are asserted as `>=`
/// rather than `==`: telemetry enablement is process-global, so sibling
/// tests running concurrently in this binary may add samples to the
/// layered registry (they all drive the same N_WORKERS, so the worker
/// index set stays exact).
#[test]
fn reactor_worker_latency_telemetry_matches_threads() {
    let (threads, t_counts, t_report) =
        with_worker_latency(|| run_threads(AlgoSpec::Ef21, "top2", TransportKind::Local));
    let (reactor, r_counts, r_report) =
        with_worker_latency(|| run_reactor_engine(AlgoSpec::Ef21, "top2", TransportKind::Local, 3));
    // Telemetry capture must not perturb the trajectory.
    assert_bitwise_equal(&threads, &reactor, "ef21 top2 telemetry-on");
    let all: Vec<usize> = (0..N_WORKERS).collect();
    for (label, counts) in [("threads", &t_counts), ("reactor", &r_counts)] {
        let workers: Vec<usize> = counts.keys().copied().collect();
        assert_eq!(workers, all, "{label}: per-worker histogram coverage");
        for (w, n) in counts {
            assert!(*n >= ROUNDS as u64, "{label}: w{w} has {n} samples, want >= {ROUNDS}");
        }
    }
    for (label, report) in [("threads", t_report), ("reactor", r_report)] {
        let text = report.unwrap_or_else(|| panic!("{label}: straggler report missing"));
        assert!(
            text.contains(&format!("top {N_WORKERS} of")),
            "{label}: report lists all workers:\n{text}"
        );
    }
}

/// The aggregation tree's integration-level contract: at every
/// (shards, fanout) split the fleet master's g/x trajectories equal the
/// flat worker-order fold bitwise.
#[test]
fn aggregation_tree_equals_flat_fold_bitwise_at_all_fanouts() {
    let base = FleetSpec {
        n_clients: 64,
        d: 257,
        k: 5,
        rounds: 3,
        fanout: 0,
        shards: 1,
        seed: 42,
        gamma: 0.3,
        track_mirrors: false,
        blackbox: None,
    };
    let mut g = vec![0.0; base.d];
    let mut x = vec![0.0; base.d];
    for t in 0..base.rounds {
        reference_round(&base, t, &mut g);
        linalg::axpy(-base.gamma, &g, &mut x);
    }
    let (want_g, want_x) = (dense_digest(&g), dense_digest(&x));
    for shards in [1usize, 2, 5, 9] {
        for fanout in [0usize, 2, 3, 16, 64] {
            let out = ef21::coordinator::fleet::run_fleet(&FleetSpec {
                shards,
                fanout,
                ..base.clone()
            })
            .expect("fleet run");
            assert_eq!(out.g_digest, want_g, "g: shards={shards} fanout={fanout}");
            assert_eq!(out.x_digest, want_x, "x: shards={shards} fanout={fanout}");
        }
    }
}

/// Crash→resync with sparse mirrors: feed real compressor outputs
/// (top-k deltas, rand-k deltas, DCGD whole-state assignments) through
/// the tracker, snapshot + restore mid-stream (the crash), and require
/// the reconstructed mirror to match a dense replay bit for bit.
#[test]
fn sparse_mirror_resync_matches_dense_replay_after_crash() {
    let d = 64;
    let topk = ef21::compress::TopK::new(3);
    let randk = ef21::compress::RandK::new(4);
    let mut rng = Rng::seed(99);
    let mut tracker = StateTracker::new(2, d);
    let mut dense = vec![vec![0.0f64; d]; 2];
    for step in 0..120 {
        for w in 0..2 {
            let v: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
            let payload = if w == 0 {
                topk.compress(&v, &mut rng)
            } else {
                randk.compress(&v, &mut rng)
            };
            let msg = if step % 17 == 5 {
                WireMsg::Tagged { dcgd_branch: true, payload }
            } else {
                WireMsg::Sparse(payload)
            };
            match &msg {
                WireMsg::Tagged { dcgd_branch: true, payload } => {
                    dense[w].iter_mut().for_each(|x| *x = 0.0);
                    payload.sparse.add_into(&mut dense[w]);
                }
                WireMsg::Sparse(c) | WireMsg::Tagged { dcgd_branch: false, payload: c } => {
                    c.sparse.add_into(&mut dense[w]);
                }
            }
            tracker.absorb_msg(w, &msg);
        }
        if step == 60 {
            // The crash: only the sparse image survives; the rebuilt
            // tracker must carry on bit-identically.
            let image = tracker.image();
            tracker = StateTracker::new(2, d);
            tracker.restore(&image).expect("restore");
        }
    }
    for w in 0..2 {
        let mirror = tracker.mirror_dense(w).to_vec();
        for (i, (m, e)) in mirror.iter().zip(&dense[w]).enumerate() {
            assert_eq!(m.to_bits(), e.to_bits(), "worker {w} coord {i}");
        }
    }
}

/// 2000 simulated clients complete a short run on one master within a
/// generous wall bound, with sparse mirrors far under the dense n×d
/// floor — the "one master, thousands of clients" smoke.
#[test]
fn two_thousand_client_fleet_smoke_is_bounded() {
    let spec = FleetSpec { rounds: 5, ..FleetSpec::quick(2000) };
    let t0 = std::time::Instant::now();
    let out = ef21::coordinator::fleet::run_fleet(&spec).expect("fleet run");
    let wall = t0.elapsed();
    assert!(wall.as_secs() < 60, "2000-client smoke took {wall:?}");
    assert_eq!(out.rounds, spec.rounds);
    assert_eq!(out.entries_folded, (spec.n_clients * spec.k * spec.rounds) as u64);
    assert!(out.g_digest != 0 && out.x_digest != 0);
    // Mirrors stay sparse: nowhere near the dense n×d×8 = 1.6 GB floor.
    let dense_floor = (spec.n_clients * spec.d * 8) as u64;
    assert!(
        out.mirror_bytes * 100 < dense_floor,
        "mirrors {} B vs dense floor {} B",
        out.mirror_bytes,
        dense_floor
    );
}
