//! Integration tests for the theory-grounded health monitor
//! (DESIGN.md §12): on a clean EF21 least-squares run at the Theorem-1
//! stepsize the Lyapunov function Φ^t = f(x^t) + (γ/θ)·G^t descends
//! every round and the anomaly detector stays silent; and with health
//! off (the default) or on, the trajectory is bit-identical — the
//! monitor is observation-only. The golden-trajectory fixtures run with
//! `CkptOptions::default()` (health = None), so they lock the health-off
//! path; the invisibility test here locks the health-on path against it.

use ef21::algo::{AlgoSpec, MasterNode as _, WireMsg, WorkerNode as _};
use ef21::blocks::BlockLayout;
use ef21::compress::Compressor;
use ef21::coordinator::runner::CkptOptions;
use ef21::exp::{Objective, Problem};
use ef21::health::{Health, HealthSpec};
use ef21::theory;
use std::sync::Arc;

const N_WORKERS: usize = 4;
const K: usize = 2;

/// Least-squares problem (PL, §A.2) — the objective the acceptance
/// criterion names.
fn lstsq_problem() -> Problem {
    let ds = ef21::data::synth::generate_custom("health", 240, 12, 0.4, 7);
    Problem::from_dataset(ds, Objective::Lstsq, N_WORKERS, 0.0)
}

/// Clean EF21 at the Theorem-1 stepsize: drive the protocol manually
/// (the same init/begin_round/round/absorb order as the runners), feed
/// each round's worker probes to [`Health::observe`], and assert the
/// paper's certificates hold — Φ^{t+1} ≤ Φ^t every round, the top-k
/// contraction ratio stays under (1−α), and zero anomalies fire. Φ is
/// recomputed here from the raw probes, independently of the monitor's
/// arithmetic, so the test checks the theory and the monitor against
/// each other.
#[test]
fn clean_ef21_lstsq_descends_lyapunov_with_zero_anomalies() {
    let p = lstsq_problem();
    let d = p.d();
    let c: Arc<dyn Compressor> = Arc::from(ef21::compress::from_spec(&format!("top{K}")).unwrap());
    let alpha = c.alpha(d);
    let gamma = theory::stepsize_theorem1(p.smoothness.l, p.smoothness.l_tilde, alpha);
    let (theta, _) = theory::theta_beta(alpha);

    let (mut master, mut workers) =
        ef21::algo::build(AlgoSpec::Ef21, vec![0.0; d], p.oracles(), c, gamma, 7);
    let x0 = master.x().to_vec();
    let init: Vec<WireMsg> = workers.iter_mut().map(|w| w.init(&x0)).collect();
    master.init_absorb(&init);

    let cfg = HealthSpec::parse("every:1").unwrap().build(alpha, gamma).unwrap();
    let mut health = Health::new(cfg, "health-test");

    let rounds = 60;
    let mut prev_phi = f64::INFINITY;
    let mut first_phi = f64::NAN;
    for t in 0..rounds {
        let x = master.begin_round();
        let msgs: Vec<WireMsg> = workers.iter_mut().map(|w| w.round(&x)).collect();
        master.absorb(&msgs);

        let loss = workers.iter().map(|w| w.last_loss()).sum::<f64>() / N_WORKERS as f64;
        let probes: Vec<(f64, f64)> = workers
            .iter()
            .map(|w| {
                (
                    w.distortion_sq().expect("EF21 exposes err_sq"),
                    w.contraction_ref_sq().expect("EF21 exposes ref_sq"),
                )
            })
            .collect();

        // Eq. 3, deterministic for top-k: ||C(v)−v||² ≤ (1−α)||v||².
        for (w, &(err, ref_sq)) in probes.iter().enumerate() {
            if ref_sq > 0.0 {
                assert!(
                    err / ref_sq <= (1.0 - alpha) + 1e-12,
                    "round {t} worker {w}: contraction ratio {} > 1−α = {}",
                    err / ref_sq,
                    1.0 - alpha
                );
            }
        }

        // Theorem 1's certificate, recomputed from the raw probes.
        let gt = probes.iter().map(|&(err, _)| err).sum::<f64>() / N_WORKERS as f64;
        let phi = loss + (gamma / theta) * gt;
        assert!(
            phi <= prev_phi + 1e-9 * prev_phi.abs().max(1.0),
            "round {t}: Φ rose from {prev_phi} to {phi}"
        );
        prev_phi = phi;
        if t == 0 {
            first_phi = phi;
        }

        let anomalies = health.observe(t, loss, &probes);
        assert!(anomalies.is_empty(), "round {t}: unexpected anomalies {anomalies:?}");
    }
    assert_eq!(health.records, rounds as u64);
    assert_eq!(health.anomaly_count, 0);
    // The run actually made progress — Φ descent was not vacuous.
    assert!(prev_phi < first_phi, "Φ never decreased: {first_phi} -> {prev_phi}");
}

/// Health is observation-only: the same trial run with the monitor off
/// (the default) and on (every round) produces bit-identical histories.
/// Together with the golden fixtures (which run health-off), this locks
/// both sides of the bit-identity contract.
#[test]
fn health_monitor_is_trajectory_invisible() {
    let p = lstsq_problem();
    let layout = Arc::new(BlockLayout::flat(p.d()));
    let run = |opts: CkptOptions| {
        p.run_trial_ckpt(AlgoSpec::Ef21, "top2", 1.0, None, 30, 1, 7, 1, layout.clone(), opts)
            .expect("trial")
    };

    let off_opts = CkptOptions::default();
    assert!(off_opts.health.is_none(), "health must default to off");
    let off = run(off_opts);

    let alpha = K as f64 / p.d() as f64;
    let health = HealthSpec::parse("every:1").unwrap().build(alpha, p.theory_gamma(alpha));
    let on = run(CkptOptions::default().with_health(health));

    assert_eq!(off.records.len(), on.records.len());
    for (a, b) in off.records.iter().zip(&on.records) {
        assert_eq!(a.round, b.round);
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "loss @r{}", a.round);
        assert_eq!(
            a.grad_norm_sq.to_bits(),
            b.grad_norm_sq.to_bits(),
            "|grad|^2 @r{}",
            a.round
        );
        assert_eq!(a.gt.to_bits(), b.gt.to_bits(), "G^t @r{}", a.round);
        assert_eq!(a.bits_per_client.to_bits(), b.bits_per_client.to_bits(), "bits @r{}", a.round);
    }
    assert_eq!(off.downlink_bits, on.downlink_bits);
    assert_eq!(off.final_x.len(), on.final_x.len());
    for (i, (xa, xb)) in off.final_x.iter().zip(&on.final_x).enumerate() {
        assert_eq!(xa.to_bits(), xb.to_bits(), "final_x[{i}]");
    }
}
