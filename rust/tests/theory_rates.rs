//! Table-2 rate verification as enforced tests (the `exp rates` driver
//! prints the same quantities): Theorem 1's O(1/T) bound and Theorem 2's
//! linear rate, checked along instrumented EF21 runs.

use ef21::exp::rates::{check_theorem1, check_theorem2};

#[test]
fn theorem1_o_one_over_t_bound_holds() {
    for seed in [0u64, 1, 2] {
        let r = check_theorem1(600, seed);
        assert!(
            r.holds,
            "seed {seed}: measured {:.4e} > predicted {:.4e}",
            r.measured, r.predicted
        );
    }
}

#[test]
fn theorem2_linear_rate_holds() {
    for seed in [0u64, 1, 2] {
        let r = check_theorem2(800, seed);
        assert!(
            r.holds,
            "seed {seed}: measured {:.4e} > predicted {:.4e}",
            r.measured, r.predicted
        );
    }
}

/// The O(1/T) character: doubling T roughly halves the running-mean squared
/// gradient norm bound's RHS, and the measured quantity keeps up (ratio
/// test on the measured values at T and 2T — sublinear decay at least).
#[test]
fn measured_mean_grad_decays_with_t() {
    let r1 = check_theorem1(300, 7);
    let r2 = check_theorem1(1200, 7);
    assert!(
        r2.measured < r1.measured * 0.6,
        "mean |grad|^2 did not decay with T: {:.3e} -> {:.3e}",
        r1.measured,
        r2.measured
    );
}
