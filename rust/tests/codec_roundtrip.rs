//! Codec robustness suite: randomized roundtrip properties for every
//! `Frame` kind (including the block-delta and block-tagged uplink
//! frames) plus adversarial truncation/garbage inputs asserting the
//! `Reader::take` error paths always surface as `Err` — never a panic,
//! never an abort-sized allocation.

use ef21::algo::WireMsg;
use ef21::compress::{Compressed, SparseVec};
use ef21::transport::codec::{decode, encode, BlockPatch, Frame};
use ef21::util::rng::Rng;
use ef21::util::testing::for_all_seeds;

/// Random sorted-unique index set of size `k` over `0..d`.
fn random_idx(rng: &mut Rng, d: usize, k: usize) -> Vec<u32> {
    rng.sample_indices(d, k.min(d))
}

fn random_sparse(rng: &mut Rng, d: usize) -> SparseVec {
    let k = rng.next_below(d.max(1)) + 1;
    let idx = random_idx(rng, d, k);
    let val: Vec<f64> = idx.iter().map(|_| rng.next_normal()).collect();
    SparseVec::new(idx, val)
}

fn random_msg(rng: &mut Rng, d: usize) -> WireMsg {
    let sparse = random_sparse(rng, d);
    let bits = sparse.standard_bits();
    let payload = Compressed { sparse, bits };
    match rng.next_below(3) {
        0 => WireMsg::Sparse(payload),
        1 => WireMsg::Tagged { dcgd_branch: false, payload },
        _ => WireMsg::Tagged { dcgd_branch: true, payload },
    }
}

/// f32-clean random value (encode quantizes values to f32; using values
/// that round-trip exactly keeps the equality assertions strict).
fn f32_clean(rng: &mut Rng) -> f64 {
    (rng.next_normal() as f32) as f64
}

fn assert_msg_eq(a: &WireMsg, b: &WireMsg) {
    match (a, b) {
        (WireMsg::Sparse(x), WireMsg::Sparse(y)) => {
            assert_eq!(x.bits, y.bits);
            assert_eq!(x.sparse.idx, y.sparse.idx);
        }
        (
            WireMsg::Tagged { dcgd_branch: ba, payload: x },
            WireMsg::Tagged { dcgd_branch: bb, payload: y },
        ) => {
            assert_eq!(ba, bb);
            assert_eq!(x.bits, y.bits);
            assert_eq!(x.sparse.idx, y.sparse.idx);
        }
        _ => panic!("message kind changed in roundtrip"),
    }
}

#[test]
fn roundtrip_property_all_frame_kinds() {
    for_all_seeds(60, |rng| {
        let d = 2 + rng.next_below(200);

        // Model
        let x: Vec<f64> = (0..d).map(|_| f32_clean(rng)).collect();
        match decode(&encode(&Frame::Model(x.clone()))).unwrap() {
            Frame::Model(y) => assert_eq!(x, y),
            _ => panic!("Model roundtrip changed kind"),
        }

        // Up: the optional health probe rides the kind byte's high bit
        // and must round-trip bit for bit (f64, no f32 quantization).
        let msg = random_msg(rng, d);
        let loss = rng.next_normal();
        let health = if rng.next_below(2) == 0 { Some(rng.next_normal()) } else { None };
        match decode(&encode(&Frame::Up { msg: msg.clone(), loss, health })).unwrap() {
            Frame::Up { msg: m2, loss: l2, health: h2 } => {
                assert_eq!(loss.to_bits(), l2.to_bits());
                assert_eq!(health.map(f64::to_bits), h2.map(f64::to_bits));
                assert_msg_eq(&msg, &m2);
            }
            _ => panic!("Up roundtrip changed kind"),
        }

        // UpBlock
        let n_blocks = 1 + rng.next_below(6) as u32;
        let block = rng.next_below(n_blocks as usize) as u32;
        let msg = random_msg(rng, d);
        let f = Frame::UpBlock { block, n_blocks, msg: msg.clone(), loss };
        match decode(&encode(&f)).unwrap() {
            Frame::UpBlock { block: b2, n_blocks: n2, msg: m2, .. } => {
                assert_eq!((block, n_blocks), (b2, n2));
                assert_msg_eq(&msg, &m2);
            }
            _ => panic!("UpBlock roundtrip changed kind"),
        }

        // ModelDelta: non-overlapping ascending patches.
        let mut patches = Vec::new();
        let mut offset = 0usize;
        while offset + 1 < d && patches.len() < 5 {
            let len = 1 + rng.next_below((d - offset).min(20));
            patches.push(BlockPatch {
                offset: offset as u32,
                vals: (0..len).map(|_| f32_clean(rng)).collect(),
            });
            offset += len + rng.next_below(10);
        }
        match decode(&encode(&Frame::ModelDelta(patches.clone()))).unwrap() {
            Frame::ModelDelta(p2) => assert_eq!(patches, p2),
            _ => panic!("ModelDelta roundtrip changed kind"),
        }

        // StateSync: f64-exact on the wire (no f32 quantization — raw
        // normals must round-trip bit for bit).
        let g: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
        match decode(&encode(&Frame::StateSync(g.clone()))).unwrap() {
            Frame::StateSync(g2) => {
                assert_eq!(g.len(), g2.len());
                for (a, b) in g.iter().zip(&g2) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            _ => panic!("StateSync roundtrip changed kind"),
        }

        // Stop
        assert!(matches!(decode(&encode(&Frame::Stop)).unwrap(), Frame::Stop));
    });
}

/// Every strict prefix of a valid frame must decode to a clean error
/// (frames carry explicit lengths, so truncation always under-runs some
/// `Reader::take`, or trips the trailing-bytes check).
#[test]
fn truncation_never_panics() {
    for_all_seeds(20, |rng| {
        let d = 2 + rng.next_below(60);
        let frames = vec![
            Frame::Model((0..d).map(|_| rng.next_normal()).collect()),
            Frame::Up { msg: random_msg(rng, d), loss: 0.5, health: Some(0.25) },
            Frame::UpBlock { block: 0, n_blocks: 3, msg: random_msg(rng, d), loss: 0.0 },
            Frame::ModelDelta(vec![BlockPatch {
                offset: 1,
                vals: vec![1.0, 2.0, 3.0],
            }]),
            Frame::StateSync((0..d).map(|_| rng.next_normal()).collect()),
            Frame::Stop,
        ];
        for f in &frames {
            let bytes = encode(f);
            for l in 0..bytes.len() {
                assert!(
                    decode(&bytes[..l]).is_err(),
                    "prefix of length {l}/{} decoded successfully",
                    bytes.len()
                );
            }
            // Appending junk must also fail (trailing-bytes check).
            let mut longer = bytes.clone();
            longer.push(0xAB);
            assert!(decode(&longer).is_err());
        }
    });
}

/// Random garbage must produce `Err` or a valid frame — never a panic.
#[test]
fn garbage_bytes_never_panic() {
    for_all_seeds(40, |rng| {
        let len = rng.next_below(300);
        let bytes: Vec<u8> = (0..len).map(|_| (rng.next_u64() & 0xFF) as u8).collect();
        let _ = decode(&bytes); // must return, not panic
    });
}

/// Headers that promise enormous element counts must error out without
/// allocating anywhere near the promised size.
#[test]
fn lying_length_headers_error_cleanly() {
    // Model claiming u32::MAX coordinates, 1 actual byte of payload.
    let mut bytes = vec![0x01];
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    bytes.push(0);
    assert!(decode(&bytes).is_err());

    // Up frame claiming 2^31 entries.
    let mut bytes = vec![0x02, 0x00];
    bytes.extend_from_slice(&0.0f64.to_le_bytes());
    bytes.extend_from_slice(&64u64.to_le_bytes());
    bytes.extend_from_slice(&(1u32 << 31).to_le_bytes());
    assert!(decode(&bytes).is_err());

    // ModelDelta claiming a huge patch.
    let mut bytes = vec![0x04];
    bytes.extend_from_slice(&1u32.to_le_bytes());
    bytes.extend_from_slice(&0u32.to_le_bytes()); // offset
    bytes.extend_from_slice(&u32::MAX.to_le_bytes()); // len
    assert!(decode(&bytes).is_err());

    // StateSync claiming u32::MAX f64s with an empty payload.
    let mut bytes = vec![0x06];
    bytes.extend_from_slice(&u32::MAX.to_le_bytes());
    assert!(decode(&bytes).is_err());
}

/// Malformed uplink payloads (unsorted / duplicate indices) are rejected
/// at decode time rather than corrupting master state later.
#[test]
fn unsorted_uplink_indices_rejected() {
    // Hand-assemble an Up frame with decreasing indices.
    let mut bytes = vec![0x02, 0x00]; // tag, kind = Sparse
    bytes.extend_from_slice(&0.0f64.to_le_bytes()); // loss
    bytes.extend_from_slice(&128u64.to_le_bytes()); // bits
    bytes.extend_from_slice(&2u32.to_le_bytes()); // nnz
    bytes.extend_from_slice(&7u32.to_le_bytes()); // idx 7
    bytes.extend_from_slice(&3u32.to_le_bytes()); // idx 3 (out of order)
    bytes.extend_from_slice(&1.0f32.to_le_bytes());
    bytes.extend_from_slice(&2.0f32.to_le_bytes());
    assert!(decode(&bytes).is_err());
}
