//! Golden-trajectory regression fixtures: the first 20 `RoundRecord`s of
//! one canonical run per algorithm, serialized via `util::json` and
//! pinned under `tests/golden/`. Any drift in oracle math, compressor
//! selection, RNG forking, metering, or the runner's reduction order
//! fails this suite with a field-level diff.
//!
//! Fixture lifecycle:
//!   * fixture present → strict bit-exact comparison (f64s round-trip
//!     through the JSON shortest-representation printer losslessly;
//!     NaN is encoded as `null`);
//!   * fixture missing → it is **bootstrapped** (written, test passes
//!     with a loud commit reminder), UNLESS `EF21_GOLDEN_STRICT=1`, in
//!     which case missing fixtures are a hard failure. The authoring
//!     environment of this repo has no Rust toolchain, so the first
//!     `cargo test` materializes the fixtures; commit them — only
//!     committed fixtures give cross-commit drift protection. CI runs
//!     the suite twice (bootstrap pass, then strict pass), which at
//!     minimum proves intra-checkout run-to-run stability;
//!   * `EF21_UPDATE_GOLDEN=1 cargo test` → regenerate after an
//!     intentional trajectory change; commit the diff.

use ef21::algo::AlgoSpec;
use ef21::exp::{Objective, Problem};
use ef21::metrics::{History, RoundRecord};
use ef21::util::json::Json;
use std::collections::BTreeMap;
use std::path::PathBuf;

const GOLDEN_ROUNDS: usize = 20;

fn golden_dir() -> PathBuf {
    PathBuf::from(concat!(env!("CARGO_MANIFEST_DIR"), "/tests/golden"))
}

/// The canonical run: fixed synthetic dataset, 4 workers, Top-2, the 1x
/// theory stepsize, seed 7. Deliberately small so the suite stays fast;
/// deliberately Top-k so every algorithm (EF21+ included) is covered.
fn canonical_history(algo: AlgoSpec) -> History {
    let ds = ef21::data::synth::generate_custom("golden", 300, 10, 0.4, 42);
    let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
    p.run_trial(algo, "top2", 1.0, None, GOLDEN_ROUNDS, 1, 7)
}

/// JSON has no NaN/inf tokens: NaN → `null`, infinities → signed string
/// markers, so a divergence inside the golden window still produces a
/// parseable, pinnable fixture.
fn num_or_null(x: f64) -> Json {
    if x.is_nan() {
        Json::Null
    } else if x == f64::INFINITY {
        Json::Str("inf".into())
    } else if x == f64::NEG_INFINITY {
        Json::Str("-inf".into())
    } else {
        Json::Num(x)
    }
}

fn record_to_json(r: &RoundRecord) -> Json {
    let mut m = BTreeMap::new();
    m.insert("round".into(), Json::Num(r.round as f64));
    m.insert("bits_per_client".into(), num_or_null(r.bits_per_client));
    m.insert("loss".into(), num_or_null(r.loss));
    m.insert("grad_norm_sq".into(), num_or_null(r.grad_norm_sq));
    m.insert("gt".into(), num_or_null(r.gt));
    m.insert("dcgd_frac".into(), num_or_null(r.dcgd_frac));
    Json::Obj(m)
}

fn history_to_json(h: &History) -> Json {
    Json::Arr(h.records.iter().take(GOLDEN_ROUNDS).map(record_to_json).collect())
}

fn field(rec: &Json, key: &str, algo: &str, round: usize) -> f64 {
    match rec.get(key) {
        Some(Json::Null) => f64::NAN,
        Some(Json::Str(s)) if s == "inf" => f64::INFINITY,
        Some(Json::Str(s)) if s == "-inf" => f64::NEG_INFINITY,
        Some(j) => j.as_f64().unwrap_or_else(|| panic!("{algo} r{round}: bad {key}")),
        None => panic!("{algo} golden r{round}: missing field {key}"),
    }
}

#[track_caller]
fn compare(algo: &str, fixture: &Json, fresh: &History) {
    let arr = fixture.as_arr().unwrap_or_else(|| panic!("{algo} golden: not an array"));
    assert_eq!(
        arr.len(),
        fresh.records.len().min(GOLDEN_ROUNDS),
        "{algo}: golden record count drifted (EF21_UPDATE_GOLDEN=1 to regen)"
    );
    for (i, (want, got)) in arr.iter().zip(&fresh.records).enumerate() {
        for (key, val) in [
            ("round", got.round as f64),
            ("bits_per_client", got.bits_per_client),
            ("loss", got.loss),
            ("grad_norm_sq", got.grad_norm_sq),
            ("gt", got.gt),
            ("dcgd_frac", got.dcgd_frac),
        ] {
            let expect = field(want, key, algo, i);
            assert_eq!(
                expect.to_bits(),
                val.to_bits(),
                "{algo} round {i}: {key} drifted from golden ({expect:?} -> {val:?}); \
                 rerun with EF21_UPDATE_GOLDEN=1 if the change is intentional"
            );
        }
    }
}

fn check_algo(algo: AlgoSpec) {
    let h = canonical_history(algo);
    // A divergence abort inside the window would also be deterministic
    // and pinned; today every canonical run completes all 20 rounds.
    assert!(!h.records.is_empty(), "{}: canonical run recorded nothing", algo.name());
    let path = golden_dir().join(format!(
        "trajectory_{}.json",
        algo.name().to_ascii_lowercase().replace('+', "plus")
    ));
    let regen = std::env::var("EF21_UPDATE_GOLDEN").map(|v| v == "1").unwrap_or(false);
    if regen || !path.exists() {
        // Strict mode (CI's second pass): a missing fixture is a
        // failure, not a bootstrap — bootstrapping there would compare
        // freshly-broken code against its own output and hide drift.
        let strict = std::env::var("EF21_GOLDEN_STRICT").map(|v| v == "1").unwrap_or(false);
        if strict && !regen {
            panic!(
                "{}: golden fixture {} missing under EF21_GOLDEN_STRICT=1 — \
                 generate it (cargo test) and COMMIT it; until fixtures are \
                 committed the suite only proves intra-checkout stability",
                algo.name(),
                path.display()
            );
        }
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, history_to_json(&h).to_string()).unwrap();
        eprintln!(
            "golden: {} fixture for {} at {} — COMMIT this file so drift is \
             caught across commits, not just within one checkout",
            if regen { "regenerated" } else { "bootstrapped" },
            algo.name(),
            path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let fixture = Json::parse(&text)
        .unwrap_or_else(|e| panic!("{}: unparsable golden fixture: {e}", algo.name()));
    compare(algo.name(), &fixture, &h);
}

#[test]
fn golden_ef21() {
    check_algo(AlgoSpec::Ef21);
}

#[test]
fn golden_ef21plus() {
    check_algo(AlgoSpec::Ef21Plus);
}

#[test]
fn golden_ef() {
    check_algo(AlgoSpec::Ef);
}

#[test]
fn golden_dcgd() {
    check_algo(AlgoSpec::Dcgd);
}

#[test]
fn golden_gd() {
    check_algo(AlgoSpec::Gd);
}

/// The golden trajectory itself is engine-independent: the parallel
/// runner reproduces the exact fixture trajectory too (ties the golden
/// suite to the differential suite).
#[test]
fn golden_trajectory_is_engine_independent() {
    let ds = ef21::data::synth::generate_custom("golden", 300, 10, 0.4, 42);
    let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
    let h_seq = p.run_trial(AlgoSpec::Ef21, "top2", 1.0, None, GOLDEN_ROUNDS, 1, 7);
    let h_par =
        p.run_trial_threads(AlgoSpec::Ef21, "top2", 1.0, None, GOLDEN_ROUNDS, 1, 7, 4);
    for (a, b) in h_seq.records.iter().zip(&h_par.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.grad_norm_sq.to_bits(), b.grad_norm_sq.to_bits());
        assert_eq!(a.gt.to_bits(), b.gt.to_bits());
    }
}

/// `--blocks 1` is not a new trajectory: the single-block layout must
/// reproduce the canonical flat run bit for bit, so the existing golden
/// fixtures also lock the blocked pipeline's degenerate case. The flat
/// reference is assembled by hand (`from_spec` + `algo::build` +
/// `run_protocol`, no block API anywhere) so the comparison cannot
/// collapse into one code path testing itself.
#[test]
fn golden_blocks1_matches_canonical_flat_run() {
    use ef21::compress::Compressor;
    use std::sync::Arc;
    let ds = ef21::data::synth::generate_custom("golden", 300, 10, 0.4, 42);
    let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
    // The canonical run's exact parameters, without blocked plumbing.
    let c: Arc<dyn Compressor> = Arc::from(ef21::compress::from_spec("top2").unwrap());
    let gamma = p.theory_gamma(c.alpha(p.d()));
    let (m, w) = ef21::algo::build(AlgoSpec::Ef21, vec![0.0; p.d()], p.oracles(), c, gamma, 7);
    let mut cfg = ef21::coordinator::runner::RunConfig::rounds(GOLDEN_ROUNDS);
    cfg.divergence_cap = 1e60;
    let flat = ef21::coordinator::runner::run_protocol(m, w, &cfg);

    let layout = Arc::new(ef21::blocks::BlockLayout::flat(p.d()));
    let blocked =
        p.run_trial_blocked(AlgoSpec::Ef21, "top2", 1.0, None, GOLDEN_ROUNDS, 1, 7, 1, layout);
    assert_eq!(flat.records.len(), blocked.records.len());
    for (a, b) in flat.records.iter().zip(&blocked.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.grad_norm_sq.to_bits(), b.grad_norm_sq.to_bits());
        assert_eq!(a.bits_per_client.to_bits(), b.bits_per_client.to_bits());
        assert_eq!(a.gt.to_bits(), b.gt.to_bits());
    }
}

/// Partial participation gets its own pinned fixture (same lifecycle:
/// bootstrap on first run, strict under EF21_GOLDEN_STRICT=1, regen via
/// EF21_UPDATE_GOLDEN=1): the canonical problem under seeded
/// Bernoulli-0.5 participation. Locks the whole scheduled pipeline —
/// per-round mask sampling, the subset round path, absent-message
/// aggregation, and the PP uplink accounting.
#[test]
fn golden_ef21_pp() {
    let ds = ef21::data::synth::generate_custom("golden", 300, 10, 0.4, 42);
    let mut p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
    p.sched = ef21::config::SchedSpec {
        participation: ef21::sched::Participation::Bernoulli(0.5),
        ..ef21::config::SchedSpec::default()
    };
    let h = p.run_trial(AlgoSpec::Ef21, "top2", 1.0, None, GOLDEN_ROUNDS, 1, 7);
    assert!(!h.records.is_empty(), "EF21-PP: canonical run recorded nothing");
    // The schedule really dropped uplinks: strictly fewer bits than the
    // full-participation canonical run.
    let full = canonical_history(AlgoSpec::Ef21);
    assert!(
        h.records.last().unwrap().bits_per_client
            < full.records.last().unwrap().bits_per_client,
        "PP run must spend fewer uplink bits than full participation"
    );
    let path = golden_dir().join("trajectory_ef21_pp05.json");
    let regen = std::env::var("EF21_UPDATE_GOLDEN").map(|v| v == "1").unwrap_or(false);
    if regen || !path.exists() {
        let strict = std::env::var("EF21_GOLDEN_STRICT").map(|v| v == "1").unwrap_or(false);
        if strict && !regen {
            panic!(
                "EF21-PP: golden fixture {} missing under EF21_GOLDEN_STRICT=1 — \
                 generate it (cargo test) and COMMIT it",
                path.display()
            );
        }
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, history_to_json(&h).to_string()).unwrap();
        eprintln!(
            "golden: {} EF21-PP fixture at {} — COMMIT this file",
            if regen { "regenerated" } else { "bootstrapped" },
            path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let fixture = Json::parse(&text)
        .unwrap_or_else(|e| panic!("EF21-PP: unparsable golden fixture: {e}"));
    compare("EF21-PP", &fixture, &h);
}

/// The scheduled code path with a noop scheduler must reproduce the
/// canonical golden trajectory exactly — `--participation full` can
/// never move a fixture.
#[test]
fn golden_full_participation_through_scheduler_matches_canonical() {
    let h_legacy = canonical_history(AlgoSpec::Ef21);
    let ds = ef21::data::synth::generate_custom("golden", 300, 10, 0.4, 42);
    let mut p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
    // `full` with no faults resolves to the legacy path by construction;
    // force the scheduler machinery instead via the low-level runner.
    assert!(p.sched.build(4, 7).unwrap().is_none(), "full must resolve to legacy");
    p.sched = ef21::config::SchedSpec::default();
    let c: std::sync::Arc<dyn ef21::compress::Compressor> =
        std::sync::Arc::from(ef21::compress::from_spec("top2").unwrap());
    use ef21::compress::Compressor as _;
    let gamma = p.theory_gamma(c.alpha(p.d()));
    let (m, w) = ef21::algo::build(AlgoSpec::Ef21, vec![0.0; p.d()], p.oracles(), c, gamma, 7);
    let mut cfg = ef21::coordinator::runner::RunConfig::rounds(GOLDEN_ROUNDS)
        .with_sched(std::sync::Arc::new(ef21::sched::Scheduler::noop(4)));
    cfg.divergence_cap = 1e60;
    let h_sched = ef21::coordinator::runner::run_protocol(m, w, &cfg);
    assert_eq!(h_legacy.records.len(), h_sched.records.len());
    for (a, b) in h_legacy.records.iter().zip(&h_sched.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits());
        assert_eq!(a.grad_norm_sq.to_bits(), b.grad_norm_sq.to_bits());
        assert_eq!(a.bits_per_client.to_bits(), b.bits_per_client.to_bits());
        assert_eq!(a.gt.to_bits(), b.gt.to_bits());
    }
}

/// The blocked configuration gets its own pinned fixture (same
/// lifecycle: bootstrap on first run, strict under EF21_GOLDEN_STRICT=1,
/// regen via EF21_UPDATE_GOLDEN=1): the canonical problem under a
/// 5-block equal partition with layer-wise Top-k — per-block budgets,
/// per-block state, blocked absorb, and delta downlink accounting all
/// sit under this trajectory.
#[test]
fn golden_ef21_blocked() {
    use std::sync::Arc;
    let ds = ef21::data::synth::generate_custom("golden", 300, 10, 0.4, 42);
    let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
    let layout = Arc::new(ef21::blocks::BlockLayout::equal(5, p.d()).unwrap());
    let h = p.run_trial_blocked(
        AlgoSpec::Ef21,
        "top2",
        1.0,
        None,
        GOLDEN_ROUNDS,
        1,
        7,
        1,
        layout,
    );
    assert!(!h.records.is_empty(), "EF21-blocked: canonical run recorded nothing");
    assert!(h.downlink_bits > 0, "blocked run must meter the downlink");
    let path = golden_dir().join("trajectory_ef21_blocked5.json");
    let regen = std::env::var("EF21_UPDATE_GOLDEN").map(|v| v == "1").unwrap_or(false);
    if regen || !path.exists() {
        let strict = std::env::var("EF21_GOLDEN_STRICT").map(|v| v == "1").unwrap_or(false);
        if strict && !regen {
            panic!(
                "EF21-blocked: golden fixture {} missing under EF21_GOLDEN_STRICT=1 — \
                 generate it (cargo test) and COMMIT it",
                path.display()
            );
        }
        std::fs::create_dir_all(golden_dir()).unwrap();
        std::fs::write(&path, history_to_json(&h).to_string()).unwrap();
        eprintln!(
            "golden: {} blocked-EF21 fixture at {} — COMMIT this file",
            if regen { "regenerated" } else { "bootstrapped" },
            path.display()
        );
        return;
    }
    let text = std::fs::read_to_string(&path).unwrap();
    let fixture = Json::parse(&text)
        .unwrap_or_else(|e| panic!("EF21-blocked: unparsable golden fixture: {e}"));
    compare("EF21-blocked", &fixture, &h);
}
