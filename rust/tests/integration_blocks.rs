//! Block-partitioned pipeline integration: Eq. (3) contraction per block
//! and for the composite operator, exact bit accounting, `blocks = 1`
//! bit-identity with the legacy flat path, worker×block tile
//! determinism, and downlink delta-broadcast accounting (simulated and
//! over a real transport).

use ef21::algo::{AlgoSpec, BuildOpts};
use ef21::blocks::BlockLayout;
use ef21::compress::{BlockCompressor, Compressor, TopK};
use ef21::coordinator::dist::{run_distributed_opts, Broadcast, TransportKind};
use ef21::coordinator::runner::{run_protocol, RunConfig};
use ef21::exp::{Objective, Problem};
use ef21::metrics::History;
use ef21::oracle::{GradOracle, QuadraticOracle};
use ef21::util::rng::Rng;
use ef21::util::testing::{for_all_seeds, random_vec};
use std::sync::Arc;

fn tiny_problem() -> Problem {
    let ds = ef21::data::synth::generate_custom("blk", 400, 12, 0.4, 5);
    Problem::from_dataset(ds, Objective::LogReg, 4, 0.1)
}

fn assert_histories_bit_identical(a: &History, b: &History) {
    assert_eq!(a.records.len(), b.records.len());
    for (x, y) in a.records.iter().zip(&b.records) {
        assert_eq!(x.round, y.round);
        assert_eq!(x.loss.to_bits(), y.loss.to_bits(), "loss @ round {}", x.round);
        assert_eq!(x.grad_norm_sq.to_bits(), y.grad_norm_sq.to_bits());
        assert_eq!(x.bits_per_client.to_bits(), y.bits_per_client.to_bits());
        assert_eq!(x.gt.to_bits(), y.gt.to_bits());
    }
}

/// Eq. (3) holds per block with each block's own alpha_b, and for the
/// composite operator with alpha = min_b alpha_b.
#[test]
fn contraction_per_block_and_composite() {
    for_all_seeds(25, |rng| {
        let n_blocks = 2 + rng.next_below(4);
        let d = n_blocks * (2 + rng.next_below(10));
        let k = 1 + rng.next_below(d);
        let layout = Arc::new(BlockLayout::equal(n_blocks, d).unwrap());
        let c = BlockCompressor::from_spec(&format!("top{k}"), layout.clone(), 1).unwrap();
        let v = random_vec(rng, d, 3.0);
        let out = c.compress(&v, rng).sparse.to_dense(d);
        let alphas = c.block_alphas();
        let mut total_dist = 0.0;
        let mut total_norm = 0.0;
        for (b, spec) in layout.specs().iter().enumerate() {
            let vb = &v[spec.range()];
            let ob = &out[spec.range()];
            let dist: f64 = ob.iter().zip(vb).map(|(a, x)| (a - x) * (a - x)).sum();
            let norm: f64 = vb.iter().map(|x| x * x).sum();
            assert!(
                dist <= (1.0 - alphas[b]) * norm + 1e-9,
                "block {b}: dist {dist} > (1 - {}) * {norm}",
                alphas[b]
            );
            total_dist += dist;
            total_norm += norm;
        }
        let alpha = c.alpha(d);
        assert!(
            total_dist <= (1.0 - alpha) * total_norm + 1e-9,
            "composite Eq.(3) violated: {total_dist} vs (1 - {alpha}) * {total_norm}"
        );
    });
}

/// Composite wire cost is exactly the sum of the per-block inner costs.
#[test]
fn bit_accounting_is_sum_of_per_block_costs() {
    for_all_seeds(20, |rng| {
        let n_blocks = 1 + rng.next_below(5);
        let d = n_blocks * (1 + rng.next_below(12));
        let k = 1 + rng.next_below(d);
        let layout = Arc::new(BlockLayout::equal(n_blocks, d).unwrap());
        let blocked = BlockCompressor::from_spec(&format!("top{k}"), layout.clone(), 1).unwrap();
        let budgets = ef21::compress::split_budget(k, &layout);
        let v = random_vec(rng, d, 1.0);
        let out = blocked.compress(&v, rng);
        let mut want = 0u64;
        for (b, spec) in layout.specs().iter().enumerate() {
            want += TopK::new(budgets[b]).compress(&v[spec.range()], rng).bits;
        }
        assert_eq!(out.bits, want);
    });
}

/// `blocks = 1` is the exact legacy path. The reference side is built by
/// hand — `compress::from_spec` + `algo::build` + `run_protocol`, no
/// blocked plumbing anywhere — so this cannot degenerate into comparing
/// `run_trial_blocked(flat)` against itself.
#[test]
fn blocks1_run_is_bit_identical_to_flat_run() {
    let p = tiny_problem();
    for algo in [AlgoSpec::Ef21, AlgoSpec::Ef21Plus, AlgoSpec::Ef, AlgoSpec::Dcgd] {
        // Legacy reference, assembled without touching any block API.
        let c: Arc<dyn Compressor> = Arc::from(ef21::compress::from_spec("top3").unwrap());
        let gamma = p.theory_gamma(c.alpha(p.d()));
        let (m, w) = ef21::algo::build(algo, vec![0.0; p.d()], p.oracles(), c, gamma, 3);
        let mut cfg = RunConfig::rounds(50).with_record_every(5);
        cfg.divergence_cap = 1e60;
        let legacy = run_protocol(m, w, &cfg);

        let flat_layout = Arc::new(BlockLayout::flat(p.d()));
        let blocked = p.run_trial_blocked(algo, "top3", 1.0, None, 50, 5, 3, 1, flat_layout);
        assert_histories_bit_identical(&legacy, &blocked);
    }
}

/// An explicitly single-block `BlockCompressor` (not the flat shortcut)
/// also reproduces the legacy trajectory bit for bit — the degenerate
/// case really is the same operator, not just the same plumbing.
#[test]
fn explicit_single_block_compressor_matches_plain_topk() {
    let p = tiny_problem();
    let gamma = p.theory_gamma(3.0 / p.d() as f64);
    let build_with_comp = |c: Arc<dyn Compressor>| {
        ef21::algo::build(AlgoSpec::Ef21, vec![0.0; p.d()], p.oracles(), c, gamma, 7)
    };
    let (m1, w1) = build_with_comp(Arc::new(TopK::new(3)));
    let h1 = run_protocol(m1, w1, &RunConfig::rounds(40));
    let layout = Arc::new(BlockLayout::flat(p.d()));
    let blocked = BlockCompressor::from_spec("top3", layout, 1).unwrap();
    let (m2, w2) = build_with_comp(Arc::new(blocked));
    let h2 = run_protocol(m2, w2, &RunConfig::rounds(40));
    assert_histories_bit_identical(&h1, &h2);
}

/// Worker × block tiles are deterministic: a blocked run is bit-identical
/// at every absorb/compress fan-out width.
#[test]
fn blocked_run_is_bit_identical_at_any_thread_width() {
    let p = tiny_problem();
    let layout = Arc::new(BlockLayout::equal(6, p.d()).unwrap());
    let base = p.run_trial_blocked(
        AlgoSpec::Ef21,
        "top6",
        1.0,
        None,
        60,
        4,
        1,
        1,
        layout.clone(),
    );
    assert!(base.downlink_bits > 0);
    for threads in [2usize, 4, 8] {
        let h = p.run_trial_blocked(
            AlgoSpec::Ef21,
            "top6",
            1.0,
            None,
            60,
            4,
            1,
            threads,
            layout.clone(),
        );
        assert_histories_bit_identical(&base, &h);
        assert_eq!(base.downlink_bits, h.downlink_bits);
    }
}

/// Blocked uplink accounting: with per-block Top-k budgets the per-round
/// uplink is exactly `sum_b k_b` standard entries per worker.
#[test]
fn blocked_uplink_bits_match_budget_sum() {
    let p = tiny_problem();
    let layout = Arc::new(BlockLayout::equal(4, p.d()).unwrap());
    let budgets = ef21::compress::split_budget(6, &layout);
    let k_eff: usize = budgets.iter().sum();
    let h = p.run_trial_blocked(AlgoSpec::Ef21, "top6", 1.0, None, 10, 1, 0, 1, layout);
    // init + round 0 => 2 messages of k_eff entries (idx+val = 64 bits).
    let per_round = (k_eff * 64) as f64;
    assert!((h.records[0].bits_per_client - 2.0 * per_round).abs() < 1e-9);
    let last = h.records.last().unwrap();
    assert!((last.bits_per_client - 11.0 * per_round).abs() < 1e-9);
}

/// Three quadratic workers whose objectives are constant on the second
/// half of the coordinates: that block's gradient is identically zero,
/// so after the initial full broadcast its model never moves and delta
/// broadcast must come in strictly under dense — the simulated meter and
/// the real transport agree on that.
const FROZEN_D: usize = 16;

fn frozen_block_setup() -> (Vec<f64>, Arc<BlockLayout>, f64) {
    let layout = Arc::new(BlockLayout::equal(2, FROZEN_D).unwrap());
    (vec![0.5; FROZEN_D], layout, 0.1)
}

fn frozen_block_oracle(i: usize) -> Box<dyn GradOracle> {
    // Curvature only inside block 1 (coords 0..8); block 2 (coords
    // 8..16) is flat, so its gradient is identically zero.
    let mut h = vec![0.0; FROZEN_D];
    let mut c = vec![0.0; FROZEN_D];
    h[i % 8] = 4.0;
    h[(i + 1) % 8] = 2.0;
    c[i % 8] = (i + 1) as f64;
    Box::new(QuadraticOracle::diagonal(h, c))
}

#[test]
fn delta_downlink_is_strictly_cheaper_when_a_block_freezes() {
    let (x0, layout, gamma) = frozen_block_setup();
    let oracles: Vec<Box<dyn GradOracle>> = (0..3).map(frozen_block_oracle).collect();
    let c: Arc<dyn Compressor> =
        Arc::from(ef21::compress::from_spec_blocked("top2", &layout, 1).unwrap());
    let opts = BuildOpts { layout: Some(layout.clone()), threads: 1, full_init: false };
    let (m, w) = ef21::algo::build_with(AlgoSpec::Ef21, x0, oracles, c, gamma, 0, &opts);
    let rounds = 200u64;
    let cfg = RunConfig::rounds(rounds as usize).with_layout(layout.clone());
    let h = run_protocol(m, w, &cfg);
    let dense_bits = (rounds + 1) * 32 * FROZEN_D as u64;
    assert!(
        h.downlink_bits < dense_bits,
        "delta downlink {} not below dense {dense_bits}",
        h.downlink_bits
    );
    // The frozen block is never re-broadcast: every post-init round costs
    // at most one 8-coordinate patch (frame header + patch header + f32s),
    // which is strictly below the 16-coordinate dense frame.
    let per_round_max = 32 + 64 + 8 * 32;
    assert!(h.downlink_bits <= (FROZEN_D as u64 * 32) + rounds * per_round_max);
    // And the run still makes progress on the live block.
    let first = h.records.first().unwrap().grad_norm_sq;
    let last = h.records.last().unwrap().grad_norm_sq;
    assert!(last < first * 0.5, "no progress: {first} -> {last}");
    assert!(last.is_finite() && first.is_finite());
}

#[test]
fn dist_delta_broadcast_matches_dense_and_is_cheaper() {
    let (x0, layout, gamma) = frozen_block_setup();
    let run = |broadcast: Broadcast| {
        let layout = layout.clone();
        let x0 = x0.clone();
        let master = Box::new(ef21::algo::ef21::Ef21Master::with_layout(
            x0,
            3,
            gamma,
            layout.clone(),
            1,
        ));
        run_distributed_opts(
            master,
            3,
            move |i| {
                let c: Arc<dyn Compressor> =
                    Arc::from(ef21::compress::from_spec_blocked("top2", &layout, 1).unwrap());
                let rng = ef21::util::rng::worker_rng(0, i);
                Box::new(ef21::algo::ef21::Ef21Worker::with_layout(
                    frozen_block_oracle(i),
                    c,
                    rng,
                    layout.clone(),
                ))
            },
            30,
            TransportKind::Local,
            "dist-blocks",
            broadcast,
        )
        .unwrap()
    };
    let dense = run(Broadcast::Dense);
    let delta = run(Broadcast::Delta(layout.clone()));
    // Same trajectory (delta-applied models equal dense f32 broadcasts
    // bit for bit), same uplink accounting.
    for (a, b) in dense.history.records.iter().zip(&delta.history.records) {
        assert_eq!(a.loss.to_bits(), b.loss.to_bits(), "round {}", a.round);
        assert_eq!(a.bits_per_client.to_bits(), b.bits_per_client.to_bits());
    }
    for (a, b) in dense.final_x.iter().zip(&delta.final_x) {
        assert_eq!(a.to_bits(), b.to_bits());
    }
    // Strictly fewer downlink bits and bytes on the wire.
    assert!(delta.history.downlink_bits < dense.history.downlink_bits);
    assert!(delta.downlink_frame_bytes < dense.downlink_frame_bytes);
}

/// The blocked compressor's per-block telemetry keys appear under
/// `compress.<spec>.<block>.*` when telemetry is enabled.
#[test]
fn per_block_telemetry_keys_are_emitted() {
    let layout = Arc::new(BlockLayout::equal(2, 8).unwrap());
    let c = ef21::compress::from_spec_blocked("top2", &layout, 1).unwrap();
    ef21::telemetry::enable();
    let mut rng = Rng::seed(0);
    let v: Vec<f64> = (0..8).map(|j| j as f64 + 1.0).collect();
    let _ = c.compress(&v, &mut rng);
    ef21::telemetry::disable();
    let snap = ef21::telemetry::snapshot();
    let keys: Vec<String> = snap.histograms.iter().map(|(k, _)| k.clone()).collect();
    assert!(
        keys.iter().any(|k| k == "compress.top2.b0.ns"),
        "missing per-block latency key; histogram keys: {keys:?}"
    );
    assert!(keys.iter().any(|k| k == "compress.top2.b1.ns"));
}

/// EF21 with a blocked layout still converges on the divergence example
/// (alpha = min_b alpha_b keeps the Theorem-1 stepsize valid).
#[test]
fn blocked_ef21_converges_on_divergence_example() {
    let oracles: Vec<Box<dyn GradOracle>> = ef21::oracle::quadratic::divergence_example()
        .into_iter()
        .map(|q| Box::new(q) as Box<dyn GradOracle>)
        .collect();
    let layout = Arc::new(BlockLayout::equal(3, 3).unwrap());
    let c: Arc<dyn Compressor> =
        Arc::from(ef21::compress::from_spec_blocked("top1", &layout, 1).unwrap());
    let alpha = c.alpha(3);
    let gamma = ef21::theory::stepsize_theorem1(16.0, 16.0, alpha);
    let opts = BuildOpts { layout: Some(layout.clone()), threads: 1, full_init: false };
    let (m, w) = ef21::algo::build_with(AlgoSpec::Ef21, vec![1.0; 3], oracles, c, gamma, 2, &opts);
    let h = run_protocol(m, w, &RunConfig::rounds(4000).with_layout(layout));
    assert!(
        h.records.last().unwrap().grad_norm_sq < 1e-10,
        "blocked EF21 failed to converge: {}",
        h.records.last().unwrap().grad_norm_sq
    );
}
