//! Zero-allocation gate for the steady-state round loop (DESIGN.md §8).
//!
//! Built only with `--features count-allocs`, which installs the
//! counting global allocator. Methodology: run the identical scenario at
//! two round counts (after a warmup run that populates thread-local
//! scratch) and assert the allocation counts are **equal** — i.e. the
//! extra rounds allocated exactly nothing. Setup, init, the t=0 record,
//! and the final observation allocate identically in both runs, so they
//! cancel; any per-round allocation shows up as a nonzero delta.
//!
//! Covered: EF21 / EF / DCGD × top-k (k=1 and 3) / sign, at pool widths
//! 1 (sequential) and 4 (the pooled engine's command/reply slots and
//! buffer ping-pong must also be allocation-free). EF21+ is asserted
//! too: its branch candidates come from the pooled `Workspace` and the
//! winner swaps buffers with the message slot, so it reaches zero as
//! well (the historical exemption is thereby retired).
#![cfg(feature = "count-allocs")]

use ef21::algo::AlgoSpec;
use ef21::compress::{Compressor, ScaledSign, TopK};
use ef21::coordinator::{run_protocol_par, RunConfig};
use ef21::oracle::{GradOracle, QuadraticOracle};
use ef21::util::alloc::allocation_count;
use ef21::util::rng::Rng;
use std::sync::{Arc, Mutex};

/// Serialize measuring sections: the counter is process-wide, so no
/// other test's allocations may interleave with a measured run.
static SERIAL: Mutex<()> = Mutex::new(());

const D: usize = 32;
const WORKERS: usize = 8;

fn oracles() -> Vec<Box<dyn GradOracle>> {
    let mut rng = Rng::seed(42);
    (0..WORKERS)
        .map(|_| {
            let h: Vec<f64> = (0..D).map(|_| 0.5 + rng.next_f64()).collect();
            let c: Vec<f64> = (0..D).map(|_| rng.next_normal()).collect();
            Box::new(QuadraticOracle::diagonal(h, c)) as Box<dyn GradOracle>
        })
        .collect()
}

fn compressor(spec: &str) -> Arc<dyn Compressor> {
    match spec {
        "top1" => Arc::new(TopK::new(1)),
        "top3" => Arc::new(TopK::new(3)),
        "sign" => Arc::new(ScaledSign),
        other => panic!("unknown test compressor {other}"),
    }
}

/// Allocation count consumed by one fresh run of `rounds` rounds.
fn run_allocs(algo: AlgoSpec, spec: &str, threads: usize, rounds: usize) -> u64 {
    let (m, w) =
        ef21::algo::build(algo, vec![0.3; D], oracles(), compressor(spec), 0.01, 9);
    // Record only at t=0 and the final round, so steady-state rounds are
    // pure protocol (observation rounds legitimately snapshot gradients).
    let cfg = RunConfig::rounds(rounds).with_record_every(usize::MAX);
    let before = allocation_count();
    let h = run_protocol_par(m, w, &cfg, threads);
    let after = allocation_count();
    assert_eq!(h.records.last().unwrap().round, rounds - 1, "run stopped early");
    after - before
}

#[test]
fn steady_state_rounds_allocate_nothing() {
    for algo in [AlgoSpec::Ef21, AlgoSpec::Ef, AlgoSpec::Dcgd, AlgoSpec::Ef21Plus] {
        for spec in ["top1", "top3", "sign"] {
            if algo == AlgoSpec::Ef21Plus && spec == "sign" {
                // The gate's required matrix is EF21/EF/DCGD × {top-k,
                // sign}; EF21+ is asserted on the top-k pair.
                continue;
            }
            for threads in [1usize, 4] {
                let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
                // Warmup: thread-local scratch (top-k order buffer) and
                // lazily-grown buffers settle on the measuring thread.
                let _ = run_allocs(algo, spec, threads, 8);
                let short = run_allocs(algo, spec, threads, 8);
                let long = run_allocs(algo, spec, threads, 40);
                assert_eq!(
                    short,
                    long,
                    "{:?}/{spec}/threads={threads}: {} allocation(s) across 32 extra \
                     steady-state rounds (expected 0)",
                    algo,
                    long.saturating_sub(short)
                );
            }
        }
    }
}

/// The measurement itself must be live: a run with the alloc-forcing
/// legacy compression path (default `compress_into` → owned `compress`)
/// MUST show per-round allocations, proving the gate can fail.
#[test]
fn gate_detects_the_allocating_legacy_path() {
    struct AllocEveryCall(TopK);
    impl Compressor for AllocEveryCall {
        fn name(&self) -> String {
            self.0.name()
        }
        fn alpha(&self, d: usize) -> f64 {
            Compressor::alpha(&self.0, d)
        }
        fn compress(&self, v: &[f64], rng: &mut Rng) -> ef21::compress::Compressed {
            self.0.compress(v, rng)
        }
        // No compress_into override: the trait default allocates.
        fn is_deterministic(&self) -> bool {
            true
        }
    }

    let _g = SERIAL.lock().unwrap_or_else(|e| e.into_inner());
    let run = |rounds: usize| {
        let (m, w) = ef21::algo::build(
            AlgoSpec::Ef21,
            vec![0.3; D],
            oracles(),
            Arc::new(AllocEveryCall(TopK::new(3))),
            0.01,
            9,
        );
        let cfg = RunConfig::rounds(rounds).with_record_every(usize::MAX);
        let before = allocation_count();
        let _ = run_protocol_par(m, w, &cfg, 1);
        allocation_count() - before
    };
    let _ = run(8);
    let short = run(8);
    let long = run(40);
    assert!(
        long > short,
        "legacy allocating path was not detected (short={short}, long={long})"
    );
}
