//! Property tests for the log-linear histogram quantiles: the midpoint
//! estimator must stay within the documented ≤ 1/16 relative error of an
//! exact sorted reference across magnitudes and seeds, be monotone in q,
//! and handle the documented edge cases (empty, single sample, 0, 1,
//! `u64::MAX`).
//!
//! Private `Registry` instances only — this binary never touches the
//! process-global telemetry flag, so the tests can run in parallel.

use ef21::telemetry::Registry;
use ef21::util::rng::Rng;

/// Exact reference with the same rank convention as
/// `HistogramSnapshot::quantile`: the `ceil(q * n).max(1)`-th smallest.
fn exact_quantile(sorted: &[u64], q: f64) -> u64 {
    assert!(!sorted.is_empty());
    let rank = ((q.clamp(0.0, 1.0) * sorted.len() as f64).ceil() as usize).max(1);
    sorted[rank - 1]
}

/// |est − exact| ≤ exact/16 + 1: the sub-bucket width is at most 1/16 of
/// its lower bound (hence of any sample inside it), plus one for integer
/// midpoint rounding in the exact unit-bucket range below 32.
fn assert_within_bound(est: u64, exact: u64, ctx: &str) {
    let bound = exact / 16 + 1;
    let err = est.abs_diff(exact);
    assert!(err <= bound, "{ctx}: est={est} exact={exact} err={err} > bound={bound}");
}

#[test]
fn quantiles_track_the_exact_reference_across_magnitudes() {
    for seed in 0..8u64 {
        let mut rng = Rng::seed(seed);
        let reg = Registry::new();
        let h = reg.histogram("q.prop");
        let mut vals = Vec::with_capacity(1000);
        for _ in 0..1000 {
            // Mixed magnitudes: the sub-32 exact range, microsecond- and
            // millisecond-scale latencies, and occasional huge outliers.
            let v = match rng.next_u64() % 4 {
                0 => rng.next_u64() % 32,
                1 => 1_000 + rng.next_u64() % 9_000,
                2 => 1_000_000 + rng.next_u64() % 9_000_000,
                _ => rng.next_u64() % (1 << 40),
            };
            vals.push(v);
            h.record(v);
        }
        vals.sort_unstable();
        let snap = reg.snapshot();
        let hs = snap.histogram("q.prop").unwrap();
        for &q in &[0.0, 0.01, 0.1, 0.25, 0.5, 0.75, 0.9, 0.99, 0.999, 1.0] {
            let est = hs.quantile(q);
            let exact = exact_quantile(&vals, q);
            assert_within_bound(est, exact, &format!("seed {seed} q={q}"));
        }
        assert_eq!(hs.max, *vals.last().unwrap(), "max is tracked exactly");
    }
}

#[test]
fn quantile_is_monotone_in_q() {
    let mut rng = Rng::seed(42);
    let reg = Registry::new();
    let h = reg.histogram("q.mono");
    for _ in 0..500 {
        h.record(rng.next_u64() % (1 << 30));
    }
    let snap = reg.snapshot();
    let hs = snap.histogram("q.mono").unwrap();
    let mut last = 0u64;
    for i in 0..=100u32 {
        let q = f64::from(i) / 100.0;
        let v = hs.quantile(q);
        assert!(v >= last, "quantile({q}) = {v} went below {last}");
        last = v;
    }
}

#[test]
fn edge_cases_empty_single_and_extremes() {
    // Empty histogram: every quantile is 0.
    let reg = Registry::new();
    let _ = reg.histogram("q.edge"); // registered, never recorded
    let snap = reg.snapshot();
    let hs = snap.histogram("q.edge").unwrap();
    assert_eq!(hs.count, 0);
    for &q in &[0.0, 0.5, 1.0] {
        assert_eq!(hs.quantile(q), 0);
    }

    // A single sample at each documented extreme stays within bound.
    for v in [0u64, 1, 31, 32, u64::MAX] {
        let reg = Registry::new();
        reg.histogram("q.single").record(v);
        let snap = reg.snapshot();
        let hs = snap.histogram("q.single").unwrap();
        assert_eq!(hs.count, 1);
        for &q in &[0.0, 0.5, 1.0] {
            assert_within_bound(hs.quantile(q), v, &format!("single value {v} q={q}"));
        }
        assert_eq!(hs.max, v, "exact max for single sample {v}");
    }

    // Below 32 the buckets are unit-width, so quantiles are exact.
    let reg = Registry::new();
    reg.histogram("q.unit").record(17);
    let snap = reg.snapshot();
    assert_eq!(snap.histogram("q.unit").unwrap().quantile(0.5), 17);
}
