//! Scaled Rand-k (Example 2): keep k uniformly random coordinates,
//! unscaled. This is the biased compressor `(1/(1+omega)) C'` obtained from
//! the unbiased Rand-k `C'(v) = (d/k) v_S` via Lemma 8 — the `(1/(1+omega))`
//! and `(d/k)` factors cancel, so the output is simply `v` restricted to a
//! random k-subset. `alpha = k/d`, same as Top-k, which is exactly the
//! paper's point: identical worst-case theory, very different practice.

use super::{Compressed, Compressor};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct RandK {
    pub k: usize,
}

impl RandK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "rand-k needs k >= 1");
        RandK { k }
    }
}

impl Compressor for RandK {
    fn name(&self) -> String {
        format!("rand{}", self.k)
    }

    fn alpha(&self, d: usize) -> f64 {
        (self.k.min(d) as f64 / d as f64).min(1.0)
    }

    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        let mut out = Compressed::empty();
        self.compress_into(v, rng, &mut out);
        out
    }

    fn compress_into(&self, v: &[f64], rng: &mut Rng, out: &mut Compressed) {
        let d = v.len();
        let k = self.k.min(d);
        let sp = &mut out.sparse;
        if k == d {
            sp.idx.clear();
            sp.idx.extend(0..d as u32);
        } else {
            rng.sample_indices_into(d, k, &mut sp.idx);
        }
        sp.val.clear();
        sp.val.extend(sp.idx.iter().map(|&i| v[i as usize]));
        out.bits = out.sparse.standard_bits();
    }

    fn is_deterministic(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{for_all_seeds, random_vec};

    #[test]
    fn keeps_exactly_k_unscaled_entries() {
        for_all_seeds(20, |rng| {
            let d = 2 + rng.next_below(100);
            let k = 1 + rng.next_below(d);
            let v = random_vec(rng, d, 1.0);
            let out = RandK::new(k).compress(&v, rng);
            assert_eq!(out.sparse.nnz(), k);
            for (&i, &x) in out.sparse.idx.iter().zip(&out.sparse.val) {
                assert_eq!(x, v[i as usize], "entries must be unscaled");
            }
        });
    }

    #[test]
    fn expected_distortion_equals_one_minus_k_over_d() {
        // E||C(v)-v||^2 = (1 - k/d)||v||^2 with equality (uniform subset).
        let mut rng = Rng::seed(3);
        let d = 50;
        let k = 10;
        let v = random_vec(&mut rng, d, 2.0);
        let c = RandK::new(k);
        let reps = 4000;
        let mean: f64 = (0..reps)
            .map(|_| super::super::distortion_ratio(&c, &v, &mut rng))
            .sum::<f64>()
            / reps as f64;
        assert!((mean - (1.0 - k as f64 / d as f64)).abs() < 0.02, "{mean}");
    }

    #[test]
    fn k_geq_d_is_identity() {
        let v = vec![1.0, 2.0];
        let mut rng = Rng::seed(1);
        assert_eq!(RandK::new(5).compress(&v, &mut rng).sparse.to_dense(2), v);
    }
}
