//! Identity "compressor" (`alpha = 1`): transmits the full dense vector.
//! With EF21 this degenerates to exact distributed GD (the paper's `k = d`
//! reference curves in Figures 2 and 7); the bit accounting still charges
//! the full `d * 32` value bits (no indices — dense wire format).

use super::{Compressed, Compressor};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct Identity;

impl Compressor for Identity {
    fn name(&self) -> String {
        "identity".into()
    }

    fn alpha(&self, _d: usize) -> f64 {
        1.0
    }

    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        let mut out = Compressed::empty();
        self.compress_into(v, rng, &mut out);
        out
    }

    fn compress_into(&self, v: &[f64], _rng: &mut Rng, out: &mut Compressed) {
        // Same entries as `SparseVec::from_dense_full(v)`, into reused
        // buffers.
        let sp = &mut out.sparse;
        sp.idx.clear();
        sp.idx.extend(0..v.len() as u32);
        sp.val.clear();
        sp.val.extend_from_slice(v);
        // Dense wire format: values only, no index stream.
        out.bits = v.len() as u64 * super::sparse::VALUE_BITS;
    }

    fn is_deterministic(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn exact_and_dense_billed() {
        let v = vec![1.0, 0.0, -2.0];
        let mut rng = Rng::seed(0);
        let out = Identity.compress(&v, &mut rng);
        assert_eq!(out.sparse.to_dense(3), v);
        assert_eq!(out.bits, 3 * 32);
    }
}
