//! Compression operators (§2.1 of the paper) and the Markov compressor
//! (§3.1), with exact wire-cost accounting.
//!
//! Two families:
//!   * unbiased `U(omega)` — Eq. (2); see [`unbiased`], used only to
//!     demonstrate Lemma 8 (scaling an unbiased compressor into `B`).
//!   * biased/contractive `B(alpha)` — Eq. (3); the [`Compressor`] trait.
//!     Canonical member: Top-k with `alpha = k/d`.
//!
//! Every compressor returns a [`Compressed`] carrying the output vector (as
//! a [`SparseVec`]) plus the exact number of bits a real wire transfer
//! would cost — the paper's x-axis (`bits/n`) is regenerated from these.

pub mod block;
pub mod identity;
pub mod markov;
pub mod randk;
pub mod sign;
pub mod sparse;
pub mod topk;
pub mod unbiased;

pub use block::{split_budget, BlockCompressor};
pub use identity::Identity;
pub use markov::Markov;
pub use randk::RandK;
pub use sign::ScaledSign;
pub use sparse::SparseVec;
pub use topk::TopK;
pub use unbiased::{RandKUnbiased, Scaled};

use crate::util::rng::Rng;

/// Result of one compression: the vector plus its exact wire cost.
#[derive(Clone, Debug, Default)]
pub struct Compressed {
    pub sparse: SparseVec,
    /// Exact wire bits (values + indices + any header), as accounted in the
    /// paper's `bits/n` plots.
    pub bits: u64,
}

impl Compressed {
    /// An empty message (no entries, 0 bits). `Vec::new` does not
    /// allocate, so this is also the zero-cost [`Compressor::compress_into`]
    /// target seed.
    pub fn empty() -> Compressed {
        Compressed { sparse: SparseVec::empty(), bits: 0 }
    }
}

/// A (possibly randomized) contractive compressor `C ∈ B(alpha)`, Eq. (3):
/// `E ||C(x) - x||^2 <= (1 - alpha) ||x||^2`.
pub trait Compressor: Send + Sync {
    /// Human-readable name ("top1", "rand8", ...).
    fn name(&self) -> String;

    /// Contraction parameter for input dimension `d` (`0 < alpha <= 1`).
    fn alpha(&self, d: usize) -> f64;

    /// Compress `v`. Deterministic compressors ignore `rng`.
    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed;

    /// Compress `v` into a caller-owned message, overwriting `out` while
    /// reusing its index/value allocations — the zero-allocation round
    /// path. Output is identical to [`Compressor::compress`] (the two
    /// share one arithmetic path in every in-tree impl; this default
    /// exists for exotic implementations and simply forwards).
    fn compress_into(&self, v: &[f64], rng: &mut Rng, out: &mut Compressed) {
        *out = self.compress(v, rng);
    }

    /// Whether the operator is deterministic (Top-k yes, Rand-k no). EF21+'s
    /// analysis (§3.5) needs a deterministic `C`.
    fn is_deterministic(&self) -> bool;
}

impl<T: Compressor + ?Sized> Compressor for Box<T> {
    fn name(&self) -> String {
        (**self).name()
    }
    fn alpha(&self, d: usize) -> f64 {
        (**self).alpha(d)
    }
    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        (**self).compress(v, rng)
    }
    fn compress_into(&self, v: &[f64], rng: &mut Rng, out: &mut Compressed) {
        // Explicit forward: the default would bounce through the inner
        // `compress` and re-allocate, defeating the buffer-reuse contract.
        (**self).compress_into(v, rng, out)
    }
    fn is_deterministic(&self) -> bool {
        (**self).is_deterministic()
    }
}

/// Telemetry wrapper: meters each apply under
/// `compress.<name>.ns` (latency histogram) and `compress.<name>.sparsity`
/// (gauge, achieved `nnz/d` of the last output). Costs one atomic load per
/// apply when telemetry is disabled; when enabled, the handles are
/// resolved once and cached so registry lookups stay off the per-round
/// hot path.
pub struct Instrumented {
    inner: Box<dyn Compressor>,
    ns_key: String,
    sparsity_key: String,
    ns: std::sync::OnceLock<crate::telemetry::Histogram>,
    sparsity: std::sync::OnceLock<crate::telemetry::Gauge>,
}

impl Instrumented {
    pub fn wrap(inner: Box<dyn Compressor>) -> Box<dyn Compressor> {
        let name = inner.name();
        Box::new(Instrumented {
            ns_key: format!("compress.{name}.ns"),
            sparsity_key: format!("compress.{name}.sparsity"),
            inner,
            ns: std::sync::OnceLock::new(),
            sparsity: std::sync::OnceLock::new(),
        })
    }

    /// Close one metered apply. `t0` is Some only when telemetry was
    /// enabled at apply time, so the cached handles are only ever
    /// initialized live, never as noops.
    fn record(&self, t0: Option<std::time::Instant>, out: &Compressed, d: usize) {
        if let Some(t0) = t0 {
            self.ns
                .get_or_init(|| crate::telemetry::histogram(&self.ns_key))
                .record(t0.elapsed().as_nanos() as u64);
            self.sparsity
                .get_or_init(|| crate::telemetry::gauge(&self.sparsity_key))
                .set(out.sparse.nnz() as f64 / d.max(1) as f64);
        }
    }
}

impl Compressor for Instrumented {
    fn name(&self) -> String {
        self.inner.name()
    }

    fn alpha(&self, d: usize) -> f64 {
        self.inner.alpha(d)
    }

    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        let t0 = crate::telemetry::maybe_now();
        let sp = crate::telemetry::span("compress.apply");
        let out = self.inner.compress(v, rng);
        sp.end();
        self.record(t0, &out, v.len());
        out
    }

    fn compress_into(&self, v: &[f64], rng: &mut Rng, out: &mut Compressed) {
        let t0 = crate::telemetry::maybe_now();
        let sp = crate::telemetry::span("compress.apply");
        self.inner.compress_into(v, rng, out);
        sp.end();
        self.record(t0, out, v.len());
    }

    fn is_deterministic(&self) -> bool {
        self.inner.is_deterministic()
    }
}

/// Build a compressor from a CLI/config spec string:
/// `"top<k>"`, `"rand<k>"`, `"sign"`, `"identity"` / `"none"`.
/// The result is telemetry-[`Instrumented`].
pub fn from_spec(spec: &str) -> anyhow::Result<Box<dyn Compressor>> {
    let s = spec.trim().to_ascii_lowercase();
    if s == "identity" || s == "none" {
        return Ok(Instrumented::wrap(Box::new(Identity)));
    }
    if s == "sign" {
        return Ok(Instrumented::wrap(Box::new(ScaledSign)));
    }
    if let Some(k) = s.strip_prefix("top") {
        let k: usize = k.parse()?;
        anyhow::ensure!(k >= 1, "top-k needs k >= 1");
        return Ok(Instrumented::wrap(Box::new(TopK::new(k))));
    }
    if let Some(k) = s.strip_prefix("rand") {
        let k: usize = k.parse()?;
        anyhow::ensure!(k >= 1, "rand-k needs k >= 1");
        return Ok(Instrumented::wrap(Box::new(RandK::new(k))));
    }
    anyhow::bail!("unknown compressor spec '{spec}' (try top1, rand8, sign, identity)")
}

/// [`from_spec`] against a block layout: a flat (single-block) layout
/// takes the exact legacy path — same operator object, same telemetry
/// keys, bit-identical output — while a real partition builds a
/// telemetry-instrumented [`BlockCompressor`] (layer-wise budgets,
/// `alpha = min_b alpha_b`, per-block `compress.<spec>.<block>.*` keys).
/// `threads` bounds the block-parallel fan-out of the hot path.
pub fn from_spec_blocked(
    spec: &str,
    layout: &std::sync::Arc<crate::blocks::BlockLayout>,
    threads: usize,
) -> anyhow::Result<Box<dyn Compressor>> {
    if layout.is_flat() {
        return from_spec(spec);
    }
    let c = BlockCompressor::from_spec(spec, layout.clone(), threads)?;
    Ok(Instrumented::wrap(Box::new(c)))
}

/// Empirical check of the contraction property (3) for a single input:
/// returns `||C(v) - v||^2 / ||v||^2`, which must be `<= 1 - alpha` for
/// deterministic compressors (and in expectation for randomized ones).
pub fn distortion_ratio(c: &dyn Compressor, v: &[f64], rng: &mut Rng) -> f64 {
    let out = c.compress(v, rng).sparse.to_dense(v.len());
    let num = crate::util::linalg::dist_sq(&out, v);
    let den = crate::util::linalg::norm2_sq(v);
    if den == 0.0 {
        0.0
    } else {
        num / den
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{for_all_seeds, random_vec};

    fn all_compressors(d: usize) -> Vec<Box<dyn Compressor>> {
        vec![
            Box::new(TopK::new(1)),
            Box::new(TopK::new((d / 4).max(1))),
            Box::new(RandK::new(1)),
            Box::new(RandK::new((d / 4).max(1))),
            Box::new(ScaledSign),
            Box::new(Identity),
        ]
    }

    /// Property: Eq. (3) holds pointwise for deterministic compressors and
    /// in expectation (checked empirically with slack) for randomized ones.
    #[test]
    fn contraction_property_eq3() {
        for_all_seeds(30, |rng| {
            let d = 2 + rng.next_below(60);
            let scale = 1.0 + 5.0 * rng.next_f64();
            let v = random_vec(rng, d, scale);
            for c in all_compressors(d) {
                let alpha = c.alpha(d);
                assert!(alpha > 0.0 && alpha <= 1.0, "{} alpha {alpha}", c.name());
                if c.is_deterministic() {
                    let r = distortion_ratio(c.as_ref(), &v, rng);
                    assert!(
                        r <= 1.0 - alpha + 1e-9,
                        "{}: ratio {r} > 1 - alpha {}",
                        c.name(),
                        1.0 - alpha
                    );
                } else {
                    // Average over repeats for the expectation bound.
                    let reps = 300;
                    let mean: f64 = (0..reps)
                        .map(|_| distortion_ratio(c.as_ref(), &v, rng))
                        .sum::<f64>()
                        / reps as f64;
                    assert!(
                        mean <= (1.0 - alpha) * 1.15 + 1e-9,
                        "{}: mean ratio {mean} vs 1-alpha {}",
                        c.name(),
                        1.0 - alpha
                    );
                }
            }
        });
    }

    /// Zero input must compress to (exactly) zero — this is what makes EF21
    /// stable near stationary points (§3: vanishing inputs, vanishing
    /// distortion).
    #[test]
    fn zero_maps_to_zero() {
        let mut rng = crate::util::rng::Rng::seed(1);
        let v = vec![0.0; 32];
        for c in all_compressors(32) {
            let out = c.compress(&v, &mut rng).sparse.to_dense(32);
            assert!(out.iter().all(|&x| x == 0.0), "{}", c.name());
        }
    }

    #[test]
    fn from_spec_parses_and_rejects() {
        assert_eq!(from_spec("top5").unwrap().name(), "top5");
        assert_eq!(from_spec("rand3").unwrap().name(), "rand3");
        assert_eq!(from_spec("sign").unwrap().name(), "sign");
        assert_eq!(from_spec("identity").unwrap().name(), "identity");
        assert!(from_spec("top0").is_err());
        assert!(from_spec("bogus").is_err());
    }

    #[test]
    fn bits_accounting_is_positive_and_monotone_in_k() {
        let mut rng = crate::util::rng::Rng::seed(2);
        let v = random_vec(&mut rng, 100, 1.0);
        let b1 = TopK::new(1).compress(&v, &mut rng).bits;
        let b10 = TopK::new(10).compress(&v, &mut rng).bits;
        assert!(b1 > 0 && b10 > b1);
    }
}
