//! Top-k: the canonical greedy biased compressor (`B(k/d)`, Example 1).
//! Keeps the k largest-magnitude entries, zeros the rest.
//!
//! Selection uses `select_nth_unstable` (expected O(d)) on a scratch index
//! buffer rather than a full O(d log d) sort — this is the L3 hot spot when
//! compressing the ~470k-dim transformer gradient (see EXPERIMENTS.md §Perf).
//! Ties are broken deterministically (by index) so Top-k remains a
//! deterministic operator, as required by EF21+'s analysis (§3.5).

use super::{Compressed, Compressor};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct TopK {
    pub k: usize,
}

impl TopK {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1, "top-k needs k >= 1");
        TopK { k }
    }

    /// Indices of the k largest |v| entries (deterministic tie-break by
    /// lower index), written sorted ascending into the caller's buffer
    /// (cleared first; its allocation is reused — the selection itself
    /// allocates nothing in steady state).
    ///
    /// Perf (§Perf L3, iteration log in EXPERIMENTS.md): expected-O(d)
    /// `select_nth_unstable` instead of a full O(d log d) sort
    /// ([`Self::select_indices_via_sort`] is kept as the measured
    /// baseline), and the index scratch buffer is thread-local so the
    /// 470k-dim transformer gradient compression does not allocate ~2 MB
    /// per round.
    pub fn select_indices_into(&self, v: &[f64], out: &mut Vec<u32>) {
        let d = v.len();
        let k = self.k.min(d);
        out.clear();
        if k == d {
            out.extend(0..d as u32);
            return;
        }
        SCRATCH.with(|cell| {
            let mut order = cell.take();
            order.clear();
            order.extend(0..d as u32);
            // Descending |v|, ascending index on ties.
            let key = |i: &u32| {
                let a = v[*i as usize].abs();
                (std::cmp::Reverse(FloatOrd(a)), *i)
            };
            order.select_nth_unstable_by_key(k - 1, key);
            out.extend_from_slice(&order[..k]);
            out.sort_unstable();
            cell.set(order);
        });
    }

    /// [`Self::select_indices_into`] into a fresh vector (convenience;
    /// the hot path uses the caller-buffer form).
    pub fn select_indices(&self, v: &[f64]) -> Vec<u32> {
        let mut out = Vec::new();
        self.select_indices_into(v, &mut out);
        out
    }

    /// Baseline selection via full sort — kept for the §Perf ablation
    /// bench (`bench_compressors`) and as a differential-testing oracle.
    pub fn select_indices_via_sort(&self, v: &[f64]) -> Vec<u32> {
        let d = v.len();
        let k = self.k.min(d);
        let mut order: Vec<u32> = (0..d as u32).collect();
        order.sort_unstable_by(|&a, &b| {
            v[b as usize]
                .abs()
                .partial_cmp(&v[a as usize].abs())
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        let mut top = order[..k].to_vec();
        top.sort_unstable();
        top
    }
}

thread_local! {
    /// Reused index buffer for [`TopK::select_indices`]. Thread-local,
    /// so the shared `Arc<TopK>` stays `Sync` and each pool thread of
    /// [`crate::coordinator::par`] amortizes its own buffer; the buffer
    /// is cleared and refilled on every use, so selection output never
    /// depends on which thread (or which prior call) last used it —
    /// required for the parallel runner's bit-identity guarantee.
    static SCRATCH: std::cell::Cell<Vec<u32>> = const { std::cell::Cell::new(Vec::new()) };
}

/// Total order on f64 magnitudes (no NaNs expected in gradients; NaN sorts
/// last so it is never selected before finite values). PartialOrd MUST be
/// defined through Ord — sort internals compare via `lt`, and a derived
/// (IEEE) PartialOrd would disagree with the NaN-totalized Ord.
#[derive(PartialEq)]
struct FloatOrd(f64);

impl Eq for FloatOrd {}

impl PartialOrd for FloatOrd {
    fn partial_cmp(&self, other: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(other))
    }
}

impl Ord for FloatOrd {
    fn cmp(&self, other: &Self) -> std::cmp::Ordering {
        self.0.partial_cmp(&other.0).unwrap_or_else(|| {
            // NaN handling: treat NaN as smallest magnitude.
            match (self.0.is_nan(), other.0.is_nan()) {
                (true, true) => std::cmp::Ordering::Equal,
                (true, false) => std::cmp::Ordering::Less,
                (false, true) => std::cmp::Ordering::Greater,
                _ => unreachable!(),
            }
        })
    }
}

impl Compressor for TopK {
    fn name(&self) -> String {
        format!("top{}", self.k)
    }

    /// alpha = k/d (Example 1 / Beznosikov et al. 2020).
    fn alpha(&self, d: usize) -> f64 {
        (self.k.min(d) as f64 / d as f64).min(1.0)
    }

    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        let mut out = Compressed::empty();
        self.compress_into(v, rng, &mut out);
        out
    }

    fn compress_into(&self, v: &[f64], _rng: &mut Rng, out: &mut Compressed) {
        let sp = &mut out.sparse;
        self.select_indices_into(v, &mut sp.idx);
        sp.val.clear();
        sp.val.extend(sp.idx.iter().map(|&i| v[i as usize]));
        out.bits = out.sparse.standard_bits();
    }

    fn is_deterministic(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{for_all_seeds, random_vec};

    #[test]
    fn picks_largest_magnitudes() {
        let v = vec![0.1, -5.0, 2.0, 0.0, 3.0];
        let mut rng = Rng::seed(0);
        let out = TopK::new(2).compress(&v, &mut rng).sparse.to_dense(5);
        assert_eq!(out, vec![0.0, -5.0, 0.0, 0.0, 3.0]);
    }

    #[test]
    fn k_geq_d_is_identity() {
        let v = vec![1.0, -2.0, 3.0];
        let mut rng = Rng::seed(0);
        let out = TopK::new(10).compress(&v, &mut rng).sparse.to_dense(3);
        assert_eq!(out, v);
        assert!((TopK::new(10).alpha(3) - 1.0).abs() < 1e-15);
    }

    #[test]
    fn deterministic_tie_break() {
        // All equal magnitudes: lowest indices must win, repeatably.
        let v = vec![1.0; 8];
        let mut rng = Rng::seed(0);
        let a = TopK::new(3).compress(&v, &mut rng).sparse;
        let b = TopK::new(3).compress(&v, &mut rng).sparse;
        assert_eq!(a, b);
        assert_eq!(a.idx, vec![0, 1, 2]);
    }

    #[test]
    fn contraction_is_tight_on_uniform_vector() {
        // Worst case of Eq. (3): uniform energy. ratio == 1 - k/d exactly.
        let d = 10;
        let v = vec![2.0; d];
        let mut rng = Rng::seed(0);
        let c = TopK::new(3);
        let r = super::super::distortion_ratio(&c, &v, &mut rng);
        assert!((r - (1.0 - 0.3)).abs() < 1e-12, "{r}");
    }

    #[test]
    fn matches_naive_sort_selection() {
        for_all_seeds(25, |rng| {
            let d = 1 + rng.next_below(200);
            let k = 1 + rng.next_below(d);
            let v = random_vec(rng, d, 3.0);
            let fast = TopK::new(k).select_indices(&v);
            // Naive: full sort by (|v| desc, idx asc).
            let mut order: Vec<u32> = (0..d as u32).collect();
            order.sort_by(|&a, &b| {
                v[b as usize]
                    .abs()
                    .partial_cmp(&v[a as usize].abs())
                    .unwrap()
                    .then(a.cmp(&b))
            });
            let mut naive = order[..k].to_vec();
            naive.sort_unstable();
            assert_eq!(fast, naive, "d={d} k={k}");
        });
    }

    #[test]
    fn handles_nan_by_never_selecting_it_over_finite() {
        let v = vec![f64::NAN, 1.0, 2.0];
        let idx = TopK::new(2).select_indices(&v);
        assert_eq!(idx, vec![1, 2]);
    }

    #[test]
    fn compress_into_matches_compress_and_reuses_buffers() {
        let mut rng = Rng::seed(6);
        let v = random_vec(&mut rng, 64, 2.0);
        let c = TopK::new(5);
        let owned = Compressor::compress(&c, &v, &mut rng);
        let mut out = Compressed::empty();
        c.compress_into(&v, &mut rng, &mut out);
        assert_eq!(owned.sparse, out.sparse);
        assert_eq!(owned.bits, out.bits);
        // Second apply reuses the same allocations (k unchanged).
        let idx_ptr = out.sparse.idx.as_ptr();
        let val_ptr = out.sparse.val.as_ptr();
        let w = random_vec(&mut rng, 64, 2.0);
        c.compress_into(&w, &mut rng, &mut out);
        assert_eq!(out.sparse.idx.as_ptr(), idx_ptr, "index buffer was reallocated");
        assert_eq!(out.sparse.val.as_ptr(), val_ptr, "value buffer was reallocated");
        assert_eq!(out.sparse, Compressor::compress(&c, &w, &mut rng).sparse);
    }

    #[test]
    fn fast_path_matches_sort_baseline() {
        for_all_seeds(30, |rng| {
            let d = 1 + rng.next_below(300);
            let k = 1 + rng.next_below(d);
            let v = random_vec(rng, d, 2.0);
            let c = TopK::new(k);
            assert_eq!(c.select_indices(&v), c.select_indices_via_sort(&v));
        });
    }
}
