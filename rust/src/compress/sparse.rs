//! Sparse vector: the in-memory and on-wire representation of compressed
//! messages. Index/value pairs, sorted by index; the codec (transport) and
//! the bit accounting both derive from this one type so the simulated
//! `bits/n` axis and the real TCP byte stream can never disagree.

/// Sparse vector over a dense space of dimension `d` (implicit; carried by
/// context). Indices are `u32`, strictly increasing; values are `f64` in
/// memory, accounted and serialized as IEEE f32 on the wire (the paper's
/// plots count 32-bit floats).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseVec {
    pub idx: Vec<u32>,
    pub val: Vec<f64>,
}

/// Wire bits per value (f32).
pub const VALUE_BITS: u64 = 32;
/// Wire bits per index (u32; the paper also counts plain 32-bit indices).
pub const INDEX_BITS: u64 = 32;

impl SparseVec {
    pub fn new(idx: Vec<u32>, val: Vec<f64>) -> Self {
        debug_assert_eq!(idx.len(), val.len());
        debug_assert!(idx.windows(2).all(|w| w[0] < w[1]), "indices must be sorted+unique");
        SparseVec { idx, val }
    }

    pub fn empty() -> Self {
        SparseVec { idx: Vec::new(), val: Vec::new() }
    }

    /// Dense vector -> sparse (drops exact zeros).
    pub fn from_dense(v: &[f64]) -> Self {
        let mut idx = Vec::new();
        let mut val = Vec::new();
        for (i, &x) in v.iter().enumerate() {
            if x != 0.0 {
                idx.push(i as u32);
                val.push(x);
            }
        }
        SparseVec { idx, val }
    }

    /// Dense vector, keeping explicit entries for ALL coordinates (used by
    /// dense-message algorithms like GD where zeros are still transmitted).
    pub fn from_dense_full(v: &[f64]) -> Self {
        SparseVec {
            idx: (0..v.len() as u32).collect(),
            val: v.to_vec(),
        }
    }

    pub fn nnz(&self) -> usize {
        self.idx.len()
    }

    /// Materialize into a dense vector of dimension `d`.
    pub fn to_dense(&self, d: usize) -> Vec<f64> {
        let mut out = vec![0.0; d];
        self.add_into(&mut out);
        out
    }

    /// out += self
    pub fn add_into(&self, out: &mut [f64]) {
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += v;
        }
    }

    /// out += scale * self
    pub fn add_scaled_into(&self, scale: f64, out: &mut [f64]) {
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] += scale * v;
        }
    }

    /// Overwrite the touched coordinates (used by EF21+'s DCGD branch where
    /// the message *is* the new state, not a delta).
    pub fn assign_into(&self, out: &mut [f64]) {
        for (&i, &v) in self.idx.iter().zip(&self.val) {
            out[i as usize] = v;
        }
    }

    pub fn scale(&mut self, s: f64) {
        for v in self.val.iter_mut() {
            *v *= s;
        }
    }

    /// Standard wire cost: nnz * (value + index) bits. Compressors with a
    /// cheaper encoding (e.g. sign) report their own `Compressed::bits`.
    pub fn standard_bits(&self) -> u64 {
        self.nnz() as u64 * (VALUE_BITS + INDEX_BITS)
    }

    /// Entry range (into `idx`/`val`) whose coordinates fall in
    /// `[lo, hi)` — binary search over the sorted index stream. The one
    /// block-windowing primitive shared by the blocked aggregation tile
    /// and the per-block uplink splitter.
    pub fn entry_range(&self, lo: u32, hi: u32) -> std::ops::Range<usize> {
        let a = self.idx.partition_point(|&i| i < lo);
        let b = self.idx.partition_point(|&i| i < hi);
        a..b
    }

    /// ||self||^2
    pub fn norm2_sq(&self) -> f64 {
        self.val.iter().map(|v| v * v).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_dense_roundtrip() {
        let v = vec![0.0, 1.5, 0.0, -2.0, 0.0];
        let s = SparseVec::from_dense(&v);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense(5), v);
    }

    #[test]
    fn from_dense_full_keeps_zeros() {
        let v = vec![0.0, 1.0];
        let s = SparseVec::from_dense_full(&v);
        assert_eq!(s.nnz(), 2);
        assert_eq!(s.to_dense(2), v);
    }

    #[test]
    fn add_scaled_and_assign() {
        let s = SparseVec::new(vec![1, 3], vec![2.0, -1.0]);
        let mut out = vec![1.0; 4];
        s.add_scaled_into(0.5, &mut out);
        assert_eq!(out, vec![1.0, 2.0, 1.0, 0.5]);
        s.assign_into(&mut out);
        assert_eq!(out, vec![1.0, 2.0, 1.0, -1.0]);
    }

    #[test]
    fn bits_and_norm() {
        let s = SparseVec::new(vec![0, 2, 9], vec![3.0, 4.0, 0.0]);
        assert_eq!(s.standard_bits(), 3 * 64);
        assert!((s.norm2_sq() - 25.0).abs() < 1e-12);
    }

    #[test]
    fn entry_range_windows_sorted_indices() {
        let s = SparseVec::new(vec![2, 5, 9, 17], vec![1.0, 2.0, 3.0, 4.0]);
        assert_eq!(s.entry_range(0, 6), 0..2);
        assert_eq!(s.entry_range(5, 10), 1..3);
        assert_eq!(s.entry_range(10, 17), 3..3); // empty window
        assert_eq!(s.entry_range(0, 100), 0..4);
        assert_eq!(SparseVec::empty().entry_range(0, 5), 0..0);
    }

    #[test]
    fn empty_is_free() {
        let s = SparseVec::empty();
        assert_eq!(s.standard_bits(), 0);
        assert_eq!(s.to_dense(3), vec![0.0; 3]);
    }
}
