//! Norm-scaled sign compressor: `C(v) = (||v||_1 / d) * sign(v)`.
//!
//! A classical member of `B(alpha)` (Beznosikov et al. 2020, Table 1):
//! `||C(v) - v||^2 = ||v||^2 - ||v||_1^2 / d <= (1 - 1/d) ||v||^2`,
//! so `alpha = 1/d` in the worst case. Wire cost is d sign bits plus one
//! f32 scale — by far the cheapest per-round message, which makes it a
//! useful extreme point in the bits/accuracy trade-off benches.

use super::{Compressed, Compressor};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct ScaledSign;

impl Compressor for ScaledSign {
    fn name(&self) -> String {
        "sign".into()
    }

    fn alpha(&self, d: usize) -> f64 {
        1.0 / d as f64
    }

    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        let mut out = Compressed::empty();
        self.compress_into(v, rng, &mut out);
        out
    }

    fn compress_into(&self, v: &[f64], _rng: &mut Rng, out: &mut Compressed) {
        let d = v.len();
        let l1: f64 = v.iter().map(|x| x.abs()).sum();
        let scale = l1 / d as f64;
        // Dense wire image (zeros kept), written straight into the reused
        // buffers — same entries as `SparseVec::from_dense_full` of the
        // signed-scale vector.
        let sp = &mut out.sparse;
        sp.idx.clear();
        sp.idx.extend(0..d as u32);
        sp.val.clear();
        sp.val.extend(v.iter().map(|&x| {
            if x > 0.0 {
                scale
            } else if x < 0.0 {
                -scale
            } else {
                0.0
            }
        }));
        // 1 sign bit per coordinate + one f32 scale.
        out.bits = d as u64 + super::sparse::VALUE_BITS;
    }

    fn is_deterministic(&self) -> bool {
        true
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{for_all_seeds, random_vec};

    #[test]
    fn identity_on_zero_and_exact_distortion_formula() {
        for_all_seeds(20, |rng| {
            let d = 1 + rng.next_below(64);
            let v = random_vec(rng, d, 2.0);
            let out = ScaledSign.compress(&v, rng).sparse.to_dense(d);
            let l1: f64 = v.iter().map(|x| x.abs()).sum();
            let n2: f64 = v.iter().map(|x| x * x).sum();
            let dist: f64 = out.iter().zip(&v).map(|(a, b)| (a - b) * (a - b)).sum();
            let expect = n2 - l1 * l1 / d as f64;
            assert!((dist - expect).abs() < 1e-9 * n2.max(1.0), "{dist} vs {expect}");
        });
    }

    #[test]
    fn bits_are_d_plus_32() {
        let v = vec![1.0; 100];
        let mut rng = Rng::seed(0);
        assert_eq!(ScaledSign.compress(&v, &mut rng).bits, 132);
    }
}
