//! The Markov compressor `M` (§3.1, Eqs. 9–10): the recursive construction
//! at the heart of EF21.
//!
//!   M(v^0)    = C(v^0)
//!   M(v^{t+1}) = M(v^t) + C(v^{t+1} - M(v^t))
//!
//! The state `g = M(v^t)` is maintained on both endpoints (worker and
//! master), so only the compressed *delta* `C(v^{t+1} - g)` crosses the
//! wire. Lemma 1 (distortion recursion) and Corollary 1 (distortion -> 0
//! for convergent inputs) are verified in the tests below.

use super::{Compressed, Compressor};
use crate::util::linalg;
use crate::util::rng::Rng;

/// Stateful Markov compressor wrapping any `C ∈ B(alpha)`.
pub struct Markov<C: Compressor> {
    c: C,
    /// Current estimate g = M(v^t); mirrored by the receiving end.
    g: Vec<f64>,
    initialized: bool,
}

impl<C: Compressor> Markov<C> {
    pub fn new(c: C, d: usize) -> Self {
        Markov { c, g: vec![0.0; d], initialized: false }
    }

    /// Current estimate `M(v^t)`.
    pub fn estimate(&self) -> &[f64] {
        &self.g
    }

    /// Feed the next input vector; returns the compressed delta that a
    /// worker would transmit. Applies Eq. (10) (Eq. (9) on first call,
    /// which coincides with (10) when g = 0).
    pub fn step(&mut self, v: &[f64], rng: &mut Rng) -> Compressed {
        assert_eq!(v.len(), self.g.len());
        let diff: Vec<f64> = v.iter().zip(&self.g).map(|(a, b)| a - b).collect();
        let out = self.c.compress(&diff, rng);
        out.sparse.add_into(&mut self.g);
        self.initialized = true;
        out
    }

    /// Squared distortion `||M(v) - v||^2` against a given input.
    pub fn distortion_sq(&self, v: &[f64]) -> f64 {
        linalg::dist_sq(&self.g, v)
    }

    /// Reset the state (fresh compressor).
    pub fn reset(&mut self) {
        self.g.iter_mut().for_each(|x| *x = 0.0);
        self.initialized = false;
    }

    pub fn inner(&self) -> &C {
        &self.c
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::TopK;
    use crate::theory;
    use crate::util::testing::{for_all_seeds, random_vec};

    /// Corollary 1: for a linearly convergent input sequence, the Markov
    /// compressor's distortion converges to 0 — while the plain compressor's
    /// does not (it stalls at (1-alpha)||v*||^2).
    #[test]
    fn markov_distortion_decays_on_convergent_sequence() {
        for_all_seeds(15, |rng| {
            let d = 5 + rng.next_below(40);
            let k = 1 + rng.next_below(3.min(d));
            let vstar = random_vec(rng, d, 3.0); // limit with ||v*|| > 0
            let dir = random_vec(rng, d, 1.0);
            let mut m = Markov::new(TopK::new(k), d);
            let mut last = f64::INFINITY;
            let mut v = vec![0.0; d];
            let t_max = 400;
            for t in 0..t_max {
                let decay = 0.97f64.powi(t);
                for j in 0..d {
                    v[j] = vstar[j] + decay * dir[j];
                }
                m.step(&v, rng);
                if t == t_max - 1 {
                    last = m.distortion_sq(&v);
                }
            }
            let vstar_norm = crate::util::linalg::norm2_sq(&vstar);
            assert!(
                last < 1e-6 * vstar_norm.max(1.0),
                "Markov distortion should vanish, got {last} (||v*||^2 = {vstar_norm})"
            );
            // Plain compressor on the same final input does NOT vanish
            // unless the vector is nearly k-sparse.
            let c = TopK::new(k);
            let plain = crate::compress::distortion_ratio(&c, &v, rng);
            // For a random Gaussian v* and k << d this is bounded away
            // from 0 with overwhelming probability.
            if d >= 10 && k <= 2 {
                assert!(plain > 1e-4, "plain top-k distortion unexpectedly zero: {plain}");
            }
        });
    }

    /// Lemma 1 one-step recursion: E D^{t+1} <= (1-theta) D^t + beta Delta^t
    /// (deterministic C = Top-k, so it holds pointwise).
    #[test]
    fn lemma1_single_step_recursion() {
        for_all_seeds(20, |rng| {
            let d = 4 + rng.next_below(30);
            let k = 1 + rng.next_below(d);
            let c = TopK::new(k);
            let alpha = crate::compress::Compressor::alpha(&c, d);
            let (theta, beta) = theory::theta_beta(alpha);
            let mut m = Markov::new(TopK::new(k), d);
            let v0 = random_vec(rng, d, 2.0);
            m.step(&v0, rng);
            let d0 = m.distortion_sq(&v0);
            let v1: Vec<f64> =
                v0.iter().map(|x| x + 0.3 * rng.next_normal()).collect();
            let delta = crate::util::linalg::dist_sq(&v1, &v0);
            m.step(&v1, rng);
            let d1 = m.distortion_sq(&v1);
            crate::util::testing::assert_le_approx(
                d1,
                (1.0 - theta) * d0 + beta * delta,
                1e-9,
                "Lemma 1 recursion",
            );
        });
    }

    #[test]
    fn first_step_equals_plain_compression() {
        let mut rng = Rng::seed(0);
        let v = random_vec(&mut rng, 16, 1.0);
        let mut m = Markov::new(TopK::new(4), 16);
        let delta = m.step(&v, &mut rng);
        let plain = crate::compress::Compressor::compress(&TopK::new(4), &v, &mut rng);
        assert_eq!(delta.sparse, plain.sparse);
        assert_eq!(m.estimate(), plain.sparse.to_dense(16).as_slice());
    }

    #[test]
    fn reset_clears_state() {
        let mut rng = Rng::seed(1);
        let v = random_vec(&mut rng, 8, 1.0);
        let mut m = Markov::new(TopK::new(2), 8);
        m.step(&v, &mut rng);
        assert!(crate::util::linalg::norm2_sq(m.estimate()) > 0.0);
        m.reset();
        assert_eq!(m.estimate(), vec![0.0; 8].as_slice());
    }

    /// With alpha = 1 (identity compressor) the Markov compressor tracks the
    /// input exactly from the first step.
    #[test]
    fn identity_markov_is_exact() {
        let mut rng = Rng::seed(2);
        let mut m = Markov::new(crate::compress::Identity, 6);
        for _ in 0..5 {
            let v = random_vec(&mut rng, 6, 2.0);
            m.step(&v, &mut rng);
            assert!(m.distortion_sq(&v) < 1e-24);
        }
    }
}
