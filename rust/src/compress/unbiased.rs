//! Unbiased compressors `U(omega)` (Eq. 2) and Lemma 8's scaling bridge
//! into the biased class `B(1/(1+omega))`.
//!
//! EF21's whole point is that it needs only `B(alpha)`; these exist to
//! (a) test Lemma 8 and (b) provide the unbiased comparators used in the
//! discussion of §2.2.

use super::{Compressed, Compressor};
use crate::util::rng::Rng;

/// An unbiased compressor with known variance parameter omega (Eq. 2).
pub trait UnbiasedCompressor: Send + Sync {
    fn name(&self) -> String;
    fn omega(&self, d: usize) -> f64;
    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed;

    /// Caller-buffer form of [`UnbiasedCompressor::compress`] (same
    /// contract as [`super::Compressor::compress_into`]).
    fn compress_into(&self, v: &[f64], rng: &mut Rng, out: &mut Compressed) {
        *out = self.compress(v, rng);
    }
}

/// Unbiased Rand-k: keep k random coordinates scaled by d/k.
/// `omega = d/k - 1`.
#[derive(Clone, Debug)]
pub struct RandKUnbiased {
    pub k: usize,
}

impl RandKUnbiased {
    pub fn new(k: usize) -> Self {
        assert!(k >= 1);
        RandKUnbiased { k }
    }
}

impl UnbiasedCompressor for RandKUnbiased {
    fn name(&self) -> String {
        format!("urand{}", self.k)
    }

    fn omega(&self, d: usize) -> f64 {
        (d as f64 / self.k.min(d) as f64) - 1.0
    }

    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        let mut out = Compressed::empty();
        UnbiasedCompressor::compress_into(self, v, rng, &mut out);
        out
    }

    fn compress_into(&self, v: &[f64], rng: &mut Rng, out: &mut Compressed) {
        let d = v.len();
        let k = self.k.min(d);
        let scale = d as f64 / k as f64;
        let sp = &mut out.sparse;
        if k == d {
            sp.idx.clear();
            sp.idx.extend(0..d as u32);
        } else {
            rng.sample_indices_into(d, k, &mut sp.idx);
        }
        sp.val.clear();
        sp.val.extend(sp.idx.iter().map(|&i| scale * v[i as usize]));
        out.bits = out.sparse.standard_bits();
    }
}

/// Lemma 8: if `C' ∈ U(omega)` then `(1/(1+omega)) C' ∈ B(1/(1+omega))`.
/// Wraps any unbiased compressor into the biased interface.
pub struct Scaled<U: UnbiasedCompressor> {
    pub inner: U,
}

impl<U: UnbiasedCompressor> Scaled<U> {
    pub fn new(inner: U) -> Self {
        Scaled { inner }
    }
}

impl<U: UnbiasedCompressor> Compressor for Scaled<U> {
    fn name(&self) -> String {
        format!("scaled({})", self.inner.name())
    }

    fn alpha(&self, d: usize) -> f64 {
        1.0 / (1.0 + self.inner.omega(d))
    }

    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        let mut out = Compressed::empty();
        Compressor::compress_into(self, v, rng, &mut out);
        out
    }

    fn compress_into(&self, v: &[f64], rng: &mut Rng, out: &mut Compressed) {
        self.inner.compress_into(v, rng, out);
        let scale = 1.0 / (1.0 + self.inner.omega(v.len()));
        out.sparse.scale(scale);
    }

    fn is_deterministic(&self) -> bool {
        false
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{for_all_seeds, random_vec};

    #[test]
    fn randk_unbiasedness() {
        // E[C(v)] = v empirically.
        let mut rng = Rng::seed(1);
        let d = 20;
        let v = random_vec(&mut rng, d, 1.0);
        let c = RandKUnbiased::new(4);
        let reps = 8000;
        let mut mean = vec![0.0; d];
        for _ in 0..reps {
            let out = c.compress(&v, &mut rng).sparse.to_dense(d);
            for (m, o) in mean.iter_mut().zip(&out) {
                *m += o / reps as f64;
            }
        }
        for (m, t) in mean.iter().zip(&v) {
            assert!((m - t).abs() < 0.15, "{m} vs {t}");
        }
    }

    #[test]
    fn randk_variance_bound_eq2() {
        // E||C(v)-v||^2 = (d/k - 1)||v||^2 exactly for unbiased rand-k.
        let mut rng = Rng::seed(2);
        let d = 30;
        let k = 6;
        let v = random_vec(&mut rng, d, 1.0);
        let n2: f64 = v.iter().map(|x| x * x).sum();
        let c = RandKUnbiased::new(k);
        let reps = 5000;
        let mean: f64 = (0..reps)
            .map(|_| {
                let out = c.compress(&v, &mut rng).sparse.to_dense(d);
                out.iter().zip(&v).map(|(a, b)| (a - b) * (a - b)).sum::<f64>()
            })
            .sum::<f64>()
            / reps as f64;
        let omega = c.omega(d);
        assert!((mean / n2 - omega).abs() < 0.25, "{} vs {omega}", mean / n2);
    }

    #[test]
    fn lemma8_scaled_compressor_is_contractive() {
        // Scaled unbiased rand-k must satisfy Eq. (3) with alpha=1/(1+omega)
        // in expectation.
        for_all_seeds(10, |rng| {
            let d = 4 + rng.next_below(40);
            let k = 1 + rng.next_below(d);
            let c = Scaled::new(RandKUnbiased::new(k));
            let alpha = c.alpha(d);
            assert!((alpha - k.min(d) as f64 / d as f64).abs() < 1e-12);
            let v = random_vec(rng, d, 1.5);
            let reps = 400;
            let mean: f64 = (0..reps)
                .map(|_| super::super::distortion_ratio(&c, &v, rng))
                .sum::<f64>()
                / reps as f64;
            assert!(mean <= (1.0 - alpha) * 1.15 + 1e-9, "{mean} vs {}", 1.0 - alpha);
        });
    }
}
