//! Layer-wise (block-partitioned) compression: apply an inner
//! contractive compressor independently per block of a [`BlockLayout`].
//!
//! This is how the paper's DL experiments actually compress (§5,
//! Fig. 5: Top-k per layer), and the structural prerequisite for the
//! per-layer/per-block EF21 variants of "EF21 with Bells & Whistles"
//! (Fatkhullin et al., 2021).
//!
//! Theory: if block `b` is compressed with `C_b ∈ B(alpha_b)` then the
//! composite operator is in `B(min_b alpha_b)` — blocks are orthogonal
//! coordinate subspaces, so
//! `||C(x) - x||^2 = Σ_b ||C_b(x_b) - x_b||^2 <= Σ_b (1 - alpha_b)
//! ||x_b||^2 <= (1 - min_b alpha_b) ||x||^2` — Eq. (3) still holds and
//! every EF21 stepsize rule applies unchanged with
//! `alpha = min_b alpha_b` ([`Compressor::alpha`] reports exactly that).
//!
//! Bit accounting is exact: the composite cost is the **sum** of the
//! per-block inner costs (asserted in `tests/integration_blocks.rs`).
//! Top-k / Rand-k budgets are split across blocks proportionally to
//! block length (largest-remainder, deterministic; every block keeps at
//! least one slot — the layer-wise floor of the paper's DL setup).

use super::{Compressed, Compressor, SparseVec};
use crate::blocks::BlockLayout;
use crate::util::rng::Rng;
use std::sync::Arc;

/// Applies one inner compressor per block, concatenating the per-block
/// sparse outputs (block order == ascending offsets, so the composite
/// index stream stays sorted).
pub struct BlockCompressor {
    layout: Arc<BlockLayout>,
    /// One compressor per block, in block order.
    inner: Vec<Box<dyn Compressor>>,
    /// Block-parallel fan-out width for the hot path (1 = inline). Only
    /// deterministic inners are ever fanned out — randomized ones must
    /// consume the worker RNG stream in block order.
    threads: usize,
    /// Base spec name ("top64", ...) used for telemetry keys.
    base: String,
    /// Per-block telemetry handles (`compress.<base>.<block>.ns` /
    /// `.sparsity`), resolved once on the first *enabled* apply.
    meters: Vec<std::sync::OnceLock<(crate::telemetry::Histogram, crate::telemetry::Gauge)>>,
}

/// Split a total Top-k/Rand-k budget across blocks proportionally to
/// block length: largest-remainder apportionment with a floor of one
/// slot per block, clamped to each block's dimension. Deterministic
/// (ties broken by block index) and exact:
/// `sum(budgets) == k_total.clamp(n_blocks, d)`.
pub fn split_budget(k_total: usize, layout: &BlockLayout) -> Vec<usize> {
    let d = layout.d();
    let n = layout.n_blocks();
    let k_total = k_total.clamp(n, d);
    // Start from the floor of the proportional share, but at least 1.
    let mut budgets: Vec<usize> = layout
        .specs()
        .iter()
        .map(|s| ((k_total * s.len) / d).clamp(1, s.len))
        .collect();
    let mut assigned: usize = budgets.iter().sum();
    // Distribute the remainder by largest fractional share, then index.
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by_key(|&b| {
        let s = layout.spec(b);
        // fractional part of k_total * len / d, scaled to an integer key;
        // negative for descending order.
        let rem = (k_total * s.len) % d;
        (std::cmp::Reverse(rem), b)
    });
    let mut i = 0;
    while assigned < k_total {
        let b = order[i % n];
        if budgets[b] < layout.spec(b).len {
            budgets[b] += 1;
            assigned += 1;
        }
        i += 1;
    }
    // Floors can also overshoot (many tiny blocks): trim from the
    // largest budgets, largest block last to keep proportionality.
    let mut j = n;
    while assigned > k_total {
        j -= 1;
        let b = order[j % n];
        if budgets[b] > 1 {
            budgets[b] -= 1;
            assigned -= 1;
        }
        if j == 0 {
            j = n;
        }
    }
    debug_assert_eq!(budgets.iter().sum::<usize>(), k_total);
    budgets
}

impl BlockCompressor {
    /// One inner compressor per block from a base spec string. `top<k>` /
    /// `rand<k>` budgets are [`split_budget`] across blocks; `sign` /
    /// `identity` apply per block as-is. `threads` bounds the
    /// block-parallel fan-out of [`Compressor::compress`] (deterministic
    /// inners only).
    pub fn from_spec(
        spec: &str,
        layout: Arc<BlockLayout>,
        threads: usize,
    ) -> anyhow::Result<BlockCompressor> {
        let s = spec.trim().to_ascii_lowercase();
        let n = layout.n_blocks();
        let make_k = |k: usize| -> Vec<usize> { split_budget(k, &layout) };
        let inner: Vec<Box<dyn Compressor>> = if let Some(k) = s.strip_prefix("top") {
            let k: usize = k.parse()?;
            anyhow::ensure!(k >= 1, "top-k needs k >= 1");
            make_k(k)
                .into_iter()
                .map(|kb| Box::new(super::TopK::new(kb)) as Box<dyn Compressor>)
                .collect()
        } else if let Some(k) = s.strip_prefix("rand") {
            let k: usize = k.parse()?;
            anyhow::ensure!(k >= 1, "rand-k needs k >= 1");
            make_k(k)
                .into_iter()
                .map(|kb| Box::new(super::RandK::new(kb)) as Box<dyn Compressor>)
                .collect()
        } else if s == "sign" {
            (0..n).map(|_| Box::new(super::ScaledSign) as Box<dyn Compressor>).collect()
        } else if s == "identity" || s == "none" {
            (0..n).map(|_| Box::new(super::Identity) as Box<dyn Compressor>).collect()
        } else {
            anyhow::bail!("unknown blocked compressor spec '{spec}' (top<k>|rand<k>|sign|identity)")
        };
        Ok(BlockCompressor::new(s, layout, inner, threads))
    }

    /// Assemble from explicit per-block compressors (one per block).
    pub fn new(
        base: impl Into<String>,
        layout: Arc<BlockLayout>,
        inner: Vec<Box<dyn Compressor>>,
        threads: usize,
    ) -> BlockCompressor {
        assert_eq!(inner.len(), layout.n_blocks(), "one inner compressor per block");
        let meters = (0..layout.n_blocks()).map(|_| std::sync::OnceLock::new()).collect();
        BlockCompressor { layout, inner, threads: threads.max(1), base: base.into(), meters }
    }

    pub fn layout(&self) -> &Arc<BlockLayout> {
        &self.layout
    }

    /// The per-block contraction parameters `alpha_b`.
    pub fn block_alphas(&self) -> Vec<f64> {
        self.layout
            .specs()
            .iter()
            .zip(&self.inner)
            .map(|(s, c)| c.alpha(s.len))
            .collect()
    }

    /// Compress one block (no telemetry), returning the *globally*
    /// indexed sparse output.
    fn compress_block(&self, b: usize, v: &[f64], rng: &mut Rng) -> Compressed {
        let mut out = Compressed::empty();
        self.compress_block_into(b, v, rng, &mut out);
        out
    }

    /// [`Self::compress_block`] into a reused buffer.
    fn compress_block_into(&self, b: usize, v: &[f64], rng: &mut Rng, out: &mut Compressed) {
        let spec = self.layout.spec(b);
        self.inner[b].compress_into(self.layout.slice(b, v), rng, out);
        for i in out.sparse.idx.iter_mut() {
            *i += spec.offset as u32;
        }
    }

    /// Whether [`Compressor::compress`] takes the block-parallel fan-out
    /// path (deterministic inners only; the threshold is shared with the
    /// aggregation tile).
    fn fan_out_active(&self) -> bool {
        self.threads.min(self.layout.n_blocks()) > 1
            && self.is_deterministic()
            && self.layout.d() >= crate::blocks::PAR_MIN_DIM
    }

    fn record_block(&self, b: usize, t0: Option<std::time::Instant>, out: &Compressed) {
        if let Some(t0) = t0 {
            let (ns, sparsity) = self.meters[b].get_or_init(|| {
                let name = &self.layout.spec(b).name;
                (
                    crate::telemetry::histogram(&format!("compress.{}.{name}.ns", self.base)),
                    crate::telemetry::gauge(&format!("compress.{}.{name}.sparsity", self.base)),
                )
            });
            ns.record(t0.elapsed().as_nanos() as u64);
            sparsity.set(out.sparse.nnz() as f64 / self.layout.spec(b).len.max(1) as f64);
        }
    }

    /// Concatenate per-block outputs (already globally indexed, in block
    /// order) into one message with summed bits.
    fn concat(parts: Vec<Compressed>) -> Compressed {
        let nnz: usize = parts.iter().map(|p| p.sparse.nnz()).sum();
        let mut idx = Vec::with_capacity(nnz);
        let mut val = Vec::with_capacity(nnz);
        let mut bits = 0u64;
        for p in parts {
            idx.extend(p.sparse.idx);
            val.extend(p.sparse.val);
            bits += p.bits;
        }
        Compressed { sparse: SparseVec::new(idx, val), bits }
    }
}

thread_local! {
    /// Reused per-block output buffer for the inline
    /// [`Compressor::compress_into`] path of [`BlockCompressor`].
    /// Thread-local so the shared `Arc<BlockCompressor>` stays `Sync`;
    /// the buffer is fully overwritten by every block compression, so
    /// output never depends on which thread (or prior call) used it.
    static BLOCK_SCRATCH: std::cell::Cell<Compressed> = std::cell::Cell::new(Compressed::empty());
}

impl Compressor for BlockCompressor {
    fn name(&self) -> String {
        format!("{}/b{}", self.base, self.layout.n_blocks())
    }

    /// `alpha = min_b alpha_b` — the contraction Eq. (3) certifies for
    /// the composite operator (see module docs).
    fn alpha(&self, _d: usize) -> f64 {
        self.block_alphas().into_iter().fold(1.0, f64::min)
    }

    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        assert_eq!(v.len(), self.layout.d(), "input does not match block layout");
        let n = self.layout.n_blocks();
        if self.fan_out_active() {
            // Worker × block tiling, compression half: blocks are
            // independent for deterministic inners (rng unused), and
            // results land in per-block slots, so the reassembled output
            // is identical to the inline path at any width. Shares the
            // chunked-scope harness (and threshold) with the
            // aggregation half.
            let fan_out = self.threads.min(n);
            let mut parts: Vec<Option<Compressed>> = (0..n).map(|_| None).collect();
            let items: Vec<(usize, &mut Option<Compressed>)> =
                parts.iter_mut().enumerate().collect();
            crate::blocks::run_chunked(items, fan_out, |(b, slot)| {
                let mut rng = Rng::seed(0); // unused: deterministic inners
                let t0 = crate::telemetry::maybe_now();
                let out = self.compress_block(b, v, &mut rng);
                self.record_block(b, t0, &out);
                *slot = Some(out);
            });
            return Self::concat(parts.into_iter().map(|p| p.expect("block compressed")).collect());
        }
        let mut out = Compressed::empty();
        self.compress_into(v, rng, &mut out);
        out
    }

    fn compress_into(&self, v: &[f64], rng: &mut Rng, out: &mut Compressed) {
        assert_eq!(v.len(), self.layout.d(), "input does not match block layout");
        if self.fan_out_active() {
            // The threaded tile collects per-block outputs on scoped
            // threads; buffer reuse would need per-thread pooling for no
            // gain (this path targets huge d, where compute dominates).
            *out = self.compress(v, rng);
            return;
        }
        // Inline path: block order, sharing the caller's RNG stream (the
        // order randomized inners consume it is part of the trajectory).
        // Per-block output goes through a thread-local scratch and is
        // appended to `out`, so steady-state calls allocate nothing.
        out.sparse.idx.clear();
        out.sparse.val.clear();
        out.bits = 0;
        BLOCK_SCRATCH.with(|cell| {
            let mut part = cell.take();
            for b in 0..self.layout.n_blocks() {
                let t0 = crate::telemetry::maybe_now();
                self.compress_block_into(b, v, rng, &mut part);
                self.record_block(b, t0, &part);
                out.sparse.idx.extend_from_slice(&part.sparse.idx);
                out.sparse.val.extend_from_slice(&part.sparse.val);
                out.bits += part.bits;
            }
            cell.set(part);
        });
    }

    fn is_deterministic(&self) -> bool {
        self.inner.iter().all(|c| c.is_deterministic())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{for_all_seeds, random_vec};

    fn layout(n: usize, d: usize) -> Arc<BlockLayout> {
        Arc::new(BlockLayout::equal(n, d).unwrap())
    }

    #[test]
    fn budget_split_is_exact_and_proportional() {
        let l = BlockLayout::from_named(&[
            ("a".into(), 60),
            ("b".into(), 30),
            ("c".into(), 10),
        ])
        .unwrap();
        let b = split_budget(10, &l);
        assert_eq!(b.iter().sum::<usize>(), 10);
        assert_eq!(b, vec![6, 3, 1]);
        // Floor of one slot per block even when k < n_blocks.
        let tiny = split_budget(1, &l);
        assert_eq!(tiny, vec![1, 1, 1]);
        // Clamped to d when k > d.
        let full = split_budget(1000, &l);
        assert_eq!(full, vec![60, 30, 10]);
    }

    #[test]
    fn budget_split_never_exceeds_block_len() {
        for_all_seeds(20, |rng| {
            let n = 1 + rng.next_below(6);
            let d = n + rng.next_below(80);
            let l = BlockLayout::equal(n, d).unwrap();
            let k = 1 + rng.next_below(d + 4);
            let b = split_budget(k, &l);
            assert_eq!(b.iter().sum::<usize>(), k.clamp(n, d));
            for (bi, s) in b.iter().zip(l.specs()) {
                assert!(*bi >= 1 && *bi <= s.len);
            }
        });
    }

    #[test]
    fn flat_block_topk_is_bit_identical_to_plain_topk() {
        for_all_seeds(15, |rng| {
            let d = 2 + rng.next_below(60);
            let k = 1 + rng.next_below(d);
            let v = random_vec(rng, d, 2.0);
            let plain = super::super::TopK::new(k).compress(&v, rng);
            let blocked = BlockCompressor::from_spec(
                &format!("top{k}"),
                Arc::new(BlockLayout::flat(d)),
                1,
            )
            .unwrap()
            .compress(&v, rng);
            assert_eq!(plain.sparse, blocked.sparse);
            assert_eq!(plain.bits, blocked.bits);
        });
    }

    #[test]
    fn bits_are_sum_of_per_block_costs() {
        let d = 24;
        let l = layout(3, d);
        let c = BlockCompressor::from_spec("top6", l.clone(), 1).unwrap();
        let mut rng = Rng::seed(4);
        let v = random_vec(&mut rng, d, 1.0);
        let out = c.compress(&v, &mut rng);
        let mut want_bits = 0;
        for b in 0..3 {
            want_bits += c.inner[b].compress(l.slice(b, &v), &mut rng).bits;
        }
        assert_eq!(out.bits, want_bits);
        assert_eq!(out.sparse.nnz(), 6);
    }

    #[test]
    fn alpha_is_min_over_blocks() {
        // 3 blocks of 8, top6 -> 2 per block -> alpha_b = 2/8 each.
        let c = BlockCompressor::from_spec("top6", layout(3, 24), 1).unwrap();
        assert_eq!(c.block_alphas(), vec![0.25, 0.25, 0.25]);
        assert!((c.alpha(24) - 0.25).abs() < 1e-15);
        // Uneven budgets: top4 over 3 blocks of 8 -> [2, 1, 1].
        let c = BlockCompressor::from_spec("top4", layout(3, 24), 1).unwrap();
        assert_eq!(split_budget(4, &BlockLayout::equal(3, 24).unwrap()), vec![2, 1, 1]);
        assert!((c.alpha(24) - 0.125).abs() < 1e-15);
    }

    #[test]
    fn parallel_fanout_matches_inline() {
        let d = 1 << 16;
        let l = layout(8, d);
        let mut rng = Rng::seed(7);
        let v = random_vec(&mut rng, d, 3.0);
        let seq = BlockCompressor::from_spec("top128", l.clone(), 1).unwrap();
        let par = BlockCompressor::from_spec("top128", l, 4).unwrap();
        let a = seq.compress(&v, &mut rng);
        let b = par.compress(&v, &mut rng);
        assert_eq!(a.sparse, b.sparse);
        assert_eq!(a.bits, b.bits);
    }

    #[test]
    fn randomized_inner_stays_inline_and_seed_stable() {
        let d = 40;
        let c = BlockCompressor::from_spec("rand8", layout(4, d), 8).unwrap();
        assert!(!c.is_deterministic());
        let mut rng1 = Rng::seed(9);
        let mut rng2 = Rng::seed(9);
        let v = random_vec(&mut Rng::seed(1), d, 1.0);
        let a = c.compress(&v, &mut rng1);
        let b = c.compress(&v, &mut rng2);
        assert_eq!(a.sparse, b.sparse, "same seed must give the same subset");
        assert_eq!(a.sparse.nnz(), 8);
    }

    #[test]
    fn rejects_unknown_spec_and_reports_name() {
        assert!(BlockCompressor::from_spec("bogus", layout(2, 8), 1).is_err());
        let c = BlockCompressor::from_spec("top4", layout(2, 8), 1).unwrap();
        assert_eq!(c.name(), "top4/b2");
    }
}
