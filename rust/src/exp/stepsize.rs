//! Experiment 1 — stepsize tolerance (Figure 1, and Figures 3–6 in §A.1.1).
//!
//! For a dataset and Top-k compressor, run EF, EF21, EF21+ with stepsizes
//! `{1x, 2x, 4x, ...}` of the Theorem-1 prediction. The paper's finding to
//! reproduce: EF stalls/oscillates at large multiples while EF21 and EF21+
//! keep converging, i.e. they tolerate (much) larger stepsizes.

use super::common::{mult_ladder, parallel_trials, results_dir, Objective, Problem};
use crate::algo::AlgoSpec;
use crate::metrics::FigureData;

pub struct StepsizeCfg {
    pub dataset: String,
    pub k: usize,
    pub rounds: usize,
    pub max_pow: u32,
    pub n_workers: usize,
    pub seed: u64,
    /// Trial-scheduler pool width (1 = legacy sequential sweep).
    pub threads: usize,
    /// Participation/fault schedule applied to every trial
    /// (`--participation`/`--faults`; default = legacy full rounds).
    pub sched: crate::config::SchedSpec,
}

impl Default for StepsizeCfg {
    fn default() -> Self {
        StepsizeCfg {
            dataset: "a9a".into(),
            k: 1,
            rounds: 1500,
            max_pow: 6,
            n_workers: 20,
            seed: 0,
            threads: 1,
            sched: crate::config::SchedSpec::default(),
        }
    }
}

/// Run the sweep for one (dataset, k); returns the figure data. The
/// algo × multiplier grid of independent trials fans across
/// `cfg.threads` scheduler threads; curve order (and every curve's
/// contents) is identical to the sequential sweep.
pub fn run(cfg: &StepsizeCfg) -> FigureData {
    let mut problem =
        Problem::new(&cfg.dataset, Objective::LogReg, cfg.n_workers, 0.1, cfg.seed);
    problem.sched = cfg.sched.clone();
    let comp = format!("top{}", cfg.k);
    let mut fig = FigureData::new(format!("stepsize_{}_k{}", cfg.dataset, cfg.k));
    let record_every = (cfg.rounds / 200).max(1);
    let mut jobs: Vec<(AlgoSpec, f64)> = Vec::new();
    for algo in [AlgoSpec::Ef, AlgoSpec::Ef21, AlgoSpec::Ef21Plus] {
        for &mult in &mult_ladder(cfg.max_pow) {
            jobs.push((algo, mult));
        }
    }
    let curves = parallel_trials(jobs, cfg.threads, |(algo, mult)| {
        let mut h = problem.run_trial(
            algo,
            &comp,
            mult,
            None,
            cfg.rounds,
            record_every,
            cfg.seed,
        );
        h.label = format!("{} {comp} {mult}x {}", algo.name(), cfg.dataset);
        h
    });
    for h in curves {
        fig.push(h);
    }
    fig
}

/// CLI entry: single (dataset, k) or the full §A.1.1 grid with `--all`.
pub fn main(args: &crate::config::cli::Args) -> anyhow::Result<()> {
    let out = results_dir();
    let threads = crate::config::Threads::from_args(args)?.resolve();
    let sched = crate::config::SchedSpec::from_args(args)?;
    if args.has("all") {
        // Figures 3-6 grid (trimmed k-list per dataset as in the paper).
        for ds in ["phishing", "mushrooms", "a9a", "w8a"] {
            for k in [1usize, 2, 4, 32] {
                let cfg = StepsizeCfg {
                    dataset: ds.into(),
                    k,
                    rounds: args.get_parse("rounds")?.unwrap_or(800),
                    max_pow: args.get_parse("max-pow")?.unwrap_or(5),
                    threads,
                    sched: sched.clone(),
                    ..Default::default()
                };
                let fig = run(&cfg);
                fig.print_summary();
                fig.write_dir(&out)?;
            }
        }
        return Ok(());
    }
    let cfg = StepsizeCfg {
        dataset: args.get_str("dataset").unwrap_or("a9a").to_string(),
        k: args.get_parse("k")?.unwrap_or(1),
        rounds: args.get_parse("rounds")?.unwrap_or(1500),
        max_pow: args.get_parse("max-pow")?.unwrap_or(6),
        n_workers: args.get_parse("workers")?.unwrap_or(20),
        seed: args.get_parse("seed")?.unwrap_or(0),
        threads,
        sched,
    };
    let fig = run(&cfg);
    fig.print_summary();
    fig.write_dir(&out)?;
    println!("wrote {}", out.join(&fig.name).display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::exp::common::Problem;

    /// The paper's core claim at miniature scale: at an aggressive stepsize
    /// multiple, EF21's best gradient norm beats EF's (EF oscillates).
    #[test]
    fn ef21_tolerates_larger_stepsize_than_ef() {
        let ds = synth::generate_custom("tol", 600, 16, 0.4, 1);
        let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
        let mult = 16.0;
        let h_ef = p.run_trial(AlgoSpec::Ef, "top1", mult, None, 800, 10, 0);
        let h_21 = p.run_trial(AlgoSpec::Ef21, "top1", mult, None, 800, 10, 0);
        let ef = h_ef.best_grad_norm_sq();
        let e21 = h_21.best_grad_norm_sq();
        assert!(
            e21 < ef || h_ef.diverged(),
            "EF21 ({e21:.3e}) should beat EF ({ef:.3e}) at {mult}x"
        );
    }

    /// The fanned-out sweep reproduces the sequential sweep exactly:
    /// same curve order, same records bit-for-bit.
    #[test]
    fn pooled_sweep_matches_sequential_sweep() {
        let mk = |threads| StepsizeCfg {
            dataset: "phishing".into(),
            k: 1,
            rounds: 25,
            max_pow: 1,
            n_workers: 4,
            seed: 0,
            threads,
        };
        let seq = run(&mk(1));
        let par = run(&mk(3));
        assert_eq!(seq.curves.len(), par.curves.len());
        for (a, b) in seq.curves.iter().zip(&par.curves) {
            assert_eq!(a.label, b.label);
            assert_eq!(a.records.len(), b.records.len());
            for (ra, rb) in a.records.iter().zip(&b.records) {
                assert_eq!(ra.loss.to_bits(), rb.loss.to_bits());
                assert_eq!(ra.grad_norm_sq.to_bits(), rb.grad_norm_sq.to_bits());
            }
        }
    }

    /// At the 1x theory stepsize all three methods make progress.
    #[test]
    fn all_methods_progress_at_theory_stepsize() {
        let ds = synth::generate_custom("prog", 600, 16, 0.4, 2);
        let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
        for algo in [AlgoSpec::Ef, AlgoSpec::Ef21, AlgoSpec::Ef21Plus] {
            let h = p.run_trial(algo, "top2", 1.0, None, 500, 25, 0);
            assert!(!h.diverged(), "{:?} diverged at 1x", algo);
            let first = h.records.first().unwrap().grad_norm_sq;
            let last = h.final_grad_norm_sq();
            assert!(last < first * 0.5, "{:?}: {first:.3e} -> {last:.3e}", algo);
        }
    }
}
