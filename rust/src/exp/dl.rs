//! Figures 13–15 — the deep-learning experiment (§A.3), substituted with a
//! small causal transformer LM on the synthetic token corpus (see DESIGN.md
//! §3). Gradients come from the AOT `transformer_step` artifact via PJRT —
//! the full three-layer path. Compared: EF21-SGD (Algorithm 5), EF-SGD,
//! and plain SGD, plus a k-sweep (Figure 15).

use super::common::results_dir;
use crate::algo::{AlgoSpec, BuildOpts};
use crate::compress;
use crate::config::BlocksSpec;
use crate::coordinator::runner::RunConfig;
use crate::metrics::{FigureData, History};
use crate::nn::tokens::TokenSampler;
use crate::nn::ParamLayout;
use crate::oracle::xla::XlaTransformerOracle;
use crate::oracle::GradOracle;
use crate::runtime::Runtime;
use crate::transport::downlink::DownlinkMeter;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct DlCfg {
    pub n_workers: usize,
    pub steps: usize,
    /// Top-k fraction of the parameter count (paper uses ~0.05 D).
    pub k_frac: f64,
    pub gamma: f64,
    pub noise: f64,
    pub seed: u64,
    /// Parameter partition: `auto` = the transformer's real per-layer
    /// shapes (layer-wise Top-k + delta broadcast, §5 / Fig. 5);
    /// `flat` = the legacy whole-vector path.
    pub blocks: BlocksSpec,
}

impl Default for DlCfg {
    fn default() -> Self {
        DlCfg {
            n_workers: 4,
            steps: 60,
            k_frac: 0.05,
            gamma: 0.5,
            noise: 0.1,
            seed: 0,
            blocks: BlocksSpec::Flat,
        }
    }
}

fn worker_oracles(rt: &Arc<Runtime>, cfg: &DlCfg) -> anyhow::Result<Vec<Box<dyn GradOracle>>> {
    let mut oracles: Vec<Box<dyn GradOracle>> = Vec::new();
    let entry = rt.entry("transformer_step")?;
    let vocab = entry.meta_usize("vocab")?;
    let (batch, seq) = {
        let b = entry.meta_usize("batch")?;
        let s = entry.meta_usize("seq_len")?;
        (b, s)
    };
    for i in 0..cfg.n_workers {
        let mut sampler = TokenSampler::new(vocab, cfg.noise, cfg.seed, cfg.seed * 1000 + i as u64);
        let o = XlaTransformerOracle::new(
            rt.clone(),
            Box::new(move || sampler.batch(batch, seq)),
        )?;
        oracles.push(Box::new(o));
    }
    Ok(oracles)
}

/// One training run; `eval` reports final held-out loss/accuracy.
pub fn run_one(
    rt: &Arc<Runtime>,
    cfg: &DlCfg,
    algo: AlgoSpec,
    comp_spec: &str,
    label: &str,
) -> anyhow::Result<(History, f64, f64)> {
    let entry = rt.entry("transformer_step")?.clone();
    let layout = ParamLayout::from_entry(&entry)?;
    let mut rng = Rng::seed(cfg.seed);
    let flat0 = layout.init_flat(&mut rng);
    let x0: Vec<f64> = flat0.iter().map(|&v| v as f64).collect();

    // `--blocks auto` resolves to the transformer's real per-layer
    // shapes; `flat` is the legacy whole-vector path.
    let blocks = cfg.blocks.resolve(x0.len(), Some(&layout.block_layout()))?;
    let oracles = worker_oracles(rt, cfg)?;
    let c: Arc<dyn compress::Compressor> =
        Arc::from(compress::from_spec_blocked(comp_spec, &blocks, 1)?);
    // EF21 uses the paper-sanctioned dense init g_i^0 = ∇f_i(x^0)
    // (E[G^0] = 0) — one dense message, vital at k ≈ 0.05 D.
    let opts = BuildOpts {
        layout: if blocks.is_flat() { None } else { Some(blocks.clone()) },
        threads: 1,
        full_init: algo == AlgoSpec::Ef21,
    };
    let (master, workers) =
        crate::algo::build_with(algo, x0, oracles, c, cfg.gamma, cfg.seed, &opts);
    let run_cfg = RunConfig::rounds(cfg.steps).with_label(label.to_string());
    // Capture final x through the master after the run: run_protocol owns
    // the master, so re-derive the final model from a fresh protocol run is
    // wasteful — instead we evaluate with the last broadcast implied by the
    // history. Simplest correct approach: run manually here.
    let mut master = master;
    let mut workers = workers;
    let mut history = History::new(label.to_string());
    // Downlink: dense accounting for flat, f32-floor delta for blocked —
    // the per-layer savings Fig. 5's broadcast direction leaves on the
    // table. Mirrors runner::drive's metering (same counter/gauge keys)
    // since this loop is hand-rolled.
    let mut downlink = DownlinkMeter::for_layout(blocks.clone());
    crate::telemetry::gauge(crate::telemetry::keys::BLOCKS).set(blocks.n_blocks() as f64);
    let x_first = master.x().to_vec();
    let b0 = downlink.plan(&x_first).bits;
    crate::telemetry::counter(crate::telemetry::keys::DOWNLINK_BITS).incr(b0);
    let msgs: Vec<_> = workers.iter_mut().map(|w| w.init(&x_first)).collect();
    let mut bits: u64 = msgs.iter().map(|m| m.bits()).sum();
    master.init_absorb(&msgs);
    for t in 0..cfg.steps {
        let x = master.begin_round();
        let bt = downlink.plan(&x).bits;
        crate::telemetry::counter(crate::telemetry::keys::DOWNLINK_BITS).incr(bt);
        let msgs: Vec<_> = workers.iter_mut().map(|w| w.round(&x)).collect();
        bits += msgs.iter().map(|m| m.bits()).sum::<u64>();
        master.absorb(&msgs);
        let loss =
            workers.iter().map(|w| w.last_loss()).sum::<f64>() / workers.len() as f64;
        history.records.push(crate::metrics::RoundRecord {
            round: t,
            bits_per_client: bits as f64 / cfg.n_workers as f64,
            loss,
            grad_norm_sq: f64::NAN, // dense grads too large to average here
            gt: f64::NAN,
            dcgd_frac: f64::NAN,
        });
        let _ = run_cfg;
    }
    history.downlink_bits = downlink.bits();
    if !blocks.is_flat() {
        let dense = downlink.dense_baseline_bits();
        println!(
            "{label}: downlink {} bits vs dense {} bits ({:.1}% saved, {} blocks)",
            downlink.bits(),
            dense,
            100.0 * (1.0 - downlink.bits() as f64 / dense.max(1) as f64),
            blocks.n_blocks()
        );
    }

    // Final eval on a held-out stream.
    let final_flat: Vec<f32> = master.x().iter().map(|&v| v as f32).collect();
    let entry_eval = rt.entry("transformer_eval")?;
    let vocab = entry_eval.meta_usize("vocab")?;
    let batch = entry_eval.meta_usize("batch")?;
    let seq = entry_eval.meta_usize("seq_len")?;
    let mut eval_sampler = TokenSampler::new(vocab, cfg.noise, cfg.seed, 0xEEEE);
    let mut sampler_box = {
        let mut s = TokenSampler::new(vocab, cfg.noise, cfg.seed, 0xEEEF);
        Box::new(move || s.batch(batch, seq)) as Box<dyn FnMut() -> Vec<i32> + Send>
    };
    let _ = &mut sampler_box;
    let oracle = XlaTransformerOracle::new(rt.clone(), sampler_box)?;
    let tokens = eval_sampler.batch(batch, seq);
    let (eval_loss, eval_acc) = oracle.eval(&final_flat, &tokens)?;
    Ok((history, eval_loss, eval_acc))
}

/// Figures 13–14 analogue: EF21 vs EF vs SGD at the same k and stepsize.
pub fn run_methods(rt: &Arc<Runtime>, cfg: &DlCfg) -> anyhow::Result<FigureData> {
    let entry = rt.entry("transformer_step")?;
    let n_params = entry.meta_usize("n_params")?;
    let k = ((n_params as f64 * cfg.k_frac) as usize).max(1);
    let comp = format!("top{k}");
    let mut fig = FigureData::new("dl_methods");
    for (algo, cspec, label) in [
        (AlgoSpec::Ef21, comp.as_str(), "EF21-SGD"),
        (AlgoSpec::Ef, comp.as_str(), "EF-SGD"),
        (AlgoSpec::Gd, "identity", "SGD"),
    ] {
        let (h, el, ea) = run_one(rt, cfg, algo, cspec, label)?;
        println!(
            "{label:10} final train loss {:.4}  eval loss {el:.4}  eval acc {ea:.4}",
            h.final_loss()
        );
        fig.push(h);
    }
    Ok(fig)
}

/// Figure 15 analogue: EF21 dependence on k.
pub fn run_k_sweep(rt: &Arc<Runtime>, cfg: &DlCfg, fracs: &[f64]) -> anyhow::Result<FigureData> {
    let entry = rt.entry("transformer_step")?;
    let n_params = entry.meta_usize("n_params")?;
    let mut fig = FigureData::new("dl_ksweep");
    for &f in fracs {
        let k = ((n_params as f64 * f) as usize).max(1);
        let label = format!("EF21-SGD k={:.3}D", f);
        let (h, el, ea) = run_one(rt, cfg, AlgoSpec::Ef21, &format!("top{k}"), &label)?;
        println!(
            "{label:18} final train loss {:.4}  eval loss {el:.4}  eval acc {ea:.4}",
            h.final_loss()
        );
        fig.push(h);
    }
    Ok(fig)
}

pub fn main(args: &crate::config::cli::Args) -> anyhow::Result<()> {
    let rt = Arc::new(Runtime::from_default_dir()?);
    let cfg = DlCfg {
        n_workers: args.get_parse("workers")?.unwrap_or(4),
        steps: args.get_parse("steps")?.unwrap_or(60),
        k_frac: args.get_parse("k-frac")?.unwrap_or(0.05),
        gamma: args.get_parse("gamma")?.unwrap_or(0.5),
        noise: args.get_parse("noise")?.unwrap_or(0.1),
        seed: args.get_parse("seed")?.unwrap_or(0),
        blocks: BlocksSpec::from_args(args)?,
    };
    let out = results_dir();
    if args.has("sweep-k") {
        let fig = run_k_sweep(&rt, &cfg, &[0.01, 0.05, 0.2])?;
        fig.print_summary();
        fig.write_dir(&out)?;
    } else {
        let fig = run_methods(&rt, &cfg)?;
        fig.print_summary();
        fig.write_dir(&out)?;
    }
    Ok(())
}
