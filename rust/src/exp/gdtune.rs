//! Figure 8 — GD stepsize tuning: distributed GD at multiples of 1/L,
//! the reference curves behind Figures 2/7's "GD (tuned)" line.

use super::common::{mult_ladder, parallel_trials, results_dir, Objective, Problem};
use crate::algo::AlgoSpec;
use crate::metrics::FigureData;

pub fn run(dataset: &str, rounds: usize, max_pow: u32, seed: u64, threads: usize) -> FigureData {
    run_sched(dataset, rounds, max_pow, seed, threads, crate::config::SchedSpec::default())
}

/// [`run`] under a participation/fault schedule.
pub fn run_sched(
    dataset: &str,
    rounds: usize,
    max_pow: u32,
    seed: u64,
    threads: usize,
    sched: crate::config::SchedSpec,
) -> FigureData {
    let mut problem = Problem::new(dataset, Objective::LogReg, 20, 0.1, seed);
    problem.sched = sched;
    let record_every = (rounds / 300).max(1);
    let mut fig = FigureData::new(format!("gdtune_{dataset}"));
    let curves = parallel_trials(mult_ladder(max_pow), threads, |m| {
        let mut h =
            problem.run_trial(AlgoSpec::Gd, "identity", m, None, rounds, record_every, seed);
        h.label = format!("GD {m}x");
        h
    });
    for h in curves {
        fig.push(h);
    }
    fig
}

pub fn main(args: &crate::config::cli::Args) -> anyhow::Result<()> {
    let fig = run_sched(
        args.get_str("dataset").unwrap_or("a9a"),
        args.get_parse("rounds")?.unwrap_or(1000),
        args.get_parse("max-pow")?.unwrap_or(4),
        args.get_parse("seed")?.unwrap_or(0),
        crate::config::Threads::from_args(args)?.resolve(),
        crate::config::SchedSpec::from_args(args)?,
    );
    fig.print_summary();
    fig.write_dir(&results_dir())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::exp::common::Problem;

    /// GD at the 1x theory stepsize (gamma = 1/L with alpha = 1) descends
    /// monotonically in f (the classical guarantee).
    #[test]
    fn gd_descends_monotonically_at_1x() {
        let ds = synth::generate_custom("gdt", 400, 10, 0.4, 2);
        let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
        let h = p.run_trial(AlgoSpec::Gd, "identity", 1.0, None, 200, 1, 0);
        for w in h.records.windows(2) {
            assert!(
                w[1].loss <= w[0].loss + 1e-12,
                "GD ascended: {} -> {}",
                w[0].loss,
                w[1].loss
            );
        }
    }
}
