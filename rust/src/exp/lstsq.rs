//! Figures 9–12 — least-squares (PL) stepsize tolerance (§A.2): same
//! protocol as the logistic experiment but on the PL objective with the
//! Theorem-2 stepsize. Paper's finding: EF21/EF21+ tolerate far larger
//! multiples (the paper pushes to 512x–4096x before EF-like oscillation).

use super::common::{mult_ladder, parallel_trials, results_dir, Objective, Problem};
use crate::algo::AlgoSpec;
use crate::metrics::FigureData;

pub struct LstsqCfg {
    pub dataset: String,
    pub k: usize,
    pub rounds: usize,
    pub max_pow: u32,
    pub n_workers: usize,
    pub seed: u64,
    /// Trial-scheduler pool width (1 = legacy sequential sweep).
    pub threads: usize,
    /// Participation/fault schedule applied to every trial.
    pub sched: crate::config::SchedSpec,
}

impl Default for LstsqCfg {
    fn default() -> Self {
        LstsqCfg {
            dataset: "a9a".into(),
            k: 1,
            rounds: 1500,
            max_pow: 6,
            n_workers: 20,
            seed: 0,
            threads: 1,
            sched: crate::config::SchedSpec::default(),
        }
    }
}

pub fn run(cfg: &LstsqCfg) -> FigureData {
    let mut problem =
        Problem::new(&cfg.dataset, Objective::Lstsq, cfg.n_workers, 0.0, cfg.seed);
    problem.sched = cfg.sched.clone();
    let comp = format!("top{}", cfg.k);
    let record_every = (cfg.rounds / 200).max(1);
    let mut fig = FigureData::new(format!("lstsq_{}_k{}", cfg.dataset, cfg.k));
    let mut jobs: Vec<(AlgoSpec, f64)> = Vec::new();
    for algo in [AlgoSpec::Ef, AlgoSpec::Ef21, AlgoSpec::Ef21Plus] {
        for &m in &mult_ladder(cfg.max_pow) {
            jobs.push((algo, m));
        }
    }
    let curves = parallel_trials(jobs, cfg.threads, |(algo, m)| {
        let mut h =
            problem.run_trial(algo, &comp, m, None, cfg.rounds, record_every, cfg.seed);
        h.label = format!("{} {comp} {m}x {} (PL)", algo.name(), cfg.dataset);
        h
    });
    for h in curves {
        fig.push(h);
    }
    fig
}

pub fn main(args: &crate::config::cli::Args) -> anyhow::Result<()> {
    let out = results_dir();
    let datasets: Vec<String> = match args.get_str("dataset") {
        Some(d) => vec![d.to_string()],
        None => vec!["phishing".into(), "mushrooms".into(), "a9a".into(), "w8a".into()],
    };
    let threads = crate::config::Threads::from_args(args)?.resolve();
    let sched = crate::config::SchedSpec::from_args(args)?;
    for ds in datasets {
        let cfg = LstsqCfg {
            dataset: ds,
            k: args.get_parse("k")?.unwrap_or(1),
            rounds: args.get_parse("rounds")?.unwrap_or(1000),
            max_pow: args.get_parse("max-pow")?.unwrap_or(6),
            threads,
            sched: sched.clone(),
            ..Default::default()
        };
        let fig = run(&cfg);
        fig.print_summary();
        fig.write_dir(&out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    /// Linear convergence under PL at the Theorem-2 stepsize: loss gap
    /// shrinks geometrically for EF21.
    #[test]
    fn ef21_converges_linearly_on_least_squares() {
        let ds = synth::generate_custom("pl", 400, 8, 0.6, 3);
        let p = Problem::from_dataset(ds, Objective::Lstsq, 4, 0.0);
        assert!(p.mu.unwrap() > 0.0, "need full-rank data for PL");
        let h = p.run_trial(AlgoSpec::Ef21, "top2", 1.0, None, 4000, 40, 0);
        assert!(!h.diverged());
        let n = h.records.len();
        let early = h.records[n / 4].grad_norm_sq;
        let late = h.records[n - 1].grad_norm_sq;
        assert!(
            late < early * 1e-3,
            "not linear-looking: {early:.3e} -> {late:.3e}"
        );
    }

    /// EF21 tolerates a stepsize multiple on the PL problem that breaks EF.
    #[test]
    fn ef21_outlasts_ef_at_large_multiples_pl() {
        let ds = synth::generate_custom("pl2", 400, 8, 0.6, 4);
        let p = Problem::from_dataset(ds, Objective::Lstsq, 4, 0.0);
        let mult = 64.0;
        let h_ef = p.run_trial(AlgoSpec::Ef, "top1", mult, None, 1500, 15, 0);
        let h21 = p.run_trial(AlgoSpec::Ef21, "top1", mult, None, 1500, 15, 0);
        assert!(
            h21.best_grad_norm_sq() < h_ef.best_grad_norm_sq() || h_ef.diverged(),
            "EF21 {:.3e} vs EF {:.3e}",
            h21.best_grad_norm_sq(),
            h_ef.best_grad_norm_sq()
        );
    }
}
