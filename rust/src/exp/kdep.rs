//! Figure 7 — effect of the Top-k parameter k on communication efficiency
//! (tuned stepsize per k). Paper's finding: small k (1, 2, 4) is the most
//! bits-efficient; k = d (GD-like) is the worst.

use super::common::{parallel_trials, results_dir, Objective, Problem};
use crate::algo::AlgoSpec;
use crate::metrics::FigureData;

pub struct KdepCfg {
    pub dataset: String,
    pub rounds: usize,
    pub ks: Vec<usize>,
    pub mults: Vec<f64>,
    pub n_workers: usize,
    pub seed: u64,
    /// Trial-scheduler pool width (1 = legacy sequential sweep).
    pub threads: usize,
    /// Participation/fault schedule applied to every trial.
    pub sched: crate::config::SchedSpec,
}

impl Default for KdepCfg {
    fn default() -> Self {
        KdepCfg {
            dataset: "a9a".into(),
            rounds: 1500,
            ks: vec![1, 2, 4, 8, 32],
            mults: vec![1.0, 4.0, 16.0],
            n_workers: 20,
            seed: 0,
            threads: 1,
            sched: crate::config::SchedSpec::default(),
        }
    }
}

pub fn run(cfg: &KdepCfg) -> FigureData {
    let mut problem =
        Problem::new(&cfg.dataset, Objective::LogReg, cfg.n_workers, 0.1, cfg.seed);
    problem.sched = cfg.sched.clone();
    let record_every = (cfg.rounds / 300).max(1);
    let mut fig = FigureData::new(format!("kdep_{}", cfg.dataset));
    let d = problem.d();
    let mut ks = cfg.ks.clone();
    ks.push(d); // k = d reference
    let jobs: Vec<(usize, f64)> = ks
        .iter()
        .flat_map(|&k| cfg.mults.iter().map(move |&m| (k.min(d), m)))
        .collect();
    let results = parallel_trials(jobs, cfg.threads, |(k, m)| {
        let mut h = problem.run_trial(
            AlgoSpec::Ef21,
            &format!("top{k}"),
            m,
            None,
            cfg.rounds,
            record_every,
            cfg.seed,
        );
        h.label = format!("EF21 top{k} {m}x");
        h
    });
    // Tune the multiplier by final gradient norm, folding candidates in
    // the legacy (k outer, m inner) order.
    let mut results = results.into_iter();
    for _k in &ks {
        let mut best: Option<crate::metrics::History> = None;
        for h in results.by_ref().take(cfg.mults.len()) {
            let better = best
                .as_ref()
                .map(|b| h.final_grad_norm_sq() < b.final_grad_norm_sq() && !h.diverged())
                .unwrap_or(true);
            if better {
                best = Some(h);
            }
        }
        fig.push(best.unwrap());
    }
    fig
}

pub fn main(args: &crate::config::cli::Args) -> anyhow::Result<()> {
    let cfg = KdepCfg {
        dataset: args.get_str("dataset").unwrap_or("a9a").to_string(),
        rounds: args.get_parse("rounds")?.unwrap_or(1500),
        threads: crate::config::Threads::from_args(args)?.resolve(),
        sched: crate::config::SchedSpec::from_args(args)?,
        ..Default::default()
    };
    let fig = run(&cfg);
    fig.print_summary();
    fig.write_dir(&results_dir())?;
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    /// Small k reaches a given tolerance with fewer bits than k = d.
    #[test]
    fn small_k_is_more_bit_efficient_than_full() {
        let ds = synth::generate_custom("kd", 500, 16, 0.4, 7);
        let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
        let tol = 1e-5;
        let h_small = p.run_trial(AlgoSpec::Ef21, "top2", 4.0, None, 4000, 5, 0);
        let h_full = p.run_trial(AlgoSpec::Ef21, "top16", 1.0, None, 4000, 5, 0);
        let (bs, bf) = (h_small.bits_to_tolerance(tol), h_full.bits_to_tolerance(tol));
        assert!(bs.is_some(), "top2 never converged");
        if let (Some(bs), Some(bf)) = (bs, bf) {
            assert!(bs < bf, "top2 {bs:.3e} bits !< top-d {bf:.3e} bits");
        }
    }
}
