//! Experiment 2 — fine-tuned k and stepsizes (Figure 2; Figure 7's
//! companion). For each method, grid-search (k, gamma multiplier), pick the
//! configuration reaching the gradient tolerance with the fewest bits per
//! client, then emit the winning curves together with the GD reference.
//! The paper's finding: EF21/EF21+ beat EF in bits-to-accuracy, and GD is
//! worst.

use super::common::{parallel_trials, results_dir, Objective, Problem};
use crate::algo::AlgoSpec;
use crate::metrics::{FigureData, History};

pub struct FinetuneCfg {
    pub dataset: String,
    pub rounds: usize,
    pub ks: Vec<usize>,
    pub mults: Vec<f64>,
    pub tol: f64,
    pub n_workers: usize,
    pub seed: u64,
    /// Trial-scheduler pool width (1 = legacy sequential sweep).
    pub threads: usize,
    /// Participation/fault schedule applied to every trial.
    pub sched: crate::config::SchedSpec,
}

impl Default for FinetuneCfg {
    fn default() -> Self {
        FinetuneCfg {
            dataset: "a9a".into(),
            rounds: 1500,
            ks: vec![1, 2, 4],
            mults: vec![1.0, 4.0, 16.0, 64.0],
            tol: 1e-6,
            n_workers: 20,
            seed: 0,
            threads: 1,
            sched: crate::config::SchedSpec::default(),
        }
    }
}

/// Score a history: bits/client to tolerance, falling back to final grad
/// norm (so never-converged configs rank below any converged one).
fn score(h: &History, tol: f64) -> (bool, f64) {
    match h.bits_to_tolerance(tol) {
        Some(b) => (true, b),
        None => (false, h.final_grad_norm_sq()),
    }
}

/// Strictly-better fold matching the legacy sequential selection: a
/// converged config beats any non-converged one; ties broken by score,
/// first-seen wins.
fn pick_best(candidates: Vec<(History, (bool, f64))>) -> History {
    let mut best: Option<(History, (bool, f64))> = None;
    for (h, s) in candidates {
        let better = match &best {
            None => true,
            Some((_, bs)) => match (s.0, bs.0) {
                (true, false) => true,
                (false, true) => false,
                _ => s.1 < bs.1,
            },
        };
        if better {
            best = Some((h, s));
        }
    }
    best.expect("at least one config ran").0
}

pub fn run(cfg: &FinetuneCfg) -> FigureData {
    let mut problem =
        Problem::new(&cfg.dataset, Objective::LogReg, cfg.n_workers, 0.1, cfg.seed);
    problem.sched = cfg.sched.clone();
    let record_every = (cfg.rounds / 400).max(1);
    let mut fig = FigureData::new(format!("finetune_{}", cfg.dataset));

    // Full grid — every (algo, k, m) cell plus the GD multipliers — as
    // one flat job list; each trial is independent, so the scheduler can
    // fan them all out while the per-algo selection fold below still
    // sees candidates in the legacy (k outer, m inner) order.
    let algos = [AlgoSpec::Ef, AlgoSpec::Ef21, AlgoSpec::Ef21Plus];
    let mut jobs: Vec<(AlgoSpec, Option<usize>, f64)> = Vec::new();
    for algo in algos {
        for &k in &cfg.ks {
            for &m in &cfg.mults {
                jobs.push((algo, Some(k), m));
            }
        }
    }
    for &m in &cfg.mults {
        jobs.push((AlgoSpec::Gd, None, m));
    }

    let results = parallel_trials(jobs, cfg.threads, |(algo, k, m)| {
        let comp = match k {
            Some(k) => format!("top{k}"),
            None => "identity".to_string(),
        };
        let mut h =
            problem.run_trial(algo, &comp, m, None, cfg.rounds, record_every, cfg.seed);
        h.label = match k {
            Some(k) => format!("{} top{k} {m}x (tuned)", algo.name()),
            None => format!("GD {m}x (tuned)"),
        };
        let s = score(&h, cfg.tol);
        (h, s)
    });

    let per_algo = cfg.ks.len() * cfg.mults.len();
    let mut results = results.into_iter();
    for _algo in algos {
        fig.push(pick_best(results.by_ref().take(per_algo).collect()));
    }
    // GD reference: tuned multiplier, k = d (identity).
    fig.push(pick_best(results.collect()));
    fig
}

pub fn main(args: &crate::config::cli::Args) -> anyhow::Result<()> {
    let out = results_dir();
    let datasets: Vec<String> = match args.get_str("dataset") {
        Some(d) => vec![d.to_string()],
        None => ["phishing", "mushrooms", "a9a", "w8a"]
            .iter()
            .map(|s| s.to_string())
            .collect(),
    };
    let threads = crate::config::Threads::from_args(args)?.resolve();
    let sched = crate::config::SchedSpec::from_args(args)?;
    for ds in datasets {
        let cfg = FinetuneCfg {
            dataset: ds,
            rounds: args.get_parse("rounds")?.unwrap_or(1200),
            tol: args.get_parse("tol")?.unwrap_or(1e-6),
            threads,
            sched: sched.clone(),
            ..Default::default()
        };
        let fig = run(&cfg);
        fig.print_summary();
        fig.write_dir(&out)?;
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    /// Miniature Figure-2 shape check: tuned EF21 needs no more bits than
    /// tuned GD to reach the tolerance (compression wins).
    #[test]
    fn tuned_ef21_beats_gd_in_bits() {
        let ds = synth::generate_custom("ft", 500, 12, 0.4, 5);
        let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
        let tol = 1e-5;
        let h21 = p.run_trial(AlgoSpec::Ef21, "top2", 4.0, None, 3000, 5, 0);
        let hgd = p.run_trial(AlgoSpec::Gd, "identity", 1.0, None, 3000, 5, 0);
        let b21 = h21.bits_to_tolerance(tol);
        let bgd = hgd.bits_to_tolerance(tol);
        assert!(b21.is_some(), "EF21 never reached tol");
        if let (Some(b21), Some(bgd)) = (b21, bgd) {
            assert!(b21 < bgd, "EF21 bits {b21:.3e} !< GD bits {bgd:.3e}");
        }
    }

    #[test]
    fn score_prefers_converged() {
        let mut a = History::new("a");
        a.records.push(crate::metrics::RoundRecord {
            round: 0,
            bits_per_client: 100.0,
            loss: 1.0,
            grad_norm_sq: 1e-9,
            gt: f64::NAN,
            dcgd_frac: f64::NAN,
        });
        let (conv, s) = score(&a, 1e-6);
        assert!(conv && s == 100.0);
    }
}
