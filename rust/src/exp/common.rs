//! Shared experiment plumbing: problem construction (dataset + shards +
//! smoothness/PL constants) and single-trial execution with theory-derived
//! stepsizes, exactly as §5 does ("stepsize set to a multiple of the
//! largest stepsize predicted by our theory").

use crate::algo::{AlgoSpec, BuildOpts};
use crate::blocks::BlockLayout;
use crate::compress;
use crate::coordinator::par::run_protocol_par_ckpt;
use crate::coordinator::runner::{CkptOptions, RunConfig};
use crate::data::{partition, synth, Dataset};
use crate::metrics::History;
use crate::oracle::{GradOracle, LogRegOracle, LstsqOracle};
use crate::theory::{self, Smoothness};
use std::path::PathBuf;
use std::sync::Arc;

/// Which objective family (paper §5 vs §A.2).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Objective {
    /// Nonconvex-regularized logistic regression, Eq. (19).
    LogReg,
    /// Least squares (PL), §A.2.
    Lstsq,
}

/// A fully-prepared distributed problem instance.
pub struct Problem {
    pub dataset: Dataset,
    pub objective: Objective,
    pub n_workers: usize,
    pub lam: f64,
    pub smoothness: Smoothness,
    /// PL constant (least squares only).
    pub mu: Option<f64>,
    /// Round participation/fault schedule applied to every trial run on
    /// this problem (default = legacy full participation). The concrete
    /// scheduler is built per trial from `(n_workers, trial seed)`, so
    /// sweeps stay reproducible run-to-run.
    pub sched: crate::config::SchedSpec,
}

impl Problem {
    /// Build a problem over a (real or synthetic) Table-3 dataset, compute
    /// per-shard smoothness constants, and (for least squares) the PL
    /// constant.
    pub fn new(name: &str, objective: Objective, n_workers: usize, lam: f64, seed: u64) -> Problem {
        let dataset = synth::load_or_generate(name, &PathBuf::from("data"), seed);
        Self::from_dataset(dataset, objective, n_workers, lam)
    }

    pub fn from_dataset(
        dataset: Dataset,
        objective: Objective,
        n_workers: usize,
        lam: f64,
    ) -> Problem {
        let shards = partition::shards(&dataset, n_workers);
        let l_i: Vec<f64> = shards
            .iter()
            .map(|s| match objective {
                Objective::LogReg => theory::logreg_l(s.a, s.n, s.d, lam),
                Objective::Lstsq => theory::lstsq_l(s.a, s.n, s.d),
            })
            .collect();
        // Global L from the full matrix (tighter than mean of L_i).
        let l_full = match objective {
            Objective::LogReg => theory::logreg_l(&dataset.a, dataset.n, dataset.d, lam),
            Objective::Lstsq => theory::lstsq_l(&dataset.a, dataset.n, dataset.d),
        };
        let smoothness = Smoothness::from_l_i(l_i, l_full);
        let mu = match objective {
            Objective::Lstsq => {
                Some(theory::lstsq_pl_mu(&dataset.a, dataset.n, dataset.d))
            }
            Objective::LogReg => None,
        };
        Problem {
            dataset,
            objective,
            n_workers,
            lam,
            smoothness,
            mu,
            sched: crate::config::SchedSpec::default(),
        }
    }

    pub fn d(&self) -> usize {
        self.dataset.d
    }

    /// Fresh per-worker oracles (pure-Rust backend).
    pub fn oracles(&self) -> Vec<Box<dyn GradOracle>> {
        partition::shards(&self.dataset, self.n_workers)
            .into_iter()
            .map(|s| match self.objective {
                Objective::LogReg => {
                    Box::new(LogRegOracle::new(s, self.lam)) as Box<dyn GradOracle>
                }
                Objective::Lstsq => Box::new(LstsqOracle::new(s)) as Box<dyn GradOracle>,
            })
            .collect()
    }

    /// The largest theory-predicted stepsize for a compressor with
    /// contraction `alpha` (Theorem 1, or Theorem 2 when PL applies).
    pub fn theory_gamma(&self, alpha: f64) -> f64 {
        match (self.objective, self.mu) {
            (Objective::Lstsq, Some(mu)) if mu > 0.0 => {
                theory::stepsize_theorem2(self.smoothness.l, self.smoothness.l_tilde, alpha, mu)
            }
            _ => theory::stepsize_theorem1(self.smoothness.l, self.smoothness.l_tilde, alpha),
        }
    }

    /// Run one trial: `algo` with compressor `comp_spec`, stepsize =
    /// `gamma_mult x` theory (or `gamma_abs` if given). Sequential
    /// legacy path; see [`Self::run_trial_threads`].
    #[allow(clippy::too_many_arguments)]
    pub fn run_trial(
        &self,
        algo: AlgoSpec,
        comp_spec: &str,
        gamma_mult: f64,
        gamma_abs: Option<f64>,
        rounds: usize,
        record_every: usize,
        seed: u64,
    ) -> History {
        self.run_trial_threads(algo, comp_spec, gamma_mult, gamma_abs, rounds, record_every, seed, 1)
    }

    /// [`Self::run_trial`] with the per-round worker pool fanned across
    /// `threads` pool threads ([`crate::coordinator::par`]); `1` is the
    /// exact sequential path and the result is bit-identical either way
    /// for deterministic algorithms.
    #[allow(clippy::too_many_arguments)]
    pub fn run_trial_threads(
        &self,
        algo: AlgoSpec,
        comp_spec: &str,
        gamma_mult: f64,
        gamma_abs: Option<f64>,
        rounds: usize,
        record_every: usize,
        seed: u64,
        threads: usize,
    ) -> History {
        let layout = Arc::new(BlockLayout::flat(self.d()));
        self.run_trial_blocked(
            algo,
            comp_spec,
            gamma_mult,
            gamma_abs,
            rounds,
            record_every,
            seed,
            threads,
            layout,
        )
    }

    /// The oracles' natural block partition, straight from the oracle
    /// hook ([`crate::oracle::GradOracle::block_layout`]) so there is
    /// one source of truth: the Table-3 objectives report a flat layout
    /// (`--blocks auto` on these problems = legacy path), while
    /// structured oracles (the DL transformer) report real per-layer
    /// shapes. Only the first shard's oracle is materialized — the
    /// layout is a per-objective property, not per worker.
    pub fn block_layout(&self) -> BlockLayout {
        let mut shards = partition::shards(&self.dataset, self.n_workers);
        if shards.is_empty() {
            return BlockLayout::flat(self.d());
        }
        let s = shards.remove(0);
        match self.objective {
            Objective::LogReg => LogRegOracle::new(s, self.lam).block_layout(),
            Objective::Lstsq => LstsqOracle::new(s).block_layout(),
        }
    }

    /// [`Self::run_trial_threads`] over an explicit block layout: the
    /// compressor becomes layer-wise ([`compress::from_spec_blocked`],
    /// per-block budgets, `alpha = min_b alpha_b`), algorithm state and
    /// master aggregation go per block, and the downlink meter switches
    /// to f32-floor delta accounting. A flat layout is the exact legacy
    /// path, bit for bit.
    #[allow(clippy::too_many_arguments)]
    pub fn run_trial_blocked(
        &self,
        algo: AlgoSpec,
        comp_spec: &str,
        gamma_mult: f64,
        gamma_abs: Option<f64>,
        rounds: usize,
        record_every: usize,
        seed: u64,
        threads: usize,
        layout: Arc<BlockLayout>,
    ) -> History {
        self.run_trial_ckpt(
            algo,
            comp_spec,
            gamma_mult,
            gamma_abs,
            rounds,
            record_every,
            seed,
            threads,
            layout,
            CkptOptions::default(),
        )
        .unwrap_or_else(|e| panic!("run_trial: {e:#}"))
    }

    /// [`Self::run_trial_blocked`] with checkpoint/resume options.
    /// Fallible: checkpoint IO, a resume/config mismatch, a bad
    /// compressor or schedule spec, or a scheduled `killmaster@r` fault
    /// all surface as errors instead of panics.
    #[allow(clippy::too_many_arguments)]
    pub fn run_trial_ckpt(
        &self,
        algo: AlgoSpec,
        comp_spec: &str,
        gamma_mult: f64,
        gamma_abs: Option<f64>,
        rounds: usize,
        record_every: usize,
        seed: u64,
        threads: usize,
        layout: Arc<BlockLayout>,
        opts: CkptOptions,
    ) -> anyhow::Result<History> {
        // The worker pool owns the `threads` budget: with several workers
        // per round already fanned across pool threads, a per-compress
        // block fan-out on top would oversubscribe to ~threads^2 scoped
        // threads (block-parallel compression is a library-level knob for
        // single-compressor workloads — see bench_round's comparison).
        let c: Arc<dyn compress::Compressor> =
            Arc::from(compress::from_spec_blocked(comp_spec, &layout, 1)?);
        let alpha = c.alpha(self.d());
        let gamma = gamma_abs.unwrap_or_else(|| gamma_mult * self.theory_gamma(alpha));
        let x0 = vec![0.0; self.d()];
        let build_opts = BuildOpts {
            layout: if layout.is_flat() { None } else { Some(layout.clone()) },
            threads,
            full_init: false,
        };
        let (master, workers) =
            crate::algo::build_with(algo, x0, self.oracles(), c, gamma, seed, &build_opts);
        let label = format!("{} {} {gamma_mult}x", algo.name(), comp_spec);
        let mut cfg = RunConfig::rounds(rounds)
            .with_label(label)
            .with_record_every(record_every);
        if !layout.is_flat() {
            cfg = cfg.with_layout(layout);
        }
        if let Some(sched) = self.sched.build(self.n_workers, seed)? {
            cfg = cfg.with_sched(sched);
        }
        cfg.divergence_cap = 1e60;
        run_protocol_par_ckpt(master, workers, &cfg, threads, opts)
    }

    /// Evaluate the exact global loss and squared gradient norm at `x`
    /// with fresh oracles — the PP sweeps report this instead of the
    /// in-run observation, whose per-worker gradients go stale for
    /// workers that sat out the final rounds.
    pub fn eval_at(&self, x: &[f64]) -> (f64, f64) {
        let mut loss = 0.0;
        let mut grad = vec![0.0; self.d()];
        let inv_n = 1.0 / self.n_workers as f64;
        for mut o in self.oracles() {
            let (l, g) = o.loss_grad(x);
            loss += l * inv_n;
            crate::util::linalg::axpy(inv_n, &g, &mut grad);
        }
        (loss, crate::util::linalg::norm2_sq(&grad))
    }
}

/// Fan independent sweep trials across a bounded thread pool, returning
/// results **in input order** (so figure curve files, tuned-config
/// selection folds, and console summaries are invariant to scheduling).
///
/// `threads <= 1` runs inline on the caller — the exact legacy path.
/// Trials must be independent (each builds its own oracles/nodes, as
/// [`Problem::run_trial`] does), which is what makes order-preserved
/// fan-out result-identical to the sequential sweep. A panicking trial
/// propagates out of the scope, like it would sequentially.
pub fn parallel_trials<J, O, F>(jobs: Vec<J>, threads: usize, run: F) -> Vec<O>
where
    J: Send,
    O: Send,
    F: Fn(J) -> O + Sync,
{
    if threads <= 1 || jobs.len() <= 1 {
        return jobs.into_iter().map(run).collect();
    }
    let n_jobs = jobs.len();
    let queue: std::sync::Mutex<std::collections::VecDeque<(usize, J)>> =
        std::sync::Mutex::new(jobs.into_iter().enumerate().collect());
    let results: std::sync::Mutex<Vec<Option<O>>> =
        std::sync::Mutex::new((0..n_jobs).map(|_| None).collect());
    std::thread::scope(|scope| {
        for _ in 0..threads.min(n_jobs) {
            scope.spawn(|| loop {
                // Pop under the lock, run outside it.
                let job = queue.lock().unwrap().pop_front();
                match job {
                    Some((i, j)) => {
                        let out = run(j);
                        results.lock().unwrap()[i] = Some(out);
                    }
                    None => break,
                }
            });
        }
    });
    results
        .into_inner()
        .unwrap()
        .into_iter()
        .map(|o| o.expect("every queued trial completes"))
        .collect()
}

/// Results directory (override with $EF21_RESULTS).
pub fn results_dir() -> PathBuf {
    std::env::var_os("EF21_RESULTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("results"))
}

/// Standard stepsize-multiplier ladder used across the stepsize-tolerance
/// experiments (powers of two, as in §A.1.1).
pub fn mult_ladder(max_pow: u32) -> Vec<f64> {
    (0..=max_pow).map(|p| (1u64 << p) as f64).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny_problem(obj: Objective) -> Problem {
        let ds = synth::generate_custom("tiny", 400, 12, 0.4, 3);
        Problem::from_dataset(ds, obj, 4, 0.1)
    }

    #[test]
    fn constants_are_positive_and_consistent() {
        let p = tiny_problem(Objective::LogReg);
        assert_eq!(p.smoothness.l_i.len(), 4);
        assert!(p.smoothness.l > 0.0);
        assert!(p.smoothness.l_tilde >= p.smoothness.l_i.iter().sum::<f64>() / 4.0 - 1e-9);
        assert!(p.mu.is_none());
        let pl = tiny_problem(Objective::Lstsq);
        assert!(pl.mu.unwrap() >= 0.0);
    }

    #[test]
    fn theory_gamma_monotone_in_alpha() {
        let p = tiny_problem(Objective::LogReg);
        assert!(p.theory_gamma(0.05) < p.theory_gamma(0.5));
        assert!(p.theory_gamma(1.0) > 0.0);
    }

    #[test]
    fn trial_runs_and_converges_toward_stationarity() {
        let p = tiny_problem(Objective::LogReg);
        let h = p.run_trial(crate::algo::AlgoSpec::Ef21, "top1", 1.0, None, 400, 10, 0);
        assert!(!h.diverged());
        let first = h.records.first().unwrap().grad_norm_sq;
        let last = h.records.last().unwrap().grad_norm_sq;
        assert!(last < first, "no progress: {first} -> {last}");
    }

    #[test]
    fn mult_ladder_is_powers_of_two() {
        assert_eq!(mult_ladder(3), vec![1.0, 2.0, 4.0, 8.0]);
    }

    #[test]
    fn parallel_trials_preserves_input_order() {
        let jobs: Vec<usize> = (0..23).collect();
        let seq = parallel_trials(jobs.clone(), 1, |j| j * j);
        let par = parallel_trials(jobs, 4, |j| j * j);
        assert_eq!(seq, par);
        assert_eq!(par[7], 49);
    }

    #[test]
    fn pooled_trial_is_bit_identical_to_sequential() {
        let p = tiny_problem(Objective::LogReg);
        let h1 = p.run_trial(crate::algo::AlgoSpec::Ef21, "top1", 1.0, None, 60, 5, 0);
        let h4 =
            p.run_trial_threads(crate::algo::AlgoSpec::Ef21, "top1", 1.0, None, 60, 5, 0, 4);
        assert_eq!(h1.records.len(), h4.records.len());
        for (a, b) in h1.records.iter().zip(&h4.records) {
            assert_eq!(a.loss.to_bits(), b.loss.to_bits());
            assert_eq!(a.grad_norm_sq.to_bits(), b.grad_norm_sq.to_bits());
        }
    }
}
