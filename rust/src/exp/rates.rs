//! Table 2 — the theory checks. Verifies, on instrumented runs:
//!
//!   * Theorem 1: `min_t ||∇f(x^t)||^2 <= 2(f(x^0)-f_inf)/(γT) + G^0/(θT)`
//!     for EF21 at the theory stepsize (we check the bound with f_inf
//!     replaced by the best observed loss — a conservative substitution).
//!   * Theorem 2 (PL): `Ψ^T <= (1 - γμ)^T Ψ^0` with
//!     `Ψ^t = f(x^t) - f* + (γ/θ) G^t` on least squares.
//!
//! Printed as a measured-vs-predicted table; also enforced in
//! `rust/tests/theory_rates.rs`.

use super::common::{Objective, Problem};
use crate::algo::AlgoSpec;
use crate::data::synth;
use crate::theory;

pub struct RateReport {
    pub label: String,
    pub measured: f64,
    pub predicted: f64,
    pub holds: bool,
}

/// Theorem 1 check on a synthetic logistic problem.
pub fn check_theorem1(rounds: usize, seed: u64) -> RateReport {
    let ds = synth::generate_custom("rates_ncvx", 800, 16, 0.4, seed);
    let p = Problem::from_dataset(ds, Objective::LogReg, 4, 0.1);
    let alpha = 1.0 / 16.0; // top1 on d=16
    let gamma = p.theory_gamma(alpha);
    let (theta, _) = theory::theta_beta(alpha);
    let h = p.run_trial(AlgoSpec::Ef21, "top1", 1.0, None, rounds, 1, seed);

    let f0 = h.records.first().unwrap().loss; // ≈ f(x^1); f(x^0)=log 2 + 0
    let f_best = h.records.iter().map(|r| r.loss).fold(f64::INFINITY, f64::min);
    let g0 = h.records.first().unwrap().gt;
    // Mean over rounds == E over uniformly random t (Theorem 1's LHS).
    let mean_grad: f64 =
        h.records.iter().map(|r| r.grad_norm_sq).sum::<f64>() / h.records.len() as f64;
    let t = h.records.len() as f64;
    let predicted = 2.0 * (f0 - f_best) / (gamma * t) + g0 / (theta * t);
    RateReport {
        label: format!("Theorem 1 (O(1/T), T={rounds})"),
        measured: mean_grad,
        predicted,
        holds: mean_grad <= predicted * 1.05,
    }
}

/// Theorem 2 check: geometric decay of the Lyapunov function on least
/// squares.
pub fn check_theorem2(rounds: usize, seed: u64) -> RateReport {
    let ds = synth::generate_custom("rates_pl", 600, 8, 0.6, seed);
    let p = Problem::from_dataset(ds, Objective::Lstsq, 4, 0.0);
    let mu = p.mu.unwrap();
    let alpha = 1.0 / 8.0;
    let gamma = p.theory_gamma(alpha);
    let (theta, _) = theory::theta_beta(alpha);
    let h = p.run_trial(AlgoSpec::Ef21, "top1", 1.0, None, rounds, 1, seed);

    // f* estimated by the run's tail (PL => convergence to global min).
    let fstar = h.records.iter().map(|r| r.loss).fold(f64::INFINITY, f64::min);
    let psi = |r: &crate::metrics::RoundRecord| (r.loss - fstar).max(0.0) + gamma / theta * r.gt;
    let psi0 = psi(&h.records[0]);
    let t_probe = rounds * 3 / 4;
    let psi_t = psi(&h.records[t_probe]);
    let predicted = (1.0 - gamma * mu).powi(t_probe as i32) * psi0;
    RateReport {
        label: format!("Theorem 2 (linear, T={t_probe})"),
        measured: psi_t,
        predicted,
        holds: psi_t <= predicted * 1.05 + 1e-12,
    }
}

pub fn main(args: &crate::config::cli::Args) -> anyhow::Result<()> {
    let rounds = args.get_parse("rounds")?.unwrap_or(2000);
    let seed = args.get_parse("seed")?.unwrap_or(0);
    println!("{:<28} {:>14} {:>14} {:>7}", "bound", "measured", "predicted", "holds");
    for r in [check_theorem1(rounds, seed), check_theorem2(rounds, seed)] {
        println!(
            "{:<28} {:>14.4e} {:>14.4e} {:>7}",
            r.label, r.measured, r.predicted, r.holds
        );
        anyhow::ensure!(r.holds, "{} violated", r.label);
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn theorem1_bound_holds_small() {
        let r = check_theorem1(300, 1);
        assert!(r.holds, "measured {:.3e} > predicted {:.3e}", r.measured, r.predicted);
    }

    #[test]
    fn theorem2_bound_holds_small() {
        let r = check_theorem2(400, 1);
        assert!(r.holds, "measured {:.3e} > predicted {:.3e}", r.measured, r.predicted);
    }
}
