//! `exp pp` — the partial-participation sweep: EF21-PP across
//! participation fraction × compressor × data heterogeneity on the least
//! squares (PL) objective, each cell run at its EF21-PP theory stepsize
//! ([`theory::stepsize_pp`]).
//!
//! Reported per cell: the *exact* end-of-run loss and squared gradient
//! norm (fresh-oracle evaluation at the final model — the in-run record
//! mixes stale gradients from workers that sat out late rounds), the
//! uplink bits per client, and the mean wall-clock per round. The
//! practical claim to see: participation `p` cuts uplink bits per round
//! by ~`p` while EF21-PP still converges at the (smaller) PP stepsize,
//! on homogeneous and pathologically heterogeneous shards alike.
//!
//! Heterogeneity model: `het` sorts rows by target before the paper's
//! contiguous split, so every shard sees a disjoint slice of the
//! response distribution — the regime where naive methods suffer most.

use super::common::{parallel_trials, results_dir, Objective, Problem};
use crate::algo::AlgoSpec;
use crate::compress::Compressor;
use crate::config::SchedSpec;
use crate::data::{synth, Dataset};
use crate::metrics::FigureData;
use crate::sched::Participation;
use crate::theory;

pub struct PpCfg {
    pub dataset: String,
    pub rounds: usize,
    pub n_workers: usize,
    pub seed: u64,
    /// Trial-scheduler pool width (1 = legacy sequential sweep).
    pub threads: usize,
    /// Participation modes to sweep (parsed `--p` list; `full` = 1.0).
    pub participation: Vec<Participation>,
    /// Compressor specs to sweep.
    pub compressors: Vec<String>,
}

impl Default for PpCfg {
    fn default() -> Self {
        PpCfg {
            dataset: "phishing".into(),
            rounds: 800,
            n_workers: 20,
            seed: 0,
            threads: 1,
            participation: vec![
                Participation::Full,
                Participation::Bernoulli(0.5),
                Participation::Bernoulli(0.25),
                Participation::Bernoulli(0.1),
            ],
            compressors: vec!["top1".into(), "top8".into(), "rand8".into()],
        }
    }
}

/// Reorder rows by ascending target so the contiguous split hands every
/// worker a disjoint slice of the response distribution.
pub fn heterogenize(ds: &Dataset) -> Dataset {
    let mut order: Vec<usize> = (0..ds.n).collect();
    order.sort_by(|&i, &j| {
        ds.y[i].partial_cmp(&ds.y[j]).unwrap_or(std::cmp::Ordering::Equal)
    });
    let mut a = Vec::with_capacity(ds.a.len());
    let mut y = Vec::with_capacity(ds.n);
    for &i in &order {
        a.extend_from_slice(ds.row(i));
        y.push(ds.y[i]);
    }
    Dataset::new(format!("{}-het", ds.name), a, y, ds.n, ds.d)
}

/// One sweep cell's outcome (console table row + figure curve).
pub struct PpCell {
    pub history: crate::metrics::History,
    pub exact_loss: f64,
    pub exact_grad_sq: f64,
    pub gamma: f64,
    pub round_ms: f64,
}

/// Run the sweep on an explicit base dataset (tests inject tiny ones).
pub fn run_on(base: &Dataset, cfg: &PpCfg) -> (FigureData, Vec<PpCell>) {
    let mut fig = FigureData::new(format!("pp_{}", base.name));
    let mut cells = Vec::new();
    for het in [false, true] {
        let ds = if het { heterogenize(base) } else { base.clone() };
        let het_tag = if het { "het" } else { "iid" };
        // Constants once per dataset variant; per-cell Problems clone the
        // rows but reuse nothing heavier than the spectral estimates.
        let template = Problem::from_dataset(ds.clone(), Objective::Lstsq, cfg.n_workers, 0.0);
        let (l, l_tilde) = (template.smoothness.l, template.smoothness.l_tilde);
        let d = template.d();
        let mut jobs: Vec<(String, Participation)> = Vec::new();
        for comp in &cfg.compressors {
            for &part in &cfg.participation {
                jobs.push((comp.clone(), part));
            }
        }
        let row = |(comp, part): (String, Participation)| -> PpCell {
            let alpha = crate::compress::from_spec(&comp).expect("compressor spec").alpha(d);
            let p_frac = part.expected_fraction(cfg.n_workers);
            let gamma = theory::stepsize_pp(l, l_tilde, alpha, p_frac);
            let mut problem =
                Problem::from_dataset(ds.clone(), Objective::Lstsq, cfg.n_workers, 0.0);
            problem.sched = SchedSpec { participation: part, ..SchedSpec::default() };
            let record_every = (cfg.rounds / 100).max(1);
            let t0 = std::time::Instant::now();
            let mut h = problem.run_trial(
                AlgoSpec::Ef21,
                &comp,
                1.0,
                Some(gamma),
                cfg.rounds,
                record_every,
                cfg.seed,
            );
            let round_ms = t0.elapsed().as_secs_f64() * 1e3 / cfg.rounds as f64;
            h.label = format!("EF21-PP {} {comp} {het_tag}", part.spec());
            let (exact_loss, exact_grad_sq) = problem.eval_at(&h.final_x);
            PpCell { history: h, exact_loss, exact_grad_sq, gamma, round_ms }
        };
        for cell in parallel_trials(jobs, cfg.threads, row) {
            fig.push(cell.history.clone());
            cells.push(cell);
        }
    }
    (fig, cells)
}

pub fn run(cfg: &PpCfg) -> (FigureData, Vec<PpCell>) {
    let base = synth::load_or_generate(&cfg.dataset, &std::path::PathBuf::from("data"), cfg.seed);
    run_on(&base, cfg)
}

fn parse_participation_list(s: &str) -> anyhow::Result<Vec<Participation>> {
    s.split(',')
        .map(str::trim)
        .filter(|t| !t.is_empty())
        .map(|t| {
            // Accept bare fractions ("0.5") as Bernoulli shorthand.
            if let Ok(p) = t.parse::<f64>() {
                if (p - 1.0).abs() < 1e-12 {
                    return Ok(Participation::Full);
                }
                return Participation::parse(&format!("p:{p}"));
            }
            Participation::parse(t)
        })
        .collect()
}

pub fn main(args: &crate::config::cli::Args) -> anyhow::Result<()> {
    let mut cfg = PpCfg {
        dataset: args.get_str("dataset").unwrap_or("phishing").to_string(),
        rounds: args.get_parse("rounds")?.unwrap_or(800),
        n_workers: args.get_parse("workers")?.unwrap_or(20),
        seed: args.get_parse("seed")?.unwrap_or(0),
        threads: crate::config::Threads::from_args(args)?.resolve(),
        ..Default::default()
    };
    if let Some(list) = args.get_str("p") {
        cfg.participation = parse_participation_list(list)?;
        anyhow::ensure!(!cfg.participation.is_empty(), "--p: empty participation list");
    }
    if let Some(list) = args.get_str("compressors") {
        cfg.compressors =
            list.split(',').map(|s| s.trim().to_string()).filter(|s| !s.is_empty()).collect();
        anyhow::ensure!(!cfg.compressors.is_empty(), "--compressors: empty list");
    }
    let (fig, cells) = run(&cfg);
    println!(
        "{:<36} {:>11} {:>12} {:>12} {:>13} {:>9}",
        "curve", "gamma", "exact f", "exact |g|^2", "bits/client", "ms/round"
    );
    for c in &cells {
        println!(
            "{:<36} {:>11.3e} {:>12.4e} {:>12.4e} {:>13.3e} {:>9.2}",
            c.history.label,
            c.gamma,
            c.exact_loss,
            c.exact_grad_sq,
            c.history.records.last().map(|r| r.bits_per_client).unwrap_or(f64::NAN),
            c.round_ms
        );
    }
    fig.write_dir(&results_dir())?;
    println!("wrote {}", results_dir().join(&fig.name).display());
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn heterogenize_sorts_targets_and_keeps_rows_paired() {
        let ds = Dataset::new(
            "t",
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            vec![0.5, -1.0, 0.0],
            3,
            2,
        );
        let het = heterogenize(&ds);
        assert_eq!(het.y, vec![-1.0, 0.0, 0.5]);
        // Rows moved with their targets.
        assert_eq!(het.row(0), &[3.0, 4.0]);
        assert_eq!(het.row(1), &[5.0, 6.0]);
        assert_eq!(het.row(2), &[1.0, 2.0]);
        assert_eq!(het.n, 3);
    }

    #[test]
    fn sweep_runs_and_pp_cells_spend_fewer_bits() {
        let base = synth::generate_custom("ppmini", 240, 8, 0.6, 3);
        let cfg = PpCfg {
            rounds: 120,
            n_workers: 4,
            threads: 2,
            participation: vec![Participation::Full, Participation::Bernoulli(0.5)],
            compressors: vec!["top2".into()],
            ..Default::default()
        };
        let (fig, cells) = run_on(&base, &cfg);
        // 2 heterogeneity variants x 1 compressor x 2 fractions.
        assert_eq!(cells.len(), 4);
        assert_eq!(fig.curves.len(), 4);
        for c in &cells {
            assert!(c.exact_loss.is_finite() && c.exact_grad_sq.is_finite(), "{}", c.history.label);
            assert!(c.gamma > 0.0);
        }
        // Within one variant, p=0.5 spends fewer uplink bits than full.
        let bits = |i: usize| cells[i].history.records.last().unwrap().bits_per_client;
        assert!(bits(1) < bits(0), "PP must cut uplink bits ({} vs {})", bits(1), bits(0));
        assert!(bits(3) < bits(2));
    }
}
