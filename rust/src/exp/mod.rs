//! Experiment harness: one module per paper figure/table (see DESIGN.md §7
//! for the full index). Each driver regenerates the corresponding series
//! as CSV curves under `results/` plus a console summary.

pub mod common;
#[cfg(feature = "xla-runtime")]
pub mod dl;
pub mod finetune;
pub mod gdtune;
pub mod kdep;
pub mod lstsq;
pub mod pp;
pub mod rates;
pub mod stepsize;

pub use common::{parallel_trials, Objective, Problem};
