//! TCP transport: u32-length-prefixed frames over std::net sockets.
//! Exercised by the distributed runner's TCP mode and the transport
//! integration test (real sockets on 127.0.0.1).

use super::Conn;
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};

pub struct TcpConn {
    stream: TcpStream,
}

impl TcpConn {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        Ok(TcpConn { stream })
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Self::new(stream)
    }
}

impl Conn for TcpConn {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let len = frame.len() as u32;
        self.stream.write_all(&len.to_le_bytes()).context("tcp write len")?;
        self.stream.write_all(frame).context("tcp write frame")?;
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut len_bytes = [0u8; 4];
        self.stream.read_exact(&mut len_bytes).context("tcp read len")?;
        let len = u32::from_le_bytes(len_bytes) as usize;
        anyhow::ensure!(len <= 1 << 30, "frame too large: {len}");
        let mut buf = vec![0u8; len];
        self.stream.read_exact(&mut buf).context("tcp read frame")?;
        Ok(buf)
    }
}

/// Accept `n` connections on an ephemeral local port; returns the port and
/// a handle producing the accepted master-side conns in arrival order.
pub fn listen_local(n: usize) -> Result<(u16, std::thread::JoinHandle<Result<Vec<TcpConn>>>)> {
    let listener = TcpListener::bind("127.0.0.1:0").context("bind")?;
    let port = listener.local_addr()?.port();
    let handle = std::thread::spawn(move || {
        let mut conns = Vec::with_capacity(n);
        for _ in 0..n {
            let (stream, _) = listener.accept().context("accept")?;
            conns.push(TcpConn::new(stream)?);
        }
        Ok(conns)
    });
    Ok((port, handle))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_over_loopback() {
        let (port, acceptor) = listen_local(1).unwrap();
        let client = std::thread::spawn(move || {
            let mut c = TcpConn::connect(&format!("127.0.0.1:{port}")).unwrap();
            c.send(b"abc").unwrap();
            let echo = c.recv().unwrap();
            assert_eq!(echo, b"abc--reply");
        });
        let mut server_conns = acceptor.join().unwrap().unwrap();
        let got = server_conns[0].recv().unwrap();
        assert_eq!(got, b"abc");
        let mut reply = got.clone();
        reply.extend_from_slice(b"--reply");
        server_conns[0].send(&reply).unwrap();
        client.join().unwrap();
    }

    #[test]
    fn large_frame() {
        let (port, acceptor) = listen_local(1).unwrap();
        let payload: Vec<u8> = (0..1_000_000u32).map(|i| (i % 251) as u8).collect();
        let p2 = payload.clone();
        let client = std::thread::spawn(move || {
            let mut c = TcpConn::connect(&format!("127.0.0.1:{port}")).unwrap();
            c.send(&p2).unwrap();
        });
        let mut conns = acceptor.join().unwrap().unwrap();
        assert_eq!(conns[0].recv().unwrap(), payload);
        client.join().unwrap();
    }
}
