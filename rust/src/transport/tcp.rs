//! TCP transport: u32-length-prefixed frames over std::net sockets.
//! Exercised by the distributed runner's TCP mode and the transport
//! integration test (real sockets on 127.0.0.1).
//!
//! Hardening: every [`TcpConn`] carries read/write timeouts
//! ([`DEFAULT_IO_TIMEOUT`]) so a dead peer surfaces as an error instead
//! of a hang, and [`TcpConn::connect_with_retry`] rides out the race
//! where workers dial before the master's listener is up.
//!
//! The timeout is configurable end to end: `--net-timeout-ms` (wired via
//! [`set_default_io_timeout_ms`]) > `$EF21_NET_TIMEOUT_MS` >
//! `$EF21_TCP_TIMEOUT_SECS` (legacy) > [`DEFAULT_IO_TIMEOUT`], with `0`
//! meaning "no timeout, block forever" at every layer. The same knob is
//! the wall-clock floor for the scheduler's straggler deadline: a
//! scheduled in-deadline straggle sleeps on the wire, so the peer's read
//! timeout must exceed the longest scheduled delay (the scheduler-aware
//! dist runner validates this).
//!
//! Telemetry: frames and bytes moved are counted process-wide under
//! `transport.tx.*` / `transport.rx.*` (see [`crate::telemetry::keys`]).

use super::Conn;
use crate::telemetry::{self, keys};
use anyhow::{Context, Result};
use std::io::{Read, Write};
use std::net::{TcpListener, TcpStream};
use std::time::Duration;

/// Default read/write timeout applied to every connection. It must
/// exceed the slowest full protocol round (workers sit in recv while
/// stragglers compute), so it is deliberately generous — its job is to
/// turn a dead peer into a bounded-time error, not to police round
/// latency. Override with `$EF21_TCP_TIMEOUT_SECS` (0 = no timeout,
/// block forever) or per-conn via [`TcpConn::set_io_timeout`].
pub const DEFAULT_IO_TIMEOUT: Duration = Duration::from_secs(300);

/// Process-level `--net-timeout-ms` override: `u64::MAX` = unset,
/// `0` = no timeout, anything else = milliseconds.
static IO_TIMEOUT_MS_OVERRIDE: std::sync::atomic::AtomicU64 =
    std::sync::atomic::AtomicU64::new(u64::MAX);

/// Install the CLI's `--net-timeout-ms` value as the process default for
/// every subsequently-created connection (`Some(0)` disables timeouts;
/// `None` clears the override back to the env/default chain).
pub fn set_default_io_timeout_ms(ms: Option<u64>) {
    IO_TIMEOUT_MS_OVERRIDE.store(ms.unwrap_or(u64::MAX), std::sync::atomic::Ordering::SeqCst);
}

/// Pure resolution of the effective I/O timeout from the three layers —
/// the unit-testable parse path behind [`io_timeout`]. `cli_ms` is the
/// `--net-timeout-ms` override, `env_ms`/`env_secs` the raw values of
/// `$EF21_NET_TIMEOUT_MS` / `$EF21_TCP_TIMEOUT_SECS`. `0` at any layer
/// means "no timeout"; an unparseable env value falls through to the
/// next layer.
pub fn resolve_io_timeout(
    cli_ms: Option<u64>,
    env_ms: Option<&str>,
    env_secs: Option<&str>,
) -> Option<Duration> {
    if let Some(ms) = cli_ms {
        return (ms > 0).then(|| Duration::from_millis(ms));
    }
    if let Some(ms) = env_ms.and_then(|v| v.trim().parse::<u64>().ok()) {
        return (ms > 0).then(|| Duration::from_millis(ms));
    }
    if let Some(secs) = env_secs.and_then(|v| v.trim().parse::<u64>().ok()) {
        return (secs > 0).then(|| Duration::from_secs(secs));
    }
    Some(DEFAULT_IO_TIMEOUT)
}

/// The effective default timeout for new connections: the
/// `--net-timeout-ms` override if installed, else `$EF21_NET_TIMEOUT_MS`
/// (milliseconds), else `$EF21_TCP_TIMEOUT_SECS` (legacy, seconds), else
/// [`DEFAULT_IO_TIMEOUT`]; `0` disables at every layer.
pub fn io_timeout() -> Option<Duration> {
    let cli = match IO_TIMEOUT_MS_OVERRIDE.load(std::sync::atomic::Ordering::SeqCst) {
        u64::MAX => None,
        ms => Some(ms),
    };
    let env_ms = std::env::var("EF21_NET_TIMEOUT_MS").ok();
    let env_secs = std::env::var("EF21_TCP_TIMEOUT_SECS").ok();
    // A set-but-unparseable env value falls through to the next layer;
    // say so once instead of silently handing the user the default.
    for (var, val) in [("EF21_NET_TIMEOUT_MS", &env_ms), ("EF21_TCP_TIMEOUT_SECS", &env_secs)] {
        if let Some(v) = val {
            if v.trim().parse::<u64>().is_err() {
                static WARN_ONCE: std::sync::Once = std::sync::Once::new();
                WARN_ONCE.call_once(|| {
                    eprintln!(
                        "warning: {var}='{v}' is not a whole number; ignoring it and \
                         falling back to the next timeout layer"
                    );
                });
            }
        }
    }
    resolve_io_timeout(cli, env_ms.as_deref(), env_secs.as_deref())
}

/// High bit of the worker-id hello: set when a worker re-dials to
/// RESUME an existing session rather than join fresh.
pub const RESUME_FLAG: u32 = 0x8000_0000;

pub struct TcpConn {
    stream: TcpStream,
    /// Reusable write assembly buffer: each send builds `len ‖ frame`
    /// here and ships it with one `write_all` — one syscall instead of
    /// two, and no allocation per frame in sustained rounds.
    wbuf: Vec<u8>,
}

impl TcpConn {
    pub fn new(stream: TcpStream) -> Result<Self> {
        stream.set_nodelay(true).context("set_nodelay")?;
        let timeout = io_timeout();
        stream.set_read_timeout(timeout).context("set_read_timeout")?;
        stream.set_write_timeout(timeout).context("set_write_timeout")?;
        Ok(TcpConn { stream, wbuf: Vec::new() })
    }

    /// Override the default I/O timeouts (`None` = block forever).
    pub fn set_io_timeout(&mut self, timeout: Option<Duration>) -> Result<()> {
        self.stream.set_read_timeout(timeout).context("set_read_timeout")?;
        self.stream.set_write_timeout(timeout).context("set_write_timeout")
    }

    pub fn connect(addr: &str) -> Result<Self> {
        let stream = TcpStream::connect(addr).with_context(|| format!("connect {addr}"))?;
        Self::new(stream)
    }

    /// Surrender the underlying stream (used by the reactor, which runs
    /// its own nonblocking framing instead of the blocking [`Conn`] path).
    pub(crate) fn into_stream(self) -> TcpStream {
        self.stream
    }

    /// Connect under the shared exponential-backoff-with-decorrelated-
    /// jitter policy ([`crate::transport::session::RetryPolicy`]), seeded
    /// for reproducible schedules and budgeted by the resolved I/O
    /// timeout — lets workers dial a master that is still binding its
    /// listener, while a genuinely dead address fails in bounded time.
    /// Each retry warns once (never silent).
    pub fn connect_with_retry(addr: &str, seed: u64) -> Result<Self> {
        let policy = super::session::RetryPolicy::for_io_timeout(seed);
        policy.run(&format!("connect {addr}"), || {
            TcpStream::connect(addr).map_err(anyhow::Error::from).and_then(Self::new)
        })
    }
}

impl Conn for TcpConn {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let len = frame.len() as u32;
        self.wbuf.clear();
        self.wbuf.extend_from_slice(&len.to_le_bytes());
        self.wbuf.extend_from_slice(frame);
        self.stream.write_all(&self.wbuf).context("tcp write frame")?;
        telemetry::counter(keys::TX_FRAMES).incr(1);
        telemetry::counter(keys::TX_BYTES).incr(frame.len() as u64 + 4);
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.recv_into(&mut buf)?;
        Ok(buf)
    }

    fn recv_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        let mut len_bytes = [0u8; 4];
        self.stream.read_exact(&mut len_bytes).context("tcp read len")?;
        let len = u32::from_le_bytes(len_bytes) as usize;
        anyhow::ensure!(len <= 1 << 30, "frame too large: {len}");
        buf.clear();
        buf.resize(len, 0);
        self.stream.read_exact(buf).context("tcp read frame")?;
        telemetry::counter(keys::RX_FRAMES).incr(1);
        telemetry::counter(keys::RX_BYTES).incr(len as u64 + 4);
        Ok(())
    }

    /// Hard teardown, as a real network reset: both directions die and
    /// the peer sees an error, not a clean close. Used by the chaos
    /// proxy's `reset`/`down` clauses on redial-capable paths.
    fn sever(&mut self) {
        let _ = self.stream.shutdown(std::net::Shutdown::Both);
    }
}

/// Ask the kernel for a deeper accept queue on an already-listening
/// socket. `std` hardcodes a backlog of 128, which drops/refuses SYNs
/// when thousands of workers dial the instant the port is published
/// (they no longer stagger their connects). POSIX allows re-calling
/// `listen(2)` on a listening socket to change the backlog and Linux
/// honors it, so this is a direct libc call — the symbol is already
/// linked on every unix target, no new dependency. Best-effort: the
/// kernel clamps to `somaxconn`, and connect-side retry still covers an
/// overflowing queue.
#[cfg(unix)]
fn raise_listen_backlog(listener: &TcpListener, backlog: i32) {
    use std::os::unix::io::AsRawFd;
    extern "C" {
        fn listen(fd: std::os::raw::c_int, backlog: std::os::raw::c_int) -> std::os::raw::c_int;
    }
    unsafe {
        let _ = listen(listener.as_raw_fd(), backlog);
    }
}

#[cfg(not(unix))]
fn raise_listen_backlog(_listener: &TcpListener, _backlog: i32) {}

/// Accept `n` connections on an ephemeral local port; returns the port and
/// a handle producing the accepted master-side conns in arrival order.
/// The accept queue is deepened ([`raise_listen_backlog`]) so a
/// simultaneous thundering herd of connects is queued, not refused.
pub fn listen_local(n: usize) -> Result<(u16, std::thread::JoinHandle<Result<Vec<TcpConn>>>)> {
    let listener = TcpListener::bind("127.0.0.1:0").context("bind")?;
    raise_listen_backlog(&listener, 4096);
    let port = listener.local_addr()?.port();
    let handle = std::thread::spawn(move || {
        let mut conns = Vec::with_capacity(n);
        for _ in 0..n {
            let (stream, _) = listener.accept().context("accept")?;
            conns.push(TcpConn::new(stream)?);
        }
        Ok(conns)
    });
    Ok((port, handle))
}

/// Persistent acceptor for session-enabled TCP runs: keeps the listener
/// alive for the whole run and routes every accepted stream by its
/// 4-byte hello — fresh workers (`id`) to the initial-wiring channel,
/// redialing workers (`id | RESUME_FLAG`) to that worker's resume
/// channel, where the master-side session adopts them.
pub(crate) struct TcpSwitchboard {
    pub(crate) port: u16,
    init_rx: std::sync::mpsc::Receiver<(usize, TcpConn)>,
    resume_rx: Vec<Option<std::sync::mpsc::Receiver<TcpConn>>>,
    stop: std::sync::Arc<std::sync::atomic::AtomicBool>,
}

impl TcpSwitchboard {
    pub(crate) fn bind(n_workers: usize) -> Result<TcpSwitchboard> {
        use std::sync::atomic::{AtomicBool, Ordering};
        use std::sync::mpsc::channel;
        let listener = TcpListener::bind("127.0.0.1:0").context("bind switchboard")?;
        raise_listen_backlog(&listener, 4096);
        listener.set_nonblocking(true).context("switchboard set_nonblocking")?;
        let port = listener.local_addr()?.port();
        let stop = std::sync::Arc::new(AtomicBool::new(false));
        let (init_tx, init_rx) = channel();
        let mut resume_txs = Vec::with_capacity(n_workers);
        let mut resume_rx = Vec::with_capacity(n_workers);
        for _ in 0..n_workers {
            let (tx, rx) = channel();
            resume_txs.push(tx);
            resume_rx.push(Some(rx));
        }
        let stop2 = stop.clone();
        std::thread::Builder::new()
            .name("tcp-switchboard".into())
            .spawn(move || loop {
                if stop2.load(Ordering::Relaxed) {
                    return;
                }
                match listener.accept() {
                    Ok((stream, _)) => {
                        let routed = (|| -> Result<()> {
                            let mut conn = TcpConn::new(stream)?;
                            let hello = conn.recv()?;
                            anyhow::ensure!(hello.len() == 4, "bad hello length {}", hello.len());
                            let raw = u32::from_le_bytes(hello[..].try_into().expect("len"));
                            let resume = raw & RESUME_FLAG != 0;
                            let id = (raw & !RESUME_FLAG) as usize;
                            anyhow::ensure!(id < n_workers, "bad worker id {id}");
                            if resume {
                                let _ = resume_txs[id].send(conn);
                            } else {
                                let _ = init_tx.send((id, conn));
                            }
                            Ok(())
                        })();
                        if let Err(e) = routed {
                            eprintln!("switchboard: rejected a connection: {e:#}");
                        }
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(2));
                    }
                    Err(e) => {
                        eprintln!("switchboard: accept failed, exiting: {e:#}");
                        return;
                    }
                }
            })
            .context("spawn tcp switchboard")?;
        Ok(TcpSwitchboard { port, init_rx, resume_rx, stop })
    }

    /// Collect the initial connection of every worker (hello already
    /// consumed by the acceptor), ordered by worker id.
    pub(crate) fn initial_conns(&self, n_workers: usize) -> Result<Vec<TcpConn>> {
        let window = io_timeout().unwrap_or(DEFAULT_IO_TIMEOUT);
        let mut ordered: Vec<Option<TcpConn>> = (0..n_workers).map(|_| None).collect();
        for _ in 0..n_workers {
            let (id, conn) = self
                .init_rx
                .recv_timeout(window)
                .context("waiting for initial worker connections")?;
            ensure_slot_free(&ordered, id)?;
            ordered[id] = Some(conn);
        }
        let mut out = Vec::with_capacity(n_workers);
        for c in ordered {
            out.push(c.context("missing worker connection")?);
        }
        Ok(out)
    }

    /// Hand worker `w`'s resume channel to its master-side session (can
    /// only be taken once).
    pub(crate) fn take_resume_rx(&mut self, w: usize) -> std::sync::mpsc::Receiver<TcpConn> {
        self.resume_rx[w].take().expect("resume receiver already taken")
    }
}

impl Drop for TcpSwitchboard {
    fn drop(&mut self) {
        self.stop.store(true, std::sync::atomic::Ordering::Relaxed);
    }
}

fn ensure_slot_free(ordered: &[Option<TcpConn>], id: usize) -> Result<()> {
    anyhow::ensure!(ordered[id].is_none(), "duplicate worker id {id}");
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn frame_roundtrip_over_loopback() {
        let (port, acceptor) = listen_local(1).unwrap();
        let client = std::thread::spawn(move || {
            let mut c = TcpConn::connect(&format!("127.0.0.1:{port}")).unwrap();
            c.send(b"abc").unwrap();
            let echo = c.recv().unwrap();
            assert_eq!(echo, b"abc--reply");
        });
        let mut server_conns = acceptor.join().unwrap().unwrap();
        let got = server_conns[0].recv().unwrap();
        assert_eq!(got, b"abc");
        let mut reply = got.clone();
        reply.extend_from_slice(b"--reply");
        server_conns[0].send(&reply).unwrap();
        client.join().unwrap();
    }

    #[test]
    fn large_frame() {
        let (port, acceptor) = listen_local(1).unwrap();
        let payload: Vec<u8> = (0..1_000_000u32).map(|i| (i % 251) as u8).collect();
        let p2 = payload.clone();
        let client = std::thread::spawn(move || {
            let mut c = TcpConn::connect(&format!("127.0.0.1:{port}")).unwrap();
            c.send(&p2).unwrap();
        });
        let mut conns = acceptor.join().unwrap().unwrap();
        assert_eq!(conns[0].recv().unwrap(), payload);
        client.join().unwrap();
    }

    #[test]
    fn simultaneous_connects_are_all_accepted() {
        // No stagger: every client dials the instant the port exists.
        // The deepened backlog (plus connect retry for overflow) must
        // deliver all of them.
        let n = 64;
        let (port, acceptor) = listen_local(n).unwrap();
        let clients: Vec<_> = (0..n as u32)
            .map(|i| {
                std::thread::spawn(move || {
                    let mut c =
                        TcpConn::connect_with_retry(&format!("127.0.0.1:{port}"), i as u64)
                            .unwrap();
                    c.send(&i.to_le_bytes()).unwrap();
                    c
                })
            })
            .collect();
        let mut conns = acceptor.join().unwrap().unwrap();
        let mut seen: Vec<u32> = conns
            .iter_mut()
            .map(|c| u32::from_le_bytes(c.recv().unwrap().try_into().unwrap()))
            .collect();
        seen.sort_unstable();
        assert_eq!(seen, (0..n as u32).collect::<Vec<_>>());
        for c in clients {
            c.join().unwrap();
        }
    }

    #[test]
    fn connect_with_retry_succeeds_after_listener_appears() {
        // Reserve a port, drop the listener, then bind it again shortly
        // after the client starts retrying.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = probe.local_addr().unwrap().port();
        drop(probe);
        let addr = format!("127.0.0.1:{port}");
        let server = std::thread::spawn(move || {
            std::thread::sleep(Duration::from_millis(60));
            let listener = TcpListener::bind(("127.0.0.1", port)).unwrap();
            let _ = listener.accept().unwrap();
        });
        let conn = TcpConn::connect_with_retry(&addr, 42);
        assert!(conn.is_ok(), "{:?}", conn.err());
        server.join().unwrap();
    }

    #[test]
    fn connect_with_retry_fails_in_bounded_time() {
        // Nothing listens here; the retry budget (tied to the resolved
        // I/O timeout) must bound the failure, not retry forever. Use
        // the policy directly with a tiny budget so the test is fast
        // regardless of the process-wide timeout knob.
        let probe = TcpListener::bind("127.0.0.1:0").unwrap();
        let port = probe.local_addr().unwrap().port();
        drop(probe);
        let addr = format!("127.0.0.1:{port}");
        let policy = super::super::session::RetryPolicy::new(
            Duration::from_millis(5),
            Duration::from_millis(20),
            Some(Duration::from_millis(150)),
            7,
        );
        let t0 = std::time::Instant::now();
        let r = policy.run(&format!("connect {addr}"), || {
            TcpStream::connect(&addr).map_err(anyhow::Error::from).and_then(TcpConn::new)
        });
        assert!(r.is_err());
        assert!(t0.elapsed() < Duration::from_secs(5));
    }

    #[test]
    fn resolve_io_timeout_layers_and_parses() {
        // CLI override wins, 0 disables.
        assert_eq!(
            resolve_io_timeout(Some(1500), Some("9"), Some("9")),
            Some(Duration::from_millis(1500))
        );
        assert_eq!(resolve_io_timeout(Some(0), Some("9"), None), None);
        // Env ms next (0 disables), legacy secs after that.
        assert_eq!(
            resolve_io_timeout(None, Some("250"), Some("9")),
            Some(Duration::from_millis(250))
        );
        assert_eq!(resolve_io_timeout(None, Some("0"), Some("9")), None);
        assert_eq!(resolve_io_timeout(None, None, Some("7")), Some(Duration::from_secs(7)));
        assert_eq!(resolve_io_timeout(None, None, Some("0")), None);
        // Unparseable env values fall through to the next layer.
        assert_eq!(
            resolve_io_timeout(None, Some("fast"), Some("3")),
            Some(Duration::from_secs(3))
        );
        assert_eq!(resolve_io_timeout(None, Some("?"), Some("?")), Some(DEFAULT_IO_TIMEOUT));
        assert_eq!(resolve_io_timeout(None, None, None), Some(DEFAULT_IO_TIMEOUT));
        // Whitespace tolerated.
        assert_eq!(
            resolve_io_timeout(None, Some(" 40 "), None),
            Some(Duration::from_millis(40))
        );
    }

    #[test]
    fn switchboard_routes_fresh_and_resume_hellos() {
        let mut sb = TcpSwitchboard::bind(2).unwrap();
        let port = sb.port;
        let dial = |hello: u32| {
            let mut c = TcpConn::connect(&format!("127.0.0.1:{port}")).unwrap();
            c.send(&hello.to_le_bytes()).unwrap();
            c
        };
        let mut w1 = dial(1);
        let mut w0 = dial(0);
        let mut conns = sb.initial_conns(2).unwrap();
        // Ordered by id regardless of arrival order.
        w0.send(b"from-0").unwrap();
        w1.send(b"from-1").unwrap();
        assert_eq!(conns[0].recv().unwrap(), b"from-0");
        assert_eq!(conns[1].recv().unwrap(), b"from-1");
        // A resume hello lands on that worker's resume channel, not the
        // initial one.
        let resume_rx = sb.take_resume_rx(1);
        let mut w1b = dial(1 | RESUME_FLAG);
        let mut adopted = resume_rx.recv_timeout(Duration::from_secs(5)).unwrap();
        w1b.send(b"resumed").unwrap();
        assert_eq!(adopted.recv().unwrap(), b"resumed");
        adopted.send(b"ack").unwrap();
        assert_eq!(w1b.recv().unwrap(), b"ack");
    }

    #[test]
    fn read_timeout_fires_instead_of_hanging() {
        let (port, acceptor) = listen_local(1).unwrap();
        let client = std::thread::spawn(move || {
            let mut c = TcpConn::connect(&format!("127.0.0.1:{port}")).unwrap();
            c.set_io_timeout(Some(Duration::from_millis(50))).unwrap();
            // Peer never sends: recv must error out, not block forever.
            assert!(c.recv().is_err());
        });
        let _server_conn = acceptor.join().unwrap().unwrap();
        client.join().unwrap();
    }
}
