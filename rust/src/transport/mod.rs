//! Transports: the byte-level substrate under the distributed runner.
//!
//! * [`codec`] — explicit little-endian wire format for protocol frames;
//!   the frame sizes are consistent with the simulated bit accounting.
//! * [`local`] — in-process mpsc channel transport.
//! * [`tcp`]   — length-prefixed frames over real TCP sockets (std::net).

pub mod codec;
pub mod local;
pub mod tcp;

use anyhow::Result;

/// A bidirectional, blocking, framed connection endpoint.
pub trait Conn: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    fn recv(&mut self) -> Result<Vec<u8>>;
}
