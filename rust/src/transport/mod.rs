//! Transports: the byte-level substrate under the distributed runner.
//!
//! * [`codec`] — explicit little-endian wire format for protocol frames
//!   (dense model, block-delta model, plain and block-tagged uplinks);
//!   the frame sizes are consistent with the simulated bit accounting.
//! * [`downlink`] — broadcast accounting and block-delta planning (which
//!   blocks cleared the f32-quantization floor since the last send).
//! * [`local`] — in-process mpsc channel transport.
//! * [`tcp`]   — length-prefixed frames over real TCP sockets (std::net).
//! * [`fault`] — scheduler-armed fault injection (straggler delay, frame
//!   duplication) over any of the above.
//! * [`session`] — self-healing session envelope: CRC32 + sequence
//!   numbers, retransmit ring, reconnect/RESUME handshake.
//! * [`chaos`] — seeded wire-level chaos proxy (resets, bit flips,
//!   stalls, permanent link death) for exercising the session layer.

pub mod chaos;
pub mod codec;
pub mod downlink;
pub mod fault;
pub mod local;
pub mod session;
pub mod tcp;

use anyhow::Result;

/// A bidirectional, blocking, framed connection endpoint.
pub trait Conn: Send {
    fn send(&mut self, frame: &[u8]) -> Result<()>;
    fn recv(&mut self) -> Result<Vec<u8>>;

    /// Receive one frame into a caller-owned buffer (cleared and
    /// refilled; its allocation is reused). The default forwards to
    /// [`Conn::recv`]; transports that read off a raw byte stream (TCP)
    /// override it so sustained rounds stop allocating a fresh frame
    /// buffer per receive.
    fn recv_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        *buf = self.recv()?;
        Ok(())
    }

    /// Tear the transport down *hard*, as a network reset would (both
    /// directions die, the peer sees an error, no clean shutdown frame).
    /// Default is a no-op: in-process channels have no wire to cut — the
    /// chaos proxy models their resets as in-flight frame loss instead.
    fn sever(&mut self) {}
}

impl<T: Conn + ?Sized> Conn for Box<T> {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        (**self).send(frame)
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        (**self).recv()
    }

    fn recv_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        (**self).recv_into(buf)
    }

    fn sever(&mut self) {
        (**self).sever()
    }
}
