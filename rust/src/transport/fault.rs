//! Fault-injecting [`Conn`] wrapper: realizes the scheduler's wire-level
//! faults — straggler delay and frame duplication — on any underlying
//! transport (in-process channels or TCP alike).
//!
//! The wrapper is armed *per uplink* by the worker loop from the same
//! deterministic [`crate::sched::Scheduler`] plan the master derives, so
//! the receiving side always knows exactly how many frames to expect;
//! nothing here needs acks or timers. Faults are one-shot: a send
//! consumes the armed fault and the wrapper reverts to transparent.

use super::Conn;
use crate::telemetry::{self, keys};
use anyhow::Result;
use std::time::Duration;

pub struct FaultConn<C: Conn> {
    inner: C,
    delay: Duration,
    dup: bool,
}

impl<C: Conn> FaultConn<C> {
    pub fn new(inner: C) -> Self {
        FaultConn { inner, delay: Duration::ZERO, dup: false }
    }

    /// Arm the faults for the next send: sleep `delay_ms` first (the
    /// straggler model — real wall-clock on a real transport), then send
    /// the frame `1 + dup` times.
    pub fn arm(&mut self, delay_ms: u64, dup: bool) {
        self.delay = Duration::from_millis(delay_ms);
        self.dup = dup;
    }
}

impl<C: Conn> Conn for FaultConn<C> {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        if !self.delay.is_zero() {
            std::thread::sleep(self.delay);
            self.delay = Duration::ZERO;
        }
        self.inner.send(frame)?;
        if self.dup {
            self.dup = false;
            self.inner.send(frame)?;
            telemetry::counter(keys::SCHED_DUP_FRAMES).incr(1);
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        self.inner.recv()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::local;

    #[test]
    fn transparent_by_default() {
        let (m, w) = local::pair();
        let mut f = FaultConn::new(w);
        let mut m = m;
        m.send(b"down").unwrap();
        assert_eq!(f.recv().unwrap(), b"down");
        f.send(b"up").unwrap();
        assert_eq!(m.recv().unwrap(), b"up");
    }

    #[test]
    fn dup_sends_the_frame_twice_then_disarms() {
        let (mut m, w) = local::pair();
        let mut f = FaultConn::new(w);
        f.arm(0, true);
        f.send(b"x").unwrap();
        assert_eq!(m.recv().unwrap(), b"x");
        assert_eq!(m.recv().unwrap(), b"x");
        // One-shot: the next send is single.
        f.send(b"y").unwrap();
        assert_eq!(m.recv().unwrap(), b"y");
        m.send(b"done").unwrap();
        assert_eq!(f.recv().unwrap(), b"done");
    }

    #[test]
    fn delay_is_one_shot_wall_clock() {
        let (mut m, w) = local::pair();
        let mut f = FaultConn::new(w);
        f.arm(30, false);
        let t0 = std::time::Instant::now();
        f.send(b"slow").unwrap();
        assert!(t0.elapsed() >= Duration::from_millis(30));
        assert_eq!(m.recv().unwrap(), b"slow");
        let t1 = std::time::Instant::now();
        f.send(b"fast").unwrap();
        assert!(t1.elapsed() < Duration::from_millis(25));
    }
}
