//! In-process channel transport: a pair of mpsc queues per worker.
//! Frames/bytes moved are metered under the same `transport.tx/rx.*`
//! telemetry keys as the TCP transport.

use super::Conn;
use crate::telemetry::{self, keys};
use anyhow::{Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};

pub struct LocalConn {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl Conn for LocalConn {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.tx.send(frame.to_vec()).context("local conn closed (send)")?;
        telemetry::counter(keys::TX_FRAMES).incr(1);
        telemetry::counter(keys::TX_BYTES).incr(frame.len() as u64);
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let frame = self.rx.recv().context("local conn closed (recv)")?;
        telemetry::counter(keys::RX_FRAMES).incr(1);
        telemetry::counter(keys::RX_BYTES).incr(frame.len() as u64);
        Ok(frame)
    }
}

impl LocalConn {
    /// Nonblocking receive for the reactor's readiness loop: a complete
    /// frame if one is queued, `None` if the peer simply has not sent
    /// yet, an error once the peer is gone.
    pub fn try_recv_frame(&mut self) -> Result<Option<Vec<u8>>> {
        use std::sync::mpsc::TryRecvError;
        match self.rx.try_recv() {
            Ok(frame) => {
                telemetry::counter(keys::RX_FRAMES).incr(1);
                telemetry::counter(keys::RX_BYTES).incr(frame.len() as u64);
                Ok(Some(frame))
            }
            Err(TryRecvError::Empty) => Ok(None),
            Err(TryRecvError::Disconnected) => {
                anyhow::bail!("local conn closed (try_recv)")
            }
        }
    }
}

/// Create a connected (master_end, worker_end) pair.
pub fn pair() -> (LocalConn, LocalConn) {
    let (tx_a, rx_b) = channel();
    let (tx_b, rx_a) = channel();
    (LocalConn { tx: tx_a, rx: rx_a }, LocalConn { tx: tx_b, rx: rx_b })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplex_roundtrip() {
        let (mut m, mut w) = pair();
        m.send(b"hello").unwrap();
        assert_eq!(w.recv().unwrap(), b"hello");
        w.send(b"world").unwrap();
        assert_eq!(m.recv().unwrap(), b"world");
    }

    #[test]
    fn works_across_threads() {
        let (mut m, mut w) = pair();
        let h = std::thread::spawn(move || {
            let got = w.recv().unwrap();
            w.send(&got).unwrap();
        });
        m.send(b"ping").unwrap();
        assert_eq!(m.recv().unwrap(), b"ping");
        h.join().unwrap();
    }

    #[test]
    fn closed_peer_errors() {
        let (mut m, w) = pair();
        drop(w);
        assert!(m.send(b"x").is_err() || m.recv().is_err());
    }

    #[test]
    fn try_recv_frame_is_nonblocking() {
        let (mut m, mut w) = pair();
        assert!(m.try_recv_frame().unwrap().is_none());
        w.send(b"later").unwrap();
        assert_eq!(m.try_recv_frame().unwrap().unwrap(), b"later");
        drop(w);
        assert!(m.try_recv_frame().is_err());
    }
}
