//! In-process channel transport: a pair of mpsc queues per worker.
//! Frames/bytes moved are metered under the same `transport.tx/rx.*`
//! telemetry keys as the TCP transport.

use super::Conn;
use crate::telemetry::{self, keys};
use anyhow::{Context, Result};
use std::sync::mpsc::{channel, Receiver, Sender};

pub struct LocalConn {
    tx: Sender<Vec<u8>>,
    rx: Receiver<Vec<u8>>,
}

impl Conn for LocalConn {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        self.tx.send(frame.to_vec()).context("local conn closed (send)")?;
        telemetry::counter(keys::TX_FRAMES).incr(1);
        telemetry::counter(keys::TX_BYTES).incr(frame.len() as u64);
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let frame = self.rx.recv().context("local conn closed (recv)")?;
        telemetry::counter(keys::RX_FRAMES).incr(1);
        telemetry::counter(keys::RX_BYTES).incr(frame.len() as u64);
        Ok(frame)
    }
}

/// Create a connected (master_end, worker_end) pair.
pub fn pair() -> (LocalConn, LocalConn) {
    let (tx_a, rx_b) = channel();
    let (tx_b, rx_a) = channel();
    (LocalConn { tx: tx_a, rx: rx_a }, LocalConn { tx: tx_b, rx: rx_b })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn duplex_roundtrip() {
        let (mut m, mut w) = pair();
        m.send(b"hello").unwrap();
        assert_eq!(w.recv().unwrap(), b"hello");
        w.send(b"world").unwrap();
        assert_eq!(m.recv().unwrap(), b"world");
    }

    #[test]
    fn works_across_threads() {
        let (mut m, mut w) = pair();
        let h = std::thread::spawn(move || {
            let got = w.recv().unwrap();
            w.send(&got).unwrap();
        });
        m.send(b"ping").unwrap();
        assert_eq!(m.recv().unwrap(), b"ping");
        h.join().unwrap();
    }

    #[test]
    fn closed_peer_errors() {
        let (mut m, w) = pair();
        drop(w);
        assert!(m.send(b"x").is_err() || m.recv().is_err());
    }
}
