//! Wire format for protocol frames (explicit little-endian, no serde).
//!
//! Frames:
//!   0x01 Model     : u32 d | d * f32          (master -> worker broadcast)
//!   0x02 Up        : u8 kind | f64 loss | u64 bits | u32 nnz
//!                    | nnz * u32 idx | nnz * f32 val
//!                    kind: 0 = Sparse, 1 = Markov delta, 2 = DCGD assign
//!   0x03 Stop      : empty                    (master -> worker shutdown)
//!
//! Values travel as f32 — the same precision the bit accounting charges —
//! so the simulated `bits/n` axis and the real byte stream agree (the `Up`
//! frame's payload portion is exactly `bits/8` bytes plus the fixed header;
//! `loss` is instrumentation and excluded from the metered bits).

use crate::algo::WireMsg;
use crate::compress::{Compressed, SparseVec};
use anyhow::{bail, Result};

pub const TAG_MODEL: u8 = 0x01;
pub const TAG_UP: u8 = 0x02;
pub const TAG_STOP: u8 = 0x03;

/// A decoded protocol frame.
#[derive(Clone, Debug)]
pub enum Frame {
    /// Broadcast model (f32 on the wire).
    Model(Vec<f64>),
    /// Worker uplink: message plus piggybacked instrumentation loss.
    Up { msg: WireMsg, loss: f64 },
    Stop,
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("frame truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> bool {
        self.i == self.b.len()
    }
}

pub fn encode(frame: &Frame) -> Vec<u8> {
    // Handle cached in a static: initialized on the first *enabled* call
    // (t0 is Some only then), so the hot path never repeats the registry
    // lookup and the disabled path is a single atomic load.
    static ENCODE_NS: std::sync::OnceLock<crate::telemetry::Histogram> =
        std::sync::OnceLock::new();
    let t0 = crate::telemetry::maybe_now();
    let out = encode_impl(frame);
    if let Some(t0) = t0 {
        ENCODE_NS
            .get_or_init(|| crate::telemetry::histogram(crate::telemetry::keys::CODEC_ENCODE_NS))
            .record(t0.elapsed().as_nanos() as u64);
    }
    out
}

pub fn decode(bytes: &[u8]) -> Result<Frame> {
    static DECODE_NS: std::sync::OnceLock<crate::telemetry::Histogram> =
        std::sync::OnceLock::new();
    let t0 = crate::telemetry::maybe_now();
    let frame = decode_impl(bytes);
    if let Some(t0) = t0 {
        DECODE_NS
            .get_or_init(|| crate::telemetry::histogram(crate::telemetry::keys::CODEC_DECODE_NS))
            .record(t0.elapsed().as_nanos() as u64);
    }
    frame
}

fn encode_impl(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::new();
    match frame {
        Frame::Model(x) => {
            out.push(TAG_MODEL);
            put_u32(&mut out, x.len() as u32);
            for &v in x {
                put_f32(&mut out, v as f32);
            }
        }
        Frame::Up { msg, loss } => {
            out.push(TAG_UP);
            let (kind, payload) = match msg {
                WireMsg::Sparse(c) => (0u8, c),
                WireMsg::Tagged { dcgd_branch: false, payload } => (1u8, payload),
                WireMsg::Tagged { dcgd_branch: true, payload } => (2u8, payload),
            };
            out.push(kind);
            put_f64(&mut out, *loss);
            put_u64(&mut out, payload.bits);
            put_u32(&mut out, payload.sparse.nnz() as u32);
            for &i in &payload.sparse.idx {
                put_u32(&mut out, i);
            }
            for &v in &payload.sparse.val {
                put_f32(&mut out, v as f32);
            }
        }
        Frame::Stop => out.push(TAG_STOP),
    }
    out
}

fn decode_impl(bytes: &[u8]) -> Result<Frame> {
    let mut r = Reader { b: bytes, i: 0 };
    let frame = match r.u8()? {
        TAG_MODEL => {
            let d = r.u32()? as usize;
            let mut x = Vec::with_capacity(d);
            for _ in 0..d {
                x.push(r.f32()? as f64);
            }
            Frame::Model(x)
        }
        TAG_UP => {
            let kind = r.u8()?;
            let loss = r.f64()?;
            let bits = r.u64()?;
            let nnz = r.u32()? as usize;
            let mut idx = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                idx.push(r.u32()?);
            }
            let mut val = Vec::with_capacity(nnz);
            for _ in 0..nnz {
                val.push(r.f32()? as f64);
            }
            let payload = Compressed { sparse: SparseVec::new(idx, val), bits };
            let msg = match kind {
                0 => WireMsg::Sparse(payload),
                1 => WireMsg::Tagged { dcgd_branch: false, payload },
                2 => WireMsg::Tagged { dcgd_branch: true, payload },
                k => bail!("bad Up kind {k}"),
            };
            Frame::Up { msg, loss }
        }
        TAG_STOP => Frame::Stop,
        t => bail!("unknown frame tag {t:#x}"),
    };
    if !r.done() {
        bail!("trailing bytes in frame");
    }
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_msg() -> WireMsg {
        WireMsg::Tagged {
            dcgd_branch: true,
            payload: Compressed {
                sparse: SparseVec::new(vec![1, 5, 9], vec![0.5, -1.25, 3.0]),
                bits: 3 * 64 + 1,
            },
        }
    }

    #[test]
    fn roundtrip_model() {
        let f = Frame::Model(vec![1.0, -2.5, 0.125]);
        match decode(&encode(&f)).unwrap() {
            Frame::Model(x) => assert_eq!(x, vec![1.0, -2.5, 0.125]),
            _ => panic!("wrong frame"),
        }
    }

    #[test]
    fn roundtrip_up() {
        let f = Frame::Up { msg: sample_msg(), loss: 0.75 };
        match decode(&encode(&f)).unwrap() {
            Frame::Up { msg, loss } => {
                assert_eq!(loss, 0.75);
                match msg {
                    WireMsg::Tagged { dcgd_branch, payload } => {
                        assert!(dcgd_branch);
                        assert_eq!(payload.bits, 193);
                        assert_eq!(payload.sparse.idx, vec![1, 5, 9]);
                        assert_eq!(payload.sparse.val, vec![0.5, -1.25, 3.0]);
                    }
                    _ => panic!("wrong msg kind"),
                }
            }
            _ => panic!("wrong frame"),
        }
    }

    #[test]
    fn roundtrip_stop_and_rejects_garbage() {
        assert!(matches!(decode(&encode(&Frame::Stop)).unwrap(), Frame::Stop));
        assert!(decode(&[0xFF]).is_err());
        assert!(decode(&[]).is_err());
        // Truncated Up frame.
        let mut bytes = encode(&Frame::Up { msg: sample_msg(), loss: 0.0 });
        bytes.truncate(bytes.len() - 1);
        assert!(decode(&bytes).is_err());
        // Trailing junk.
        let mut bytes = encode(&Frame::Stop);
        bytes.push(0);
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn payload_bytes_match_accounted_bits() {
        // Up frame payload (idx+val) must be exactly bits/8 rounded up
        // minus the tag bit for sparse messages.
        let sparse = SparseVec::new(vec![0, 1], vec![1.0, 2.0]);
        let bits = sparse.standard_bits();
        let f = Frame::Up {
            msg: WireMsg::Sparse(Compressed { sparse, bits }),
            loss: 0.0,
        };
        let bytes = encode(&f);
        // header: tag(1) + kind(1) + loss(8) + bits(8) + nnz(4) = 22 bytes.
        let payload_bytes = bytes.len() - 22;
        assert_eq!(payload_bytes as u64 * 8, bits);
    }
}
