//! Wire format for protocol frames (explicit little-endian, no serde).
//!
//! Frames:
//!   0x01 Model     : u32 d | d * f32          (master -> worker broadcast)
//!   0x02 Up        : u8 kind | f64 loss | u64 bits | u32 nnz
//!                    | nnz * u32 idx | nnz * f32 val [| f64 health]
//!                    kind low 7 bits: 0 = Sparse, 1 = Markov delta,
//!                    2 = DCGD assign; high bit 0x80 set means a trailing
//!                    f64 health probe (the worker's ||g_i - grad f_i||^2)
//!                    follows the payload — instrumentation, excluded
//!                    from metered bits like `loss`
//!   0x03 Stop      : empty                    (master -> worker shutdown)
//!   0x04 ModelDelta: u32 n_patches | per patch: u32 offset | u32 len
//!                    | len * f32              (blocks past the f32 floor;
//!                    the worker patches its cached model — empty = round
//!                    heartbeat, model unchanged at f32 precision)
//!   0x05 UpBlock   : u8 kind | u32 block | u32 n_blocks | f64 loss
//!                    | u64 bits | u32 nnz | nnz * u32 idx | nnz * f32 val
//!                    (block-tagged uplink: one frame per block, global
//!                    indices; the master reassembles blocks 0..n_blocks
//!                    of one worker into a single message)
//!   0x06 StateSync : u32 d | d * f64           (master -> rejoining worker:
//!                    the tracker-reconstructed Markov state g_i. Full f64,
//!                    unlike the f32 data plane, so a resynced worker is
//!                    bit-identical to one that was merely absent; metered
//!                    as 64*d bits under `sched.resync.bits`)
//!   0x07 CkptReq   : empty                     (master -> worker: reply with
//!                    your opaque checkpoint blob)
//!   0x08 CkptState : u32 len | len bytes       (worker -> master: the blob,
//!                    [`crate::algo::WorkerNode::ckpt_save`])
//!   0x09 Restore   : u32 len | len bytes       (master -> worker at resume:
//!                    | u32 d | d * f64         the blob to load plus the
//!                    exact f64 model image the worker must cache — f64, not
//!                    the f32 data plane, so a resumed delta-broadcast worker
//!                    patches against precisely the pre-crash image)
//!   0x0A SessReq   : u64 sid | u64 from_seq    (either direction: replay
//!                    your session ring from sequence number `from_seq`;
//!                    sent after a CRC reject or as the first frame of a
//!                    RESUME handshake. Never enveloped itself.)
//!   0x0B SessAck   : u64 sid | u64 from_seq    (RESUME reply: the peer
//!                    adopted the reconnected stream; semantically a
//!                    SessReq for the opposite direction, but never
//!                    answered with another ack — that asymmetry is what
//!                    terminates the handshake)
//!
//! Session envelope (`transport/session.rs`): with sessions on, every
//! frame except SessReq/SessAck travels with bit 0x40 set on the tag
//! byte and a 12-byte trailer `u64 seq | u32 crc32` appended; unsealing
//! strips both, so the bytes handed to [`decode`] are exactly the
//! session-off wire format. Tags stop at 0x0B, so bits 0x40 (session)
//! and 0x80 are free on the tag byte; the `Up` HEALTH_FLAG lives on the
//! *kind* byte (offset 1) and never collides.
//!
//! Values travel as f32 — the same precision the bit accounting charges —
//! so the simulated `bits/n` axis and the real byte stream agree (the `Up`
//! frame's payload portion is exactly `bits/8` bytes plus the fixed header;
//! `loss` is instrumentation and excluded from the metered bits).

use crate::algo::WireMsg;
use crate::compress::{Compressed, SparseVec};
use anyhow::{bail, ensure, Result};

pub const TAG_MODEL: u8 = 0x01;
pub const TAG_UP: u8 = 0x02;
pub const TAG_STOP: u8 = 0x03;
pub const TAG_MODEL_DELTA: u8 = 0x04;
pub const TAG_UP_BLOCK: u8 = 0x05;
pub const TAG_STATE_SYNC: u8 = 0x06;
pub const TAG_CKPT_REQ: u8 = 0x07;
pub const TAG_CKPT_STATE: u8 = 0x08;
pub const TAG_RESTORE: u8 = 0x09;
pub const TAG_SESS_REQ: u8 = 0x0A;
pub const TAG_SESS_ACK: u8 = 0x0B;

/// High bit of the `Up` kind byte: a trailing f64 health probe follows
/// the payload. `UpBlock` never sets it (health-on workers send whole
/// `Up` frames instead of splitting).
pub const HEALTH_FLAG: u8 = 0x80;

/// One contiguous patch of a [`Frame::ModelDelta`] broadcast.
#[derive(Clone, Debug, PartialEq)]
pub struct BlockPatch {
    /// First coordinate the patch overwrites.
    pub offset: u32,
    /// New values (f32 on the wire).
    pub vals: Vec<f64>,
}

/// A decoded protocol frame.
#[derive(Clone, Debug)]
pub enum Frame {
    /// Broadcast model (f32 on the wire).
    Model(Vec<f64>),
    /// Worker uplink: message plus piggybacked instrumentation loss and
    /// (with `--health`) the worker's distortion probe `||g_i - grad f_i||^2`.
    Up { msg: WireMsg, loss: f64, health: Option<f64> },
    Stop,
    /// Broadcast delta: only the blocks whose f32 image moved since the
    /// last broadcast. An empty patch list is a heartbeat (the round
    /// still runs on the cached model).
    ModelDelta(Vec<BlockPatch>),
    /// Block-tagged uplink: block `block` of `n_blocks` for this round,
    /// with globally-indexed entries and this block's exact bit cost.
    UpBlock { block: u32, n_blocks: u32, msg: WireMsg, loss: f64 },
    /// Crash-recovery state push (master -> rejoining worker): the
    /// reconstructed worker state, full f64 precision.
    StateSync(Vec<f64>),
    /// Checkpoint request (master -> worker): reply with a CkptState.
    CkptReq,
    /// The worker's opaque checkpoint blob (worker -> master).
    CkptState(Vec<u8>),
    /// Resume push (master -> fresh worker): state blob + the exact f64
    /// model image to cache (replaces init on a resumed run).
    Restore { blob: Vec<u8>, model: Vec<f64> },
    /// Session replay request (either direction): retransmit every ring
    /// frame with sequence number >= `from_seq` for session `sid`.
    SessReq { sid: u64, from_seq: u64 },
    /// Session resume acknowledgement: stream adopted; also a replay
    /// request for the reverse direction (never answered with an ack).
    SessAck { sid: u64, from_seq: u64 },
}

fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

struct Reader<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Reader<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.i + n > self.b.len() {
            bail!("frame truncated at byte {}", self.i);
        }
        let s = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn done(&self) -> bool {
        self.i == self.b.len()
    }

    /// Bytes left — used to clamp `Vec::with_capacity` against declared
    /// counts from untrusted frames (a lying header can force an error
    /// but never an oversized allocation).
    fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    /// Safe pre-allocation for a declared element count: a frame with
    /// `declared` elements of `bytes_per` wire bytes each cannot be
    /// longer than what remains, so the capacity is clamped there — the
    /// one helper behind every decode-side `Vec::with_capacity` (five
    /// hand-rolled `min(remaining / …)` expressions before it).
    fn clamped_cap(&self, declared: usize, bytes_per: usize) -> usize {
        declared.min(self.remaining() / bytes_per)
    }
}

pub fn encode(frame: &Frame) -> Vec<u8> {
    let mut out = Vec::new();
    encode_into(frame, &mut out);
    out
}

/// [`encode`] into a caller-owned buffer (cleared first; its allocation
/// is reused) — the per-connection write path of sustained rounds.
pub fn encode_into(frame: &Frame, out: &mut Vec<u8>) {
    // Handle cached in a static: initialized on the first *enabled* call
    // (t0 is Some only then), so the hot path never repeats the registry
    // lookup and the disabled path is a single atomic load.
    static ENCODE_NS: std::sync::OnceLock<crate::telemetry::Histogram> =
        std::sync::OnceLock::new();
    let t0 = crate::telemetry::maybe_now();
    out.clear();
    encode_impl(frame, out);
    if let Some(t0) = t0 {
        ENCODE_NS
            .get_or_init(|| crate::telemetry::histogram(crate::telemetry::keys::CODEC_ENCODE_NS))
            .record(t0.elapsed().as_nanos() as u64);
    }
}

pub fn decode(bytes: &[u8]) -> Result<Frame> {
    static DECODE_NS: std::sync::OnceLock<crate::telemetry::Histogram> =
        std::sync::OnceLock::new();
    let t0 = crate::telemetry::maybe_now();
    let frame = decode_impl(bytes);
    if let Some(t0) = t0 {
        DECODE_NS
            .get_or_init(|| crate::telemetry::histogram(crate::telemetry::keys::CODEC_DECODE_NS))
            .record(t0.elapsed().as_nanos() as u64);
    }
    frame
}

/// Shared tail of `Up` / `UpBlock`: kind is emitted by the caller.
fn put_msg_body(out: &mut Vec<u8>, payload: &Compressed, loss: f64) {
    put_f64(out, loss);
    put_u64(out, payload.bits);
    put_u32(out, payload.sparse.nnz() as u32);
    for &i in &payload.sparse.idx {
        put_u32(out, i);
    }
    for &v in &payload.sparse.val {
        put_f32(out, v as f32);
    }
}

fn msg_kind(msg: &WireMsg) -> (u8, &Compressed) {
    match msg {
        WireMsg::Sparse(c) => (0u8, c),
        WireMsg::Tagged { dcgd_branch: false, payload } => (1u8, payload),
        WireMsg::Tagged { dcgd_branch: true, payload } => (2u8, payload),
    }
}

fn encode_impl(frame: &Frame, out: &mut Vec<u8>) {
    match frame {
        Frame::Model(x) => {
            out.push(TAG_MODEL);
            put_u32(&mut out, x.len() as u32);
            for &v in x {
                put_f32(&mut out, v as f32);
            }
        }
        Frame::Up { msg, loss, health } => {
            out.push(TAG_UP);
            let (kind, payload) = msg_kind(msg);
            // High bit of the kind byte flags a trailing health probe.
            out.push(kind | if health.is_some() { HEALTH_FLAG } else { 0 });
            put_msg_body(&mut out, payload, *loss);
            if let Some(h) = health {
                put_f64(&mut out, *h);
            }
        }
        Frame::Stop => out.push(TAG_STOP),
        Frame::ModelDelta(patches) => {
            out.push(TAG_MODEL_DELTA);
            put_u32(&mut out, patches.len() as u32);
            for p in patches {
                put_u32(&mut out, p.offset);
                put_u32(&mut out, p.vals.len() as u32);
                for &v in &p.vals {
                    put_f32(&mut out, v as f32);
                }
            }
        }
        Frame::UpBlock { block, n_blocks, msg, loss } => {
            out.push(TAG_UP_BLOCK);
            let (kind, payload) = msg_kind(msg);
            out.push(kind);
            put_u32(&mut out, *block);
            put_u32(&mut out, *n_blocks);
            put_msg_body(&mut out, payload, *loss);
        }
        Frame::StateSync(g) => {
            out.push(TAG_STATE_SYNC);
            put_u32(&mut out, g.len() as u32);
            for &v in g {
                put_f64(&mut out, v);
            }
        }
        Frame::CkptReq => out.push(TAG_CKPT_REQ),
        Frame::CkptState(blob) => {
            out.push(TAG_CKPT_STATE);
            put_u32(&mut out, blob.len() as u32);
            out.extend_from_slice(blob);
        }
        Frame::Restore { blob, model } => {
            out.push(TAG_RESTORE);
            put_u32(&mut out, blob.len() as u32);
            out.extend_from_slice(blob);
            put_u32(&mut out, model.len() as u32);
            for &v in model {
                put_f64(&mut out, v);
            }
        }
        Frame::SessReq { sid, from_seq } => {
            out.push(TAG_SESS_REQ);
            put_u64(&mut out, *sid);
            put_u64(&mut out, *from_seq);
        }
        Frame::SessAck { sid, from_seq } => {
            out.push(TAG_SESS_ACK);
            put_u64(&mut out, *sid);
            put_u64(&mut out, *from_seq);
        }
    }
}

/// Shared tail of `Up` / `UpBlock` decoding (after the kind byte and any
/// block tags): loss, bits, and the sparse payload.
fn take_msg_body(r: &mut Reader<'_>, kind: u8) -> Result<(WireMsg, f64)> {
    let loss = r.f64()?;
    let bits = r.u64()?;
    let nnz = r.u32()? as usize;
    let mut idx = Vec::with_capacity(r.clamped_cap(nnz, 4));
    for _ in 0..nnz {
        idx.push(r.u32()?);
    }
    let mut val = Vec::with_capacity(r.clamped_cap(nnz, 4));
    for _ in 0..nnz {
        val.push(r.f32()? as f64);
    }
    ensure!(
        idx.windows(2).all(|w| w[0] < w[1]),
        "uplink indices not strictly increasing"
    );
    let payload = Compressed { sparse: SparseVec::new(idx, val), bits };
    let msg = match kind {
        0 => WireMsg::Sparse(payload),
        1 => WireMsg::Tagged { dcgd_branch: false, payload },
        2 => WireMsg::Tagged { dcgd_branch: true, payload },
        k => bail!("bad Up kind {k}"),
    };
    Ok((msg, loss))
}

fn decode_impl(bytes: &[u8]) -> Result<Frame> {
    let mut r = Reader { b: bytes, i: 0 };
    let frame = match r.u8()? {
        TAG_MODEL => {
            let d = r.u32()? as usize;
            let mut x = Vec::with_capacity(r.clamped_cap(d, 4));
            for _ in 0..d {
                x.push(r.f32()? as f64);
            }
            Frame::Model(x)
        }
        TAG_UP => {
            let kind = r.u8()?;
            let (msg, loss) = take_msg_body(&mut r, kind & !HEALTH_FLAG)?;
            let health =
                if kind & HEALTH_FLAG != 0 { Some(r.f64()?) } else { None };
            Frame::Up { msg, loss, health }
        }
        TAG_STOP => Frame::Stop,
        TAG_MODEL_DELTA => {
            let n = r.u32()? as usize;
            let mut patches = Vec::with_capacity(r.clamped_cap(n, 8));
            let mut next_free = 0u64;
            for _ in 0..n {
                let offset = r.u32()?;
                let len = r.u32()? as usize;
                ensure!(len >= 1, "empty ModelDelta patch");
                ensure!(
                    offset as u64 >= next_free,
                    "ModelDelta patches overlap or are out of order"
                );
                next_free = offset as u64 + len as u64;
                let mut vals = Vec::with_capacity(r.clamped_cap(len, 4));
                for _ in 0..len {
                    vals.push(r.f32()? as f64);
                }
                patches.push(BlockPatch { offset, vals });
            }
            Frame::ModelDelta(patches)
        }
        TAG_UP_BLOCK => {
            let kind = r.u8()?;
            let block = r.u32()?;
            let n_blocks = r.u32()?;
            ensure!(block < n_blocks, "UpBlock tag {block} out of range (n={n_blocks})");
            let (msg, loss) = take_msg_body(&mut r, kind)?;
            Frame::UpBlock { block, n_blocks, msg, loss }
        }
        TAG_STATE_SYNC => {
            let d = r.u32()? as usize;
            let mut g = Vec::with_capacity(r.clamped_cap(d, 8));
            for _ in 0..d {
                g.push(r.f64()?);
            }
            Frame::StateSync(g)
        }
        TAG_CKPT_REQ => Frame::CkptReq,
        TAG_CKPT_STATE => {
            let n = r.u32()? as usize;
            Frame::CkptState(r.take(n)?.to_vec())
        }
        TAG_RESTORE => {
            let n = r.u32()? as usize;
            let blob = r.take(n)?.to_vec();
            let d = r.u32()? as usize;
            let mut model = Vec::with_capacity(r.clamped_cap(d, 8));
            for _ in 0..d {
                model.push(r.f64()?);
            }
            Frame::Restore { blob, model }
        }
        TAG_SESS_REQ => {
            let sid = r.u64()?;
            let from_seq = r.u64()?;
            Frame::SessReq { sid, from_seq }
        }
        TAG_SESS_ACK => {
            let sid = r.u64()?;
            let from_seq = r.u64()?;
            Frame::SessAck { sid, from_seq }
        }
        t => bail!("unknown frame tag {t:#x}"),
    };
    if !r.done() {
        bail!("trailing bytes in frame");
    }
    Ok(frame)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_msg() -> WireMsg {
        WireMsg::Tagged {
            dcgd_branch: true,
            payload: Compressed {
                sparse: SparseVec::new(vec![1, 5, 9], vec![0.5, -1.25, 3.0]),
                bits: 3 * 64 + 1,
            },
        }
    }

    #[test]
    fn roundtrip_model() {
        let f = Frame::Model(vec![1.0, -2.5, 0.125]);
        match decode(&encode(&f)).unwrap() {
            Frame::Model(x) => assert_eq!(x, vec![1.0, -2.5, 0.125]),
            _ => panic!("wrong frame"),
        }
    }

    #[test]
    fn roundtrip_up() {
        let f = Frame::Up { msg: sample_msg(), loss: 0.75, health: None };
        match decode(&encode(&f)).unwrap() {
            Frame::Up { msg, loss, health } => {
                assert_eq!(loss, 0.75);
                assert_eq!(health, None);
                match msg {
                    WireMsg::Tagged { dcgd_branch, payload } => {
                        assert!(dcgd_branch);
                        assert_eq!(payload.bits, 193);
                        assert_eq!(payload.sparse.idx, vec![1, 5, 9]);
                        assert_eq!(payload.sparse.val, vec![0.5, -1.25, 3.0]);
                    }
                    _ => panic!("wrong msg kind"),
                }
            }
            _ => panic!("wrong frame"),
        }
    }

    #[test]
    fn roundtrip_stop_and_rejects_garbage() {
        assert!(matches!(decode(&encode(&Frame::Stop)).unwrap(), Frame::Stop));
        assert!(decode(&[0xFF]).is_err());
        assert!(decode(&[]).is_err());
        // Truncated Up frame.
        let mut bytes = encode(&Frame::Up { msg: sample_msg(), loss: 0.0, health: None });
        bytes.truncate(bytes.len() - 1);
        assert!(decode(&bytes).is_err());
        // Trailing junk.
        let mut bytes = encode(&Frame::Stop);
        bytes.push(0);
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn roundtrip_model_delta() {
        let f = Frame::ModelDelta(vec![
            BlockPatch { offset: 0, vals: vec![1.0, -2.5] },
            BlockPatch { offset: 7, vals: vec![0.125] },
        ]);
        match decode(&encode(&f)).unwrap() {
            Frame::ModelDelta(patches) => {
                assert_eq!(patches.len(), 2);
                assert_eq!(patches[0].offset, 0);
                assert_eq!(patches[0].vals, vec![1.0, -2.5]);
                assert_eq!(patches[1].offset, 7);
                assert_eq!(patches[1].vals, vec![0.125]);
            }
            _ => panic!("wrong frame"),
        }
        // Heartbeat (no patches) is legal.
        assert!(matches!(
            decode(&encode(&Frame::ModelDelta(Vec::new()))).unwrap(),
            Frame::ModelDelta(p) if p.is_empty()
        ));
    }

    #[test]
    fn roundtrip_up_block() {
        let f = Frame::UpBlock { block: 2, n_blocks: 5, msg: sample_msg(), loss: -1.5 };
        match decode(&encode(&f)).unwrap() {
            Frame::UpBlock { block, n_blocks, msg, loss } => {
                assert_eq!((block, n_blocks), (2, 5));
                assert_eq!(loss, -1.5);
                assert_eq!(msg.bits(), 3 * 64 + 1 + 1);
            }
            _ => panic!("wrong frame"),
        }
        // Out-of-range block tag is rejected.
        let bad = Frame::UpBlock { block: 5, n_blocks: 5, msg: sample_msg(), loss: 0.0 };
        assert!(decode(&encode(&bad)).is_err());
    }

    #[test]
    fn model_delta_rejects_overlapping_patches() {
        let f = Frame::ModelDelta(vec![
            BlockPatch { offset: 4, vals: vec![1.0, 2.0] },
            BlockPatch { offset: 5, vals: vec![3.0] },
        ]);
        assert!(decode(&encode(&f)).is_err());
    }

    #[test]
    fn roundtrip_state_sync_is_f64_exact() {
        // StateSync must NOT go through the f32 wire precision of the
        // data plane: resync exactness depends on it.
        let g = vec![1.0, -2.5e-300, std::f64::consts::PI, 0.0, f64::MIN_POSITIVE];
        match decode(&encode(&Frame::StateSync(g.clone()))).unwrap() {
            Frame::StateSync(out) => {
                assert_eq!(out.len(), g.len());
                for (a, b) in out.iter().zip(&g) {
                    assert_eq!(a.to_bits(), b.to_bits());
                }
            }
            _ => panic!("wrong frame"),
        }
        // Empty state and truncation behave like the other frames.
        assert!(matches!(
            decode(&encode(&Frame::StateSync(Vec::new()))).unwrap(),
            Frame::StateSync(g) if g.is_empty()
        ));
        let mut bytes = encode(&Frame::StateSync(vec![1.0, 2.0]));
        bytes.truncate(bytes.len() - 3);
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn roundtrip_checkpoint_frames() {
        assert!(matches!(decode(&encode(&Frame::CkptReq)).unwrap(), Frame::CkptReq));
        let blob = vec![0x21u8, 0xFF, 0x00, 0x7A];
        match decode(&encode(&Frame::CkptState(blob.clone()))).unwrap() {
            Frame::CkptState(b) => assert_eq!(b, blob),
            _ => panic!("wrong frame"),
        }
        // Restore carries the model in exact f64 (not the f32 data plane).
        let model = vec![1.0, -2.5e-300, std::f64::consts::PI];
        match decode(&encode(&Frame::Restore { blob: blob.clone(), model: model.clone() }))
            .unwrap()
        {
            Frame::Restore { blob: b, model: m } => {
                assert_eq!(b, blob);
                for (a, x) in m.iter().zip(&model) {
                    assert_eq!(a.to_bits(), x.to_bits());
                }
            }
            _ => panic!("wrong frame"),
        }
        // Truncated blob length is rejected.
        let mut bytes = encode(&Frame::CkptState(blob));
        bytes.truncate(bytes.len() - 2);
        assert!(decode(&bytes).is_err());
    }

    #[test]
    fn encode_into_matches_encode_and_reuses_buffer() {
        let frames = [
            Frame::Model(vec![1.0, -2.5]),
            Frame::Up { msg: sample_msg(), loss: 0.5, health: Some(0.125) },
            Frame::Stop,
            Frame::StateSync(vec![0.25; 3]),
        ];
        let mut buf = Vec::new();
        // Pre-grow so every later encode fits in place.
        encode_into(&Frame::StateSync(vec![0.0; 64]), &mut buf);
        let cap = buf.capacity();
        let ptr = buf.as_ptr();
        for f in &frames {
            encode_into(f, &mut buf);
            assert_eq!(buf, encode(f), "encode_into drifted from encode");
            assert_eq!(buf.capacity(), cap, "buffer was reallocated");
            assert_eq!(buf.as_ptr(), ptr);
        }
    }

    #[test]
    fn roundtrip_up_with_health_probe() {
        // Health probe travels as exact f64 after the payload; the
        // flagged frame costs exactly 8 bytes more than the plain one.
        let plain = encode(&Frame::Up { msg: sample_msg(), loss: 0.75, health: None });
        let probe = 1.25e-7_f64;
        let f = Frame::Up { msg: sample_msg(), loss: 0.75, health: Some(probe) };
        let bytes = encode(&f);
        assert_eq!(bytes.len(), plain.len() + 8);
        assert_eq!(bytes[1] & HEALTH_FLAG, HEALTH_FLAG);
        match decode(&bytes).unwrap() {
            Frame::Up { msg, loss, health } => {
                assert_eq!(loss, 0.75);
                assert_eq!(health.unwrap().to_bits(), probe.to_bits());
                assert_eq!(msg.bits(), 3 * 64 + 1 + 1);
            }
            _ => panic!("wrong frame"),
        }
        // Truncating the trailing probe is rejected.
        let mut cut = bytes.clone();
        cut.truncate(cut.len() - 1);
        assert!(decode(&cut).is_err());
        // A flagged UpBlock kind byte is rejected (blocks never carry it).
        let mut blk = encode(&Frame::UpBlock { block: 0, n_blocks: 2, msg: sample_msg(), loss: 0.0 });
        blk[1] |= HEALTH_FLAG;
        assert!(decode(&blk).is_err());
    }

    #[test]
    fn roundtrip_session_frames() {
        let req = Frame::SessReq { sid: 0xDEAD_BEEF_0BAD_F00D, from_seq: 17 };
        match decode(&encode(&req)).unwrap() {
            Frame::SessReq { sid, from_seq } => {
                assert_eq!(sid, 0xDEAD_BEEF_0BAD_F00D);
                assert_eq!(from_seq, 17);
            }
            _ => panic!("wrong frame"),
        }
        let ack = Frame::SessAck { sid: 7, from_seq: u64::MAX };
        match decode(&encode(&ack)).unwrap() {
            Frame::SessAck { sid, from_seq } => {
                assert_eq!(sid, 7);
                assert_eq!(from_seq, u64::MAX);
            }
            _ => panic!("wrong frame"),
        }
        // Fixed 17-byte layout; truncation is rejected.
        let bytes = encode(&req);
        assert_eq!(bytes.len(), 17);
        assert!(decode(&bytes[..16]).is_err());
    }

    #[test]
    fn payload_bytes_match_accounted_bits() {
        // Up frame payload (idx+val) must be exactly bits/8 rounded up
        // minus the tag bit for sparse messages.
        let sparse = SparseVec::new(vec![0, 1], vec![1.0, 2.0]);
        let bits = sparse.standard_bits();
        let f = Frame::Up {
            msg: WireMsg::Sparse(Compressed { sparse, bits }),
            loss: 0.0,
            health: None,
        };
        let bytes = encode(&f);
        // header: tag(1) + kind(1) + loss(8) + bits(8) + nnz(4) = 22 bytes.
        let payload_bytes = bytes.len() - 22;
        assert_eq!(payload_bytes as u64 * 8, bits);
    }
}
