//! Seeded wire-level chaos proxy: a [`Conn`] wrapper around the
//! *worker-side* endpoint that injects frame loss, single-bit
//! corruption, stalls, and permanent link death — deterministically from
//! `(spec, seed, worker, round)`, so a chaos run is exactly repeatable
//! and its recovered trajectory can be asserted bitwise against the
//! fault-free run.
//!
//! Grammar (clauses comma-separated, same splitter as the fault DSL):
//!
//! ```text
//!   reset(w@r)          one frame of worker w's round r evaporates in
//!                       flight; a seeded coin picks the direction (the
//!                       round's model broadcast or the worker's uplink).
//!                       On a redial-capable transport the socket is
//!                       severed too, forcing the full RESUME handshake;
//!                       otherwise the session layer retransmits over the
//!                       live conn.
//!   corrupt(w@r)        one seeded bit flip in a round-r frame (direction
//!                       by the same coin) — the CRC envelope must detect
//!                       it and the session layer re-request the frame.
//!   stall(w,r0..r1,MSms) worker w sleeps MS ms before each uplink of
//!                       rounds r0..=r1 (real wall-clock; trajectory
//!                       unchanged).
//!   down(w@r)           worker w's link dies permanently when round r's
//!                       broadcast arrives — the deterministic trigger for
//!                       the `--on-worker-loss` policies.
//! ```
//!
//! Wrapping only the worker endpoints still exercises every detection
//! site: a tx-corrupt is caught by the *master's* CRC check, an
//! rx-corrupt by the worker's, and reset recovery runs in both
//! directions. Rounds are counted autonomously from the downlink: each
//! *new* (by envelope sequence) `Model`/`ModelDelta` frame opens the next
//! round, so the proxy needs no side channel to the scheduler — which is
//! also why chaos requires full participation and the session envelope
//! (both validated at the CLI).

use super::codec::{TAG_MODEL, TAG_MODEL_DELTA, TAG_SESS_ACK, TAG_SESS_REQ, TAG_UP, TAG_UP_BLOCK};
use super::session::{crc32, TransientLoss, SESS_FLAG, TRAILER};
use super::Conn;
use crate::sched::faults::{parse_call, parse_worker_round, split_clauses};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};
use std::sync::{Arc, Mutex};
use std::time::Duration;

/// One stall window: uplinks of rounds `from..=to` sleep `delay_ms`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Stall {
    pub worker: usize,
    pub from: usize,
    pub to: usize,
    pub delay_ms: u64,
}

/// A parsed, validated chaos schedule. Excluded from run fingerprints by
/// construction (a recovered run must share the fault-free identity).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct ChaosPlan {
    resets: Vec<(usize, usize)>,
    corrupts: Vec<(usize, usize)>,
    stalls: Vec<Stall>,
    downs: Vec<(usize, usize)>,
    /// CRC32 of the cleaned spec, folded into every fault-site RNG so
    /// distinct specs realize distinct direction/bit choices.
    spec_hash: u32,
}

impl ChaosPlan {
    pub fn parse(spec: &str) -> Result<ChaosPlan> {
        let cleaned: String = spec.chars().filter(|c| !c.is_whitespace()).collect();
        let mut plan = ChaosPlan { spec_hash: crc32(cleaned.as_bytes()), ..Default::default() };
        if cleaned.is_empty() || cleaned == "none" {
            return Ok(plan);
        }
        for clause in split_clauses(&cleaned) {
            if clause.is_empty() {
                continue;
            }
            if let Some(args) = parse_call(clause, "reset") {
                plan.resets.push(parse_worker_round(args, clause)?);
                continue;
            }
            if let Some(args) = parse_call(clause, "corrupt") {
                plan.corrupts.push(parse_worker_round(args, clause)?);
                continue;
            }
            if let Some(args) = parse_call(clause, "down") {
                plan.downs.push(parse_worker_round(args, clause)?);
                continue;
            }
            if let Some(args) = parse_call(clause, "stall") {
                let parts: Vec<&str> = args.split(',').collect();
                ensure!(parts.len() == 3, "stall needs (worker, r0..r1, delay_ms): '{clause}'");
                let worker: usize =
                    parts[0].parse().map_err(|_| anyhow::anyhow!("bad worker in '{clause}'"))?;
                let (from, to) = parts[1]
                    .split_once("..")
                    .ok_or_else(|| anyhow::anyhow!("bad round range in '{clause}'"))?;
                let from: usize =
                    from.parse().map_err(|_| anyhow::anyhow!("bad range start in '{clause}'"))?;
                let to: usize =
                    to.parse().map_err(|_| anyhow::anyhow!("bad range end in '{clause}'"))?;
                ensure!(from <= to, "stall range {from}..{to} is empty in '{clause}'");
                let ms = parts[2].strip_suffix("ms").unwrap_or(parts[2]);
                let delay_ms: u64 =
                    ms.parse().map_err(|_| anyhow::anyhow!("bad delay in '{clause}'"))?;
                ensure!(delay_ms > 0, "stall delay must be positive in '{clause}'");
                plan.stalls.push(Stall { worker, from, to, delay_ms });
                continue;
            }
            bail!(
                "unknown chaos clause '{clause}' (expected reset(<w>@<r>), \
                 corrupt(<w>@<r>), stall(<w>,<r0>..<r1>,<ms>ms), down(<w>@<r>))"
            );
        }
        // A downed worker can't also suffer later recoverable faults.
        for &(w, r) in &plan.downs {
            for &(w2, r2) in plan.resets.iter().chain(&plan.corrupts) {
                ensure!(
                    w2 != w || r2 < r,
                    "chaos plan: worker {w} is down from round {r} but has a \
                     recoverable fault at round {r2}"
                );
            }
        }
        Ok(plan)
    }

    pub fn is_empty(&self) -> bool {
        self.resets.is_empty()
            && self.corrupts.is_empty()
            && self.stalls.is_empty()
            && self.downs.is_empty()
    }

    /// Largest worker index referenced (for validation against n).
    pub fn max_worker(&self) -> Option<usize> {
        self.resets
            .iter()
            .chain(&self.corrupts)
            .chain(&self.downs)
            .map(|&(w, _)| w)
            .chain(self.stalls.iter().map(|s| s.worker))
            .max()
    }

    /// Any permanent link death scheduled?
    pub fn has_downs(&self) -> bool {
        !self.downs.is_empty()
    }

    /// The round worker `w` goes permanently dark, if any.
    pub fn down_round(&self, w: usize) -> Option<usize> {
        self.downs.iter().filter(|&&(dw, _)| dw == w).map(|&(_, r)| r).min()
    }

    /// Largest stall a single round can sleep (timeout validation).
    pub fn max_stall_ms(&self) -> u64 {
        self.stalls.iter().map(|s| s.delay_ms).max().unwrap_or(0)
    }

    fn reset_at(&self, w: usize, r: usize) -> bool {
        self.resets.contains(&(w, r))
    }

    fn corrupt_at(&self, w: usize, r: usize) -> bool {
        self.corrupts.contains(&(w, r))
    }

    fn stall_ms(&self, w: usize, r: usize) -> u64 {
        self.stalls
            .iter()
            .filter(|s| s.worker == w && s.from <= r && r <= s.to)
            .map(|s| s.delay_ms)
            .sum()
    }
}

/// Permanent injected link death (`down(w@r)`): not recoverable by the
/// session layer; surfaces to the master as worker loss and is governed
/// by `--on-worker-loss`.
#[derive(Debug, Clone, Copy)]
pub struct LinkDown {
    pub worker: usize,
    pub round: usize,
}

impl std::fmt::Display for LinkDown {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "chaos: worker {} link down since round {}", self.worker, self.round)
    }
}

impl std::error::Error for LinkDown {}

/// Injection site kinds, folded into the fault-site RNG seed.
const KIND_RESET: u64 = 1;
const KIND_CORRUPT: u64 = 2;

/// Fault-tracking state that must survive a redial: when the session
/// layer replaces a severed socket, the fresh [`ChaosConn`] wrapper is
/// built with [`ChaosConn::with_state`] over the *same* shared state, so
/// round counting and one-shot fault bookkeeping continue seamlessly.
#[derive(Default)]
pub struct ChaosState {
    /// Sealed model frames counted so far (dedup'd by envelope seq):
    /// the k-th opens round k-1 (the first is the init broadcast).
    models_seen: u64,
    /// Highest envelope seq among counted model frames — replayed
    /// duplicates carry older seqs and must not advance the round.
    last_model_seq: Option<u64>,
    /// Consumed one-shot fault sites (kind, round).
    fired: Vec<(u64, usize)>,
    /// Last round whose stall already slept (one sleep per round even
    /// when the uplink is retransmitted).
    stalled_round: Option<usize>,
    down: bool,
}

/// Shared handle to a worker's [`ChaosState`], cloned into redial
/// closures so reconnection preserves fault progress.
pub type SharedChaosState = Arc<Mutex<ChaosState>>;

/// The chaos proxy. Sits *under* the worker's `SessionConn` (it mangles
/// sealed wire bytes) and above the raw transport.
pub struct ChaosConn {
    inner: Box<dyn Conn>,
    plan: Arc<ChaosPlan>,
    worker: usize,
    seed: u64,
    state: SharedChaosState,
    /// Sever the real transport on reset/down (redial-capable paths).
    hard: bool,
}

impl ChaosConn {
    pub fn new(
        inner: Box<dyn Conn>,
        plan: Arc<ChaosPlan>,
        worker: usize,
        seed: u64,
        hard: bool,
    ) -> ChaosConn {
        Self::with_state(inner, plan, worker, seed, hard, Arc::default())
    }

    /// Wrap a (fresh) transport while continuing from existing shared
    /// fault state — the redial path.
    pub fn with_state(
        inner: Box<dyn Conn>,
        plan: Arc<ChaosPlan>,
        worker: usize,
        seed: u64,
        hard: bool,
        state: SharedChaosState,
    ) -> ChaosConn {
        ChaosConn { inner, plan, worker, seed, state, hard }
    }

    /// The shared fault state, for re-wrapping after a redial.
    pub fn shared_state(&self) -> SharedChaosState {
        self.state.clone()
    }

    /// Deterministic per-site RNG: every direction and bit choice derives
    /// only from (spec, seed, worker, round, kind).
    fn site_rng(&self, kind: u64, round: usize) -> Rng {
        Rng::seed(
            self.seed
                ^ (u64::from(self.plan.spec_hash) << 16)
                ^ ((self.worker as u64) << 40)
                ^ ((round as u64) << 4)
                ^ kind,
        )
    }

    /// Does the (kind, round) site inject on the uplink (tx) direction?
    fn dir_is_tx(&self, kind: u64, round: usize) -> bool {
        self.site_rng(kind, round).next_u64() & 1 == 1
    }

    fn consume(st: &mut ChaosState, kind: u64, round: usize) -> bool {
        if st.fired.contains(&(kind, round)) {
            return false;
        }
        st.fired.push((kind, round));
        true
    }

    /// Round the worker's *next uplink* belongs to (`None` during init).
    fn current_round(&self) -> Option<usize> {
        self.state.lock().expect("chaos state poisoned").current_round()
    }

    fn down_err(&self, round: usize) -> anyhow::Error {
        anyhow::Error::new(LinkDown { worker: self.worker, round })
    }
}

impl ChaosState {
    fn current_round(&self) -> Option<usize> {
        (self.models_seen >= 2).then(|| self.models_seen as usize - 2)
    }
}

impl Conn for ChaosConn {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let mut st = self.state.lock().expect("chaos state poisoned");
        if st.down {
            bail!(self.down_err(self.plan.down_round(self.worker).unwrap_or(0)));
        }
        let tag = frame.first().copied().unwrap_or(0) & !SESS_FLAG;
        let is_up = tag == TAG_UP || tag == TAG_UP_BLOCK;
        if let (true, Some(r)) = (is_up, st.current_round()) {
            let stall = self.plan.stall_ms(self.worker, r);
            if stall > 0 && st.stalled_round != Some(r) {
                st.stalled_round = Some(r);
                std::thread::sleep(Duration::from_millis(stall));
            }
            if self.plan.corrupt_at(self.worker, r)
                && self.dir_is_tx(KIND_CORRUPT, r)
                && Self::consume(&mut st, KIND_CORRUPT, r)
            {
                let mut mangled = frame.to_vec();
                let bit = self.site_rng(KIND_CORRUPT, r).fork(1).next_below(mangled.len() * 8);
                mangled[bit / 8] ^= 1 << (bit % 8);
                return self.inner.send(&mangled);
            }
            if self.plan.reset_at(self.worker, r)
                && self.dir_is_tx(KIND_RESET, r)
                && Self::consume(&mut st, KIND_RESET, r)
            {
                // The frame evaporates. On a redial path the socket dies
                // with it; otherwise the session retransmits in place.
                if self.hard {
                    self.inner.sever();
                    bail!("chaos: injected connection reset (worker {}, round {r})", self.worker);
                }
                return Err(anyhow::Error::new(TransientLoss));
            }
        }
        self.inner.send(frame)
    }

    fn recv_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        {
            let st = self.state.lock().expect("chaos state poisoned");
            if st.down {
                bail!(self.down_err(self.plan.down_round(self.worker).unwrap_or(0)));
            }
        }
        self.inner.recv_into(buf)?;
        let tag = buf.first().copied().unwrap_or(0);
        if tag == TAG_SESS_REQ || tag == TAG_SESS_ACK {
            return Ok(()); // the recovery channel itself is never mangled
        }
        let masked = tag & !SESS_FLAG;
        let sealed_model = (masked == TAG_MODEL || masked == TAG_MODEL_DELTA)
            && tag & SESS_FLAG != 0
            && buf.len() >= 1 + TRAILER;
        if sealed_model {
            let body = buf.len() - 4;
            let seq = u64::from_le_bytes(buf[body - 8..body].try_into().expect("len checked"));
            let mut st = self.state.lock().expect("chaos state poisoned");
            if st.last_model_seq.map_or(true, |s| seq > s) {
                // A NEW model frame: it would open round `models_seen - 1`.
                let opens = st.models_seen as i64 - 1;
                if opens >= 0 {
                    let r = opens as usize;
                    if self.plan.down_round(self.worker) == Some(r) {
                        st.down = true;
                        if self.hard {
                            self.inner.sever();
                        }
                        return Err(self.down_err(r));
                    }
                    if self.plan.corrupt_at(self.worker, r)
                        && !self.dir_is_tx(KIND_CORRUPT, r)
                        && Self::consume(&mut st, KIND_CORRUPT, r)
                    {
                        // Deliver damaged; the clean replay (same seq)
                        // will be counted instead.
                        let bit =
                            self.site_rng(KIND_CORRUPT, r).fork(1).next_below(buf.len() * 8);
                        buf[bit / 8] ^= 1 << (bit % 8);
                        return Ok(());
                    }
                    if self.plan.reset_at(self.worker, r)
                        && !self.dir_is_tx(KIND_RESET, r)
                        && Self::consume(&mut st, KIND_RESET, r)
                    {
                        buf.clear();
                        if self.hard {
                            self.inner.sever();
                            bail!(
                                "chaos: injected connection reset (worker {}, round {r})",
                                self.worker
                            );
                        }
                        return Err(anyhow::Error::new(TransientLoss));
                    }
                }
                st.models_seen += 1;
                st.last_model_seq = Some(seq);
            }
        }
        Ok(())
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.recv_into(&mut buf)?;
        Ok(buf)
    }

    fn sever(&mut self) {
        self.inner.sever();
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_the_full_grammar() {
        let p = ChaosPlan::parse("reset(0@5), corrupt(1@9), stall(2,3..6,40ms), down(3@7)")
            .unwrap();
        assert!(p.reset_at(0, 5) && !p.reset_at(0, 4));
        assert!(p.corrupt_at(1, 9));
        assert_eq!(p.stall_ms(2, 3), 40);
        assert_eq!(p.stall_ms(2, 6), 40);
        assert_eq!(p.stall_ms(2, 7), 0);
        assert_eq!(p.down_round(3), Some(7));
        assert_eq!(p.down_round(0), None);
        assert_eq!(p.max_worker(), Some(3));
        assert_eq!(p.max_stall_ms(), 40);
        assert!(p.has_downs());
        assert!(!p.is_empty());
    }

    #[test]
    fn empty_and_invalid_specs() {
        assert!(ChaosPlan::parse("").unwrap().is_empty());
        assert!(ChaosPlan::parse("none").unwrap().is_empty());
        assert!(ChaosPlan::parse("explode(0@1)").is_err());
        assert!(ChaosPlan::parse("reset(0)").is_err());
        assert!(ChaosPlan::parse("stall(0,5..2,10ms)").is_err());
        assert!(ChaosPlan::parse("stall(0,1..2,0ms)").is_err());
        // A recoverable fault after the link is down can never fire.
        assert!(ChaosPlan::parse("down(0@3),reset(0@5)").is_err());
        assert!(ChaosPlan::parse("down(0@5),reset(0@3)").is_ok());
    }

    #[test]
    fn direction_and_bit_choices_are_deterministic() {
        let p = std::sync::Arc::new(ChaosPlan::parse("corrupt(1@4)").unwrap());
        let (_, w) = crate::transport::local::pair();
        let a = ChaosConn::new(Box::new(w), p.clone(), 1, 7, false);
        assert_eq!(a.dir_is_tx(KIND_CORRUPT, 4), a.dir_is_tx(KIND_CORRUPT, 4));
        // Distinct specs with the same clause realize independent coins
        // somewhere — at minimum the spec hash differs.
        let q = ChaosPlan::parse("corrupt(1@4),stall(0,1..1,5ms)").unwrap();
        assert_ne!(p.spec_hash, q.spec_hash);
    }

    #[test]
    fn counts_rounds_by_new_model_frames_only() {
        use crate::transport::codec::{encode, Frame};
        use crate::transport::session::seal;
        let plan = std::sync::Arc::new(ChaosPlan::parse("none").unwrap());
        let (mut m, w) = crate::transport::local::pair();
        let mut c = ChaosConn::new(Box::new(w), plan, 0, 1, false);
        let model = encode(&Frame::Model(vec![1.0]));
        m.send(&seal(&model, 0)).unwrap(); // init
        m.send(&seal(&model, 1)).unwrap(); // round 0
        m.send(&seal(&model, 1)).unwrap(); // replayed duplicate
        m.send(&seal(&model, 2)).unwrap(); // round 1
        for _ in 0..4 {
            c.recv().unwrap();
        }
        assert_eq!(c.current_round(), Some(1), "duplicate must not advance the round");
    }

    #[test]
    fn shared_state_survives_rewrap() {
        use crate::transport::codec::{encode, Frame};
        use crate::transport::session::seal;
        let plan = Arc::new(ChaosPlan::parse("none").unwrap());
        let (mut m1, w1) = crate::transport::local::pair();
        let mut c1 = ChaosConn::new(Box::new(w1), plan.clone(), 0, 1, false);
        let model = encode(&Frame::Model(vec![1.0]));
        m1.send(&seal(&model, 0)).unwrap(); // init
        m1.send(&seal(&model, 1)).unwrap(); // round 0
        c1.recv().unwrap();
        c1.recv().unwrap();
        // "Redial": fresh transport, same shared fault state.
        let (mut m2, w2) = crate::transport::local::pair();
        let mut c2 =
            ChaosConn::with_state(Box::new(w2), plan, 0, 1, false, c1.shared_state());
        m2.send(&seal(&model, 2)).unwrap(); // round 1
        c2.recv().unwrap();
        assert_eq!(c2.current_round(), Some(1), "round count continues across rewrap");
    }

    #[test]
    fn down_kills_the_link_permanently() {
        use crate::transport::codec::{encode, Frame};
        use crate::transport::session::seal;
        let plan = std::sync::Arc::new(ChaosPlan::parse("down(0@1)").unwrap());
        let (mut m, w) = crate::transport::local::pair();
        let mut c = ChaosConn::new(Box::new(w), plan, 0, 1, false);
        let model = encode(&Frame::Model(vec![1.0]));
        m.send(&seal(&model, 0)).unwrap(); // init
        m.send(&seal(&model, 1)).unwrap(); // round 0
        m.send(&seal(&model, 2)).unwrap(); // round 1 -> down
        c.recv().unwrap();
        c.recv().unwrap();
        let err = c.recv().expect_err("round-1 model must kill the link");
        let down = err.downcast_ref::<LinkDown>().expect("typed LinkDown");
        assert_eq!((down.worker, down.round), (0, 1));
        assert!(c.recv().is_err(), "dead is dead");
        assert!(c.send(b"\x02").is_err());
    }
}
