//! Downlink (master → worker broadcast) accounting and delta planning.
//!
//! Until the block refactor the master re-broadcast all `d` coordinates
//! as dense f32 every round and nobody metered it; uplink bits were
//! tracked to the single bit while the downlink was invisible. A
//! [`DownlinkMeter`] closes that gap:
//!
//! * **dense mode** (flat layouts) — charges the legacy `32·d` payload
//!   bits per broadcast, so `transport.downlink.bits` finally sits next
//!   to `transport.uplink.bits` in the telemetry snapshot;
//! * **delta mode** (blocked layouts) — per round, re-quantizes the
//!   model to f32 (the wire precision) and marks a block *changed* only
//!   if some coordinate's f32 image differs from the last broadcast —
//!   i.e. the update cleared the f32-quantization floor. Only changed
//!   blocks are charged (and, in the distributed runner, sent as a
//!   `ModelDelta` frame); when the delta encoding would not beat the
//!   dense frame the plan falls back to dense, so delta bits are never
//!   worse than dense bits.
//!
//! Because an unchanged block's f32 image is, by definition, exactly
//! what the worker already holds, a delta-applied model equals the dense
//! broadcast's f32 image bit for bit — delta broadcast changes wire
//! cost, never the trajectory.

use crate::blocks::BlockLayout;
use std::sync::Arc;

/// Payload bits of one dense f32 model broadcast.
pub fn dense_bits(d: usize) -> u64 {
    d as u64 * 32
}

/// Per-patch header: u32 offset + u32 len.
pub const PATCH_HEADER_BITS: u64 = 64;
/// Per-frame header: u32 patch count.
pub const DELTA_FRAME_BITS: u64 = 32;

/// One round's broadcast plan.
#[derive(Clone, Debug)]
pub struct BroadcastPlan {
    /// Send a full dense model frame (first broadcast, dense mode, or
    /// delta-would-not-be-cheaper fallback).
    pub full: bool,
    /// Blocks whose f32 image changed (delta frames carry exactly
    /// these; empty + `!full` = heartbeat frame, workers reuse their
    /// cached model).
    pub changed: Vec<usize>,
    /// Metered payload bits of the chosen encoding.
    pub bits: u64,
}

/// Stateful per-run downlink meter / delta planner.
pub struct DownlinkMeter {
    layout: Arc<BlockLayout>,
    delta: bool,
    /// f32 image of the last broadcast (None until the first one).
    last: Option<Vec<f32>>,
    bits_cum: u64,
    dense_bits_cum: u64,
}

impl DownlinkMeter {
    /// Legacy dense accounting (flat layouts): `32·d` bits per round.
    pub fn dense(d: usize) -> DownlinkMeter {
        Self::with_mode(Arc::new(BlockLayout::flat(d)), false)
    }

    /// Delta accounting/planning over a block layout. A flat layout
    /// degenerates to dense-or-nothing (one block), which still skips
    /// re-broadcasts of a converged model.
    pub fn delta(layout: Arc<BlockLayout>) -> DownlinkMeter {
        Self::with_mode(layout, true)
    }

    /// Dense for flat layouts, delta for real partitions — what the
    /// runners use.
    pub fn for_layout(layout: Arc<BlockLayout>) -> DownlinkMeter {
        let delta = !layout.is_flat();
        Self::with_mode(layout, delta)
    }

    fn with_mode(layout: Arc<BlockLayout>, delta: bool) -> DownlinkMeter {
        DownlinkMeter { layout, delta, last: None, bits_cum: 0, dense_bits_cum: 0 }
    }

    pub fn layout(&self) -> &Arc<BlockLayout> {
        &self.layout
    }

    /// Cumulative metered downlink payload bits.
    pub fn bits(&self) -> u64 {
        self.bits_cum
    }

    /// What the same broadcasts would have cost densely (savings =
    /// `dense_baseline_bits - bits`).
    pub fn dense_baseline_bits(&self) -> u64 {
        self.dense_bits_cum
    }

    /// Plan one broadcast of model `x` — **pure**: no accounting, no
    /// state update. Call [`DownlinkMeter::commit`] once the frame has
    /// actually reached the workers. The split matters on real
    /// transports: if a send fails mid-broadcast, committing anyway
    /// would record an image the workers never received, and every
    /// later delta frame would patch against the wrong base.
    pub fn plan(&self, x: &[f64]) -> BroadcastPlan {
        let d = self.layout.d();
        assert_eq!(x.len(), d, "broadcast does not match layout dimension");

        // Dense mode is stateless: the legacy hot path pays only
        // constant-time accounting (in commit), no per-round f32 image.
        if !self.delta {
            return BroadcastPlan { full: true, changed: Vec::new(), bits: dense_bits(d) };
        }

        match &self.last {
            // Nothing broadcast yet: full frame.
            None => BroadcastPlan { full: true, changed: Vec::new(), bits: dense_bits(d) },
            Some(last) => {
                let mut changed = Vec::new();
                let mut delta_bits = DELTA_FRAME_BITS;
                for (b, spec) in self.layout.specs().iter().enumerate() {
                    let moved = spec
                        .range()
                        .any(|j| (x[j] as f32).to_bits() != last[j].to_bits());
                    if moved {
                        changed.push(b);
                        delta_bits += PATCH_HEADER_BITS + 32 * spec.len as u64;
                    }
                }
                if delta_bits >= dense_bits(d) {
                    BroadcastPlan { full: true, changed: Vec::new(), bits: dense_bits(d) }
                } else {
                    BroadcastPlan { full: false, changed, bits: delta_bits }
                }
            }
        }
    }

    /// Account a delivered broadcast and advance the planner state to
    /// the post-broadcast worker image (f32(x) whichever encoding won —
    /// an unchanged block's image already equals it). Only call this
    /// after every worker has the frame.
    pub fn commit(&mut self, x: &[f64], plan: &BroadcastPlan) {
        let d = self.layout.d();
        assert_eq!(x.len(), d, "broadcast does not match layout dimension");
        self.dense_bits_cum += dense_bits(d);
        self.bits_cum += plan.bits;
        if !self.delta {
            return;
        }
        match &mut self.last {
            Some(last) => {
                for (li, &xi) in last.iter_mut().zip(x) {
                    *li = xi as f32;
                }
            }
            None => self.last = Some(x.iter().map(|&v| v as f32).collect()),
        }
    }

    /// [`DownlinkMeter::plan`] + [`DownlinkMeter::commit`] in one step,
    /// for the simulated runners where the broadcast cannot fail.
    pub fn broadcast(&mut self, x: &[f64]) -> BroadcastPlan {
        let plan = self.plan(x);
        self.commit(x, &plan);
        plan
    }

    /// Checkpoint image: the last-broadcast f32 model (None until the
    /// first broadcast, and always None in dense mode) plus both
    /// cumulative bit counters.
    pub fn ckpt_state(&self) -> (Option<&[f32]>, u64, u64) {
        (self.last.as_deref(), self.bits_cum, self.dense_bits_cum)
    }

    /// Restore a checkpointed meter. Mode and layout come from the run
    /// configuration (they are not serialized); only the dynamic state
    /// is replaced.
    pub fn restore(
        &mut self,
        last: Option<Vec<f32>>,
        bits_cum: u64,
        dense_bits_cum: u64,
    ) -> anyhow::Result<()> {
        if let Some(img) = &last {
            anyhow::ensure!(
                img.len() == self.layout.d(),
                "downlink checkpoint image dim {} vs layout d={}",
                img.len(),
                self.layout.d()
            );
        }
        self.last = last;
        self.bits_cum = bits_cum;
        self.dense_bits_cum = dense_bits_cum;
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_mode_charges_32d_every_round() {
        let mut m = DownlinkMeter::dense(10);
        for _ in 0..3 {
            let p = m.broadcast(&[1.0; 10]);
            assert!(p.full);
            assert_eq!(p.bits, 320);
        }
        assert_eq!(m.bits(), 960);
        assert_eq!(m.dense_baseline_bits(), 960);
    }

    #[test]
    fn delta_mode_charges_only_changed_blocks() {
        let layout = Arc::new(BlockLayout::equal(5, 100).unwrap());
        let mut m = DownlinkMeter::delta(layout);
        let mut x = vec![1.0f64; 100];
        // First broadcast is always full.
        assert!(m.broadcast(&x).full);
        // Touch one coordinate in block 2 (coords 40..60).
        x[45] += 1.0;
        let p = m.broadcast(&x);
        assert!(!p.full);
        assert_eq!(p.changed, vec![2]);
        assert_eq!(p.bits, DELTA_FRAME_BITS + PATCH_HEADER_BITS + 32 * 20);
        // No change at all -> heartbeat frame, near-zero bits.
        let p = m.broadcast(&x);
        assert!(!p.full);
        assert!(p.changed.is_empty());
        assert_eq!(p.bits, DELTA_FRAME_BITS);
        assert!(m.bits() < m.dense_baseline_bits());
    }

    #[test]
    fn sub_f32_floor_updates_are_free() {
        let layout = Arc::new(BlockLayout::equal(2, 8).unwrap());
        let mut m = DownlinkMeter::delta(layout);
        let x = vec![1.0f64; 8];
        m.broadcast(&x);
        // A perturbation below f32 resolution does not clear the floor.
        let y: Vec<f64> = x.iter().map(|v| v + 1e-12).collect();
        let p = m.broadcast(&y);
        assert!(p.changed.is_empty(), "sub-ULP update must not count as changed");
    }

    #[test]
    fn delta_never_beats_itself_with_headers() {
        // All blocks changed: the planner must fall back to dense, so
        // delta accounting is never worse than dense accounting.
        let layout = Arc::new(BlockLayout::equal(4, 16).unwrap());
        let mut m = DownlinkMeter::delta(layout);
        let mut x: Vec<f64> = (0..16).map(|i| i as f64).collect();
        m.broadcast(&x);
        for v in x.iter_mut() {
            *v += 1.0;
        }
        let p = m.broadcast(&x);
        assert!(p.full, "all-changed must fall back to a dense frame");
        assert_eq!(p.bits, dense_bits(16));
        assert!(m.bits() <= m.dense_baseline_bits());
    }

    #[test]
    fn uncommitted_plan_does_not_desync_the_planner() {
        let layout = Arc::new(BlockLayout::equal(2, 8).unwrap());
        let mut m = DownlinkMeter::delta(layout);
        let x = vec![1.0f64; 8];
        m.broadcast(&x);
        // A broadcast that fails mid-send: planned but never committed.
        let y: Vec<f64> = x.iter().map(|v| v + 1.0).collect();
        let aborted = m.plan(&y);
        assert!(!aborted.changed.is_empty() || aborted.full);
        assert_eq!(m.bits(), dense_bits(8), "aborted plan must not be billed");
        // Retrying the same model must replan the same patches — the
        // workers still hold the pre-failure image.
        let retry = m.plan(&y);
        assert_eq!(retry.changed, aborted.changed);
        assert_eq!(retry.bits, aborted.bits);
        m.commit(&y, &retry);
        // Now the image has advanced: the same model is a heartbeat.
        assert!(m.plan(&y).changed.is_empty());
    }

    #[test]
    fn ckpt_state_restore_roundtrip() {
        let layout = Arc::new(BlockLayout::equal(2, 8).unwrap());
        let mut m = DownlinkMeter::delta(layout.clone());
        let x = vec![1.0f64; 8];
        m.broadcast(&x);
        let y: Vec<f64> = x.iter().map(|v| v + 1.0).collect();
        m.broadcast(&y);
        let (img, bits, dense) = m.ckpt_state();
        let (img, bits, dense) = (img.map(<[f32]>::to_vec), bits, dense);
        let mut fresh = DownlinkMeter::delta(layout);
        fresh.restore(img, bits, dense).unwrap();
        assert_eq!(fresh.bits(), m.bits());
        assert_eq!(fresh.dense_baseline_bits(), m.dense_baseline_bits());
        // The restored planner sees the same image: same future plans.
        let z: Vec<f64> = y.iter().map(|v| v + 1.0).collect();
        let a = m.broadcast(&z);
        let b = fresh.broadcast(&z);
        assert_eq!(a.changed, b.changed);
        assert_eq!(a.bits, b.bits);
        // A wrong-dimension image is rejected.
        let mut bad = DownlinkMeter::dense(8);
        assert!(bad.restore(Some(vec![0.0f32; 3]), 0, 0).is_err());
    }
}
