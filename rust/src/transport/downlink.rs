//! Downlink (master → worker broadcast) accounting and delta planning.
//!
//! Until the block refactor the master re-broadcast all `d` coordinates
//! as dense f32 every round and nobody metered it; uplink bits were
//! tracked to the single bit while the downlink was invisible. A
//! [`DownlinkMeter`] closes that gap:
//!
//! * **dense mode** (flat layouts) — charges the legacy `32·d` payload
//!   bits per broadcast, so `transport.downlink.bits` finally sits next
//!   to `transport.uplink.bits` in the telemetry snapshot;
//! * **delta mode** (blocked layouts) — per round, re-quantizes the
//!   model to f32 (the wire precision) and marks a block *changed* only
//!   if some coordinate's f32 image differs from the last broadcast —
//!   i.e. the update cleared the f32-quantization floor. Only changed
//!   blocks are charged (and, in the distributed runner, sent as a
//!   `ModelDelta` frame); when the delta encoding would not beat the
//!   dense frame the plan falls back to dense, so delta bits are never
//!   worse than dense bits.
//!
//! Because an unchanged block's f32 image is, by definition, exactly
//! what the worker already holds, a delta-applied model equals the dense
//! broadcast's f32 image bit for bit — delta broadcast changes wire
//! cost, never the trajectory.

use crate::blocks::BlockLayout;
use std::sync::Arc;

/// Payload bits of one dense f32 model broadcast.
pub fn dense_bits(d: usize) -> u64 {
    d as u64 * 32
}

/// Per-patch header: u32 offset + u32 len.
pub const PATCH_HEADER_BITS: u64 = 64;
/// Per-frame header: u32 patch count.
pub const DELTA_FRAME_BITS: u64 = 32;

/// One round's broadcast plan.
#[derive(Clone, Debug)]
pub struct BroadcastPlan {
    /// Send a full dense model frame (first broadcast, dense mode, or
    /// delta-would-not-be-cheaper fallback).
    pub full: bool,
    /// Blocks whose f32 image changed (delta frames carry exactly
    /// these; empty + `!full` = heartbeat frame, workers reuse their
    /// cached model).
    pub changed: Vec<usize>,
    /// Metered payload bits of the chosen encoding.
    pub bits: u64,
}

/// Stateful per-run downlink meter / delta planner.
pub struct DownlinkMeter {
    layout: Arc<BlockLayout>,
    delta: bool,
    /// f32 image of the last broadcast (None until the first one).
    last: Option<Vec<f32>>,
    bits_cum: u64,
    dense_bits_cum: u64,
}

impl DownlinkMeter {
    /// Legacy dense accounting (flat layouts): `32·d` bits per round.
    pub fn dense(d: usize) -> DownlinkMeter {
        Self::with_mode(Arc::new(BlockLayout::flat(d)), false)
    }

    /// Delta accounting/planning over a block layout. A flat layout
    /// degenerates to dense-or-nothing (one block), which still skips
    /// re-broadcasts of a converged model.
    pub fn delta(layout: Arc<BlockLayout>) -> DownlinkMeter {
        Self::with_mode(layout, true)
    }

    /// Dense for flat layouts, delta for real partitions — what the
    /// runners use.
    pub fn for_layout(layout: Arc<BlockLayout>) -> DownlinkMeter {
        let delta = !layout.is_flat();
        Self::with_mode(layout, delta)
    }

    fn with_mode(layout: Arc<BlockLayout>, delta: bool) -> DownlinkMeter {
        DownlinkMeter { layout, delta, last: None, bits_cum: 0, dense_bits_cum: 0 }
    }

    pub fn layout(&self) -> &Arc<BlockLayout> {
        &self.layout
    }

    /// Cumulative metered downlink payload bits.
    pub fn bits(&self) -> u64 {
        self.bits_cum
    }

    /// What the same broadcasts would have cost densely (savings =
    /// `dense_baseline_bits - bits`).
    pub fn dense_baseline_bits(&self) -> u64 {
        self.dense_bits_cum
    }

    /// Plan (and account) one broadcast of model `x`.
    pub fn plan(&mut self, x: &[f64]) -> BroadcastPlan {
        let d = self.layout.d();
        assert_eq!(x.len(), d, "broadcast does not match layout dimension");
        self.dense_bits_cum += dense_bits(d);

        // Dense mode is stateless: the legacy hot path pays only this
        // constant-time accounting, no per-round f32 image.
        if !self.delta {
            self.bits_cum += dense_bits(d);
            return BroadcastPlan { full: true, changed: Vec::new(), bits: dense_bits(d) };
        }

        let plan = match &mut self.last {
            // Nothing broadcast yet: full frame.
            None => BroadcastPlan { full: true, changed: Vec::new(), bits: dense_bits(d) },
            Some(last) => {
                let mut changed = Vec::new();
                let mut delta_bits = DELTA_FRAME_BITS;
                for (b, spec) in self.layout.specs().iter().enumerate() {
                    let moved = spec
                        .range()
                        .any(|j| (x[j] as f32).to_bits() != last[j].to_bits());
                    if moved {
                        changed.push(b);
                        delta_bits += PATCH_HEADER_BITS + 32 * spec.len as u64;
                    }
                }
                if delta_bits >= dense_bits(d) {
                    BroadcastPlan { full: true, changed: Vec::new(), bits: dense_bits(d) }
                } else {
                    BroadcastPlan { full: false, changed, bits: delta_bits }
                }
            }
        };

        // The post-broadcast worker image is f32(x) whichever encoding
        // won (an unchanged block's image already equals it).
        match &mut self.last {
            Some(last) => {
                for (li, &xi) in last.iter_mut().zip(x) {
                    *li = xi as f32;
                }
            }
            None => self.last = Some(x.iter().map(|&v| v as f32).collect()),
        }
        self.bits_cum += plan.bits;
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dense_mode_charges_32d_every_round() {
        let mut m = DownlinkMeter::dense(10);
        for _ in 0..3 {
            let p = m.plan(&[1.0; 10]);
            assert!(p.full);
            assert_eq!(p.bits, 320);
        }
        assert_eq!(m.bits(), 960);
        assert_eq!(m.dense_baseline_bits(), 960);
    }

    #[test]
    fn delta_mode_charges_only_changed_blocks() {
        let layout = Arc::new(BlockLayout::equal(5, 100).unwrap());
        let mut m = DownlinkMeter::delta(layout);
        let mut x = vec![1.0f64; 100];
        // First broadcast is always full.
        assert!(m.plan(&x).full);
        // Touch one coordinate in block 2 (coords 40..60).
        x[45] += 1.0;
        let p = m.plan(&x);
        assert!(!p.full);
        assert_eq!(p.changed, vec![2]);
        assert_eq!(p.bits, DELTA_FRAME_BITS + PATCH_HEADER_BITS + 32 * 20);
        // No change at all -> heartbeat frame, near-zero bits.
        let p = m.plan(&x);
        assert!(!p.full);
        assert!(p.changed.is_empty());
        assert_eq!(p.bits, DELTA_FRAME_BITS);
        assert!(m.bits() < m.dense_baseline_bits());
    }

    #[test]
    fn sub_f32_floor_updates_are_free() {
        let layout = Arc::new(BlockLayout::equal(2, 8).unwrap());
        let mut m = DownlinkMeter::delta(layout);
        let x = vec![1.0f64; 8];
        m.plan(&x);
        // A perturbation below f32 resolution does not clear the floor.
        let y: Vec<f64> = x.iter().map(|v| v + 1e-12).collect();
        let p = m.plan(&y);
        assert!(p.changed.is_empty(), "sub-ULP update must not count as changed");
    }

    #[test]
    fn delta_never_beats_itself_with_headers() {
        // All blocks changed: the planner must fall back to dense, so
        // delta accounting is never worse than dense accounting.
        let layout = Arc::new(BlockLayout::equal(4, 16).unwrap());
        let mut m = DownlinkMeter::delta(layout);
        let mut x: Vec<f64> = (0..16).map(|i| i as f64).collect();
        m.plan(&x);
        for v in x.iter_mut() {
            *v += 1.0;
        }
        let p = m.plan(&x);
        assert!(p.full, "all-changed must fall back to a dense frame");
        assert_eq!(p.bits, dense_bits(16));
        assert!(m.bits() <= m.dense_baseline_bits());
    }
}
