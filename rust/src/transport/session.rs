//! Self-healing transport sessions: CRC32 frame envelope, per-direction
//! sequence numbers, a bounded retransmit ring, and reconnect with
//! exponential backoff + decorrelated jitter.
//!
//! # Envelope
//!
//! With sessions on, every data frame is *sealed* before it touches the
//! wire: bit [`SESS_FLAG`] (0x40) is set on the tag byte and a 12-byte
//! trailer `u64 seq (LE) | u32 crc32 (LE)` is appended, with the CRC
//! covering the flagged frame body plus the sequence bytes. Unsealing
//! strips both and clears the flag, so the bytes handed to the codec are
//! **exactly** the session-off wire format — the envelope is invisible to
//! every layer above [`SessionConn`], including the uplink/downlink
//! frame-byte accounting, which meters logical (unsealed) frames.
//! Control frames ([`Frame::SessReq`]/[`Frame::SessAck`]) never carry the
//! envelope: they are the recovery channel itself.
//!
//! # Recovery protocol
//!
//! Each direction numbers its sealed frames 0, 1, 2, … and keeps the last
//! [`SessionCfg::ring`] sealed frames in a retransmit ring.
//!
//! * **Corruption** (CRC mismatch) and **frame loss** are receiver-driven:
//!   the receiver sends `SessReq{sid, from_seq = rx_seq}` and keeps
//!   reading; the peer's next `recv` serves the request by replaying ring
//!   frames with `seq >= from_seq`. Duplicates are dropped by sequence
//!   number, so replay is idempotent.
//! * **Connection loss** is two-sided: the worker (initiator) redials
//!   with [`RetryPolicy`] backoff, announces itself with a resume hello,
//!   then sends `SessReq`; the master (responder) adopts the resumed
//!   stream from the acceptor switchboard, answers `SessAck{sid, rx_seq}`
//!   (its own replay request — never answered with another ack, which is
//!   what terminates the handshake), and both sides replay. Because the
//!   lockstep protocol holds each side in `recv` while the other works,
//!   serving `SessReq` inline inside `recv` can never deadlock.
//! * **Ring overrun**: a replay request older than the ring's oldest
//!   frame fails with a typed [`RingOverrun`]; the scheduler master path
//!   downgrades that to the exact `StateSync` resync it already knows how
//!   to perform, everything else surfaces it as a hard error. In
//!   lockstep at most a handful of frames are ever unacknowledged, so
//!   the default ring never overruns — the fallback is for protocol
//!   extensions that pipeline more deeply.
//!
//! Sessions are off by default; when off, none of this code runs and the
//! wire bytes are identical to builds without the module.

use super::codec::{self, Frame, TAG_SESS_ACK, TAG_SESS_REQ};
use super::Conn;
use crate::telemetry::{self, keys};
use crate::util::rng::Rng;
use anyhow::{bail, ensure, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;
use std::time::{Duration, Instant};

/// Tag-byte bit marking a sealed (enveloped) frame. Codec tags stop at
/// 0x0B, so bit 6 is free; the `Up` health flag lives on the *kind* byte
/// (offset 1) and never collides.
pub const SESS_FLAG: u8 = 0x40;

/// Envelope trailer: u64 sequence number + u32 CRC32.
pub const TRAILER: usize = 12;

fn crc_table() -> &'static [u32; 256] {
    static TABLE: std::sync::OnceLock<[u32; 256]> = std::sync::OnceLock::new();
    TABLE.get_or_init(|| {
        let mut t = [0u32; 256];
        for (i, e) in t.iter_mut().enumerate() {
            let mut c = i as u32;
            for _ in 0..8 {
                c = if c & 1 != 0 { 0xEDB8_8320 ^ (c >> 1) } else { c >> 1 };
            }
            *e = c;
        }
        t
    })
}

/// CRC-32 (IEEE 802.3, the zlib polynomial), hand-rolled — the container
/// vendors no checksum crate and the checkpoint module's FNV is too weak
/// for single-bit-flip guarantees on long frames.
pub fn crc32(bytes: &[u8]) -> u32 {
    let t = crc_table();
    let mut c = 0xFFFF_FFFFu32;
    for &b in bytes {
        c = t[((c ^ b as u32) & 0xFF) as usize] ^ (c >> 8);
    }
    c ^ 0xFFFF_FFFF
}

/// Seal a codec frame for the wire: set [`SESS_FLAG`], append
/// `seq | crc32(flagged body + seq)`.
pub fn seal(frame: &[u8], seq: u64) -> Vec<u8> {
    debug_assert!(!frame.is_empty());
    let mut out = Vec::with_capacity(frame.len() + TRAILER);
    out.extend_from_slice(frame);
    out[0] |= SESS_FLAG;
    out.extend_from_slice(&seq.to_le_bytes());
    let crc = crc32(&out);
    out.extend_from_slice(&crc.to_le_bytes());
    out
}

/// What a received buffer turned out to be (see [`unseal`]).
#[derive(Debug)]
pub enum Inspect {
    /// A session control frame (never enveloped).
    Control(Frame),
    /// A sealed data frame carrying this sequence number; the buffer now
    /// holds the exact session-off bytes.
    Sealed(u64),
    /// CRC mismatch, truncation, or an unenveloped data frame where a
    /// sealed one was required — request a replay.
    Corrupt,
}

/// Inspect (and in place unseal) a frame received with sessions on.
/// Unenveloped data frames are reported [`Inspect::Corrupt`]: both ends
/// enable sessions together, so a missing envelope means the tag byte
/// itself was damaged.
pub fn unseal(buf: &mut Vec<u8>) -> Inspect {
    let Some(&tag) = buf.first() else { return Inspect::Corrupt };
    if tag == TAG_SESS_REQ || tag == TAG_SESS_ACK {
        return match codec::decode(buf) {
            Ok(f @ (Frame::SessReq { .. } | Frame::SessAck { .. })) => Inspect::Control(f),
            _ => Inspect::Corrupt,
        };
    }
    if tag & SESS_FLAG == 0 || buf.len() < 1 + TRAILER {
        return Inspect::Corrupt;
    }
    let body = buf.len() - 4;
    let want = u32::from_le_bytes(buf[body..].try_into().unwrap());
    if crc32(&buf[..body]) != want {
        return Inspect::Corrupt;
    }
    let seq = u64::from_le_bytes(buf[body - 8..body].try_into().unwrap());
    buf.truncate(body - 8);
    buf[0] &= !SESS_FLAG;
    Inspect::Sealed(seq)
}

/// Deterministic session identity for `(run seed, worker)` — carried in
/// the RESUME handshake so a stray reconnect can never splice into the
/// wrong worker's stream.
pub fn session_id(seed: u64, worker: usize) -> u64 {
    Rng::seed(seed.wrapping_mul(0x9E37_79B9_7F4A_7C15) ^ 0xEF21_5E55 ^ worker as u64).next_u64()
}

/// A replay request that predates the ring's oldest retained frame.
/// Typed so the scheduler master loop can downcast and fall back to the
/// exact `StateSync` resync instead of aborting.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RingOverrun {
    /// Oldest sequence number the peer asked for.
    pub wanted: u64,
    /// Oldest sequence number still in the ring.
    pub oldest: u64,
}

impl std::fmt::Display for RingOverrun {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "session retransmit ring overrun: peer needs seq {} but the ring starts at {}",
            self.wanted, self.oldest
        )
    }
}

impl std::error::Error for RingOverrun {}

/// Marker error for a chaos-injected transient frame loss: the frame was
/// discarded in flight but the transport underneath is still alive, so
/// the session layer recovers by retransmission instead of redialing.
#[derive(Debug, Clone, Copy)]
pub struct TransientLoss;

impl std::fmt::Display for TransientLoss {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "injected transient frame loss")
    }
}

impl std::error::Error for TransientLoss {}

/// Exponential backoff with decorrelated jitter (`sleep' = uniform(base,
/// 3*sleep)`, clamped to `cap`), bounded by an optional total elapsed
/// `budget`. Seeded, so retry schedules are reproducible; one warn line
/// per retry, never silent.
#[derive(Clone, Debug)]
pub struct RetryPolicy {
    pub base: Duration,
    pub cap: Duration,
    /// Total elapsed budget across attempts; `None` retries forever.
    pub budget: Option<Duration>,
    seed: u64,
}

impl RetryPolicy {
    pub fn new(base: Duration, cap: Duration, budget: Option<Duration>, seed: u64) -> RetryPolicy {
        RetryPolicy { base, cap: cap.max(base), budget, seed }
    }

    /// The shared connect/reconnect policy: base 10 ms, capped at 1/8 of
    /// the resolved I/O timeout (clamped to [50 ms, 2 s]), with the
    /// timeout itself as the total budget. With timeouts disabled the
    /// budget is unbounded — the `wait` worker-loss policy.
    pub fn for_io_timeout(seed: u64) -> RetryPolicy {
        let io = super::tcp::io_timeout();
        let cap = io
            .map(|t| (t / 8).clamp(Duration::from_millis(50), Duration::from_secs(2)))
            .unwrap_or(Duration::from_millis(200));
        RetryPolicy::new(Duration::from_millis(10), cap, io, seed)
    }

    /// Cap the total budget (keeps the tighter of the two).
    pub fn with_budget(mut self, budget: Duration) -> RetryPolicy {
        self.budget = Some(self.budget.map_or(budget, |b| b.min(budget)));
        self
    }

    /// Run `f` until it succeeds or the budget is exhausted, sleeping the
    /// jittered backoff between attempts and warning once per retry.
    pub fn run<T>(&self, what: &str, mut f: impl FnMut() -> Result<T>) -> Result<T> {
        let start = Instant::now();
        let mut rng = Rng::seed(self.seed ^ 0xBAC0_FF5E);
        let mut sleep = self.base;
        let mut attempt = 0u32;
        loop {
            match f() {
                Ok(v) => return Ok(v),
                Err(e) => {
                    attempt += 1;
                    let spent = start.elapsed();
                    if let Some(budget) = self.budget {
                        if spent + sleep >= budget {
                            return Err(e.context(format!(
                                "{what}: gave up after {attempt} attempts over {spent:?}"
                            )));
                        }
                    }
                    eprintln!(
                        "transport: {what} failed (attempt {attempt}: {e:#}); retrying in {:?}",
                        sleep
                    );
                    std::thread::sleep(sleep);
                    // Decorrelated jitter: uniform in [base, 3*sleep].
                    let hi = (sleep.as_millis() as u64).saturating_mul(3).max(1);
                    let lo = self.base.as_millis() as u64;
                    let next = lo + (rng.next_u64() % (hi.saturating_sub(lo) + 1));
                    sleep = Duration::from_millis(next).min(self.cap).max(self.base);
                }
            }
        }
    }
}

/// Session counters shared by every [`SessionConn`] of one run; the
/// master loop reads them for health accounting, and each increment also
/// lands in the global `session.*` telemetry keys.
#[derive(Default)]
pub struct SessionStats {
    pub reconnects: AtomicU64,
    pub replayed_frames: AtomicU64,
    pub crc_rejects: AtomicU64,
}

impl SessionStats {
    fn note_reconnect(&self) {
        self.reconnects.fetch_add(1, Ordering::Relaxed);
        telemetry::counter(keys::SESSION_RECONNECTS).incr(1);
    }

    pub(crate) fn note_replayed(&self, n: u64) {
        self.replayed_frames.fetch_add(n, Ordering::Relaxed);
        telemetry::counter(keys::SESSION_REPLAYED_FRAMES).incr(n);
    }

    pub(crate) fn note_crc_reject(&self) {
        self.crc_rejects.fetch_add(1, Ordering::Relaxed);
        telemetry::counter(keys::SESSION_CRC_REJECTS).incr(1);
    }

    /// Consistent-enough snapshot `(reconnects, replayed_frames,
    /// crc_rejects)` for per-round health deltas.
    pub fn snapshot(&self) -> (u64, u64, u64) {
        (
            self.reconnects.load(Ordering::Relaxed),
            self.replayed_frames.load(Ordering::Relaxed),
            self.crc_rejects.load(Ordering::Relaxed),
        )
    }
}

/// Session configuration shared by both ends of a run's connections.
#[derive(Clone)]
pub struct SessionCfg {
    /// Retransmit ring capacity per direction, in frames.
    pub ring: usize,
    /// Run seed (session ids + retry jitter derive from it).
    pub seed: u64,
    pub stats: Arc<SessionStats>,
}

impl SessionCfg {
    pub fn new(seed: u64) -> SessionCfg {
        SessionCfg { ring: DEFAULT_RING, seed, stats: Arc::new(SessionStats::default()) }
    }
}

/// Default retransmit ring depth. Lockstep keeps at most a handful of
/// frames unacknowledged, so 64 gives two orders of headroom.
pub const DEFAULT_RING: usize = 64;

/// How a [`SessionConn`] recovers transport-level failures.
pub enum Reconnect {
    /// No transport recovery (local channels): retransmit over the
    /// still-live inner conn. Only [`TransientLoss`] send failures are
    /// recoverable; a real hangup propagates.
    Replay,
    /// Initiator (worker side): the closure redials, re-sends the resume
    /// hello, and returns the fresh conn; the session then runs the
    /// SessReq -> SessAck handshake.
    Dial(Box<dyn FnMut() -> Result<Box<dyn Conn>> + Send>),
    /// Responder (master side): the closure adopts the next resumed
    /// stream for this worker from the acceptor switchboard; the session
    /// then answers the initiator's SessReq with a SessAck.
    Adopt(Box<dyn FnMut() -> Result<Box<dyn Conn>> + Send>),
}

/// A [`Conn`] adapter adding the session envelope, sequence-number
/// dedup, the bounded retransmit ring, and reconnect/replay recovery.
/// Everything above it sees the exact session-off protocol.
pub struct SessionConn {
    inner: Box<dyn Conn>,
    sid: u64,
    label: String,
    tx_seq: u64,
    rx_seq: u64,
    ring: VecDeque<(u64, Vec<u8>)>,
    ring_cap: usize,
    reconnect: Reconnect,
    stats: Arc<SessionStats>,
}

impl SessionConn {
    pub fn new(
        inner: Box<dyn Conn>,
        worker: usize,
        cfg: &SessionCfg,
        reconnect: Reconnect,
    ) -> SessionConn {
        SessionConn {
            inner,
            sid: session_id(cfg.seed, worker),
            label: format!("w{worker}"),
            tx_seq: 0,
            rx_seq: 0,
            ring: VecDeque::with_capacity(cfg.ring.max(1)),
            ring_cap: cfg.ring.max(1),
            reconnect,
            stats: cfg.stats.clone(),
        }
    }

    /// Retransmit every retained frame with `seq >= from`; fails with a
    /// downcastable [`RingOverrun`] when `from` predates the ring.
    fn replay(&mut self, from: u64) -> Result<()> {
        if let Some(&(oldest, _)) = self.ring.front() {
            if from < oldest {
                return Err(anyhow::Error::new(RingOverrun { wanted: from, oldest }));
            }
        } else if from < self.tx_seq {
            return Err(anyhow::Error::new(RingOverrun { wanted: from, oldest: self.tx_seq }));
        }
        let mut sent = 0u64;
        for i in 0..self.ring.len() {
            let (seq, bytes) = self.ring[i].clone();
            if seq < from {
                continue;
            }
            self.inner.send(&bytes)?;
            sent += 1;
        }
        self.stats.note_replayed(sent);
        Ok(())
    }

    fn send_control(&mut self, frame: &Frame) -> Result<()> {
        self.inner.send(&codec::encode(frame))
    }

    /// Initiator side of the RESUME handshake (after the dial closure
    /// delivered a fresh, hello'd conn).
    fn handshake_dial(&mut self) -> Result<()> {
        let sid = self.sid;
        self.send_control(&Frame::SessReq { sid, from_seq: self.rx_seq })?;
        let buf = self.inner.recv()?;
        match codec::decode(&buf) {
            Ok(Frame::SessAck { sid: got, from_seq }) => {
                ensure!(
                    got == sid,
                    "session {}: resume ack for wrong session ({got:#x} != {sid:#x})",
                    self.label
                );
                self.replay(from_seq)
            }
            other => bail!(
                "session {}: expected SessAck during resume, got {other:?}",
                self.label
            ),
        }
    }

    /// Responder side of the RESUME handshake (after adopting a stream).
    fn handshake_adopt(&mut self) -> Result<()> {
        let buf = self.inner.recv()?;
        match codec::decode(&buf) {
            Ok(Frame::SessReq { sid: got, from_seq }) => {
                ensure!(
                    got == self.sid,
                    "session {}: resume request for wrong session ({got:#x} != {:#x})",
                    self.label,
                    self.sid
                );
                let ack = Frame::SessAck { sid: self.sid, from_seq: self.rx_seq };
                self.send_control(&ack)?;
                self.replay(from_seq)
            }
            other => bail!(
                "session {}: expected SessReq during resume, got {other:?}",
                self.label
            ),
        }
    }

    /// Re-establish transport after an I/O failure and run the resume
    /// handshake. `Replay` mode recovers [`TransientLoss`] only.
    fn recover(&mut self, err: anyhow::Error) -> Result<()> {
        let transient = err.downcast_ref::<TransientLoss>().is_some();
        match &mut self.reconnect {
            Reconnect::Replay => {
                if !transient {
                    return Err(err.context(format!(
                        "session {}: transport lost with no reconnect path",
                        self.label
                    )));
                }
                self.stats.note_reconnect();
                Ok(())
            }
            Reconnect::Dial(dial) => {
                self.stats.note_reconnect();
                eprintln!(
                    "session {}: transport error ({err:#}); reconnecting",
                    self.label
                );
                self.inner = dial()?;
                self.handshake_dial()
            }
            Reconnect::Adopt(adopt) => {
                self.stats.note_reconnect();
                eprintln!(
                    "session {}: transport error ({err:#}); awaiting resumed stream",
                    self.label
                );
                self.inner = adopt()?;
                self.handshake_adopt()
            }
        }
    }

    /// Ask the peer to retransmit from our next expected sequence.
    fn request_replay(&mut self) -> Result<()> {
        let req = Frame::SessReq { sid: self.sid, from_seq: self.rx_seq };
        match self.send_control(&req) {
            Ok(()) => Ok(()),
            Err(e) => {
                self.recover(e)?;
                Ok(())
            }
        }
    }
}

impl Conn for SessionConn {
    fn send(&mut self, frame: &[u8]) -> Result<()> {
        let seq = self.tx_seq;
        self.tx_seq += 1;
        let sealed = seal(frame, seq);
        while self.ring.len() >= self.ring_cap {
            self.ring.pop_front();
        }
        self.ring.push_back((seq, sealed.clone()));
        match self.inner.send(&sealed) {
            Ok(()) => Ok(()),
            Err(e) => {
                let transient = e.downcast_ref::<TransientLoss>().is_some();
                self.recover(e)?;
                if transient {
                    // Transport is live; only this frame was dropped.
                    self.inner.send(&sealed)?;
                    self.stats.note_replayed(1);
                }
                // After a real reconnect the handshake replay already
                // covered the frame (it was ringed before the failure).
                Ok(())
            }
        }
    }

    fn recv_into(&mut self, buf: &mut Vec<u8>) -> Result<()> {
        loop {
            if let Err(e) = self.inner.recv_into(buf) {
                if e.downcast_ref::<TransientLoss>().is_some() {
                    // The frame evaporated in flight; ask for it again.
                    self.stats.note_crc_reject();
                    self.request_replay()?;
                    continue;
                }
                self.recover(e)?;
                continue;
            }
            match unseal(buf) {
                Inspect::Control(Frame::SessReq { sid, from_seq })
                | Inspect::Control(Frame::SessAck { sid, from_seq }) => {
                    ensure!(
                        sid == self.sid,
                        "session {}: replay request for wrong session",
                        self.label
                    );
                    self.replay(from_seq)?;
                }
                Inspect::Control(_) => unreachable!("unseal only yields session control frames"),
                Inspect::Corrupt => {
                    self.stats.note_crc_reject();
                    self.request_replay()?;
                }
                Inspect::Sealed(seq) => {
                    if seq < self.rx_seq {
                        continue; // replayed duplicate
                    }
                    if seq > self.rx_seq {
                        // Gap: frames before this one were lost.
                        self.request_replay()?;
                        continue;
                    }
                    self.rx_seq += 1;
                    return Ok(());
                }
            }
        }
    }

    fn recv(&mut self) -> Result<Vec<u8>> {
        let mut buf = Vec::new();
        self.recv_into(&mut buf)?;
        Ok(buf)
    }

    fn sever(&mut self) {
        self.inner.sever();
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::transport::local;

    #[test]
    fn crc32_matches_the_reference_vector() {
        assert_eq!(crc32(b"123456789"), 0xCBF4_3926);
        assert_eq!(crc32(b""), 0);
    }

    #[test]
    fn seal_unseal_roundtrip_restores_exact_bytes() {
        let frame = codec::encode(&Frame::Model(vec![1.0, -2.5, 0.125]));
        let mut sealed = seal(&frame, 42);
        assert_eq!(sealed.len(), frame.len() + TRAILER);
        assert_eq!(sealed[0], frame[0] | SESS_FLAG);
        match unseal(&mut sealed) {
            Inspect::Sealed(seq) => assert_eq!(seq, 42),
            other => panic!("expected sealed, got {other:?}"),
        }
        assert_eq!(sealed, frame, "unseal must restore the session-off bytes");
    }

    /// One exemplar of every data frame kind the protocol ships (f32-exact
    /// values so decode→encode is byte-stable).
    fn frame_zoo() -> Vec<Frame> {
        let payload = crate::compress::Compressed {
            sparse: crate::compress::SparseVec::new(vec![0, 3], vec![1.5, -2.0]),
            bits: 130,
        };
        vec![
            Frame::Model(vec![1.0, -2.5, 0.125]),
            Frame::Up {
                msg: crate::algo::WireMsg::Sparse(payload.clone()),
                loss: 0.5,
                health: None,
            },
            Frame::Up {
                msg: crate::algo::WireMsg::Tagged { dcgd_branch: true, payload: payload.clone() },
                loss: 0.25,
                health: Some(3.5),
            },
            Frame::Stop,
            Frame::ModelDelta(vec![
                codec::BlockPatch { offset: 0, vals: vec![0.5] },
                codec::BlockPatch { offset: 4, vals: vec![-1.0, 2.0] },
            ]),
            Frame::UpBlock { block: 1, n_blocks: 2, msg: crate::algo::WireMsg::Sparse(payload), loss: 0.75 },
            Frame::StateSync(vec![0.25, -0.5]),
            Frame::CkptReq,
            Frame::CkptState(vec![0xDE, 0xAD, 0xBE, 0xEF]),
            Frame::Restore { blob: vec![1, 2, 3], model: vec![0.5, 1.5] },
        ]
    }

    /// The envelope property the whole recovery design rests on: over
    /// every frame kind, every single-bit corruption of a sealed frame is
    /// rejected (never mis-decoded), and with sessions off the codec
    /// bytes are untouched by this module existing.
    #[test]
    fn every_single_bit_flip_is_detected_across_all_frame_kinds() {
        for frame in frame_zoo() {
            let plain = codec::encode(&frame);
            // Envelope off: the tag byte never carries SESS_FLAG and the
            // bytes decode→re-encode unchanged — sessions-off wire is
            // byte-identical to builds without this module.
            assert_eq!(plain[0] & SESS_FLAG, 0, "{frame:?}");
            let redecoded = codec::decode(&plain).expect("zoo frame decodes");
            assert_eq!(codec::encode(&redecoded), plain, "{frame:?}");
            // Envelope on: seal/unseal restores the exact plain bytes…
            let sealed = seal(&plain, 7);
            let mut ok = sealed.clone();
            assert!(matches!(unseal(&mut ok), Inspect::Sealed(7)), "{frame:?}");
            assert_eq!(ok, plain, "{frame:?}");
            // …and every single-bit flip anywhere in the sealed frame
            // (tag, body, seq, crc) is caught.
            for byte in 0..sealed.len() {
                for bit in 0..8 {
                    let mut flipped = sealed.clone();
                    flipped[byte] ^= 1 << bit;
                    match unseal(&mut flipped) {
                        Inspect::Corrupt => {}
                        other => panic!("{frame:?}: flip at {byte}.{bit} survived as {other:?}"),
                    }
                }
            }
        }
    }

    #[test]
    fn control_frames_pass_unsealed() {
        let mut req = codec::encode(&Frame::SessReq { sid: 9, from_seq: 3 });
        match unseal(&mut req) {
            Inspect::Control(Frame::SessReq { sid, from_seq }) => {
                assert_eq!((sid, from_seq), (9, 3));
            }
            other => panic!("{other:?}"),
        }
        // Truncated control frame is corrupt, not a panic.
        let mut cut = codec::encode(&Frame::SessAck { sid: 9, from_seq: 3 });
        cut.truncate(5);
        assert!(matches!(unseal(&mut cut), Inspect::Corrupt));
        // An unenveloped data frame where a sealed one is required.
        let mut plain = codec::encode(&Frame::Stop);
        assert!(matches!(unseal(&mut plain), Inspect::Corrupt));
    }

    #[test]
    fn session_ids_are_stable_and_worker_distinct() {
        assert_eq!(session_id(7, 3), session_id(7, 3));
        assert_ne!(session_id(7, 3), session_id(7, 4));
        assert_ne!(session_id(7, 3), session_id(8, 3));
    }

    #[test]
    fn retry_policy_respects_budget_and_warns() {
        let policy = RetryPolicy::new(
            Duration::from_millis(1),
            Duration::from_millis(2),
            Some(Duration::from_millis(30)),
            99,
        );
        let mut calls = 0u32;
        let err: Result<()> = policy.run("probe", || {
            calls += 1;
            bail!("nope")
        });
        assert!(err.is_err());
        assert!(calls >= 2, "policy must actually retry (got {calls})");
        // Success passes through untouched.
        let ok: Result<u32> = policy.run("probe", || Ok(5));
        assert_eq!(ok.unwrap(), 5);
        // Same seed, same schedule: deterministic attempt counts.
        let mut calls2 = 0u32;
        let _: Result<()> = policy.run("probe", || {
            calls2 += 1;
            bail!("nope")
        });
        assert_eq!(calls, calls2, "retry schedule must be seed-deterministic");
    }

    fn pair_with_sessions(
        cfg: &SessionCfg,
    ) -> (SessionConn, SessionConn) {
        let (m, w) = local::pair();
        (
            SessionConn::new(Box::new(m), 0, cfg, Reconnect::Replay),
            SessionConn::new(Box::new(w), 0, cfg, Reconnect::Replay),
        )
    }

    #[test]
    fn sealed_traffic_roundtrips_and_dedups_replays() {
        let cfg = SessionCfg::new(1);
        let (mut a, mut b) = pair_with_sessions(&cfg);
        let f1 = codec::encode(&Frame::Model(vec![1.0, 2.0]));
        let f2 = codec::encode(&Frame::Stop);
        a.send(&f1).unwrap();
        a.send(&f2).unwrap();
        assert_eq!(b.recv().unwrap(), f1);
        // A stale replay request makes `a` retransmit everything; the
        // receiver must skip the duplicate of f1 and deliver f2 once.
        b.send_control(&Frame::SessReq { sid: b.sid, from_seq: 0 }).unwrap();
        // a's next recv serves the request (replaying both frames), then
        // a sends a third frame.
        let f3 = codec::encode(&Frame::CkptReq);
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                // a: serve the SessReq that is already queued, then send f3.
                a.send(&f3).unwrap();
                let got = a.recv(); // serves SessReq inline, then blocks for data
                got
            });
            assert_eq!(b.recv().unwrap(), f2, "duplicate f1 must be skipped");
            assert_eq!(b.recv().unwrap(), f3);
            let up = codec::encode(&Frame::Stop);
            b.send(&up).unwrap();
            assert_eq!(h.join().unwrap().unwrap(), up);
        });
        assert_eq!(cfg.stats.replayed_frames.load(Ordering::Relaxed), 3);
    }

    #[test]
    fn corrupt_frame_is_rerequested_not_fatal() {
        let cfg = SessionCfg::new(2);
        let (mut a, mut b) = pair_with_sessions(&cfg);
        let f1 = codec::encode(&Frame::Model(vec![4.0]));
        // Deliver a corrupted copy by hand, then let the session recover.
        let mut sealed = seal(&f1, 0);
        let n = sealed.len();
        sealed[n - 6] ^= 0x10; // damage the trailer
        a.tx_seq = 1;
        a.ring.push_back((0, seal(&f1, 0)));
        // Push the damaged bytes directly through the inner conn.
        a.inner.send(&sealed).unwrap();
        std::thread::scope(|s| {
            let h = s.spawn(|| {
                // a serves b's SessReq inline from its next recv.
                let _ = a.recv();
            });
            assert_eq!(b.recv().unwrap(), f1, "recovered frame must decode");
            // Unblock a's recv.
            b.send(&codec::encode(&Frame::Stop)).unwrap();
            h.join().unwrap();
        });
        assert_eq!(cfg.stats.crc_rejects.load(Ordering::Relaxed), 1);
        assert_eq!(cfg.stats.replayed_frames.load(Ordering::Relaxed), 1);
    }

    #[test]
    fn ring_overrun_surfaces_the_typed_error() {
        let cfg = SessionCfg { ring: 1, ..SessionCfg::new(3) };
        let (m, w) = local::pair();
        let mut a = SessionConn::new(Box::new(m), 0, &cfg, Reconnect::Replay);
        let mut w = w;
        a.send(&codec::encode(&Frame::Stop)).unwrap();
        a.send(&codec::encode(&Frame::CkptReq)).unwrap();
        a.send(&codec::encode(&Frame::Stop)).unwrap();
        // The peer asks for seq 0, which the 1-deep ring evicted.
        w.send(&codec::encode(&Frame::SessReq { sid: a.sid, from_seq: 0 })).unwrap();
        // Drain the three data frames first, then the request is served.
        for _ in 0..3 {
            w.recv().unwrap();
        }
        let err = a.recv().expect_err("overrun must fail");
        let overrun = err.downcast_ref::<RingOverrun>().expect("typed RingOverrun");
        assert_eq!(overrun.wanted, 0);
        assert_eq!(overrun.oldest, 2);
    }

    #[test]
    fn transient_send_loss_is_resent_over_the_live_conn() {
        // An inner conn that drops the first send with TransientLoss.
        struct Flaky {
            inner: local::LocalConn,
            dropped: bool,
        }
        impl Conn for Flaky {
            fn send(&mut self, frame: &[u8]) -> Result<()> {
                if !self.dropped {
                    self.dropped = true;
                    return Err(anyhow::Error::new(TransientLoss));
                }
                self.inner.send(frame)
            }
            fn recv(&mut self) -> Result<Vec<u8>> {
                self.inner.recv()
            }
        }
        let cfg = SessionCfg::new(4);
        let (m, w) = local::pair();
        let mut a = SessionConn::new(
            Box::new(Flaky { inner: m, dropped: false }),
            0,
            &cfg,
            Reconnect::Replay,
        );
        let mut b = SessionConn::new(Box::new(w), 0, &cfg, Reconnect::Replay);
        let f = codec::encode(&Frame::Model(vec![9.0]));
        a.send(&f).unwrap();
        assert_eq!(b.recv().unwrap(), f);
        assert_eq!(cfg.stats.reconnects.load(Ordering::Relaxed), 1);
    }
}
