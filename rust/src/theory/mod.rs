//! Theory-side quantities: Lemma 3 constants, Theorem 1/2 stepsizes, and
//! smoothness/PL constants estimated from data. Every experiment's "1x
//! stepsize" is `stepsize_theorem1/2` evaluated on the actual shards, just
//! as in §5 ("multiple of the largest stepsize predicted by our theory").

use crate::util::linalg;

/// Optimal Lemma-3 constants for a given contraction parameter alpha:
/// theta = 1 - sqrt(1-alpha), beta = (1-alpha) / (1 - sqrt(1-alpha)).
/// For alpha = 1 (identity): theta = 1, beta = 0.
pub fn theta_beta(alpha: f64) -> (f64, f64) {
    assert!(alpha > 0.0 && alpha <= 1.0, "alpha must be in (0,1], got {alpha}");
    let root = (1.0 - alpha).max(0.0).sqrt();
    let theta = 1.0 - root;
    let beta = if alpha >= 1.0 { 0.0 } else { (1.0 - alpha) / theta };
    (theta, beta)
}

/// sqrt(beta/theta) in closed form (Eq. 26): 1/sqrt(1-alpha) - 1 inverted —
/// precisely sqrt(beta(s*)/theta(s*)) = sqrt(1-alpha) / (1 - sqrt(1-alpha)).
pub fn sqrt_beta_over_theta(alpha: f64) -> f64 {
    let (theta, beta) = theta_beta(alpha);
    if beta == 0.0 {
        0.0
    } else {
        (beta / theta).sqrt()
    }
}

/// Theorem 1 stepsize: gamma <= 1 / (L + Ltilde * sqrt(beta/theta)).
pub fn stepsize_theorem1(l: f64, l_tilde: f64, alpha: f64) -> f64 {
    1.0 / (l + l_tilde * sqrt_beta_over_theta(alpha))
}

/// Theorem 2 stepsize: gamma <= min{ 1/(L + Ltilde sqrt(2 beta/theta)),
/// theta/(2 mu) }.
pub fn stepsize_theorem2(l: f64, l_tilde: f64, alpha: f64, mu: f64) -> f64 {
    let (theta, beta) = theta_beta(alpha);
    let a = if beta == 0.0 { 0.0 } else { (2.0 * beta / theta).sqrt() };
    let lhs = 1.0 / (l + l_tilde * a);
    let rhs = theta / (2.0 * mu);
    lhs.min(rhs)
}

/// EF21-PP stepsize bound (partial participation; Fatkhullin et al.
/// 2021, "EF21 with Bells & Whistles"): each worker participates
/// independently with probability `p` per round and holds `g_i` when
/// absent. The Lyapunov recursion mixes the participating contraction
/// `(1-θ)` with the absent branch's `(1+s)` growth (Young), giving for
/// `s = θp / (2(1-p))`:
///
/// ```text
///   θ_p = pθ/2,   β_p = pβ + (1-p)(1 + 1/s),
///   γ  <= 1 / (L + L̃ sqrt(β_p / θ_p)).
/// ```
///
/// Conservative by design: at `p = 1` the Young term vanishes but the
/// halved θ remains, landing a factor √2 below Theorem 1 — so `p = 1`
/// short-circuits to [`stepsize_theorem1`] and the bound is continuous
/// from below elsewhere. Monotone increasing in `p`.
pub fn stepsize_pp(l: f64, l_tilde: f64, alpha: f64, p: f64) -> f64 {
    assert!(p > 0.0 && p <= 1.0, "participation probability must be in (0,1], got {p}");
    if p >= 1.0 {
        return stepsize_theorem1(l, l_tilde, alpha);
    }
    let (theta, beta) = theta_beta(alpha);
    let s = theta * p / (2.0 * (1.0 - p));
    let theta_p = p * theta / 2.0;
    let beta_p = p * beta + (1.0 - p) * (1.0 + 1.0 / s);
    1.0 / (l + l_tilde * (beta_p / theta_p).sqrt())
}

/// Smoothness constants for the distributed objective.
#[derive(Clone, Debug)]
pub struct Smoothness {
    /// Per-node Lipschitz constants L_i.
    pub l_i: Vec<f64>,
    /// L of the average f (estimated; <= mean of L_i).
    pub l: f64,
    /// Ltilde = sqrt(mean of L_i^2) >= mean of L_i.
    pub l_tilde: f64,
}

impl Smoothness {
    pub fn from_l_i(l_i: Vec<f64>, l: f64) -> Self {
        let l_tilde = (l_i.iter().map(|x| x * x).sum::<f64>() / l_i.len() as f64).sqrt();
        Smoothness { l_i, l, l_tilde }
    }

    /// Conservative fallback when only L_i are known: L <= mean(L_i).
    pub fn from_l_i_mean(l_i: Vec<f64>) -> Self {
        let l = l_i.iter().sum::<f64>() / l_i.len() as f64;
        Self::from_l_i(l_i, l)
    }
}

/// L_i for the nonconvex logistic regression of Eq. (19) on a shard:
/// data term has Hessian bounded by lambda_max(A^T A) / (4 n_i); the
/// regularizer r(x) = sum x_j^2/(1+x_j^2) has |r''| <= 2, contributing
/// 2 * lam.
pub fn logreg_l(a: &[f32], n: usize, d: usize, lam: f64) -> f64 {
    if n == 0 {
        return 2.0 * lam;
    }
    let lmax = linalg::spectral_norm_sq_ata(a, n, d, 100, 0xD0E5);
    lmax / (4.0 * n as f64) + 2.0 * lam
}

/// L_i for least squares f(x) = (1/n) sum (a_i^T x - b_i)^2:
/// Hessian = (2/n) A^T A.
pub fn lstsq_l(a: &[f32], n: usize, d: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    2.0 * linalg::spectral_norm_sq_ata(a, n, d, 100, 0xD0E5) / n as f64
}

/// PL constant for least squares: mu = 2 lambda_min(A^T A) / n (valid when
/// A has full column rank; otherwise PL holds on the row space and this
/// returns the smallest eigenvalue estimate, possibly ~0).
pub fn lstsq_pl_mu(a: &[f32], n: usize, d: usize) -> f64 {
    if n == 0 {
        return 0.0;
    }
    2.0 * linalg::lambda_min_ata(a, n, d, 400, 0xD0E6) / n as f64
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn lemma3_closed_forms() {
        // alpha = 3/4: sqrt(1-alpha) = 1/2, theta = 1/2, beta = (1/4)/(1/2) = 1/2.
        let (theta, beta) = theta_beta(0.75);
        assert!((theta - 0.5).abs() < 1e-12);
        assert!((beta - 0.5).abs() < 1e-12);
        assert!((sqrt_beta_over_theta(0.75) - 1.0).abs() < 1e-12);
    }

    #[test]
    fn sqrt_ratio_matches_example1_topk_formula() {
        // Example 1: sqrt(beta/theta) = sqrt(1-k/d)/(1-sqrt(1-k/d)).
        for (k, d) in [(1usize, 10usize), (2, 68), (4, 123), (32, 300)] {
            let alpha = k as f64 / d as f64;
            let expect = (1.0 - alpha).sqrt() / (1.0 - (1.0 - alpha).sqrt());
            assert!(
                (sqrt_beta_over_theta(alpha) - expect).abs() < 1e-10,
                "k={k} d={d}"
            );
        }
    }

    #[test]
    fn sqrt_ratio_upper_bound_two_over_alpha_minus_one() {
        // Eq. (26): sqrt(beta/theta) <= 2/alpha - 1.
        for alpha in [0.001, 0.01, 0.1, 0.5, 0.9, 1.0] {
            assert!(sqrt_beta_over_theta(alpha) <= 2.0 / alpha - 1.0 + 1e-9);
        }
    }

    #[test]
    fn identity_alpha_gives_gd_stepsize() {
        // alpha = 1: sqrt(beta/theta) = 0 so gamma = 1/L (classic GD).
        let g = stepsize_theorem1(4.0, 5.0, 1.0);
        assert!((g - 0.25).abs() < 1e-12);
    }

    #[test]
    fn stepsize_decreases_with_more_aggressive_compression() {
        let (l, lt) = (1.0, 1.2);
        let g_small_alpha = stepsize_theorem1(l, lt, 0.01);
        let g_big_alpha = stepsize_theorem1(l, lt, 0.5);
        assert!(g_small_alpha < g_big_alpha);
    }

    #[test]
    fn theorem2_takes_the_min() {
        // Huge mu forces the theta/(2mu) branch.
        let g = stepsize_theorem2(1.0, 1.0, 0.75, 1e9);
        assert!((g - 0.5 / (2.0 * 1e9)).abs() < 1e-18);
        // Tiny mu leaves the smoothness branch; compare against formula.
        let g2 = stepsize_theorem2(1.0, 1.0, 0.75, 1e-12);
        let expect = 1.0 / (1.0 + (2.0f64 * 0.5 / 0.5).sqrt());
        assert!((g2 - expect).abs() < 1e-12);
    }

    #[test]
    fn pp_stepsize_monotone_and_bounded_by_theorem1() {
        let (l, lt, alpha) = (1.0, 1.3, 0.25);
        let full = stepsize_theorem1(l, lt, alpha);
        assert_eq!(stepsize_pp(l, lt, alpha, 1.0), full);
        let mut prev = 0.0;
        for p in [0.05, 0.1, 0.25, 0.5, 0.75, 0.99] {
            let g = stepsize_pp(l, lt, alpha, p);
            assert!(g > 0.0, "p={p}: gamma must stay positive");
            assert!(g < full, "p={p}: PP bound must be below the full bound");
            assert!(g > prev, "p={p}: monotone in p");
            prev = g;
        }
        // Identity compressor (alpha = 1): absence still costs — the
        // bound stays finite and positive.
        let g = stepsize_pp(1.0, 1.0, 1.0, 0.5);
        assert!(g > 0.0 && g < stepsize_theorem1(1.0, 1.0, 1.0));
    }

    #[test]
    fn l_tilde_dominates_mean_l() {
        let s = Smoothness::from_l_i_mean(vec![1.0, 2.0, 3.0]);
        assert!(s.l_tilde >= s.l - 1e-12);
        assert!((s.l - 2.0).abs() < 1e-12);
        assert!((s.l_tilde - (14.0f64 / 3.0).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn logreg_l_on_identity_rows() {
        // A = I (2x2), n=2, lam=0: lambda_max(A^T A)=1, L = 1/(4*2) = 0.125.
        let a = [1.0f32, 0.0, 0.0, 1.0];
        let l = logreg_l(&a, 2, 2, 0.0);
        assert!((l - 0.125).abs() < 1e-9, "{l}");
        // lam adds 2*lam.
        let l2 = logreg_l(&a, 2, 2, 0.1);
        assert!((l2 - 0.325).abs() < 1e-9, "{l2}");
    }

    #[test]
    fn lstsq_constants_on_identity_rows() {
        let a = [1.0f32, 0.0, 0.0, 1.0];
        assert!((lstsq_l(&a, 2, 2) - 1.0).abs() < 1e-9);
        let mu = lstsq_pl_mu(&a, 2, 2);
        assert!((mu - 1.0).abs() < 1e-3, "{mu}");
    }
}
