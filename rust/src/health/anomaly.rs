//! Pure-function anomaly rules over a sliding window of
//! [`HealthRecord`]s. No I/O, no clocks, no globals: `detect` is a
//! function of (rules, window) evaluated at the newest record, which
//! makes every rule property-testable on synthetic G^t/Φ^t sequences.

use super::HealthRecord;

/// What went wrong. Each kind maps to one rule in [`detect`].
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum AnomalyKind {
    /// Windowed-average contraction ratio exceeded Eq. 3's (1−α) bound.
    /// Averaged because rand-k style compressors only contract in
    /// expectation; deterministic top-k violates per-round long before
    /// the average trips.
    ContractionViolation,
    /// Φ^t rose beyond tolerance — Theorem 1 descent broken.
    LyapunovIncrease,
    /// A full window of observations with no meaningful Φ descent while
    /// G^t is still far from zero (converged runs have tiny G and are
    /// exempt).
    StalledDescent,
    /// One worker's G contribution dwarfs the fleet median — a bad
    /// shard, broken compressor state, or desynced mirror.
    WorkerOutlier,
    /// More session reconnects landed in one round than the fleet has
    /// workers — the transport is flapping instead of recovering.
    /// Raised by the session accounting in `Health::record_session`,
    /// not by [`detect`]: it reads transport counters, not the
    /// certificate window.
    ReconnectStorm,
}

impl AnomalyKind {
    pub fn name(&self) -> &'static str {
        match self {
            AnomalyKind::ContractionViolation => "contraction_violation",
            AnomalyKind::LyapunovIncrease => "lyapunov_increase",
            AnomalyKind::StalledDescent => "stalled_descent",
            AnomalyKind::WorkerOutlier => "worker_outlier",
            AnomalyKind::ReconnectStorm => "reconnect_storm",
        }
    }
}

/// One raised event, attributed to the round it was detected at.
#[derive(Clone, Debug, PartialEq)]
pub struct Anomaly {
    pub kind: AnomalyKind,
    pub round: usize,
    pub detail: String,
}

/// Rule thresholds. `contraction_bound` and `window` come from the
/// health config; the rest have conservative defaults tuned so a clean
/// EF21 run at the Theorem 1 stepsize raises nothing.
#[derive(Clone, Debug)]
pub struct Rules {
    /// Eq. 3's (1−α): E‖C(v)−v‖² ≤ (1−α)‖v‖².
    pub contraction_bound: f64,
    /// Relative tolerance (numerical slack) for the Φ rules and the
    /// contraction margin.
    pub tol: f64,
    /// Window length the windowed rules need filled before firing.
    pub window: usize,
    /// WorkerOutlier fires when err_sq > outlier_factor × median.
    pub outlier_factor: f64,
    /// G floor below which Stalled/Outlier are exempt (converged run).
    pub g_floor: f64,
}

impl Default for Rules {
    fn default() -> Self {
        Rules {
            contraction_bound: 1.0,
            tol: 1e-6,
            window: 8,
            outlier_factor: 50.0,
            g_floor: 1e-10,
        }
    }
}

/// Evaluate all rules at the NEWEST record of `window` (oldest-first
/// slice). Windowed rules stay silent until the window is full; this is
/// called once per observation, so each returned anomaly is a fresh
/// event for that round.
pub fn detect(rules: &Rules, window: &[HealthRecord]) -> Vec<Anomaly> {
    let mut out = Vec::new();
    let newest = match window.last() {
        Some(r) => r,
        None => return out,
    };
    let round = newest.round;

    // 1. Contraction-bound violation: mean of the per-round worst-case
    // ratios over a full window exceeds (1−α)(1+tol).
    if window.len() >= rules.window {
        let mut sum = 0.0;
        let mut n = 0usize;
        for r in window {
            if r.ratio_max.is_finite() {
                sum += r.ratio_max;
                n += 1;
            }
        }
        if n >= rules.window {
            let mean = sum / n as f64;
            let bound = rules.contraction_bound * (1.0 + rules.tol);
            if mean > bound {
                out.push(Anomaly {
                    kind: AnomalyKind::ContractionViolation,
                    round,
                    detail: format!(
                        "windowed mean contraction ratio {mean:.6e} > (1-alpha) bound {:.6e} \
                         over {n} rounds",
                        rules.contraction_bound
                    ),
                });
            }
        }
    }

    // 2. Lyapunov increase: Φ rose beyond tolerance this observation.
    if newest.phi_delta.is_finite() && newest.phi.is_finite() {
        let prev_phi = newest.phi - newest.phi_delta;
        let slack = rules.tol * prev_phi.abs().max(1.0);
        if newest.phi_delta > slack {
            out.push(Anomaly {
                kind: AnomalyKind::LyapunovIncrease,
                round,
                detail: format!(
                    "phi rose {prev_phi:.6e} -> {:.6e} (delta {:+.6e} > slack {slack:.3e})",
                    newest.phi, newest.phi_delta
                ),
            });
        }
    }

    // 3. Stalled descent: a full window of deltas, none a meaningful
    // decrease, while G says we are far from a stationary point. The
    // G guard keeps converged plateaus (tiny G, tiny deltas) quiet.
    if window.len() >= rules.window && newest.gt.is_finite() && newest.gt > rules.g_floor {
        let deltas: Vec<f64> =
            window.iter().map(|r| r.phi_delta).filter(|d| d.is_finite()).collect();
        if deltas.len() >= rules.window - 1 {
            let scale = rules.tol * newest.phi.abs().max(1.0);
            if deltas.iter().all(|&d| d >= -scale) {
                out.push(Anomaly {
                    kind: AnomalyKind::StalledDescent,
                    round,
                    detail: format!(
                        "no phi descent over last {} observations (G^t = {:.3e} still above \
                         floor {:.1e})",
                        deltas.len(),
                        newest.gt,
                        rules.g_floor
                    ),
                });
            }
        }
    }

    // 4. Per-worker outlier G contribution. Needs enough workers for a
    // median to mean anything.
    let mut finite: Vec<f64> = newest.worker_g.iter().copied().filter(|g| g.is_finite()).collect();
    if finite.len() >= 4 {
        finite.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let median = finite[finite.len() / 2];
        if median > rules.g_floor {
            for (w, &g) in newest.worker_g.iter().enumerate() {
                if g.is_finite() && g > rules.outlier_factor * median {
                    out.push(Anomaly {
                        kind: AnomalyKind::WorkerOutlier,
                        round,
                        detail: format!(
                            "worker {w} err_sq {g:.3e} > {}x fleet median {median:.3e}",
                            rules.outlier_factor
                        ),
                    });
                }
            }
        }
    }

    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Synthesize one health record; ratios/worker_g default healthy.
    fn rec(round: usize, phi: f64, phi_delta: f64, gt: f64, ratio: f64) -> HealthRecord {
        HealthRecord {
            round,
            loss: phi - gt,
            gt,
            phi,
            phi_delta,
            ratio_max: ratio,
            worker_g: vec![gt; 4],
        }
    }

    fn rules() -> Rules {
        // alpha = 0.25 -> bound 0.75, window 4 to keep tests short.
        Rules { contraction_bound: 0.75, window: 4, ..Rules::default() }
    }

    /// Build the window a monitor would hold after feeding `seq`
    /// (keeps the last `window` records) and detect at the newest.
    fn detect_tail(r: &Rules, seq: &[HealthRecord]) -> Vec<Anomaly> {
        let start = seq.len().saturating_sub(r.window);
        detect(r, &seq[start..])
    }

    /// Property: a clean EF21 trajectory — Φ strictly decreasing, ratios
    /// under the bound, balanced workers — raises zero anomalies at
    /// every step, across many randomized decay profiles.
    #[test]
    fn clean_ef21_sequences_raise_nothing() {
        let r = rules();
        for seed in 0..50u64 {
            let mut rng = Rng::seed(seed + 1);
            let mut phi = 10.0 * (1.0 + rng.next_f64());
            let mut gt = 1.0;
            let mut seq = Vec::new();
            for t in 0..30 {
                // Geometric-ish decay with random per-round factors,
                // ratios spread anywhere inside the contraction bound.
                let decay = 0.80 + 0.15 * rng.next_f64();
                let new_phi = phi * decay;
                let delta = if t == 0 { f64::NAN } else { new_phi - phi };
                phi = new_phi;
                gt *= decay;
                let ratio = r.contraction_bound * rng.next_f64() * 0.99;
                seq.push(rec(t, phi, delta, gt, ratio));
                let found = detect_tail(&r, &seq);
                assert!(found.is_empty(), "seed {seed} round {t}: {found:?}");
            }
        }
    }

    /// Property: injecting a sustained contraction violation into an
    /// otherwise-clean run raises exactly ContractionViolation — no
    /// other kind — once the window fills with bad ratios. Fixed ratios
    /// keep the first-fire round exact: with clean = 0.1×bound and
    /// bad = 1.2×bound, a window of (1 clean + 3 bad) averages
    /// 0.925×bound — under the bound — so the rule first trips when the
    /// window holds only bad rounds.
    #[test]
    fn injected_contraction_violation_raises_exactly_that() {
        let r = rules();
        let mut phi = 5.0;
        let mut seq = Vec::new();
        let mut fired_at = None;
        for t in 0..20 {
            let new_phi = phi * 0.9;
            let delta = if t == 0 { f64::NAN } else { new_phi - phi };
            phi = new_phi;
            let ratio =
                if t >= 8 { r.contraction_bound * 1.2 } else { r.contraction_bound * 0.1 };
            seq.push(rec(t, phi, delta, 0.5, ratio));
            let found = detect_tail(&r, &seq);
            if t < 8 + r.window - 1 {
                // Window not yet saturated with violating rounds.
                assert!(found.is_empty(), "round {t}: early fire {found:?}");
            } else {
                assert!(!found.is_empty(), "round {t}: should fire");
                for a in &found {
                    assert_eq!(a.kind, AnomalyKind::ContractionViolation, "round {t}");
                }
                fired_at.get_or_insert(t);
            }
        }
        // Fires exactly when the window first fills with violations.
        assert_eq!(fired_at, Some(8 + r.window - 1));
    }

    #[test]
    fn lyapunov_increase_fires_on_phi_spike_only() {
        let r = rules();
        let mut seq = vec![
            rec(0, 5.0, f64::NAN, 0.5, 0.3),
            rec(1, 4.5, -0.5, 0.4, 0.3),
            rec(2, 4.0, -0.5, 0.3, 0.3),
        ];
        assert!(detect_tail(&r, &seq).is_empty());
        // Spike: phi jumps 4.0 -> 6.0.
        seq.push(rec(3, 6.0, 2.0, 0.3, 0.3));
        let found = detect_tail(&r, &seq);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].kind, AnomalyKind::LyapunovIncrease);
        assert_eq!(found[0].round, 3);
        // A tiny numerical wobble under tolerance stays quiet.
        seq.push(rec(4, 6.0 + 1e-9, 1e-9, 0.3, 0.3));
        assert!(detect_tail(&r, &seq).is_empty());
    }

    #[test]
    fn stalled_descent_needs_full_window_and_big_g() {
        let r = rules();
        // Plateau with G far above floor: fires once window is full.
        let mut seq = vec![rec(0, 5.0, f64::NAN, 0.5, 0.3)];
        for t in 1..r.window + 1 {
            seq.push(rec(t, 5.0, 0.0, 0.5, 0.3));
        }
        let found = detect_tail(&r, &seq);
        assert!(found.iter().any(|a| a.kind == AnomalyKind::StalledDescent), "{found:?}");
        // Same plateau at convergence (G under floor): silent.
        let mut seq = vec![rec(0, 5.0, f64::NAN, 1e-14, 0.3)];
        for t in 1..r.window + 1 {
            seq.push(rec(t, 5.0, 0.0, 1e-14, 0.3));
        }
        assert!(detect_tail(&r, &seq).is_empty());
    }

    #[test]
    fn worker_outlier_fires_on_skewed_fleet() {
        let r = rules();
        let mut bad = rec(7, 5.0, -0.1, 0.5, 0.3);
        bad.worker_g = vec![0.1, 0.1, 0.1, 0.1, 0.1 * r.outlier_factor * 20.0];
        let found = detect(&r, &[bad]);
        assert_eq!(found.len(), 1);
        assert_eq!(found[0].kind, AnomalyKind::WorkerOutlier);
        assert!(found[0].detail.contains("worker 4"));
        // Balanced fleet, and tiny-median fleets, stay quiet.
        let ok = rec(8, 5.0, -0.1, 0.5, 0.3);
        assert!(detect(&r, &[ok]).is_empty());
        let mut tiny = rec(9, 5.0, -0.1, 1e-13, 0.3);
        tiny.worker_g = vec![1e-13, 1e-13, 1e-13, 1e-13, 1e-9];
        assert!(detect(&r, &[tiny]).is_empty());
    }

    #[test]
    fn nan_ratio_windows_never_fire_contraction() {
        // Transport paths: ratio_max always NaN -> rule inactive.
        let r = rules();
        let mut seq = Vec::new();
        for t in 0..10 {
            seq.push(rec(t, 5.0 - t as f64 * 0.1, -0.1, 0.5, f64::NAN));
        }
        assert!(detect_tail(&r, &seq)
            .iter()
            .all(|a| a.kind != AnomalyKind::ContractionViolation));
    }
}
