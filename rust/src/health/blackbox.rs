//! Crash flight recorder: a bounded ring of recent rounds dumped
//! atomically as a versioned `ef21.blackbox/v1` JSON artifact when
//! something goes wrong (divergence guard, anomaly, `killmaster@r`, a
//! worker error). The dump is the postmortem counterpart of the live
//! `--ops` endpoint: everything a human needs to reconstruct the last
//! seconds of a run without re-running it.
//!
//! Format notes: serialized with [`crate::util::json::Json`] (stable
//! key order, integers rendered without decimals) so `python3 -m
//! json.tool` and diff-based CI checks both work; written with the
//! checkpoint module's tmp → write → fsync → rename discipline so a
//! crash mid-dump never leaves a torn artifact; NaN/inf degrade to
//! `null` (JSON has no NaN).

use super::anomaly::Anomaly;
use super::{num, HealthRecord};
use crate::metrics::RoundRecord;
use crate::sched::RoundPlan;
use crate::telemetry::trace;
use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::{BTreeMap, VecDeque};
use std::io::Write;
use std::path::Path;

/// Artifact schema tag; bump on breaking layout changes.
pub const SCHEMA: &str = "ef21.blackbox/v1";

/// Ring capacity in distinct rounds. Enough to cover several monitor
/// windows without letting a million-round run grow the artifact.
pub const DEFAULT_RING: usize = 64;

/// How many trace events the dump snapshots from the ring tail.
const TRACE_TAIL: usize = 64;

/// Cap on retained anomalies (the counted total lives in telemetry).
const MAX_ANOMALIES: usize = 64;

/// FNV-1a over a float slice's little-endian bytes — the worker state
/// digest the ring stores (no intermediate byte buffer, so probing
/// allocates nothing beyond the digest vector itself).
pub fn digest_f64(v: &[f64]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for x in v {
        for b in x.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100_0000_01b3);
        }
    }
    h
}

/// Everything captured about one round. Fields fill in lazily as the
/// runner reports them; a round with only a metrics row is fine.
#[derive(Clone, Debug, Default)]
struct Entry {
    round: usize,
    /// Mirrored metrics row (loss/grad/bits at x^{t+1}).
    metrics: Option<(f64, f64, f64, f64, f64)>, // bits, loss, grad_sq, gt, dcgd
    health: Option<HealthRecord>,
    /// Scheduler plan digest: (participants, crashes, resyncs, stragglers, dups).
    plan: Option<(usize, usize, usize, usize, usize)>,
    /// Per-worker state digests (FNV-1a over mirror bytes), worker order.
    digests: Option<Vec<u64>>,
    /// Session-layer deltas this round: (reconnects, replayed_frames,
    /// crc_rejects). Only recorded for rounds where any were nonzero.
    session: Option<(u64, u64, u64)>,
}

impl Entry {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("round".into(), Json::Num(self.round as f64));
        if let Some((bits, loss, grad_sq, gt, dcgd)) = self.metrics {
            let mut mm = BTreeMap::new();
            mm.insert("bits_per_client".into(), num(bits));
            mm.insert("loss".into(), num(loss));
            mm.insert("grad_norm_sq".into(), num(grad_sq));
            mm.insert("gt".into(), num(gt));
            mm.insert("dcgd_frac".into(), num(dcgd));
            m.insert("metrics".into(), Json::Obj(mm));
        }
        if let Some(h) = &self.health {
            m.insert("health".into(), h.to_json());
        }
        if let Some((participants, crashes, resyncs, stragglers, dups)) = self.plan {
            let mut pm = BTreeMap::new();
            pm.insert("participants".into(), Json::Num(participants as f64));
            pm.insert("crashes".into(), Json::Num(crashes as f64));
            pm.insert("resyncs".into(), Json::Num(resyncs as f64));
            pm.insert("stragglers".into(), Json::Num(stragglers as f64));
            pm.insert("dups".into(), Json::Num(dups as f64));
            m.insert("plan".into(), Json::Obj(pm));
        }
        if let Some((reconnects, replayed, crc_rejects)) = self.session {
            let mut sm = BTreeMap::new();
            sm.insert("reconnects".into(), Json::Num(reconnects as f64));
            sm.insert("replayed_frames".into(), Json::Num(replayed as f64));
            sm.insert("crc_rejects".into(), Json::Num(crc_rejects as f64));
            m.insert("session".into(), Json::Obj(sm));
        }
        if let Some(d) = &self.digests {
            // Hex strings: u64 digests don't fit f64 exactly.
            m.insert(
                "worker_digests".into(),
                Json::Arr(d.iter().map(|v| Json::Str(format!("{v:016x}"))).collect()),
            );
        }
        Json::Obj(m)
    }
}

/// The bounded ring plus the anomaly log. Owned by [`super::Health`];
/// all recording methods are cheap (no I/O until [`FlightRecorder::dump`]).
pub struct FlightRecorder {
    label: String,
    cap: usize,
    entries: VecDeque<Entry>,
    anomalies: Vec<Anomaly>,
    anomalies_dropped: u64,
}

impl FlightRecorder {
    pub fn new(label: &str, cap: usize) -> FlightRecorder {
        FlightRecorder {
            label: label.to_string(),
            cap: cap.max(1),
            entries: VecDeque::new(),
            anomalies: Vec::new(),
            anomalies_dropped: 0,
        }
    }

    /// Get-or-create the ring slot for `round` (rounds arrive in
    /// nondecreasing order from every runner).
    fn entry(&mut self, round: usize) -> &mut Entry {
        let fresh = match self.entries.back() {
            Some(e) => e.round != round,
            None => true,
        };
        if fresh {
            self.entries.push_back(Entry { round, ..Entry::default() });
            while self.entries.len() > self.cap {
                self.entries.pop_front();
            }
        }
        self.entries.back_mut().unwrap()
    }

    pub fn record_round(&mut self, rec: &RoundRecord) {
        self.entry(rec.round).metrics =
            Some((rec.bits_per_client, rec.loss, rec.grad_norm_sq, rec.gt, rec.dcgd_frac));
    }

    pub fn record_health(&mut self, rec: &HealthRecord) {
        self.entry(rec.round).health = Some(rec.clone());
    }

    pub fn record_plan(&mut self, round: usize, plan: &RoundPlan) {
        let participants = plan.active.iter().filter(|&&a| a).count();
        let stragglers = plan.delay_ms.iter().filter(|&&d| d > 0).count();
        let dups = plan.dup.iter().filter(|&&d| d).count();
        self.entry(round).plan =
            Some((participants, plan.crash.len(), plan.resync.len(), stragglers, dups));
    }

    pub fn record_worker_digests(&mut self, round: usize, digests: Vec<u64>) {
        self.entry(round).digests = Some(digests);
    }

    /// Record a round's session-layer activity deltas (reconnects,
    /// replayed frames, CRC rejects) — only called for active rounds.
    pub fn record_session(&mut self, round: usize, delta: (u64, u64, u64)) {
        self.entry(round).session = Some(delta);
    }

    pub fn note_anomaly(&mut self, a: Anomaly) {
        if self.anomalies.len() < MAX_ANOMALIES {
            self.anomalies.push(a);
        } else {
            self.anomalies_dropped += 1;
        }
    }

    /// Render the artifact body.
    fn to_json(&self, reason: &str, round: usize) -> Json {
        let mut m = BTreeMap::new();
        m.insert("schema".into(), Json::Str(SCHEMA.into()));
        m.insert("label".into(), Json::Str(self.label.clone()));
        m.insert("reason".into(), Json::Str(reason.to_string()));
        m.insert("round".into(), Json::Num(round as f64));
        m.insert(
            "anomalies".into(),
            Json::Arr(
                self.anomalies
                    .iter()
                    .map(|a| {
                        let mut am = BTreeMap::new();
                        am.insert("kind".into(), Json::Str(a.kind.name().into()));
                        am.insert("round".into(), Json::Num(a.round as f64));
                        am.insert("detail".into(), Json::Str(a.detail.clone()));
                        Json::Obj(am)
                    })
                    .collect(),
            ),
        );
        m.insert("anomalies_dropped".into(), Json::Num(self.anomalies_dropped as f64));
        m.insert("rounds".into(), Json::Arr(self.entries.iter().map(Entry::to_json).collect()));
        // Trace tail: non-destructive snapshot so an active exporter
        // still writes the full trace at shutdown.
        let tail = trace::tail(TRACE_TAIL);
        let mut tm = BTreeMap::new();
        tm.insert("dropped".into(), Json::Num(trace::dropped_total() as f64));
        tm.insert(
            "tail".into(),
            Json::Arr(
                tail.iter()
                    .map(|e| {
                        let mut em = BTreeMap::new();
                        em.insert("name".into(), Json::Str(e.name.into()));
                        em.insert("tid".into(), Json::Num(e.tid as f64));
                        em.insert("start_ns".into(), Json::Num(e.start_ns as f64));
                        em.insert("dur_ns".into(), Json::Num(e.dur_ns as f64));
                        if let Some((k, v)) = e.arg {
                            em.insert("arg".into(), Json::Str(format!("{k}={v}")));
                        }
                        Json::Obj(em)
                    })
                    .collect(),
            ),
        );
        m.insert("trace".into(), Json::Obj(tm));
        Json::Obj(m)
    }

    /// Write the artifact atomically (tmp → write → fsync → rename, the
    /// checkpoint discipline) and return the byte count.
    pub fn dump(&self, path: &Path, reason: &str, round: usize) -> Result<u64> {
        let body = self.to_json(reason, round).to_string();
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)
                    .with_context(|| format!("creating blackbox dir {}", parent.display()))?;
            }
        }
        let tmp = path.with_extension("blackbox.tmp");
        {
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(body.as_bytes())
                .and_then(|()| f.sync_all())
                .with_context(|| format!("writing {}", tmp.display()))?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
        Ok(body.len() as u64)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::health::anomaly::AnomalyKind;

    fn rr(round: usize, loss: f64) -> RoundRecord {
        RoundRecord {
            round,
            bits_per_client: 64.0,
            loss,
            grad_norm_sq: 0.5,
            gt: 0.25,
            dcgd_frac: 0.0,
        }
    }

    #[test]
    fn ring_is_bounded_and_keyed_by_round() {
        let mut fr = FlightRecorder::new("t", 4);
        for t in 0..10 {
            fr.record_round(&rr(t, 1.0));
            fr.record_worker_digests(t, vec![t as u64]);
        }
        assert_eq!(fr.entries.len(), 4);
        assert_eq!(fr.entries.front().unwrap().round, 6);
        // Same-round updates merge into one entry.
        let e = fr.entries.back().unwrap();
        assert!(e.metrics.is_some() && e.digests.is_some());
    }

    #[test]
    fn dump_is_versioned_valid_json_and_atomic() {
        let dir = std::env::temp_dir().join(format!("ef21_bb_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("bb.json");
        let mut fr = FlightRecorder::new("smoke", 8);
        fr.record_round(&rr(3, f64::NAN)); // NaN must degrade to null
        fr.note_anomaly(Anomaly {
            kind: AnomalyKind::LyapunovIncrease,
            round: 3,
            detail: "phi rose".into(),
        });
        let bytes = fr.dump(&path, "divergence", 3).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert_eq!(bytes as usize, text.len());
        let j = Json::parse(&text).expect("valid json");
        assert_eq!(j.get("schema").and_then(|s| s.as_str()), Some(SCHEMA));
        assert_eq!(j.get("reason").and_then(|s| s.as_str()), Some("divergence"));
        let rounds = j.get("rounds").unwrap().as_arr().unwrap();
        assert_eq!(rounds.len(), 1);
        assert_eq!(rounds[0].get("metrics").unwrap().get("loss"), Some(&Json::Null));
        let an = j.get("anomalies").unwrap().as_arr().unwrap();
        assert_eq!(an[0].get("kind").and_then(|s| s.as_str()), Some("lyapunov_increase"));
        // No tmp file left behind.
        assert!(!dir.join("bb.blackbox.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
