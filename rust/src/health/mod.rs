//! Theory-grounded run health: the paper's own convergence certificates
//! monitored live, plus postmortem capture (DESIGN.md §12).
//!
//! Theorem 1 of EF21 proves descent of the Lyapunov function
//! `Φ^t = f(x^t) + (γ/θ)·G^t`, where
//! `G^t = (1/n)·Σ_i ||g_i^t − ∇f_i(x^t)||²` is the compression error
//! that Eq. 3 contracts by `(1−α)` each round. A run that silently
//! violates the contraction (bad α from a misconfigured block budget,
//! heterogeneous shards outside the stepsize bound) looks identical to a
//! healthy one until the divergence cap trips — unless these quantities
//! are computed at runtime. This module does exactly that, in three
//! layers:
//!
//! 1. **Monitor** ([`Health::observe`]): on a `--health every:<r>`
//!    cadence, compute `G^t`, `Φ^t`, per-worker contraction ratios
//!    against the `(1−α)` bound, and descent deltas — exported as
//!    `health.*` telemetry keys and per-round [`HealthRecord`]s.
//! 2. **Anomaly detector** ([`anomaly`]): pure-function rules over a
//!    sliding window of health records raising counted, logged events.
//! 3. **Flight recorder** ([`blackbox`]): a bounded ring of recent
//!    rounds dumped atomically as a versioned `ef21.blackbox/v1` JSON
//!    artifact when the divergence guard, an anomaly, `killmaster@r`,
//!    or a worker error fires. The live counterpart is the `--ops`
//!    HTTP endpoint ([`ops`]).
//!
//! Everything here is off by default and bitwise invisible when off:
//! the monitor reads only cached worker instrumentation (the same
//! values [`crate::coordinator`]'s `observe` reduces), never touches
//! the trajectory, and allocates nothing unless a health config is
//! present.
//!
//! # Where the quantities come from
//!
//! After round `t` the master has stepped to `x^{t+1}` and every
//! participant holds `last_grad = ∇f_i(x^{t+1})` and
//! `g_i^{t+1} = g_i^t + C(∇f_i(x^{t+1}) − g_i^t)`, so:
//!
//! * `err_sq_i = ||g_i^{t+1} − ∇f_i(x^{t+1})||²` — exactly the `G^{t+1}`
//!   summand, and also exactly `||C(v_i) − v_i||²` for
//!   `v_i = ∇f_i(x^{t+1}) − g_i^t`;
//! * `ref_sq_i = ||v_i||²` — the compressor input norm, making
//!   `err_sq_i / ref_sq_i ≤ (1−α)` the Eq. 3 contraction check
//!   (deterministic compressors satisfy it per round; rand-k only in
//!   expectation, which is why the anomaly rule averages over a window).
//!
//! The sim runners probe both scalars from the worker pool; the
//! distributed/reactor paths piggyback `err_sq` (8 bytes) on the uplink
//! frame (`ref_sq` stays worker-local there, so the contraction rule is
//! simply inactive — `ratio_max` is NaN).

pub mod anomaly;
pub mod blackbox;
pub mod ops;

use crate::config::cli::Args;
use crate::telemetry::{self, keys};
use crate::util::json::Json;
use anyhow::{bail, Result};
use std::collections::BTreeMap;
use std::collections::VecDeque;
use std::path::PathBuf;

/// CLI-level health spec, parsed from
/// `--health off | every:<r>[,window:<w>][,tol:<f>][,blackbox:<path>]`.
/// Deliberately excluded from the run fingerprint (like telemetry):
/// monitoring never changes the trajectory, so a checkpoint moves freely
/// between health-on and health-off runs.
#[derive(Clone, Debug, PartialEq)]
pub struct HealthSpec {
    /// Monitor cadence in rounds; 0 = off (the default).
    pub every: usize,
    /// Sliding-window length for the anomaly rules.
    pub window: usize,
    /// Relative tolerance for the Lyapunov/contraction rules.
    pub tol: f64,
    /// Flight-recorder dump path (`ef21.blackbox/v1` JSON artifact).
    pub blackbox: Option<String>,
}

impl Default for HealthSpec {
    fn default() -> Self {
        HealthSpec { every: 0, window: 8, tol: 1e-6, blackbox: None }
    }
}

impl HealthSpec {
    pub fn is_off(&self) -> bool {
        self.every == 0
    }

    /// Parse the `--health` grammar.
    pub fn parse(spec: &str) -> Result<HealthSpec> {
        let mut out = HealthSpec::default();
        if spec == "off" {
            return Ok(out);
        }
        for part in spec.split(',') {
            let (key, val) = match part.split_once(':') {
                Some(kv) => kv,
                None => bail!(
                    "bad --health clause '{part}' (expected \
                     every:<r>[,window:<w>][,tol:<f>][,blackbox:<path>] or off)"
                ),
            };
            match key {
                "every" => {
                    out.every = val
                        .parse()
                        .map_err(|e| anyhow::anyhow!("--health every:{val}: {e}"))?;
                    if out.every == 0 {
                        bail!("--health every:0 is 'off'; spell it --health off");
                    }
                }
                "window" => {
                    out.window = val
                        .parse()
                        .map_err(|e| anyhow::anyhow!("--health window:{val}: {e}"))?;
                    if out.window < 2 {
                        bail!("--health window must be >= 2, got {val}");
                    }
                }
                "tol" => {
                    out.tol = val
                        .parse()
                        .map_err(|e| anyhow::anyhow!("--health tol:{val}: {e}"))?;
                    if !(out.tol >= 0.0) {
                        bail!("--health tol must be >= 0, got {val}");
                    }
                }
                "blackbox" => out.blackbox = Some(val.to_string()),
                other => bail!("unknown --health clause '{other}'"),
            }
        }
        if out.every == 0 {
            bail!("--health needs an every:<r> clause (or 'off')");
        }
        Ok(out)
    }

    /// Read `--health` (default off).
    pub fn from_args(args: &Args) -> Result<HealthSpec> {
        match args.get_str("health") {
            None => Ok(HealthSpec::default()),
            Some(s) => Self::parse(s),
        }
    }

    /// Bind the spec to one run's theory context. `None` when off.
    /// `θ = 1 − sqrt(1−α)` is the Lemma 3 constant the Lyapunov
    /// coefficient `γ/θ` uses.
    pub fn build(&self, alpha: f64, gamma: f64) -> Option<HealthCfg> {
        if self.is_off() {
            return None;
        }
        let (theta, _beta) = crate::theory::theta_beta(alpha);
        Some(HealthCfg {
            every: self.every,
            window: self.window,
            tol: self.tol,
            blackbox: self.blackbox.clone().map(PathBuf::from),
            alpha,
            gamma,
            theta,
        })
    }
}

/// A health spec bound to one run's theory constants — everything the
/// monitor needs to evaluate the paper's certificates.
#[derive(Clone, Debug)]
pub struct HealthCfg {
    pub every: usize,
    pub window: usize,
    pub tol: f64,
    pub blackbox: Option<PathBuf>,
    /// Compressor contraction parameter (Eq. 3's α).
    pub alpha: f64,
    /// Master stepsize γ.
    pub gamma: f64,
    /// Lemma 3's θ = 1 − sqrt(1−α); the Lyapunov coefficient is γ/θ.
    pub theta: f64,
}

/// One monitored round. All quantities refer to the state after the
/// round's master step (the same convention as
/// [`crate::metrics::RoundRecord`]). NaN marks "not measurable on this
/// path" (e.g. `ratio_max` over transports).
#[derive(Clone, Debug)]
pub struct HealthRecord {
    pub round: usize,
    /// f(x) = average worker loss.
    pub loss: f64,
    /// G^t = (1/n) Σ err_sq_i.
    pub gt: f64,
    /// Φ^t = loss + (γ/θ)·G^t.
    pub phi: f64,
    /// Φ^t − Φ^{t−obs} (NaN on the first observation).
    pub phi_delta: f64,
    /// max_i err_sq_i / ref_sq_i (NaN when ref_sq is unavailable).
    pub ratio_max: f64,
    /// Per-worker err_sq_i in worker order (NaN = unknown).
    pub worker_g: Vec<f64>,
}

/// JSON number that degrades NaN/inf to `null` (JSON has no NaN).
pub(crate) fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

impl HealthRecord {
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("round".into(), Json::Num(self.round as f64));
        m.insert("loss".into(), num(self.loss));
        m.insert("gt".into(), num(self.gt));
        m.insert("phi".into(), num(self.phi));
        m.insert("phi_delta".into(), num(self.phi_delta));
        m.insert("ratio_max".into(), num(self.ratio_max));
        m.insert(
            "worker_g".into(),
            Json::Arr(self.worker_g.iter().map(|&g| num(g)).collect()),
        );
        Json::Obj(m)
    }
}

/// The per-run health state machine: monitor + anomaly window + flight
/// recorder. Owned by whichever runner drives the round loop; every
/// method is cheap and none touches the trajectory.
pub struct Health {
    pub cfg: HealthCfg,
    label: String,
    /// Sliding window of recent records (oldest first).
    recent: VecDeque<HealthRecord>,
    rules: anomaly::Rules,
    pub records: u64,
    pub anomaly_count: u64,
    pub recorder: blackbox::FlightRecorder,
    /// Cumulative session counters `(reconnects, replayed_frames,
    /// crc_rejects)` at the previous observation, for per-round deltas.
    last_session: (u64, u64, u64),
}

impl Health {
    pub fn new(cfg: HealthCfg, label: &str) -> Health {
        let rules = anomaly::Rules {
            contraction_bound: 1.0 - cfg.alpha,
            tol: cfg.tol,
            window: cfg.window,
            ..anomaly::Rules::default()
        };
        Health {
            cfg,
            label: label.to_string(),
            recent: VecDeque::new(),
            rules,
            records: 0,
            anomaly_count: 0,
            recorder: blackbox::FlightRecorder::new(label, blackbox::DEFAULT_RING),
            last_session: (0, 0, 0),
        }
    }

    /// Is the monitor due at round `t`?
    pub fn due(&self, t: usize) -> bool {
        self.cfg.every > 0 && t % self.cfg.every == 0
    }

    /// Feed one observation: mean loss plus per-worker
    /// `(err_sq, ref_sq)` pairs (NaN where unavailable). Computes
    /// G^t/Φ^t/ratios, exports `health.*` telemetry, runs the anomaly
    /// rules, and records everything into the flight recorder. Returns
    /// the anomalies this observation raised (usually empty).
    pub fn observe(
        &mut self,
        round: usize,
        loss: f64,
        workers: &[(f64, f64)],
    ) -> Vec<anomaly::Anomaly> {
        let mut worker_g = Vec::with_capacity(workers.len());
        let mut g_sum = 0.0;
        let mut g_n = 0usize;
        let mut ratio_max = f64::NAN;
        for &(err_sq, ref_sq) in workers {
            worker_g.push(err_sq);
            if err_sq.is_finite() {
                g_sum += err_sq;
                g_n += 1;
            }
            if err_sq.is_finite() && ref_sq.is_finite() && ref_sq > 0.0 {
                let r = err_sq / ref_sq;
                if !(ratio_max >= r) {
                    ratio_max = r;
                }
            }
        }
        // G^t averages over ALL workers (the paper's 1/n), treating the
        // rare all-NaN probe as unmeasurable rather than zero.
        let gt = if g_n == 0 { f64::NAN } else { g_sum / workers.len() as f64 };
        let phi = loss + (self.cfg.gamma / self.cfg.theta) * gt;
        let phi_delta = match self.recent.back() {
            Some(prev) => phi - prev.phi,
            None => f64::NAN,
        };
        let rec = HealthRecord { round, loss, gt, phi, phi_delta, ratio_max, worker_g };

        telemetry::counter(keys::HEALTH_RECORDS).incr(1);
        telemetry::gauge(keys::HEALTH_G).set(gt);
        telemetry::gauge(keys::HEALTH_PHI).set(phi);
        telemetry::gauge(keys::HEALTH_PHI_DELTA).set(phi_delta);
        telemetry::gauge(keys::HEALTH_RATIO_MAX).set(ratio_max);

        self.recent.push_back(rec.clone());
        while self.recent.len() > self.cfg.window {
            self.recent.pop_front();
        }
        self.records += 1;

        self.recent.make_contiguous();
        let anomalies = anomaly::detect(&self.rules, self.recent.as_slices().0);
        for a in &anomalies {
            self.anomaly_count += 1;
            telemetry::counter(keys::HEALTH_ANOMALIES).incr(1);
            eprintln!("health: ANOMALY [{}] round {}: {}", a.kind.name(), a.round, a.detail);
            self.recorder.note_anomaly(a.clone());
        }
        self.recorder.record_health(&rec);
        ops::publish_health(&rec, self.anomaly_count, self.records);
        anomalies
    }

    /// Feed the run's cumulative session counters `(reconnects,
    /// replayed_frames, crc_rejects)` after round `round`. Computes
    /// per-round deltas, mirrors active rounds into the flight
    /// recorder, and raises [`anomaly::AnomalyKind::ReconnectStorm`]
    /// when more reconnects landed in one round than the fleet has
    /// workers — a healthy recovery touches each lost worker once, so
    /// exceeding `n` means the transport is flapping.
    pub fn record_session(&mut self, round: usize, n_workers: usize, totals: (u64, u64, u64)) {
        let prev = self.last_session;
        self.last_session = totals;
        let delta = (
            totals.0.saturating_sub(prev.0),
            totals.1.saturating_sub(prev.1),
            totals.2.saturating_sub(prev.2),
        );
        if delta == (0, 0, 0) {
            return;
        }
        self.recorder.record_session(round, delta);
        if delta.0 > n_workers as u64 {
            let a = anomaly::Anomaly {
                kind: anomaly::AnomalyKind::ReconnectStorm,
                round,
                detail: format!(
                    "{} session reconnects in one round across {n_workers} workers",
                    delta.0
                ),
            };
            self.anomaly_count += 1;
            telemetry::counter(keys::HEALTH_ANOMALIES).incr(1);
            eprintln!("health: ANOMALY [{}] round {}: {}", a.kind.name(), a.round, a.detail);
            self.recorder.note_anomaly(a);
        }
    }

    /// Mirror a recorded metrics row into the flight recorder ring.
    pub fn record_round(&mut self, rec: &crate::metrics::RoundRecord) {
        self.recorder.record_round(rec);
        ops::publish_round(&self.label, rec.round, rec.loss);
    }

    /// Mirror a scheduler round plan digest into the flight recorder.
    pub fn record_plan(&mut self, round: usize, plan: &crate::sched::RoundPlan) {
        self.recorder.record_plan(round, plan);
    }

    /// Mirror per-worker state digests (e.g. FNV over resync mirrors).
    pub fn record_worker_digests(&mut self, round: usize, digests: Vec<u64>) {
        self.recorder.record_worker_digests(round, digests);
    }

    /// Dump the flight recorder as an `ef21.blackbox/v1` artifact, if a
    /// blackbox path is configured. Best-effort: failures are reported
    /// on stderr, never propagated (the dump runs on error paths where a
    /// second failure must not mask the first).
    pub fn dump_blackbox(&self, reason: &str, round: usize) -> Option<PathBuf> {
        let path = self.cfg.blackbox.as_ref()?;
        match self.recorder.dump(path, reason, round) {
            Ok(bytes) => {
                eprintln!(
                    "health: blackbox dumped to {} ({} bytes, reason: {reason})",
                    path.display(),
                    bytes
                );
                Some(path.clone())
            }
            Err(e) => {
                eprintln!("health: blackbox dump to {} failed: {e:#}", path.display());
                None
            }
        }
    }
}

/// Serializes tests that exercise the process-global ops publish path
/// against the ops server's own test (which opens the publish gate).
#[cfg(test)]
pub(crate) fn tests_ops_lock() -> std::sync::MutexGuard<'static, ()> {
    static LOCK: std::sync::OnceLock<std::sync::Mutex<()>> = std::sync::OnceLock::new();
    LOCK.get_or_init(|| std::sync::Mutex::new(()))
        .lock()
        .unwrap_or_else(|p| p.into_inner())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parses_grammar_and_defaults() {
        assert!(HealthSpec::default().is_off());
        assert!(HealthSpec::parse("off").unwrap().is_off());
        let s = HealthSpec::parse("every:5").unwrap();
        assert_eq!(s.every, 5);
        assert_eq!(s.window, 8);
        assert!(s.blackbox.is_none());
        let s = HealthSpec::parse("every:2,window:4,tol:0.01,blackbox:/tmp/bb.json").unwrap();
        assert_eq!((s.every, s.window), (2, 4));
        assert!((s.tol - 0.01).abs() < 1e-15);
        assert_eq!(s.blackbox.as_deref(), Some("/tmp/bb.json"));
        for bad in ["every:0", "window:4", "every:x", "every:2,window:1", "nope", "every:2,zz:1"] {
            assert!(HealthSpec::parse(bad).is_err(), "{bad} should fail");
        }
    }

    #[test]
    fn build_binds_theory_constants() {
        let s = HealthSpec::parse("every:1").unwrap();
        let cfg = s.build(0.75, 0.1).unwrap();
        // theta = 1 - sqrt(1 - 3/4) = 1/2.
        assert!((cfg.theta - 0.5).abs() < 1e-12);
        assert!((cfg.alpha - 0.75).abs() < 1e-12);
        assert!(HealthSpec::default().build(0.75, 0.1).is_none());
    }

    #[test]
    fn monitor_computes_certificates() {
        let _guard = tests_ops_lock();
        let cfg = HealthSpec::parse("every:2").unwrap().build(0.75, 0.1).unwrap();
        let mut h = Health::new(cfg, "t");
        assert!(h.due(0) && !h.due(1) && h.due(2));
        // Two workers: err 0.2/0.4 -> G = 0.3; loss 1.0; phi = 1 + (0.1/0.5)*0.3.
        let a = h.observe(0, 1.0, &[(0.2, 1.0), (0.4, 2.0)]);
        assert!(a.is_empty());
        let rec = h.recent.back().unwrap();
        assert!((rec.gt - 0.3).abs() < 1e-12);
        assert!((rec.phi - 1.06).abs() < 1e-12);
        assert!(rec.phi_delta.is_nan());
        assert!((rec.ratio_max - 0.2).abs() < 1e-12);
        // Second observation carries the delta.
        h.observe(2, 0.9, &[(0.1, 1.0), (0.1, 1.0)]);
        let rec = h.recent.back().unwrap();
        assert!(rec.phi_delta < 0.0);
        assert_eq!(h.records, 2);
        assert_eq!(h.anomaly_count, 0);
    }

    #[test]
    fn monitor_handles_missing_refs_and_nan_workers() {
        let _guard = tests_ops_lock();
        let cfg = HealthSpec::parse("every:1").unwrap().build(0.5, 0.2).unwrap();
        let mut h = Health::new(cfg, "t");
        // Transports: ref_sq unavailable (NaN) -> ratio_max NaN, G fine.
        h.observe(0, 1.0, &[(0.2, f64::NAN), (0.4, f64::NAN)]);
        let rec = h.recent.back().unwrap();
        assert!((rec.gt - 0.3).abs() < 1e-12);
        assert!(rec.ratio_max.is_nan());
        // All-NaN probe: G unmeasurable, not zero.
        h.observe(1, 1.0, &[(f64::NAN, f64::NAN)]);
        assert!(h.recent.back().unwrap().gt.is_nan());
    }

    #[test]
    fn health_record_json_degrades_nan_to_null() {
        let rec = HealthRecord {
            round: 3,
            loss: 1.5,
            gt: 0.25,
            phi: 2.0,
            phi_delta: f64::NAN,
            ratio_max: f64::NAN,
            worker_g: vec![0.25, f64::NAN],
        };
        let j = rec.to_json();
        assert_eq!(j.get("round").unwrap().as_f64(), Some(3.0));
        assert_eq!(j.get("phi_delta"), Some(&Json::Null));
        let wg = j.get("worker_g").unwrap().as_arr().unwrap();
        assert_eq!(wg[0].as_f64(), Some(0.25));
        assert_eq!(wg[1], Json::Null);
        // Round-trips through the writer.
        assert!(Json::parse(&j.to_string()).is_ok());
    }
}
