//! Live ops endpoint: `--ops <port>` serves `/health`, `/status`, and
//! `/workers` JSON over the same minimal HTTP/1.0 stack as
//! [`crate::telemetry::prom`]. Where Prometheus exposition answers
//! "what are the metrics", this answers the operator's three questions
//! about a long fleet run — is it converging (the Theorem 1
//! certificates), where is it (round progress), and which worker is
//! misbehaving — without attaching a scraper.
//!
//! Publishing is push-based and gated on one relaxed atomic: runners
//! call [`publish_round`]/[`publish_health`] unconditionally, and when
//! no server was ever started the calls return after a single atomic
//! load — nothing allocates, so the zero-alloc gate does not notice the
//! wiring.

use crate::util::json::Json;
use std::collections::BTreeMap;
use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Mutex, OnceLock};
use std::time::Duration;

use anyhow::{Context, Result};

const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Last-published run state; the process-global single source the
/// endpoint renders. One slot is enough: a process drives one run.
#[derive(Clone, Debug, Default)]
struct OpsState {
    label: String,
    round: usize,
    loss: f64,
    gt: f64,
    phi: f64,
    phi_delta: f64,
    ratio_max: f64,
    records: u64,
    anomalies: u64,
    /// Per-worker err_sq from the latest health observation.
    workers: Vec<f64>,
    seen_health: bool,
}

static ACTIVE: AtomicBool = AtomicBool::new(false);

fn state() -> &'static Mutex<OpsState> {
    static STATE: OnceLock<Mutex<OpsState>> = OnceLock::new();
    STATE.get_or_init(|| Mutex::new(OpsState::default()))
}

/// Cheap progress publish from every runner's record point.
pub fn publish_round(label: &str, round: usize, loss: f64) {
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    let mut s = state().lock().unwrap();
    if s.label != label {
        s.label.clear();
        s.label.push_str(label);
    }
    s.round = round;
    s.loss = loss;
}

/// Publish one health observation (called from [`super::Health::observe`]).
pub fn publish_health(rec: &super::HealthRecord, anomalies: u64, records: u64) {
    if !ACTIVE.load(Ordering::Relaxed) {
        return;
    }
    let mut s = state().lock().unwrap();
    s.round = rec.round;
    s.loss = rec.loss;
    s.gt = rec.gt;
    s.phi = rec.phi;
    s.phi_delta = rec.phi_delta;
    s.ratio_max = rec.ratio_max;
    s.records = records;
    s.anomalies = anomalies;
    s.workers.clear();
    s.workers.extend_from_slice(&rec.worker_g);
    s.seen_health = true;
}

fn num(v: f64) -> Json {
    if v.is_finite() {
        Json::Num(v)
    } else {
        Json::Null
    }
}

/// `/health`: the verdict plus the certificates behind it.
fn render_health(s: &OpsState) -> String {
    let mut m = BTreeMap::new();
    // "ok" until an anomaly is counted; "unknown" before any observation.
    let verdict = if !s.seen_health {
        "unknown"
    } else if s.anomalies == 0 {
        "ok"
    } else {
        "anomalous"
    };
    m.insert("health".into(), Json::Str(verdict.into()));
    m.insert("anomalies".into(), Json::Num(s.anomalies as f64));
    m.insert("records".into(), Json::Num(s.records as f64));
    m.insert("gt".into(), num(s.gt));
    m.insert("phi".into(), num(s.phi));
    m.insert("phi_delta".into(), num(s.phi_delta));
    m.insert("contraction_ratio_max".into(), num(s.ratio_max));
    Json::Obj(m).to_string()
}

/// `/status`: where the run is.
fn render_status(s: &OpsState) -> String {
    let mut m = BTreeMap::new();
    m.insert("label".into(), Json::Str(s.label.clone()));
    m.insert("round".into(), Json::Num(s.round as f64));
    m.insert("loss".into(), num(s.loss));
    m.insert("workers".into(), Json::Num(s.workers.len() as f64));
    Json::Obj(m).to_string()
}

/// `/workers`: per-worker G contributions from the last observation.
fn render_workers(s: &OpsState) -> String {
    let mut m = BTreeMap::new();
    m.insert(
        "err_sq".into(),
        Json::Arr(s.workers.iter().map(|&g| num(g)).collect()),
    );
    m.insert("round".into(), Json::Num(s.round as f64));
    Json::Obj(m).to_string()
}

/// Running ops server (same lifecycle contract as
/// [`crate::telemetry::prom::PromServer`]).
pub struct OpsServer {
    port: u16,
    shutdown: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<()>,
}

impl OpsServer {
    /// Bind `127.0.0.1:port` (0 picks an ephemeral port) and start
    /// answering. Flips the publish gate on.
    pub fn bind(port: u16) -> Result<OpsServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding ops port {port}"))?;
        let port = listener.local_addr().context("ops local_addr")?.port();
        listener.set_nonblocking(true).context("ops listener nonblocking")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = shutdown.clone();
        let handle = std::thread::Builder::new()
            .name("ef21-ops".into())
            .spawn(move || accept_loop(listener, stop))
            .context("spawning ops server")?;
        ACTIVE.store(true, Ordering::SeqCst);
        Ok(OpsServer { port, shutdown, handle })
    }

    pub fn port(&self) -> u16 {
        self.port
    }

    pub fn stop(self) {
        ACTIVE.store(false, Ordering::SeqCst);
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = self.handle.join();
    }
}

fn accept_loop(listener: TcpListener, shutdown: Arc<AtomicBool>) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                let _ = serve(stream);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn serve(mut stream: TcpStream) -> std::io::Result<()> {
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut req = [0u8; 1024];
    let n = stream.read(&mut req).unwrap_or(0);
    let path = parse_path(&req[..n]);

    let snap = state().lock().unwrap().clone();
    let (status, body) = match path.as_deref() {
        Some("/health") => ("200 OK", render_health(&snap)),
        Some("/status") | Some("/") => ("200 OK", render_status(&snap)),
        Some("/workers") => ("200 OK", render_workers(&snap)),
        _ => ("404 Not Found", "{\"error\": \"unknown path\"}".to_string()),
    };
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    write!(
        stream,
        "HTTP/1.0 {status}\r\nContent-Type: application/json\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

/// Extract the request path from `GET <path> HTTP/1.x`.
fn parse_path(req: &[u8]) -> Option<String> {
    let line = std::str::from_utf8(req).ok()?.lines().next()?;
    let mut parts = line.split_whitespace();
    let method = parts.next()?;
    if method != "GET" {
        return None;
    }
    // Strip any query string; the routes take no parameters.
    let path = parts.next()?.split('?').next()?;
    Some(path.to_string())
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::health::HealthRecord;

    fn get(port: u16, path: &str) -> String {
        let mut conn = TcpStream::connect(("127.0.0.1", port)).unwrap();
        conn.write_all(format!("GET {path} HTTP/1.0\r\n\r\n").as_bytes()).unwrap();
        let mut text = String::new();
        conn.read_to_string(&mut text).unwrap();
        text
    }

    fn body(resp: &str) -> Json {
        let idx = resp.find("\r\n\r\n").unwrap();
        Json::parse(&resp[idx + 4..]).expect("json body")
    }

    #[test]
    fn serves_health_status_workers_and_404() {
        // Publishing is process-global; serialize against the monitor
        // tests (whose observe() also publishes while the gate is open).
        let _guard = crate::health::tests_ops_lock();
        let server = OpsServer::bind(0).unwrap();
        let port = server.port();
        publish_round("ops-test", 7, 1.25);
        publish_health(
            &HealthRecord {
                round: 7,
                loss: 1.25,
                gt: 0.5,
                phi: 2.0,
                phi_delta: -0.25,
                ratio_max: f64::NAN,
                worker_g: vec![0.5, 0.5],
            },
            0,
            3,
        );

        let h = body(&get(port, "/health"));
        assert_eq!(h.get("health").and_then(|v| v.as_str()), Some("ok"));
        assert_eq!(h.get("phi").and_then(|v| v.as_f64()), Some(2.0));
        assert_eq!(h.get("contraction_ratio_max"), Some(&Json::Null));

        let s = body(&get(port, "/status"));
        assert_eq!(s.get("label").and_then(|v| v.as_str()), Some("ops-test"));
        assert_eq!(s.get("round").and_then(|v| v.as_f64()), Some(7.0));

        let w = body(&get(port, "/workers"));
        assert_eq!(w.get("err_sq").unwrap().as_arr().unwrap().len(), 2);

        let nf = get(port, "/nope");
        assert!(nf.starts_with("HTTP/1.0 404"), "got: {nf}");
        server.stop();
        // Gate closes with the server: publishes become no-ops again.
        assert!(!ACTIVE.load(Ordering::SeqCst));
    }
}
