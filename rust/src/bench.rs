//! `ef21 bench` — the machine-readable perf instrument behind
//! `BENCH_round.json`, the repo's performance trajectory (DESIGN.md §8).
//!
//! Mirrors the scenario families of `benches/bench_round.rs` (which
//! remains the human-readable console instrument) but emits structured
//! JSON so CI can archive every run and diff key metrics against the
//! committed baseline:
//!
//!   * `round.seq.*` / `round.par.*` — full EF21 round-loop throughput on
//!     synthetic diagonal quadratics at d ∈ {10^4, 10^6} (top-k at 1%
//!     density), sequential and pooled;
//!   * `round.seq.d1e6.*.allocpath` — the same loop with a wrapper
//!     compressor that routes through the legacy owned-`Compressed`
//!     path, quantifying what the zero-allocation engine buys;
//!   * `compress.*` — the compressor zoo (top-k / rand-k / sign /
//!     identity) and the 32-block layer-wise layout at DL scale;
//!   * `pp.*` — the participation sweep (p ∈ {1.0, 0.5, 0.1}) on the a9a
//!     logistic problem, wall + uplink bits;
//!   * `fleet.*` — the fleet-scale sweep (DESIGN.md §11): the sharded
//!     tree-aggregation master driven by n simulated clients
//!     (n ∈ {10^2, 10^4} quick, plus 10^6 full), recording rounds/sec,
//!     the per-round latency tail, master RSS, and the sparse resync
//!     mirrors' byte footprint. `--fleet-n 100,10000` runs *only* the
//!     fleet cases at the listed client counts — CI's RSS-sublinearity
//!     leg launches one process per n so the RSS samples are
//!     independent.
//!
//! Schema (`ef21.bench.round/v3`): a top-level object with `schema`,
//! `isa` (dispatched SIMD path), `threads_auto`, `alloc_counting`,
//! `quick`, and `cases` — one object per case with `name`, `rounds`,
//! `wall_ns`, `rounds_per_sec`, `uplink_bits`, `downlink_bits`, `d`,
//! `workers`, `allocs_per_round` (`null` unless built with
//! `--features count-allocs`; `allocs_per_round` is a steady-state
//! measurement — the delta between a long and a short run divided by the
//! extra rounds, so setup/teardown allocations cancel), and `round_ns`
//! (`null` for `compress.*` cases): the per-round latency distribution
//! of the timed run — `count`, `p50`, `p90`, `p99`, `max`, `mean` in
//! nanoseconds, read from a private telemetry registry layered onto the
//! facade for the timed run only. Warmup and alloc-counting runs stay
//! telemetry-disabled, so the zero-allocation path is measured exactly
//! as it ships; v2 is what lets CI gate on tail (p99) regressions, not
//! just mean throughput. v3 adds the `fleet.*` cases, which carry two
//! extra keys (absent elsewhere, so v2 baseline diffs stay valid):
//! `rss_kb` — master `VmRSS` after the run (`null` when
//! `/proc/self/status` is unavailable: non-Linux, or a container that
//! masks `/proc`) — and `mirror_bytes` — bytes held by the sparse
//! per-worker state mirrors.

use crate::algo::AlgoSpec;
use crate::compress::{self, Compressed, Compressor};
use crate::config::cli::Args;
use crate::coordinator::{auto_threads, run_protocol_par, RunConfig};
use crate::exp::{Objective, Problem};
use crate::metrics::History;
use crate::oracle::{GradOracle, QuadraticOracle};
use crate::telemetry;
use crate::util::alloc::measured_allocation_count;
use crate::util::json::Json;
use crate::util::rng::Rng;
use crate::util::simd;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::sync::Arc;
use std::time::Instant;

/// Per-round latency distribution of a timed run (nanoseconds), read
/// from the `coordinator.round.ns` histogram of a case-private registry.
struct RoundSummary {
    count: u64,
    p50: u64,
    p90: u64,
    p99: u64,
    max: u64,
    mean: f64,
}

impl RoundSummary {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("count".into(), Json::Num(self.count as f64));
        m.insert("p50".into(), Json::Num(self.p50 as f64));
        m.insert("p90".into(), Json::Num(self.p90 as f64));
        m.insert("p99".into(), Json::Num(self.p99 as f64));
        m.insert("max".into(), Json::Num(self.max as f64));
        m.insert("mean".into(), Json::Num(self.mean));
        Json::Obj(m)
    }
}

/// One emitted bench case.
struct Case {
    name: String,
    rounds: u64,
    wall_ns: u64,
    uplink_bits: u64,
    downlink_bits: u64,
    d: usize,
    workers: usize,
    allocs_per_round: Option<f64>,
    round_ns: Option<RoundSummary>,
    /// Fleet-only columns — `Some` exactly for `fleet.*` cases.
    fleet: Option<FleetStats>,
}

/// The `fleet.*` extra columns: master RSS after the run (`None` ⇒ JSON
/// `null` — `/proc/self/status` unavailable, e.g. non-Linux or a masked
/// `/proc`) and the sparse resync mirrors' byte footprint.
struct FleetStats {
    rss_kb: Option<u64>,
    mirror_bytes: u64,
}

impl Case {
    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("rounds".into(), Json::Num(self.rounds as f64));
        m.insert("wall_ns".into(), Json::Num(self.wall_ns as f64));
        let rps = if self.wall_ns == 0 {
            0.0
        } else {
            self.rounds as f64 / (self.wall_ns as f64 / 1e9)
        };
        m.insert("rounds_per_sec".into(), Json::Num(rps));
        m.insert("uplink_bits".into(), Json::Num(self.uplink_bits as f64));
        m.insert("downlink_bits".into(), Json::Num(self.downlink_bits as f64));
        m.insert("d".into(), Json::Num(self.d as f64));
        m.insert("workers".into(), Json::Num(self.workers as f64));
        m.insert(
            "allocs_per_round".into(),
            match self.allocs_per_round {
                Some(a) => Json::Num(a),
                None => Json::Null,
            },
        );
        m.insert(
            "round_ns".into(),
            match &self.round_ns {
                Some(r) => r.to_json(),
                None => Json::Null,
            },
        );
        // Fleet-only keys: always present on fleet cases (rss_kb is
        // null when the probe has nothing to read — a masked /proc must
        // not silently shrink the schema), absent elsewhere so
        // non-fleet cases keep their exact v2 shape.
        if let Some(fs) = &self.fleet {
            m.insert(
                "rss_kb".into(),
                match fs.rss_kb {
                    Some(rss) => Json::Num(rss as f64),
                    None => Json::Null,
                },
            );
            m.insert("mirror_bytes".into(), Json::Num(fs.mirror_bytes as f64));
        }
        Json::Obj(m)
    }
}

/// Run `f` (the timed run, and only the timed run) with telemetry
/// enabled and a fresh private registry layered onto the facade, then
/// summarize the `coordinator.round.ns` histogram it recorded. The
/// warmup and alloc-counting runs never pass through here: they run
/// telemetry-disabled, so `allocs_per_round` keeps measuring the
/// zero-allocation path exactly as it ships.
fn with_round_stats<T>(f: impl FnOnce() -> T) -> (T, Option<RoundSummary>) {
    let reg = Arc::new(telemetry::Registry::new());
    telemetry::push_layer(Arc::new(telemetry::RegistryRecorder::new(reg.clone())));
    let was_enabled = telemetry::is_enabled();
    telemetry::enable();
    let out = f();
    if !was_enabled {
        telemetry::disable();
    }
    telemetry::pop_layer();
    let summary = reg
        .snapshot()
        .histograms
        .into_iter()
        .find(|(k, _)| k == telemetry::keys::ROUND_NS)
        .map(|(_, h)| RoundSummary {
            count: h.count,
            p50: h.quantile(0.50),
            p90: h.quantile(0.90),
            p99: h.quantile(0.99),
            max: h.max,
            mean: h.mean(),
        })
        .filter(|s| s.count > 0);
    (out, summary)
}

/// Wrapper forcing the legacy allocating compression path: only
/// `compress` is implemented, so `compress_into` falls back to the trait
/// default (`*out = compress(..)`) and every round pays fresh
/// index/value allocations — the pre-zero-allocation behavior, kept as
/// the bench comparator.
struct AllocPath<C: Compressor>(C);

impl<C: Compressor> Compressor for AllocPath<C> {
    fn name(&self) -> String {
        format!("{}+allocpath", self.0.name())
    }

    fn alpha(&self, d: usize) -> f64 {
        self.0.alpha(d)
    }

    fn compress(&self, v: &[f64], rng: &mut Rng) -> Compressed {
        self.0.compress(v, rng)
    }

    fn is_deterministic(&self) -> bool {
        self.0.is_deterministic()
    }
}

/// n synthetic strongly-convex diagonal quadratics of dimension d with
/// heterogeneous minimizers (O(d) per gradient, so the round loop — not
/// the oracle — dominates at large d).
fn quad_oracles(n: usize, d: usize, seed: u64) -> Vec<Box<dyn GradOracle>> {
    let mut rng = Rng::seed(seed);
    (0..n)
        .map(|_| {
            let h: Vec<f64> = (0..d).map(|_| 0.5 + rng.next_f64()).collect();
            let c: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
            Box::new(QuadraticOracle::diagonal(h, c)) as Box<dyn GradOracle>
        })
        .collect()
}

/// One full EF21 protocol run (fresh nodes) on the quadratic problem;
/// returns wall seconds and the history (bits).
fn ef21_quad_run(
    n: usize,
    d: usize,
    c: Arc<dyn Compressor>,
    rounds: usize,
    threads: usize,
) -> (f64, History) {
    let (m, w) = crate::algo::build(AlgoSpec::Ef21, vec![0.0; d], quad_oracles(n, d, 7), c, 0.1, 0);
    let cfg = RunConfig::rounds(rounds).with_record_every(rounds.max(1));
    let t0 = Instant::now();
    let h = run_protocol_par(m, w, &cfg, threads);
    (t0.elapsed().as_secs_f64(), h)
}

/// Steady-state allocations per round: re-run the scenario at two round
/// counts and divide the allocation-count delta by the extra rounds
/// (setup, warmup, and final-record allocations cancel). `None` without
/// the `count-allocs` feature.
fn allocs_per_round(mut run: impl FnMut(usize), short: usize, long: usize) -> Option<f64> {
    measured_allocation_count()?;
    run(short); // warm thread-locals so the two measured runs match
    let a0 = measured_allocation_count()?;
    run(short);
    let a1 = measured_allocation_count()?;
    run(long);
    let a2 = measured_allocation_count()?;
    let short_allocs = a1 - a0;
    let long_allocs = a2 - a1;
    Some(long_allocs.saturating_sub(short_allocs) as f64 / (long - short) as f64)
}

/// Round-loop case on the quadratic problem.
#[allow(clippy::too_many_arguments)]
fn round_case(
    name: &str,
    n: usize,
    d: usize,
    make_c: impl Fn() -> Arc<dyn Compressor>,
    rounds: usize,
    threads: usize,
) -> Case {
    // Warmup run (allocator, page cache), then the timed run — the only
    // run that records per-round latency (see `with_round_stats`).
    let _ = ef21_quad_run(n, d, make_c(), rounds.min(4), threads);
    let ((secs, h), round_ns) = with_round_stats(|| ef21_quad_run(n, d, make_c(), rounds, threads));
    let uplink = (h.records.last().map(|r| r.bits_per_client).unwrap_or(0.0) * n as f64) as u64;
    // Fixed short/long pair (independent of the timing round count):
    // only the delta per extra round matters.
    let apr = allocs_per_round(
        |r| {
            let _ = ef21_quad_run(n, d, make_c(), r, threads);
        },
        3,
        9,
    );
    Case {
        name: name.to_string(),
        rounds: rounds as u64,
        wall_ns: (secs * 1e9) as u64,
        uplink_bits: uplink,
        downlink_bits: h.downlink_bits,
        d,
        workers: n,
        allocs_per_round: apr,
        round_ns,
        fleet: None,
    }
}

/// Latency of repeated single compressions (zoo / blocked cases): runs
/// `compress_into` on a fixed input until ~0.2 s elapse and reports the
/// per-call mean as `wall_ns` with `rounds` = calls.
fn compress_case(name: &str, c: &dyn Compressor, d: usize) -> Case {
    let mut rng = Rng::seed(3);
    let v: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
    let mut out = Compressed::empty();
    c.compress_into(&v, &mut rng, &mut out); // warmup
    let mut calls = 0u64;
    let mut bits = 0u64;
    let t0 = Instant::now();
    while t0.elapsed().as_secs_f64() < 0.2 {
        c.compress_into(&v, &mut rng, &mut out);
        bits = out.bits;
        calls += 1;
    }
    let wall = t0.elapsed().as_nanos() as u64;
    Case {
        name: name.to_string(),
        rounds: calls,
        wall_ns: wall,
        uplink_bits: bits,
        downlink_bits: 0,
        d,
        workers: 1,
        allocs_per_round: None,
        round_ns: None, // per-call latency, not a round loop
        fleet: None,
    }
}

/// EF21-PP participation sweep case on a9a logreg. Problem, oracles,
/// and nodes are built before the clock starts, so `wall_ns` measures
/// the round loop with the same semantics as the `round.*` cases.
fn pp_case(name: &str, participation: Option<f64>, rounds: usize) -> Case {
    let mut problem = Problem::new("a9a", Objective::LogReg, 20, 0.1, 0);
    if let Some(frac) = participation {
        problem.sched = crate::config::SchedSpec {
            participation: crate::sched::Participation::Bernoulli(frac),
            ..crate::config::SchedSpec::default()
        };
    }
    let d = problem.d();
    // Mirror Problem::run_trial's construction (theory stepsize, seed 0)
    // outside the timed region.
    let c: Arc<dyn Compressor> = Arc::from(compress::from_spec("top8").expect("spec"));
    let gamma = problem.theory_gamma(c.alpha(d));
    let (m, w) = crate::algo::build(AlgoSpec::Ef21, vec![0.0; d], problem.oracles(), c, gamma, 0);
    let mut cfg = RunConfig::rounds(rounds).with_record_every(rounds);
    if let Some(sched) = problem.sched.build(20, 0).expect("schedule") {
        cfg = cfg.with_sched(sched);
    }
    cfg.divergence_cap = 1e60;
    let ((wall, h), round_ns) = with_round_stats(|| {
        let t0 = Instant::now();
        let h = run_protocol_par(m, w, &cfg, 1);
        (t0.elapsed().as_nanos() as u64, h)
    });
    let uplink = (h.records.last().map(|r| r.bits_per_client).unwrap_or(0.0) * 20.0) as u64;
    Case {
        name: name.to_string(),
        rounds: rounds as u64,
        wall_ns: wall,
        uplink_bits: uplink,
        downlink_bits: h.downlink_bits,
        d,
        workers: 20,
        allocs_per_round: None,
        round_ns,
        fleet: None,
    }
}

/// Summarize an explicit per-round sample vector (the fleet harness
/// times rounds itself rather than going through the telemetry
/// histogram, so its percentiles are exact, not bucketed).
fn summarize_samples(mut ns: Vec<u64>) -> Option<RoundSummary> {
    if ns.is_empty() {
        return None;
    }
    ns.sort_unstable();
    let q = |frac: f64| ns[((ns.len() - 1) as f64 * frac).round() as usize];
    let sum: u64 = ns.iter().sum();
    Some(RoundSummary {
        count: ns.len() as u64,
        p50: q(0.50),
        p90: q(0.90),
        p99: q(0.99),
        max: *ns.last().expect("nonempty"),
        mean: sum as f64 / ns.len() as f64,
    })
}

/// Fleet-scale sweep point: the sharded tree-aggregation master driven
/// by `n` simulated clients (`coordinator::fleet`). Whole-run wall time
/// includes shard spawn/join — the fleet claim is about steady-state
/// aggregation, and at 10 rounds the spawn cost is visible in `wall_ns`
/// vs `round_ns.mean`, which is fine: both are recorded.
fn fleet_case(n_clients: usize, quick: bool) -> Result<Case> {
    let mut spec = crate::coordinator::fleet::FleetSpec::quick(n_clients);
    if !quick {
        // More rounds for stable tails, fewer at 1e6 to bound wall time.
        spec.rounds = if n_clients >= 1_000_000 { 6 } else { 30 };
    }
    let out = crate::coordinator::fleet::run_fleet(&spec)?;
    // Every merged entry is one client coordinate: u32 index + f64
    // value, the standard sparse uplink accounting.
    let uplink_bits = out.entries_folded * 96;
    Ok(Case {
        name: format!("fleet.n{n_clients}"),
        rounds: out.rounds as u64,
        wall_ns: out.wall_ns,
        uplink_bits,
        downlink_bits: 0, // simulated clients: no model broadcast
        d: spec.d,
        workers: n_clients,
        allocs_per_round: None,
        round_ns: summarize_samples(out.round_ns),
        fleet: Some(FleetStats { rss_kb: out.rss_kb, mirror_bytes: out.mirror_bytes }),
    })
}

/// Entry point for `ef21 bench [--json PATH] [--quick] [--fleet-n N,N,..]`.
pub fn main(args: &Args) -> Result<()> {
    let quick = args.has("quick");
    let json_path = args.get_str("json").unwrap_or("BENCH_round.json").to_string();
    // `--fleet-n 100,10000`: run only the fleet sweep, at these client
    // counts. Without it, the full suite runs and the fleet sweep uses
    // its default ladder.
    let fleet_only: Option<Vec<usize>> = match args.get_str("fleet-n") {
        None => None,
        Some(list) => Some(
            list.split(',')
                .map(|t| {
                    t.trim()
                        .parse::<usize>()
                        .map_err(|e| anyhow::anyhow!("--fleet-n {t:?}: {e}"))
                })
                .collect::<Result<Vec<usize>>>()?,
        ),
    };
    let auto = auto_threads();
    let mut cases: Vec<Case> = Vec::new();

    if let Some(ns) = &fleet_only {
        eprintln!("bench: fleet sweep only (n = {ns:?})...");
        for &n in ns {
            cases.push(fleet_case(n, quick)?);
        }
        return write_report(&json_path, quick, auto, &cases);
    }

    // Round loops on synthetic quadratics: top-k at 1% density.
    let (r4, r6) = if quick { (60, 6) } else { (300, 24) };
    let topk = |k: usize| move || Arc::new(compress::TopK::new(k)) as Arc<dyn Compressor>;
    eprintln!("bench: round loops (seq/par, d=1e4 and 1e6)...");
    cases.push(round_case("round.seq.d1e4.top1pct", 8, 10_000, topk(100), r4, 1));
    cases.push(round_case("round.seq.d1e6.top1pct", 8, 1_000_000, topk(10_000), r6, 1));
    // Static case name: the machine's thread count lives in the
    // top-level `threads_auto` field, so baseline diffs match the case
    // across machines with different core counts.
    cases.push(round_case("round.par.d1e6.top1pct.auto", 8, 1_000_000, topk(10_000), r6, auto));
    cases.push(round_case(
        "round.seq.d1e6.top1pct.allocpath",
        8,
        1_000_000,
        || Arc::new(AllocPath(compress::TopK::new(10_000))) as Arc<dyn Compressor>,
        r6,
        1,
    ));
    cases.push(round_case(
        "round.seq.d1e4.sign",
        8,
        10_000,
        || Arc::new(compress::ScaledSign) as Arc<dyn Compressor>,
        r4,
        1,
    ));

    // Compressor zoo at DL scale (2^18 coordinates, ~5% density).
    eprintln!("bench: compressor zoo...");
    let dz = 1 << 18;
    let kz = dz / 20;
    cases.push(compress_case("compress.topk.d262144", &compress::TopK::new(kz), dz));
    cases.push(compress_case("compress.randk.d262144", &compress::RandK::new(kz), dz));
    cases.push(compress_case("compress.sign.d262144", &compress::ScaledSign, dz));
    cases.push(compress_case("compress.identity.d262144", &compress::Identity, dz));
    let layout32 = Arc::new(crate::blocks::BlockLayout::equal(32, dz).expect("layout"));
    for threads in [1usize, 4] {
        let c = compress::BlockCompressor::from_spec(&format!("top{kz}"), layout32.clone(), threads)
            .expect("blocked spec");
        cases.push(compress_case(
            &format!("compress.topk.b32.fan{threads}.d262144"),
            &c,
            dz,
        ));
    }

    // Participation sweep (a9a logreg, 20 workers).
    eprintln!("bench: participation sweep...");
    let rpp = if quick { 30 } else { 120 };
    cases.push(pp_case("pp.full", None, rpp));
    for p in [1.0, 0.5, 0.1] {
        cases.push(pp_case(&format!("pp.p{p}"), Some(p), rpp));
    }

    // Fleet-scale sweep: 10^2 and 10^4 simulated clients always, 10^6
    // in full runs only.
    let fleet_ns: &[usize] =
        if quick { &[100, 10_000] } else { &[100, 10_000, 1_000_000] };
    eprintln!("bench: fleet sweep (n = {fleet_ns:?})...");
    for &n in fleet_ns {
        cases.push(fleet_case(n, quick)?);
    }

    write_report(&json_path, quick, auto, &cases)
}

/// Assemble the JSON report, write it, and print the console summary.
fn write_report(json_path: &str, quick: bool, auto: usize, cases: &[Case]) -> Result<()> {
    let mut top = BTreeMap::new();
    top.insert("schema".into(), Json::Str("ef21.bench.round/v3".into()));
    top.insert("isa".into(), Json::Str(simd::isa().name().into()));
    top.insert("threads_auto".into(), Json::Num(auto as f64));
    top.insert(
        "alloc_counting".into(),
        Json::Bool(measured_allocation_count().is_some()),
    );
    top.insert("quick".into(), Json::Bool(quick));
    top.insert(
        "cases".into(),
        Json::Arr(cases.iter().map(Case::to_json).collect()),
    );
    let body = Json::Obj(top).to_string();
    std::fs::write(json_path, body.as_bytes())
        .with_context(|| format!("writing {json_path}"))?;

    // Console summary (the JSON is the artifact; this is for humans).
    println!(
        "{:<38} {:>10} {:>14} {:>14} {:>12} {:>9}",
        "case", "rounds", "wall", "rounds/s", "p99", "allocs/r"
    );
    for c in cases {
        let rps = if c.wall_ns == 0 { 0.0 } else { c.rounds as f64 / (c.wall_ns as f64 / 1e9) };
        let apr = match c.allocs_per_round {
            Some(a) => format!("{a:.1}"),
            None => "-".to_string(),
        };
        let p99 = match &c.round_ns {
            Some(r) => format!("{:.2} ms", r.p99 as f64 / 1e6),
            None => "-".to_string(),
        };
        println!(
            "{:<38} {:>10} {:>11.2} ms {:>14.1} {:>12} {:>10}",
            c.name,
            c.rounds,
            c.wall_ns as f64 / 1e6,
            rps,
            p99,
            apr
        );
    }
    println!("wrote {json_path} (isa={}, threads_auto={auto})", simd::isa().name());
    Ok(())
}
