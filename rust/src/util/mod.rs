//! Offline substrates: PRNG, JSON, dense linear algebra, property-testing.
//!
//! These exist because the build environment has no network: serde, rand,
//! and proptest are unavailable, so the library carries minimal, fully
//! tested replacements.

pub mod alloc;
pub mod json;
pub mod linalg;
pub mod mem;
pub mod rng;
pub mod simd;
pub mod testing;
