//! Minimal JSON parser/writer — just enough for `artifacts/manifest.json`
//! and the metrics JSONL sinks. Hand-rolled because serde is not vendored
//! in this offline environment.

use std::collections::BTreeMap;
use std::fmt::Write as _;

/// A JSON value. Numbers are kept as f64 (manifest only holds small ints).
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(s: &str) -> Result<Json, String> {
        let mut p = Parser { b: s.as_bytes(), i: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.i != p.b.len() {
            return Err(format!("trailing data at byte {}", p.i));
        }
        Ok(v)
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|n| n as usize)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Some(m),
            _ => None,
        }
    }

    /// Compact serialization (stable key order via BTreeMap).
    pub fn to_string(&self) -> String {
        let mut out = String::new();
        self.write(&mut out);
        out
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => {
                if n.fract() == 0.0 && n.abs() < 1e15 {
                    let _ = write!(out, "{}", *n as i64);
                } else {
                    let _ = write!(out, "{}", n);
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(out, k);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<(), String> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(format!("expected '{}' at byte {}", c as char, self.i))
        }
    }

    fn value(&mut self) -> Result<Json, String> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(format!("unexpected {:?} at byte {}", other.map(|c| c as char), self.i)),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json, String> {
        if self.b[self.i..].starts_with(word.as_bytes()) {
            self.i += word.len();
            Ok(v)
        } else {
            Err(format!("bad literal at byte {}", self.i))
        }
    }

    fn object(&mut self) -> Result<Json, String> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let k = self.string()?;
            self.skip_ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(format!("expected ',' or '}}' at byte {}", self.i)),
            }
        }
    }

    fn array(&mut self) -> Result<Json, String> {
        self.expect(b'[')?;
        let mut a = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(a));
        }
        loop {
            a.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(a));
                }
                _ => return Err(format!("expected ',' or ']' at byte {}", self.i)),
            }
        }
    }

    fn string(&mut self) -> Result<String, String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err("unterminated string".into()),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            if self.i + 4 >= self.b.len() {
                                return Err("bad \\u escape".into());
                            }
                            let hex = std::str::from_utf8(&self.b[self.i + 1..self.i + 5])
                                .map_err(|_| "bad \\u escape")?;
                            let code =
                                u32::from_str_radix(hex, 16).map_err(|_| "bad \\u escape")?;
                            s.push(char::from_u32(code).unwrap_or('\u{fffd}'));
                            self.i += 4;
                        }
                        _ => return Err(format!("bad escape at byte {}", self.i)),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Consume one UTF-8 code point.
                    let rest = std::str::from_utf8(&self.b[self.i..])
                        .map_err(|_| "invalid utf-8".to_string())?;
                    let c = rest.chars().next().unwrap();
                    s.push(c);
                    self.i += c.len_utf8();
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json, String> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        std::str::from_utf8(&self.b[start..self.i])
            .ok()
            .and_then(|s| s.parse::<f64>().ok())
            .map(Json::Num)
            .ok_or_else(|| format!("bad number at byte {start}"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse(" -3.5e2 ").unwrap(), Json::Num(-350.0));
        assert_eq!(Json::parse("\"hi\\n\"").unwrap(), Json::Str("hi\n".into()));
    }

    #[test]
    fn parses_nested() {
        let j = Json::parse(r#"{"a": [1, 2, {"b": "c"}], "d": null}"#).unwrap();
        assert_eq!(j.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            j.get("a").unwrap().as_arr().unwrap()[2].get("b").unwrap().as_str(),
            Some("c")
        );
        assert_eq!(j.get("d"), Some(&Json::Null));
    }

    #[test]
    fn roundtrip() {
        let src = r#"{"arr":[1,2.5,"x"],"num":3,"obj":{"k":true},"s":"a\"b"}"#;
        let j = Json::parse(src).unwrap();
        let out = j.to_string();
        assert_eq!(Json::parse(&out).unwrap(), j);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
    }

    #[test]
    fn unicode_escape() {
        assert_eq!(Json::parse("\"\\u0041\"").unwrap(), Json::Str("A".into()));
    }

    #[test]
    fn parses_real_manifest_shape() {
        let src = r#"{
          "logreg_grad_a9a": {
            "file": "logreg_grad_a9a.hlo.txt",
            "inputs": [{"name": "a", "shape": [1792, 123], "dtype": "f32"}],
            "meta": {"d": 123, "n_rows_padded": 1792}
          }
        }"#;
        let j = Json::parse(src).unwrap();
        let entry = j.get("logreg_grad_a9a").unwrap();
        assert_eq!(entry.get("file").unwrap().as_str().unwrap(), "logreg_grad_a9a.hlo.txt");
        assert_eq!(entry.get("meta").unwrap().get("d").unwrap().as_usize(), Some(123));
    }
}
