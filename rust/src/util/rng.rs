//! Deterministic, seedable PRNG (xoshiro256**) with the distributions the
//! library needs. Hand-rolled because the environment is offline; also gives
//! us bit-exact reproducibility across runs, which the experiment harness
//! relies on (every figure is regenerated from a fixed seed).

/// xoshiro256** by Blackman & Vigna — fast, high-quality, 256-bit state.
#[derive(Clone, Debug)]
pub struct Rng {
    s: [u64; 4],
}

/// splitmix64, used to expand a 64-bit seed into the xoshiro state.
fn splitmix64(state: &mut u64) -> u64 {
    *state = state.wrapping_add(0x9E37_79B9_7F4A_7C15);
    let mut z = *state;
    z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
    z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
    z ^ (z >> 31)
}

impl Rng {
    /// Seeded construction; distinct seeds give independent-looking streams.
    pub fn seed(seed: u64) -> Self {
        let mut sm = seed;
        let s = [
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
            splitmix64(&mut sm),
        ];
        Rng { s }
    }

    /// Derive an independent child stream (worker-local RNGs fork from the
    /// experiment seed so results are invariant to worker scheduling).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::seed(self.next_u64() ^ tag.wrapping_mul(0x9E37_79B9_7F4A_7C15))
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        let s = &mut self.s;
        let result = s[1].wrapping_mul(5).rotate_left(7).wrapping_mul(9);
        let t = s[1] << 17;
        s[2] ^= s[0];
        s[3] ^= s[1];
        s[1] ^= s[2];
        s[0] ^= s[3];
        s[2] ^= t;
        s[3] = s[3].rotate_left(45);
        result
    }

    /// Uniform in [0, 1).
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        // 53 high bits -> [0,1) with full double mantissa resolution.
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    #[inline]
    pub fn next_below(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        // Lemire's multiply-shift rejection-free approximation is fine here
        // (statistical use only); keep exact via widening multiply.
        ((self.next_u64() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (cached second value).
    pub fn next_normal(&mut self) -> f64 {
        // No cache: simplicity over speed; data generation is not hot.
        let u1 = loop {
            let u = self.next_f64();
            if u > 0.0 {
                break u;
            }
        };
        let u2 = self.next_f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Bernoulli(p) in {-1.0, +1.0} (labels).
    pub fn next_sign(&mut self, p_plus: f64) -> f64 {
        if self.next_f64() < p_plus {
            1.0
        } else {
            -1.0
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, v: &mut [T]) {
        for i in (1..v.len()).rev() {
            let j = self.next_below(i + 1);
            v.swap(i, j);
        }
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm), written
    /// sorted into the caller's buffer (cleared first). The membership set
    /// is a thread-local scratch, so steady-state calls allocate nothing;
    /// the draw sequence is exactly [`Rng::sample_indices`]'s — the two
    /// consume identical RNG streams and return identical index sets.
    pub fn sample_indices_into(&mut self, n: usize, k: usize, out: &mut Vec<u32>) {
        assert!(k <= n);
        out.clear();
        SAMPLE_SCRATCH.with(|cell| {
            let mut chosen = cell.borrow_mut();
            chosen.clear();
            for j in (n - k)..n {
                let t = self.next_below(j + 1);
                let pick = if chosen.contains(&(t as u32)) { j as u32 } else { t as u32 };
                chosen.insert(pick);
                out.push(pick);
            }
        });
        out.sort_unstable();
    }

    /// Sample k distinct indices from [0, n) (Floyd's algorithm).
    pub fn sample_indices(&mut self, n: usize, k: usize) -> Vec<u32> {
        let mut out = Vec::with_capacity(k);
        self.sample_indices_into(n, k, &mut out);
        out
    }

    /// The raw 256-bit stream position, for checkpointing. Restoring via
    /// [`Rng::from_state`] continues the stream exactly where it left off.
    pub fn state(&self) -> [u64; 4] {
        self.s
    }

    /// Rebuild an RNG at a previously captured stream position.
    pub fn from_state(s: [u64; 4]) -> Rng {
        Rng { s }
    }
}

thread_local! {
    /// Reused membership set for [`Rng::sample_indices_into`] (cleared on
    /// every use; clearing keeps the table allocation).
    static SAMPLE_SCRATCH: std::cell::RefCell<std::collections::HashSet<u32>> =
        std::cell::RefCell::new(std::collections::HashSet::new());
}

/// The RNG stream of worker `i` under the builders' fork scheme
/// (`crate::algo::build*`): a base RNG seeded with the experiment seed,
/// forked once per worker in index order. Reconstructing a single
/// worker's stream out-of-band (transport factories, differential
/// tests) MUST go through this helper so it can never desynchronize
/// from the builders.
pub fn worker_rng(seed: u64, worker: usize) -> Rng {
    let mut base = Rng::seed(seed);
    let mut rng = base.fork(0);
    for j in 1..=worker {
        rng = base.fork(j as u64);
    }
    rng
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn worker_rng_matches_builder_fork_sequence() {
        // The builders do: base = seed(s); worker i gets the i-th fork.
        let mut base = Rng::seed(99);
        let expected: Vec<Rng> = (0..5).map(|i| base.fork(i as u64)).collect();
        for (i, mut want) in expected.into_iter().enumerate() {
            let mut got = worker_rng(99, i);
            for _ in 0..20 {
                assert_eq!(got.next_u64(), want.next_u64(), "worker {i} stream");
            }
        }
    }

    #[test]
    fn deterministic_for_same_seed() {
        let mut a = Rng::seed(42);
        let mut b = Rng::seed(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Rng::seed(1);
        let mut b = Rng::seed(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }

    #[test]
    fn uniform_range_and_mean() {
        let mut r = Rng::seed(7);
        let n = 20_000;
        let mut sum = 0.0;
        for _ in 0..n {
            let x = r.next_f64();
            assert!((0.0..1.0).contains(&x));
            sum += x;
        }
        assert!((sum / n as f64 - 0.5).abs() < 0.01);
    }

    #[test]
    fn next_below_bounds_and_coverage() {
        let mut r = Rng::seed(9);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.next_below(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&b| b));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::seed(11);
        let n = 50_000;
        let (mut s, mut s2) = (0.0, 0.0);
        for _ in 0..n {
            let x = r.next_normal();
            s += x;
            s2 += x * x;
        }
        let mean = s / n as f64;
        let var = s2 / n as f64 - mean * mean;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn sample_indices_distinct_sorted() {
        let mut r = Rng::seed(13);
        for _ in 0..50 {
            let k = 1 + r.next_below(20);
            let n = k + r.next_below(100);
            let idx = r.sample_indices(n, k);
            assert_eq!(idx.len(), k);
            for w in idx.windows(2) {
                assert!(w[0] < w[1]);
            }
            assert!(idx.iter().all(|&i| (i as usize) < n));
        }
    }

    #[test]
    fn sample_indices_into_matches_owned_and_reuses_buffer() {
        // Same seed => identical draw sequence through both entry points.
        let mut a = Rng::seed(21);
        let mut b = Rng::seed(21);
        let mut out = Vec::new();
        for _ in 0..30 {
            let k = 1 + a.next_below(15);
            let n = k + a.next_below(60);
            // Keep b's stream aligned with a's.
            let k2 = 1 + b.next_below(15);
            let n2 = k2 + b.next_below(60);
            assert_eq!((k, n), (k2, n2));
            a.sample_indices_into(n, k, &mut out);
            assert_eq!(out, b.sample_indices(n, k));
        }
        // Buffer reuse: capacity settles, no reallocation on same-k draws.
        a.sample_indices_into(50, 10, &mut out);
        let ptr = out.as_ptr();
        a.sample_indices_into(50, 10, &mut out);
        assert_eq!(out.as_ptr(), ptr, "index buffer was reallocated");
    }

    #[test]
    fn state_roundtrip_resumes_stream_exactly() {
        let mut a = Rng::seed(31);
        for _ in 0..17 {
            a.next_u64();
        }
        let snap = a.state();
        let mut b = Rng::from_state(snap);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
        // The snapshot itself is unchanged by either stream's progress.
        assert_eq!(Rng::from_state(snap).state(), snap);
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::seed(17);
        let mut v: Vec<u32> = (0..100).collect();
        r.shuffle(&mut v);
        let mut sorted = v.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut base = Rng::seed(3);
        let mut a = base.fork(0);
        let mut b = base.fork(1);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 4);
    }
}
