//! Process memory introspection for the fleet bench: resident set size
//! read from `/proc/self/status` (no external crates). Off Linux the
//! probes return `None` and the bench simply omits the fields.

/// Parse a `VmRSS:\t  123 kB`-style line's numeric field.
fn parse_kb_line(line: &str) -> Option<u64> {
    line.split_whitespace().nth(1)?.parse().ok()
}

/// `/proc/self/status` field in kB, or `None` when unavailable.
fn status_field(key: &str) -> Option<u64> {
    let status = std::fs::read_to_string("/proc/self/status").ok()?;
    status.lines().find(|l| l.starts_with(key)).and_then(parse_kb_line)
}

/// Current resident set size in kB (`VmRSS`).
pub fn rss_kb() -> Option<u64> {
    status_field("VmRSS:")
}

/// Peak resident set size in kB (`VmHWM`).
pub fn peak_rss_kb() -> Option<u64> {
    status_field("VmHWM:")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_status_lines() {
        assert_eq!(parse_kb_line("VmRSS:\t  123456 kB"), Some(123456));
        assert_eq!(parse_kb_line("VmRSS: 7 kB"), Some(7));
        assert_eq!(parse_kb_line("VmRSS:"), None);
        assert_eq!(parse_kb_line("VmRSS:\tnope kB"), None);
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn live_probes_report_plausible_values() {
        let rss = rss_kb().expect("VmRSS readable on Linux");
        let peak = peak_rss_kb().expect("VmHWM readable on Linux");
        assert!(rss > 0);
        assert!(peak >= rss / 2, "peak {peak} kB vs rss {rss} kB");
    }
}
