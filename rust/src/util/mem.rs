//! Process memory introspection for the fleet bench: resident set size
//! read from `/proc/self/status` (no external crates). When the file is
//! unavailable — non-Linux hosts, or containers that mask `/proc` — the
//! probes degrade to `None` and the bench reports the column as JSON
//! `null` instead of omitting or fabricating it.

/// Parse a `VmRSS:\t  123 kB`-style line's numeric field.
fn parse_kb_line(line: &str) -> Option<u64> {
    line.split_whitespace().nth(1)?.parse().ok()
}

/// Extract `key`'s kB value from status-file text.
fn field_from_status(status: &str, key: &str) -> Option<u64> {
    status.lines().find(|l| l.starts_with(key)).and_then(parse_kb_line)
}

/// Read a status file and pull one field; `None` on any failure (file
/// missing, unreadable, field absent, or malformed).
fn status_field_at(path: &str, key: &str) -> Option<u64> {
    field_from_status(&std::fs::read_to_string(path).ok()?, key)
}

/// `/proc/self/status` field in kB, or `None` when unavailable.
fn status_field(key: &str) -> Option<u64> {
    status_field_at("/proc/self/status", key)
}

/// Current resident set size in kB (`VmRSS`).
pub fn rss_kb() -> Option<u64> {
    status_field("VmRSS:")
}

/// Peak resident set size in kB (`VmHWM`).
pub fn peak_rss_kb() -> Option<u64> {
    status_field("VmHWM:")
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_status_lines() {
        assert_eq!(parse_kb_line("VmRSS:\t  123456 kB"), Some(123456));
        assert_eq!(parse_kb_line("VmRSS: 7 kB"), Some(7));
        assert_eq!(parse_kb_line("VmRSS:"), None);
        assert_eq!(parse_kb_line("VmRSS:\tnope kB"), None);
    }

    #[test]
    fn extracts_field_from_status_text() {
        let status = "Name:\tef21\nVmHWM:\t  2048 kB\nVmRSS:\t  1024 kB\n";
        assert_eq!(field_from_status(status, "VmRSS:"), Some(1024));
        assert_eq!(field_from_status(status, "VmHWM:"), Some(2048));
        assert_eq!(field_from_status(status, "VmSwap:"), None);
        assert_eq!(field_from_status("", "VmRSS:"), None);
    }

    /// The degraded branch: a missing status file (non-Linux, masked
    /// /proc) yields `None` rather than an error or a bogus number.
    #[test]
    fn missing_status_file_degrades_to_none() {
        assert_eq!(
            status_field_at("/proc/ef21-no-such-status-file", "VmRSS:"),
            None
        );
    }

    #[test]
    #[cfg(target_os = "linux")]
    fn live_probes_report_plausible_values() {
        let rss = rss_kb().expect("VmRSS readable on Linux");
        let peak = peak_rss_kb().expect("VmHWM readable on Linux");
        assert!(rss > 0);
        assert!(peak >= rss / 2, "peak {peak} kB vs rss {rss} kB");
    }
}
