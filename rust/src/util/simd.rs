//! Runtime-dispatched SIMD kernels for the dense hot loops.
//!
//! Every kernel here has three implementations — AVX2, SSE2, and scalar —
//! selected once per process by [`isa`] (`is_x86_feature_detected!` on
//! x86_64, scalar elsewhere). The contract that makes dispatch safe for a
//! reproducible system is **bit-identity**: the vector paths perform
//! exactly the floating-point operations of the scalar path, in exactly
//! the same order, so every golden trajectory, divergence round, and
//! top-k tie-break is independent of which ISA executed it.
//!
//! Concretely, the lane layout mirrors the 4-way unrolled accumulators of
//! the legacy scalar loops (see the scalar bodies below, lifted verbatim
//! from `util::linalg`):
//!
//!   * reductions keep 4 independent f64 accumulators — one AVX2 lane
//!     each (two SSE2 registers), combined `((a0 + a1) + a2) + a3` like
//!     the scalar `acc[0] + acc[1] + acc[2] + acc[3]`;
//!   * products and sums use separate mul/add instructions (never FMA —
//!     fusing would change the rounding of every accumulate);
//!   * `f32 -> f64` widening is exact, so converting four floats with
//!     `cvtps_pd` equals four scalar `as f64` casts;
//!   * element-wise kernels (`axpy*`, `sub_into`) have no cross-lane
//!     dependency at all, so per-lane mul/add is the scalar op verbatim;
//!   * the `% 4` tail always runs the scalar loop.
//!
//! `EF21_FORCE_SCALAR=1` pins the process to the scalar path (read once,
//! at first kernel use); [`set_override`] does the same in-process for
//! tests and the bench harness. Property tests in
//! `rust/tests/simd_identity.rs` assert bitwise equality across paths,
//! including NaN/±inf payload propagation, subnormals, and lengths with
//! every `% 4` remainder.

use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;

/// Instruction set the kernels dispatch to.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Isa {
    /// Plain Rust loops (always available; the reference semantics).
    Scalar,
    /// 128-bit SSE2 (baseline on x86_64).
    Sse2,
    /// 256-bit AVX2.
    Avx2,
}

impl Isa {
    pub fn name(self) -> &'static str {
        match self {
            Isa::Scalar => "scalar",
            Isa::Sse2 => "sse2",
            Isa::Avx2 => "avx2",
        }
    }
}

/// In-process override installed by [`set_override`]:
/// 0 = none, 1 = scalar, 2 = sse2, 3 = avx2.
static OVERRIDE: AtomicU8 = AtomicU8::new(0);

/// Force a specific ISA (tests / bench harness); `None` restores the
/// detected default. Safe at any time: every path computes bit-identical
/// results, so flipping mid-run changes speed, never values. Requesting
/// an ISA the host lacks falls back to scalar at dispatch time.
pub fn set_override(isa: Option<Isa>) {
    let v = match isa {
        None => 0,
        Some(Isa::Scalar) => 1,
        Some(Isa::Sse2) => 2,
        Some(Isa::Avx2) => 3,
    };
    OVERRIDE.store(v, Ordering::Relaxed);
}

fn detected() -> Isa {
    static DETECTED: OnceLock<Isa> = OnceLock::new();
    *DETECTED.get_or_init(|| {
        let forced = std::env::var("EF21_FORCE_SCALAR")
            .map(|v| !v.trim().is_empty() && v.trim() != "0")
            .unwrap_or(false);
        if forced {
            return Isa::Scalar;
        }
        #[cfg(target_arch = "x86_64")]
        {
            if is_x86_feature_detected!("avx2") {
                return Isa::Avx2;
            }
            if is_x86_feature_detected!("sse2") {
                return Isa::Sse2;
            }
        }
        Isa::Scalar
    })
}

/// The ISA every kernel call dispatches to (override > `EF21_FORCE_SCALAR`
/// > detection). One relaxed atomic load per call.
#[inline]
pub fn isa() -> Isa {
    match OVERRIDE.load(Ordering::Relaxed) {
        1 => Isa::Scalar,
        2 => {
            if cfg!(target_arch = "x86_64") {
                Isa::Sse2
            } else {
                Isa::Scalar
            }
        }
        3 => {
            #[cfg(target_arch = "x86_64")]
            {
                if is_x86_feature_detected!("avx2") {
                    return Isa::Avx2;
                }
            }
            Isa::Scalar
        }
        _ => detected(),
    }
}

// ---------------------------------------------------------------------------
// Scalar reference kernels — the semantics every vector path must match
// bit for bit. The 4-accumulator bodies are the legacy `util::linalg`
// loops, moved here so dispatch and reference live side by side.
// ---------------------------------------------------------------------------

pub(crate) mod scalar {
    /// Dot product with 4-way unrolled accumulators (f64).
    #[inline]
    pub fn dot(a: &[f64], b: &[f64]) -> f64 {
        let mut acc = [0.0f64; 4];
        let chunks = a.len() / 4;
        for i in 0..chunks {
            let j = i * 4;
            acc[0] += a[j] * b[j];
            acc[1] += a[j + 1] * b[j + 1];
            acc[2] += a[j + 2] * b[j + 2];
            acc[3] += a[j + 3] * b[j + 3];
        }
        let mut s = acc[0] + acc[1] + acc[2] + acc[3];
        for j in chunks * 4..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    /// Dot product of an f32 row against an f64 vector.
    #[inline]
    pub fn dot_f32_f64(row: &[f32], x: &[f64]) -> f64 {
        let mut acc = [0.0f64; 4];
        let chunks = row.len() / 4;
        for i in 0..chunks {
            let j = i * 4;
            acc[0] += row[j] as f64 * x[j];
            acc[1] += row[j + 1] as f64 * x[j + 1];
            acc[2] += row[j + 2] as f64 * x[j + 2];
            acc[3] += row[j + 3] as f64 * x[j + 3];
        }
        let mut s = acc[0] + acc[1] + acc[2] + acc[3];
        for j in chunks * 4..row.len() {
            s += row[j] as f64 * x[j];
        }
        s
    }

    /// y += alpha * x
    #[inline]
    pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
        for (yi, xi) in y.iter_mut().zip(x) {
            *yi += alpha * xi;
        }
    }

    /// y += alpha * row (f32 row into f64 accumulator).
    #[inline]
    pub fn axpy_f32(alpha: f64, row: &[f32], y: &mut [f64]) {
        for (yi, xi) in y.iter_mut().zip(row) {
            *yi += alpha * *xi as f64;
        }
    }

    /// out = a - b, element-wise.
    #[inline]
    pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
        for ((o, ai), bi) in out.iter_mut().zip(a).zip(b) {
            *o = ai - bi;
        }
    }

    /// Four row-dots sharing one pass over `x`; each lane runs the exact
    /// [`dot_f32_f64`] recurrence, so `dot4(..)[r] == dot_f32_f64(row_r, x)`
    /// bitwise.
    #[inline]
    pub fn dot4_f32_f64(r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32], x: &[f64]) -> [f64; 4] {
        [dot_f32_f64(r0, x), dot_f32_f64(r1, x), dot_f32_f64(r2, x), dot_f32_f64(r3, x)]
    }

    /// Four row-axpys sharing one pass over `y`. Per coordinate the adds
    /// land in row order 0..3 — the same per-coordinate sequence as four
    /// sequential [`axpy_f32`] calls, so the result is bitwise equal.
    #[inline]
    pub fn axpy4_f32(
        coef: [f64; 4],
        r0: &[f32],
        r1: &[f32],
        r2: &[f32],
        r3: &[f32],
        y: &mut [f64],
    ) {
        for (j, yj) in y.iter_mut().enumerate() {
            let mut t = *yj;
            t += coef[0] * r0[j] as f64;
            t += coef[1] * r1[j] as f64;
            t += coef[2] * r2[j] as f64;
            t += coef[3] * r3[j] as f64;
            *yj = t;
        }
    }
}

// ---------------------------------------------------------------------------
// x86_64 vector kernels. Lane layout documented per kernel; every unsafe
// block only touches lanes proven in-bounds by the chunk arithmetic.
// ---------------------------------------------------------------------------

#[cfg(target_arch = "x86_64")]
mod x86 {
    use std::arch::x86_64::*;

    /// Combine the 4 accumulator lanes exactly like the scalar
    /// `acc[0] + acc[1] + acc[2] + acc[3]` (left-to-right).
    #[inline]
    fn hsum4(lanes: [f64; 4]) -> f64 {
        ((lanes[0] + lanes[1]) + lanes[2]) + lanes[3]
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_avx2(a: &[f64], b: &[f64]) -> f64 {
        let chunks = a.len() / 4;
        let mut acc = _mm256_setzero_pd();
        for i in 0..chunks {
            let j = i * 4;
            let va = _mm256_loadu_pd(a.as_ptr().add(j));
            let vb = _mm256_loadu_pd(b.as_ptr().add(j));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(va, vb));
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = hsum4(lanes);
        for j in chunks * 4..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn dot_sse2(a: &[f64], b: &[f64]) -> f64 {
        let chunks = a.len() / 4;
        // acc01 holds scalar accumulators 0 and 1, acc23 holds 2 and 3.
        let mut acc01 = _mm_setzero_pd();
        let mut acc23 = _mm_setzero_pd();
        for i in 0..chunks {
            let j = i * 4;
            let a01 = _mm_loadu_pd(a.as_ptr().add(j));
            let b01 = _mm_loadu_pd(b.as_ptr().add(j));
            acc01 = _mm_add_pd(acc01, _mm_mul_pd(a01, b01));
            let a23 = _mm_loadu_pd(a.as_ptr().add(j + 2));
            let b23 = _mm_loadu_pd(b.as_ptr().add(j + 2));
            acc23 = _mm_add_pd(acc23, _mm_mul_pd(a23, b23));
        }
        let mut lanes = [0.0f64; 4];
        _mm_storeu_pd(lanes.as_mut_ptr(), acc01);
        _mm_storeu_pd(lanes.as_mut_ptr().add(2), acc23);
        let mut s = hsum4(lanes);
        for j in chunks * 4..a.len() {
            s += a[j] * b[j];
        }
        s
    }

    /// Widen 4 f32s at `p` to 4 f64 lanes (exact conversion).
    #[inline]
    #[target_feature(enable = "avx2")]
    unsafe fn load4_f32_as_f64_avx(p: *const f32) -> __m256d {
        _mm256_cvtps_pd(_mm_loadu_ps(p))
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn dot_f32_f64_avx2(row: &[f32], x: &[f64]) -> f64 {
        let chunks = row.len() / 4;
        let mut acc = _mm256_setzero_pd();
        for i in 0..chunks {
            let j = i * 4;
            let vr = load4_f32_as_f64_avx(row.as_ptr().add(j));
            let vx = _mm256_loadu_pd(x.as_ptr().add(j));
            acc = _mm256_add_pd(acc, _mm256_mul_pd(vr, vx));
        }
        let mut lanes = [0.0f64; 4];
        _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
        let mut s = hsum4(lanes);
        for j in chunks * 4..row.len() {
            s += row[j] as f64 * x[j];
        }
        s
    }

    /// Widen f32 pairs `[p, p+1]` / `[p+2, p+3]` to two f64 registers.
    #[inline]
    #[target_feature(enable = "sse2")]
    unsafe fn load4_f32_as_f64_sse(p: *const f32) -> (__m128d, __m128d) {
        let v = _mm_loadu_ps(p);
        (_mm_cvtps_pd(v), _mm_cvtps_pd(_mm_movehl_ps(v, v)))
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn dot_f32_f64_sse2(row: &[f32], x: &[f64]) -> f64 {
        let chunks = row.len() / 4;
        let mut acc01 = _mm_setzero_pd();
        let mut acc23 = _mm_setzero_pd();
        for i in 0..chunks {
            let j = i * 4;
            let (r01, r23) = load4_f32_as_f64_sse(row.as_ptr().add(j));
            acc01 = _mm_add_pd(acc01, _mm_mul_pd(r01, _mm_loadu_pd(x.as_ptr().add(j))));
            acc23 = _mm_add_pd(acc23, _mm_mul_pd(r23, _mm_loadu_pd(x.as_ptr().add(j + 2))));
        }
        let mut lanes = [0.0f64; 4];
        _mm_storeu_pd(lanes.as_mut_ptr(), acc01);
        _mm_storeu_pd(lanes.as_mut_ptr().add(2), acc23);
        let mut s = hsum4(lanes);
        for j in chunks * 4..row.len() {
            s += row[j] as f64 * x[j];
        }
        s
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_avx2(alpha: f64, x: &[f64], y: &mut [f64]) {
        let chunks = x.len() / 4;
        let va = _mm256_set1_pd(alpha);
        for i in 0..chunks {
            let j = i * 4;
            let vy = _mm256_loadu_pd(y.as_ptr().add(j));
            let vx = _mm256_loadu_pd(x.as_ptr().add(j));
            _mm256_storeu_pd(y.as_mut_ptr().add(j), _mm256_add_pd(vy, _mm256_mul_pd(va, vx)));
        }
        for j in chunks * 4..x.len() {
            y[j] += alpha * x[j];
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy_sse2(alpha: f64, x: &[f64], y: &mut [f64]) {
        let pairs = x.len() / 2;
        let va = _mm_set1_pd(alpha);
        for i in 0..pairs {
            let j = i * 2;
            let vy = _mm_loadu_pd(y.as_ptr().add(j));
            let vx = _mm_loadu_pd(x.as_ptr().add(j));
            _mm_storeu_pd(y.as_mut_ptr().add(j), _mm_add_pd(vy, _mm_mul_pd(va, vx)));
        }
        for j in pairs * 2..x.len() {
            y[j] += alpha * x[j];
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn axpy_f32_avx2(alpha: f64, row: &[f32], y: &mut [f64]) {
        let chunks = row.len() / 4;
        let va = _mm256_set1_pd(alpha);
        for i in 0..chunks {
            let j = i * 4;
            let vy = _mm256_loadu_pd(y.as_ptr().add(j));
            let vr = load4_f32_as_f64_avx(row.as_ptr().add(j));
            _mm256_storeu_pd(y.as_mut_ptr().add(j), _mm256_add_pd(vy, _mm256_mul_pd(va, vr)));
        }
        for j in chunks * 4..row.len() {
            y[j] += alpha * row[j] as f64;
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn axpy_f32_sse2(alpha: f64, row: &[f32], y: &mut [f64]) {
        let chunks = row.len() / 4;
        let va = _mm_set1_pd(alpha);
        for i in 0..chunks {
            let j = i * 4;
            let (r01, r23) = load4_f32_as_f64_sse(row.as_ptr().add(j));
            let y01 = _mm_loadu_pd(y.as_ptr().add(j));
            let y23 = _mm_loadu_pd(y.as_ptr().add(j + 2));
            _mm_storeu_pd(y.as_mut_ptr().add(j), _mm_add_pd(y01, _mm_mul_pd(va, r01)));
            _mm_storeu_pd(y.as_mut_ptr().add(j + 2), _mm_add_pd(y23, _mm_mul_pd(va, r23)));
        }
        for j in chunks * 4..row.len() {
            y[j] += alpha * row[j] as f64;
        }
    }

    #[target_feature(enable = "avx2")]
    pub unsafe fn sub_into_avx2(a: &[f64], b: &[f64], out: &mut [f64]) {
        let chunks = a.len() / 4;
        for i in 0..chunks {
            let j = i * 4;
            let va = _mm256_loadu_pd(a.as_ptr().add(j));
            let vb = _mm256_loadu_pd(b.as_ptr().add(j));
            _mm256_storeu_pd(out.as_mut_ptr().add(j), _mm256_sub_pd(va, vb));
        }
        for j in chunks * 4..a.len() {
            out[j] = a[j] - b[j];
        }
    }

    #[target_feature(enable = "sse2")]
    pub unsafe fn sub_into_sse2(a: &[f64], b: &[f64], out: &mut [f64]) {
        let pairs = a.len() / 2;
        for i in 0..pairs {
            let j = i * 2;
            let va = _mm_loadu_pd(a.as_ptr().add(j));
            let vb = _mm_loadu_pd(b.as_ptr().add(j));
            _mm_storeu_pd(out.as_mut_ptr().add(j), _mm_sub_pd(va, vb));
        }
        for j in pairs * 2..a.len() {
            out[j] = a[j] - b[j];
        }
    }

    /// 4-row register-blocked dot: one pass over `x`, four accumulator
    /// registers, each running the exact single-row recurrence.
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn dot4_f32_f64_avx2(
        r0: &[f32],
        r1: &[f32],
        r2: &[f32],
        r3: &[f32],
        x: &[f64],
    ) -> [f64; 4] {
        let d = x.len();
        let chunks = d / 4;
        let mut acc0 = _mm256_setzero_pd();
        let mut acc1 = _mm256_setzero_pd();
        let mut acc2 = _mm256_setzero_pd();
        let mut acc3 = _mm256_setzero_pd();
        for i in 0..chunks {
            let j = i * 4;
            let vx = _mm256_loadu_pd(x.as_ptr().add(j));
            acc0 = _mm256_add_pd(acc0, _mm256_mul_pd(load4_f32_as_f64_avx(r0.as_ptr().add(j)), vx));
            acc1 = _mm256_add_pd(acc1, _mm256_mul_pd(load4_f32_as_f64_avx(r1.as_ptr().add(j)), vx));
            acc2 = _mm256_add_pd(acc2, _mm256_mul_pd(load4_f32_as_f64_avx(r2.as_ptr().add(j)), vx));
            acc3 = _mm256_add_pd(acc3, _mm256_mul_pd(load4_f32_as_f64_avx(r3.as_ptr().add(j)), vx));
        }
        let mut out = [0.0f64; 4];
        for (o, acc) in out.iter_mut().zip([acc0, acc1, acc2, acc3]) {
            let mut lanes = [0.0f64; 4];
            _mm256_storeu_pd(lanes.as_mut_ptr(), acc);
            *o = hsum4(lanes);
        }
        for j in chunks * 4..d {
            out[0] += r0[j] as f64 * x[j];
            out[1] += r1[j] as f64 * x[j];
            out[2] += r2[j] as f64 * x[j];
            out[3] += r3[j] as f64 * x[j];
        }
        out
    }

    /// 4-row register-blocked axpy: one pass over `y`, adds applied in
    /// row order per coordinate (the sequential-axpy order).
    #[target_feature(enable = "avx2")]
    #[allow(clippy::too_many_arguments)]
    pub unsafe fn axpy4_f32_avx2(
        coef: [f64; 4],
        r0: &[f32],
        r1: &[f32],
        r2: &[f32],
        r3: &[f32],
        y: &mut [f64],
    ) {
        let d = y.len();
        let chunks = d / 4;
        let c0 = _mm256_set1_pd(coef[0]);
        let c1 = _mm256_set1_pd(coef[1]);
        let c2 = _mm256_set1_pd(coef[2]);
        let c3 = _mm256_set1_pd(coef[3]);
        for i in 0..chunks {
            let j = i * 4;
            let mut vy = _mm256_loadu_pd(y.as_ptr().add(j));
            vy = _mm256_add_pd(vy, _mm256_mul_pd(c0, load4_f32_as_f64_avx(r0.as_ptr().add(j))));
            vy = _mm256_add_pd(vy, _mm256_mul_pd(c1, load4_f32_as_f64_avx(r1.as_ptr().add(j))));
            vy = _mm256_add_pd(vy, _mm256_mul_pd(c2, load4_f32_as_f64_avx(r2.as_ptr().add(j))));
            vy = _mm256_add_pd(vy, _mm256_mul_pd(c3, load4_f32_as_f64_avx(r3.as_ptr().add(j))));
            _mm256_storeu_pd(y.as_mut_ptr().add(j), vy);
        }
        for j in chunks * 4..d {
            let mut t = y[j];
            t += coef[0] * r0[j] as f64;
            t += coef[1] * r1[j] as f64;
            t += coef[2] * r2[j] as f64;
            t += coef[3] * r3[j] as f64;
            y[j] = t;
        }
    }
}

// ---------------------------------------------------------------------------
// Dispatched entry points.
// ---------------------------------------------------------------------------

/// Dot product (4-accumulator order). Bit-identical across ISAs.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    #[cfg(target_arch = "x86_64")]
    match isa() {
        Isa::Avx2 => return unsafe { x86::dot_avx2(a, b) },
        Isa::Sse2 => return unsafe { x86::dot_sse2(a, b) },
        Isa::Scalar => {}
    }
    scalar::dot(a, b)
}

/// f32-row × f64-vector dot (4-accumulator order).
#[inline]
pub fn dot_f32_f64(row: &[f32], x: &[f64]) -> f64 {
    debug_assert_eq!(row.len(), x.len());
    #[cfg(target_arch = "x86_64")]
    match isa() {
        Isa::Avx2 => return unsafe { x86::dot_f32_f64_avx2(row, x) },
        Isa::Sse2 => return unsafe { x86::dot_f32_f64_sse2(row, x) },
        Isa::Scalar => {}
    }
    scalar::dot_f32_f64(row, x)
}

/// y += alpha * x (element-wise; no cross-lane dependency).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    match isa() {
        Isa::Avx2 => return unsafe { x86::axpy_avx2(alpha, x, y) },
        Isa::Sse2 => return unsafe { x86::axpy_sse2(alpha, x, y) },
        Isa::Scalar => {}
    }
    scalar::axpy(alpha, x, y)
}

/// y += alpha * row (f32 row widened exactly).
#[inline]
pub fn axpy_f32(alpha: f64, row: &[f32], y: &mut [f64]) {
    debug_assert_eq!(row.len(), y.len());
    #[cfg(target_arch = "x86_64")]
    match isa() {
        Isa::Avx2 => return unsafe { x86::axpy_f32_avx2(alpha, row, y) },
        Isa::Sse2 => return unsafe { x86::axpy_f32_sse2(alpha, row, y) },
        Isa::Scalar => {}
    }
    scalar::axpy_f32(alpha, row, y)
}

/// out = a - b (element-wise).
#[inline]
pub fn sub_into(a: &[f64], b: &[f64], out: &mut [f64]) {
    debug_assert_eq!(a.len(), b.len());
    debug_assert_eq!(a.len(), out.len());
    #[cfg(target_arch = "x86_64")]
    match isa() {
        Isa::Avx2 => return unsafe { x86::sub_into_avx2(a, b, out) },
        Isa::Sse2 => return unsafe { x86::sub_into_sse2(a, b, out) },
        Isa::Scalar => {}
    }
    scalar::sub_into(a, b, out)
}

/// Four row-dots in one pass over `x` (register-blocked matvec tile).
/// `dot4_f32_f64(r0..r3, x)[r]` is bitwise `dot_f32_f64(row_r, x)`.
#[inline]
pub fn dot4_f32_f64(r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32], x: &[f64]) -> [f64; 4] {
    debug_assert!(
        r0.len() == x.len() && r1.len() == x.len() && r2.len() == x.len() && r3.len() == x.len()
    );
    #[cfg(target_arch = "x86_64")]
    if isa() == Isa::Avx2 {
        return unsafe { x86::dot4_f32_f64_avx2(r0, r1, r2, r3, x) };
    }
    scalar::dot4_f32_f64(r0, r1, r2, r3, x)
}

/// Four row-axpys in one pass over `y`, adds in row order per coordinate
/// — bitwise equal to four sequential [`axpy_f32`] calls.
#[inline]
pub fn axpy4_f32(coef: [f64; 4], r0: &[f32], r1: &[f32], r2: &[f32], r3: &[f32], y: &mut [f64]) {
    debug_assert!(
        r0.len() == y.len() && r1.len() == y.len() && r2.len() == y.len() && r3.len() == y.len()
    );
    #[cfg(target_arch = "x86_64")]
    if isa() == Isa::Avx2 {
        return unsafe { x86::axpy4_f32_avx2(coef, r0, r1, r2, r3, y) };
    }
    scalar::axpy4_f32(coef, r0, r1, r2, r3, y)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Rng;

    /// Hold the override for the duration of a test section (the kernels
    /// are bit-identical either way, so concurrent tests seeing a
    /// temporary override still compute correct values).
    struct ForceIsa;
    impl ForceIsa {
        fn new(isa: Isa) -> ForceIsa {
            set_override(Some(isa));
            ForceIsa
        }
    }
    impl Drop for ForceIsa {
        fn drop(&mut self) {
            set_override(None);
        }
    }

    fn vecs(d: usize, seed: u64) -> (Vec<f64>, Vec<f64>, Vec<f32>) {
        let mut rng = Rng::seed(seed);
        let a: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
        let b: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
        let r: Vec<f32> = (0..d).map(|_| rng.next_normal() as f32).collect();
        (a, b, r)
    }

    #[test]
    fn every_isa_matches_scalar_bitwise() {
        for d in [0usize, 1, 2, 3, 4, 5, 7, 8, 13, 31, 64, 65] {
            let (a, b, r) = vecs(d, d as u64 + 1);
            let want_dot = scalar::dot(&a, &b);
            let want_dotf = scalar::dot_f32_f64(&r, &a);
            let mut want_y = b.clone();
            scalar::axpy(0.37, &a, &mut want_y);
            scalar::axpy_f32(-1.25, &r, &mut want_y);
            let mut want_sub = vec![0.0; d];
            scalar::sub_into(&a, &b, &mut want_sub);
            for isa in [Isa::Scalar, Isa::Sse2, Isa::Avx2] {
                let _g = ForceIsa::new(isa);
                assert_eq!(dot(&a, &b).to_bits(), want_dot.to_bits(), "dot d={d} {isa:?}");
                assert_eq!(
                    dot_f32_f64(&r, &a).to_bits(),
                    want_dotf.to_bits(),
                    "dotf d={d} {isa:?}"
                );
                let mut y = b.clone();
                axpy(0.37, &a, &mut y);
                axpy_f32(-1.25, &r, &mut y);
                for (got, want) in y.iter().zip(&want_y) {
                    assert_eq!(got.to_bits(), want.to_bits(), "axpy d={d} {isa:?}");
                }
                let mut s = vec![0.0; d];
                sub_into(&a, &b, &mut s);
                for (got, want) in s.iter().zip(&want_sub) {
                    assert_eq!(got.to_bits(), want.to_bits(), "sub d={d} {isa:?}");
                }
            }
        }
    }

    #[test]
    fn blocked_kernels_match_single_row_calls() {
        for d in [1usize, 3, 4, 6, 8, 17, 32, 33] {
            let mut rng = Rng::seed(d as u64);
            let rows: Vec<Vec<f32>> =
                (0..4).map(|_| (0..d).map(|_| rng.next_normal() as f32).collect()).collect();
            let x: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
            let coef = [0.5, -1.0, 2.25, -0.125];
            for isa in [Isa::Scalar, Isa::Avx2] {
                let _g = ForceIsa::new(isa);
                let got = dot4_f32_f64(&rows[0], &rows[1], &rows[2], &rows[3], &x);
                for (lane, row) in rows.iter().enumerate() {
                    assert_eq!(
                        got[lane].to_bits(),
                        scalar::dot_f32_f64(row, &x).to_bits(),
                        "dot4 lane {lane} d={d} {isa:?}"
                    );
                }
                let mut y = x.clone();
                axpy4_f32(coef, &rows[0], &rows[1], &rows[2], &rows[3], &mut y);
                let mut want = x.clone();
                for (c, row) in coef.iter().zip(&rows) {
                    scalar::axpy_f32(*c, row, &mut want);
                }
                for (got, want) in y.iter().zip(&want) {
                    assert_eq!(got.to_bits(), want.to_bits(), "axpy4 d={d} {isa:?}");
                }
            }
        }
    }

    #[test]
    fn override_falls_back_when_unavailable_and_resets() {
        set_override(Some(Isa::Scalar));
        assert_eq!(isa(), Isa::Scalar);
        set_override(None);
        let _ = isa(); // whatever detection yields; just must not panic
        assert_eq!(Isa::Avx2.name(), "avx2");
    }
}
