//! Mini property-testing harness (proptest is not vendored offline).
//!
//! `for_all_seeds(n, |rng| ...)` runs a property across `n` independent
//! seeded RNG streams and reports the failing seed so the case can be
//! replayed deterministically with `replay(seed, f)`.

use crate::util::rng::Rng;

/// Run `prop` for `cases` deterministic seeds; panic with the seed on the
/// first failure (the closure should panic/assert on violation).
pub fn for_all_seeds<F: FnMut(&mut Rng)>(cases: u64, mut prop: F) {
    for seed in 0..cases {
        let mut rng = Rng::seed(0x5EED_0000 + seed);
        let result = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            prop(&mut rng);
        }));
        if let Err(e) = result {
            let msg = e
                .downcast_ref::<String>()
                .cloned()
                .or_else(|| e.downcast_ref::<&str>().map(|s| s.to_string()))
                .unwrap_or_else(|| "<non-string panic>".into());
            panic!("property failed for seed {seed}: {msg}");
        }
    }
}

/// Replay one specific seed (debugging helper).
pub fn replay<F: FnMut(&mut Rng)>(seed: u64, mut prop: F) {
    let mut rng = Rng::seed(0x5EED_0000 + seed);
    prop(&mut rng);
}

/// Random dense vector with entries ~ N(0, scale^2).
pub fn random_vec(rng: &mut Rng, d: usize, scale: f64) -> Vec<f64> {
    (0..d).map(|_| scale * rng.next_normal()).collect()
}

/// Assert a <= b with a small relative slack (floating-point-safe).
#[track_caller]
pub fn assert_le_approx(a: f64, b: f64, rel: f64, what: &str) {
    let slack = rel * b.abs().max(1.0);
    assert!(a <= b + slack, "{what}: {a} > {b} (+{slack})");
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn for_all_seeds_passes_trivial_property() {
        for_all_seeds(10, |rng| {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
        });
    }

    #[test]
    #[should_panic(expected = "property failed for seed")]
    fn for_all_seeds_reports_failing_seed() {
        for_all_seeds(5, |rng| {
            assert!(rng.next_f64() < 0.5, "too big");
        });
    }

    #[test]
    fn random_vec_has_expected_len_and_scale() {
        let mut rng = Rng::seed(1);
        let v = random_vec(&mut rng, 1000, 2.0);
        assert_eq!(v.len(), 1000);
        let var: f64 = v.iter().map(|x| x * x).sum::<f64>() / 1000.0;
        assert!((var - 4.0).abs() < 0.8, "var {var}");
    }
}
