//! Small dense linear-algebra helpers on slices. The coordinator's hot path
//! (aggregation, compressor input prep, oracle matvecs) runs through these.
//! The four leaf kernels (`dot`, `dot_f32_f64`, `axpy`, `axpy_f32`)
//! dispatch to [`crate::util::simd`] — runtime-selected AVX2/SSE2 paths
//! whose lane layout mirrors the legacy 4-accumulator scalar loops, so
//! results are **bit-identical** whichever ISA executes them (the scalar
//! reference bodies live in `simd::scalar`).

use crate::util::simd;

/// Dot product with 4-way unrolled accumulators (f64). SIMD-dispatched;
/// bit-identical to the scalar 4-accumulator loop.
#[inline]
pub fn dot(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    simd::dot(a, b)
}

/// Dot product of an f32 row against an f64 vector (oracle inner loop:
/// data stays f32, model/state stays f64). SIMD-dispatched.
#[inline]
pub fn dot_f32_f64(row: &[f32], x: &[f64]) -> f64 {
    debug_assert_eq!(row.len(), x.len());
    simd::dot_f32_f64(row, x)
}

/// y += alpha * x (SIMD-dispatched; element-wise, so lane width cannot
/// change any result).
#[inline]
pub fn axpy(alpha: f64, x: &[f64], y: &mut [f64]) {
    debug_assert_eq!(x.len(), y.len());
    simd::axpy(alpha, x, y);
}

/// y += alpha * row (f32 row into f64 accumulator; SIMD-dispatched).
#[inline]
pub fn axpy_f32(alpha: f64, row: &[f32], y: &mut [f64]) {
    debug_assert_eq!(row.len(), y.len());
    simd::axpy_f32(alpha, row, y);
}

/// Squared Euclidean norm.
#[inline]
pub fn norm2_sq(v: &[f64]) -> f64 {
    dot(v, v)
}

/// Euclidean norm.
#[inline]
pub fn norm2(v: &[f64]) -> f64 {
    norm2_sq(v).sqrt()
}

/// Squared distance ||a - b||^2.
#[inline]
pub fn dist_sq(a: &[f64], b: &[f64]) -> f64 {
    debug_assert_eq!(a.len(), b.len());
    let mut s = 0.0;
    for (x, y) in a.iter().zip(b) {
        let d = x - y;
        s += d * d;
    }
    s
}

/// v *= alpha
#[inline]
pub fn scale(v: &mut [f64], alpha: f64) {
    for x in v.iter_mut() {
        *x *= alpha;
    }
}

/// Mean of a set of equal-length vectors.
pub fn mean_vec(vs: &[Vec<f64>]) -> Vec<f64> {
    assert!(!vs.is_empty());
    let d = vs[0].len();
    let mut out = vec![0.0; d];
    for v in vs {
        axpy(1.0, v, &mut out);
    }
    scale(&mut out, 1.0 / vs.len() as f64);
    out
}

/// Largest eigenvalue of the PSD matrix `M = A^T A / rows_scale` given the
/// row-major f32 matrix A (n x d), via power iteration. Used for smoothness
/// constants (L_i for logreg/lstsq).
pub fn spectral_norm_sq_ata(a: &[f32], n: usize, d: usize, iters: usize, seed: u64) -> f64 {
    assert_eq!(a.len(), n * d);
    if n == 0 || d == 0 {
        return 0.0;
    }
    let mut rng = crate::util::rng::Rng::seed(seed);
    let mut v: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
    let nv = norm2(&v).max(1e-300);
    scale(&mut v, 1.0 / nv);
    let mut lambda = 0.0;
    let mut av = vec![0.0f64; n];
    let mut w = vec![0.0f64; d];
    for _ in 0..iters {
        // av = A v ; w = A^T av
        for (i, avi) in av.iter_mut().enumerate() {
            *avi = dot_f32_f64(&a[i * d..(i + 1) * d], &v);
        }
        w.iter_mut().for_each(|x| *x = 0.0);
        for (i, avi) in av.iter().enumerate() {
            axpy_f32(*avi, &a[i * d..(i + 1) * d], &mut w);
        }
        lambda = norm2(&w);
        if lambda <= 1e-300 {
            return 0.0;
        }
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi / lambda;
        }
    }
    lambda // = lambda_max(A^T A)
}

/// Smallest eigenvalue of A^T A (d x d, PSD) via power iteration on
/// (c I - A^T A) with c = lambda_max. Used for the least-squares PL constant.
pub fn lambda_min_ata(a: &[f32], n: usize, d: usize, iters: usize, seed: u64) -> f64 {
    let lmax = spectral_norm_sq_ata(a, n, d, iters, seed);
    if lmax == 0.0 {
        return 0.0;
    }
    let c = lmax * 1.0001;
    let mut rng = crate::util::rng::Rng::seed(seed ^ 0xABCD);
    let mut v: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
    let nv = norm2(&v);
    scale(&mut v, 1.0 / nv);
    let mut av = vec![0.0f64; n];
    let mut w = vec![0.0f64; d];
    let mut mu = 0.0;
    for _ in 0..iters {
        for (i, avi) in av.iter_mut().enumerate() {
            *avi = dot_f32_f64(&a[i * d..(i + 1) * d], &v);
        }
        w.iter_mut().for_each(|x| *x = 0.0);
        for (i, avi) in av.iter().enumerate() {
            axpy_f32(*avi, &a[i * d..(i + 1) * d], &mut w);
        }
        // u = c v - A^T A v
        for j in 0..d {
            w[j] = c * v[j] - w[j];
        }
        mu = norm2(&w);
        if mu <= 1e-300 {
            break;
        }
        for (vi, wi) in v.iter_mut().zip(&w) {
            *vi = wi / mu;
        }
    }
    (c - mu).max(0.0) // lambda_min(A^T A)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dot_matches_naive() {
        let a: Vec<f64> = (0..13).map(|i| i as f64 * 0.5).collect();
        let b: Vec<f64> = (0..13).map(|i| (i as f64).sin()).collect();
        let naive: f64 = a.iter().zip(&b).map(|(x, y)| x * y).sum();
        assert!((dot(&a, &b) - naive).abs() < 1e-12);
    }

    #[test]
    fn axpy_and_norm() {
        let x = vec![1.0, 2.0, 3.0];
        let mut y = vec![1.0, 1.0, 1.0];
        axpy(2.0, &x, &mut y);
        assert_eq!(y, vec![3.0, 5.0, 7.0]);
        assert!((norm2(&[3.0, 4.0]) - 5.0).abs() < 1e-12);
    }

    #[test]
    fn dist_and_scale() {
        let a = vec![1.0, 2.0];
        let b = vec![4.0, 6.0];
        assert!((dist_sq(&a, &b) - 25.0).abs() < 1e-12);
        let mut v = vec![2.0, -4.0];
        scale(&mut v, 0.5);
        assert_eq!(v, vec![1.0, -2.0]);
    }

    #[test]
    fn mean_vec_averages() {
        let m = mean_vec(&[vec![1.0, 2.0], vec![3.0, 6.0]]);
        assert_eq!(m, vec![2.0, 4.0]);
    }

    #[test]
    fn spectral_norm_of_diagonal() {
        // A = diag(3, 1) as 2x2 f32 row-major; A^T A = diag(9, 1).
        let a = [3.0f32, 0.0, 0.0, 1.0];
        let l = spectral_norm_sq_ata(&a, 2, 2, 200, 1);
        assert!((l - 9.0).abs() < 1e-6, "{l}");
        let lmin = lambda_min_ata(&a, 2, 2, 400, 1);
        assert!((lmin - 1.0).abs() < 1e-3, "{lmin}");
    }

    #[test]
    fn spectral_norm_random_vs_gram_trace_bound() {
        let mut rng = crate::util::rng::Rng::seed(5);
        let (n, d) = (40, 7);
        let a: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
        let l = spectral_norm_sq_ata(&a, n, d, 300, 2);
        // trace(A^T A) >= lambda_max >= trace / d
        let trace: f64 = a.iter().map(|&x| (x as f64) * (x as f64)).sum();
        assert!(l <= trace + 1e-6);
        assert!(l >= trace / d as f64 - 1e-6);
    }

    #[test]
    fn dot_f32_f64_matches() {
        let row = [1.0f32, 2.0, 3.0, 4.0, 5.0];
        let x = [0.5f64, 0.5, 0.5, 0.5, 0.5];
        assert!((dot_f32_f64(&row, &x) - 7.5).abs() < 1e-12);
    }
}
