//! Counting global allocator — the measurement behind the repo's
//! zero-allocation guarantee for the steady-state round loop.
//!
//! [`CountingAlloc`] wraps [`std::alloc::System`] and bumps a process-wide
//! relaxed atomic on every `alloc` / `alloc_zeroed` / `realloc` (frees are
//! not counted: the gate cares about allocation *pressure*, and a path
//! that frees without allocating is already alloc-free on the next
//! round). It is only installed as `#[global_allocator]` under the
//! `count-allocs` cargo feature (see `lib.rs`), so ordinary builds pay
//! nothing; the counter itself compiles unconditionally so call sites
//! don't need cfg gymnastics.
//!
//! Consumers:
//!   * `rust/tests/integration_alloc.rs` — asserts that extending a sim
//!     run by N rounds adds **zero** allocations (steady state);
//!   * `ef21 bench` — reports `allocs_per_round` in `BENCH_round.json`
//!     when the feature is on (`null` otherwise).

use std::alloc::{GlobalAlloc, Layout, System};
use std::sync::atomic::{AtomicU64, Ordering};

static ALLOCS: AtomicU64 = AtomicU64::new(0);

/// System allocator wrapper counting allocation events process-wide.
pub struct CountingAlloc;

unsafe impl GlobalAlloc for CountingAlloc {
    unsafe fn alloc(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc(layout)
    }

    unsafe fn alloc_zeroed(&self, layout: Layout) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.alloc_zeroed(layout)
    }

    unsafe fn realloc(&self, ptr: *mut u8, layout: Layout, new_size: usize) -> *mut u8 {
        ALLOCS.fetch_add(1, Ordering::Relaxed);
        System.realloc(ptr, layout, new_size)
    }

    unsafe fn dealloc(&self, ptr: *mut u8, layout: Layout) {
        System.dealloc(ptr, layout)
    }
}

/// Raw allocation-event count. Monotone; only meaningful as a *delta*
/// around a measured section, and only nonzero when the `count-allocs`
/// feature installed [`CountingAlloc`] as the global allocator.
pub fn allocation_count() -> u64 {
    ALLOCS.load(Ordering::Relaxed)
}

/// `Some(count)` when the counting allocator is installed (feature
/// `count-allocs`), `None` otherwise — lets reports distinguish "zero
/// allocations" from "not measured".
pub fn measured_allocation_count() -> Option<u64> {
    if cfg!(feature = "count-allocs") {
        Some(allocation_count())
    } else {
        None
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counter_is_monotone_and_counts_when_installed() {
        let before = allocation_count();
        let v: Vec<u64> = (0..1024).collect();
        std::hint::black_box(&v);
        let after = allocation_count();
        assert!(after >= before);
        if cfg!(feature = "count-allocs") {
            assert!(after > before, "an allocation must bump the counter");
            assert!(measured_allocation_count().is_some());
        } else {
            assert_eq!(measured_allocation_count(), None);
        }
    }
}
