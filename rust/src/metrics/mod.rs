//! Metrics: per-round records, run history, and CSV/JSONL sinks. Every
//! paper figure is regenerated from these histories.

use std::io::Write;
use std::path::Path;

/// One recorded round. All quantities refer to the state after the round's
/// master step (i.e. at `x^{t+1}`), evaluated through the instrumentation
/// path (NOT counted as communication).
#[derive(Clone, Debug)]
pub struct RoundRecord {
    /// Round index t (0-based).
    pub round: usize,
    /// Cumulative uplink bits per client (the paper's x-axis, `bits/n`).
    pub bits_per_client: f64,
    /// f(x) = average of worker losses.
    pub loss: f64,
    /// ||∇f(x)||^2 (squared norm of the averaged worker gradients).
    pub grad_norm_sq: f64,
    /// G^t = (1/n) Σ ||g_i - ∇f_i||^2 (EF21 family; NaN otherwise).
    pub gt: f64,
    /// Fraction of workers that used the DCGD branch (EF21+; NaN otherwise).
    pub dcgd_frac: f64,
}

/// History of one run (one curve in a figure).
#[derive(Clone, Debug)]
pub struct History {
    /// Label, e.g. "EF21 top1 4x".
    pub label: String,
    pub records: Vec<RoundRecord>,
    /// Total metered downlink (broadcast) payload bits over the run —
    /// dense `32·d` per round for flat layouts, the block-delta cost for
    /// blocked ones. Kept off [`RoundRecord`] so per-round fixtures and
    /// CSVs are unchanged; 0 for runs predating the meter (or manual
    /// record assembly).
    pub downlink_bits: u64,
    /// Final model on the master (empty for manually-assembled
    /// histories). Kept off [`RoundRecord`] like `downlink_bits`; used
    /// by the PP sweeps to evaluate exact end-of-run loss/gradient with
    /// fresh oracles.
    pub final_x: Vec<f64>,
}

impl History {
    pub fn new(label: impl Into<String>) -> Self {
        History {
            label: label.into(),
            records: Vec::new(),
            downlink_bits: 0,
            final_x: Vec::new(),
        }
    }

    pub fn final_loss(&self) -> f64 {
        self.records.last().map(|r| r.loss).unwrap_or(f64::NAN)
    }

    pub fn final_grad_norm_sq(&self) -> f64 {
        self.records.last().map(|r| r.grad_norm_sq).unwrap_or(f64::NAN)
    }

    /// Best (minimum) squared gradient norm along the run.
    pub fn best_grad_norm_sq(&self) -> f64 {
        self.records.iter().map(|r| r.grad_norm_sq).fold(f64::INFINITY, f64::min)
    }

    /// Did the run blow up (NaN/inf loss) at any point?
    pub fn diverged(&self) -> bool {
        self.records.iter().any(|r| !r.loss.is_finite())
    }

    /// Bits/client needed to first reach `||∇f||^2 <= tol`; None if never.
    pub fn bits_to_tolerance(&self, tol: f64) -> Option<f64> {
        self.records
            .iter()
            .find(|r| r.grad_norm_sq <= tol)
            .map(|r| r.bits_per_client)
    }

    /// Rounds needed to first reach `||∇f||^2 <= tol`; None if never.
    pub fn rounds_to_tolerance(&self, tol: f64) -> Option<usize> {
        self.records.iter().find(|r| r.grad_norm_sq <= tol).map(|r| r.round)
    }

    /// Write as CSV: round,bits_per_client,loss,grad_norm_sq,gt,dcgd_frac.
    pub fn write_csv(&self, path: &Path) -> std::io::Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        writeln!(f, "round,bits_per_client,loss,grad_norm_sq,gt,dcgd_frac")?;
        for r in &self.records {
            writeln!(
                f,
                "{},{},{},{},{},{}",
                r.round, r.bits_per_client, r.loss, r.grad_norm_sq, r.gt, r.dcgd_frac
            )?;
        }
        Ok(())
    }
}

/// A set of histories (one figure); writes one CSV per curve plus an
/// index file.
pub struct FigureData {
    pub name: String,
    pub curves: Vec<History>,
}

impl FigureData {
    pub fn new(name: impl Into<String>) -> Self {
        FigureData { name: name.into(), curves: Vec::new() }
    }

    pub fn push(&mut self, h: History) {
        self.curves.push(h);
    }

    pub fn write_dir(&self, dir: &Path) -> std::io::Result<()> {
        let sub = dir.join(&self.name);
        std::fs::create_dir_all(&sub)?;
        let mut idx = std::io::BufWriter::new(std::fs::File::create(sub.join("index.txt"))?);
        for (i, h) in self.curves.iter().enumerate() {
            let fname = format!("curve_{i:02}.csv");
            h.write_csv(&sub.join(&fname))?;
            writeln!(idx, "{fname}\t{}", h.label)?;
        }
        Ok(())
    }

    /// Console summary: one row per curve.
    pub fn print_summary(&self) {
        println!("== {} ==", self.name);
        println!(
            "{:<34} {:>12} {:>12} {:>14} {:>10}",
            "curve", "final f", "final |g|^2", "bits/n@1e-6", "diverged"
        );
        for h in &self.curves {
            let b = h
                .bits_to_tolerance(1e-6)
                .map(|b| format!("{b:.3e}"))
                .unwrap_or_else(|| "-".into());
            println!(
                "{:<34} {:>12.4e} {:>12.4e} {:>14} {:>10}",
                h.label,
                h.final_loss(),
                h.final_grad_norm_sq(),
                b,
                h.diverged()
            );
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rec(round: usize, bits: f64, loss: f64, g2: f64) -> RoundRecord {
        RoundRecord {
            round,
            bits_per_client: bits,
            loss,
            grad_norm_sq: g2,
            gt: f64::NAN,
            dcgd_frac: f64::NAN,
        }
    }

    #[test]
    fn tolerance_queries() {
        let mut h = History::new("x");
        h.records.push(rec(0, 64.0, 1.0, 1e-2));
        h.records.push(rec(1, 128.0, 0.5, 1e-5));
        h.records.push(rec(2, 192.0, 0.2, 1e-8));
        assert_eq!(h.bits_to_tolerance(1e-5), Some(128.0));
        assert_eq!(h.rounds_to_tolerance(1e-8), Some(2));
        assert_eq!(h.bits_to_tolerance(1e-12), None);
        assert!(!h.diverged());
        assert_eq!(h.final_loss(), 0.2);
        assert_eq!(h.best_grad_norm_sq(), 1e-8);
    }

    #[test]
    fn divergence_detection() {
        let mut h = History::new("x");
        h.records.push(rec(0, 1.0, f64::NAN, 1.0));
        assert!(h.diverged());
    }

    #[test]
    fn csv_roundtrip_shape() {
        let dir = std::env::temp_dir().join(format!("ef21_metrics_{}", std::process::id()));
        let mut h = History::new("c");
        h.records.push(rec(0, 64.0, 1.0, 0.1));
        let path = dir.join("h.csv");
        h.write_csv(&path).unwrap();
        let text = std::fs::read_to_string(&path).unwrap();
        assert!(text.starts_with("round,bits_per_client"));
        assert_eq!(text.lines().count(), 2);
        let mut fig = FigureData::new("fig_test");
        fig.push(h);
        fig.write_dir(&dir).unwrap();
        assert!(dir.join("fig_test/curve_00.csv").exists());
        assert!(dir.join("fig_test/index.txt").exists());
        std::fs::remove_dir_all(&dir).ok();
    }
}
