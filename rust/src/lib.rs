//! # ef21 — a Rust+JAX+Pallas reproduction of EF21
//!
//! *EF21: A New, Simpler, Theoretically Better, and Practically Faster
//! Error Feedback* (Richtárik, Sokolov, Fatkhullin; NeurIPS 2021).
//!
//! Three-layer architecture (see DESIGN.md):
//!   * **L3 (this crate)** — distributed coordinator: master/worker round
//!     protocol, compressors with exact bit accounting, the EF21 family of
//!     algorithms and its baselines, transports, datasets, metrics, and the
//!     experiment harness regenerating every figure in the paper.
//!   * **L2 (`python/compile/model.py`)** — JAX compute graphs (logistic
//!     regression, least squares, a small transformer LM), AOT-lowered to
//!     HLO text once at build time.
//!   * **L1 (`python/compile/kernels/`)** — Pallas kernels for the
//!     per-worker gradient hot spot, embedded in the L2 artifacts.
//!
//! Python never runs at request time: the [`runtime`] module loads the
//! artifacts via PJRT and `oracle::xla` exposes them as gradient oracles
//! (both behind the `xla-runtime` feature, which needs the vendored `xla`
//! PJRT bindings).
//!
//! Live observability comes from the [`telemetry`] facade: lock-free
//! counters/gauges/histograms instrumenting every layer, a JSONL file
//! sink, and a Prometheus-style TCP exposition endpoint
//! (`--telemetry jsonl:<path>|tcp:<port>|off` on the CLI).
//!
//! Quick start (simulated 20-node EF21 on a Table-3 dataset):
//!
//! ```no_run
//! use ef21::prelude::*;
//! use std::sync::Arc;
//!
//! let ds = ef21::data::synth::generate("a9a", 0);
//! let shards = ef21::data::partition::shards(&ds, 20);
//! let lam = 0.1;
//! let oracles: Vec<Box<dyn GradOracle>> = shards
//!     .iter()
//!     .map(|s| Box::new(LogRegOracle::new(*s, lam)) as Box<dyn GradOracle>)
//!     .collect();
//! let l_i: Vec<f64> = shards
//!     .iter()
//!     .map(|s| ef21::theory::logreg_l(s.a, s.n, s.d, lam))
//!     .collect();
//! let sm = ef21::theory::Smoothness::from_l_i_mean(l_i);
//! let gamma = ef21::theory::stepsize_theorem1(sm.l, sm.l_tilde, 1.0 / ds.d as f64);
//! let (master, workers) = ef21::algo::build(
//!     AlgoSpec::Ef21,
//!     vec![0.0; ds.d],
//!     oracles,
//!     Arc::new(TopK::new(1)),
//!     gamma,
//!     0,
//! );
//! let history = run_protocol(master, workers, &RunConfig::rounds(1000));
//! println!("final ||grad||^2 = {:.3e}", history.final_grad_norm_sq());
//! ```

pub mod algo;
pub mod bench;
pub mod blocks;
pub mod ckpt;
pub mod compress;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod exp;
pub mod health;
pub mod metrics;
pub mod nn;
pub mod oracle;
pub mod runtime;
pub mod sched;
pub mod telemetry;
pub mod theory;
pub mod transport;
pub mod util;

/// Counting global allocator behind the zero-allocation round gate
/// (`tests/integration_alloc.rs`, `ef21 bench`); ordinary builds use the
/// system allocator untouched.
#[cfg(feature = "count-allocs")]
#[global_allocator]
static COUNTING_ALLOC: crate::util::alloc::CountingAlloc = crate::util::alloc::CountingAlloc;

/// Convenience re-exports for the common simulation workflow.
pub mod prelude {
    pub use crate::algo::{AlgoSpec, MasterNode, WireMsg, WorkerNode};
    pub use crate::blocks::{BlockLayout, BlockSpec, ParamBlocks};
    pub use crate::compress::{
        BlockCompressor, Compressor, Identity, Markov, RandK, ScaledSign, SparseVec, TopK,
    };
    pub use crate::coordinator::par::{auto_threads, run_protocol_par};
    pub use crate::coordinator::runner::{run_protocol, RunConfig};
    pub use crate::data::Dataset;
    pub use crate::metrics::{FigureData, History};
    pub use crate::oracle::{GradOracle, LogRegOracle, LstsqOracle, QuadraticOracle};
    pub use crate::sched::{FaultPlan, Participation, Scheduler};
    pub use crate::util::rng::Rng;
}
