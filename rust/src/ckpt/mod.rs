//! Durable checkpoint/resume: a versioned binary snapshot of everything
//! the round loop needs to continue a run **bitwise identically** after
//! a master crash.
//!
//! # What is captured
//!
//! EF21's Markov-state view ("EF21 with Bells & Whistles", arXiv
//! 2110.03294) pins down the full run state exactly:
//!
//! * the model `x` and the master's aggregate state (inside the opaque
//!   master blob, serialized by [`crate::algo::MasterNode::ckpt_save`]);
//! * every worker's Markov/error state `g_i`/`e_i`, RNG stream position
//!   (rand-k consumes the stream every compress), and cached
//!   instrumentation (`last_loss`/`last_grad` — under partial
//!   participation an absent worker's stale cache feeds the divergence
//!   sum and the round records, so it is trajectory-relevant state);
//! * the master's resync mirrors ([`crate::sched::StateTracker`]);
//! * the [`crate::transport::downlink::DownlinkMeter`] image + counters
//!   (the delta planner must keep patching against what workers hold);
//! * the recorded [`History`] so far and the cumulative uplink bits;
//! * the next round index. The scheduler itself is **not** serialized:
//!   round plans are pure in `(spec, seed, t, n)`, so the round index is
//!   the entire scheduler position.
//!
//! Not captured: oracles/datasets, compressor objects, layouts, stepsize
//! — all rebuilt from the run configuration, which the caller fingerprints
//! ([`Checkpoint::fingerprint`]) so a checkpoint cannot be resumed into a
//! mismatched run. Transport frame-byte counters restart from zero.
//!
//! # Container format (`ef21.ckpt/v1`)
//!
//! ```text
//!   magic  "ef21.ckpt/v1\n"
//!   sections: [u32 tag][u64 len][payload]...   (little-endian)
//!   last section: CKSUM — FNV-1a-64 over every preceding byte
//! ```
//!
//! Unknown section tags are rejected (v1 readers read v1 files only);
//! truncation, trailing garbage, and bit flips all fail with a clear
//! error instead of resuming a corrupted run. Writes go through
//! [`Checkpoint::write_atomic`]: tmp file + rename, so a crash mid-write
//! leaves the previous checkpoint intact.

pub mod wire;

use crate::metrics::{History, RoundRecord};
use anyhow::{bail, ensure, Context, Result};
use std::path::Path;
use wire::Rd;

/// File magic, version included.
pub const MAGIC: &[u8] = b"ef21.ckpt/v1\n";

// Section tags.
const SEC_META: u32 = 1;
const SEC_MASTER: u32 = 2;
const SEC_WORKERS: u32 = 3;
/// Dense resync mirrors (v1 layout). Decode-only: old snapshots are
/// converted to [`TrackerImage`] on read; new files write
/// [`SEC_TRACKER_SPARSE`].
const SEC_TRACKER: u32 = 4;
const SEC_DOWNLINK: u32 = 5;
const SEC_HISTORY: u32 = 6;
const SEC_LOSSES: u32 = 7;
/// Sparse resync mirrors ([`TrackerImage`]) — O(total nnz) on disk
/// instead of the dense n×d f64 dump.
const SEC_TRACKER_SPARSE: u32 = 8;
const SEC_CKSUM: u32 = 0xC5C5_C5C5;

/// FNV-1a 64 over a byte slice (no external deps; collision resistance
/// is not the goal — catching truncation and bit rot is).
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One worker's resync mirror as sorted-unique `(idx, val)` pairs.
/// Coordinates absent from `idx` are exactly `+0.0` (the dense initial
/// value); an explicit entry may hold any bits, including `-0.0`.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SparseMirror {
    pub idx: Vec<u32>,
    pub val: Vec<f64>,
}

/// The [`crate::sched::StateTracker`] checkpoint image: one compacted
/// sparse mirror per worker plus the mirrored dimension (needed for
/// validation — the sparse entries alone do not pin down `d`).
#[derive(Clone, Debug, Default, PartialEq)]
pub struct TrackerImage {
    pub d: usize,
    pub mirrors: Vec<SparseMirror>,
}

impl TrackerImage {
    /// Convert a dense v1 mirror dump, keeping every cell whose **bits**
    /// are nonzero. `+0.0` cells become implicit (bit-identical to the
    /// reconstruction default); `-0.0` has nonzero bits and keeps an
    /// explicit entry, so reconstruction is exact for every cell.
    pub fn from_dense(mirrors: &[Vec<f64>]) -> Result<TrackerImage> {
        let d = mirrors.first().map_or(0, Vec::len);
        let mut out = Vec::with_capacity(mirrors.len());
        for m in mirrors {
            ensure!(
                m.len() == d,
                "dense tracker mirrors are ragged ({} vs {d})",
                m.len()
            );
            let mut idx = Vec::new();
            let mut val = Vec::new();
            for (i, &v) in m.iter().enumerate() {
                if v.to_bits() != 0 {
                    idx.push(i as u32);
                    val.push(v);
                }
            }
            out.push(SparseMirror { idx, val });
        }
        Ok(TrackerImage { d, mirrors: out })
    }
}

/// Downlink meter dynamic state: last-broadcast f32 image (None until
/// the first broadcast / dense mode) + cumulative payload bits +
/// cumulative dense-baseline bits.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct DownlinkState {
    pub last: Option<Vec<f32>>,
    pub bits_cum: u64,
    pub dense_bits_cum: u64,
}

/// One decoded/encodable run snapshot.
#[derive(Clone, Debug, Default)]
pub struct Checkpoint {
    /// Caller-chosen run identity (algo, compressor, shape, seed,
    /// schedule...). Resume verifies it verbatim.
    pub fingerprint: String,
    /// First round the resumed loop executes.
    pub next_round: usize,
    /// Cumulative uplink bits at snapshot time (the `bits/n` x-axis).
    pub uplink_bits_cum: u64,
    /// Opaque master blob ([`crate::algo::MasterNode::ckpt_save`]).
    pub master: Vec<u8>,
    /// Opaque per-worker blobs, in worker order.
    pub workers: Vec<Vec<u8>>,
    /// Resync mirrors, present iff the run keeps a StateTracker.
    /// Written sparse ([`SEC_TRACKER_SPARSE`]); dense v1 snapshots are
    /// converted losslessly on decode.
    pub tracker: Option<TrackerImage>,
    /// Downlink meter state.
    pub downlink: DownlinkState,
    /// Everything recorded so far (final_x is ignored/empty).
    pub history: History,
    /// Master-side per-worker loss cache (distributed scheduled runner
    /// only; the sim runners cache inside the worker blobs).
    pub last_loss: Option<Vec<f64>>,
}

fn put_section(out: &mut Vec<u8>, tag: u32, payload: &[u8]) {
    wire::put_u32(out, tag);
    wire::put_u64(out, payload.len() as u64);
    out.extend_from_slice(payload);
}

impl Checkpoint {
    /// Serialize to the `ef21.ckpt/v1` container.
    pub fn encode(&self) -> Vec<u8> {
        let mut out = Vec::new();
        out.extend_from_slice(MAGIC);

        let mut sec = Vec::new();
        wire::put_str(&mut sec, &self.fingerprint);
        wire::put_u64(&mut sec, self.next_round as u64);
        wire::put_u64(&mut sec, self.uplink_bits_cum);
        wire::put_u32(&mut sec, self.workers.len() as u32);
        put_section(&mut out, SEC_META, &sec);

        put_section(&mut out, SEC_MASTER, &self.master);

        sec.clear();
        wire::put_u32(&mut sec, self.workers.len() as u32);
        for blob in &self.workers {
            wire::put_u32(&mut sec, blob.len() as u32);
            sec.extend_from_slice(blob);
        }
        put_section(&mut out, SEC_WORKERS, &sec);

        if let Some(image) = &self.tracker {
            sec.clear();
            wire::put_u64(&mut sec, image.d as u64);
            wire::put_u32(&mut sec, image.mirrors.len() as u32);
            for m in &image.mirrors {
                wire::put_u32(&mut sec, m.idx.len() as u32);
                for &i in &m.idx {
                    wire::put_u32(&mut sec, i);
                }
                for &v in &m.val {
                    wire::put_f64(&mut sec, v);
                }
            }
            put_section(&mut out, SEC_TRACKER_SPARSE, &sec);
        }

        sec.clear();
        match &self.downlink.last {
            Some(img) => {
                wire::put_u8(&mut sec, 1);
                wire::put_u32(&mut sec, img.len() as u32);
                for &v in img {
                    wire::put_f32(&mut sec, v);
                }
            }
            None => wire::put_u8(&mut sec, 0),
        }
        wire::put_u64(&mut sec, self.downlink.bits_cum);
        wire::put_u64(&mut sec, self.downlink.dense_bits_cum);
        put_section(&mut out, SEC_DOWNLINK, &sec);

        sec.clear();
        wire::put_str(&mut sec, &self.history.label);
        wire::put_u64(&mut sec, self.history.downlink_bits);
        wire::put_u32(&mut sec, self.history.records.len() as u32);
        for r in &self.history.records {
            wire::put_u64(&mut sec, r.round as u64);
            wire::put_f64(&mut sec, r.bits_per_client);
            wire::put_f64(&mut sec, r.loss);
            wire::put_f64(&mut sec, r.grad_norm_sq);
            wire::put_f64(&mut sec, r.gt);
            wire::put_f64(&mut sec, r.dcgd_frac);
        }
        put_section(&mut out, SEC_HISTORY, &sec);

        if let Some(losses) = &self.last_loss {
            sec.clear();
            wire::put_f64s(&mut sec, losses);
            put_section(&mut out, SEC_LOSSES, &sec);
        }

        let sum = fnv1a64(&out);
        let mut tail = Vec::with_capacity(8);
        wire::put_u64(&mut tail, sum);
        put_section(&mut out, SEC_CKSUM, &tail);
        out
    }

    /// Decode and verify an `ef21.ckpt/v1` container.
    pub fn decode(bytes: &[u8]) -> Result<Checkpoint> {
        ensure!(
            bytes.len() >= MAGIC.len() && &bytes[..MAGIC.len()] == MAGIC,
            "not an ef21 checkpoint (bad magic; expected {:?})",
            String::from_utf8_lossy(MAGIC).trim_end()
        );
        let mut ck = Checkpoint::default();
        let mut rd = Rd::new(&bytes[MAGIC.len()..]);
        let mut meta_workers: Option<usize> = None;
        let mut seen_cksum = false;
        let mut seen = std::collections::BTreeSet::new();
        while rd.remaining() > 0 {
            ensure!(!seen_cksum, "trailing bytes after the checksum section");
            let base = bytes.len() - rd.remaining();
            let tag = rd.u32().context("truncated section header")?;
            let len = rd.u64().context("truncated section header")? as usize;
            let payload = rd
                .bytes(len)
                .with_context(|| format!("truncated section 0x{tag:x} ({len} bytes declared)"))?;
            ensure!(seen.insert(tag), "duplicate section 0x{tag:x}");
            let mut p = Rd::new(payload);
            match tag {
                SEC_META => {
                    ck.fingerprint = p.str()?;
                    ck.next_round = p.u64()? as usize;
                    ck.uplink_bits_cum = p.u64()?;
                    meta_workers = Some(p.u32()? as usize);
                }
                SEC_MASTER => ck.master = payload.to_vec(),
                SEC_WORKERS => {
                    let n = p.u32()? as usize;
                    let mut blobs = Vec::with_capacity(n.min(1 << 16));
                    for _ in 0..n {
                        let blen = p.u32()? as usize;
                        blobs.push(p.bytes(blen).context("truncated worker blob")?.to_vec());
                    }
                    ck.workers = blobs;
                }
                SEC_TRACKER => {
                    // Dense v1 compatibility path: convert losslessly.
                    ensure!(
                        ck.tracker.is_none(),
                        "checkpoint has both dense and sparse tracker sections"
                    );
                    let n = p.u32()? as usize;
                    let mut mirrors = Vec::with_capacity(n.min(1 << 16));
                    for _ in 0..n {
                        mirrors.push(p.f64s()?);
                    }
                    ck.tracker = Some(TrackerImage::from_dense(&mirrors)?);
                }
                SEC_TRACKER_SPARSE => {
                    ensure!(
                        ck.tracker.is_none(),
                        "checkpoint has both dense and sparse tracker sections"
                    );
                    let d = p.u64()? as usize;
                    let n = p.u32()? as usize;
                    let mut mirrors = Vec::with_capacity(n.min(1 << 16));
                    for _ in 0..n {
                        let nnz = p.u32()? as usize;
                        let mut idx = Vec::with_capacity(p.clamped_cap(nnz, 4));
                        for _ in 0..nnz {
                            idx.push(p.u32()?);
                        }
                        let mut val = Vec::with_capacity(p.clamped_cap(nnz, 8));
                        for _ in 0..nnz {
                            val.push(p.f64()?);
                        }
                        mirrors.push(SparseMirror { idx, val });
                    }
                    ck.tracker = Some(TrackerImage { d, mirrors });
                }
                SEC_DOWNLINK => {
                    let has_img = p.u8()?;
                    ck.downlink.last = match has_img {
                        0 => None,
                        1 => {
                            let d = p.u32()? as usize;
                            let mut img = Vec::with_capacity(p.clamped_cap(d, 4));
                            for _ in 0..d {
                                img.push(p.f32()?);
                            }
                            Some(img)
                        }
                        other => bail!("downlink section: bad image flag {other}"),
                    };
                    ck.downlink.bits_cum = p.u64()?;
                    ck.downlink.dense_bits_cum = p.u64()?;
                }
                SEC_HISTORY => {
                    ck.history.label = p.str()?;
                    ck.history.downlink_bits = p.u64()?;
                    let n = p.u32()? as usize;
                    let mut records = Vec::with_capacity(p.clamped_cap(n, 48));
                    for _ in 0..n {
                        records.push(RoundRecord {
                            round: p.u64()? as usize,
                            bits_per_client: p.f64()?,
                            loss: p.f64()?,
                            grad_norm_sq: p.f64()?,
                            gt: p.f64()?,
                            dcgd_frac: p.f64()?,
                        });
                    }
                    ck.history.records = records;
                }
                SEC_LOSSES => ck.last_loss = Some(p.f64s()?),
                SEC_CKSUM => {
                    let want = p.u64()?;
                    let got = fnv1a64(&bytes[..base]);
                    ensure!(
                        want == got,
                        "checkpoint checksum mismatch (file {want:#018x}, computed \
                         {got:#018x}) — the file is truncated or corrupted"
                    );
                    seen_cksum = true;
                }
                other => bail!("unknown checkpoint section 0x{other:x} (v1 reader)"),
            }
            if tag != SEC_CKSUM {
                p.done().with_context(|| format!("section 0x{tag:x} has trailing bytes"))?;
            }
        }
        ensure!(seen_cksum, "checkpoint has no checksum section (truncated file?)");
        ensure!(seen.contains(&SEC_META), "checkpoint has no META section");
        ensure!(seen.contains(&SEC_MASTER), "checkpoint has no MASTER section");
        ensure!(seen.contains(&SEC_WORKERS), "checkpoint has no WORKERS section");
        ensure!(seen.contains(&SEC_HISTORY), "checkpoint has no HISTORY section");
        if let Some(nw) = meta_workers {
            ensure!(
                nw == ck.workers.len(),
                "META declares {nw} workers but the WORKERS section holds {}",
                ck.workers.len()
            );
        }
        Ok(ck)
    }

    /// Write atomically: serialize to `<path>.tmp`, fsync, rename over
    /// `path`. Returns the encoded size in bytes. Metered under
    /// `ckpt.write.ns` / `ckpt.bytes`.
    pub fn write_atomic(&self, path: &Path) -> Result<u64> {
        let t0 = crate::telemetry::maybe_now();
        let bytes = self.encode();
        let tmp = path.with_extension(match path.extension() {
            Some(e) => format!("{}.tmp", e.to_string_lossy()),
            None => "tmp".to_string(),
        });
        {
            use std::io::Write;
            let mut f = std::fs::File::create(&tmp)
                .with_context(|| format!("creating {}", tmp.display()))?;
            f.write_all(&bytes)?;
            f.sync_all()?;
        }
        std::fs::rename(&tmp, path)
            .with_context(|| format!("renaming {} -> {}", tmp.display(), path.display()))?;
        crate::telemetry::counter(crate::telemetry::keys::CKPT_BYTES).incr(bytes.len() as u64);
        if let Some(t0) = t0 {
            crate::telemetry::record_elapsed_ns(crate::telemetry::keys::CKPT_WRITE_NS, t0);
        }
        Ok(bytes.len() as u64)
    }

    /// Read + decode a checkpoint file.
    pub fn read(path: &Path) -> Result<Checkpoint> {
        let bytes = std::fs::read(path)
            .with_context(|| format!("reading checkpoint {}", path.display()))?;
        Self::decode(&bytes)
            .with_context(|| format!("decoding checkpoint {}", path.display()))
    }

    /// Verify the run identity before resuming into a configuration the
    /// snapshot was not taken from.
    pub fn verify_fingerprint(&self, expected: &str) -> Result<()> {
        ensure!(
            self.fingerprint == expected,
            "checkpoint was taken from a different run:\n  checkpoint: {}\n  this run:   {}",
            self.fingerprint,
            expected
        );
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint {
            fingerprint: "EF21|top1|n=4|d=8|seed=0|sched=".into(),
            next_round: 7,
            uplink_bits_cum: 12345,
            master: vec![1, 2, 3, 4],
            workers: vec![vec![9], vec![], vec![8, 7]],
            tracker: Some(TrackerImage {
                d: 2,
                mirrors: vec![
                    SparseMirror { idx: vec![0, 1], val: vec![1.0, -2.0] },
                    SparseMirror { idx: vec![0, 1], val: vec![0.5, 0.25] },
                ],
            }),
            downlink: DownlinkState {
                last: Some(vec![1.0f32, 2.5]),
                bits_cum: 640,
                dense_bits_cum: 640,
            },
            history: History {
                label: "EF21 top1 1x".into(),
                records: vec![RoundRecord {
                    round: 6,
                    bits_per_client: 96.0,
                    loss: 0.5,
                    grad_norm_sq: 1e-3,
                    gt: 2e-3,
                    dcgd_frac: 0.0,
                }],
                downlink_bits: 2048,
                final_x: Vec::new(),
            },
            last_loss: Some(vec![0.1, 0.2, 0.3]),
        }
    }

    #[test]
    fn encode_decode_roundtrip() {
        let ck = sample();
        let bytes = ck.encode();
        assert_eq!(&bytes[..MAGIC.len()], MAGIC);
        let back = Checkpoint::decode(&bytes).unwrap();
        assert_eq!(back.fingerprint, ck.fingerprint);
        assert_eq!(back.next_round, 7);
        assert_eq!(back.uplink_bits_cum, 12345);
        assert_eq!(back.master, ck.master);
        assert_eq!(back.workers, ck.workers);
        assert_eq!(back.tracker, ck.tracker);
        assert_eq!(back.downlink, ck.downlink);
        assert_eq!(back.history.label, ck.history.label);
        assert_eq!(back.history.downlink_bits, 2048);
        assert_eq!(back.history.records.len(), 1);
        assert_eq!(back.history.records[0].round, 6);
        assert_eq!(back.history.records[0].loss.to_bits(), 0.5f64.to_bits());
        assert_eq!(back.last_loss, ck.last_loss);
    }

    /// Dense v1 snapshots (SEC_TRACKER) still decode, converted to the
    /// sparse image losslessly: +0.0 cells become implicit, -0.0 and
    /// every nonzero cell keep their exact bits.
    #[test]
    fn dense_v1_tracker_section_still_decodes() {
        // Hand-build a v1-layout container: re-encode sample() without
        // its sparse tracker section, then splice in a dense SEC_TRACKER
        // before the checksum.
        let ck = Checkpoint { tracker: None, ..sample() };
        let bytes = ck.encode();
        let body_len = bytes.len() - (4 + 8 + 8); // strip CKSUM section
        let mut v1 = bytes[..body_len].to_vec();
        let mut sec = Vec::new();
        wire::put_u32(&mut sec, 2);
        wire::put_f64s(&mut sec, &[1.5, 0.0, -0.0]);
        wire::put_f64s(&mut sec, &[0.0, 0.25, 0.0]);
        put_section(&mut v1, SEC_TRACKER, &sec);
        let sum = fnv1a64(&v1);
        let mut tail = Vec::new();
        wire::put_u64(&mut tail, sum);
        put_section(&mut v1, SEC_CKSUM, &tail);

        let back = Checkpoint::decode(&v1).unwrap();
        let tr = back.tracker.expect("dense tracker section must decode");
        assert_eq!(tr.d, 3);
        assert_eq!(tr.mirrors.len(), 2);
        assert_eq!(tr.mirrors[0].idx, vec![0, 2]);
        assert_eq!(tr.mirrors[0].val[0].to_bits(), 1.5f64.to_bits());
        assert_eq!(tr.mirrors[0].val[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(tr.mirrors[1].idx, vec![1]);
        assert_eq!(tr.mirrors[1].val, vec![0.25]);

        // A file carrying BOTH tracker layouts is rejected.
        let mut both = v1[..v1.len() - (4 + 8 + 8)].to_vec();
        let mut sp = Vec::new();
        wire::put_u64(&mut sp, 3);
        wire::put_u32(&mut sp, 0);
        put_section(&mut both, SEC_TRACKER_SPARSE, &sp);
        let sum = fnv1a64(&both);
        let mut tail = Vec::new();
        wire::put_u64(&mut tail, sum);
        put_section(&mut both, SEC_CKSUM, &tail);
        let e = format!("{:#}", Checkpoint::decode(&both).unwrap_err());
        assert!(e.contains("both dense and sparse"), "{e}");
    }

    #[test]
    fn optional_sections_roundtrip_absent() {
        let ck = Checkpoint { tracker: None, last_loss: None, ..sample() };
        let back = Checkpoint::decode(&ck.encode()).unwrap();
        assert!(back.tracker.is_none());
        assert!(back.last_loss.is_none());
    }

    #[test]
    fn corruption_is_rejected_with_clear_errors() {
        let bytes = sample().encode();
        // Bad magic.
        let mut b = bytes.clone();
        b[0] ^= 0xFF;
        let e = Checkpoint::decode(&b).unwrap_err().to_string();
        assert!(e.contains("bad magic"), "{e}");
        // Truncation (drop the checksum tail).
        let e = Checkpoint::decode(&bytes[..bytes.len() - 10]).unwrap_err();
        assert!(format!("{e:#}").contains("truncated"), "{e:#}");
        // A flipped payload byte fails the checksum.
        let mut b = bytes.clone();
        let mid = MAGIC.len() + 20;
        b[mid] ^= 0x01;
        let e = format!("{:#}", Checkpoint::decode(&b).unwrap_err());
        assert!(e.contains("checksum mismatch"), "{e}");
        // Trailing garbage after the checksum.
        let mut b = bytes.clone();
        b.extend_from_slice(&[0u8; 12]);
        assert!(Checkpoint::decode(&b).is_err());
        // Empty file.
        assert!(Checkpoint::decode(&[]).is_err());
    }

    #[test]
    fn fingerprint_mismatch_is_rejected() {
        let ck = sample();
        assert!(ck.verify_fingerprint(&ck.fingerprint).is_ok());
        let e = ck.verify_fingerprint("EF|rand8|n=2").unwrap_err().to_string();
        assert!(e.contains("different run"), "{e}");
    }

    #[test]
    fn write_atomic_roundtrips_and_replaces() {
        let dir = std::env::temp_dir().join(format!("ef21_ckpt_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let path = dir.join("run.ckpt");
        let ck = sample();
        let n = ck.write_atomic(&path).unwrap();
        assert_eq!(n, ck.encode().len() as u64);
        let back = Checkpoint::read(&path).unwrap();
        assert_eq!(back.next_round, ck.next_round);
        // Overwrite with a later snapshot; the tmp file must be gone.
        let later = Checkpoint { next_round: 9, ..ck };
        later.write_atomic(&path).unwrap();
        assert_eq!(Checkpoint::read(&path).unwrap().next_round, 9);
        assert!(!path.with_extension("ckpt.tmp").exists());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn fnv_matches_reference_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }
}
