//! Little-endian byte helpers shared by the checkpoint container and the
//! per-node state blobs ([`crate::algo::WorkerNode::ckpt_save`] &c.).
//! Reads are checked: truncated input is an error, never a panic.

use anyhow::{ensure, Result};

pub fn put_u8(out: &mut Vec<u8>, v: u8) {
    out.push(v);
}

pub fn put_u32(out: &mut Vec<u8>, v: u32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_u64(out: &mut Vec<u8>, v: u64) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f32(out: &mut Vec<u8>, v: f32) {
    out.extend_from_slice(&v.to_le_bytes());
}

pub fn put_f64(out: &mut Vec<u8>, v: f64) {
    out.extend_from_slice(&v.to_le_bytes());
}

/// u32 length prefix + raw f64s.
pub fn put_f64s(out: &mut Vec<u8>, v: &[f64]) {
    put_u32(out, v.len() as u32);
    for &x in v {
        put_f64(out, x);
    }
}

/// u32 length prefix + UTF-8 bytes.
pub fn put_str(out: &mut Vec<u8>, s: &str) {
    put_u32(out, s.len() as u32);
    out.extend_from_slice(s.as_bytes());
}

/// Serialize an RNG stream position (4×u64, [`crate::util::rng::Rng::state`]).
pub fn put_rng(out: &mut Vec<u8>, rng: &crate::util::rng::Rng) {
    for w in rng.state() {
        put_u64(out, w);
    }
}

/// Read an RNG stream position written by [`put_rng`].
pub fn read_rng(rd: &mut Rd) -> Result<crate::util::rng::Rng> {
    let s = [rd.u64()?, rd.u64()?, rd.u64()?, rd.u64()?];
    Ok(crate::util::rng::Rng::from_state(s))
}

/// Read a [`put_f64s`] vector into an existing buffer; the length must
/// match exactly (state blobs are restored into identically configured
/// nodes, so a length mismatch means a config/checkpoint mismatch).
pub fn read_f64s_into(rd: &mut Rd, out: &mut [f64]) -> Result<()> {
    let n = rd.u32()? as usize;
    ensure!(n == out.len(), "blob vector len {n} vs expected {}", out.len());
    for v in out.iter_mut() {
        *v = rd.f64()?;
    }
    Ok(())
}

/// Checked little-endian reader over a byte slice.
pub struct Rd<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Rd<'a> {
    pub fn new(b: &'a [u8]) -> Rd<'a> {
        Rd { b, i: 0 }
    }

    pub fn remaining(&self) -> usize {
        self.b.len() - self.i
    }

    pub fn bytes(&mut self, n: usize) -> Result<&'a [u8]> {
        ensure!(
            self.remaining() >= n,
            "truncated blob: need {n} bytes at offset {}, have {}",
            self.i,
            self.remaining()
        );
        let out = &self.b[self.i..self.i + n];
        self.i += n;
        Ok(out)
    }

    pub fn u8(&mut self) -> Result<u8> {
        Ok(self.bytes(1)?[0])
    }

    pub fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    pub fn f32(&mut self) -> Result<f32> {
        Ok(f32::from_le_bytes(self.bytes(4)?.try_into().unwrap()))
    }

    pub fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.bytes(8)?.try_into().unwrap()))
    }

    /// Length-prefixed f64 vector ([`put_f64s`]).
    pub fn f64s(&mut self) -> Result<Vec<f64>> {
        let n = self.u32()? as usize;
        let mut out = Vec::with_capacity(self.clamped_cap(n, 8));
        for _ in 0..n {
            out.push(self.f64()?);
        }
        Ok(out)
    }

    /// Length-prefixed UTF-8 string ([`put_str`]).
    pub fn str(&mut self) -> Result<String> {
        let n = self.u32()? as usize;
        let bytes = self.bytes(n)?;
        Ok(std::str::from_utf8(bytes)
            .map_err(|e| anyhow::anyhow!("invalid UTF-8 in blob string: {e}"))?
            .to_string())
    }

    /// A safe `Vec::with_capacity` argument for `declared` elements of
    /// `bytes_per` bytes each: never more than the bytes actually left,
    /// so a corrupted length prefix cannot trigger a huge allocation
    /// before the read fails.
    pub fn clamped_cap(&self, declared: usize, bytes_per: usize) -> usize {
        declared.min(self.remaining() / bytes_per.max(1))
    }

    /// Assert the blob was consumed exactly.
    pub fn done(&self) -> Result<()> {
        ensure!(self.remaining() == 0, "{} trailing bytes in blob", self.remaining());
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_all_primitives() {
        let mut b = Vec::new();
        put_u8(&mut b, 7);
        put_u32(&mut b, 0xDEAD_BEEF);
        put_u64(&mut b, u64::MAX - 1);
        put_f32(&mut b, -1.5);
        put_f64(&mut b, std::f64::consts::PI);
        put_f64s(&mut b, &[1.0, -0.0, f64::INFINITY]);
        put_str(&mut b, "ef21");
        let mut r = Rd::new(&b);
        assert_eq!(r.u8().unwrap(), 7);
        assert_eq!(r.u32().unwrap(), 0xDEAD_BEEF);
        assert_eq!(r.u64().unwrap(), u64::MAX - 1);
        assert_eq!(r.f32().unwrap(), -1.5);
        assert_eq!(r.f64().unwrap().to_bits(), std::f64::consts::PI.to_bits());
        let v = r.f64s().unwrap();
        assert_eq!(v.len(), 3);
        assert_eq!(v[1].to_bits(), (-0.0f64).to_bits());
        assert_eq!(r.str().unwrap(), "ef21");
        r.done().unwrap();
    }

    #[test]
    fn truncation_and_trailing_are_errors() {
        let mut b = Vec::new();
        put_u64(&mut b, 42);
        let mut r = Rd::new(&b[..5]);
        assert!(r.u64().is_err());
        let mut r = Rd::new(&b);
        assert_eq!(r.u32().unwrap(), 42);
        assert!(r.done().is_err());
        // Corrupted length prefix: errors without a giant allocation.
        let mut b = Vec::new();
        put_u32(&mut b, u32::MAX);
        let mut r = Rd::new(&b);
        assert!(r.f64s().is_err());
    }
}
