//! PJRT runtime: loads the AOT HLO-text artifacts produced by
//! `python/compile/aot.py` and executes them on the CPU PJRT client.
//! This is the only place Rust touches XLA; Python never runs at request
//! time.

#[cfg(feature = "xla-runtime")]
pub mod client;
pub mod manifest;

#[cfg(feature = "xla-runtime")]
pub use client::Runtime;
pub use manifest::{ArtifactEntry, IoSpec, Manifest};
