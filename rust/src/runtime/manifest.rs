//! `artifacts/manifest.json` loader: names, files, I/O shapes and metadata
//! for every AOT-compiled computation.

use crate::util::json::Json;
use anyhow::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// One input or output tensor description.
#[derive(Clone, Debug, PartialEq)]
pub struct IoSpec {
    pub name: String,
    pub shape: Vec<usize>,
    pub dtype: String,
}

impl IoSpec {
    pub fn element_count(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<IoSpec> {
        Ok(IoSpec {
            name: j
                .get("name")
                .and_then(|v| v.as_str())
                .context("io spec missing name")?
                .to_string(),
            shape: j
                .get("shape")
                .and_then(|v| v.as_arr())
                .context("io spec missing shape")?
                .iter()
                .map(|v| v.as_usize().context("bad shape entry"))
                .collect::<Result<Vec<_>>>()?,
            dtype: j
                .get("dtype")
                .and_then(|v| v.as_str())
                .context("io spec missing dtype")?
                .to_string(),
        })
    }
}

/// One artifact: an HLO module plus its interface.
#[derive(Clone, Debug)]
pub struct ArtifactEntry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
    pub meta: Json,
}

impl ArtifactEntry {
    /// usize metadata field accessor (e.g. "d", "n_rows_padded").
    pub fn meta_usize(&self, key: &str) -> Result<usize> {
        self.meta
            .get(key)
            .and_then(|v| v.as_usize())
            .with_context(|| format!("artifact {}: missing meta.{key}", self.name))
    }
}

/// Parsed manifest.
#[derive(Clone, Debug)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: BTreeMap<String, ArtifactEntry>,
}

impl Manifest {
    pub fn load(dir: &Path) -> Result<Manifest> {
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`)", path.display()))?;
        Self::parse(dir, &text)
    }

    pub fn parse(dir: &Path, text: &str) -> Result<Manifest> {
        let j = Json::parse(text).map_err(|e| anyhow::anyhow!("manifest JSON: {e}"))?;
        let obj = j.as_obj().context("manifest must be an object")?;
        let mut entries = BTreeMap::new();
        for (name, v) in obj {
            let file = dir.join(
                v.get("file")
                    .and_then(|f| f.as_str())
                    .with_context(|| format!("artifact {name}: missing file"))?,
            );
            let inputs = v
                .get("inputs")
                .and_then(|x| x.as_arr())
                .with_context(|| format!("artifact {name}: missing inputs"))?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let outputs = v
                .get("outputs")
                .and_then(|x| x.as_arr())
                .with_context(|| format!("artifact {name}: missing outputs"))?
                .iter()
                .map(IoSpec::from_json)
                .collect::<Result<Vec<_>>>()?;
            let meta = v.get("meta").cloned().unwrap_or(Json::Null);
            entries.insert(
                name.clone(),
                ArtifactEntry { name: name.clone(), file, inputs, outputs, meta },
            );
        }
        Ok(Manifest { dir: dir.to_path_buf(), entries })
    }

    pub fn get(&self, name: &str) -> Result<&ArtifactEntry> {
        self.entries
            .get(name)
            .with_context(|| format!("artifact '{name}' not in manifest (run `make artifacts`)"))
    }
}

/// Default artifacts directory: $EF21_ARTIFACTS or ./artifacts.
pub fn default_dir() -> PathBuf {
    std::env::var_os("EF21_ARTIFACTS")
        .map(PathBuf::from)
        .unwrap_or_else(|| PathBuf::from("artifacts"))
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "logreg_grad_a9a": {
        "file": "logreg_grad_a9a.hlo.txt",
        "inputs": [
          {"name": "a", "shape": [1792, 123], "dtype": "f32"},
          {"name": "x", "shape": [123], "dtype": "f32"},
          {"name": "lam", "shape": [], "dtype": "f32"}
        ],
        "outputs": [{"name": "loss", "shape": [], "dtype": "f32"}],
        "meta": {"d": 123, "n_rows_padded": 1792, "kind": "logreg"}
      }
    }"#;

    #[test]
    fn parses_entries_and_meta() {
        let m = Manifest::parse(Path::new("/tmp/x"), SAMPLE).unwrap();
        let e = m.get("logreg_grad_a9a").unwrap();
        assert_eq!(e.inputs.len(), 3);
        assert_eq!(e.inputs[0].shape, vec![1792, 123]);
        assert_eq!(e.inputs[0].element_count(), 1792 * 123);
        assert_eq!(e.inputs[2].element_count(), 1); // scalar
        assert_eq!(e.meta_usize("d").unwrap(), 123);
        assert!(e.meta_usize("missing").is_err());
        assert!(m.get("nope").is_err());
        assert_eq!(e.file, Path::new("/tmp/x/logreg_grad_a9a.hlo.txt"));
    }

    #[test]
    fn rejects_malformed() {
        assert!(Manifest::parse(Path::new("."), "[]").is_err());
        assert!(Manifest::parse(Path::new("."), r#"{"a": {}}"#).is_err());
        assert!(Manifest::parse(Path::new("."), "{nope").is_err());
    }
}
