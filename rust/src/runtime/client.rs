//! PJRT client wrapper: compile-once / execute-many over HLO-text
//! artifacts, with literal conversion helpers. Pattern follows
//! /opt/xla-example/load_hlo (text interchange, `to_tuple*` unwrapping).

use super::manifest::{ArtifactEntry, Manifest};
use anyhow::{Context, Result};
use std::collections::HashMap;
use std::path::Path;
use std::sync::{Arc, Mutex};
use xla::{Literal, PjRtClient, PjRtLoadedExecutable};

/// Compile-cached PJRT runtime over one artifacts directory.
///
/// Shared as `Arc<Runtime>` so XLA-backed oracles satisfy the
/// `GradOracle: Send` bound the parallel runners need; the compile
/// cache is behind a `Mutex` accordingly (touched once per artifact,
/// never on the execute hot path once warm).
pub struct Runtime {
    client: PjRtClient,
    pub manifest: Manifest,
    cache: Mutex<HashMap<String, Arc<PjRtLoadedExecutable>>>,
}

// SAFETY: the PJRT C API requires clients and loaded executables to be
// usable from multiple threads (XLA serializes internally); the Rust
// binding only lacks the auto-impls because it wraps raw pointers. All
// interior mutability on our side is the `Mutex`ed compile cache.
unsafe impl Send for Runtime {}
unsafe impl Sync for Runtime {}

impl Runtime {
    /// CPU PJRT client + manifest from `dir`.
    pub fn new(dir: &Path) -> Result<Runtime> {
        let client = PjRtClient::cpu().context("creating PJRT CPU client")?;
        let manifest = Manifest::load(dir)?;
        Ok(Runtime { client, manifest, cache: Mutex::new(HashMap::new()) })
    }

    /// Default location (`$EF21_ARTIFACTS` or `./artifacts`).
    pub fn from_default_dir() -> Result<Runtime> {
        Self::new(&super::manifest::default_dir())
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile an artifact (cached).
    pub fn executable(&self, name: &str) -> Result<Arc<PjRtLoadedExecutable>> {
        if let Some(exe) = self.cache.lock().unwrap().get(name) {
            return Ok(exe.clone());
        }
        let entry = self.manifest.get(name)?;
        let proto = xla::HloModuleProto::from_text_file(
            entry.file.to_str().context("non-utf8 artifact path")?,
        )
        .with_context(|| format!("parsing HLO text {}", entry.file.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling artifact {name}"))?;
        let exe = Arc::new(exe);
        self.cache.lock().unwrap().insert(name.to_string(), exe.clone());
        Ok(exe)
    }

    pub fn entry(&self, name: &str) -> Result<&ArtifactEntry> {
        self.manifest.get(name)
    }

    /// Execute an artifact; all our artifacts return a tuple, which is
    /// decomposed into its elements. Accepts owned or borrowed literals
    /// (`&[Literal]` or `&[&Literal]`) so cached inputs are not copied.
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<Literal>> {
        let exe = self.executable(name)?;
        let entry = self.manifest.get(name)?;
        anyhow::ensure!(
            inputs.len() == entry.inputs.len(),
            "artifact {name}: expected {} inputs, got {}",
            entry.inputs.len(),
            inputs.len()
        );
        let result = exe
            .execute::<L>(inputs)
            .with_context(|| format!("executing artifact {name}"))?[0][0]
            .to_literal_sync()?;
        Ok(result.to_tuple()?)
    }

    /// Execute and convert every output to an f32 vector.
    pub fn execute_f32<L: std::borrow::Borrow<Literal>>(
        &self,
        name: &str,
        inputs: &[L],
    ) -> Result<Vec<Vec<f32>>> {
        self.execute(name, inputs)?
            .into_iter()
            .map(|l| l.to_vec::<f32>().map_err(Into::into))
            .collect()
    }
}

// ---------------------------------------------------------------------------
// Literal helpers
// ---------------------------------------------------------------------------

/// 1-D f32 literal from an f64 slice (wire precision is f32 everywhere).
pub fn lit_f32_1d(v: &[f64]) -> Literal {
    let v32: Vec<f32> = v.iter().map(|&x| x as f32).collect();
    Literal::vec1(&v32)
}

/// 1-D f32 literal from f32 data.
pub fn lit_f32_1d_exact(v: &[f32]) -> Literal {
    Literal::vec1(v)
}

/// Row-major (rows, cols) f32 literal.
pub fn lit_f32_2d(v: &[f32], rows: usize, cols: usize) -> Result<Literal> {
    anyhow::ensure!(v.len() == rows * cols, "shape mismatch");
    Ok(Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

/// Row-major (rows, cols) i32 literal.
pub fn lit_i32_2d(v: &[i32], rows: usize, cols: usize) -> Result<Literal> {
    anyhow::ensure!(v.len() == rows * cols, "shape mismatch");
    Ok(Literal::vec1(v).reshape(&[rows as i64, cols as i64])?)
}

/// Scalar f32 literal.
pub fn lit_f32_scalar(x: f64) -> Literal {
    Literal::scalar(x as f32)
}

/// Extract a scalar f32 from an output literal.
pub fn out_scalar_f32(l: &Literal) -> Result<f64> {
    Ok(l.get_first_element::<f32>()? as f64)
}

/// Extract an f32 vector as f64.
pub fn out_vec_f64(l: &Literal) -> Result<Vec<f64>> {
    Ok(l.to_vec::<f32>()?.into_iter().map(|x| x as f64).collect())
}

#[cfg(test)]
mod tests {
    use super::*;

    // Literal helpers are testable without artifacts; the full runtime path
    // (compile + execute vs the Rust oracle) lives in
    // rust/tests/integration_runtime.rs which requires `make artifacts`.

    #[test]
    fn literal_roundtrips() {
        let l = lit_f32_1d(&[1.0, -2.5, 3.0]);
        assert_eq!(l.to_vec::<f32>().unwrap(), vec![1.0, -2.5, 3.0]);
        let s = lit_f32_scalar(4.25);
        assert_eq!(out_scalar_f32(&s).unwrap(), 4.25);
        let m = lit_f32_2d(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        assert_eq!(m.element_count(), 6);
        let i = lit_i32_2d(&[1, 2, 3, 4], 2, 2).unwrap();
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![1, 2, 3, 4]);
        assert!(lit_f32_2d(&[1.0], 2, 3).is_err());
        let v = out_vec_f64(&lit_f32_1d(&[0.5, 1.5])).unwrap();
        assert_eq!(v, vec![0.5, 1.5]);
    }
}
