//! Pooled scratch buffers for the per-round hot path.
//!
//! The algorithm state machines need short-lived dense vectors every
//! round (gradient targets, EF21+'s two branch candidates). Before the
//! block refactor each of those was a fresh `vec![0.0; d]` per round per
//! worker; a [`Workspace`] keeps returned buffers and hands them back,
//! so steady-state rounds perform zero heap allocation. Buffers are
//! plain `Vec<f64>` — taking one always re-initializes its contents
//! (zeroed or copied), so reuse can never change a computed value.

/// A small LIFO pool of `Vec<f64>` scratch buffers. Not thread-safe by
/// design: each worker owns its workspace, exactly like the rest of its
/// state (the parallel engines move whole workers across threads, never
/// share them).
#[derive(Debug, Default)]
pub struct Workspace {
    pool: Vec<Vec<f64>>,
}

impl Workspace {
    pub fn new() -> Workspace {
        Workspace { pool: Vec::new() }
    }

    fn pop(&mut self) -> Vec<f64> {
        self.pool.pop().unwrap_or_default()
    }

    /// A buffer of length `d`, all zeros.
    pub fn take_zeroed(&mut self, d: usize) -> Vec<f64> {
        let mut b = self.pop();
        b.clear();
        b.resize(d, 0.0);
        b
    }

    /// A buffer holding a copy of `src`.
    pub fn take_copy(&mut self, src: &[f64]) -> Vec<f64> {
        let mut b = self.pop();
        b.clear();
        b.extend_from_slice(src);
        b
    }

    /// Return a buffer to the pool (contents are irrelevant; the next
    /// take re-initializes).
    pub fn put(&mut self, buf: Vec<f64>) {
        self.pool.push(buf);
    }

    /// Buffers currently pooled (tests / introspection).
    pub fn pooled(&self) -> usize {
        self.pool.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn buffers_are_recycled_not_reallocated() {
        let mut ws = Workspace::new();
        let b = ws.take_zeroed(8);
        let ptr = b.as_ptr();
        ws.put(b);
        assert_eq!(ws.pooled(), 1);
        let b2 = ws.take_zeroed(8);
        assert_eq!(b2.as_ptr(), ptr, "same allocation must come back");
        assert!(b2.iter().all(|&x| x == 0.0));
        assert_eq!(ws.pooled(), 0);
    }

    #[test]
    fn take_reinitializes_contents() {
        let mut ws = Workspace::new();
        let mut b = ws.take_zeroed(4);
        b.copy_from_slice(&[1.0, 2.0, 3.0, 4.0]);
        ws.put(b);
        assert_eq!(ws.take_copy(&[9.0, 8.0]), vec![9.0, 8.0]);
        ws.put(vec![5.0; 3]);
        assert_eq!(ws.take_zeroed(5), vec![0.0; 5]);
    }
}
