//! Block-partitioned parameter layout (the DL experiments of §5 / Fig. 5
//! compress layer-by-layer; this module gives the whole pipeline that
//! structure).
//!
//! A [`BlockLayout`] partitions the flat parameter space `0..d` into
//! contiguous named blocks (`BlockSpec { name, offset, len }`). A
//! [`ParamBlocks`] is a flat `Vec<f64>` backing buffer viewed through such
//! a layout — `blocks = 1` degenerates to today's flat vector, and every
//! consumer (compressors, algorithm state, the broadcast codec) treats
//! that case as the exact legacy path, so flat runs stay bit-identical.
//!
//! Two more pieces live here because every layer shares them:
//!
//! * [`Workspace`] — a pooled-buffer allocator for per-round scratch
//!   vectors (gradient buffers, EF21+ branch candidates), replacing
//!   per-round `vec![0.0; d]` allocations on the hot path.
//! * [`scatter_add_blocked`] — the master-side worker×block aggregation
//!   tile: disjoint block ranges of the target are updated on separate
//!   threads while, **within each coordinate**, messages are applied in
//!   worker-index order — exactly the sequential order, so the result is
//!   bit-identical to the legacy per-message loop (DESIGN.md §5).

pub mod workspace;

pub use workspace::Workspace;

use crate::compress::SparseVec;
use anyhow::{bail, ensure, Result};
use std::sync::Arc;

/// One contiguous block of the flat parameter vector.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockSpec {
    /// Human-readable name ("all", "b3", "l0.w_qkv", ...) — used in
    /// telemetry keys (`compress.<spec>.<name>.*`).
    pub name: String,
    /// First coordinate of the block.
    pub offset: usize,
    /// Number of coordinates (>= 1).
    pub len: usize,
}

impl BlockSpec {
    /// Coordinate range `[offset, offset + len)`.
    pub fn range(&self) -> std::ops::Range<usize> {
        self.offset..self.offset + self.len
    }
}

/// A contiguous, gap-free partition of `0..d` into named blocks.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct BlockLayout {
    specs: Vec<BlockSpec>,
    d: usize,
}

impl BlockLayout {
    /// Build from explicit specs; validates the partition (ascending
    /// contiguous offsets starting at 0, every block non-empty).
    pub fn new(specs: Vec<BlockSpec>) -> Result<BlockLayout> {
        ensure!(!specs.is_empty(), "block layout needs at least one block");
        let mut next = 0usize;
        for (i, s) in specs.iter().enumerate() {
            ensure!(s.len >= 1, "block {i} ('{}') is empty", s.name);
            ensure!(
                s.offset == next,
                "block {i} ('{}') starts at {} but previous block ends at {next}",
                s.name,
                s.offset
            );
            next += s.len;
        }
        Ok(BlockLayout { specs, d: next })
    }

    /// The degenerate single-block layout — today's flat vector.
    pub fn flat(d: usize) -> BlockLayout {
        assert!(d >= 1, "flat layout needs d >= 1");
        BlockLayout {
            specs: vec![BlockSpec { name: "all".into(), offset: 0, len: d }],
            d,
        }
    }

    /// Balanced contiguous split into `n_blocks` blocks named `b0..`,
    /// mirroring the worker-chunking rule of `coordinator::par` (the
    /// first `d % n_blocks` blocks take one extra coordinate).
    pub fn equal(n_blocks: usize, d: usize) -> Result<BlockLayout> {
        ensure!(n_blocks >= 1, "need at least one block");
        ensure!(
            n_blocks <= d,
            "cannot split d={d} coordinates into {n_blocks} non-empty blocks"
        );
        let base = d / n_blocks;
        let rem = d % n_blocks;
        let mut specs = Vec::with_capacity(n_blocks);
        let mut offset = 0;
        for b in 0..n_blocks {
            let len = base + usize::from(b < rem);
            specs.push(BlockSpec { name: format!("b{b}"), offset, len });
            offset += len;
        }
        BlockLayout::new(specs)
    }

    /// Build from `(name, len)` pairs in order (e.g. a transformer's
    /// per-parameter shapes flattened to lengths).
    pub fn from_named(parts: &[(String, usize)]) -> Result<BlockLayout> {
        let mut specs = Vec::with_capacity(parts.len());
        let mut offset = 0;
        for (name, len) in parts {
            specs.push(BlockSpec { name: name.clone(), offset, len: *len });
            offset += len;
        }
        BlockLayout::new(specs)
    }

    /// Parse a `--blocks` layout spec against dimension `d`:
    /// `"flat"` / `"1"` → single block; `"<n>"` → [`BlockLayout::equal`];
    /// `"name:len,name:len,..."` → [`BlockLayout::from_named`] (lengths
    /// must sum to `d`). `"auto"` is resolved by the caller (it needs the
    /// oracle's natural layout) — see `config::BlocksSpec`.
    pub fn parse(spec: &str, d: usize) -> Result<BlockLayout> {
        let s = spec.trim();
        if s.is_empty() || s.eq_ignore_ascii_case("flat") {
            return Ok(BlockLayout::flat(d));
        }
        if let Ok(n) = s.parse::<usize>() {
            // "0" is an error here too, so this grammar and the CLI's
            // `config::BlocksSpec` can never drift on it.
            ensure!(n >= 1, "--blocks 0: need at least one block");
            return if n == 1 { Ok(BlockLayout::flat(d)) } else { BlockLayout::equal(n, d) };
        }
        if s.contains(':') {
            let mut parts = Vec::new();
            for item in s.split(',') {
                let Some((name, len)) = item.split_once(':') else {
                    bail!("bad --blocks item '{item}' (expected name:len)");
                };
                let len: usize = len
                    .trim()
                    .parse()
                    .map_err(|_| anyhow::anyhow!("bad block length in '{item}'"))?;
                parts.push((name.trim().to_string(), len));
            }
            let layout = BlockLayout::from_named(&parts)?;
            ensure!(
                layout.d() == d,
                "--blocks lengths sum to {} but the problem has d={d}",
                layout.d()
            );
            return Ok(layout);
        }
        bail!("bad --blocks spec '{spec}' (flat | auto | <n> | name:len,...)")
    }

    pub fn d(&self) -> usize {
        self.d
    }

    pub fn n_blocks(&self) -> usize {
        self.specs.len()
    }

    /// `true` for the single-block layout — the exact legacy flat path.
    pub fn is_flat(&self) -> bool {
        self.specs.len() == 1
    }

    pub fn specs(&self) -> &[BlockSpec] {
        &self.specs
    }

    pub fn spec(&self, b: usize) -> &BlockSpec {
        &self.specs[b]
    }

    /// Slice `v` (length `d`) down to block `b`.
    pub fn slice<'a>(&self, b: usize, v: &'a [f64]) -> &'a [f64] {
        &v[self.specs[b].range()]
    }

    /// Split a full-length mutable slice into per-block mutable slices
    /// (in block order) — the aliasing-free basis of the block-parallel
    /// aggregation tile.
    pub fn split_mut<'a>(&self, v: &'a mut [f64]) -> Vec<&'a mut [f64]> {
        assert_eq!(v.len(), self.d);
        let mut out = Vec::with_capacity(self.specs.len());
        let mut rest: &'a mut [f64] = v;
        for s in &self.specs {
            // mem::take moves the remainder out so the split borrows
            // carry the full 'a lifetime (plain re-slicing would only
            // reborrow).
            let (head, tail) = std::mem::take(&mut rest).split_at_mut(s.len);
            out.push(head);
            rest = tail;
        }
        out
    }
}

/// A flat `f64` backing buffer viewed through a [`BlockLayout`]. The
/// algorithms keep their Markov/error state in this type so per-block
/// passes (compression, distortion accounting, aggregation) never copy.
#[derive(Clone, Debug)]
pub struct ParamBlocks {
    data: Vec<f64>,
    layout: Arc<BlockLayout>,
}

impl ParamBlocks {
    /// Zero-initialized state over `layout`.
    pub fn zeros(layout: Arc<BlockLayout>) -> ParamBlocks {
        let d = layout.d();
        ParamBlocks { data: vec![0.0; d], layout }
    }

    /// Adopt an existing flat vector (length must match the layout).
    pub fn from_flat(data: Vec<f64>, layout: Arc<BlockLayout>) -> ParamBlocks {
        assert_eq!(data.len(), layout.d());
        ParamBlocks { data, layout }
    }

    pub fn layout(&self) -> &Arc<BlockLayout> {
        &self.layout
    }

    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    pub fn as_mut_slice(&mut self) -> &mut [f64] {
        &mut self.data
    }

    /// The flat backing buffer, by value (consumes self).
    pub fn into_flat(self) -> Vec<f64> {
        self.data
    }

    /// Swap the backing buffer with another full-length vector — the
    /// allocation-free way to adopt a workspace buffer as the new state
    /// (EF21+'s winning branch) while recycling the old one.
    pub fn swap_flat(&mut self, other: &mut Vec<f64>) {
        assert_eq!(other.len(), self.layout.d());
        std::mem::swap(&mut self.data, other);
    }

    /// `out = other - self`, computed block by block — the EF21-family
    /// Markov-difference kernel (`∇f_i - g_i`). Blocks are contiguous
    /// and ascending, so the element order — and hence every f64 —
    /// matches the flat loop exactly; one shared kernel keeps the
    /// bit-identity argument in one place instead of per algorithm.
    pub fn sub_from_into(&self, other: &[f64], out: &mut [f64]) {
        assert_eq!(other.len(), self.data.len());
        assert_eq!(out.len(), self.data.len());
        for spec in self.layout.specs() {
            let r = spec.range();
            let s = &self.data[r.clone()];
            let o = &other[r.clone()];
            let dst = &mut out[r];
            for j in 0..s.len() {
                dst[j] = o[j] - s[j];
            }
        }
    }

    /// `out = self + scale * other`, block by block — EF's
    /// error-compensated message kernel (`e_i + γ ∇f_i`). Same
    /// element-order guarantee as [`Self::sub_from_into`].
    pub fn affine_into(&self, scale: f64, other: &[f64], out: &mut [f64]) {
        assert_eq!(other.len(), self.data.len());
        assert_eq!(out.len(), self.data.len());
        for spec in self.layout.specs() {
            let r = spec.range();
            let s = &self.data[r.clone()];
            let o = &other[r.clone()];
            let dst = &mut out[r];
            for j in 0..s.len() {
                dst[j] = s[j] + scale * o[j];
            }
        }
    }

    pub fn block(&self, b: usize) -> &[f64] {
        &self.data[self.layout.spec(b).range()]
    }

    pub fn block_mut(&mut self, b: usize) -> &mut [f64] {
        let r = self.layout.spec(b).range();
        &mut self.data[r]
    }
}

/// Dimension floor below which the block-parallel tile paths run inline
/// — under it, the scoped-thread fan-out costs more than the work. One
/// constant for both halves of the worker×block tile (aggregation here,
/// compression in [`crate::compress::BlockCompressor`]), so they engage
/// threading at the same scale.
pub const PAR_MIN_DIM: usize = 1 << 14;

/// Execute `f(item)` over every item, fanned across at most `threads`
/// scoped threads in contiguous chunks (`threads <= 1` runs inline).
/// Items must be independent (each is processed exactly once and
/// carries its own output target), so chunk scheduling cannot change
/// any result — the one chunked-scope harness behind both halves of
/// the worker×block tile.
pub fn run_chunked<T: Send>(items: Vec<T>, threads: usize, f: impl Fn(T) + Send + Sync) {
    let threads = threads.max(1).min(items.len());
    if threads <= 1 {
        for it in items {
            f(it);
        }
        return;
    }
    // ceil(len / threads) without div_ceil (MSRV 1.70).
    let per = (items.len() + threads - 1) / threads;
    let mut rest = items;
    std::thread::scope(|scope| {
        let f = &f;
        while !rest.is_empty() {
            let take = per.min(rest.len());
            let chunk: Vec<T> = rest.drain(..take).collect();
            scope.spawn(move || {
                for it in chunk {
                    f(it);
                }
            });
        }
    });
}

/// `target += scale * msg` for every message, tiled across blocks.
///
/// Per coordinate, contributions are applied in message (= worker-index)
/// order exactly as the legacy sequential loop does; blocks touch
/// disjoint coordinates, so distributing blocks across threads cannot
/// change any individual f64 sum — the result is **bit-identical** to
/// the sequential absorb at any thread count. `threads <= 1` (or a flat
/// layout, or `d` below [`PAR_MIN_DIM`]) runs the same per-block loops
/// inline.
pub fn scatter_add_blocked(
    target: &mut [f64],
    layout: &BlockLayout,
    msgs: &[&SparseVec],
    scale: f64,
    threads: usize,
) {
    fn apply(spec: &BlockSpec, out: &mut [f64], msgs: &[&SparseVec], scale: f64) {
        let lo = spec.offset as u32;
        let hi = (spec.offset + spec.len) as u32;
        for s in msgs {
            for e in s.entry_range(lo, hi) {
                out[s.idx[e] as usize - spec.offset] += scale * s.val[e];
            }
        }
    }

    let width = if layout.is_flat() || layout.d() < PAR_MIN_DIM { 1 } else { threads };
    let tiles: Vec<(&BlockSpec, &mut [f64])> =
        layout.specs().iter().zip(layout.split_mut(target)).collect();
    run_chunked(tiles, width, |(spec, out)| apply(spec, out, msgs, scale));
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn flat_layout_is_single_full_block() {
        let l = BlockLayout::flat(7);
        assert!(l.is_flat());
        assert_eq!(l.n_blocks(), 1);
        assert_eq!(l.d(), 7);
        assert_eq!(l.spec(0).range(), 0..7);
        assert_eq!(l.spec(0).name, "all");
    }

    #[test]
    fn equal_split_is_balanced_and_contiguous() {
        let l = BlockLayout::equal(3, 10).unwrap();
        let lens: Vec<usize> = l.specs().iter().map(|s| s.len).collect();
        assert_eq!(lens, vec![4, 3, 3]);
        assert_eq!(l.spec(1).offset, 4);
        assert_eq!(l.spec(2).offset, 7);
        assert!(BlockLayout::equal(11, 10).is_err());
        assert!(BlockLayout::equal(0, 10).is_err());
    }

    #[test]
    fn named_layout_and_validation() {
        let l = BlockLayout::from_named(&[
            ("emb".into(), 6),
            ("head".into(), 2),
        ])
        .unwrap();
        assert_eq!(l.d(), 8);
        assert_eq!(l.spec(1).name, "head");
        // Gap / overlap / empty are rejected.
        assert!(BlockLayout::new(vec![
            BlockSpec { name: "a".into(), offset: 1, len: 2 },
        ])
        .is_err());
        assert!(BlockLayout::new(vec![
            BlockSpec { name: "a".into(), offset: 0, len: 0 },
        ])
        .is_err());
        assert!(BlockLayout::new(vec![
            BlockSpec { name: "a".into(), offset: 0, len: 2 },
            BlockSpec { name: "b".into(), offset: 3, len: 1 },
        ])
        .is_err());
    }

    #[test]
    fn parse_specs() {
        assert!(BlockLayout::parse("flat", 9).unwrap().is_flat());
        assert!(BlockLayout::parse("1", 9).unwrap().is_flat());
        assert_eq!(BlockLayout::parse("3", 9).unwrap().n_blocks(), 3);
        let l = BlockLayout::parse("a:4,b:5", 9).unwrap();
        assert_eq!(l.n_blocks(), 2);
        assert_eq!(l.spec(1).offset, 4);
        assert!(BlockLayout::parse("a:4,b:4", 9).is_err()); // sums to 8
        assert!(BlockLayout::parse("wat", 9).is_err());
        assert!(BlockLayout::parse("99", 9).is_err()); // more blocks than d
        assert!(BlockLayout::parse("0", 9).is_err());
    }

    #[test]
    fn param_blocks_views() {
        let layout = Arc::new(BlockLayout::equal(2, 5).unwrap());
        let mut p = ParamBlocks::zeros(layout.clone());
        p.block_mut(1)[0] = 2.5;
        assert_eq!(p.as_slice(), &[0.0, 0.0, 0.0, 2.5, 0.0]);
        assert_eq!(p.block(0), &[0.0, 0.0, 0.0]);
        assert_eq!(p.block(1), &[2.5, 0.0]);
        let back = ParamBlocks::from_flat(p.into_flat(), layout);
        assert_eq!(back.block(1), &[2.5, 0.0]);
    }

    #[test]
    fn split_mut_covers_everything_once() {
        let layout = BlockLayout::equal(3, 7).unwrap();
        let mut v = vec![0.0; 7];
        {
            let mut parts = layout.split_mut(&mut v);
            for (b, p) in parts.iter_mut().enumerate() {
                for x in p.iter_mut() {
                    *x = b as f64;
                }
            }
        }
        assert_eq!(v, vec![0.0, 0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
    }

    #[test]
    fn scatter_add_matches_sequential_any_width() {
        let d = 50;
        let layout = BlockLayout::equal(7, d).unwrap();
        let m1 = SparseVec::new(vec![0, 3, 20, 49], vec![1.0, -2.0, 0.5, 4.0]);
        let m2 = SparseVec::new(vec![3, 21, 22], vec![10.0, 1.0, -1.0]);
        // Legacy order: per message, add_scaled_into over the whole vector.
        let mut want = vec![0.1; d];
        m1.add_scaled_into(0.25, &mut want);
        m2.add_scaled_into(0.25, &mut want);
        for threads in [1, 3, 8] {
            let mut got = vec![0.1; d];
            scatter_add_blocked(&mut got, &layout, &[&m1, &m2], 0.25, threads);
            for (a, b) in want.iter().zip(&got) {
                assert_eq!(a.to_bits(), b.to_bits(), "threads={threads}");
            }
        }
        // Flat layout takes the same code path result-wise.
        let flat = BlockLayout::flat(d);
        let mut got = vec![0.1; d];
        scatter_add_blocked(&mut got, &flat, &[&m1, &m2], 0.25, 4);
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }

    /// The per-block kernels compute the same f64s in the same order as
    /// the plain flat loops — the bit-identity contract every algorithm
    /// leans on.
    #[test]
    fn block_kernels_match_flat_loops_bitwise() {
        let d = 23;
        let layout = Arc::new(BlockLayout::equal(5, d).unwrap());
        let mut rng = crate::util::rng::Rng::seed(8);
        let base: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
        let other: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
        let p = ParamBlocks::from_flat(base.clone(), layout);

        let mut got = vec![0.0; d];
        p.sub_from_into(&other, &mut got);
        for j in 0..d {
            assert_eq!(got[j].to_bits(), (other[j] - base[j]).to_bits());
        }
        p.affine_into(0.37, &other, &mut got);
        for j in 0..d {
            assert_eq!(got[j].to_bits(), (base[j] + 0.37 * other[j]).to_bits());
        }
    }

    /// run_chunked processes every item exactly once at any width.
    #[test]
    fn run_chunked_covers_all_items_once() {
        for threads in [1usize, 2, 3, 7, 16] {
            let hits: Vec<std::sync::atomic::AtomicU32> =
                (0..11).map(|_| std::sync::atomic::AtomicU32::new(0)).collect();
            let items: Vec<usize> = (0..11).collect();
            run_chunked(items, threads, |i| {
                hits[i].fetch_add(1, std::sync::atomic::Ordering::Relaxed);
            });
            for (i, h) in hits.iter().enumerate() {
                assert_eq!(
                    h.load(std::sync::atomic::Ordering::Relaxed),
                    1,
                    "item {i} at width {threads}"
                );
            }
        }
    }

    /// Exercise the genuinely threaded tile path (d above PAR_MIN_DIM):
    /// result must still match the sequential per-message loop bit for
    /// bit.
    #[test]
    fn scatter_add_threaded_path_matches_sequential() {
        let d = 1 << 15;
        let layout = BlockLayout::equal(16, d).unwrap();
        let mut rng = crate::util::rng::Rng::seed(3);
        let msgs: Vec<SparseVec> = (0..5)
            .map(|_| {
                let idx = rng.sample_indices(d, 400);
                let val: Vec<f64> = idx.iter().map(|_| rng.next_normal()).collect();
                SparseVec::new(idx, val)
            })
            .collect();
        let refs: Vec<&SparseVec> = msgs.iter().collect();
        let mut want = vec![0.5; d];
        for m in &msgs {
            m.add_scaled_into(0.2, &mut want);
        }
        let mut got = vec![0.5; d];
        scatter_add_blocked(&mut got, &layout, &refs, 0.2, 4);
        for (a, b) in want.iter().zip(&got) {
            assert_eq!(a.to_bits(), b.to_bits());
        }
    }
}
