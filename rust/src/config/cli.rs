//! Minimal CLI argument parser: positionals, `--key value` / `--key=value`
//! options, and boolean `--switch`es (a switch is any `--key` not followed
//! by a value-looking token).

use anyhow::{Context, Result};
use std::collections::BTreeMap;

#[derive(Clone, Debug, Default)]
pub struct Args {
    pub positional: Vec<String>,
    options: BTreeMap<String, String>,
    switches: Vec<String>,
}

impl Args {
    /// Parse from an explicit token vector (tests) — tokens exclude argv[0].
    pub fn from_vec(tokens: Vec<String>) -> Args {
        let mut args = Args::default();
        let mut i = 0;
        while i < tokens.len() {
            let t = &tokens[i];
            if let Some(stripped) = t.strip_prefix("--") {
                if let Some((k, v)) = stripped.split_once('=') {
                    args.options.insert(k.to_string(), v.to_string());
                } else if i + 1 < tokens.len() && !tokens[i + 1].starts_with("--") {
                    args.options.insert(stripped.to_string(), tokens[i + 1].clone());
                    i += 1;
                } else {
                    args.switches.push(stripped.to_string());
                }
            } else {
                args.positional.push(t.clone());
            }
            i += 1;
        }
        args
    }

    /// Parse from the process environment (skips argv[0]).
    pub fn from_env() -> Args {
        Self::from_vec(std::env::args().skip(1).collect())
    }

    pub fn get_str(&self, key: &str) -> Option<&str> {
        self.options.get(key).map(|s| s.as_str())
    }

    pub fn has(&self, switch: &str) -> bool {
        self.switches.iter().any(|s| s == switch)
    }

    /// Parse an option into any FromStr type; None if absent, Err if
    /// present but malformed.
    pub fn get_parse<T: std::str::FromStr>(&self, key: &str) -> Result<Option<T>>
    where
        T::Err: std::fmt::Display,
    {
        match self.options.get(key) {
            None => Ok(None),
            Some(v) => v
                .parse::<T>()
                .map(Some)
                .map_err(|e| anyhow::anyhow!("--{key} {v}: {e}")),
        }
    }

    /// Required positional argument.
    pub fn pos(&self, i: usize, what: &str) -> Result<&str> {
        self.positional
            .get(i)
            .map(|s| s.as_str())
            .with_context(|| format!("missing positional argument <{what}>"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn v(s: &[&str]) -> Vec<String> {
        s.iter().map(|x| x.to_string()).collect()
    }

    #[test]
    fn parses_mixture() {
        let a = Args::from_vec(v(&[
            "exp", "stepsize", "--dataset", "a9a", "--k=2", "--full", "--rounds", "100",
        ]));
        assert_eq!(a.positional, vec!["exp", "stepsize"]);
        assert_eq!(a.get_str("dataset"), Some("a9a"));
        assert_eq!(a.get_str("k"), Some("2"));
        assert!(a.has("full"));
        assert_eq!(a.get_parse::<usize>("rounds").unwrap(), Some(100));
        assert_eq!(a.pos(0, "cmd").unwrap(), "exp");
        assert!(a.pos(5, "nope").is_err());
    }

    #[test]
    fn switch_followed_by_flag_stays_switch() {
        let a = Args::from_vec(v(&["--verbose", "--k", "3"]));
        assert!(a.has("verbose"));
        assert_eq!(a.get_str("k"), Some("3"));
    }

    #[test]
    fn parse_errors_are_reported() {
        let a = Args::from_vec(v(&["--rounds", "NaNrounds"]));
        assert!(a.get_parse::<usize>("rounds").is_err());
    }

    #[test]
    fn negative_number_is_treated_as_value() {
        // "-5" doesn't start with --, so it's a value.
        let a = Args::from_vec(v(&["--offset", "-5"]));
        assert_eq!(a.get_parse::<i32>("offset").unwrap(), Some(-5));
    }
}
