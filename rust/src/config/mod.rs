//! Configuration: a small CLI argument parser (clap is not vendored) and
//! the experiment configuration type shared by the launcher and the
//! experiment harness.

pub mod cli;

use crate::algo::AlgoSpec;
use anyhow::Result;

/// `--threads` spec: how wide the in-process pools run — both the
/// per-round worker pool ([`crate::coordinator::par`]) and the sweep
/// trial scheduler ([`crate::exp::parallel_trials`]).
///
/// `auto` (the default) uses every available core; an explicit `1` is
/// the exact legacy sequential path. Results are bit-identical either
/// way for deterministic algorithms — the knob trades wall-clock only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Threads {
    Auto,
    Fixed(usize),
}

impl Default for Threads {
    fn default() -> Self {
        Threads::Auto
    }
}

impl Threads {
    pub fn parse(s: &str) -> Result<Threads> {
        let s = s.trim().to_ascii_lowercase();
        if s == "auto" {
            return Ok(Threads::Auto);
        }
        let n: usize = s
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads {s}: expected 'auto' or a positive count"))?;
        anyhow::ensure!(n >= 1, "--threads 0: need at least one thread (1 = sequential)");
        Ok(Threads::Fixed(n))
    }

    /// Read `--threads` from parsed args (absent = `auto`).
    pub fn from_args(args: &cli::Args) -> Result<Threads> {
        match args.get_str("threads") {
            Some(s) => Threads::parse(s),
            None => Ok(Threads::Auto),
        }
    }

    /// Concrete pool width.
    pub fn resolve(self) -> usize {
        match self {
            Threads::Auto => crate::coordinator::auto_threads(),
            Threads::Fixed(n) => n.max(1),
        }
    }
}

/// One fully-specified training run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub algo: AlgoSpec,
    /// Compressor spec string ("top1", "rand8", "sign", "identity").
    pub compressor: String,
    pub dataset: String,
    pub n_workers: usize,
    /// Stepsize multiplier over the Theorem-1/2 prediction.
    pub gamma_mult: f64,
    /// Absolute stepsize override (None = theory * gamma_mult).
    pub gamma_abs: Option<f64>,
    pub rounds: usize,
    pub lam: f64,
    pub seed: u64,
    /// Record every k rounds.
    pub record_every: usize,
    /// Telemetry sink spec: `off`, `jsonl:<path>`, `tcp:<port>`, or a
    /// comma-separated combination. Carried for library consumers, who
    /// pass it to [`crate::telemetry::init_from_spec`]; the CLI reads
    /// the same `--telemetry` flag directly in `main::dispatch` (before
    /// any subcommand parses a RunSpec).
    pub telemetry: String,
    /// Pool width for the parallel runner / trial scheduler
    /// (`--threads n|auto`; `Fixed(1)` = exact legacy sequential path).
    pub threads: Threads,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            algo: AlgoSpec::Ef21,
            compressor: "top1".into(),
            dataset: "a9a".into(),
            n_workers: 20,
            gamma_mult: 1.0,
            gamma_abs: None,
            rounds: 2000,
            lam: 0.1,
            seed: 0,
            record_every: 1,
            telemetry: "off".into(),
            threads: Threads::Auto,
        }
    }
}

impl RunSpec {
    /// Populate from parsed CLI args (only recognized keys are consumed).
    pub fn from_args(args: &cli::Args) -> Result<RunSpec> {
        let mut s = RunSpec::default();
        if let Some(a) = args.get_str("algo") {
            s.algo = AlgoSpec::parse(a)?;
        }
        if let Some(c) = args.get_str("compressor") {
            s.compressor = c.to_string();
        }
        if let Some(k) = args.get_str("k") {
            s.compressor = format!("top{k}");
        }
        if let Some(d) = args.get_str("dataset") {
            s.dataset = d.to_string();
        }
        s.n_workers = args.get_parse("workers")?.unwrap_or(s.n_workers);
        s.gamma_mult = args.get_parse("gamma-mult")?.unwrap_or(s.gamma_mult);
        s.gamma_abs = args.get_parse("gamma")?;
        s.rounds = args.get_parse("rounds")?.unwrap_or(s.rounds);
        s.lam = args.get_parse("lam")?.unwrap_or(s.lam);
        s.seed = args.get_parse("seed")?.unwrap_or(s.seed);
        s.record_every = args.get_parse("record-every")?.unwrap_or(s.record_every);
        if let Some(t) = args.get_str("telemetry") {
            s.telemetry = t.to_string();
        }
        s.threads = Threads::from_args(args)?;
        Ok(s)
    }

    pub fn label(&self) -> String {
        format!(
            "{} {} {}x {}",
            self.algo.name(),
            self.compressor,
            self.gamma_mult,
            self.dataset
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_args_overrides_defaults() {
        let args = cli::Args::from_vec(vec![
            "--algo".into(),
            "ef".into(),
            "--k".into(),
            "4".into(),
            "--rounds=50".into(),
            "--gamma-mult".into(),
            "8".into(),
        ]);
        let s = RunSpec::from_args(&args).unwrap();
        assert_eq!(s.algo, AlgoSpec::Ef);
        assert_eq!(s.compressor, "top4");
        assert_eq!(s.rounds, 50);
        assert_eq!(s.gamma_mult, 8.0);
        assert_eq!(s.n_workers, 20); // default kept
        assert_eq!(s.telemetry, "off"); // default kept
        assert_eq!(s.threads, Threads::Auto); // default kept
    }

    #[test]
    fn threads_spec_parses_and_rejects() {
        assert_eq!(Threads::parse("auto").unwrap(), Threads::Auto);
        assert_eq!(Threads::parse("4").unwrap(), Threads::Fixed(4));
        assert_eq!(Threads::Fixed(3).resolve(), 3);
        assert!(Threads::Auto.resolve() >= 1);
        assert!(Threads::parse("0").is_err());
        assert!(Threads::parse("many").is_err());
        let args = cli::Args::from_vec(vec!["--threads".into(), "2".into()]);
        let s = RunSpec::from_args(&args).unwrap();
        assert_eq!(s.threads, Threads::Fixed(2));
    }

    #[test]
    fn telemetry_spec_is_carried() {
        let args = cli::Args::from_vec(vec![
            "--telemetry".into(),
            "jsonl:/tmp/m.jsonl,tcp:9100".into(),
        ]);
        let s = RunSpec::from_args(&args).unwrap();
        assert_eq!(s.telemetry, "jsonl:/tmp/m.jsonl,tcp:9100");
    }

    #[test]
    fn bad_values_error() {
        let args = cli::Args::from_vec(vec!["--rounds".into(), "abc".into()]);
        assert!(RunSpec::from_args(&args).is_err());
    }
}
