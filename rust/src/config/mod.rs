//! Configuration: a small CLI argument parser (clap is not vendored) and
//! the experiment configuration type shared by the launcher and the
//! experiment harness.

pub mod cli;

use crate::algo::AlgoSpec;
use anyhow::Result;

/// `--threads` spec: how wide the in-process pools run — both the
/// per-round worker pool ([`crate::coordinator::par`]) and the sweep
/// trial scheduler ([`crate::exp::parallel_trials`]).
///
/// `auto` (the default) uses every available core; an explicit `1` is
/// the exact legacy sequential path. Results are bit-identical either
/// way for deterministic algorithms — the knob trades wall-clock only.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Threads {
    Auto,
    Fixed(usize),
}

impl Default for Threads {
    fn default() -> Self {
        Threads::Auto
    }
}

impl Threads {
    pub fn parse(s: &str) -> Result<Threads> {
        let s = s.trim().to_ascii_lowercase();
        if s == "auto" {
            return Ok(Threads::Auto);
        }
        let n: usize = s
            .parse()
            .map_err(|_| anyhow::anyhow!("--threads {s}: expected 'auto' or a positive count"))?;
        anyhow::ensure!(n >= 1, "--threads 0: need at least one thread (1 = sequential)");
        Ok(Threads::Fixed(n))
    }

    /// Read `--threads` from parsed args (absent = `auto`).
    pub fn from_args(args: &cli::Args) -> Result<Threads> {
        match args.get_str("threads") {
            Some(s) => Threads::parse(s),
            None => Ok(Threads::Auto),
        }
    }

    /// Concrete pool width.
    pub fn resolve(self) -> usize {
        match self {
            Threads::Auto => crate::coordinator::auto_threads(),
            Threads::Fixed(n) => n.max(1),
        }
    }
}

/// `--blocks` spec: how the parameter space is partitioned for
/// layer-wise compression, per-block algorithm state, and delta
/// broadcast (see `blocks::BlockLayout`).
///
/// `flat` (the default) is the exact legacy single-block path. `auto`
/// resolves to the oracle's natural layout — flat for logreg/lstsq, the
/// real per-layer shapes for the DL transformer. `<n>` splits into `n`
/// balanced contiguous blocks; `name:len,...` gives an explicit named
/// partition.
#[derive(Clone, Debug, PartialEq, Eq)]
pub enum BlocksSpec {
    Flat,
    Auto,
    Count(usize),
    Named(String),
}

impl Default for BlocksSpec {
    fn default() -> Self {
        BlocksSpec::Flat
    }
}

impl BlocksSpec {
    pub fn parse(s: &str) -> Result<BlocksSpec> {
        let t = s.trim();
        // Keywords compare case-insensitively; named partitions keep the
        // user's spelling (block names flow into telemetry keys).
        if t.is_empty() || t.eq_ignore_ascii_case("flat") || t == "1" {
            return Ok(BlocksSpec::Flat);
        }
        if t.eq_ignore_ascii_case("auto") {
            return Ok(BlocksSpec::Auto);
        }
        if let Ok(n) = t.parse::<usize>() {
            anyhow::ensure!(n >= 1, "--blocks 0: need at least one block");
            return Ok(BlocksSpec::Count(n));
        }
        anyhow::ensure!(
            t.contains(':'),
            "--blocks {s}: expected flat, auto, a block count, or name:len,..."
        );
        Ok(BlocksSpec::Named(t.to_string()))
    }

    /// Read `--blocks` from parsed args (absent = `flat`).
    pub fn from_args(args: &cli::Args) -> Result<BlocksSpec> {
        match args.get_str("blocks") {
            Some(s) => BlocksSpec::parse(s),
            None => Ok(BlocksSpec::Flat),
        }
    }

    /// Resolve to a concrete layout for dimension `d`; `auto` takes the
    /// oracle-provided `natural` layout (flat when the problem has no
    /// structure).
    pub fn resolve(
        &self,
        d: usize,
        natural: Option<&crate::blocks::BlockLayout>,
    ) -> Result<std::sync::Arc<crate::blocks::BlockLayout>> {
        use crate::blocks::BlockLayout;
        let layout = match self {
            BlocksSpec::Flat => BlockLayout::flat(d),
            BlocksSpec::Auto => match natural {
                Some(l) => {
                    anyhow::ensure!(l.d() == d, "natural layout d={} vs problem d={d}", l.d());
                    l.clone()
                }
                None => BlockLayout::flat(d),
            },
            BlocksSpec::Count(1) => BlockLayout::flat(d),
            BlocksSpec::Count(n) => BlockLayout::equal(*n, d)?,
            BlocksSpec::Named(s) => BlockLayout::parse(s, d)?,
        };
        Ok(std::sync::Arc::new(layout))
    }
}

/// `--master` spec: which engine drives the master side of a transport
/// run. `threads` (the default) is the lockstep thread-per-connection
/// loop; `reactor` multiplexes every connection through a sharded
/// nonblocking poller (see `coordinator::reactor`) — same wire
/// protocol, same per-round absorb order, bit-identical trajectories.
///
/// Deliberately excluded from the checkpoint fingerprint: the engines
/// are bit-identical by construction (and locked by
/// `tests/integration_fleet.rs`), so a snapshot moves freely between
/// them — same rationale as the `threads` knob.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum MasterEngine {
    #[default]
    Threads,
    Reactor,
}

impl MasterEngine {
    pub fn parse(s: &str) -> Result<MasterEngine> {
        match s.trim().to_ascii_lowercase().as_str() {
            "threads" => Ok(MasterEngine::Threads),
            "reactor" => Ok(MasterEngine::Reactor),
            other => anyhow::bail!("--master {other}: expected 'threads' or 'reactor'"),
        }
    }

    /// Read `--master` from parsed args (absent = `threads`).
    pub fn from_args(args: &cli::Args) -> Result<MasterEngine> {
        match args.get_str("master") {
            Some(s) => MasterEngine::parse(s),
            None => Ok(MasterEngine::Threads),
        }
    }
}

/// `--participation`/`--faults`/`--deadline-ms` spec: the round
/// scheduling configuration (see `crate::sched`). The default —
/// full participation, no faults, no deadline — is the exact legacy
/// protocol and resolves to no scheduler at all.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct SchedSpec {
    pub participation: crate::sched::Participation,
    pub faults: crate::sched::FaultPlan,
    /// Straggler cutoff per round (ms). When unset but straggles are
    /// scheduled, the transport I/O timeout (`--net-timeout-ms` chain)
    /// is used as the deadline floor, so a straggler can never outlast
    /// the connection itself.
    pub deadline_ms: Option<u64>,
}

impl SchedSpec {
    /// Read `--participation`, `--faults`, and `--deadline-ms` from
    /// parsed args (all absent = legacy).
    pub fn from_args(args: &cli::Args) -> Result<SchedSpec> {
        let participation = match args.get_str("participation") {
            Some(s) => crate::sched::Participation::parse(s)?,
            None => crate::sched::Participation::Full,
        };
        let faults = match args.get_str("faults") {
            Some(s) => crate::sched::FaultPlan::parse(s)?,
            None => crate::sched::FaultPlan::none(),
        };
        let deadline_ms = args.get_parse::<u64>("deadline-ms")?;
        Ok(SchedSpec { participation, faults, deadline_ms })
    }

    /// True when this spec cannot change the legacy protocol.
    pub fn is_legacy(&self) -> bool {
        self.participation == crate::sched::Participation::Full
            && self.faults.is_empty()
            && self.deadline_ms.is_none()
    }

    /// Resolve to a concrete scheduler for `n_workers` workers, seeded
    /// by the run seed; `None` = take the exact legacy code path.
    ///
    /// The deadline is exactly `deadline_ms` — in particular, simulated
    /// trajectories depend only on `(spec, seed)`, never on the
    /// network-timeout knob (use [`Self::build_for_transport`] when a
    /// real transport is in play).
    pub fn build(
        &self,
        n_workers: usize,
        seed: u64,
    ) -> Result<Option<std::sync::Arc<crate::sched::Scheduler>>> {
        self.build_with_deadline(n_workers, seed, self.deadline_ms)
    }

    /// [`Self::build`] for runs over a real transport: when straggles
    /// are scheduled and no `--deadline-ms` was given, the transport I/O
    /// timeout becomes the deadline floor, so a straggler's real sleep
    /// can never outlast the connection itself.
    pub fn build_for_transport(
        &self,
        n_workers: usize,
        seed: u64,
    ) -> Result<Option<std::sync::Arc<crate::sched::Scheduler>>> {
        let deadline = self.deadline_ms.or_else(|| {
            if self.faults.has_straggles() {
                crate::transport::tcp::io_timeout().map(|d| d.as_millis() as u64)
            } else {
                None
            }
        });
        self.build_with_deadline(n_workers, seed, deadline)
    }

    fn build_with_deadline(
        &self,
        n_workers: usize,
        seed: u64,
        deadline_ms: Option<u64>,
    ) -> Result<Option<std::sync::Arc<crate::sched::Scheduler>>> {
        if self.is_legacy() {
            return Ok(None);
        }
        let sched = crate::sched::Scheduler::new(
            self.participation,
            self.faults.clone(),
            deadline_ms,
            n_workers,
            seed,
        )?;
        Ok(Some(std::sync::Arc::new(sched)))
    }
}

/// `--checkpoint`/`--checkpoint-every`/`--resume` spec: durable
/// snapshot/restart configuration (see [`crate::ckpt`]). The default —
/// no snapshot path, no resume — is the exact legacy run.
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct CkptSpec {
    /// Snapshot destination (`--checkpoint <path>`); written atomically
    /// (tmp + rename) so a crash mid-write can never corrupt the last
    /// good snapshot.
    pub save_path: Option<std::path::PathBuf>,
    /// Snapshot cadence in rounds (`--checkpoint-every <r>`, default 1:
    /// a snapshot at the end of every round).
    pub every: usize,
    /// Snapshot to resume from (`--resume <path>`).
    pub resume_path: Option<std::path::PathBuf>,
}

impl Default for CkptSpec {
    fn default() -> Self {
        CkptSpec { save_path: None, every: 1, resume_path: None }
    }
}

impl CkptSpec {
    /// Read `--checkpoint`, `--checkpoint-every`, and `--resume` from
    /// parsed args (all absent = legacy: no snapshots, no resume).
    pub fn from_args(args: &cli::Args) -> Result<CkptSpec> {
        let save_path = args.get_str("checkpoint").map(std::path::PathBuf::from);
        anyhow::ensure!(
            save_path.is_some() || args.get_str("checkpoint-every").is_none(),
            "--checkpoint-every needs --checkpoint <path>"
        );
        let every = args.get_parse::<usize>("checkpoint-every")?.unwrap_or(1);
        anyhow::ensure!(every >= 1, "--checkpoint-every 0: need a positive round cadence");
        let resume_path = args.get_str("resume").map(std::path::PathBuf::from);
        Ok(CkptSpec { save_path, every, resume_path })
    }

    /// True when this spec cannot change the legacy run at all.
    pub fn is_legacy(&self) -> bool {
        self.save_path.is_none() && self.resume_path.is_none()
    }

    /// Resolve to runner [`CkptOptions`]: read and decode the resume
    /// snapshot (checksum-verified), check its fingerprint against this
    /// run's identity, and stamp the same fingerprint into any snapshots
    /// the run writes.
    pub fn build(
        &self,
        fingerprint: &str,
    ) -> Result<crate::coordinator::runner::CkptOptions> {
        use crate::coordinator::runner::{CkptOptions, SaveCfg};
        let mut opts = CkptOptions::default();
        if let Some(path) = &self.save_path {
            opts.save = Some(SaveCfg { path: path.clone(), every: self.every.max(1) });
        }
        if let Some(path) = &self.resume_path {
            let ck = crate::ckpt::Checkpoint::read(path)?;
            ck.verify_fingerprint(fingerprint)?;
            opts.resume = Some(ck);
        }
        opts.fingerprint = Some(fingerprint.to_string());
        Ok(opts)
    }
}

/// `--session`/`--chaos`/`--on-worker-loss`/`--min-workers` spec: the
/// self-healing transport session layer (DESIGN.md §13). The default —
/// sessions off, no chaos, abort on worker loss — is the exact legacy
/// wire protocol, byte for byte.
///
/// Deliberately excluded from the checkpoint fingerprint: the session
/// envelope is transport framing, recovery replays the identical logical
/// frame stream, and degradation reuses the scheduler-absence (EF21-PP)
/// semantics the fingerprint already captures via the participation
/// spec. A snapshot moves freely between session-on and session-off
/// runs.
#[derive(Clone, Debug, Default, PartialEq, Eq)]
pub struct NetSpec {
    /// `--session on|off`; `None` = auto (on exactly when chaos or a
    /// non-abort loss policy or a quorum floor needs it).
    pub session: Option<bool>,
    /// `--chaos <spec>` clauses (`reset(w@r)`, `corrupt(w@r)`,
    /// `stall(w,r0..r1,MSms)`, `down(w@r)`), kept raw until build.
    pub chaos: Option<String>,
    /// `--on-worker-loss abort|degrade:<grace_ms>|wait`.
    pub on_loss: crate::coordinator::dist::LossPolicy,
    /// `--min-workers <n>` quorum floor for the degrade policy.
    pub min_workers: Option<usize>,
}

impl NetSpec {
    /// Read the four flags from parsed args (all absent = legacy).
    pub fn from_args(args: &cli::Args) -> Result<NetSpec> {
        use crate::coordinator::dist::LossPolicy;
        let session = match args.get_str("session") {
            None => None,
            Some("on") => Some(true),
            Some("off") => Some(false),
            Some(other) => anyhow::bail!("--session {other}: expected 'on' or 'off'"),
        };
        let chaos = args.get_str("chaos").map(str::to_string);
        let on_loss = match args.get_str("on-worker-loss") {
            None | Some("abort") => LossPolicy::Abort,
            Some("wait") => LossPolicy::Wait,
            Some(s) => match s.strip_prefix("degrade:").map(str::parse::<u64>) {
                Some(Ok(grace_ms)) => LossPolicy::Degrade { grace_ms },
                _ => anyhow::bail!(
                    "--on-worker-loss {s}: expected abort, degrade:<grace_ms>, or wait"
                ),
            },
        };
        let min_workers = args.get_parse::<usize>("min-workers")?;
        let spec = NetSpec { session, chaos, on_loss, min_workers };
        anyhow::ensure!(
            session != Some(false) || !spec.needs_session(),
            "--session off conflicts with --chaos/--on-worker-loss/--min-workers: \
             recovery and degradation both run over sessions"
        );
        Ok(spec)
    }

    /// True when this spec cannot change the legacy wire protocol.
    pub fn is_legacy(&self) -> bool {
        self.session != Some(true)
            && self.chaos.is_none()
            && self.on_loss == crate::coordinator::dist::LossPolicy::Abort
            && self.min_workers.is_none()
    }

    /// Would the resolved spec run with sessions enabled? Auto-enables
    /// when any dependent feature is requested.
    pub fn session_enabled(&self) -> bool {
        self.session.unwrap_or_else(|| self.needs_session())
    }

    /// Some other flag depends on the session layer.
    fn needs_session(&self) -> bool {
        self.chaos.is_some()
            || self.on_loss != crate::coordinator::dist::LossPolicy::Abort
            || self.min_workers.is_some()
    }

    /// Resolve to runner [`crate::coordinator::dist::NetOpts`], parsing
    /// the chaos spec and minting the run's session config (ids and
    /// retry jitter derive from the run seed).
    pub fn build(&self, seed: u64) -> Result<crate::coordinator::dist::NetOpts> {
        let mut net = crate::coordinator::dist::NetOpts::default();
        if let Some(spec) = &self.chaos {
            let plan = crate::transport::chaos::ChaosPlan::parse(spec)?;
            if !plan.is_empty() {
                net.chaos = Some(std::sync::Arc::new(plan));
            }
        }
        net.on_loss = self.on_loss;
        net.min_workers = self.min_workers;
        if self.session_enabled() {
            net.session = Some(crate::transport::session::SessionCfg::new(seed));
        }
        Ok(net)
    }
}

/// Read `--net-timeout-ms` (0 = disable I/O timeouts). The caller
/// installs it process-wide via
/// [`crate::transport::tcp::set_default_io_timeout_ms`]; when absent the
/// env chain (`EF21_NET_TIMEOUT_MS`, then the legacy
/// `EF21_TCP_TIMEOUT_SECS`) applies.
pub fn net_timeout_ms_from_args(args: &cli::Args) -> Result<Option<u64>> {
    args.get_parse::<u64>("net-timeout-ms")
}

/// One fully-specified training run.
#[derive(Clone, Debug)]
pub struct RunSpec {
    pub algo: AlgoSpec,
    /// Compressor spec string ("top1", "rand8", "sign", "identity").
    pub compressor: String,
    pub dataset: String,
    pub n_workers: usize,
    /// Stepsize multiplier over the Theorem-1/2 prediction.
    pub gamma_mult: f64,
    /// Absolute stepsize override (None = theory * gamma_mult).
    pub gamma_abs: Option<f64>,
    pub rounds: usize,
    pub lam: f64,
    pub seed: u64,
    /// Record every k rounds.
    pub record_every: usize,
    /// Telemetry sink spec: `off`, `jsonl:<path>`, `tcp:<port>`, or a
    /// comma-separated combination. Carried for library consumers, who
    /// pass it to [`crate::telemetry::init_from_spec`]; the CLI reads
    /// the same `--telemetry` flag directly in `main::dispatch` (before
    /// any subcommand parses a RunSpec).
    pub telemetry: String,
    /// Pool width for the parallel runner / trial scheduler
    /// (`--threads n|auto`; `Fixed(1)` = exact legacy sequential path).
    pub threads: Threads,
    /// Parameter-space partition (`--blocks flat|auto|<n>|name:len,...`;
    /// `Flat` = exact legacy single-block path).
    pub blocks: BlocksSpec,
    /// Round participation/fault schedule (`--participation`, `--faults`,
    /// `--deadline-ms`; the default is the exact legacy protocol).
    pub sched: SchedSpec,
    /// Transport-run master engine (`--master threads|reactor`;
    /// `Threads` = exact legacy thread-per-connection loop). Not part of
    /// the fingerprint: the engines are bit-identical.
    pub master: MasterEngine,
    /// Health monitor spec (`--health off|every:<r>[,...]`; off = the
    /// exact legacy run). Like telemetry, excluded from the fingerprint:
    /// monitoring never touches the trajectory.
    pub health: crate::health::HealthSpec,
    /// Live ops endpoint port (`--ops <port>`; None = no server).
    /// Excluded from the fingerprint for the same reason.
    pub ops: Option<u16>,
}

impl Default for RunSpec {
    fn default() -> Self {
        RunSpec {
            algo: AlgoSpec::Ef21,
            compressor: "top1".into(),
            dataset: "a9a".into(),
            n_workers: 20,
            gamma_mult: 1.0,
            gamma_abs: None,
            rounds: 2000,
            lam: 0.1,
            seed: 0,
            record_every: 1,
            telemetry: "off".into(),
            threads: Threads::Auto,
            blocks: BlocksSpec::Flat,
            sched: SchedSpec::default(),
            master: MasterEngine::Threads,
            health: crate::health::HealthSpec::default(),
            ops: None,
        }
    }
}

impl RunSpec {
    /// Populate from parsed CLI args (only recognized keys are consumed).
    pub fn from_args(args: &cli::Args) -> Result<RunSpec> {
        let mut s = RunSpec::default();
        if let Some(a) = args.get_str("algo") {
            s.algo = AlgoSpec::parse(a)?;
        }
        if let Some(c) = args.get_str("compressor") {
            s.compressor = c.to_string();
        }
        if let Some(k) = args.get_str("k") {
            s.compressor = format!("top{k}");
        }
        if let Some(d) = args.get_str("dataset") {
            s.dataset = d.to_string();
        }
        s.n_workers = args.get_parse("workers")?.unwrap_or(s.n_workers);
        s.gamma_mult = args.get_parse("gamma-mult")?.unwrap_or(s.gamma_mult);
        s.gamma_abs = args.get_parse("gamma")?;
        s.rounds = args.get_parse("rounds")?.unwrap_or(s.rounds);
        s.lam = args.get_parse("lam")?.unwrap_or(s.lam);
        s.seed = args.get_parse("seed")?.unwrap_or(s.seed);
        s.record_every = args.get_parse("record-every")?.unwrap_or(s.record_every);
        if let Some(t) = args.get_str("telemetry") {
            s.telemetry = t.to_string();
        }
        s.threads = Threads::from_args(args)?;
        s.blocks = BlocksSpec::from_args(args)?;
        s.sched = SchedSpec::from_args(args)?;
        s.master = MasterEngine::from_args(args)?;
        s.health = crate::health::HealthSpec::from_args(args)?;
        s.ops = args.get_parse("ops")?;
        Ok(s)
    }

    pub fn label(&self) -> String {
        format!(
            "{} {} {}x {}",
            self.algo.name(),
            self.compressor,
            self.gamma_mult,
            self.dataset
        )
    }

    /// Run identity stamped into checkpoints and verified on resume:
    /// everything a resumed trajectory must share with the saving run to
    /// be bitwise-identical. `d` is the resolved problem dimension and
    /// `transport` the runner path (`sim`, `local`, `tcp`, ...).
    ///
    /// Deliberately excluded: `rounds` (resuming with a larger horizon
    /// just trains further), `threads` (pooled runs are bit-identical to
    /// sequential), `telemetry` (metering never touches the math), and
    /// the fault plan's `killmaster` clause (the resumed run is launched
    /// without the very crash the checkpoint recovers from).
    pub fn fingerprint(&self, d: usize, transport: &str) -> String {
        format!(
            "ef21.run|{}|{}|{}|w{}|d{}|seed{}|gm{}|ga{:?}|lam{}|re{}|blocks{:?}|part{:?}|dl{:?}|faults[{}]|{}",
            self.algo.name(),
            self.compressor,
            self.dataset,
            self.n_workers,
            d,
            self.seed,
            self.gamma_mult,
            self.gamma_abs,
            self.lam,
            self.record_every,
            self.blocks,
            self.sched.participation,
            self.sched.deadline_ms,
            self.sched.faults.fingerprint(),
            transport,
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn from_args_overrides_defaults() {
        let args = cli::Args::from_vec(vec![
            "--algo".into(),
            "ef".into(),
            "--k".into(),
            "4".into(),
            "--rounds=50".into(),
            "--gamma-mult".into(),
            "8".into(),
        ]);
        let s = RunSpec::from_args(&args).unwrap();
        assert_eq!(s.algo, AlgoSpec::Ef);
        assert_eq!(s.compressor, "top4");
        assert_eq!(s.rounds, 50);
        assert_eq!(s.gamma_mult, 8.0);
        assert_eq!(s.n_workers, 20); // default kept
        assert_eq!(s.telemetry, "off"); // default kept
        assert_eq!(s.threads, Threads::Auto); // default kept
    }

    #[test]
    fn threads_spec_parses_and_rejects() {
        assert_eq!(Threads::parse("auto").unwrap(), Threads::Auto);
        assert_eq!(Threads::parse("4").unwrap(), Threads::Fixed(4));
        assert_eq!(Threads::Fixed(3).resolve(), 3);
        assert!(Threads::Auto.resolve() >= 1);
        assert!(Threads::parse("0").is_err());
        assert!(Threads::parse("many").is_err());
        let args = cli::Args::from_vec(vec!["--threads".into(), "2".into()]);
        let s = RunSpec::from_args(&args).unwrap();
        assert_eq!(s.threads, Threads::Fixed(2));
    }

    #[test]
    fn blocks_spec_parses_and_resolves() {
        assert_eq!(BlocksSpec::parse("flat").unwrap(), BlocksSpec::Flat);
        assert_eq!(BlocksSpec::parse("1").unwrap(), BlocksSpec::Flat);
        assert_eq!(BlocksSpec::parse("auto").unwrap(), BlocksSpec::Auto);
        assert_eq!(BlocksSpec::parse("8").unwrap(), BlocksSpec::Count(8));
        assert!(matches!(BlocksSpec::parse("a:3,b:5").unwrap(), BlocksSpec::Named(_)));
        // User-facing block names keep their spelling (telemetry keys).
        assert_eq!(
            BlocksSpec::parse("Emb:6,Head:2").unwrap(),
            BlocksSpec::Named("Emb:6,Head:2".into())
        );
        assert!(BlocksSpec::parse("0").is_err());
        assert!(BlocksSpec::parse("wat").is_err());

        assert!(BlocksSpec::Flat.resolve(10, None).unwrap().is_flat());
        // Auto without a natural layout degenerates to flat.
        assert!(BlocksSpec::Auto.resolve(10, None).unwrap().is_flat());
        let natural = crate::blocks::BlockLayout::equal(5, 10).unwrap();
        assert_eq!(BlocksSpec::Auto.resolve(10, Some(&natural)).unwrap().n_blocks(), 5);
        assert_eq!(BlocksSpec::Count(2).resolve(10, None).unwrap().n_blocks(), 2);
        assert_eq!(
            BlocksSpec::Named("a:3,b:7".into()).resolve(10, None).unwrap().n_blocks(),
            2
        );
        assert!(BlocksSpec::Named("a:3,b:5".into()).resolve(10, None).is_err());
        assert!(BlocksSpec::Count(11).resolve(10, None).is_err());

        let args = cli::Args::from_vec(vec!["--blocks".into(), "4".into()]);
        let s = RunSpec::from_args(&args).unwrap();
        assert_eq!(s.blocks, BlocksSpec::Count(4));
    }

    #[test]
    fn sched_spec_parses_and_resolves() {
        // Absent flags = legacy = no scheduler built.
        let s = SchedSpec::from_args(&cli::Args::from_vec(vec![])).unwrap();
        assert!(s.is_legacy());
        assert!(s.build(8, 0).unwrap().is_none());
        // `--participation full` alone is still the legacy path (golden
        // trajectories must not move).
        let s = SchedSpec::from_args(&cli::Args::from_vec(vec![
            "--participation".into(),
            "full".into(),
        ]))
        .unwrap();
        assert!(s.is_legacy());
        assert!(s.build(8, 0).unwrap().is_none());
        // A real spec builds a scheduler sized to the run.
        let s = SchedSpec::from_args(&cli::Args::from_vec(vec![
            "--participation".into(),
            "p:0.5".into(),
            "--faults".into(),
            "crash@3,rejoin@6".into(),
            "--deadline-ms".into(),
            "250".into(),
        ]))
        .unwrap();
        assert!(!s.is_legacy());
        let sched = s.build(8, 7).unwrap().unwrap();
        assert_eq!(sched.n_workers(), 8);
        assert_eq!(sched.deadline_ms(), Some(250));
        assert!(sched.needs_resync());
        // Fault plans referencing out-of-range workers fail at build.
        let bad = SchedSpec {
            faults: crate::sched::FaultPlan::parse("w9:crash@1").unwrap(),
            ..SchedSpec::default()
        };
        assert!(bad.build(4, 0).is_err());
        // Malformed flags error at parse.
        assert!(SchedSpec::from_args(&cli::Args::from_vec(vec![
            "--participation".into(),
            "p:2.0".into(),
        ]))
        .is_err());
        assert!(SchedSpec::from_args(&cli::Args::from_vec(vec![
            "--faults".into(),
            "rejoin@2".into(),
        ]))
        .is_err());
    }

    #[test]
    fn deadline_floor_applies_to_transport_builds_only() {
        let s = SchedSpec {
            faults: crate::sched::FaultPlan::parse("straggle(0,1..2,10ms)").unwrap(),
            ..SchedSpec::default()
        };
        // Sim builds never consult the network-timeout knob: the
        // trajectory must depend only on (spec, seed).
        assert_eq!(s.build(2, 0).unwrap().unwrap().deadline_ms(), None);
        // Transport builds floor to the resolved I/O timeout (or stay
        // unset when timeouts are disabled).
        let io_ms = crate::transport::tcp::io_timeout().map(|d| d.as_millis() as u64);
        assert_eq!(s.build_for_transport(2, 0).unwrap().unwrap().deadline_ms(), io_ms);
        // An explicit deadline wins everywhere.
        let s2 = SchedSpec { deadline_ms: Some(77), ..s };
        assert_eq!(s2.build(2, 0).unwrap().unwrap().deadline_ms(), Some(77));
        assert_eq!(s2.build_for_transport(2, 0).unwrap().unwrap().deadline_ms(), Some(77));
    }

    #[test]
    fn net_timeout_flag_parses() {
        assert_eq!(
            net_timeout_ms_from_args(&cli::Args::from_vec(vec![
                "--net-timeout-ms".into(),
                "750".into()
            ]))
            .unwrap(),
            Some(750)
        );
        assert_eq!(net_timeout_ms_from_args(&cli::Args::from_vec(vec![])).unwrap(), None);
        assert!(net_timeout_ms_from_args(&cli::Args::from_vec(vec![
            "--net-timeout-ms".into(),
            "soon".into()
        ]))
        .is_err());
    }

    #[test]
    fn ckpt_spec_parses_and_validates() {
        let s = CkptSpec::from_args(&cli::Args::from_vec(vec![])).unwrap();
        assert!(s.is_legacy());
        assert_eq!(s.every, 1);
        let s = CkptSpec::from_args(&cli::Args::from_vec(vec![
            "--checkpoint".into(),
            "/tmp/run.ckpt".into(),
            "--checkpoint-every".into(),
            "5".into(),
            "--resume".into(),
            "/tmp/old.ckpt".into(),
        ]))
        .unwrap();
        assert!(!s.is_legacy());
        assert_eq!(s.save_path.as_deref(), Some(std::path::Path::new("/tmp/run.ckpt")));
        assert_eq!(s.every, 5);
        assert_eq!(s.resume_path.as_deref(), Some(std::path::Path::new("/tmp/old.ckpt")));
        // A cadence without a destination, and a zero cadence, both error.
        assert!(CkptSpec::from_args(&cli::Args::from_vec(vec![
            "--checkpoint-every".into(),
            "5".into(),
        ]))
        .is_err());
        assert!(CkptSpec::from_args(&cli::Args::from_vec(vec![
            "--checkpoint".into(),
            "/tmp/run.ckpt".into(),
            "--checkpoint-every".into(),
            "0".into(),
        ]))
        .is_err());
        // A missing resume file surfaces at build time, not mid-run.
        let s = CkptSpec {
            resume_path: Some("/nonexistent/nope.ckpt".into()),
            ..CkptSpec::default()
        };
        assert!(s.build("fp").is_err());
    }

    #[test]
    fn fingerprint_ignores_killmaster_but_not_real_faults() {
        let base = RunSpec::default();
        let mut killed = base.clone();
        killed.sched.faults = crate::sched::FaultPlan::parse("killmaster@7").unwrap();
        // The kill the checkpoint recovers from must not change identity…
        assert_eq!(base.fingerprint(100, "sim"), killed.fingerprint(100, "sim"));
        // …but trajectory-shaping differences must.
        let mut crashed = base.clone();
        crashed.sched.faults =
            crate::sched::FaultPlan::parse("crash@3,rejoin@6").unwrap();
        assert_ne!(base.fingerprint(100, "sim"), crashed.fingerprint(100, "sim"));
        assert_ne!(base.fingerprint(100, "sim"), base.fingerprint(101, "sim"));
        assert_ne!(base.fingerprint(100, "sim"), base.fingerprint(100, "local"));
    }

    #[test]
    fn master_engine_parses_and_stays_out_of_the_fingerprint() {
        assert_eq!(MasterEngine::parse("threads").unwrap(), MasterEngine::Threads);
        assert_eq!(MasterEngine::parse("Reactor").unwrap(), MasterEngine::Reactor);
        assert!(MasterEngine::parse("poll").is_err());
        let s = RunSpec::from_args(&cli::Args::from_vec(vec![
            "--master".into(),
            "reactor".into(),
        ]))
        .unwrap();
        assert_eq!(s.master, MasterEngine::Reactor);
        // Bit-identical engines share checkpoint identity.
        let mut t = s.clone();
        t.master = MasterEngine::Threads;
        assert_eq!(s.fingerprint(100, "dist"), t.fingerprint(100, "dist"));
        // Absent = legacy.
        let d = RunSpec::from_args(&cli::Args::from_vec(vec![])).unwrap();
        assert_eq!(d.master, MasterEngine::Threads);
    }

    #[test]
    fn health_and_ops_parse_and_stay_out_of_the_fingerprint() {
        // Absent = off = exact legacy run.
        let d = RunSpec::from_args(&cli::Args::from_vec(vec![])).unwrap();
        assert!(d.health.is_off());
        assert_eq!(d.ops, None);
        let s = RunSpec::from_args(&cli::Args::from_vec(vec![
            "--health".into(),
            "every:5,window:4".into(),
            "--ops".into(),
            "9200".into(),
        ]))
        .unwrap();
        assert_eq!((s.health.every, s.health.window), (5, 4));
        assert_eq!(s.ops, Some(9200));
        // Monitoring never touches the trajectory, so checkpoints move
        // freely between health-on and health-off runs.
        assert_eq!(d.fingerprint(100, "sim"), s.fingerprint(100, "sim"));
        assert!(RunSpec::from_args(&cli::Args::from_vec(vec![
            "--health".into(),
            "every:zero".into(),
        ]))
        .is_err());
    }

    #[test]
    fn telemetry_spec_is_carried() {
        let args = cli::Args::from_vec(vec![
            "--telemetry".into(),
            "jsonl:/tmp/m.jsonl,tcp:9100".into(),
        ]);
        let s = RunSpec::from_args(&args).unwrap();
        assert_eq!(s.telemetry, "jsonl:/tmp/m.jsonl,tcp:9100");
    }

    #[test]
    fn bad_values_error() {
        let args = cli::Args::from_vec(vec!["--rounds".into(), "abc".into()]);
        assert!(RunSpec::from_args(&args).is_err());
    }

    #[test]
    fn net_spec_parses_and_auto_enables_sessions() {
        use crate::coordinator::dist::LossPolicy;
        // Absent flags = legacy = sessions off, exact legacy wire bytes.
        let d = NetSpec::from_args(&cli::Args::from_vec(vec![])).unwrap();
        assert!(d.is_legacy());
        assert!(!d.session_enabled());
        let net = d.build(7).unwrap();
        assert!(net.session.is_none() && net.chaos.is_none());
        assert_eq!(net.on_loss, LossPolicy::Abort);
        // `--session on` alone wraps frames but changes nothing else.
        let s = NetSpec::from_args(&cli::Args::from_vec(vec![
            "--session".into(),
            "on".into(),
        ]))
        .unwrap();
        assert!(!s.is_legacy());
        assert!(s.session_enabled());
        assert!(s.build(7).unwrap().session.is_some());
        // Chaos / degrade / quorum each auto-enable sessions.
        let s = NetSpec::from_args(&cli::Args::from_vec(vec![
            "--chaos".into(),
            "reset(0@2),corrupt(1@4)".into(),
            "--on-worker-loss".into(),
            "degrade:500".into(),
            "--min-workers".into(),
            "3".into(),
        ]))
        .unwrap();
        assert!(s.session_enabled());
        assert_eq!(s.on_loss, LossPolicy::Degrade { grace_ms: 500 });
        assert_eq!(s.min_workers, Some(3));
        let net = s.build(7).unwrap();
        assert!(net.session.is_some());
        assert!(net.chaos.is_some());
        assert_eq!(
            NetSpec::from_args(&cli::Args::from_vec(vec![
                "--on-worker-loss".into(),
                "wait".into(),
            ]))
            .unwrap()
            .on_loss,
            LossPolicy::Wait
        );
        // Conflicts and malformed values error at parse/build.
        assert!(NetSpec::from_args(&cli::Args::from_vec(vec![
            "--session".into(),
            "off".into(),
            "--chaos".into(),
            "reset(0@2)".into(),
        ]))
        .is_err());
        assert!(NetSpec::from_args(&cli::Args::from_vec(vec![
            "--session".into(),
            "maybe".into(),
        ]))
        .is_err());
        assert!(NetSpec::from_args(&cli::Args::from_vec(vec![
            "--on-worker-loss".into(),
            "degrade:soon".into(),
        ]))
        .is_err());
        let bad = NetSpec { chaos: Some("explode(0@1)".into()), ..NetSpec::default() };
        assert!(bad.build(7).is_err());
        // Same seed → same session ids; the layer itself never shifts
        // checkpoint identity (NetSpec is not part of RunSpec), so a
        // snapshot moves freely between session-on and session-off runs.
        let a = s.build(7).unwrap();
        let b = s.build(7).unwrap();
        assert_eq!(
            a.session.as_ref().map(|c| c.seed),
            b.session.as_ref().map(|c| c.seed)
        );
    }
}
