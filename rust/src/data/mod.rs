//! Dataset substrate: LibSVM parsing, Table-3 synthetic generators, and the
//! paper's 20-way heterogeneous contiguous partitioning (§5.1).

pub mod libsvm;
pub mod partition;
pub mod synth;

/// Dense row-major binary-classification dataset (features f32, labels ±1).
#[derive(Clone, Debug)]
pub struct Dataset {
    pub name: String,
    /// Row-major n x d feature matrix.
    pub a: Vec<f32>,
    /// Labels in {-1, +1} (or regression targets for least squares).
    pub y: Vec<f32>,
    pub n: usize,
    pub d: usize,
}

impl Dataset {
    pub fn new(name: impl Into<String>, a: Vec<f32>, y: Vec<f32>, n: usize, d: usize) -> Self {
        assert_eq!(a.len(), n * d);
        assert_eq!(y.len(), n);
        Dataset { name: name.into(), a, y, n, d }
    }

    pub fn row(&self, i: usize) -> &[f32] {
        &self.a[i * self.d..(i + 1) * self.d]
    }

    /// View of a contiguous row range as a borrowed shard.
    pub fn slice(&self, start: usize, len: usize) -> Shard<'_> {
        assert!(start + len <= self.n);
        Shard {
            a: &self.a[start * self.d..(start + len) * self.d],
            y: &self.y[start..start + len],
            n: len,
            d: self.d,
        }
    }
}

/// Borrowed view of a contiguous block of rows — one worker's local data.
#[derive(Clone, Copy, Debug)]
pub struct Shard<'a> {
    pub a: &'a [f32],
    pub y: &'a [f32],
    pub n: usize,
    pub d: usize,
}

impl<'a> Shard<'a> {
    pub fn row(&self, i: usize) -> &'a [f32] {
        &self.a[i * self.d..(i + 1) * self.d]
    }

    /// Owned copy (used to move shard data into worker threads).
    pub fn to_owned_parts(&self) -> (Vec<f32>, Vec<f32>) {
        (self.a.to_vec(), self.y.to_vec())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny() -> Dataset {
        Dataset::new(
            "tiny",
            vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0],
            vec![1.0, -1.0, 1.0],
            3,
            2,
        )
    }

    #[test]
    fn rows_and_slices() {
        let ds = tiny();
        assert_eq!(ds.row(1), &[3.0, 4.0]);
        let sh = ds.slice(1, 2);
        assert_eq!(sh.n, 2);
        assert_eq!(sh.row(0), &[3.0, 4.0]);
        assert_eq!(sh.y, &[-1.0, 1.0]);
    }

    #[test]
    #[should_panic]
    fn slice_out_of_bounds_panics() {
        tiny().slice(2, 2);
    }
}
