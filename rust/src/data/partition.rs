//! The paper's data partitioning (§5.1): contiguous split into `n` equal
//! parts of size floor(N/n); the remainder rows are assigned to the LAST
//! worker, making it slightly larger ("the last part, of size
//! N - 20*floor(N/20), was assigned to the last worker").

use super::{Dataset, Shard};

/// Row ranges [(start, len); n_workers] of the paper's split.
pub fn ranges(n_total: usize, n_workers: usize) -> Vec<(usize, usize)> {
    assert!(n_workers >= 1);
    assert!(n_total >= n_workers, "need at least one row per worker");
    let base = n_total / n_workers;
    let mut out = Vec::with_capacity(n_workers);
    for w in 0..n_workers {
        let start = w * base;
        let len = if w + 1 == n_workers { n_total - start } else { base };
        out.push((start, len));
    }
    out
}

/// Borrowing shards view of a dataset under the paper's split.
pub fn shards<'a>(ds: &'a Dataset, n_workers: usize) -> Vec<Shard<'a>> {
    ranges(ds.n, n_workers)
        .into_iter()
        .map(|(s, l)| ds.slice(s, l))
        .collect()
}

/// The largest shard size (drives the padded AOT artifact shape).
pub fn max_shard_rows(n_total: usize, n_workers: usize) -> usize {
    n_total / n_workers + n_total % n_workers
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;

    #[test]
    fn covers_all_rows_disjointly() {
        for (n, w) in [(100usize, 20usize), (101, 20), (119, 20), (11_055, 20), (7, 3)] {
            let r = ranges(n, w);
            assert_eq!(r.len(), w);
            let mut next = 0;
            for (start, len) in &r {
                assert_eq!(*start, next);
                assert!(*len > 0);
                next = start + len;
            }
            assert_eq!(next, n);
        }
    }

    #[test]
    fn remainder_goes_to_last_worker() {
        let r = ranges(11_055, 20);
        assert_eq!(r[0].1, 552);
        assert_eq!(r[19].1, 552 + 15);
        assert_eq!(max_shard_rows(11_055, 20), 567);
    }

    #[test]
    fn shard_views_match_dataset_rows() {
        let ds = synth::generate_custom("p", 103, 5, 0.5, 1);
        let sh = shards(&ds, 4);
        assert_eq!(sh.len(), 4);
        assert_eq!(sh[3].n, 25 + 3);
        // Row 0 of shard 2 == row 50 of the dataset.
        assert_eq!(sh[2].row(0), ds.row(50));
    }

    #[test]
    #[should_panic]
    fn too_many_workers_panics() {
        ranges(3, 5);
    }
}
