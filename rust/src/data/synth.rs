//! Synthetic stand-ins for the paper's four LibSVM datasets (Table 3).
//!
//! The generator reproduces the *statistical features the experiments
//! depend on* rather than the exact bytes (which are unavailable offline):
//!
//!   * exact (N, d) from Table 3;
//!   * binary ±1 labels from a noisy linear teacher (so the logistic
//!     problem is learnable but not separable — gradients stay nonzero);
//!   * LibSVM-like sparsity/scale: features are nonnegative, bounded, with
//!     dataset-specific density;
//!   * **heterogeneity across the contiguous 20-way split**: feature means
//!     and label balance drift smoothly with the row index, so each
//!     worker's shard has a different distribution and `∇f_i(x*) ≠ 0` —
//!     the regime where naive DCGD diverges and EF-style methods matter.
//!
//! If a real LibSVM file exists at `data/<name>` it takes precedence (see
//! [`load_or_generate`]).

use super::Dataset;
use crate::util::rng::Rng;

/// Table 3 rows: (name, N, d, feature density).
pub const TABLE3: [(&str, usize, usize, f64); 4] = [
    ("phishing", 11_055, 68, 0.44),
    ("mushrooms", 8_120, 112, 0.19),
    ("a9a", 32_560, 123, 0.11),
    ("w8a", 49_749, 300, 0.04),
];

/// Look up a Table-3 config by dataset name.
pub fn table3(name: &str) -> Option<(usize, usize, f64)> {
    TABLE3
        .iter()
        .find(|(n, _, _, _)| *n == name)
        .map(|&(_, n, d, dens)| (n, d, dens))
}

/// Deterministically generate the synthetic counterpart of a Table-3
/// dataset. Same name + seed => bit-identical data.
pub fn generate(name: &str, seed: u64) -> Dataset {
    let (n, d, density) = table3(name)
        .unwrap_or_else(|| panic!("unknown dataset '{name}' (try phishing|mushrooms|a9a|w8a)"));
    generate_custom(name, n, d, density, seed)
}

/// Generator core, exposed for tests and custom workloads.
pub fn generate_custom(name: &str, n: usize, d: usize, density: f64, seed: u64) -> Dataset {
    let mut rng = Rng::seed(seed ^ hash_name(name));
    // Hidden teacher direction; labels = sign(a.x* + noise).
    let teacher: Vec<f64> = (0..d).map(|_| rng.next_normal()).collect();
    let mut a = vec![0.0f32; n * d];
    let mut y = vec![0.0f32; n];

    for i in 0..n {
        // Heterogeneity drift in [0,1]: contiguous shards see different
        // feature scales and label balance.
        let t = i as f64 / n.max(1) as f64;
        let shift = 0.5 * (2.0 * std::f64::consts::PI * t).sin();
        let scale = 0.6 + 0.8 * t;
        let row = &mut a[i * d..(i + 1) * d];
        let mut z = 0.0f64;
        for (j, slot) in row.iter_mut().enumerate() {
            if rng.next_f64() < density {
                // Nonnegative bounded features, libsvm-style.
                let v = (scale * rng.next_f64() + 0.25 * shift).clamp(0.0, 1.0);
                *slot = v as f32;
                z += v * teacher[j];
            }
        }
        // Label noise keeps the problem non-separable (~12% flips).
        let noisy = z + 0.6 * rng.next_normal() + 0.3 * shift;
        y[i] = if noisy >= 0.0 { 1.0 } else { -1.0 };
    }
    Dataset::new(name, a, y, n, d)
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a.
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for b in name.bytes() {
        h ^= b as u64;
        h = h.wrapping_mul(0x100_0000_01b3);
    }
    h
}

/// Prefer a real LibSVM file at `data_dir/<name>` (paper-exact data); fall
/// back to the deterministic synthetic generator.
pub fn load_or_generate(name: &str, data_dir: &std::path::Path, seed: u64) -> Dataset {
    let path = data_dir.join(name);
    if path.exists() {
        let d_hint = table3(name).map(|(_, d, _)| d);
        match super::libsvm::load(name, &path, d_hint) {
            Ok(ds) => return ds,
            Err(e) => {
                eprintln!("warning: failed to parse {}: {e:#}; using synthetic", path.display())
            }
        }
    }
    generate(name, seed)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table3_shapes_match_paper() {
        for (name, n, d, _) in TABLE3 {
            let ds = generate(name, 1);
            assert_eq!(ds.n, n, "{name}");
            assert_eq!(ds.d, d, "{name}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let a = generate_custom("x", 50, 8, 0.3, 7);
        let b = generate_custom("x", 50, 8, 0.3, 7);
        assert_eq!(a.a, b.a);
        assert_eq!(a.y, b.y);
        let c = generate_custom("x", 50, 8, 0.3, 8);
        assert_ne!(a.a, c.a);
    }

    #[test]
    fn labels_are_pm1_and_roughly_balanced() {
        let ds = generate_custom("bal", 4000, 20, 0.3, 3);
        let pos = ds.y.iter().filter(|&&l| l == 1.0).count();
        assert!(ds.y.iter().all(|&l| l == 1.0 || l == -1.0));
        let frac = pos as f64 / ds.n as f64;
        assert!((0.2..=0.8).contains(&frac), "label fraction {frac}");
    }

    #[test]
    fn features_bounded_and_sparse() {
        let ds = generate_custom("sp", 2000, 30, 0.1, 5);
        assert!(ds.a.iter().all(|&v| (0.0..=1.0).contains(&v)));
        let nnz = ds.a.iter().filter(|&&v| v != 0.0).count();
        let dens = nnz as f64 / ds.a.len() as f64;
        assert!((0.05..=0.15).contains(&dens), "density {dens}");
    }

    #[test]
    fn shards_are_heterogeneous() {
        // First and last 5% of rows must have visibly different label
        // balance or feature mean — the heterogeneous-data regime.
        let ds = generate_custom("het", 10_000, 16, 0.4, 11);
        let head = ds.slice(0, 500);
        let tail = ds.slice(9_500, 500);
        let mean = |sh: crate::data::Shard| -> f64 {
            sh.a.iter().map(|&v| v as f64).sum::<f64>() / sh.a.len() as f64
        };
        let pos = |sh: crate::data::Shard| -> f64 {
            sh.y.iter().filter(|&&l| l == 1.0).count() as f64 / sh.n as f64
        };
        let dm = (mean(head) - mean(tail)).abs();
        let dp = (pos(head) - pos(tail)).abs();
        assert!(dm > 0.02 || dp > 0.05, "shards look identical: dm={dm} dp={dp}");
    }

    #[test]
    fn load_or_generate_prefers_real_file() {
        let dir = std::env::temp_dir().join(format!("ef21_test_{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("mini"), "+1 1:1\n-1 2:1\n").unwrap();
        // Unknown name without a file panics; with a file it parses.
        let ds = load_or_generate("mini", &dir, 0);
        assert_eq!(ds.n, 2);
        std::fs::remove_dir_all(&dir).ok();
    }
}
