//! LibSVM text-format parser (`label idx:val idx:val ...`, 1-based sparse
//! indices), the format of the paper's phishing/mushrooms/a9a/w8a datasets
//! [Chang & Lin 2011]. If real files are present under `data/` they are
//! parsed and used directly; otherwise the synthetic generators take over
//! (see DESIGN.md §3 Substitutions).

use super::Dataset;
use anyhow::{bail, Context, Result};

/// Parse LibSVM text into a dense Dataset. Labels are normalized to ±1:
/// {0,1} -> {-1,+1}, {1,2} -> {-1,+1}, {-1,+1} kept.
pub fn parse(name: &str, text: &str, d_hint: Option<usize>) -> Result<Dataset> {
    let mut rows: Vec<Vec<(usize, f32)>> = Vec::new();
    let mut labels_raw: Vec<f32> = Vec::new();
    let mut d_max = d_hint.unwrap_or(0);

    for (lineno, line) in text.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() || line.starts_with('#') {
            continue;
        }
        let mut parts = line.split_whitespace();
        let label: f32 = parts
            .next()
            .context("missing label")?
            .parse()
            .with_context(|| format!("bad label on line {}", lineno + 1))?;
        let mut feats = Vec::new();
        for tok in parts {
            let (i, v) = tok
                .split_once(':')
                .with_context(|| format!("bad feature '{tok}' on line {}", lineno + 1))?;
            let i: usize = i.parse().with_context(|| format!("bad index on line {}", lineno + 1))?;
            let v: f32 = v.parse().with_context(|| format!("bad value on line {}", lineno + 1))?;
            if i == 0 {
                bail!("LibSVM indices are 1-based; got 0 on line {}", lineno + 1);
            }
            d_max = d_max.max(i);
            feats.push((i - 1, v));
        }
        labels_raw.push(label);
        rows.push(feats);
    }
    if rows.is_empty() {
        bail!("empty LibSVM file for {name}");
    }

    // Normalize labels to {-1, +1}.
    let mut distinct: Vec<f32> = labels_raw.clone();
    distinct.sort_by(|a, b| a.partial_cmp(b).unwrap());
    distinct.dedup();
    if distinct.len() != 2 {
        bail!("{name}: expected binary labels, found {} distinct", distinct.len());
    }
    let (lo, hi) = (distinct[0], distinct[1]);
    let y: Vec<f32> = labels_raw
        .iter()
        .map(|&l| if l == hi { 1.0 } else { -1.0 })
        .collect();
    let _ = lo;

    let n = rows.len();
    let d = d_max;
    let mut a = vec![0.0f32; n * d];
    for (r, feats) in rows.iter().enumerate() {
        for &(j, v) in feats {
            if j < d {
                a[r * d + j] = v;
            }
        }
    }
    Ok(Dataset::new(name, a, y, n, d))
}

/// Load from a file path.
pub fn load(name: &str, path: &std::path::Path, d_hint: Option<usize>) -> Result<Dataset> {
    let text = std::fs::read_to_string(path)
        .with_context(|| format!("reading LibSVM file {}", path.display()))?;
    parse(name, &text, d_hint)
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "\
+1 1:0.5 3:1.0
-1 2:2.0
+1 1:-1.0 2:0.25 3:0.125
";

    #[test]
    fn parses_dense_matrix() {
        let ds = parse("t", SAMPLE, None).unwrap();
        assert_eq!((ds.n, ds.d), (3, 3));
        assert_eq!(ds.row(0), &[0.5, 0.0, 1.0]);
        assert_eq!(ds.row(1), &[0.0, 2.0, 0.0]);
        assert_eq!(ds.y, vec![1.0, -1.0, 1.0]);
    }

    #[test]
    fn normalizes_01_labels() {
        let ds = parse("t", "0 1:1\n1 1:2\n", None).unwrap();
        assert_eq!(ds.y, vec![-1.0, 1.0]);
    }

    #[test]
    fn respects_d_hint_for_trailing_zero_features() {
        let ds = parse("t", "+1 1:1\n-1 1:2\n", Some(5)).unwrap();
        assert_eq!(ds.d, 5);
    }

    #[test]
    fn rejects_zero_index_and_garbage() {
        assert!(parse("t", "+1 0:1\n", None).is_err());
        assert!(parse("t", "+1 a:b\n", None).is_err());
        assert!(parse("t", "", None).is_err());
        assert!(parse("t", "+1 1:1\n+2 1:1\n-1 1:1\n", None).is_err()); // 3 labels
    }

    #[test]
    fn skips_comments_and_blank_lines() {
        let ds = parse("t", "# header\n\n+1 1:1\n-1 1:2\n", None).unwrap();
        assert_eq!(ds.n, 2);
    }
}
