//! EF21+ (Algorithm 3, §3.5): each round, each worker compresses with the
//! better of the plain biased compressor `C` (DCGD-style message,
//! `b = C(∇f_i)`) and the Markov compressor (`m = g_i + C(∇f_i - g_i)`),
//! measured by actual distortion at the current gradient. The new local
//! state `g_i^{t+1}` is whichever estimate won; the branch is signalled to
//! the master with a 1-bit tag.
//!
//! Master-side reconstruction: the DCGD branch's message IS the new state
//! (`g_i = dense(b)`, determined entirely by the k-sparse payload), the
//! Markov branch's message is a delta (`g_i += c`). The master keeps the
//! per-worker mirrors and the running average.

use super::{BuildOpts, MasterNode, WireMsg, WorkerNode};
use crate::blocks::{BlockLayout, ParamBlocks, Workspace};
use crate::ckpt::wire;
use crate::compress::Compressor;
use crate::oracle::GradOracle;
use crate::util::linalg;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct Ef21PlusWorker {
    oracle: Box<dyn GradOracle>,
    c: Arc<dyn Compressor>,
    rng: Rng,
    /// Local state g_i, kept per block.
    g: ParamBlocks,
    last_loss: f64,
    /// Gradient buffer, written in place every round.
    last_grad: Vec<f64>,
    last_branch_dcgd: bool,
    diff: Vec<f64>,
    /// Pooled scratch for the two per-round dense branch candidates
    /// (previously two fresh allocations per round per worker).
    ws: Workspace,
    /// Reused compression buffers for the DCGD / Markov branch
    /// candidates (the winner is swapped into the outgoing message slot,
    /// whose previous buffers become next round's scratch).
    cand_b: crate::compress::Compressed,
    cand_m: crate::compress::Compressed,
}

impl Ef21PlusWorker {
    pub fn new(oracle: Box<dyn GradOracle>, c: Arc<dyn Compressor>, rng: Rng) -> Self {
        let layout = Arc::new(BlockLayout::flat(oracle.dim()));
        Self::with_layout(oracle, c, rng, layout)
    }

    pub fn with_layout(
        oracle: Box<dyn GradOracle>,
        c: Arc<dyn Compressor>,
        rng: Rng,
        layout: Arc<BlockLayout>,
    ) -> Self {
        assert!(
            c.is_deterministic(),
            "EF21+ analysis (§3.5) requires a deterministic compressor"
        );
        let d = oracle.dim();
        assert_eq!(layout.d(), d, "layout dimension mismatch");
        Ef21PlusWorker {
            oracle,
            c,
            rng,
            g: ParamBlocks::zeros(layout),
            last_loss: 0.0,
            last_grad: vec![0.0; d],
            last_branch_dcgd: false,
            diff: vec![0.0; d],
            ws: Workspace::new(),
            cand_b: crate::compress::Compressed::empty(),
            cand_m: crate::compress::Compressed::empty(),
        }
    }

    pub fn state_g(&self) -> &[f64] {
        self.g.as_slice()
    }
}

impl WorkerNode for Ef21PlusWorker {
    fn init(&mut self, x0: &[f64]) -> WireMsg {
        // With g = 0 both branches coincide with C(∇f_i(x^0)).
        self.round(x0)
    }

    fn round(&mut self, x: &[f64]) -> WireMsg {
        let mut out = WireMsg::empty();
        self.round_into(x, &mut out);
        out
    }

    fn round_into(&mut self, x: &[f64], out: &mut WireMsg) {
        let d = self.g.layout().d();
        self.last_loss = self.oracle.loss_grad_into(x, &mut self.last_grad);

        // Branch 1 (DCGD): b = C(grad). Both candidate compressions land
        // in worker-owned reused buffers.
        self.c.compress_into(&self.last_grad, &mut self.rng, &mut self.cand_b);
        // Branch 2 (Markov): m = g + C(grad - g); diff per block
        // (shared kernel, bit-identical to the legacy flat loop).
        self.g.sub_from_into(&self.last_grad, &mut self.diff);
        self.c.compress_into(&self.diff, &mut self.rng, &mut self.cand_m);

        // Distortions at ∇f_i(x^{t+1}).
        // B = ||b - grad||^2; M = ||(g + delta) - grad||^2.
        // Both dense candidates come from the pooled workspace (no
        // per-round allocation; contents are re-initialized on take).
        let mut b_dense = self.ws.take_zeroed(d);
        self.cand_b.sparse.add_into(&mut b_dense);
        let b_dist = linalg::dist_sq(&b_dense, &self.last_grad);
        let mut m_dense = self.ws.take_copy(self.g.as_slice());
        self.cand_m.sparse.add_into(&mut m_dense);
        let m_dist = linalg::dist_sq(&m_dense, &self.last_grad);

        let winner = if m_dist <= b_dist {
            self.g.swap_flat(&mut m_dense);
            self.last_branch_dcgd = false;
            self.ws.put(m_dense);
            self.ws.put(b_dense);
            &mut self.cand_m
        } else {
            self.g.swap_flat(&mut b_dense);
            self.last_branch_dcgd = true;
            self.ws.put(b_dense);
            self.ws.put(m_dense);
            &mut self.cand_b
        };
        // The winning candidate's buffers move into the message slot;
        // the slot's previous buffers become next round's candidate
        // scratch (pure swap, no allocation).
        std::mem::swap(out.reset_tagged(self.last_branch_dcgd), winner);
    }

    fn last_loss(&self) -> f64 {
        self.last_loss
    }

    fn last_grad(&self) -> &[f64] {
        &self.last_grad
    }

    fn distortion_sq(&self) -> Option<f64> {
        Some(linalg::dist_sq(self.g.as_slice(), &self.last_grad))
    }

    fn used_dcgd_branch(&self) -> Option<bool> {
        Some(self.last_branch_dcgd)
    }

    /// Absent EF21+ workers still speak the tagged wire protocol: a
    /// Markov-branch no-op delta (the master holds `g_i` and `g_sum`).
    /// Accounted at 0 bits — nothing actually travels.
    fn absent_msg(&self) -> WireMsg {
        WireMsg::Tagged {
            dcgd_branch: false,
            payload: crate::compress::Compressed {
                sparse: crate::compress::SparseVec::empty(),
                bits: 0,
            },
        }
    }

    // g_i is message-determined (delta or whole-state assignment), so
    // the master's tracker can rebuild it exactly.
    fn supports_resync(&self) -> bool {
        true
    }

    fn crash(&mut self) {
        self.g.as_mut_slice().iter_mut().for_each(|v| *v = 0.0);
        self.last_branch_dcgd = false;
    }

    fn resync(&mut self, state: &[f64]) {
        assert_eq!(state.len(), self.g.as_slice().len(), "StateSync dimension mismatch");
        self.g.as_mut_slice().copy_from_slice(state);
    }

    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        wire::put_u8(out, CKPT_TAG);
        wire::put_rng(out, &self.rng);
        wire::put_f64(out, self.last_loss);
        wire::put_f64s(out, &self.last_grad);
        wire::put_f64s(out, self.g.as_slice());
        wire::put_u8(out, self.last_branch_dcgd as u8);
        Ok(())
    }

    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let mut rd = wire::Rd::new(blob);
        anyhow::ensure!(rd.u8()? == CKPT_TAG, "checkpoint blob is not EF21+ worker state");
        self.rng = wire::read_rng(&mut rd)?;
        self.last_loss = rd.f64()?;
        wire::read_f64s_into(&mut rd, &mut self.last_grad)?;
        wire::read_f64s_into(&mut rd, self.g.as_mut_slice())?;
        self.last_branch_dcgd = rd.u8()? != 0;
        rd.done()
    }
}

/// Blob discriminator shared by the EF21+ worker and master state blobs.
const CKPT_TAG: u8 = 0x2B;

pub struct Ef21PlusMaster {
    x: Vec<f64>,
    /// Per-worker mirrors of g_i (needed to absorb assignment messages).
    g_i: Vec<Vec<f64>>,
    /// Sum over workers of g_i (divided by n at step time).
    g_sum: Vec<f64>,
    gamma: f64,
}

impl Ef21PlusMaster {
    pub fn new(x0: Vec<f64>, n: usize, gamma: f64) -> Self {
        let d = x0.len();
        Ef21PlusMaster { x: x0, g_i: vec![vec![0.0; d]; n], g_sum: vec![0.0; d], gamma }
    }

    pub fn aggregate_g(&self) -> Vec<f64> {
        let n = self.g_i.len() as f64;
        self.g_sum.iter().map(|v| v / n).collect()
    }
}

impl MasterNode for Ef21PlusMaster {
    fn x(&self) -> &[f64] {
        &self.x
    }

    fn init_absorb(&mut self, msgs: &[WireMsg]) {
        self.absorb(msgs);
    }

    fn begin_round(&mut self) -> Vec<f64> {
        let mut out = Vec::new();
        self.begin_round_into(&mut out);
        out
    }

    // The one copy of the step (begin_round wraps this, so the two
    // entry points cannot drift).
    fn begin_round_into(&mut self, out: &mut Vec<f64>) {
        let scale = -self.gamma / self.g_i.len() as f64;
        linalg::axpy(scale, &self.g_sum, &mut self.x);
        out.clear();
        out.extend_from_slice(&self.x);
    }

    fn absorb(&mut self, msgs: &[WireMsg]) {
        debug_assert_eq!(msgs.len(), self.g_i.len());
        for (i, m) in msgs.iter().enumerate() {
            match m {
                WireMsg::Tagged { dcgd_branch: false, payload } => {
                    payload.sparse.add_into(&mut self.g_i[i]);
                    payload.sparse.add_into(&mut self.g_sum);
                }
                WireMsg::Tagged { dcgd_branch: true, payload } => {
                    // g_sum -= old g_i; g_i = dense(b); g_sum += g_i.
                    let gi = &mut self.g_i[i];
                    for (s, old) in self.g_sum.iter_mut().zip(gi.iter()) {
                        *s -= *old;
                    }
                    gi.iter_mut().for_each(|v| *v = 0.0);
                    payload.sparse.add_into(gi);
                    for (s, new) in self.g_sum.iter_mut().zip(gi.iter()) {
                        *s += *new;
                    }
                }
                WireMsg::Sparse(_) => panic!("EF21+ master expects tagged messages"),
            }
        }
    }

    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        wire::put_u8(out, CKPT_TAG);
        wire::put_f64s(out, &self.x);
        wire::put_u32(out, self.g_i.len() as u32);
        for gi in &self.g_i {
            wire::put_f64s(out, gi);
        }
        wire::put_f64s(out, &self.g_sum);
        Ok(())
    }

    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let mut rd = wire::Rd::new(blob);
        anyhow::ensure!(rd.u8()? == CKPT_TAG, "checkpoint blob is not EF21+ master state");
        wire::read_f64s_into(&mut rd, &mut self.x)?;
        let n = rd.u32()? as usize;
        anyhow::ensure!(n == self.g_i.len(), "EF21+ master blob has {n} mirrors, run has {}", self.g_i.len());
        for gi in self.g_i.iter_mut() {
            wire::read_f64s_into(&mut rd, gi)?;
        }
        wire::read_f64s_into(&mut rd, &mut self.g_sum)?;
        rd.done()
    }
}

pub fn build(
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    build_with(x0, oracles, c, gamma, seed, &BuildOpts::default())
}

/// [`build`] with structural options. Workers keep per-block state; the
/// master's absorb stays sequential — its assignment branch rewrites a
/// whole per-worker mirror (`g_sum -= old g_i; g_i = dense(b); g_sum +=
/// g_i`), a read-modify-write across the full vector that the disjoint
/// block-tile argument does not cover.
pub fn build_with(
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
    opts: &BuildOpts,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let n = oracles.len();
    let layout = opts.layout_for(x0.len());
    let mut base = Rng::seed(seed);
    let workers: Vec<Box<dyn WorkerNode>> = oracles
        .into_iter()
        .enumerate()
        .map(|(i, o)| {
            Box::new(Ef21PlusWorker::with_layout(
                o,
                c.clone(),
                base.fork(i as u64),
                layout.clone(),
            )) as Box<dyn WorkerNode>
        })
        .collect();
    let master = Box::new(Ef21PlusMaster::new(x0, n, gamma));
    (master, workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::TopK;
    use crate::coordinator::runner::{run_protocol, RunConfig};
    use crate::oracle::quadratic::divergence_example;
    use crate::oracle::GradOracle;

    fn quads() -> Vec<Box<dyn GradOracle>> {
        divergence_example()
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradOracle>)
            .collect()
    }

    /// Per-worker distortion can never exceed plain EF21's: the worker
    /// takes the min of the two branches by construction.
    #[test]
    fn branch_choice_never_worse_than_markov() {
        let mut rng = Rng::seed(0);
        let mut w = Ef21PlusWorker::new(
            quads().remove(0),
            Arc::new(TopK::new(1)) as Arc<dyn Compressor>,
            rng.fork(0),
        );
        let mut markov = crate::compress::Markov::new(TopK::new(1), 3);
        let mut x = vec![1.0; 3];
        for t in 0..50 {
            w.round(&x);
            // Run the plain Markov compressor on the same gradient stream.
            let grad = w.last_grad().to_vec();
            markov.step(&grad, &mut rng);
            let plus = w.distortion_sq().unwrap();
            let plain = markov.distortion_sq(&grad);
            assert!(plus <= plain + 1e-12, "t={t}: {plus} > {plain}");
            x[t % 3] -= 0.05;
        }
    }

    /// Master mirrors track worker state exactly through both branches.
    #[test]
    fn master_mirror_consistency() {
        let gamma = 0.02;
        let mut m = Ef21PlusMaster::new(vec![1.0; 3], 3, gamma);
        let mut base = Rng::seed(3);
        let mut ws: Vec<Ef21PlusWorker> = quads()
            .into_iter()
            .map(|o| {
                Ef21PlusWorker::new(o, Arc::new(TopK::new(1)) as Arc<dyn Compressor>, base.fork(7))
            })
            .collect();
        let msgs: Vec<_> = ws.iter_mut().map(|w| w.init(&[1.0; 3])).collect();
        m.init_absorb(&msgs);
        for _ in 0..60 {
            let x = m.begin_round();
            let msgs: Vec<_> = ws.iter_mut().map(|w| w.round(&x)).collect();
            m.absorb(&msgs);
            for (i, w) in ws.iter().enumerate() {
                assert!(
                    linalg::dist_sq(&m.g_i[i], w.state_g()) < 1e-20,
                    "mirror {i} drifted"
                );
            }
            let avg = m.aggregate_g();
            let mut want = vec![0.0; 3];
            for w in &ws {
                linalg::axpy(1.0 / 3.0, w.state_g(), &mut want);
            }
            assert!(linalg::dist_sq(&avg, &want) < 1e-20);
        }
    }

    /// EF21+ converges on the divergence example (same guarantee as EF21).
    #[test]
    fn converges_on_divergence_example() {
        let gamma = crate::theory::stepsize_theorem1(16.0, 16.0, 1.0 / 3.0);
        let (m, ws) = build(vec![1.0; 3], quads(), Arc::new(TopK::new(1)), gamma, 5);
        let h = run_protocol(m, ws, &RunConfig::rounds(8000));
        assert!(h.records.last().unwrap().grad_norm_sq < 1e-12);
    }

    #[test]
    #[should_panic(expected = "deterministic")]
    fn rejects_randomized_compressor() {
        let _ = Ef21PlusWorker::new(
            quads().remove(0),
            Arc::new(crate::compress::RandK::new(1)) as Arc<dyn Compressor>,
            Rng::seed(0),
        );
    }
}
