//! EF (Algorithm 4) — the original error-feedback method of Seide et al.
//! (2014), in the paper's formulation.
//!
//! Worker i keeps the error accumulator `e_i`, communicates
//! `w_i^t = C(e_i^t + γ ∇f_i(x^t))` and updates
//! `e_i^{t+1} = e_i^t + γ ∇f_i(x^t) - w_i^t`. The master steps
//! `x^{t+1} = x^t - (1/n) Σ w_i^t` (the stepsize is folded into the
//! messages).

use super::{BuildOpts, MasterNode, WireMsg, WorkerNode};
use crate::blocks::{scatter_add_blocked, BlockLayout, ParamBlocks};
use crate::ckpt::wire;
use crate::compress::{Compressor, SparseVec};
use crate::oracle::GradOracle;
use crate::util::linalg;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct EfWorker {
    oracle: Box<dyn GradOracle>,
    c: Arc<dyn Compressor>,
    rng: Rng,
    gamma: f64,
    /// Error accumulator e_i, kept per block.
    e: ParamBlocks,
    last_loss: f64,
    /// Gradient buffer, written in place every round.
    last_grad: Vec<f64>,
    /// Scratch: v = e + gamma * grad (reused across rounds).
    v: Vec<f64>,
}

impl EfWorker {
    pub fn new(oracle: Box<dyn GradOracle>, c: Arc<dyn Compressor>, gamma: f64, rng: Rng) -> Self {
        let layout = Arc::new(BlockLayout::flat(oracle.dim()));
        Self::with_layout(oracle, c, gamma, rng, layout)
    }

    pub fn with_layout(
        oracle: Box<dyn GradOracle>,
        c: Arc<dyn Compressor>,
        gamma: f64,
        rng: Rng,
        layout: Arc<BlockLayout>,
    ) -> Self {
        let d = oracle.dim();
        assert_eq!(layout.d(), d, "layout dimension mismatch");
        EfWorker {
            oracle,
            c,
            rng,
            gamma,
            e: ParamBlocks::zeros(layout),
            last_loss: 0.0,
            last_grad: vec![0.0; d],
            v: vec![0.0; d],
        }
    }

    pub fn error(&self) -> &[f64] {
        self.e.as_slice()
    }
}

impl WorkerNode for EfWorker {
    fn init(&mut self, x0: &[f64]) -> WireMsg {
        // e^0 = 0, w^0 = C(γ ∇f(x^0)): identical to a regular round.
        self.round(x0)
    }

    fn round(&mut self, x: &[f64]) -> WireMsg {
        let mut out = WireMsg::empty();
        self.round_into(x, &mut out);
        out
    }

    fn round_into(&mut self, x: &[f64], out: &mut WireMsg) {
        self.last_loss = self.oracle.loss_grad_into(x, &mut self.last_grad);
        // v = e + γ grad, per block (shared kernel; bit-identical to
        // the legacy flat loop — see ParamBlocks::affine_into).
        self.e.affine_into(self.gamma, &self.last_grad, &mut self.v);
        let comp = out.reset_sparse();
        self.c.compress_into(&self.v, &mut self.rng, comp);
        // e <- v - w
        self.e.as_mut_slice().copy_from_slice(&self.v);
        comp.sparse.add_scaled_into(-1.0, self.e.as_mut_slice());
    }

    fn last_loss(&self) -> f64 {
        self.last_loss
    }

    fn last_grad(&self) -> &[f64] {
        &self.last_grad
    }

    // The error accumulator is not message-reconstructible (no resync),
    // but it checkpoints fine: the blob serializes e_i directly.
    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        wire::put_u8(out, CKPT_TAG);
        wire::put_rng(out, &self.rng);
        wire::put_f64(out, self.last_loss);
        wire::put_f64s(out, &self.last_grad);
        wire::put_f64s(out, self.e.as_slice());
        Ok(())
    }

    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let mut rd = wire::Rd::new(blob);
        anyhow::ensure!(rd.u8()? == CKPT_TAG, "checkpoint blob is not EF worker state");
        self.rng = wire::read_rng(&mut rd)?;
        self.last_loss = rd.f64()?;
        wire::read_f64s_into(&mut rd, &mut self.last_grad)?;
        wire::read_f64s_into(&mut rd, self.e.as_mut_slice())?;
        rd.done()
    }
}

/// Blob discriminator shared by the EF worker and master state blobs.
const CKPT_TAG: u8 = 0x0E;

pub struct EfMaster {
    x: Vec<f64>,
    /// u = (1/n) Σ w_i from the previous absorb (already γ-scaled).
    u: ParamBlocks,
    n: usize,
    threads: usize,
}

impl EfMaster {
    pub fn new(x0: Vec<f64>, n: usize) -> Self {
        let layout = Arc::new(BlockLayout::flat(x0.len()));
        Self::with_layout(x0, n, layout, 1)
    }

    pub fn with_layout(
        x0: Vec<f64>,
        n: usize,
        layout: Arc<BlockLayout>,
        threads: usize,
    ) -> Self {
        assert_eq!(layout.d(), x0.len(), "layout dimension mismatch");
        EfMaster { x: x0, u: ParamBlocks::zeros(layout), n, threads: threads.max(1) }
    }
}

impl MasterNode for EfMaster {
    fn x(&self) -> &[f64] {
        &self.x
    }

    fn init_absorb(&mut self, msgs: &[WireMsg]) {
        self.absorb(msgs);
    }

    fn begin_round(&mut self) -> Vec<f64> {
        let mut out = Vec::new();
        self.begin_round_into(&mut out);
        out
    }

    // The one copy of the step (begin_round wraps this, so the two
    // entry points cannot drift).
    fn begin_round_into(&mut self, out: &mut Vec<f64>) {
        linalg::axpy(-1.0, self.u.as_slice(), &mut self.x);
        out.clear();
        out.extend_from_slice(&self.x);
    }

    fn absorb(&mut self, msgs: &[WireMsg]) {
        debug_assert_eq!(msgs.len(), self.n);
        self.u.as_mut_slice().iter_mut().for_each(|v| *v = 0.0);
        let inv_n = 1.0 / self.n as f64;
        if self.u.layout().is_flat() {
            for m in msgs {
                m.payload().sparse.add_scaled_into(inv_n, self.u.as_mut_slice());
            }
            return;
        }
        let payloads: Vec<&SparseVec> = msgs.iter().map(|m| &m.payload().sparse).collect();
        let layout = self.u.layout().clone();
        scatter_add_blocked(self.u.as_mut_slice(), &layout, &payloads, inv_n, self.threads);
    }

    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        wire::put_u8(out, CKPT_TAG);
        wire::put_f64s(out, &self.x);
        wire::put_f64s(out, self.u.as_slice());
        Ok(())
    }

    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let mut rd = wire::Rd::new(blob);
        anyhow::ensure!(rd.u8()? == CKPT_TAG, "checkpoint blob is not EF master state");
        wire::read_f64s_into(&mut rd, &mut self.x)?;
        wire::read_f64s_into(&mut rd, self.u.as_mut_slice())?;
        rd.done()
    }
}

pub fn build(
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    build_with(x0, oracles, c, gamma, seed, &BuildOpts::default())
}

/// [`build`] with structural options (block layout, absorb fan-out).
pub fn build_with(
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
    opts: &BuildOpts,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let n = oracles.len();
    let layout = opts.layout_for(x0.len());
    let mut base = Rng::seed(seed);
    let workers: Vec<Box<dyn WorkerNode>> = oracles
        .into_iter()
        .enumerate()
        .map(|(i, o)| {
            Box::new(EfWorker::with_layout(
                o,
                c.clone(),
                gamma,
                base.fork(i as u64),
                layout.clone(),
            )) as Box<dyn WorkerNode>
        })
        .collect();
    let master = Box::new(EfMaster::with_layout(x0, n, layout, opts.threads));
    (master, workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};
    use crate::coordinator::runner::{run_protocol, RunConfig};

    fn quads() -> Vec<Box<dyn GradOracle>> {
        crate::oracle::quadratic::divergence_example()
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradOracle>)
            .collect()
    }

    /// With identity compression EF is plain distributed GD.
    #[test]
    fn identity_is_gd() {
        let gamma = 0.02;
        let (mut m, mut ws) = build(vec![1.0; 3], quads(), Arc::new(Identity), gamma, 0);
        let msgs: Vec<_> = ws.iter_mut().map(|w| w.init(&[1.0; 3])).collect();
        m.init_absorb(&msgs);
        let mut x_ref = vec![1.0; 3];
        let mut oracles = quads();
        for _ in 0..30 {
            let x = m.begin_round();
            let mut g = vec![0.0; 3];
            for o in oracles.iter_mut() {
                let (_, gi) = o.loss_grad(&x_ref);
                linalg::axpy(1.0 / 3.0, &gi, &mut g);
            }
            linalg::axpy(-gamma, &g, &mut x_ref);
            assert!(linalg::dist_sq(&x, &x_ref) < 1e-20);
            let msgs: Vec<_> = ws.iter_mut().map(|w| w.round(&x)).collect();
            m.absorb(&msgs);
        }
    }

    /// Theorem 3 (restricted equivalence): with a deterministic, positively
    /// homogeneous AND additive compressor, EF and EF21 generate identical
    /// iterates. Identity is such a compressor.
    #[test]
    fn theorem3_equivalence_under_additive_compressor() {
        let gamma = 0.015;
        let (m1, w1) = build(vec![0.7; 3], quads(), Arc::new(Identity), gamma, 0);
        let (m2, w2) =
            crate::algo::ef21::build(vec![0.7; 3], quads(), Arc::new(Identity), gamma, 0);
        let h1 = run_protocol(m1, w1, &RunConfig::rounds(20));
        let h2 = run_protocol(m2, w2, &RunConfig::rounds(20));
        for (a, b) in h1.records.iter().zip(&h2.records) {
            assert!((a.loss - b.loss).abs() < 1e-12, "EF vs EF21 diverged under additivity");
        }
    }

    /// Top-k is NOT additive; the equivalence must break (sanity that the
    /// two methods are genuinely different).
    #[test]
    fn ef_and_ef21_differ_under_topk() {
        let gamma = 0.02;
        let (m1, w1) = build(vec![0.7; 3], quads(), Arc::new(TopK::new(1)), gamma, 0);
        let (m2, w2) =
            crate::algo::ef21::build(vec![0.7; 3], quads(), Arc::new(TopK::new(1)), gamma, 0);
        let h1 = run_protocol(m1, w1, &RunConfig::rounds(30));
        let h2 = run_protocol(m2, w2, &RunConfig::rounds(30));
        let diff: f64 = h1
            .records
            .iter()
            .zip(&h2.records)
            .map(|(a, b)| (a.loss - b.loss).abs())
            .sum();
        assert!(diff > 1e-9, "EF and EF21 should differ under Top-k");
    }
}
