//! The distributed optimization algorithms, factored into per-worker and
//! master state machines joined by a uniform round protocol:
//!
//! ```text
//!   init:   every worker sends init(x^0);      master absorbs (state g^0/u^0)
//!   round t: master begin_round() -> x^{t+1};   broadcast
//!            every worker round(x^{t+1}) -> msg; uplink (metered in bits)
//!            master absorb(msgs)                (state g^{t+1}/u^{t+1})
//! ```
//!
//! Instances:
//!   * [`ef21`]     — Algorithm 2 (the paper's contribution)
//!   * [`ef21plus`] — Algorithm 3 (hybrid C / Markov, §3.5)
//!   * [`ef`]       — Algorithm 4 (classic error feedback, Seide et al.)
//!   * [`dcgd`]     — Eq. (7) (naive compressed GD; diverges) and GD
//!                    (identity compressor)
//!
//! The stochastic variant (Algorithm 5) is EF21 composed with
//! [`crate::oracle::StochasticOracle`] — the mechanism is oracle-agnostic.

pub mod dcgd;
pub mod ef;
pub mod ef21;
pub mod ef21plus;

use crate::blocks::BlockLayout;
use crate::compress::{Compressed, Compressor};
use crate::oracle::GradOracle;
use std::sync::Arc;

/// Structural options shared by every algorithm builder.
#[derive(Clone, Debug)]
pub struct BuildOpts {
    /// Block partition of the parameter space (`None` = the exact legacy
    /// flat path). Workers keep their Markov/error state per block and
    /// the masters aggregate block-by-block; a single-block layout is
    /// bit-identical to `None`.
    pub layout: Option<Arc<BlockLayout>>,
    /// Fan-out width for the masters' block-parallel absorb tiles
    /// (ignored for flat layouts; bit-identical at any width — see
    /// [`crate::blocks::scatter_add_blocked`]).
    pub threads: usize,
    /// EF21 only: initialize with the full gradient (`g_i^0 = ∇f_i(x^0)`,
    /// one dense init message) instead of `C(∇f_i(x^0))`.
    pub full_init: bool,
}

impl Default for BuildOpts {
    fn default() -> Self {
        BuildOpts { layout: None, threads: 1, full_init: false }
    }
}

impl BuildOpts {
    /// Resolve the effective layout for dimension `d` (flat when unset).
    pub fn layout_for(&self, d: usize) -> Arc<BlockLayout> {
        match &self.layout {
            Some(l) => {
                assert_eq!(l.d(), d, "block layout dimension mismatch");
                l.clone()
            }
            None => Arc::new(BlockLayout::flat(d)),
        }
    }
}

/// One uplink message (worker -> master), with exact wire-bit accounting.
#[derive(Clone, Debug)]
pub enum WireMsg {
    /// Plain compressed payload.
    Sparse(Compressed),
    /// EF21+ message: payload plus the branch tag (1 extra bit).
    /// `dcgd_branch = true` means "the payload IS my new state g_i"
    /// (assignment); `false` means "the payload is a Markov delta" (add).
    Tagged { dcgd_branch: bool, payload: Compressed },
}

impl WireMsg {
    /// An empty Sparse message — the placeholder seed of every reusable
    /// message slot (empty `Vec`s do not allocate).
    pub fn empty() -> WireMsg {
        WireMsg::Sparse(Compressed::empty())
    }

    pub fn bits(&self) -> u64 {
        match self {
            WireMsg::Sparse(c) => c.bits,
            WireMsg::Tagged { payload, .. } => payload.bits + 1,
        }
    }

    pub fn payload(&self) -> &Compressed {
        match self {
            WireMsg::Sparse(c) => c,
            WireMsg::Tagged { payload, .. } => payload,
        }
    }

    /// Reshape `self` into a `Sparse` message, keeping whatever payload
    /// buffers it already owns (a `Tagged` slot's payload migrates), and
    /// return the inner [`Compressed`] for in-place overwrite — the
    /// allocation-free [`WorkerNode::round_into`] target.
    pub fn reset_sparse(&mut self) -> &mut Compressed {
        if matches!(self, WireMsg::Tagged { .. }) {
            let prev = std::mem::replace(self, WireMsg::empty());
            let WireMsg::Tagged { payload, .. } = prev else { unreachable!() };
            *self = WireMsg::Sparse(payload);
        }
        let WireMsg::Sparse(c) = self else { unreachable!() };
        c
    }

    /// Like [`WireMsg::reset_sparse`], but shaping a `Tagged` message
    /// with the given branch bit (EF21+'s wire format).
    pub fn reset_tagged(&mut self, dcgd_branch: bool) -> &mut Compressed {
        if matches!(self, WireMsg::Sparse(_)) {
            let prev = std::mem::replace(self, WireMsg::empty());
            let WireMsg::Sparse(payload) = prev else { unreachable!() };
            *self = WireMsg::Tagged { dcgd_branch, payload };
        }
        let WireMsg::Tagged { dcgd_branch: tag, payload } = self else { unreachable!() };
        *tag = dcgd_branch;
        payload
    }
}

/// Grow/shrink a reusable message buffer to exactly `n` slots (new slots
/// are empty placeholders; existing slots keep their allocations).
pub fn ensure_msg_slots(msgs: &mut Vec<WireMsg>, n: usize) {
    msgs.resize_with(n, WireMsg::empty);
}

/// Worker-side state machine.
///
/// `Send` because both threaded runners ([`crate::coordinator::par`],
/// [`crate::coordinator::dist`]) move worker boxes onto pool/worker
/// threads; worker state is never shared, only owned, so `Sync` is not
/// required.
pub trait WorkerNode: Send {
    /// Produce the initialization message at `x^0` (runs the oracle).
    fn init(&mut self, x0: &[f64]) -> WireMsg;

    /// One communication round at the broadcast model `x`.
    fn round(&mut self, x: &[f64]) -> WireMsg;

    /// [`WorkerNode::round`] into a caller-owned message slot, reusing
    /// its buffers — the zero-allocation round path. Must write exactly
    /// what `round` would return (the in-tree algorithms implement
    /// `round` as a thin wrapper over this, so the two cannot drift);
    /// this default exists for exotic workers and simply forwards.
    fn round_into(&mut self, x: &[f64], out: &mut WireMsg) {
        *out = self.round(x);
    }

    // -- instrumentation (free: not counted as communication) --

    /// `f_i` at the last evaluated point.
    fn last_loss(&self) -> f64;

    /// `∇f_i` at the last evaluated point.
    fn last_grad(&self) -> &[f64];

    /// `||g_i - ∇f_i(x)||^2` for EF21-family workers (the G^t ingredient).
    fn distortion_sq(&self) -> Option<f64> {
        None
    }

    /// `||∇f_i(x) − g_i^{prev}||²` — the norm of the last compressor
    /// input, paired with [`WorkerNode::distortion_sq`]: their ratio is
    /// the Eq. 3 contraction check `‖C(v)−v‖² ≤ (1−α)‖v‖²` the health
    /// monitor evaluates per worker.
    fn contraction_ref_sq(&self) -> Option<f64> {
        None
    }

    /// EF21+: whether the last round took the DCGD branch.
    fn used_dcgd_branch(&self) -> Option<bool> {
        None
    }

    // -- scheduler hooks (partial participation & fault model) --

    /// The message an absent worker implicitly contributes under a
    /// participation schedule: a no-op for this algorithm's master (a
    /// zero Markov delta for the EF21 family), costing 0 accounted bits.
    /// EF21-PP semantics fall out of this: absorbing the no-op holds the
    /// worker's mirrored state `g_i^t` on the master.
    fn absent_msg(&self) -> WireMsg {
        WireMsg::Sparse(Compressed { sparse: crate::compress::SparseVec::empty(), bits: 0 })
    }

    /// Whether crash→resync is supported: the worker is stateless, or
    /// its uplink messages fully determine its state so the master's
    /// [`crate::sched::StateTracker`] can reconstruct it. Workers whose
    /// state is not message-derivable (classic EF's error accumulator
    /// depends on unsent gradients) must leave this `false`; schedulers
    /// with crash events are rejected for them up front.
    fn supports_resync(&self) -> bool {
        false
    }

    /// Model a crash: drop all local algorithm state, as a restarted
    /// process would. Cached instrumentation (last loss/gradient) and
    /// the RNG stream survive — they belong to the harness, not to the
    /// crashed process. Only called when [`Self::supports_resync`].
    fn crash(&mut self) {
        unreachable!("crash scheduled for a worker without resync support");
    }

    /// Restore state from the master's StateSync reconstruction (f64,
    /// exact). Only called when [`Self::supports_resync`].
    fn resync(&mut self, state: &[f64]) {
        let _ = state;
        unreachable!("resync scheduled for a worker without resync support");
    }

    // -- checkpoint hooks (durable run snapshots, `crate::ckpt`) --

    /// Append every piece of round-to-round state — algorithm state, the
    /// RNG stream position, cached instrumentation — to `out` as an
    /// opaque blob ([`crate::ckpt::wire`] encoding). Restoring the blob
    /// via [`Self::ckpt_load`] into a freshly built worker must continue
    /// the trajectory bitwise identically. Oracles, compressors, and
    /// layouts are rebuilt from configuration, not serialized.
    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        let _ = out;
        anyhow::bail!("this worker does not support checkpointing")
    }

    /// Restore state written by [`Self::ckpt_save`] on an identically
    /// configured worker.
    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let _ = blob;
        anyhow::bail!("this worker does not support checkpointing")
    }
}

/// Master-side state machine.
///
/// `Send` so whole trials (master included) can be fanned across the
/// experiment scheduler's threads ([`crate::exp::parallel_trials`]).
pub trait MasterNode: Send {
    /// Current model.
    fn x(&self) -> &[f64];

    /// Absorb the initialization messages.
    fn init_absorb(&mut self, msgs: &[WireMsg]);

    /// Take the step producing the model to broadcast this round.
    fn begin_round(&mut self) -> Vec<f64>;

    /// [`MasterNode::begin_round`] into a caller-owned buffer (cleared
    /// and refilled; its allocation is reused) — the zero-allocation
    /// broadcast path. Must leave `out` equal to what `begin_round`
    /// would have returned.
    fn begin_round_into(&mut self, out: &mut Vec<f64>) {
        let x = self.begin_round();
        out.clear();
        out.extend_from_slice(&x);
    }

    /// Absorb this round's uplink messages.
    fn absorb(&mut self, msgs: &[WireMsg]);

    // -- checkpoint hooks (durable run snapshots, `crate::ckpt`) --

    /// Append the master's full state (model + aggregate) to `out` as an
    /// opaque blob; see [`WorkerNode::ckpt_save`].
    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        let _ = out;
        anyhow::bail!("this master does not support checkpointing")
    }

    /// Restore state written by [`Self::ckpt_save`] on an identically
    /// configured master.
    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let _ = blob;
        anyhow::bail!("this master does not support checkpointing")
    }
}

/// Algorithm selector (CLI/config facing).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum AlgoSpec {
    Ef21,
    Ef21Plus,
    Ef,
    Dcgd,
    Gd,
}

impl AlgoSpec {
    pub fn parse(s: &str) -> anyhow::Result<AlgoSpec> {
        Ok(match s.trim().to_ascii_lowercase().as_str() {
            "ef21" => AlgoSpec::Ef21,
            "ef21+" | "ef21plus" | "ef21p" => AlgoSpec::Ef21Plus,
            "ef" | "ec" => AlgoSpec::Ef,
            "dcgd" | "cgd" => AlgoSpec::Dcgd,
            "gd" => AlgoSpec::Gd,
            other => anyhow::bail!("unknown algorithm '{other}' (ef21|ef21+|ef|dcgd|gd)"),
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            AlgoSpec::Ef21 => "EF21",
            AlgoSpec::Ef21Plus => "EF21+",
            AlgoSpec::Ef => "EF",
            AlgoSpec::Dcgd => "DCGD",
            AlgoSpec::Gd => "GD",
        }
    }

    pub const ALL: [AlgoSpec; 5] =
        [AlgoSpec::Ef21, AlgoSpec::Ef21Plus, AlgoSpec::Ef, AlgoSpec::Dcgd, AlgoSpec::Gd];
}

/// Build the (master, workers) pair for an algorithm.
///
/// `gamma` is the stepsize; `c` the shared compressor (GD ignores it and
/// uses identity); `seed` drives randomized compressors deterministically.
pub fn build(
    spec: AlgoSpec,
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    build_with(spec, x0, oracles, c, gamma, seed, &BuildOpts::default())
}

/// [`build`] with explicit structural options (block layout, absorb
/// fan-out, EF21 dense init). `BuildOpts::default()` is the exact legacy
/// path.
pub fn build_with(
    spec: AlgoSpec,
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
    opts: &BuildOpts,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    match spec {
        AlgoSpec::Ef21 => ef21::build_with(x0, oracles, c, gamma, seed, opts),
        AlgoSpec::Ef21Plus => ef21plus::build_with(x0, oracles, c, gamma, seed, opts),
        AlgoSpec::Ef => ef::build_with(x0, oracles, c, gamma, seed, opts),
        AlgoSpec::Dcgd => dcgd::build_with(x0, oracles, c, gamma, seed, opts),
        AlgoSpec::Gd => dcgd::build_with(
            x0,
            oracles,
            Arc::new(crate::compress::Identity),
            gamma,
            seed,
            opts,
        ),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn spec_parsing() {
        assert_eq!(AlgoSpec::parse("EF21").unwrap(), AlgoSpec::Ef21);
        assert_eq!(AlgoSpec::parse("ef21+").unwrap(), AlgoSpec::Ef21Plus);
        assert_eq!(AlgoSpec::parse("gd").unwrap(), AlgoSpec::Gd);
        assert!(AlgoSpec::parse("sgd??").is_err());
    }

    #[test]
    fn wire_bits_include_tag() {
        let c = Compressed {
            sparse: crate::compress::SparseVec::new(vec![0], vec![1.0]),
            bits: 64,
        };
        assert_eq!(WireMsg::Sparse(c.clone()).bits(), 64);
        assert_eq!(WireMsg::Tagged { dcgd_branch: true, payload: c }.bits(), 65);
    }
}
