//! DCGD (Eq. 7): naive distributed compressed gradient descent,
//! `x^{t+1} = x^t - (γ/n) Σ C(∇f_i(x^t))` — the method EF was invented to
//! fix. With biased compressors it can diverge exponentially
//! ([Beznosikov et al. 2020, Example 1]; reproduced in
//! `integration_convergence.rs`). With the identity compressor this is
//! exact distributed GD (the paper's GD baseline).

use super::{BuildOpts, MasterNode, WireMsg, WorkerNode};
use crate::blocks::{scatter_add_blocked, BlockLayout, ParamBlocks};
use crate::ckpt::wire;
use crate::compress::{Compressor, SparseVec};
use crate::oracle::GradOracle;
use crate::util::linalg;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct DcgdWorker {
    oracle: Box<dyn GradOracle>,
    c: Arc<dyn Compressor>,
    rng: Rng,
    last_loss: f64,
    /// Gradient buffer, written in place every round (DCGD is stateless
    /// otherwise — the compressor sees the raw gradient).
    last_grad: Vec<f64>,
}

impl DcgdWorker {
    pub fn new(oracle: Box<dyn GradOracle>, c: Arc<dyn Compressor>, rng: Rng) -> Self {
        let d = oracle.dim();
        DcgdWorker { oracle, c, rng, last_loss: 0.0, last_grad: vec![0.0; d] }
    }
}

impl WorkerNode for DcgdWorker {
    fn init(&mut self, x0: &[f64]) -> WireMsg {
        self.round(x0)
    }

    fn round(&mut self, x: &[f64]) -> WireMsg {
        let mut out = WireMsg::empty();
        self.round_into(x, &mut out);
        out
    }

    fn round_into(&mut self, x: &[f64], out: &mut WireMsg) {
        self.last_loss = self.oracle.loss_grad_into(x, &mut self.last_grad);
        self.c.compress_into(&self.last_grad, &mut self.rng, out.reset_sparse());
    }

    fn last_loss(&self) -> f64 {
        self.last_loss
    }

    fn last_grad(&self) -> &[f64] {
        &self.last_grad
    }

    // DCGD workers are stateless: crash and resync are both no-ops.
    fn supports_resync(&self) -> bool {
        true
    }

    fn crash(&mut self) {}

    fn resync(&mut self, _state: &[f64]) {}

    // DCGD has no Markov state; the blob only carries the RNG position
    // (rand-k consumes it) and the cached loss/grad observables.
    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        wire::put_u8(out, CKPT_TAG);
        wire::put_rng(out, &self.rng);
        wire::put_f64(out, self.last_loss);
        wire::put_f64s(out, &self.last_grad);
        Ok(())
    }

    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let mut rd = wire::Rd::new(blob);
        anyhow::ensure!(rd.u8()? == CKPT_TAG, "checkpoint blob is not DCGD worker state");
        self.rng = wire::read_rng(&mut rd)?;
        self.last_loss = rd.f64()?;
        wire::read_f64s_into(&mut rd, &mut self.last_grad)?;
        rd.done()
    }
}

/// Blob discriminator shared by the DCGD worker and master state blobs
/// (GD is DCGD with the identity compressor, so it shares the tag too).
const CKPT_TAG: u8 = 0x0D;

pub struct DcgdMaster {
    x: Vec<f64>,
    /// u = (1/n) Σ C(∇f_i) from the previous absorb.
    u: ParamBlocks,
    gamma: f64,
    n: usize,
    threads: usize,
}

impl DcgdMaster {
    pub fn new(x0: Vec<f64>, n: usize, gamma: f64) -> Self {
        let layout = Arc::new(BlockLayout::flat(x0.len()));
        Self::with_layout(x0, n, gamma, layout, 1)
    }

    pub fn with_layout(
        x0: Vec<f64>,
        n: usize,
        gamma: f64,
        layout: Arc<BlockLayout>,
        threads: usize,
    ) -> Self {
        assert_eq!(layout.d(), x0.len(), "layout dimension mismatch");
        DcgdMaster { x: x0, u: ParamBlocks::zeros(layout), gamma, n, threads: threads.max(1) }
    }
}

impl MasterNode for DcgdMaster {
    fn x(&self) -> &[f64] {
        &self.x
    }

    fn init_absorb(&mut self, msgs: &[WireMsg]) {
        self.absorb(msgs);
    }

    fn begin_round(&mut self) -> Vec<f64> {
        let mut out = Vec::new();
        self.begin_round_into(&mut out);
        out
    }

    // The one copy of the step (begin_round wraps this, so the two
    // entry points cannot drift).
    fn begin_round_into(&mut self, out: &mut Vec<f64>) {
        linalg::axpy(-self.gamma, self.u.as_slice(), &mut self.x);
        out.clear();
        out.extend_from_slice(&self.x);
    }

    fn absorb(&mut self, msgs: &[WireMsg]) {
        debug_assert_eq!(msgs.len(), self.n);
        self.u.as_mut_slice().iter_mut().for_each(|v| *v = 0.0);
        let inv_n = 1.0 / self.n as f64;
        if self.u.layout().is_flat() {
            for m in msgs {
                m.payload().sparse.add_scaled_into(inv_n, self.u.as_mut_slice());
            }
            return;
        }
        let payloads: Vec<&SparseVec> = msgs.iter().map(|m| &m.payload().sparse).collect();
        let layout = self.u.layout().clone();
        scatter_add_blocked(self.u.as_mut_slice(), &layout, &payloads, inv_n, self.threads);
    }

    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        wire::put_u8(out, CKPT_TAG);
        wire::put_f64s(out, &self.x);
        wire::put_f64s(out, self.u.as_slice());
        Ok(())
    }

    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let mut rd = wire::Rd::new(blob);
        anyhow::ensure!(rd.u8()? == CKPT_TAG, "checkpoint blob is not DCGD master state");
        wire::read_f64s_into(&mut rd, &mut self.x)?;
        wire::read_f64s_into(&mut rd, self.u.as_mut_slice())?;
        rd.done()
    }
}

pub fn build(
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    build_with(x0, oracles, c, gamma, seed, &BuildOpts::default())
}

/// [`build`] with structural options (block layout, absorb fan-out).
pub fn build_with(
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
    opts: &BuildOpts,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let n = oracles.len();
    let layout = opts.layout_for(x0.len());
    let mut base = Rng::seed(seed);
    let workers: Vec<Box<dyn WorkerNode>> = oracles
        .into_iter()
        .enumerate()
        .map(|(i, o)| {
            Box::new(DcgdWorker::new(o, c.clone(), base.fork(i as u64))) as Box<dyn WorkerNode>
        })
        .collect();
    let master = Box::new(DcgdMaster::with_layout(x0, n, gamma, layout, opts.threads));
    (master, workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};
    use crate::coordinator::runner::{run_protocol, RunConfig};
    use crate::oracle::quadratic::divergence_example;

    fn quads() -> Vec<Box<dyn GradOracle>> {
        divergence_example()
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradOracle>)
            .collect()
    }

    /// GD (= DCGD + identity) converges linearly on the quadratics.
    #[test]
    fn gd_converges_on_quadratics() {
        let (m, ws) = build(vec![1.0; 3], quads(), Arc::new(Identity), 0.05, 0);
        let h = run_protocol(m, ws, &RunConfig::rounds(600));
        assert!(
            h.records.last().unwrap().grad_norm_sq < 1e-16,
            "GD stalled: {}",
            h.records.last().unwrap().grad_norm_sq
        );
    }

    /// The headline failure mode: DCGD + Top-1 fails to converge on the
    /// divergence example (gradient norm stays bounded away from zero or
    /// blows up), at a stepsize where exact GD converges fine.
    #[test]
    fn dcgd_top1_fails_on_divergence_example() {
        let (m, ws) = build(vec![1.0; 3], quads(), Arc::new(TopK::new(1)), 0.05, 0);
        let h = run_protocol(m, ws, &RunConfig::rounds(3000));
        let tail_min = h
            .records
            .iter()
            .rev()
            .take(500)
            .map(|r| r.grad_norm_sq)
            .fold(f64::INFINITY, f64::min);
        assert!(
            tail_min > 1e-6 || !tail_min.is_finite(),
            "DCGD unexpectedly converged (tail min grad^2 = {tail_min})"
        );
    }
}
