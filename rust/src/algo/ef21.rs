//! EF21 (Algorithm 2) — the paper's main contribution.
//!
//! Worker i keeps `g_i` (its Markov-compressor state, mirrored by the
//! master), sends only `c_i^t = C(∇f_i(x^{t+1}) - g_i^t)` and updates
//! `g_i^{t+1} = g_i^t + c_i^t`. The master maintains `g^t = avg_i g_i^t`
//! incrementally (`g^{t+1} = g^t + avg_i c_i^t`) and steps
//! `x^{t+1} = x^t - γ g^t`.

use super::{BuildOpts, MasterNode, WireMsg, WorkerNode};
use crate::blocks::{scatter_add_blocked, BlockLayout, ParamBlocks};
use crate::ckpt::wire;
use crate::compress::{Compressor, SparseVec};
use crate::oracle::GradOracle;
use crate::util::linalg;
use crate::util::rng::Rng;
use std::sync::Arc;

pub struct Ef21Worker {
    oracle: Box<dyn GradOracle>,
    c: Arc<dyn Compressor>,
    rng: Rng,
    /// Local Markov state g_i, kept per block (mirrored by the master in
    /// aggregate). A flat (single-block) layout is the exact legacy
    /// state.
    g: ParamBlocks,
    last_loss: f64,
    /// Gradient buffer, written in place by `loss_grad_into` every round
    /// (no per-round allocation).
    last_grad: Vec<f64>,
    /// Scratch buffer for grad - g (reused across rounds).
    diff: Vec<f64>,
    /// Initialize with the FULL gradient (`g_i^0 = ∇f_i(x^0)`, one dense
    /// init message) instead of `C(∇f_i(x^0))`. Sanctioned by the paper
    /// ("our theorems hold for an arbitrary choice of g_i^0; if
    /// g_i^0 = ∇f_i(x^0), then E[G^0] = 0") — important at aggressive
    /// compression ratios (the DL experiment's k ≈ 0.05 D), where the
    /// compressed init otherwise costs a long warm-up.
    pub full_init: bool,
}

impl Ef21Worker {
    pub fn new(oracle: Box<dyn GradOracle>, c: Arc<dyn Compressor>, rng: Rng) -> Self {
        let layout = Arc::new(BlockLayout::flat(oracle.dim()));
        Self::with_layout(oracle, c, rng, layout)
    }

    /// Like [`Ef21Worker::new`], with Markov state partitioned by
    /// `layout` (the compressor is expected to share the partition).
    pub fn with_layout(
        oracle: Box<dyn GradOracle>,
        c: Arc<dyn Compressor>,
        rng: Rng,
        layout: Arc<BlockLayout>,
    ) -> Self {
        let d = oracle.dim();
        assert_eq!(layout.d(), d, "layout dimension mismatch");
        Ef21Worker {
            oracle,
            c,
            rng,
            g: ParamBlocks::zeros(layout),
            last_loss: 0.0,
            last_grad: vec![0.0; d],
            diff: vec![0.0; d],
            full_init: false,
        }
    }

    /// Current Markov state (tests / tracker).
    pub fn state_g(&self) -> &[f64] {
        self.g.as_slice()
    }
}

impl WorkerNode for Ef21Worker {
    fn init(&mut self, x0: &[f64]) -> WireMsg {
        if self.full_init {
            // g_i^0 = ∇f_i(x^0): one dense init message (d * 32 bits).
            self.last_loss = self.oracle.loss_grad_into(x0, &mut self.last_grad);
            self.g.as_mut_slice().copy_from_slice(&self.last_grad);
            let sparse = SparseVec::from_dense_full(&self.last_grad);
            let bits = self.last_grad.len() as u64 * 32;
            return WireMsg::Sparse(crate::compress::Compressed { sparse, bits });
        }
        // g_i^0 = C(∇f_i(x^0)); with g=0 this is exactly one round() step.
        self.round(x0)
    }

    fn round(&mut self, x: &[f64]) -> WireMsg {
        let mut out = WireMsg::empty();
        self.round_into(x, &mut out);
        out
    }

    fn round_into(&mut self, x: &[f64], out: &mut WireMsg) {
        self.last_loss = self.oracle.loss_grad_into(x, &mut self.last_grad);
        // diff = grad - g, per block (shared kernel; bit-identical to
        // the legacy flat loop — see ParamBlocks::sub_from_into).
        self.g.sub_from_into(&self.last_grad, &mut self.diff);
        let comp = out.reset_sparse();
        self.c.compress_into(&self.diff, &mut self.rng, comp);
        comp.sparse.add_into(self.g.as_mut_slice());
    }

    fn last_loss(&self) -> f64 {
        self.last_loss
    }

    fn last_grad(&self) -> &[f64] {
        &self.last_grad
    }

    fn distortion_sq(&self) -> Option<f64> {
        Some(linalg::dist_sq(self.g.as_slice(), &self.last_grad))
    }

    fn contraction_ref_sq(&self) -> Option<f64> {
        // `diff` still holds the last compressor input ∇f_i(x) − g_i^prev
        // (round_into only reads it after writing it).
        Some(linalg::dot(&self.diff, &self.diff))
    }

    // Crash model: g_i is exactly what the master's StateTracker mirrors
    // (every uplink is a delta against it), so resync is lossless.
    fn supports_resync(&self) -> bool {
        true
    }

    fn crash(&mut self) {
        self.g.as_mut_slice().iter_mut().for_each(|v| *v = 0.0);
    }

    fn resync(&mut self, state: &[f64]) {
        assert_eq!(state.len(), self.g.as_slice().len(), "StateSync dimension mismatch");
        self.g.as_mut_slice().copy_from_slice(state);
    }

    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        wire::put_u8(out, CKPT_TAG);
        wire::put_rng(out, &self.rng);
        wire::put_f64(out, self.last_loss);
        wire::put_f64s(out, &self.last_grad);
        wire::put_f64s(out, self.g.as_slice());
        Ok(())
    }

    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let mut rd = wire::Rd::new(blob);
        anyhow::ensure!(rd.u8()? == CKPT_TAG, "checkpoint blob is not EF21 worker state");
        self.rng = wire::read_rng(&mut rd)?;
        self.last_loss = rd.f64()?;
        wire::read_f64s_into(&mut rd, &mut self.last_grad)?;
        wire::read_f64s_into(&mut rd, self.g.as_mut_slice())?;
        rd.done()
    }
}

/// Blob discriminator shared by the EF21 worker and master state blobs.
const CKPT_TAG: u8 = 0x21;

pub struct Ef21Master {
    x: Vec<f64>,
    /// g^t = avg_i g_i^t, maintained incrementally from the deltas,
    /// partitioned like the workers' state.
    g: ParamBlocks,
    gamma: f64,
    n: usize,
    /// Fan-out width of the block-parallel absorb tile (1 = inline;
    /// bit-identical either way).
    threads: usize,
}

impl Ef21Master {
    pub fn new(x0: Vec<f64>, n: usize, gamma: f64) -> Self {
        let layout = Arc::new(BlockLayout::flat(x0.len()));
        Self::with_layout(x0, n, gamma, layout, 1)
    }

    pub fn with_layout(
        x0: Vec<f64>,
        n: usize,
        gamma: f64,
        layout: Arc<BlockLayout>,
        threads: usize,
    ) -> Self {
        assert_eq!(layout.d(), x0.len(), "layout dimension mismatch");
        Ef21Master { x: x0, g: ParamBlocks::zeros(layout), gamma, n, threads: threads.max(1) }
    }

    pub fn aggregate_g(&self) -> &[f64] {
        self.g.as_slice()
    }
}

impl MasterNode for Ef21Master {
    fn x(&self) -> &[f64] {
        &self.x
    }

    fn init_absorb(&mut self, msgs: &[WireMsg]) {
        // g^0 = avg_i g_i^0 (deltas against zero state).
        self.absorb(msgs);
    }

    fn begin_round(&mut self) -> Vec<f64> {
        let mut out = Vec::new();
        self.begin_round_into(&mut out);
        out
    }

    // The one copy of the step (begin_round wraps this, so the two
    // entry points cannot drift).
    fn begin_round_into(&mut self, out: &mut Vec<f64>) {
        linalg::axpy(-self.gamma, self.g.as_slice(), &mut self.x);
        out.clear();
        out.extend_from_slice(&self.x);
    }

    fn absorb(&mut self, msgs: &[WireMsg]) {
        debug_assert_eq!(msgs.len(), self.n);
        let inv_n = 1.0 / self.n as f64;
        if self.g.layout().is_flat() {
            // Exact legacy loop.
            for m in msgs {
                m.payload().sparse.add_scaled_into(inv_n, self.g.as_mut_slice());
            }
            return;
        }
        // Worker × block aggregation tile: per coordinate, messages are
        // still applied in worker order, so this is bit-identical to the
        // loop above at any thread count.
        let payloads: Vec<&SparseVec> = msgs.iter().map(|m| &m.payload().sparse).collect();
        let layout = self.g.layout().clone();
        scatter_add_blocked(self.g.as_mut_slice(), &layout, &payloads, inv_n, self.threads);
    }

    fn ckpt_save(&self, out: &mut Vec<u8>) -> anyhow::Result<()> {
        wire::put_u8(out, CKPT_TAG);
        wire::put_f64s(out, &self.x);
        wire::put_f64s(out, self.g.as_slice());
        Ok(())
    }

    fn ckpt_load(&mut self, blob: &[u8]) -> anyhow::Result<()> {
        let mut rd = wire::Rd::new(blob);
        anyhow::ensure!(rd.u8()? == CKPT_TAG, "checkpoint blob is not EF21 master state");
        wire::read_f64s_into(&mut rd, &mut self.x)?;
        wire::read_f64s_into(&mut rd, self.g.as_mut_slice())?;
        rd.done()
    }
}

pub fn build(
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    build_with(x0, oracles, c, gamma, seed, &BuildOpts::default())
}

/// Like [`build`], optionally with the dense-gradient initialization
/// `g_i^0 = ∇f_i(x^0)` (see [`Ef21Worker::full_init`]).
pub fn build_opts(
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
    full_init: bool,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let opts = BuildOpts { full_init, ..BuildOpts::default() };
    build_with(x0, oracles, c, gamma, seed, &opts)
}

/// [`build`] with full structural options (block layout, absorb fan-out,
/// dense init).
pub fn build_with(
    x0: Vec<f64>,
    oracles: Vec<Box<dyn GradOracle>>,
    c: Arc<dyn Compressor>,
    gamma: f64,
    seed: u64,
    opts: &BuildOpts,
) -> (Box<dyn MasterNode>, Vec<Box<dyn WorkerNode>>) {
    let n = oracles.len();
    let layout = opts.layout_for(x0.len());
    let mut base = Rng::seed(seed);
    let workers: Vec<Box<dyn WorkerNode>> = oracles
        .into_iter()
        .enumerate()
        .map(|(i, o)| {
            let mut w = Ef21Worker::with_layout(o, c.clone(), base.fork(i as u64), layout.clone());
            w.full_init = opts.full_init;
            Box::new(w) as Box<dyn WorkerNode>
        })
        .collect();
    let master = Box::new(Ef21Master::with_layout(x0, n, gamma, layout, opts.threads));
    (master, workers)
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::compress::{Identity, TopK};

    fn quad_oracles() -> Vec<Box<dyn GradOracle>> {
        crate::oracle::quadratic::divergence_example()
            .into_iter()
            .map(|q| Box::new(q) as Box<dyn GradOracle>)
            .collect()
    }

    /// With the identity compressor EF21 is exactly distributed GD.
    #[test]
    fn identity_compressor_reduces_to_gd() {
        let d = 3;
        let gamma = 0.02;
        let (mut master, mut workers) =
            build(vec![1.0; d], quad_oracles(), Arc::new(Identity), gamma, 0);
        // Reference GD.
        let mut x_ref = vec![1.0; d];
        let mut oracles = quad_oracles();

        let msgs: Vec<_> = workers.iter_mut().map(|w| w.init(&[1.0; 3])).collect();
        master.init_absorb(&msgs);
        for _ in 0..25 {
            let x = master.begin_round();
            // GD reference step.
            let mut g = vec![0.0; d];
            for o in oracles.iter_mut() {
                let (_, gi) = o.loss_grad(&x_ref);
                linalg::axpy(1.0 / 3.0, &gi, &mut g);
            }
            linalg::axpy(-gamma, &g, &mut x_ref);
            assert!(
                linalg::dist_sq(&x, &x_ref) < 1e-20,
                "EF21+identity diverged from GD"
            );
            let msgs: Vec<_> = workers.iter_mut().map(|w| w.round(&x)).collect();
            master.absorb(&msgs);
        }
    }

    /// Master's incremental aggregate equals the true average of worker
    /// states after every round (the core protocol invariant).
    #[test]
    fn master_aggregate_matches_worker_average() {
        let d = 3;
        let (mut master, mut workers) =
            build(vec![0.5; d], quad_oracles(), Arc::new(TopK::new(1)), 0.01, 1);
        let msgs: Vec<_> = workers.iter_mut().map(|w| w.init(&[0.5; 3])).collect();
        master.init_absorb(&msgs);
        for _ in 0..40 {
            let x = master.begin_round();
            let msgs: Vec<_> = workers.iter_mut().map(|w| w.round(&x)).collect();
            master.absorb(&msgs);
        }
        // Recover the concrete master to compare aggregates.
        // (build returns trait objects; rebuild concretely instead.)
        let mut m2 = Ef21Master::new(vec![0.5; d], 3, 0.01);
        let mut ws: Vec<Ef21Worker> = quad_oracles()
            .into_iter()
            .enumerate()
            .map(|(i, o)| {
                let c = Arc::new(TopK::new(1)) as Arc<dyn Compressor>;
                Ef21Worker::new(o, c, Rng::seed(i as u64))
            })
            .collect();
        let msgs: Vec<_> = ws.iter_mut().map(|w| w.init(&[0.5; 3])).collect();
        m2.init_absorb(&msgs);
        for _ in 0..40 {
            let x = m2.begin_round();
            let msgs: Vec<_> = ws.iter_mut().map(|w| w.round(&x)).collect();
            m2.absorb(&msgs);
            let mut avg = vec![0.0; d];
            for w in &ws {
                linalg::axpy(1.0 / 3.0, w.state_g(), &mut avg);
            }
            assert!(
                linalg::dist_sq(m2.aggregate_g(), &avg) < 1e-18,
                "master g drifted from avg of worker g_i"
            );
        }
    }

    /// EF21 with Top-1 converges on the divergence example that kills DCGD.
    #[test]
    fn converges_on_divergence_example() {
        let d = 3;
        // L_i = 16 for all three quadratics, alpha = 1/3.
        let l = 16.0;
        let gamma = crate::theory::stepsize_theorem1(l, l, 1.0 / 3.0);
        let (mut master, mut workers) =
            build(vec![1.0; d], quad_oracles(), Arc::new(TopK::new(1)), gamma, 2);
        let msgs: Vec<_> = workers.iter_mut().map(|w| w.init(&[1.0; 3])).collect();
        master.init_absorb(&msgs);
        let mut grad_norm = f64::INFINITY;
        for _ in 0..8000 {
            let x = master.begin_round();
            let msgs: Vec<_> = workers.iter_mut().map(|w| w.round(&x)).collect();
            master.absorb(&msgs);
            let mut g = vec![0.0; d];
            for w in &workers {
                linalg::axpy(1.0 / 3.0, w.last_grad(), &mut g);
            }
            grad_norm = linalg::norm2(&g);
        }
        assert!(grad_norm < 1e-6, "EF21 failed to converge: ||grad||={grad_norm}");
    }
}
