//! `ef21` launcher: run single training jobs, the paper's experiment
//! suite, or inspect datasets/artifacts.
//!
//! ```text
//! ef21 run   [--algo ef21|ef21+|ef|dcgd|gd] [--k 1 | --compressor top1]
//!            [--dataset a9a] [--workers 20] [--gamma-mult 1] [--rounds N]
//!            [--objective logreg|lstsq] [--csv out.csv] [--transport local|tcp]
//!            [--master threads|reactor]
//!            [--threads n|auto] [--blocks flat|auto|<n>|name:len,...]
//!            [--health off|every:<r>[,...]] [--ops <port>]
//! ef21 exp   <stepsize|finetune|kdep|gdtune|lstsq|rates|dl> [flags...]
//! ef21 bench [--json FILE] [--quick] [--fleet-n N,N,...]
//! ef21 data  info
//! ef21 artifacts [--dir artifacts]
//! ```

use anyhow::Result;
use ef21::config::cli::Args;
use ef21::config::RunSpec;
use ef21::exp;

fn main() {
    let args = Args::from_env();
    if let Err(e) = dispatch(&args) {
        eprintln!("error: {e:#}");
        std::process::exit(1);
    }
}

fn dispatch(args: &Args) -> Result<()> {
    // Global transport timeout knob (read/write + connect retry; the
    // scheduler's straggler-deadline floor reuses the same value).
    if let Some(ms) = ef21::config::net_timeout_ms_from_args(args)? {
        ef21::transport::tcp::set_default_io_timeout_ms(Some(ms));
    }
    // Global telemetry sinks (shared by every subcommand).
    let telemetry_spec = args.get_str("telemetry").unwrap_or("off").to_string();
    let guard = ef21::telemetry::init_from_spec(&telemetry_spec)?;
    if let Some(port) = guard.prom_port() {
        eprintln!("telemetry: serving prometheus text on 127.0.0.1:{port}");
    }
    if let Some(path) = guard.jsonl_path() {
        eprintln!("telemetry: writing jsonl snapshots to {}", path.display());
    }
    if let Some(path) = guard.trace_path() {
        eprintln!(
            "telemetry: tracing round phases to {} (open in Perfetto / chrome://tracing)",
            path.display()
        );
    }
    // Live ops endpoint (push-gated like telemetry: when absent the
    // runners' publish calls are single-atomic-load no-ops).
    let ops = match args.get_parse::<u16>("ops")? {
        Some(port) => {
            let srv = ef21::health::ops::OpsServer::bind(port)?;
            eprintln!(
                "ops: serving /health /status /workers on 127.0.0.1:{}",
                srv.port()
            );
            Some(srv)
        }
        None => None,
    };

    let result = match args.positional.first().map(|s| s.as_str()) {
        Some("run") => cmd_run(args),
        Some("exp") => cmd_exp(args),
        Some("bench") => ef21::bench::main(args),
        Some("data") => cmd_data(args),
        Some("artifacts") => cmd_artifacts(args),
        _ => {
            eprintln!("{}", HELP);
            Ok(())
        }
    };
    // Final flush even on command error; surface whichever failed first.
    if let Some(srv) = ops {
        srv.stop();
    }
    let shutdown = guard.shutdown();
    result.and(shutdown)
}

const HELP: &str = "\
ef21 — EF21 (NeurIPS 2021) reproduction

USAGE:
  ef21 run  [--algo A] [--k K] [--dataset D] [--workers N] [--gamma-mult M]
            [--rounds T] [--objective logreg|lstsq] [--csv FILE]
            [--transport local|tcp]
            [--checkpoint FILE [--checkpoint-every R]] [--resume FILE]
  (all commands) [--telemetry off|jsonl:<path>|tcp:<port>|trace:<path>[,...]]
                                      (jsonl/tcp sinks take an optional
                                       @<prefix> key filter, e.g.
                                       jsonl:w.jsonl@coordinator.worker;
                                       trace: writes chrome://tracing
                                       JSON — open in Perfetto)
  (all commands) [--ops PORT]         (live ops endpoint: HTTP JSON on
                                       127.0.0.1:PORT — /health gives the
                                       Theorem 1 verdict, /status the run
                                       position, /workers the per-worker
                                       G contributions; 0 = ephemeral)
  (run)          [--health off|every:<r>[,window:<w>][,tol:<x>]
                          [,blackbox:<dir>]]
                                      (theory-grounded monitor: every r
                                       rounds compute G^t, the Lyapunov
                                       value f(x)+(gamma/theta)G, and
                                       per-worker contraction ratios vs
                                       the (1-alpha) bound; windowed
                                       anomaly rules; anomalies,
                                       divergence, killmaster, and worker
                                       errors dump an ef21.blackbox/v1
                                       flight-recorder JSON under <dir>.
                                       off [default] is bit-identical to
                                       builds without the monitor)
  (sim run + sweep exps)
                 [--threads n|auto]   (auto = all cores; 1 = sequential;
                                       results are bit-identical either way;
                                       transport runs are already threaded,
                                       rates/dl run single trials)
  (run + exp dl)
                 [--blocks flat|auto|<n>|name:len,...]
                                      (parameter partition: layer-wise
                                       compression + per-block state +
                                       delta broadcast; flat = legacy path,
                                       auto = oracle's natural layout —
                                       per-layer for dl, flat for logreg)
  (run + sweep exps)
                 [--participation full|p:<f>|m:<k>|rr:<c>]
                                      (round participation: Bernoulli-p,
                                       fixed-m, or round-robin cohorts;
                                       absent workers hold their state —
                                       EF21-PP semantics. full = legacy)
                 [--faults <spec>]    (deterministic fault schedule:
                                       crash@R,rejoin@R,
                                       straggle(w,r0..r1,MSms),
                                       drop(w@r), dup(w@r),
                                       killmaster@R — master aborts at the
                                       start of round R; restart with
                                       --resume and the trajectory is
                                       bitwise identical)
                 [--deadline-ms D]    (straggler cutoff per round; unset =
                                       barrier waits; with straggles it
                                       floors to the net timeout)
  (run)          [--checkpoint FILE]  (write a durable snapshot at the end
                                       of every --checkpoint-every R rounds
                                       [default 1]; atomic tmp+rename, so a
                                       crash mid-write never corrupts the
                                       last good snapshot)
                 [--resume FILE]      (restart from a snapshot: checksum +
                                       run-fingerprint verified, then the
                                       run continues bitwise-identically to
                                       one that was never interrupted; drop
                                       any killmaster clause when resuming)
  (transports)   [--net-timeout-ms T] (TCP read/write + connect-retry
                                       budget; 0 = no timeout; env
                                       fallback EF21_NET_TIMEOUT_MS)
                 [--master threads|reactor]
                                      (master engine: threads = one OS
                                       thread per connection [legacy];
                                       reactor = sharded nonblocking
                                       poller multiplexing every
                                       connection — same protocol,
                                       bit-identical trajectories, scales
                                       to thousands of workers. reactor
                                       drives the plain flat path: no
                                       --participation/--faults/--blocks/
                                       --checkpoint; sessions and soft
                                       chaos work, but not down() clauses
                                       or --on-worker-loss degrade/wait)
                 [--session on|off]   (self-healing transport sessions:
                                       CRC32-enveloped, sequence-numbered
                                       frames; a dropped or corrupted
                                       link reconnects with jittered
                                       backoff and replays the missing
                                       frames, falling back to an exact
                                       state resync. off [default] is the
                                       byte-identical legacy wire; auto-
                                       enabled by the three flags below)
                 [--chaos <spec>]     (seeded in-process wire-fault
                                       injection: reset(w@r) severs w's
                                       link in round r, corrupt(w@r)
                                       flips a payload bit, stall(w,
                                       r0..r1,MSms) delays I/O, down(w@r)
                                       kills the worker for good;
                                       deterministic from (spec, seed,
                                       round) — a recovered run is
                                       bitwise identical to fault-free)
                 [--on-worker-loss abort|degrade:<grace_ms>|wait]
                                      (master policy when a worker
                                       exhausts its reconnect budget:
                                       abort [default] fails the run;
                                       degrade waits <grace_ms> then
                                       treats the worker as absent from
                                       then on — exact EF21-PP semantics,
                                       same trajectory as a
                                       --participation schedule that
                                       excludes it; wait retries forever)
                 [--min-workers N]    (quorum floor for degrade: fewer
                                       than N live workers dumps the
                                       flight recorder and aborts with a
                                       pointer to the last checkpoint)
  ef21 exp  stepsize [--dataset D] [--k K] [--max-pow P] [--rounds T] [--all]
  ef21 exp  finetune [--dataset D] [--rounds T] [--tol X]
  ef21 exp  kdep     [--dataset D] [--rounds T]
  ef21 exp  gdtune   [--dataset D] [--rounds T] [--max-pow P]
  ef21 exp  lstsq    [--dataset D] [--k K] [--max-pow P] [--rounds T]
  ef21 exp  pp       [--dataset D] [--rounds T] [--workers N]
                     [--p 1.0,0.5,0.1] [--compressors top1,top8,rand8]
                     (EF21-PP sweep: participation x compressor x
                      iid/het shards at the PP theory stepsize)
  ef21 exp  rates    [--rounds T]    (theory checks; always full rounds)
  ef21 exp  dl       [--steps N] [--workers W] [--k-frac F] [--sweep-k]
  ef21 bench [--json FILE] [--quick] [--fleet-n N,N,...]
                                     (machine-readable perf trajectory:
                                      round-loop throughput seq/par at
                                      d=1e4/1e6, compressor zoo, blocked
                                      layout, participation sweep, fleet
                                      sweep [10^2..10^6 simulated
                                      clients: rounds/sec, RSS, mirror
                                      bytes] -> BENCH_round.json;
                                      --fleet-n runs only the fleet
                                      cases at the listed client counts;
                                      build with --features count-allocs
                                      for the allocs_per_round column)
  ef21 data info
  ef21 artifacts
";

fn cmd_run(args: &Args) -> Result<()> {
    let spec = RunSpec::from_args(args)?;
    let ckpt = ef21::config::CkptSpec::from_args(args)?;
    let net = ef21::config::NetSpec::from_args(args)?;
    let objective = match args.get_str("objective").unwrap_or("logreg") {
        "lstsq" => exp::Objective::Lstsq,
        _ => exp::Objective::LogReg,
    };
    // Validate the schedule against the run shape up front (a bad
    // --faults worker index should be a CLI error, not a mid-run panic).
    spec.sched.build(spec.n_workers, spec.seed)?;
    let mut problem =
        exp::Problem::new(&spec.dataset, objective, spec.n_workers, spec.lam, spec.seed);
    problem.sched = spec.sched.clone();
    // The natural layout is only materialized when `auto` actually
    // needs it (Problem::block_layout builds a shard oracle to ask).
    let layout = if spec.blocks == ef21::config::BlocksSpec::Auto {
        spec.blocks.resolve(problem.d(), Some(&problem.block_layout()))?
    } else {
        spec.blocks.resolve(problem.d(), None)?
    };
    let threads = spec.threads.resolve();
    // Fan-out 1: this instance only reports alpha; the runners build
    // their own (and the worker pool owns the thread budget).
    let c = ef21::compress::from_spec_blocked(&spec.compressor, &layout, 1)?;
    let alpha = c.alpha(problem.d());
    let gamma = spec
        .gamma_abs
        .unwrap_or_else(|| spec.gamma_mult * problem.theory_gamma(alpha));
    println!(
        "{} on {} ({} workers, d={}, blocks={}): L={:.4} Ltilde={:.4} alpha={:.4} gamma={:.5e}",
        spec.algo.name(),
        spec.dataset,
        spec.n_workers,
        problem.d(),
        layout.n_blocks(),
        problem.smoothness.l,
        problem.smoothness.l_tilde,
        alpha,
        gamma
    );

    let transport = args.get_str("transport").unwrap_or("sim");
    // The session layer wraps wire frames; sim has no wire.
    anyhow::ensure!(
        transport != "sim" || net.is_legacy(),
        "--session/--chaos/--on-worker-loss/--min-workers need a real transport \
         (--transport local|tcp)"
    );
    // Checkpoint identity: local and tcp are bit-identical (both are the
    // lockstep dist protocol), so a snapshot moves freely between them —
    // but never across the sim/dist boundary (downlink accounting
    // differs).
    let path_tag = if transport == "sim" { "sim" } else { "dist" };
    let mut ckpt_opts = ckpt.build(&spec.fingerprint(problem.d(), path_tag))?;
    // The monitor binds the run's actual (alpha, gamma) pair so the
    // contraction bound and Lyapunov coefficient match Theorem 1 exactly.
    ckpt_opts.health = spec.health.build(alpha, gamma);
    if let (Some(ck), Some(r)) = (&ckpt_opts.resume, spec.sched.faults.kill_master()) {
        anyhow::ensure!(
            r < ck.next_round,
            "--faults killmaster@{r} would kill the resumed run again (resume starts \
             at round {}); drop the killmaster clause when resuming",
            ck.next_round
        );
    }
    let history = if transport == "sim" {
        problem.run_trial_ckpt(
            spec.algo,
            &spec.compressor,
            spec.gamma_mult,
            spec.gamma_abs,
            spec.rounds,
            spec.record_every,
            spec.seed,
            threads,
            layout.clone(),
            ckpt_opts,
        )?
    } else {
        run_over_transport(&problem, &spec, &net, gamma, transport, layout.clone(), ckpt_opts)?
    };

    let last = history.records.last().expect("no rounds recorded");
    println!(
        "rounds={} bits/client={:.3e} downlink_bits={:.3e} f={:.6e} |grad|^2={:.3e} diverged={}",
        last.round + 1,
        last.bits_per_client,
        history.downlink_bits as f64,
        last.loss,
        last.grad_norm_sq,
        history.diverged()
    );
    if let Some(csv) = args.get_str("csv") {
        history.write_csv(std::path::Path::new(csv))?;
        println!("wrote {csv}");
    }
    // Tail diagnosis: when telemetry is on, name the slowest workers
    // (per-worker p50/p99/max round latency) next to the scheduler's
    // deadline counters.
    if ef21::telemetry::is_enabled() {
        if let Some(report) = ef21::telemetry::snapshot().render_straggler_report(5) {
            eprint!("{report}");
        }
    }
    Ok(())
}

/// Run over a real transport (threaded workers + local channels or TCP).
/// Blocked layouts ship the model as block-delta frames and the uplinks
/// block-tagged; flat layouts take the legacy dense broadcast.
fn run_over_transport(
    problem: &exp::Problem,
    spec: &RunSpec,
    net: &ef21::config::NetSpec,
    gamma: f64,
    transport: &str,
    layout: std::sync::Arc<ef21::blocks::BlockLayout>,
    ckpt_opts: ef21::coordinator::runner::CkptOptions,
) -> Result<ef21::metrics::History> {
    use ef21::coordinator::dist::{
        run_distributed_ckpt_net, run_distributed_sched_ckpt_net, Broadcast, LossPolicy,
        TransportKind,
    };
    let kind = match transport {
        "tcp" => TransportKind::Tcp,
        "local" => TransportKind::Local,
        other => anyhow::bail!("unknown transport '{other}' (sim|local|tcp)"),
    };
    anyhow::ensure!(
        spec.algo == ef21::algo::AlgoSpec::Ef21,
        "transport mode currently drives EF21 (the paper's method)"
    );
    let sched = spec.sched.build_for_transport(spec.n_workers, spec.seed)?;
    let netopts = net.build(spec.seed)?;
    if spec.master == ef21::config::MasterEngine::Reactor {
        // The reactor drives the plain lockstep protocol (dense
        // broadcast, every worker every round); the scheduler, blocked,
        // and checkpoint paths stay on the thread-per-connection engine.
        anyhow::ensure!(
            sched.is_none(),
            "--master reactor drives the plain protocol; drop \
             --participation/--faults/--deadline-ms or use --master threads"
        );
        anyhow::ensure!(
            layout.is_flat(),
            "--master reactor needs a flat layout (dense broadcast); \
             use --master threads with --blocks"
        );
        anyhow::ensure!(
            ckpt_opts.save.is_none() && ckpt_opts.resume.is_none(),
            "--master reactor does not checkpoint; use --master threads \
             with --checkpoint/--resume"
        );
    }
    anyhow::ensure!(
        sched.is_none() || layout.is_flat(),
        "--participation/--faults need a flat layout over transports \
         (absent workers would miss block-delta frames)"
    );
    // Move owned shard data into the worker factory.
    let shards: Vec<(Vec<f32>, Vec<f32>, usize, usize)> =
        ef21::data::partition::shards(&problem.dataset, problem.n_workers)
            .into_iter()
            .map(|s| (s.a.to_vec(), s.y.to_vec(), s.n, s.d))
            .collect();
    let lam = problem.lam;
    let comp = spec.compressor.clone();
    let seed = spec.seed;
    let objective = problem.objective;
    let master = Box::new(ef21::algo::ef21::Ef21Master::with_layout(
        vec![0.0; problem.d()],
        problem.n_workers,
        gamma,
        layout.clone(),
        1, // absorb stays inline: dist's master thread is already one-per-run
    ));
    let broadcast = if layout.is_flat() {
        Broadcast::Dense
    } else {
        Broadcast::Delta(layout.clone())
    };
    let worker_layout = layout.clone();
    let make_worker = move |i: usize| {
        let (a, y, n, d) = shards[i].clone();
        let oracle: Box<dyn ef21::oracle::GradOracle> = match objective {
            exp::Objective::LogReg => {
                Box::new(ef21::oracle::LogRegOracle::from_parts(a, y, n, d, lam))
            }
            exp::Objective::Lstsq => Box::new(ef21::oracle::LstsqOracle::from_parts(a, y, n, d)),
        };
        // Fan-out 1: dist already runs one OS thread per worker, so
        // per-compress block fan-out would oversubscribe the host.
        let c: std::sync::Arc<dyn ef21::compress::Compressor> = std::sync::Arc::from(
            ef21::compress::from_spec_blocked(&comp, &worker_layout, 1).expect("compressor"),
        );
        let rng = ef21::util::rng::worker_rng(seed, i);
        Box::new(ef21::algo::ef21::Ef21Worker::with_layout(oracle, c, rng, worker_layout.clone()))
            as Box<dyn ef21::algo::WorkerNode>
    };
    if spec.master == ef21::config::MasterEngine::Reactor {
        let out = ef21::coordinator::reactor::run_reactor_net(
            master,
            problem.n_workers,
            make_worker,
            spec.rounds,
            kind,
            &spec.label(),
            ef21::coordinator::reactor::default_shards(),
            ckpt_opts.health.clone(),
            netopts,
        )?;
        println!(
            "transport={transport} (reactor): {} uplink frame bytes, {} downlink frame bytes",
            out.uplink_frame_bytes, out.downlink_frame_bytes
        );
        return Ok(out.history);
    }
    // Degradation reuses the scheduler's absence bookkeeping (EF21-PP
    // semantics), so a degrade/quorum run without an explicit schedule
    // routes through the scheduled runner under a no-op full schedule —
    // bit-identical to the plain path until a worker is actually lost.
    let needs_sched_runner = matches!(netopts.on_loss, LossPolicy::Degrade { .. })
        || netopts.min_workers.is_some();
    let sched = match sched {
        None if needs_sched_runner => {
            anyhow::ensure!(
                layout.is_flat(),
                "--on-worker-loss degrade / --min-workers need a flat layout \
                 (absent workers would miss block-delta frames)"
            );
            Some(std::sync::Arc::new(ef21::sched::Scheduler::noop(problem.n_workers)))
        }
        s => s,
    };
    let out = match sched {
        Some(sched) => run_distributed_sched_ckpt_net(
            master,
            problem.n_workers,
            make_worker,
            spec.rounds,
            kind,
            &spec.label(),
            sched,
            ckpt_opts,
            netopts,
        )?,
        None => run_distributed_ckpt_net(
            master,
            problem.n_workers,
            make_worker,
            spec.rounds,
            kind,
            &spec.label(),
            broadcast,
            ckpt_opts,
            netopts,
        )?,
    };
    println!(
        "transport={transport}: {} uplink frame bytes, {} downlink frame bytes",
        out.uplink_frame_bytes, out.downlink_frame_bytes
    );
    Ok(out.history)
}

fn cmd_exp(args: &Args) -> Result<()> {
    match args.pos(1, "experiment")? {
        "stepsize" => exp::stepsize::main(args),
        "finetune" => exp::finetune::main(args),
        "kdep" => exp::kdep::main(args),
        "gdtune" => exp::gdtune::main(args),
        "lstsq" => exp::lstsq::main(args),
        "pp" => exp::pp::main(args),
        "rates" => exp::rates::main(args),
        "dl" => cmd_exp_dl(args),
        other => anyhow::bail!("unknown experiment '{other}'"),
    }
}

#[cfg(feature = "xla-runtime")]
fn cmd_exp_dl(args: &Args) -> Result<()> {
    exp::dl::main(args)
}

#[cfg(not(feature = "xla-runtime"))]
fn cmd_exp_dl(_args: &Args) -> Result<()> {
    anyhow::bail!("the dl experiment needs the `xla-runtime` feature (PJRT bindings)")
}

fn cmd_data(args: &Args) -> Result<()> {
    if args.pos(1, "subcommand")? != "info" {
        anyhow::bail!("usage: ef21 data info");
    }
    println!(
        "{:<12} {:>8} {:>6} {:>10} {:>10} {:>8}",
        "dataset", "N", "d", "N_i", "N_last", "pos%"
    );
    for (name, ..) in ef21::data::synth::TABLE3 {
        let ds = ef21::data::synth::generate(name, 0);
        let ranges = ef21::data::partition::ranges(ds.n, 20);
        let pos = ds.y.iter().filter(|&&v| v == 1.0).count() as f64 / ds.n as f64;
        println!(
            "{:<12} {:>8} {:>6} {:>10} {:>10} {:>7.1}%",
            name,
            ds.n,
            ds.d,
            ranges[0].1,
            ranges[19].1,
            100.0 * pos
        );
    }
    Ok(())
}

fn print_manifest(manifest: &ef21::runtime::Manifest) {
    println!("{:<28} {:>8} {:>8}  file", "artifact", "inputs", "outputs");
    for (name, e) in &manifest.entries {
        println!(
            "{:<28} {:>8} {:>8}  {}",
            name,
            e.inputs.len(),
            e.outputs.len(),
            e.file.file_name().unwrap().to_string_lossy()
        );
    }
}

#[cfg(feature = "xla-runtime")]
fn cmd_artifacts(_args: &Args) -> Result<()> {
    let rt = ef21::runtime::Runtime::from_default_dir()?;
    println!("platform: {}", rt.platform());
    print_manifest(&rt.manifest);
    Ok(())
}

#[cfg(not(feature = "xla-runtime"))]
fn cmd_artifacts(_args: &Args) -> Result<()> {
    // Without the PJRT client we can still list the manifest.
    let dir = ef21::runtime::manifest::default_dir();
    let manifest = ef21::runtime::Manifest::load(&dir)?;
    println!("platform: (xla-runtime feature disabled; manifest only)");
    print_manifest(&manifest);
    Ok(())
}
