//! PJRT-backed oracles: `∇f_i` evaluated by the AOT HLO artifact (which
//! embeds the L1 Pallas kernel). The production path of the three-layer
//! architecture; parity with the pure-Rust oracles is an integration test.

use super::GradOracle;
use crate::data::Shard;
use crate::runtime::client::{
    lit_f32_1d, lit_f32_2d, lit_f32_scalar, out_scalar_f32, out_vec_f64,
};
use crate::runtime::Runtime;
use anyhow::Result;
use std::sync::Arc;
use xla::Literal;

/// Which padded-shard artifact family to use.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ShardKind {
    LogReg,
    Lstsq,
}

/// Oracle executing `logreg_grad_<ds>` / `lstsq_grad_<ds>` artifacts.
///
/// The shard is zero-padded to the artifact's static row count once at
/// construction; `a`, `y/b`, `w` literals are cached so the hot path only
/// materializes the (d,) model vector per call.
pub struct XlaShardOracle {
    rt: Arc<Runtime>,
    artifact: String,
    kind: ShardKind,
    d: usize,
    a_lit: Literal,
    y_lit: Literal,
    w_lit: Literal,
    lam: f64,
}

// SAFETY: required by `GradOracle: Send`. `Runtime` is Send + Sync (see
// its impls); the cached `Literal`s are owned host buffers the binding
// leaves !Send only because it wraps raw pointers, and this oracle is
// owned (never shared) by exactly one worker at a time.
unsafe impl Send for XlaShardOracle {}

impl XlaShardOracle {
    pub fn new(
        rt: Arc<Runtime>,
        dataset: &str,
        kind: ShardKind,
        shard: Shard<'_>,
        lam: f64,
    ) -> Result<XlaShardOracle> {
        let artifact = match kind {
            ShardKind::LogReg => format!("logreg_grad_{dataset}"),
            ShardKind::Lstsq => format!("lstsq_grad_{dataset}"),
        };
        let entry = rt.entry(&artifact)?;
        let n_pad = entry.meta_usize("n_rows_padded")?;
        let d = entry.meta_usize("d")?;
        anyhow::ensure!(shard.d == d, "shard d={} vs artifact d={d}", shard.d);
        anyhow::ensure!(shard.n <= n_pad, "shard rows {} exceed padded {n_pad}", shard.n);

        let mut a = vec![0.0f32; n_pad * d];
        a[..shard.n * d].copy_from_slice(shard.a);
        let mut y = vec![0.0f32; n_pad];
        y[..shard.n].copy_from_slice(shard.y);
        let mut w = vec![0.0f32; n_pad];
        w[..shard.n].fill(1.0);

        Ok(XlaShardOracle {
            rt,
            artifact,
            kind,
            d,
            a_lit: lit_f32_2d(&a, n_pad, d)?,
            y_lit: Literal::vec1(&y),
            w_lit: Literal::vec1(&w),
            lam,
        })
    }

    fn call(&self, x: &[f64]) -> Result<(f64, Vec<f64>)> {
        let x_lit = lit_f32_1d(x);
        // Span scoped to the PJRT execution only (host-side literal prep
        // and output conversion are excluded) — matches the transformer
        // oracle so `oracle.xla.call.ns` is comparable across backends.
        let t_exec = crate::telemetry::maybe_now();
        let outs = match self.kind {
            ShardKind::LogReg => {
                let lam_lit = lit_f32_scalar(self.lam);
                self.rt.execute(
                    &self.artifact,
                    &[&self.a_lit, &self.y_lit, &self.w_lit, &x_lit, &lam_lit],
                )?
            }
            ShardKind::Lstsq => self.rt.execute(
                &self.artifact,
                &[&self.a_lit, &self.y_lit, &self.w_lit, &x_lit],
            )?,
        };
        crate::telemetry::counter(crate::telemetry::keys::ORACLE_XLA_CALLS).incr(1);
        crate::telemetry::record_elapsed_ns(crate::telemetry::keys::ORACLE_XLA_NS, t_exec);
        anyhow::ensure!(outs.len() == 2, "expected (loss, grad) tuple");
        Ok((out_scalar_f32(&outs[0])?, out_vec_f64(&outs[1])?))
    }
}

impl GradOracle for XlaShardOracle {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let t0 = crate::telemetry::maybe_now();
        let out = self.call(x).expect("XLA oracle execution failed");
        crate::telemetry::record_grad_eval(t0);
        out
    }
}

/// Oracle executing `transformer_step`: stochastic loss/grad of the small
/// causal LM over this worker's token stream (the DL experiment of §A.3).
pub struct XlaTransformerOracle {
    rt: Arc<Runtime>,
    pub n_params: usize,
    batch: usize,
    seq_len: usize,
    /// Per-layer parameter layout from the artifact manifest — exposed
    /// as the oracle's natural block partition (`--blocks auto`).
    layout: crate::nn::ParamLayout,
    sampler: Box<dyn FnMut() -> Vec<i32> + Send>,
}

impl XlaTransformerOracle {
    /// `sampler` must yield `batch * seq_len` i32 tokens per call.
    pub fn new(
        rt: Arc<Runtime>,
        sampler: Box<dyn FnMut() -> Vec<i32> + Send>,
    ) -> Result<Self> {
        let entry = rt.entry("transformer_step")?;
        let n_params = entry.meta_usize("n_params")?;
        let batch = entry.meta_usize("batch")?;
        let seq_len = entry.meta_usize("seq_len")?;
        let layout = crate::nn::ParamLayout::from_entry(entry)?;
        Ok(XlaTransformerOracle { rt, n_params, batch, seq_len, layout, sampler })
    }

    pub fn step_f32(&mut self, flat: &[f32]) -> Result<(f64, Vec<f64>)> {
        let tokens = (self.sampler)();
        anyhow::ensure!(tokens.len() == self.batch * self.seq_len, "bad sampler length");
        let flat_lit = crate::runtime::client::lit_f32_1d_exact(flat);
        let tok_lit = crate::runtime::client::lit_i32_2d(&tokens, self.batch, self.seq_len)?;
        let outs = self.rt.execute("transformer_step", &[flat_lit, tok_lit])?;
        Ok((out_scalar_f32(&outs[0])?, out_vec_f64(&outs[1])?))
    }

    /// Eval loss + accuracy on a provided batch via `transformer_eval`.
    pub fn eval(&self, flat: &[f32], tokens: &[i32]) -> Result<(f64, f64)> {
        let flat_lit = crate::runtime::client::lit_f32_1d_exact(flat);
        let tok_lit = crate::runtime::client::lit_i32_2d(tokens, self.batch, self.seq_len)?;
        let outs = self.rt.execute("transformer_eval", &[flat_lit, tok_lit])?;
        Ok((out_scalar_f32(&outs[0])?, out_scalar_f32(&outs[1])?))
    }

    pub fn batch_shape(&self) -> (usize, usize) {
        (self.batch, self.seq_len)
    }
}

impl GradOracle for XlaTransformerOracle {
    fn dim(&self) -> usize {
        self.n_params
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let t0 = crate::telemetry::maybe_now();
        let flat: Vec<f32> = x.iter().map(|&v| v as f32).collect();
        let tokens = (self.sampler)();
        let flat_lit = crate::runtime::client::lit_f32_1d_exact(&flat);
        let tok_lit = crate::runtime::client::lit_i32_2d(&tokens, self.batch, self.seq_len)
            .expect("token literal");
        // Scope the xla span to the execution only, like XlaShardOracle;
        // t0 (whole eval, sampling included) feeds oracle.grad.ns.
        let t_exec = crate::telemetry::maybe_now();
        let outs = self
            .rt
            .execute("transformer_step", &[flat_lit, tok_lit])
            .expect("transformer_step execution failed");
        crate::telemetry::counter(crate::telemetry::keys::ORACLE_XLA_CALLS).incr(1);
        crate::telemetry::record_elapsed_ns(crate::telemetry::keys::ORACLE_XLA_NS, t_exec);
        let out = (
            out_scalar_f32(&outs[0]).expect("loss scalar"),
            out_vec_f64(&outs[1]).expect("grad vector"),
        );
        crate::telemetry::record_grad_eval(t0);
        out
    }

    /// The transformer's real per-layer shapes (one block per named
    /// parameter) — §5's layer-wise compression structure.
    fn block_layout(&self) -> crate::blocks::BlockLayout {
        self.layout.block_layout()
    }
}
