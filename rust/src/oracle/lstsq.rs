//! Pure-Rust oracle for least squares (§A.2, the PL-but-not-strongly-convex
//! objective): `f_i(x) = (1/n_i) sum (a_j^T x - b_j)^2` on one shard.
//! Mirrors `python/compile/kernels/lstsq.py`.

use super::GradOracle;
use crate::data::Shard;
use crate::util::{linalg, simd};

pub struct LstsqOracle {
    a: Vec<f32>,
    b: Vec<f32>,
    n: usize,
    d: usize,
}

impl LstsqOracle {
    /// Build from a classification shard, using ±1 labels as regression
    /// targets (exactly what §A.2 does).
    pub fn new(shard: Shard<'_>) -> Self {
        let (a, b) = shard.to_owned_parts();
        LstsqOracle { a, b, n: shard.n, d: shard.d }
    }

    pub fn from_parts(a: Vec<f32>, b: Vec<f32>, n: usize, d: usize) -> Self {
        assert_eq!(a.len(), n * d);
        assert_eq!(b.len(), n);
        LstsqOracle { a, b, n, d }
    }

    pub fn matrix(&self) -> &[f32] {
        &self.a
    }

    pub fn n_rows(&self) -> usize {
        self.n
    }

    /// Legacy row-at-a-time evaluation — the differential-testing
    /// baseline for the register-blocked `loss_grad_into` (bitwise
    /// agreement asserted in `tests/simd_identity.rs`).
    pub fn loss_grad_rowwise(&mut self, x: &[f64], grad: &mut Vec<f64>) -> f64 {
        assert_eq!(x.len(), self.d);
        let inv_n = 1.0 / self.n as f64;
        let mut loss = 0.0;
        grad.clear();
        grad.resize(self.d, 0.0);
        for i in 0..self.n {
            let row = &self.a[i * self.d..(i + 1) * self.d];
            let z = linalg::dot_f32_f64(row, x) - self.b[i] as f64;
            loss += z * z;
            linalg::axpy_f32(2.0 * z * inv_n, row, grad);
        }
        loss * inv_n
    }
}

impl GradOracle for LstsqOracle {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let mut grad = Vec::new();
        let loss = self.loss_grad_into(x, &mut grad);
        (loss, grad)
    }

    /// Allocation-free hot path; `loss_grad` wraps it (one arithmetic
    /// code path for both entry points). Register-blocked 4 rows at a
    /// time like the logreg oracle — bit-identical to the row-at-a-time
    /// baseline ([`Self::loss_grad_rowwise`]): blocked dots run the
    /// exact single-row recurrence, residual/loss arithmetic stays in
    /// row order, and the blocked axpy applies row updates in row order
    /// per coordinate.
    fn loss_grad_into(&mut self, x: &[f64], grad: &mut Vec<f64>) -> f64 {
        assert_eq!(x.len(), self.d);
        let t0 = crate::telemetry::maybe_now();
        let _sp = crate::telemetry::span("oracle.grad");
        let inv_n = 1.0 / self.n as f64;
        let mut loss = 0.0;
        grad.clear();
        grad.resize(self.d, 0.0);
        let d = self.d;
        let blocked = self.n / 4 * 4;
        let mut i = 0;
        while i < blocked {
            let base = i * d;
            let r0 = &self.a[base..base + d];
            let r1 = &self.a[base + d..base + 2 * d];
            let r2 = &self.a[base + 2 * d..base + 3 * d];
            let r3 = &self.a[base + 3 * d..base + 4 * d];
            let zs = simd::dot4_f32_f64(r0, r1, r2, r3, x);
            let mut coef = [0.0f64; 4];
            for (lane, zi) in zs.iter().enumerate() {
                let z = zi - self.b[i + lane] as f64;
                loss += z * z;
                coef[lane] = 2.0 * z * inv_n;
            }
            simd::axpy4_f32(coef, r0, r1, r2, r3, grad);
            i += 4;
        }
        for i in blocked..self.n {
            let row = &self.a[i * d..(i + 1) * d];
            let z = linalg::dot_f32_f64(row, x) - self.b[i] as f64;
            loss += z * z;
            linalg::axpy_f32(2.0 * z * inv_n, row, grad);
        }
        crate::telemetry::record_grad_eval(t0);
        loss * inv_n
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::testing::{for_all_seeds, random_vec};

    #[test]
    fn zero_residual_zero_grad() {
        // b = A x* => loss(x*) = 0, grad(x*) = 0.
        let mut rng = crate::util::rng::Rng::seed(0);
        let (n, d) = (30, 5);
        let a: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
        let xstar = random_vec(&mut rng, d, 1.0);
        let b: Vec<f32> = (0..n)
            .map(|i| linalg::dot_f32_f64(&a[i * d..(i + 1) * d], &xstar) as f32)
            .collect();
        let mut o = LstsqOracle::from_parts(a, b, n, d);
        let (l, g) = o.loss_grad(&xstar);
        assert!(l < 1e-10, "{l}");
        assert!(linalg::norm2(&g) < 1e-5);
    }

    #[test]
    fn gradient_matches_finite_differences() {
        for_all_seeds(10, |rng| {
            let d = 2 + rng.next_below(6);
            let n = 20;
            let a: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
            let b: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
            let mut o = LstsqOracle::from_parts(a, b, n, d);
            let x = random_vec(rng, d, 1.0);
            let (_, g) = o.loss_grad(&x);
            let eps = 1e-5;
            for j in 0..d {
                let mut xp = x.clone();
                let mut xm = x.clone();
                xp[j] += eps;
                xm[j] -= eps;
                let fd = (o.loss(&xp) - o.loss(&xm)) / (2.0 * eps);
                assert!((fd - g[j]).abs() < 1e-4, "fd={fd} vs {}", g[j]);
            }
        });
    }

    #[test]
    fn pl_inequality_holds_empirically() {
        // For full-rank least squares, f(x) - f* <= ||grad||^2 / (2 mu)
        // with mu = 2 lambda_min(A^T A)/n.
        let mut rng = crate::util::rng::Rng::seed(9);
        let (n, d) = (60, 4);
        let a: Vec<f32> = (0..n * d).map(|_| rng.next_normal() as f32).collect();
        let b: Vec<f32> = (0..n).map(|_| rng.next_normal() as f32).collect();
        let mu = crate::theory::lstsq_pl_mu(&a, n, d);
        assert!(mu > 0.0);
        // f* via normal equations is awkward without a solver; instead run
        // GD to near-optimality to get f*.
        let mut o = LstsqOracle::from_parts(a.clone(), b.clone(), n, d);
        let l = crate::theory::lstsq_l(&a, n, d);
        let mut x = vec![0.0; d];
        for _ in 0..4000 {
            let (_, g) = o.loss_grad(&x);
            linalg::axpy(-1.0 / l, &g, &mut x);
        }
        let fstar = o.loss(&x);
        for _ in 0..20 {
            let xt = random_vec(&mut rng, d, 2.0);
            let (f, g) = o.loss_grad(&xt);
            let lhs = f - fstar;
            let rhs = linalg::norm2_sq(&g) / (2.0 * mu);
            assert!(lhs <= rhs * 1.05 + 1e-8, "PL violated: {lhs} > {rhs}");
        }
    }
}
