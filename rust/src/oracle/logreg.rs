//! Pure-Rust oracle for Eq. (19): logistic loss with the nonconvex
//! regularizer `lam * sum_j x_j^2 / (1 + x_j^2)` on one shard.
//!
//! Mirrors the Pallas kernel (`python/compile/kernels/logreg.py`) exactly:
//! one fused pass over the rows computing the forward matvec, the stable
//! softplus/sigmoid link, and the backward matvec. Parity with the HLO
//! artifact is asserted in `integration_runtime.rs`.

use super::GradOracle;
use crate::data::Shard;
use crate::util::{linalg, simd};

pub struct LogRegOracle {
    a: Vec<f32>,
    y: Vec<f32>,
    n: usize,
    d: usize,
    pub lam: f64,
}

impl LogRegOracle {
    pub fn new(shard: Shard<'_>, lam: f64) -> Self {
        let (a, y) = shard.to_owned_parts();
        LogRegOracle { a, y, n: shard.n, d: shard.d, lam }
    }

    pub fn from_parts(a: Vec<f32>, y: Vec<f32>, n: usize, d: usize, lam: f64) -> Self {
        assert_eq!(a.len(), n * d);
        assert_eq!(y.len(), n);
        LogRegOracle { a, y, n, d, lam }
    }

    /// Stable softplus log(1+e^m).
    #[inline]
    fn softplus(m: f64) -> f64 {
        m.max(0.0) + (-m.abs()).exp().ln_1p()
    }

    /// sigmoid(m) computed stably for any m.
    #[inline]
    fn sigmoid(m: f64) -> f64 {
        if m >= 0.0 {
            1.0 / (1.0 + (-m).exp())
        } else {
            let e = m.exp();
            e / (1.0 + e)
        }
    }

    pub fn n_rows(&self) -> usize {
        self.n
    }

    pub fn matrix(&self) -> &[f32] {
        &self.a
    }

    /// Legacy row-at-a-time evaluation — kept as the differential-testing
    /// baseline for the register-blocked `loss_grad_into` (the two must
    /// agree bit for bit; asserted in `tests/simd_identity.rs`) and for
    /// the §Perf bench ablation.
    pub fn loss_grad_rowwise(&mut self, x: &[f64], grad: &mut Vec<f64>) -> f64 {
        assert_eq!(x.len(), self.d);
        let inv_n = 1.0 / self.n as f64;
        let mut loss = 0.0f64;
        grad.clear();
        grad.resize(self.d, 0.0);
        for i in 0..self.n {
            let row = &self.a[i * self.d..(i + 1) * self.d];
            let z = linalg::dot_f32_f64(row, x);
            let yi = self.y[i] as f64;
            let m = -yi * z;
            loss += Self::softplus(m);
            let r = -yi * Self::sigmoid(m); // d loss_i / d z
            linalg::axpy_f32(r * inv_n, row, grad);
        }
        loss *= inv_n;
        let mut reg = 0.0f64;
        for (j, &xj) in x.iter().enumerate() {
            let x2 = xj * xj;
            reg += x2 / (1.0 + x2);
            grad[j] += self.lam * 2.0 * xj / ((1.0 + x2) * (1.0 + x2));
        }
        loss + self.lam * reg
    }

    /// Label of local row i (as f64).
    pub fn label(&self, i: usize) -> f64 {
        self.y[i] as f64
    }
}

impl GradOracle for LogRegOracle {
    fn dim(&self) -> usize {
        self.d
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let mut grad = Vec::new();
        let loss = self.loss_grad_into(x, &mut grad);
        (loss, grad)
    }

    /// The allocation-free hot path (the workers' pooled buffers land
    /// here); `loss_grad` is a thin wrapper so both entry points share
    /// this arithmetic exactly.
    ///
    /// Rows are processed in register-blocked groups of 4
    /// ([`crate::util::simd::dot4_f32_f64`] / [`simd::axpy4_f32`]): one
    /// pass over `x`/`grad` serves four rows, amortizing the loads that
    /// dominate the row-at-a-time walk. Bit-identity with the legacy
    /// row-at-a-time loop ([`Self::loss_grad_rowwise`], the differential
    /// baseline): each blocked dot runs the exact single-row recurrence,
    /// the scalar link (softplus/sigmoid) and the `loss` accumulation
    /// stay in row order, and the blocked axpy applies the four row
    /// updates in row order within each coordinate — the same
    /// per-coordinate f64 sequence as four sequential axpys.
    fn loss_grad_into(&mut self, x: &[f64], grad: &mut Vec<f64>) -> f64 {
        assert_eq!(x.len(), self.d);
        let t0 = crate::telemetry::maybe_now();
        let _sp = crate::telemetry::span("oracle.grad");
        let inv_n = 1.0 / self.n as f64;
        let mut loss = 0.0f64;
        grad.clear();
        grad.resize(self.d, 0.0);
        let d = self.d;
        let blocked = self.n / 4 * 4;
        let mut i = 0;
        while i < blocked {
            let base = i * d;
            let r0 = &self.a[base..base + d];
            let r1 = &self.a[base + d..base + 2 * d];
            let r2 = &self.a[base + 2 * d..base + 3 * d];
            let r3 = &self.a[base + 3 * d..base + 4 * d];
            let z = simd::dot4_f32_f64(r0, r1, r2, r3, x);
            let mut coef = [0.0f64; 4];
            for (lane, zi) in z.iter().enumerate() {
                let yi = self.y[i + lane] as f64;
                let m = -yi * zi;
                loss += Self::softplus(m);
                let r = -yi * Self::sigmoid(m); // d loss_i / d z
                coef[lane] = r * inv_n;
            }
            simd::axpy4_f32(coef, r0, r1, r2, r3, grad);
            i += 4;
        }
        for i in blocked..self.n {
            let row = &self.a[i * d..(i + 1) * d];
            let z = linalg::dot_f32_f64(row, x);
            let yi = self.y[i] as f64;
            let m = -yi * z;
            loss += Self::softplus(m);
            let r = -yi * Self::sigmoid(m); // d loss_i / d z
            linalg::axpy_f32(r * inv_n, row, grad);
        }
        loss *= inv_n;
        // Nonconvex regularizer.
        let mut reg = 0.0f64;
        for (j, &xj) in x.iter().enumerate() {
            let x2 = xj * xj;
            reg += x2 / (1.0 + x2);
            grad[j] += self.lam * 2.0 * xj / ((1.0 + x2) * (1.0 + x2));
        }
        crate::telemetry::record_grad_eval(t0);
        loss + self.lam * reg
    }

    fn loss(&mut self, x: &[f64]) -> f64 {
        let inv_n = 1.0 / self.n as f64;
        let mut loss = 0.0f64;
        for i in 0..self.n {
            let row = &self.a[i * self.d..(i + 1) * self.d];
            let z = linalg::dot_f32_f64(row, x);
            loss += Self::softplus(-(self.y[i] as f64) * z);
        }
        loss *= inv_n;
        let reg: f64 = x.iter().map(|&xj| xj * xj / (1.0 + xj * xj)).sum();
        loss + self.lam * reg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::util::testing::{for_all_seeds, random_vec};

    fn make(seed: u64, n: usize, d: usize, lam: f64) -> LogRegOracle {
        let ds = synth::generate_custom("o", n, d, 0.5, seed);
        LogRegOracle::new(ds.slice(0, n), lam)
    }

    #[test]
    fn gradient_matches_finite_differences() {
        for_all_seeds(10, |rng| {
            let d = 2 + rng.next_below(8);
            let mut o = make(rng.next_u64(), 40, d, 0.1);
            let x = random_vec(rng, d, 1.0);
            let (_, g) = o.loss_grad(&x);
            let eps = 1e-5;
            for j in 0..d {
                let mut xp = x.clone();
                let mut xm = x.clone();
                xp[j] += eps;
                xm[j] -= eps;
                let fd = (o.loss(&xp) - o.loss(&xm)) / (2.0 * eps);
                assert!((fd - g[j]).abs() < 1e-5, "j={j}: fd={fd} g={}", g[j]);
            }
        });
    }

    #[test]
    fn loss_at_zero_is_log2_plus_no_reg() {
        let mut o = make(1, 64, 5, 0.1);
        let x = vec![0.0; 5];
        let (l, g) = o.loss_grad(&x);
        assert!((l - std::f64::consts::LN_2).abs() < 1e-12);
        // Regularizer gradient vanishes at 0; data gradient generally not.
        assert!(crate::util::linalg::norm2(&g) > 0.0);
    }

    #[test]
    fn loss_only_matches_loss_grad() {
        let mut o = make(2, 50, 6, 0.1);
        let x = vec![0.3; 6];
        assert!((o.loss(&x) - o.loss_grad(&x).0).abs() < 1e-12);
    }

    #[test]
    fn extreme_margins_stay_finite() {
        let mut o = make(3, 32, 4, 0.1);
        let x = vec![1e6; 4];
        let (l, g) = o.loss_grad(&x);
        assert!(l.is_finite());
        assert!(g.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn regularizer_bounded_by_lam_d() {
        let mut o = make(4, 32, 7, 0.1);
        let x = vec![1e9; 7];
        let l = o.loss(&x);
        // data loss for huge positive margins can be huge... but nonneg
        // features * positive x means margins are +-; at least check reg
        // contribution bound via lam*d window at x=0 vs large x difference.
        assert!(l.is_finite());
    }
}
