//! Gradient oracles: the per-worker `(f_i(x), ∇f_i(x))` computation.
//!
//! Two interchangeable backends per objective:
//!   * pure Rust (this module) — the fast simulation path used by the
//!     experiment sweeps;
//!   * the AOT HLO artifact executed via PJRT ([`crate::oracle::xla`]) —
//!     the production path proving the three-layer composition. Parity
//!     between the two is asserted in `rust/tests/integration_runtime.rs`.

pub mod logreg;
pub mod lstsq;
pub mod quadratic;
pub mod stochastic;
#[cfg(feature = "xla-runtime")]
pub mod xla;

pub use logreg::LogRegOracle;
pub use lstsq::LstsqOracle;
pub use quadratic::QuadraticOracle;
pub use stochastic::StochasticOracle;

/// A differentiable local objective `f_i`.
///
/// `Send` because workers (which own their oracle) execute on pool
/// threads in the parallel runners; oracles own their shard data, so
/// this costs implementations nothing.
pub trait GradOracle: Send {
    /// Problem dimension d.
    fn dim(&self) -> usize;

    /// Evaluate `(f_i(x), ∇f_i(x))`.
    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>);

    /// Evaluate `∇f_i(x)` into a caller-owned buffer (resized to `d`),
    /// returning `f_i(x)` — the pooled-workspace hot path used by the
    /// algorithm state machines. The default delegates to [`loss_grad`]
    /// (one allocation) for backends that cannot write in place (XLA);
    /// the pure-Rust oracles override it with the genuinely
    /// allocation-free evaluation and implement `loss_grad` on top of
    /// it, so both entry points share one arithmetic code path.
    ///
    /// [`loss_grad`]: GradOracle::loss_grad
    fn loss_grad_into(&mut self, x: &[f64], grad: &mut Vec<f64>) -> f64 {
        let (loss, g) = self.loss_grad(x);
        grad.clear();
        grad.extend_from_slice(&g);
        loss
    }

    /// Evaluate only the loss (metrics path; default goes through
    /// `loss_grad`).
    fn loss(&mut self, x: &[f64]) -> f64 {
        self.loss_grad(x).0
    }

    /// The natural block partition of this objective's parameter space:
    /// flat (one block) for unstructured problems like logreg/lstsq, the
    /// real per-layer shapes for the transformer oracle. `--blocks auto`
    /// resolves to this.
    fn block_layout(&self) -> crate::blocks::BlockLayout {
        crate::blocks::BlockLayout::flat(self.dim())
    }
}

/// The global objective f = (1/n) sum f_i realized as one oracle over all
/// shards — used by the convergence tracker to evaluate `f(x^t)` and
/// `||∇f(x^t)||` outside the communication-metered path.
pub struct AverageOracle {
    pub parts: Vec<Box<dyn GradOracle>>,
}

impl AverageOracle {
    pub fn new(parts: Vec<Box<dyn GradOracle>>) -> Self {
        assert!(!parts.is_empty());
        let d = parts[0].dim();
        assert!(parts.iter().all(|p| p.dim() == d));
        AverageOracle { parts }
    }
}

impl GradOracle for AverageOracle {
    fn dim(&self) -> usize {
        self.parts[0].dim()
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let d = self.dim();
        let n = self.parts.len() as f64;
        let mut loss = 0.0;
        let mut grad = vec![0.0; d];
        for p in self.parts.iter_mut() {
            let (l, g) = p.loss_grad(x);
            loss += l / n;
            crate::util::linalg::axpy(1.0 / n, &g, &mut grad);
        }
        (loss, grad)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn average_oracle_averages() {
        let p1 = Box::new(QuadraticOracle::diagonal(vec![1.0, 1.0], vec![0.0, 0.0]));
        let p2 = Box::new(QuadraticOracle::diagonal(vec![3.0, 3.0], vec![0.0, 0.0]));
        let mut avg = AverageOracle::new(vec![p1, p2]);
        let (l, g) = avg.loss_grad(&[1.0, 2.0]);
        // f(x) = (1/2)(0.5 x'diag(1)x + 0.5 x'diag(3)x) -> grad = 2x on avg.
        assert!((g[0] - 2.0).abs() < 1e-12);
        assert!((g[1] - 4.0).abs() < 1e-12);
        assert!((l - 0.5 * (1.0 * 5.0 + 3.0 * 5.0) / 2.0).abs() < 1e-12);
    }
}
