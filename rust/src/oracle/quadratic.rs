//! Quadratic oracle `f_i(x) = 0.5 (x-c)^T diag(h) (x-c)` — the analytic
//! test problem. Three strongly convex quadratics with distinct minimizers
//! reproduce the classic DCGD divergence example ([Beznosikov et al. 2020,
//! Example 1] — see `integration_convergence.rs::dcgd_diverges_ef21_converges`).

use super::GradOracle;

#[derive(Clone, Debug)]
pub struct QuadraticOracle {
    /// Diagonal Hessian entries (>= 0).
    pub h: Vec<f64>,
    /// Minimizer.
    pub c: Vec<f64>,
}

impl QuadraticOracle {
    pub fn diagonal(h: Vec<f64>, c: Vec<f64>) -> Self {
        assert_eq!(h.len(), c.len());
        QuadraticOracle { h, c }
    }

    /// Smoothness constant L_i = max h_j.
    pub fn l(&self) -> f64 {
        self.h.iter().cloned().fold(0.0, f64::max)
    }

    /// Strong convexity (and hence PL) constant mu = min h_j.
    pub fn mu(&self) -> f64 {
        self.h.iter().cloned().fold(f64::INFINITY, f64::min)
    }
}

impl GradOracle for QuadraticOracle {
    fn dim(&self) -> usize {
        self.h.len()
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let mut grad = Vec::new();
        let loss = self.loss_grad_into(x, &mut grad);
        (loss, grad)
    }

    fn loss_grad_into(&mut self, x: &[f64], grad: &mut Vec<f64>) -> f64 {
        let mut loss = 0.0;
        grad.clear();
        grad.resize(x.len(), 0.0);
        for j in 0..x.len() {
            let dxj = x[j] - self.c[j];
            loss += 0.5 * self.h[j] * dxj * dxj;
            grad[j] = self.h[j] * dxj;
        }
        loss
    }
}

/// The three-function divergence instance in R^3, adapted from Beznosikov
/// et al. (2020), Example 1: strongly convex quadratics whose average has a
/// minimizer where individual gradients are large and "rotated" so that
/// Top-1 DCGD cycles/diverges while EF-family methods converge.
pub fn divergence_example() -> Vec<QuadraticOracle> {
    // Rotationally mismatched minimizers with skewed curvatures.
    vec![
        QuadraticOracle::diagonal(vec![1.0, 4.0, 16.0], vec![10.0, 0.0, 0.0]),
        QuadraticOracle::diagonal(vec![16.0, 1.0, 4.0], vec![0.0, 10.0, 0.0]),
        QuadraticOracle::diagonal(vec![4.0, 16.0, 1.0], vec![0.0, 0.0, 10.0]),
    ]
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn analytic_gradient() {
        let mut q = QuadraticOracle::diagonal(vec![2.0, 3.0], vec![1.0, -1.0]);
        let (l, g) = q.loss_grad(&[2.0, 1.0]);
        assert!((l - (0.5 * 2.0 * 1.0 + 0.5 * 3.0 * 4.0)).abs() < 1e-12);
        assert_eq!(g, vec![2.0, 6.0]);
        assert_eq!(q.l(), 3.0);
        assert_eq!(q.mu(), 2.0);
    }

    #[test]
    fn divergence_example_minimizers_conflict() {
        // The average minimizer has nonzero individual gradients (the
        // heterogeneous regime EF21 is designed for).
        let mut fs = divergence_example();
        // Average minimizer solves sum h_i (x - c_i) = 0 componentwise.
        let d = 3;
        let mut x = vec![0.0; d];
        for j in 0..d {
            let num: f64 = fs.iter().map(|f| f.h[j] * f.c[j]).sum();
            let den: f64 = fs.iter().map(|f| f.h[j]).sum();
            x[j] = num / den;
        }
        let mut avg_grad = vec![0.0; d];
        for f in fs.iter_mut() {
            let (_, g) = f.loss_grad(&x);
            assert!(crate::util::linalg::norm2(&g) > 1.0, "individual grads large");
            crate::util::linalg::axpy(1.0 / 3.0, &g, &mut avg_grad);
        }
        assert!(crate::util::linalg::norm2(&avg_grad) < 1e-10, "x is the avg minimizer");
    }
}
