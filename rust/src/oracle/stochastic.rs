//! Stochastic gradient wrapper (Algorithm 5 / §3.6): turns any exact
//! shard oracle into a minibatch estimator `ghat_i ≈ ∇f_i`.
//!
//! Sampling is without replacement within an epoch (the paper's DL setup),
//! with a deterministic per-worker RNG stream so experiments replay
//! bit-exactly. EF21 + `StochasticOracle` == Algorithm 5; EF + it == the
//! paper's EF-SGD baseline.

use super::GradOracle;
use crate::util::rng::Rng;

/// A factory view over shard rows so the wrapper can subsample.
pub trait RowSubsampled {
    /// Evaluate loss/grad over a subset of local row indices.
    fn loss_grad_rows(&mut self, x: &[f64], rows: &[u32]) -> (f64, Vec<f64>);
    fn n_local_rows(&self) -> usize;
    fn dim(&self) -> usize;
}

impl RowSubsampled for crate::oracle::LogRegOracle {
    fn loss_grad_rows(&mut self, x: &[f64], rows: &[u32]) -> (f64, Vec<f64>) {
        logreg_rows(self, x, rows)
    }
    fn n_local_rows(&self) -> usize {
        self.n_rows()
    }
    fn dim(&self) -> usize {
        <Self as GradOracle>::dim(self)
    }
}

fn logreg_rows(o: &crate::oracle::LogRegOracle, x: &[f64], rows: &[u32]) -> (f64, Vec<f64>) {
    use crate::util::linalg;
    let d = <crate::oracle::LogRegOracle as GradOracle>::dim(o);
    let inv_n = 1.0 / rows.len() as f64;
    let mut loss = 0.0;
    let mut grad = vec![0.0; d];
    let a = o.matrix();
    for &ri in rows {
        let i = ri as usize;
        let row = &a[i * d..(i + 1) * d];
        let z = linalg::dot_f32_f64(row, x);
        let yi = o.label(i);
        let m = -yi * z;
        loss += m.max(0.0) + (-m.abs()).exp().ln_1p();
        let s = if m >= 0.0 { 1.0 / (1.0 + (-m).exp()) } else { let e = m.exp(); e / (1.0 + e) };
        linalg::axpy_f32(-yi * s * inv_n, row, &mut grad);
    }
    loss *= inv_n;
    let mut reg = 0.0;
    for (j, &xj) in x.iter().enumerate() {
        let x2 = xj * xj;
        reg += x2 / (1.0 + x2);
        grad[j] += o.lam * 2.0 * xj / ((1.0 + x2) * (1.0 + x2));
    }
    (loss + o.lam * reg, grad)
}

/// Minibatch-without-replacement estimator over any `RowSubsampled` oracle.
pub struct StochasticOracle<O: RowSubsampled> {
    inner: O,
    batch: usize,
    rng: Rng,
    /// Current epoch permutation and cursor.
    perm: Vec<u32>,
    cursor: usize,
}

impl<O: RowSubsampled> StochasticOracle<O> {
    pub fn new(inner: O, batch: usize, rng: Rng) -> Self {
        let n = inner.n_local_rows();
        assert!(batch >= 1 && batch <= n, "batch {batch} vs rows {n}");
        let perm: Vec<u32> = (0..n as u32).collect();
        let mut s = StochasticOracle { inner, batch, rng, perm, cursor: 0 };
        s.reshuffle();
        s
    }

    fn reshuffle(&mut self) {
        self.rng.shuffle(&mut self.perm);
        self.cursor = 0;
    }

    fn next_batch(&mut self) -> Vec<u32> {
        if self.cursor + self.batch > self.perm.len() {
            self.reshuffle();
        }
        let b = self.perm[self.cursor..self.cursor + self.batch].to_vec();
        self.cursor += self.batch;
        b
    }

    pub fn inner_mut(&mut self) -> &mut O {
        &mut self.inner
    }
}

impl<O: RowSubsampled + Send> GradOracle for StochasticOracle<O> {
    fn dim(&self) -> usize {
        self.inner.dim()
    }

    fn loss_grad(&mut self, x: &[f64]) -> (f64, Vec<f64>) {
        let rows = self.next_batch();
        self.inner.loss_grad_rows(x, &rows)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::synth;
    use crate::oracle::LogRegOracle;

    fn make(n: usize, d: usize) -> LogRegOracle {
        let ds = synth::generate_custom("s", n, d, 0.5, 3);
        LogRegOracle::new(ds.slice(0, n), 0.1)
    }

    #[test]
    fn full_batch_equals_exact_oracle() {
        let mut exact = make(64, 6);
        let mut stoch = StochasticOracle::new(make(64, 6), 64, Rng::seed(1));
        let x = vec![0.3; 6];
        let (le, ge) = exact.loss_grad(&x);
        let (ls, gs) = stoch.loss_grad(&x);
        assert!((le - ls).abs() < 1e-12);
        for (a, b) in ge.iter().zip(&gs) {
            assert!((a - b).abs() < 1e-12);
        }
    }

    #[test]
    fn minibatch_gradient_is_unbiased() {
        let mut exact = make(128, 5);
        let x = vec![0.2; 5];
        let (_, full) = exact.loss_grad(&x);
        let mut stoch = StochasticOracle::new(make(128, 5), 16, Rng::seed(2));
        let reps = 800; // 100 epochs of 8 batches: mean over epochs == full
        let mut mean = vec![0.0; 5];
        for _ in 0..reps {
            let (_, g) = stoch.loss_grad(&x);
            for (m, v) in mean.iter_mut().zip(&g) {
                *m += v / reps as f64;
            }
        }
        for (m, f) in mean.iter().zip(&full) {
            assert!((m - f).abs() < 5e-3, "{m} vs {f}");
        }
    }

    #[test]
    fn epoch_covers_every_row_once() {
        let mut stoch = StochasticOracle::new(make(64, 4), 16, Rng::seed(3));
        let mut seen = std::collections::HashSet::new();
        for _ in 0..4 {
            for &r in &stoch.next_batch() {
                assert!(seen.insert(r), "row {r} repeated within epoch");
            }
        }
        assert_eq!(seen.len(), 64);
    }

    #[test]
    #[should_panic]
    fn batch_larger_than_shard_panics() {
        StochasticOracle::new(make(8, 3), 9, Rng::seed(0));
    }
}
