//! Metric registry: the owner of all storage cells.
//!
//! Keys are dotted strings (`"transport.uplink.bits"`). Lookup takes a
//! short read lock on a `BTreeMap` and clones an `Arc`; the record path
//! through the returned handle is entirely lock-free. Call sites on hot
//! loops should cache the handle; cold sites can look up per record
//! (~100ns when telemetry is enabled, ~1ns when disabled because the
//! facade short-circuits to noop handles before ever reaching here).

use super::handles::{
    Counter, CounterCell, Gauge, GaugeCell, Histogram, HistogramCell,
};
use super::snapshot::{HistogramSnapshot, Snapshot};
use std::collections::BTreeMap;
use std::sync::{Arc, RwLock};

/// Process-wide (or test-local) metric store.
#[derive(Default)]
pub struct Registry {
    counters: RwLock<BTreeMap<String, Arc<CounterCell>>>,
    gauges: RwLock<BTreeMap<String, Arc<GaugeCell>>>,
    histograms: RwLock<BTreeMap<String, Arc<HistogramCell>>>,
}

impl Registry {
    pub fn new() -> Registry {
        Registry::default()
    }

    /// Handle to the counter `key`, registering it on first use.
    pub fn counter(&self, key: &str) -> Counter {
        if let Some(c) = self.counters.read().unwrap().get(key) {
            return Counter::from_cell(c.clone());
        }
        let mut map = self.counters.write().unwrap();
        let cell = map
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(CounterCell::default()))
            .clone();
        Counter::from_cell(cell)
    }

    /// Handle to the gauge `key`, registering it on first use.
    pub fn gauge(&self, key: &str) -> Gauge {
        if let Some(g) = self.gauges.read().unwrap().get(key) {
            return Gauge::from_cell(g.clone());
        }
        let mut map = self.gauges.write().unwrap();
        let cell = map
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(GaugeCell::default()))
            .clone();
        Gauge::from_cell(cell)
    }

    /// Handle to the histogram `key`, registering it on first use.
    pub fn histogram(&self, key: &str) -> Histogram {
        if let Some(h) = self.histograms.read().unwrap().get(key) {
            return Histogram::from_cell(h.clone());
        }
        let mut map = self.histograms.write().unwrap();
        let cell = map
            .entry(key.to_string())
            .or_insert_with(|| Arc::new(HistogramCell::new()))
            .clone();
        Histogram::from_cell(cell)
    }

    /// Consistent-enough point-in-time view, sorted by key (BTreeMap
    /// iteration order). Individual values are read with relaxed atomics,
    /// so concurrent writers may land between reads — fine for export.
    pub fn snapshot(&self) -> Snapshot {
        let counters = self
            .counters
            .read()
            .unwrap()
            .iter()
            .map(|(k, c)| (k.clone(), c.get()))
            .collect();
        let gauges = self
            .gauges
            .read()
            .unwrap()
            .iter()
            .map(|(k, g)| (k.clone(), g.get()))
            .collect();
        let histograms = self
            .histograms
            .read()
            .unwrap()
            .iter()
            .map(|(k, h)| {
                // Derive count from the bucket reads so a concurrent
                // record() can never make the +Inf bucket smaller than a
                // cumulative bucket (record bumps buckets before count).
                let buckets = h.bucket_counts();
                let count = buckets.iter().sum();
                (k.clone(), HistogramSnapshot { count, sum: h.sum(), max: h.max(), buckets })
            })
            .collect();
        Snapshot { counters, gauges, histograms }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn same_key_same_cell() {
        let r = Registry::new();
        r.counter("a.b").incr(1);
        r.counter("a.b").incr(2);
        assert_eq!(r.counter("a.b").get(), 3);
        r.gauge("g").set(2.0);
        assert_eq!(r.gauge("g").get(), 2.0);
        r.histogram("h").record(9);
        assert_eq!(r.histogram("h").count(), 1);
    }

    #[test]
    fn snapshot_is_sorted_by_key() {
        let r = Registry::new();
        r.counter("z.last").incr(1);
        r.counter("a.first").incr(1);
        r.counter("m.mid").incr(1);
        let snap = r.snapshot();
        let keys: Vec<&str> = snap.counters.iter().map(|(k, _)| k.as_str()).collect();
        assert_eq!(keys, vec!["a.first", "m.mid", "z.last"]);
    }

    #[test]
    fn concurrent_increments_sum_exactly() {
        let r = Arc::new(Registry::new());
        let threads: Vec<_> = (0..8)
            .map(|_| {
                let r = r.clone();
                std::thread::spawn(move || {
                    let c = r.counter("concurrent");
                    for _ in 0..10_000 {
                        c.incr(1);
                    }
                })
            })
            .collect();
        for t in threads {
            t.join().unwrap();
        }
        assert_eq!(r.counter("concurrent").get(), 80_000);
    }
}
