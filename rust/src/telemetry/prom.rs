//! Prometheus-style plaintext exposition over TCP.
//!
//! Rides the same `std::net` stack as [`crate::transport::tcp`]: a
//! non-blocking accept loop on a background thread answers every
//! connection with one HTTP/1.0 response whose body is
//! [`crate::telemetry::Snapshot::render_prometheus`], then closes. This
//! satisfies both `curl http://host:port/metrics` and a raw
//! read-until-EOF TCP client.

use std::io::{Read as _, Write as _};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;
use std::time::Duration;

use anyhow::{Context, Result};

use super::registry::Registry;

const ACCEPT_POLL: Duration = Duration::from_millis(25);

/// Running exposition server. [`PromServer::stop`] joins the accept loop;
/// dropping without stop leaves the thread serving until process exit.
pub struct PromServer {
    port: u16,
    shutdown: Arc<AtomicBool>,
    handle: std::thread::JoinHandle<()>,
}

impl PromServer {
    /// Bind `127.0.0.1:port` (`port` 0 picks an ephemeral port; see
    /// [`PromServer::port`]) and start serving the process-global
    /// snapshot.
    pub fn bind(port: u16) -> Result<PromServer> {
        Self::bind_inner(port, None)
    }

    /// Like [`PromServer::bind`], but serving a private [`Registry`] —
    /// the sink side of a `@<prefix>`-filtered `--telemetry` spec.
    pub fn bind_with_source(port: u16, source: Arc<Registry>) -> Result<PromServer> {
        Self::bind_inner(port, Some(source))
    }

    fn bind_inner(port: u16, source: Option<Arc<Registry>>) -> Result<PromServer> {
        let listener = TcpListener::bind(("127.0.0.1", port))
            .with_context(|| format!("binding telemetry port {port}"))?;
        let port = listener.local_addr().context("telemetry local_addr")?.port();
        listener
            .set_nonblocking(true)
            .context("telemetry listener nonblocking")?;
        let shutdown = Arc::new(AtomicBool::new(false));
        let stop = shutdown.clone();
        let handle = std::thread::Builder::new()
            .name("ef21-telemetry-prom".into())
            .spawn(move || accept_loop(listener, stop, source))
            .context("spawning prom server")?;
        Ok(PromServer { port, shutdown, handle })
    }

    /// The bound port (useful when constructed with port 0).
    pub fn port(&self) -> u16 {
        self.port
    }

    pub fn stop(self) {
        self.shutdown.store(true, Ordering::SeqCst);
        let _ = self.handle.join();
    }
}

fn accept_loop(
    listener: TcpListener,
    shutdown: Arc<AtomicBool>,
    source: Option<Arc<Registry>>,
) {
    while !shutdown.load(Ordering::SeqCst) {
        match listener.accept() {
            Ok((stream, _)) => {
                // Serve inline; exposition is tiny and scrapes are rare.
                let _ = serve(stream, &source);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                std::thread::sleep(ACCEPT_POLL);
            }
            Err(_) => std::thread::sleep(ACCEPT_POLL),
        }
    }
}

fn serve(mut stream: TcpStream, source: &Option<Arc<Registry>>) -> std::io::Result<()> {
    // Drain whatever request line/headers the client sends (best-effort;
    // a raw TCP reader sends nothing and just waits for our bytes).
    stream.set_nonblocking(false)?;
    stream.set_read_timeout(Some(Duration::from_millis(200)))?;
    let mut req = [0u8; 1024];
    let _ = stream.read(&mut req);

    let body = match source {
        Some(reg) => reg.snapshot(),
        None => super::snapshot(),
    }
    .render_prometheus();
    stream.set_write_timeout(Some(Duration::from_secs(2)))?;
    write!(
        stream,
        "HTTP/1.0 200 OK\r\nContent-Type: text/plain; version=0.0.4\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
        body.len()
    )?;
    stream.write_all(body.as_bytes())?;
    stream.flush()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serves_exposition_and_stops() {
        let server = PromServer::bind(0).unwrap();
        let port = server.port();
        let mut conn = TcpStream::connect(("127.0.0.1", port)).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut text = String::new();
        conn.read_to_string(&mut text).unwrap();
        assert!(text.starts_with("HTTP/1.0 200 OK"), "got: {text}");
        assert!(text.contains("text/plain"));
        // stop() must join promptly (bounded by the accept poll interval).
        server.stop();
    }

    #[test]
    fn serves_a_private_source_registry() {
        let reg = Arc::new(Registry::new());
        reg.counter("prom.source.test").incr(11);
        let server = PromServer::bind_with_source(0, reg).unwrap();
        let mut conn = TcpStream::connect(("127.0.0.1", server.port())).unwrap();
        conn.write_all(b"GET /metrics HTTP/1.0\r\n\r\n").unwrap();
        let mut text = String::new();
        conn.read_to_string(&mut text).unwrap();
        server.stop();
        assert!(text.contains("ef21_prom_source_test 11"), "got: {text}");
    }
}
