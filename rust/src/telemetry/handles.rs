//! Metric handles and their lock-free atomic storage cells.
//!
//! A handle is a cheap, cloneable view onto a storage cell owned by a
//! [`crate::telemetry::Registry`]. The noop variant (`Counter::noop()` etc.)
//! carries no cell at all, so recording through it is a single branch on a
//! `None` — this is what makes disabled instrumentation cost ~1ns.
//!
//! Storage is plain atomics (no locks anywhere on the record path):
//!   * counters — `AtomicU64`, relaxed `fetch_add`;
//!   * gauges   — `AtomicU64` holding `f64::to_bits`, relaxed `store`;
//!   * histograms — 64 fixed power-of-two buckets (`bucket i` covers
//!     `[2^i, 2^(i+1))`, bucket 0 also absorbs 0), plus sum and count.
//!     Values are `u64` — by convention nanoseconds for `*.ns` keys.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Number of fixed log2 histogram buckets (covers the full u64 range).
pub const HISTOGRAM_BUCKETS: usize = 64;

/// Bucket index for a recorded value: `floor(log2(v))`, with 0 mapping to
/// bucket 0. Bucket `i` therefore covers `[2^i, 2^(i+1) - 1]` (bucket 0
/// covers `{0, 1}`).
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v == 0 {
        0
    } else {
        63 - v.leading_zeros() as usize
    }
}

/// Inclusive upper bound of bucket `i` (`u64::MAX` for the last bucket).
pub fn bucket_upper(i: usize) -> u64 {
    if i >= 63 {
        u64::MAX
    } else {
        (2u64 << i) - 1
    }
}

/// Inclusive lower bound of bucket `i`.
pub fn bucket_lower(i: usize) -> u64 {
    if i == 0 {
        0
    } else {
        1u64 << i
    }
}

/// Storage cell for a monotone counter.
#[derive(Debug, Default)]
pub struct CounterCell(AtomicU64);

impl CounterCell {
    #[inline]
    pub fn incr(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Storage cell for a last-value-wins gauge (f64 stored as bits).
#[derive(Debug, Default)]
pub struct GaugeCell(AtomicU64);

impl GaugeCell {
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Storage cell for a fixed-bucket log-scale histogram.
#[derive(Debug)]
pub struct HistogramCell {
    counts: [AtomicU64; HISTOGRAM_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
}

impl HistogramCell {
    pub fn new() -> Self {
        HistogramCell {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

impl Default for HistogramCell {
    fn default() -> Self {
        Self::new()
    }
}

/// Handle to a counter (None = noop).
#[derive(Clone, Debug, Default)]
pub struct Counter(Option<Arc<CounterCell>>);

impl Counter {
    pub fn noop() -> Counter {
        Counter(None)
    }

    pub(crate) fn from_cell(cell: Arc<CounterCell>) -> Counter {
        Counter(Some(cell))
    }

    #[inline]
    pub fn incr(&self, n: u64) {
        if let Some(c) = &self.0 {
            c.incr(n);
        }
    }

    /// Current value (0 for a noop handle).
    pub fn get(&self) -> u64 {
        self.0.as_ref().map(|c| c.get()).unwrap_or(0)
    }

    pub fn is_noop(&self) -> bool {
        self.0.is_none()
    }
}

/// Handle to a gauge (None = noop).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Option<Arc<GaugeCell>>);

impl Gauge {
    pub fn noop() -> Gauge {
        Gauge(None)
    }

    pub(crate) fn from_cell(cell: Arc<GaugeCell>) -> Gauge {
        Gauge(Some(cell))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        if let Some(g) = &self.0 {
            g.set(v);
        }
    }

    /// Current value (0.0 for a noop handle).
    pub fn get(&self) -> f64 {
        self.0.as_ref().map(|g| g.get()).unwrap_or(0.0)
    }

    pub fn is_noop(&self) -> bool {
        self.0.is_none()
    }
}

/// Handle to a histogram (None = noop).
#[derive(Clone, Debug, Default)]
pub struct Histogram(Option<Arc<HistogramCell>>);

impl Histogram {
    pub fn noop() -> Histogram {
        Histogram(None)
    }

    pub(crate) fn from_cell(cell: Arc<HistogramCell>) -> Histogram {
        Histogram(Some(cell))
    }

    #[inline]
    pub fn record(&self, v: u64) {
        if let Some(h) = &self.0 {
            h.record(v);
        }
    }

    /// Number of recorded samples (0 for a noop handle).
    pub fn count(&self) -> u64 {
        self.0.as_ref().map(|h| h.count()).unwrap_or(0)
    }

    pub fn is_noop(&self) -> bool {
        self.0.is_none()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        assert_eq!(bucket_index(0), 0);
        assert_eq!(bucket_index(1), 0);
        assert_eq!(bucket_index(2), 1);
        assert_eq!(bucket_index(3), 1);
        assert_eq!(bucket_index(4), 2);
        assert_eq!(bucket_index(1023), 9);
        assert_eq!(bucket_index(1024), 10);
        assert_eq!(bucket_index(u64::MAX), 63);
    }

    #[test]
    fn bucket_bounds_tile_the_range() {
        for i in 0..HISTOGRAM_BUCKETS {
            let lo = bucket_lower(i);
            let hi = bucket_upper(i);
            assert!(lo <= hi);
            assert_eq!(bucket_index(lo), i);
            assert_eq!(bucket_index(hi), i);
            if i + 1 < HISTOGRAM_BUCKETS {
                assert_eq!(hi + 1, bucket_lower(i + 1));
            }
        }
    }

    #[test]
    fn noop_handles_record_nothing() {
        let c = Counter::noop();
        c.incr(10);
        assert_eq!(c.get(), 0);
        assert!(c.is_noop());
        let g = Gauge::noop();
        g.set(3.5);
        assert_eq!(g.get(), 0.0);
        let h = Histogram::noop();
        h.record(7);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn live_handles_share_the_cell() {
        let c = Counter::from_cell(Arc::new(CounterCell::default()));
        let c2 = c.clone();
        c.incr(2);
        c2.incr(3);
        assert_eq!(c.get(), 5);

        let g = Gauge::from_cell(Arc::new(GaugeCell::default()));
        g.set(-1.25);
        assert_eq!(g.get(), -1.25);

        let h = Histogram::from_cell(Arc::new(HistogramCell::new()));
        h.record(0);
        h.record(5);
        assert_eq!(h.count(), 2);
    }
}
