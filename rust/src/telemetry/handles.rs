//! Metric handles and their lock-free atomic storage cells.
//!
//! A handle is a cheap, cloneable view onto storage owned by a
//! [`crate::telemetry::Registry`]. The noop variant (`Counter::noop()` etc.)
//! carries no cell at all, so recording through it is a single branch —
//! this is what makes disabled instrumentation cost ~1ns. A fanout
//! variant (built by [`Counter::fanout`] etc., used by
//! [`crate::telemetry::FanoutRecorder`]) carries several child handles
//! and forwards each record to all of them.
//!
//! Storage is plain atomics (no locks anywhere on the record path):
//!   * counters — `AtomicU64`, relaxed `fetch_add`;
//!   * gauges   — `AtomicU64` holding `f64::to_bits`, relaxed `store`;
//!   * histograms — fixed log-linear sub-buckets (HdrHistogram-style;
//!     see [`bucket_index`]) plus sum, count, and an exact running max.
//!     Values are `u64` — by convention nanoseconds for `*.ns` keys.
//!
//! # Sub-bucket layout
//!
//! Each power-of-two octave `[2^o, 2^(o+1))` is split into
//! [`SUB_BUCKETS`] = 16 equal-width linear sub-buckets, so a bucket's
//! width is at most `lower/16` and the midpoint quantile estimate in
//! [`crate::telemetry::HistogramSnapshot::quantile`] has relative error
//! ≤ ~6.25% (values below 32 get exact unit-width buckets). The previous
//! layout was one bucket per octave — up to 2× quantile error.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

/// Linear sub-buckets per power-of-two octave.
pub const SUB_BUCKETS: usize = 16;

/// Total fixed histogram buckets covering the full `u64` range:
/// unit-width buckets for `v < 32` (indices 0..=31), then 16 sub-buckets
/// for each octave `[2^o, 2^(o+1))`, `o` in 5..=63.
pub const HISTOGRAM_BUCKETS: usize = 32 + 59 * SUB_BUCKETS;

/// Bucket index for a recorded value (log-linear, HdrHistogram-style).
///
/// * `v < 32`: exact — index `v` (the two lowest "octave groups" are
///   unit-width, which also keeps the formula continuous at 32).
/// * `v >= 32`: with octave `o = floor(log2 v)` and `shift = o - 4`,
///   index = `(o-4)*16 + (v >> shift)` where `v >> shift` is in 16..=31
///   — the value's top five bits select the linear sub-bucket.
///
/// Bucket width is `2^(o-4)`, at most 1/16 of the bucket's lower bound.
#[inline]
pub fn bucket_index(v: u64) -> usize {
    if v < 32 {
        v as usize
    } else {
        let octave = 63 - v.leading_zeros() as usize; // >= 5 here
        let shift = octave - 4;
        (shift * SUB_BUCKETS) + (v >> shift) as usize
    }
}

/// Inclusive lower bound of bucket `i`.
pub fn bucket_lower(i: usize) -> u64 {
    if i < 32 {
        i as u64
    } else {
        let shift = i / SUB_BUCKETS - 1;
        ((i - shift * SUB_BUCKETS) as u64) << shift
    }
}

/// Inclusive upper bound of bucket `i` (`u64::MAX` for the last bucket).
pub fn bucket_upper(i: usize) -> u64 {
    if i + 1 >= HISTOGRAM_BUCKETS {
        u64::MAX
    } else {
        bucket_lower(i + 1) - 1
    }
}

/// Storage cell for a monotone counter.
#[derive(Debug, Default)]
pub struct CounterCell(AtomicU64);

impl CounterCell {
    #[inline]
    pub fn incr(&self, n: u64) {
        self.0.fetch_add(n, Ordering::Relaxed);
    }

    pub fn get(&self) -> u64 {
        self.0.load(Ordering::Relaxed)
    }
}

/// Storage cell for a last-value-wins gauge (f64 stored as bits).
#[derive(Debug, Default)]
pub struct GaugeCell(AtomicU64);

impl GaugeCell {
    #[inline]
    pub fn set(&self, v: f64) {
        self.0.store(v.to_bits(), Ordering::Relaxed);
    }

    pub fn get(&self) -> f64 {
        f64::from_bits(self.0.load(Ordering::Relaxed))
    }
}

/// Storage cell for a fixed-bucket log-linear histogram.
pub struct HistogramCell {
    counts: [AtomicU64; HISTOGRAM_BUCKETS],
    sum: AtomicU64,
    count: AtomicU64,
    max: AtomicU64,
}

impl HistogramCell {
    pub fn new() -> Self {
        HistogramCell {
            counts: std::array::from_fn(|_| AtomicU64::new(0)),
            sum: AtomicU64::new(0),
            count: AtomicU64::new(0),
            max: AtomicU64::new(0),
        }
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.counts[bucket_index(v)].fetch_add(1, Ordering::Relaxed);
        self.sum.fetch_add(v, Ordering::Relaxed);
        self.count.fetch_add(1, Ordering::Relaxed);
        self.max.fetch_max(v, Ordering::Relaxed);
    }

    pub fn count(&self) -> u64 {
        self.count.load(Ordering::Relaxed)
    }

    pub fn sum(&self) -> u64 {
        self.sum.load(Ordering::Relaxed)
    }

    /// Exact largest recorded value (0 when empty).
    pub fn max(&self) -> u64 {
        self.max.load(Ordering::Relaxed)
    }

    pub fn bucket_counts(&self) -> Vec<u64> {
        self.counts.iter().map(|c| c.load(Ordering::Relaxed)).collect()
    }
}

impl Default for HistogramCell {
    fn default() -> Self {
        Self::new()
    }
}

impl std::fmt::Debug for HistogramCell {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("HistogramCell")
            .field("count", &self.count())
            .field("sum", &self.sum())
            .field("max", &self.max())
            .finish()
    }
}

/// The three handle shapes shared by every metric kind: no storage,
/// one storage cell, or a fanout over child handles (recorder layering).
#[derive(Clone, Debug, Default)]
enum Repr<C> {
    #[default]
    Noop,
    Cell(Arc<C>),
    Fanout(Arc<[Handle<C>]>),
}

#[derive(Clone, Debug, Default)]
struct Handle<C>(Repr<C>);

impl<C> Handle<C> {
    fn fanout(children: Vec<Handle<C>>) -> Handle<C> {
        let mut live: Vec<Handle<C>> =
            children.into_iter().filter(|c| !matches!(c.0, Repr::Noop)).collect();
        match live.len() {
            0 => Handle(Repr::Noop),
            1 => live.pop().expect("len checked"),
            _ => Handle(Repr::Fanout(live.into())),
        }
    }

    #[inline]
    fn each(&self, f: &mut impl FnMut(&C)) {
        match &self.0 {
            Repr::Noop => {}
            Repr::Cell(c) => f(c),
            Repr::Fanout(children) => {
                for c in children.iter() {
                    c.each(f);
                }
            }
        }
    }

    /// The first live cell in issue order (the primary target — for a
    /// registry-then-layers fanout that is the global registry's cell).
    fn primary(&self) -> Option<&Arc<C>> {
        match &self.0 {
            Repr::Noop => None,
            Repr::Cell(c) => Some(c),
            Repr::Fanout(children) => children.iter().find_map(|c| c.primary()),
        }
    }

    fn is_noop(&self) -> bool {
        matches!(self.0, Repr::Noop)
    }
}

/// Handle to a counter (noop, single-cell, or fanout).
#[derive(Clone, Debug, Default)]
pub struct Counter(Handle<CounterCell>);

impl Counter {
    pub fn noop() -> Counter {
        Counter(Handle(Repr::Noop))
    }

    pub(crate) fn from_cell(cell: Arc<CounterCell>) -> Counter {
        Counter(Handle(Repr::Cell(cell)))
    }

    /// Combine handles into one that records to every live child
    /// (noop children are dropped; 0 live children collapse to noop).
    pub fn fanout(children: Vec<Counter>) -> Counter {
        Counter(Handle::fanout(children.into_iter().map(|c| c.0).collect()))
    }

    #[inline]
    pub fn incr(&self, n: u64) {
        self.0.each(&mut |c| c.incr(n));
    }

    /// Current value (0 for a noop handle; the first live target's value
    /// for a fanout handle).
    pub fn get(&self) -> u64 {
        self.0.primary().map(|c| c.get()).unwrap_or(0)
    }

    pub fn is_noop(&self) -> bool {
        self.0.is_noop()
    }
}

/// Handle to a gauge (noop, single-cell, or fanout).
#[derive(Clone, Debug, Default)]
pub struct Gauge(Handle<GaugeCell>);

impl Gauge {
    pub fn noop() -> Gauge {
        Gauge(Handle(Repr::Noop))
    }

    pub(crate) fn from_cell(cell: Arc<GaugeCell>) -> Gauge {
        Gauge(Handle(Repr::Cell(cell)))
    }

    /// See [`Counter::fanout`].
    pub fn fanout(children: Vec<Gauge>) -> Gauge {
        Gauge(Handle::fanout(children.into_iter().map(|c| c.0).collect()))
    }

    #[inline]
    pub fn set(&self, v: f64) {
        self.0.each(&mut |g| g.set(v));
    }

    /// Current value (0.0 for a noop handle; first live target for
    /// fanout).
    pub fn get(&self) -> f64 {
        self.0.primary().map(|g| g.get()).unwrap_or(0.0)
    }

    pub fn is_noop(&self) -> bool {
        self.0.is_noop()
    }
}

/// Handle to a histogram (noop, single-cell, or fanout).
#[derive(Clone, Debug, Default)]
pub struct Histogram(Handle<HistogramCell>);

impl Histogram {
    pub fn noop() -> Histogram {
        Histogram(Handle(Repr::Noop))
    }

    pub(crate) fn from_cell(cell: Arc<HistogramCell>) -> Histogram {
        Histogram(Handle(Repr::Cell(cell)))
    }

    /// See [`Counter::fanout`].
    pub fn fanout(children: Vec<Histogram>) -> Histogram {
        Histogram(Handle::fanout(children.into_iter().map(|c| c.0).collect()))
    }

    #[inline]
    pub fn record(&self, v: u64) {
        self.0.each(&mut |h| h.record(v));
    }

    /// Number of recorded samples (0 for a noop handle; first live
    /// target for fanout).
    pub fn count(&self) -> u64 {
        self.0.primary().map(|h| h.count()).unwrap_or(0)
    }

    pub fn is_noop(&self) -> bool {
        self.0.is_noop()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bucket_index_boundaries() {
        // Values below 32 map exactly to their own bucket.
        for v in 0..32u64 {
            assert_eq!(bucket_index(v), v as usize);
        }
        // First sub-bucketed octave: [32, 64) in width-2 buckets.
        assert_eq!(bucket_index(32), 32);
        assert_eq!(bucket_index(33), 32);
        assert_eq!(bucket_index(34), 33);
        assert_eq!(bucket_index(63), 47);
        assert_eq!(bucket_index(64), 48);
        // 1023 = 0b11_1111_1111: octave 9, top-five-bits sub-bucket 31.
        assert_eq!(bucket_index(1023), 5 * SUB_BUCKETS + 31);
        assert_eq!(bucket_index(1024), 6 * SUB_BUCKETS + 16);
        assert_eq!(bucket_index(u64::MAX), HISTOGRAM_BUCKETS - 1);
    }

    #[test]
    fn bucket_bounds_tile_the_range() {
        for i in 0..HISTOGRAM_BUCKETS {
            let lo = bucket_lower(i);
            let hi = bucket_upper(i);
            assert!(lo <= hi, "bucket {i}");
            assert_eq!(bucket_index(lo), i);
            assert_eq!(bucket_index(hi), i);
            if i + 1 < HISTOGRAM_BUCKETS {
                assert_eq!(hi + 1, bucket_lower(i + 1));
            } else {
                assert_eq!(hi, u64::MAX);
            }
        }
    }

    #[test]
    fn bucket_width_is_within_one_sixteenth_of_lower_bound() {
        // The documented quantile error bound: width <= lower/16 for all
        // sub-bucketed values (exact below 32).
        for i in 32..HISTOGRAM_BUCKETS - 1 {
            let lo = bucket_lower(i);
            let width = bucket_upper(i) - lo + 1;
            assert!(width * 16 <= lo, "bucket {i}: width {width} vs lower {lo}");
        }
    }

    #[test]
    fn histogram_cell_tracks_exact_max() {
        let h = HistogramCell::new();
        assert_eq!(h.max(), 0);
        h.record(17);
        h.record(100_000);
        h.record(99);
        assert_eq!(h.max(), 100_000);
        assert_eq!(h.count(), 3);
        assert_eq!(h.sum(), 17 + 100_000 + 99);
    }

    #[test]
    fn noop_handles_record_nothing() {
        let c = Counter::noop();
        c.incr(10);
        assert_eq!(c.get(), 0);
        assert!(c.is_noop());
        let g = Gauge::noop();
        g.set(3.5);
        assert_eq!(g.get(), 0.0);
        let h = Histogram::noop();
        h.record(7);
        assert_eq!(h.count(), 0);
    }

    #[test]
    fn live_handles_share_the_cell() {
        let c = Counter::from_cell(Arc::new(CounterCell::default()));
        let c2 = c.clone();
        c.incr(2);
        c2.incr(3);
        assert_eq!(c.get(), 5);

        let g = Gauge::from_cell(Arc::new(GaugeCell::default()));
        g.set(-1.25);
        assert_eq!(g.get(), -1.25);

        let h = Histogram::from_cell(Arc::new(HistogramCell::new()));
        h.record(0);
        h.record(5);
        assert_eq!(h.count(), 2);
    }

    #[test]
    fn fanout_records_into_every_child() {
        let a = Arc::new(CounterCell::default());
        let b = Arc::new(CounterCell::default());
        let f = Counter::fanout(vec![
            Counter::from_cell(a.clone()),
            Counter::noop(),
            Counter::from_cell(b.clone()),
        ]);
        assert!(!f.is_noop());
        f.incr(5);
        assert_eq!(a.get(), 5);
        assert_eq!(b.get(), 5);
        // get() reads the first live target.
        assert_eq!(f.get(), 5);

        let ha = Arc::new(HistogramCell::new());
        let hb = Arc::new(HistogramCell::new());
        let fh = Histogram::fanout(vec![
            Histogram::from_cell(ha.clone()),
            Histogram::from_cell(hb.clone()),
        ]);
        fh.record(9);
        assert_eq!(ha.count(), 1);
        assert_eq!(hb.count(), 1);
    }

    #[test]
    fn fanout_collapses_noops() {
        assert!(Counter::fanout(vec![]).is_noop());
        assert!(Counter::fanout(vec![Counter::noop(), Counter::noop()]).is_noop());
        // A single live child collapses to a plain cell handle.
        let a = Arc::new(CounterCell::default());
        let f = Counter::fanout(vec![Counter::noop(), Counter::from_cell(a.clone())]);
        f.incr(1);
        assert_eq!(a.get(), 1);
    }
}
