//! The recorder facade: the object instrumented code talks to.
//!
//! Mirrors the metrics-rs split between the facade (handle issuance) and
//! storage: a [`Recorder`] hands out [`Counter`]/[`Gauge`]/[`Histogram`]
//! handles for string keys. Two implementations:
//!   * [`NoopRecorder`] — the process-global default; every handle is a
//!     noop, so instrumentation on disabled processes costs ~1ns.
//!   * [`RegistryRecorder`] — issues live handles backed by a
//!     [`Registry`]'s atomic cells.

use super::handles::{Counter, Gauge, Histogram};
use super::registry::Registry;
use super::snapshot::Snapshot;
use std::sync::Arc;

/// Issues metric handles; the seam between instrumentation and storage.
pub trait Recorder: Send + Sync {
    fn counter(&self, key: &str) -> Counter;
    fn gauge(&self, key: &str) -> Gauge;
    fn histogram(&self, key: &str) -> Histogram;

    /// Observer side: sorted key→value view (empty for noop).
    fn snapshot(&self) -> Snapshot {
        Snapshot::default()
    }
}

/// Default recorder: hands out noop handles only.
pub struct NoopRecorder;

impl Recorder for NoopRecorder {
    fn counter(&self, _key: &str) -> Counter {
        Counter::noop()
    }

    fn gauge(&self, _key: &str) -> Gauge {
        Gauge::noop()
    }

    fn histogram(&self, _key: &str) -> Histogram {
        Histogram::noop()
    }
}

/// Recorder backed by a shared [`Registry`].
pub struct RegistryRecorder {
    registry: Arc<Registry>,
}

impl RegistryRecorder {
    pub fn new(registry: Arc<Registry>) -> RegistryRecorder {
        RegistryRecorder { registry }
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }
}

impl Recorder for RegistryRecorder {
    fn counter(&self, key: &str) -> Counter {
        self.registry.counter(key)
    }

    fn gauge(&self, key: &str) -> Gauge {
        self.registry.gauge(key)
    }

    fn histogram(&self, key: &str) -> Histogram {
        self.registry.histogram(key)
    }

    fn snapshot(&self) -> Snapshot {
        self.registry.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_recorder_is_inert() {
        let r = NoopRecorder;
        r.counter("x").incr(1);
        r.gauge("y").set(1.0);
        r.histogram("z").record(1);
        assert!(r.counter("x").is_noop());
        assert!(r.snapshot().is_empty());
    }

    #[test]
    fn registry_recorder_round_trips() {
        let r = RegistryRecorder::new(Arc::new(Registry::new()));
        r.counter("c").incr(7);
        r.gauge("g").set(0.5);
        r.histogram("h").record(3);
        let s = r.snapshot();
        assert_eq!(s.counter("c"), Some(7));
        assert_eq!(s.gauge("g"), Some(0.5));
        assert_eq!(s.histogram("h").unwrap().count, 1);
    }
}
