//! The recorder facade: the object instrumented code talks to.
//!
//! Mirrors the metrics-rs split between the facade (handle issuance) and
//! storage: a [`Recorder`] hands out [`Counter`]/[`Gauge`]/[`Histogram`]
//! handles for string keys. Four implementations:
//!   * [`NoopRecorder`] — the process-global default; every handle is a
//!     noop, so instrumentation on disabled processes costs ~1ns.
//!   * [`RegistryRecorder`] — issues live handles backed by a
//!     [`Registry`]'s atomic cells.
//!   * [`FanoutRecorder`] — composes several recorders; every issued
//!     handle records into all of them (metrics-rs layer-style). The cost
//!     is paid once at handle issuance: the returned handle holds the
//!     per-target cells directly, so the record path is still lock-free.
//!   * [`FilterRecorder`] — key-prefix allowlist in front of another
//!     recorder; non-matching keys get noop handles. This is how a sink
//!     subscribes to a slice of the key space (e.g. `jsonl:x@sched.`).

use super::handles::{Counter, Gauge, Histogram};
use super::registry::Registry;
use super::snapshot::Snapshot;
use std::sync::Arc;

/// Issues metric handles; the seam between instrumentation and storage.
pub trait Recorder: Send + Sync {
    fn counter(&self, key: &str) -> Counter;
    fn gauge(&self, key: &str) -> Gauge;
    fn histogram(&self, key: &str) -> Histogram;

    /// Observer side: sorted key→value view (empty for noop).
    fn snapshot(&self) -> Snapshot {
        Snapshot::default()
    }
}

/// Default recorder: hands out noop handles only.
pub struct NoopRecorder;

impl Recorder for NoopRecorder {
    fn counter(&self, _key: &str) -> Counter {
        Counter::noop()
    }

    fn gauge(&self, _key: &str) -> Gauge {
        Gauge::noop()
    }

    fn histogram(&self, _key: &str) -> Histogram {
        Histogram::noop()
    }
}

/// Recorder backed by a shared [`Registry`].
pub struct RegistryRecorder {
    registry: Arc<Registry>,
}

impl RegistryRecorder {
    pub fn new(registry: Arc<Registry>) -> RegistryRecorder {
        RegistryRecorder { registry }
    }

    pub fn registry(&self) -> &Arc<Registry> {
        &self.registry
    }
}

impl Recorder for RegistryRecorder {
    fn counter(&self, key: &str) -> Counter {
        self.registry.counter(key)
    }

    fn gauge(&self, key: &str) -> Gauge {
        self.registry.gauge(key)
    }

    fn histogram(&self, key: &str) -> Histogram {
        self.registry.histogram(key)
    }

    fn snapshot(&self) -> Snapshot {
        self.registry.snapshot()
    }
}

/// Composes recorders: issued handles record into every target. The
/// first target is the primary — its cells answer `get()`/`count()` on
/// the issued handles, and its snapshot is the fanout's snapshot.
pub struct FanoutRecorder {
    targets: Vec<Arc<dyn Recorder>>,
}

impl FanoutRecorder {
    pub fn new(targets: Vec<Arc<dyn Recorder>>) -> FanoutRecorder {
        FanoutRecorder { targets }
    }
}

impl Recorder for FanoutRecorder {
    fn counter(&self, key: &str) -> Counter {
        Counter::fanout(self.targets.iter().map(|t| t.counter(key)).collect())
    }

    fn gauge(&self, key: &str) -> Gauge {
        Gauge::fanout(self.targets.iter().map(|t| t.gauge(key)).collect())
    }

    fn histogram(&self, key: &str) -> Histogram {
        Histogram::fanout(self.targets.iter().map(|t| t.histogram(key)).collect())
    }

    fn snapshot(&self) -> Snapshot {
        self.targets.first().map(|t| t.snapshot()).unwrap_or_default()
    }
}

/// Key-prefix allowlist in front of another recorder: keys matching any
/// prefix get the inner recorder's handle, everything else gets noop.
/// An empty prefix list matches every key (a transparent layer).
pub struct FilterRecorder {
    prefixes: Vec<String>,
    inner: Arc<dyn Recorder>,
}

impl FilterRecorder {
    pub fn new(prefixes: Vec<String>, inner: Arc<dyn Recorder>) -> FilterRecorder {
        FilterRecorder { prefixes, inner }
    }

    fn matches(&self, key: &str) -> bool {
        self.prefixes.is_empty() || self.prefixes.iter().any(|p| key.starts_with(p.as_str()))
    }
}

impl Recorder for FilterRecorder {
    fn counter(&self, key: &str) -> Counter {
        if self.matches(key) {
            self.inner.counter(key)
        } else {
            Counter::noop()
        }
    }

    fn gauge(&self, key: &str) -> Gauge {
        if self.matches(key) {
            self.inner.gauge(key)
        } else {
            Gauge::noop()
        }
    }

    fn histogram(&self, key: &str) -> Histogram {
        if self.matches(key) {
            self.inner.histogram(key)
        } else {
            Histogram::noop()
        }
    }

    fn snapshot(&self) -> Snapshot {
        self.inner.snapshot()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn noop_recorder_is_inert() {
        let r = NoopRecorder;
        r.counter("x").incr(1);
        r.gauge("y").set(1.0);
        r.histogram("z").record(1);
        assert!(r.counter("x").is_noop());
        assert!(r.snapshot().is_empty());
    }

    #[test]
    fn registry_recorder_round_trips() {
        let r = RegistryRecorder::new(Arc::new(Registry::new()));
        r.counter("c").incr(7);
        r.gauge("g").set(0.5);
        r.histogram("h").record(3);
        let s = r.snapshot();
        assert_eq!(s.counter("c"), Some(7));
        assert_eq!(s.gauge("g"), Some(0.5));
        assert_eq!(s.histogram("h").unwrap().count, 1);
    }

    #[test]
    fn fanout_records_into_all_targets_and_reads_the_primary() {
        let a = Arc::new(Registry::new());
        let b = Arc::new(Registry::new());
        let fan = FanoutRecorder::new(vec![
            Arc::new(RegistryRecorder::new(a.clone())) as Arc<dyn Recorder>,
            Arc::new(RegistryRecorder::new(b.clone())),
        ]);
        let c = fan.counter("fan.c");
        c.incr(4);
        fan.gauge("fan.g").set(1.5);
        fan.histogram("fan.h").record(33);
        assert_eq!(a.snapshot().counter("fan.c"), Some(4));
        assert_eq!(b.snapshot().counter("fan.c"), Some(4));
        assert_eq!(b.snapshot().gauge("fan.g"), Some(1.5));
        assert_eq!(a.snapshot().histogram("fan.h").unwrap().count, 1);
        // Handle reads and the fanout snapshot come from the primary.
        assert_eq!(c.get(), 4);
        assert_eq!(fan.snapshot().counter("fan.c"), Some(4));
        // Empty fanout degenerates to noop handles.
        assert!(FanoutRecorder::new(vec![]).counter("x").is_noop());
    }

    #[test]
    fn filter_passes_matching_prefixes_only() {
        let reg = Arc::new(Registry::new());
        let f = FilterRecorder::new(
            vec!["sched.".into(), "coordinator.round".into()],
            Arc::new(RegistryRecorder::new(reg.clone())),
        );
        f.counter("sched.drops").incr(2);
        f.counter("transport.uplink.bits").incr(99);
        f.histogram("coordinator.round.ns").record(10);
        assert!(f.counter("transport.uplink.bits").is_noop());
        let s = reg.snapshot();
        assert_eq!(s.counter("sched.drops"), Some(2));
        assert_eq!(s.counter("transport.uplink.bits"), None);
        assert_eq!(s.histogram("coordinator.round.ns").unwrap().count, 1);
        // Empty prefix list is a transparent layer.
        let open = FilterRecorder::new(vec![], Arc::new(RegistryRecorder::new(reg)));
        assert!(!open.counter("anything.goes").is_noop());
    }
}
